"""Make `compile.*` importable whether pytest runs from the repo root
(`pytest python/tests/`) or from `python/` (the Makefile path)."""

import os
import sys

sys.path.insert(0, os.path.dirname(__file__))

import hypothesis

# One profile for every test module: JIT compilation on first call blows
# the default 200 ms deadline and trips FlakyFailure.
hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=25, derandomize=True
)
hypothesis.settings.load_profile("ci")
