"""L1 Pallas kernels vs pure-jnp oracles — the core correctness signal.

hypothesis sweeps shapes, dtypes, tilings and data seeds; integer paths
must match the oracle exactly, float paths to tight tolerance.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels import hwce_conv3x3, hwce_conv5x5, matmul, matmul_f32, matmul_int8
from compile.kernels import ref

# hypothesis profile loaded in conftest.py


def _rand_i8(rng, shape):
    return jnp.asarray(rng.integers(-128, 128, size=shape, dtype=np.int64).astype(np.int8))


def _rand_i16(rng, shape):
    # "16-bit" HWCE operands; keep magnitudes modest so int32 accum is exact.
    return jnp.asarray(rng.integers(-1 << 11, 1 << 11, size=shape).astype(np.int16))


# ---------------------------------------------------------------- matmul

@given(
    m=st.sampled_from([1, 2, 4, 8, 16]),
    k=st.sampled_from([1, 4, 8, 32]),
    n=st.sampled_from([1, 4, 8, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_int8_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a, b = _rand_i8(rng, (m, k)), _rand_i8(rng, (k, n))
    got = matmul_int8(a, b)
    want = ref.matmul_ref(a, b)
    assert got.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(
    bm=st.sampled_from([2, 4, 8]),
    bn=st.sampled_from([2, 4, 8]),
    bk=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_int8_tiling_invariance(bm, bn, bk, seed):
    """Any legal tiling produces the identical result (K-accumulation)."""
    rng = np.random.default_rng(seed)
    a, b = _rand_i8(rng, (8, 8)), _rand_i8(rng, (8, 8))
    got = matmul_int8(a, b, block_m=bm, block_n=bn, block_k=bk)
    want = ref.matmul_ref(a, b)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(seed=st.integers(0, 2**31 - 1))
def test_matmul_f32_matches_ref(seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((16, 24), dtype=np.float32))
    b = jnp.asarray(rng.standard_normal((24, 8), dtype=np.float32))
    got = matmul_f32(a, b, block_k=8)
    want = ref.matmul_ref(a, b, accum_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_matmul_rejects_bad_shapes():
    a = jnp.zeros((4, 5), jnp.int8)
    b = jnp.zeros((4, 4), jnp.int8)
    with pytest.raises(AssertionError):
        matmul_int8(a, b)


# ---------------------------------------------------------------- conv3x3

@given(
    h=st.sampled_from([1, 2, 4, 8]),
    w=st.sampled_from([1, 4, 8]),
    cin=st.sampled_from([1, 4, 8, 16]),
    cout=st.sampled_from([1, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hwce_conv3x3_matches_ref(h, w, cin, cout, seed):
    rng = np.random.default_rng(seed)
    x = _rand_i8(rng, (h + 2, w + 2, cin))
    k = _rand_i8(rng, (3, 3, cin, cout))
    got = hwce_conv3x3(x, k)
    want = ref.conv3x3_ref(x, k)
    assert got.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(
    bci=st.sampled_from([1, 2, 4, 8]),
    bco=st.sampled_from([1, 2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hwce_conv3x3_channel_tiling_invariance(bci, bco, seed):
    """Cin-tile accumulation (the partial-sum FIFO analogue) is exact."""
    rng = np.random.default_rng(seed)
    x = _rand_i8(rng, (6, 6, 8))
    k = _rand_i8(rng, (3, 3, 8, 8))
    got = hwce_conv3x3(x, k, block_ci=bci, block_co=bco)
    want = ref.conv3x3_ref(x, k)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(seed=st.integers(0, 2**31 - 1))
def test_hwce_conv3x3_int16_operands(seed):
    """Multi-precision path: 16-bit operands accumulate exactly (the HWCE
    upscales all sub-words to 16 bit before the CSA tree)."""
    rng = np.random.default_rng(seed)
    x = _rand_i16(rng, (5, 5, 4))
    k = _rand_i16(rng, (3, 3, 4, 4))
    got = hwce_conv3x3(x, k)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.conv3x3_ref(x, k)))


def test_hwce_conv3x3_4bit_subrange():
    """4-bit operands are the int8 path restricted to [-8, 7]."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(-8, 8, size=(6, 6, 8)).astype(np.int8))
    k = jnp.asarray(rng.integers(-8, 8, size=(3, 3, 8, 4)).astype(np.int8))
    np.testing.assert_array_equal(
        np.asarray(hwce_conv3x3(x, k)), np.asarray(ref.conv3x3_ref(x, k))
    )


def test_hwce_conv3x3_identity_filter():
    """A centre-tap identity filter returns the unpadded input."""
    rng = np.random.default_rng(1)
    x = _rand_i8(rng, (6, 6, 3))
    k = np.zeros((3, 3, 3, 3), np.int8)
    for c in range(3):
        k[1, 1, c, c] = 1
    got = hwce_conv3x3(x, jnp.asarray(k))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x[1:5, 1:5, :], dtype=np.int32))


@given(seed=st.integers(0, 2**31 - 1))
def test_hwce_conv5x5_matches_ref(seed):
    """5x5 mode composed from 3x3 units matches a direct 5x5 conv."""
    rng = np.random.default_rng(seed)
    x = _rand_i8(rng, (9, 9, 4))
    k = _rand_i8(rng, (5, 5, 4, 4))
    got = hwce_conv5x5(x, k)
    want = ref.conv5x5_ref(x, k)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_conv_linearity():
    """conv(x, k1 + k2) == conv(x, k1) + conv(x, k2) — the RepVGG
    reparameterisation identity that makes deploy-mode equivalent."""
    rng = np.random.default_rng(7)
    x = _rand_i8(rng, (6, 6, 4))
    k1 = jnp.asarray(rng.integers(-50, 50, size=(3, 3, 4, 4)).astype(np.int8))
    k2 = jnp.asarray(rng.integers(-50, 50, size=(3, 3, 4, 4)).astype(np.int8))
    lhs = hwce_conv3x3(x, (k1.astype(jnp.int32) + k2).astype(jnp.int8))
    rhs = hwce_conv3x3(x, k1) + hwce_conv3x3(x, k2)
    np.testing.assert_array_equal(np.asarray(lhs), np.asarray(rhs))
