"""AOT pipeline: HLO text artifacts parse, manifest is consistent."""

import os

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    lines = aot.lower_all(str(out))
    return str(out), lines


def test_all_entries_lowered(built):
    out, lines = built
    assert len(lines) == len(model.AOT_ENTRIES)
    for name, _, _ in model.AOT_ENTRIES:
        p = os.path.join(out, f"{name}.hlo.txt")
        assert os.path.exists(p), p
        text = open(p).read()
        assert text.startswith("HloModule"), text[:60]
        assert "ENTRY" in text


def test_manifest_format(built):
    out, lines = built
    manifest = open(os.path.join(out, "manifest.txt")).read().strip().splitlines()
    assert manifest == lines
    for line in manifest:
        name, ins, outs = line.split(";")
        assert ins.startswith("in=") and outs.startswith("out=")


def test_matmul_artifact_signature(built):
    out, lines = built
    line = next(l for l in lines if l.startswith("matmul_int8_64;"))
    assert line == "matmul_int8_64;in=s8[64,64],s8[64,64];out=s32[64,64]"


def test_hlo_is_tupled(built):
    """Rust unwraps with to_tuple1 — the root must be a tuple."""
    out, _ = built
    text = open(os.path.join(out, "matmul_int8_64.hlo.txt")).read()
    root = [l for l in text.splitlines() if "ROOT" in l and "tuple" in l]
    assert root, "expected ROOT tuple in entry computation"
