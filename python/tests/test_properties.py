"""Cross-kernel invariants (hypothesis): algebraic properties the HWCE
datapath and the quantization pipeline must satisfy regardless of tiling.
These mirror the Rust-side property tests so both functional models are
held to the same contracts."""

import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
from hypothesis import given

from compile import model
from compile.kernels import hwce_conv3x3, matmul_int8
from compile.kernels import ref


def _i8(rng, shape, lim=127):
    return jnp.asarray(rng.integers(-lim, lim + 1, size=shape).astype(np.int8))


@given(seed=st.integers(0, 2**31 - 1))
def test_conv_distributes_over_input_sum(seed):
    """conv(x1 + x2, k) == conv(x1, k) + conv(x2, k) in int32 (exact)."""
    rng = np.random.default_rng(seed)
    x1 = _i8(rng, (6, 6, 4), 50)
    x2 = _i8(rng, (6, 6, 4), 50)
    k = _i8(rng, (3, 3, 4, 4), 64)
    xs = (x1.astype(jnp.int32) + x2.astype(jnp.int32)).astype(jnp.int8)
    lhs = hwce_conv3x3(xs, k)
    rhs = hwce_conv3x3(x1, k) + hwce_conv3x3(x2, k)
    np.testing.assert_array_equal(np.asarray(lhs), np.asarray(rhs))


@given(seed=st.integers(0, 2**31 - 1), scale=st.sampled_from([-2, -1, 1, 3]))
def test_matmul_scales_linearly(seed, scale):
    """matmul(s*A, B) == s * matmul(A, B) for small scalars (int32 exact)."""
    rng = np.random.default_rng(seed)
    a = _i8(rng, (8, 8), 40)
    b = _i8(rng, (8, 8), 40)
    sa = (a.astype(jnp.int32) * scale).astype(jnp.int8)
    lhs = matmul_int8(sa, b)
    rhs = scale * matmul_int8(a, b)
    np.testing.assert_array_equal(np.asarray(lhs), np.asarray(rhs))


@given(seed=st.integers(0, 2**31 - 1))
def test_matmul_transpose_symmetry(seed):
    """(A B)^T == B^T A^T — catches layout/indexing bugs in the kernel."""
    rng = np.random.default_rng(seed)
    a = _i8(rng, (8, 12))
    b = _i8(rng, (12, 4))
    lhs = matmul_int8(a, b).T
    rhs = matmul_int8(b.T, a.T)
    np.testing.assert_array_equal(np.asarray(lhs), np.asarray(rhs))


@given(seed=st.integers(0, 2**31 - 1), shift=st.integers(0, 12))
def test_requantize_monotone(seed, shift):
    """Requantisation preserves ordering (monotone non-decreasing)."""
    rng = np.random.default_rng(seed)
    acc = jnp.asarray(np.sort(rng.integers(-(1 << 20), 1 << 20, size=64)).astype(np.int32))
    q = np.asarray(model.requantize(acc, shift, relu=False)).astype(np.int64)
    assert (np.diff(q) >= 0).all()


@given(seed=st.integers(0, 2**31 - 1))
def test_repvgg_reparam_requant_commutes_with_branch_merge(seed):
    """Deploy-time RepVGG: conv with (k3 + pad(k1)) equals the merged
    branches — the re-parameterisation the HWCE-only flow relies on."""
    rng = np.random.default_rng(seed)
    x = _i8(rng, (6, 6, 4), 30)
    k3 = _i8(rng, (3, 3, 4, 4), 20)
    k1 = _i8(rng, (1, 1, 4, 4), 20)
    k1_padded = jnp.pad(k1, ((1, 1), (1, 1), (0, 0), (0, 0)))
    merged = (k3.astype(jnp.int32) + k1_padded).astype(jnp.int8)
    lhs = hwce_conv3x3(x, merged)
    rhs = hwce_conv3x3(x, k3) + ref.conv3x3_ref(x, k1_padded)
    np.testing.assert_array_equal(np.asarray(lhs), np.asarray(rhs))


def test_mbv2_bottleneck_without_residual_is_composition():
    """The bottleneck equals the explicit composition of its three stages."""
    rng = np.random.default_rng(12)
    x = _i8(rng, (6, 6, 8))
    we, wd, wp = _i8(rng, (8, 32)), _i8(rng, (3, 3, 32)), _i8(rng, (32, 8))
    out = model.mbv2_bottleneck(x, we, wd, wp, (6, 6, 6), residual=False)
    h = model.conv1x1_int8(x, we, 6, relu=True)
    h = model.depthwise3x3_int8(jnp.pad(h, ((1, 1), (1, 1), (0, 0))), wd, 6, relu=True)
    want = model.conv1x1_int8(h, wp, 6, relu=False)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))
