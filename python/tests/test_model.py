"""L2 model graphs: shapes, quantization ranges, block semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def _rand_i8(rng, shape):
    return jnp.asarray(rng.integers(-128, 128, size=shape).astype(np.int8))


def test_requantize_range_and_relu():
    acc = jnp.asarray([-(1 << 20), -256, -1, 0, 255, 1 << 20], jnp.int32)
    q = model.requantize(acc, 4, relu=True)
    assert q.dtype == jnp.int8
    assert int(q.min()) >= 0 and int(q.max()) <= 127
    q2 = model.requantize(acc, 4, relu=False)
    assert int(q2.min()) == -128 and int(q2.max()) == 127


def test_requantize_matches_ref_without_relu():
    rng = np.random.default_rng(3)
    acc = jnp.asarray(rng.integers(-(1 << 16), 1 << 16, size=(32,)).astype(np.int32))
    np.testing.assert_array_equal(
        np.asarray(model.requantize(acc, 6, relu=False)),
        np.asarray(ref.requantize_ref(acc, 6)),
    )


def test_conv1x1_equals_per_pixel_matmul():
    rng = np.random.default_rng(0)
    x = _rand_i8(rng, (4, 5, 8))
    w = _rand_i8(rng, (8, 12))
    out = model.conv1x1_int8(x, w, shift=5)
    assert out.shape == (4, 5, 12)
    want = model.requantize(
        ref.matmul_ref(x.reshape(20, 8), w), 5, relu=True
    ).reshape(4, 5, 12)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


def test_mbv2_bottleneck_shapes_and_residual():
    rng = np.random.default_rng(1)
    x = _rand_i8(rng, (8, 8, 16))
    we, wd, wp = _rand_i8(rng, (16, 64)), _rand_i8(rng, (3, 3, 64)), _rand_i8(rng, (64, 16))
    out = model.mbv2_bottleneck(x, we, wd, wp, (7, 7, 7), residual=True)
    assert out.shape == x.shape and out.dtype == jnp.int8
    out_nores = model.mbv2_bottleneck(x, we, wd, wp, (7, 7, 7), residual=False)
    # residual = clip(proj + x): recompute from the non-residual output
    want = jnp.clip(
        out_nores.astype(jnp.int32) + x.astype(jnp.int32), -128, 127
    ).astype(jnp.int8)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


def test_repvgg_block_is_conv_relu():
    rng = np.random.default_rng(2)
    x = _rand_i8(rng, (10, 10, 8))
    w = _rand_i8(rng, (3, 3, 8, 8))
    out = model.repvgg_block(x, w, shift=7)
    assert out.shape == (8, 8, 8)
    want = model.requantize(ref.conv3x3_ref(x, w), 7, relu=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))
    assert int(out.min()) >= 0  # ReLU folded into requant


@pytest.mark.parametrize("name,fn,args", model.AOT_ENTRIES)
def test_aot_entries_evaluate(name, fn, args):
    """Every AOT entry runs end-to-end on concrete data and returns a
    1-tuple with the manifest shape."""
    rng = np.random.default_rng(42)
    concrete = [
        jnp.asarray(rng.integers(-8, 8, size=s.shape).astype(s.dtype)) for s in args
    ]
    out = fn(*concrete)
    assert isinstance(out, tuple) and len(out) == 1
    want = jax.eval_shape(fn, *args)[0]
    assert out[0].shape == want.shape and out[0].dtype == want.dtype
