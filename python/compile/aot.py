"""AOT pipeline: lower the L2 graphs to HLO *text* artifacts for Rust.

HLO text (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the published `xla` 0.1.6 crate) rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly. Lowered with return_tuple=True, unwrapped with to_tuple1() on the
Rust side. See /opt/xla-example/gen_hlo.py.

Also writes artifacts/manifest.txt describing each artifact's signature so
the Rust runtime can construct correctly-shaped literals:

    name;in=s8[64,64],s8[64,64];out=s32[64,64]
"""

from __future__ import annotations

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from .model import AOT_ENTRIES

_DTYPE_NAMES = {
    "int8": "s8",
    "int32": "s32",
    "float32": "f32",
    "float16": "f16",
    "bfloat16": "bf16",
}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sig(structs) -> str:
    parts = []
    for s in structs:
        dt = _DTYPE_NAMES[str(s.dtype)]
        parts.append(f"{dt}[{','.join(str(d) for d in s.shape)}]")
    return ",".join(parts)


def lower_all(out_dir: str) -> list[str]:
    os.makedirs(out_dir, exist_ok=True)
    manifest_lines = []
    for name, fn, args in AOT_ENTRIES:
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        out_structs = jax.eval_shape(fn, *args)
        manifest_lines.append(f"{name};in={_sig(args)};out={_sig(out_structs)}")
        print(f"  {name}: {len(text)} chars -> {path}")
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    return manifest_lines


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    lines = lower_all(args.out_dir)
    print(f"wrote {len(lines)} artifacts + manifest to {args.out_dir}")


if __name__ == "__main__":
    main()
