"""Layer-2: the paper's DNN compute graphs in JAX, calling the L1 kernels.

Vega's DNN evaluation (section IV-B) runs int8 quantized inference of
MobileNetV2 bottlenecks and RepVGG 3x3 stages through PULP-NN (software) or
the HWCE (hardware). These graphs are the build-time source of truth for
the numerics: they are AOT-lowered to HLO text by aot.py and executed from
the Rust coordinator through PJRT, where they serve as golden models for
the simulator's functional datapaths.

Quantization scheme (PULP-NN style): int8 tensors, int32 accumulation,
requantisation by arithmetic right shift + saturating clip. ReLU is folded
into the clip-low bound (0) of the requantisation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import hwce_conv3x3, matmul_int8
from .kernels.ref import depthwise3x3_ref


def requantize(acc, shift, relu=True):
    """int32 -> int8: arithmetic shift, optional fused ReLU, saturate."""
    q = jnp.right_shift(acc, shift)
    lo = 0 if relu else -128
    return jnp.clip(q, lo, 127).astype(jnp.int8)


def conv1x1_int8(x, w, shift, relu=True):
    """Pointwise conv as the PULP-NN matmul kernel over pixels.

    x: (H, W, Cin) int8; w: (Cin, Cout) int8 -> (H, W, Cout) int8.
    """
    h, wd, cin = x.shape
    acc = matmul_int8(x.reshape(h * wd, cin), w)
    return requantize(acc, shift, relu).reshape(h, wd, w.shape[1])


def conv3x3_int8(x_padded, w, shift, relu=True):
    """3x3 conv on the HWCE kernel + output requant stage.

    x_padded: (H+2, W+2, Cin) int8; w: (3, 3, Cin, Cout) int8.
    """
    acc = hwce_conv3x3(x_padded, w)
    return requantize(acc, shift, relu)


def depthwise3x3_int8(x_padded, w, shift, relu=True):
    """3x3 depthwise conv (not HWCE-accelerated on Vega either; the paper
    runs MobileNetV2 depthwise layers in software on the cluster)."""
    acc = depthwise3x3_ref(x_padded, w)
    return requantize(acc, shift, relu)


def _pad_hw(x):
    return jnp.pad(x, ((1, 1), (1, 1), (0, 0)))


def mbv2_bottleneck(x, w_exp, w_dw, w_proj, shifts, residual=True):
    """MobileNetV2 BottleNeck (section IV-B): 1x1 expand -> 3x3 depthwise
    -> 1x1 project, optional residual.

    x: (H, W, Cin) int8
    w_exp: (Cin, Cexp) int8; w_dw: (3, 3, Cexp) int8; w_proj: (Cexp, Cout)
    shifts: (s_exp, s_dw, s_proj) requantisation shifts.
    """
    s_exp, s_dw, s_proj = shifts
    h = conv1x1_int8(x, w_exp, s_exp, relu=True)
    h = depthwise3x3_int8(_pad_hw(h), w_dw, s_dw, relu=True)
    h = conv1x1_int8(h, w_proj, s_proj, relu=False)  # linear bottleneck
    if residual:
        acc = h.astype(jnp.int32) + x.astype(jnp.int32)
        h = jnp.clip(acc, -128, 127).astype(jnp.int8)
    return h


def repvgg_block(x_padded, w3, shift):
    """RepVGG deploy-mode block: a single reparameterised 3x3 conv + ReLU
    (Table VII runs the A0/A1/A2 networks in this form on the HWCE)."""
    return conv3x3_int8(x_padded, w3, shift, relu=True)


def matmul_graph(a, b):
    """The Fig. 6 benchmark: plain int8 matmul with int32 accumulation."""
    return matmul_int8(a, b)


# ----------------------------------------------------------------------
# AOT entry points: (name, function, example argument shapes)
# Shapes are kept small; the Rust side uses these artifacts as functional
# golden models, not as the performance workload.
# ----------------------------------------------------------------------

def _i8(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int8)


AOT_ENTRIES = [
    # name, fn, example args (ShapeDtypeStructs)
    ("matmul_int8_64", lambda a, b: (matmul_graph(a, b),),
     (_i8(64, 64), _i8(64, 64))),
    ("hwce_conv3x3_16", lambda x, w: (hwce_conv3x3(x, w),),
     (_i8(18, 18, 16), _i8(3, 3, 16, 16))),
    ("repvgg_block_16", lambda x, w: (repvgg_block(x, w, 7),),
     (_i8(18, 18, 16), _i8(3, 3, 16, 16))),
    ("mbv2_bottleneck_14", lambda x, we, wd, wp: (mbv2_bottleneck(
        x, we, wd, wp, (7, 7, 7), residual=True),),
     (_i8(14, 14, 24), _i8(24, 96), _i8(3, 3, 96), _i8(96, 24))),
]
