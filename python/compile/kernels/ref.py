"""Pure-jnp oracles for the Pallas kernels (the correctness anchor).

Every Pallas kernel in this package must agree exactly (integer) or to
float tolerance with these references; pytest + hypothesis sweep shapes,
dtypes and tilings against them.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def conv3x3_ref(x, w, accum_dtype=jnp.int32):
    """(H+2, W+2, Cin) pre-padded x, (3, 3, Cin, Cout) w -> (H, W, Cout)."""
    out = jax.lax.conv_general_dilated(
        x[None].astype(accum_dtype),
        w.astype(accum_dtype),
        window_strides=(1, 1),
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=accum_dtype,
    )
    return out[0]


def conv5x5_ref(x, w, accum_dtype=jnp.int32):
    out = jax.lax.conv_general_dilated(
        x[None].astype(accum_dtype),
        w.astype(accum_dtype),
        window_strides=(1, 1),
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=accum_dtype,
    )
    return out[0]


def depthwise3x3_ref(x, w, accum_dtype=jnp.int32, stride=1):
    """(H+2, W+2, C) pre-padded x, (3, 3, C) per-channel filters."""
    c = x.shape[-1]
    out = jax.lax.conv_general_dilated(
        x[None].astype(accum_dtype),
        w.reshape(3, 3, 1, c).astype(accum_dtype),
        window_strides=(stride, stride),
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c,
        preferred_element_type=accum_dtype,
    )
    return out[0]


def matmul_ref(a, b, accum_dtype=jnp.int32):
    return jnp.matmul(
        a.astype(accum_dtype),
        b.astype(accum_dtype),
        preferred_element_type=accum_dtype,
    )


def requantize_ref(acc, shift, zero_point=0):
    """int32 accumulator -> int8, PULP-NN style (arithmetic right shift,
    saturating clip) -- the HWCE 'normalisation and right-shift' stage."""
    q = jnp.right_shift(acc, shift) + zero_point
    return jnp.clip(q, -128, 127).astype(jnp.int8)
