# L1: Pallas kernels for the paper's compute hot-spots.
from .hwce_conv import hwce_conv3x3, hwce_conv5x5  # noqa: F401
from .matmul import matmul, matmul_f32, matmul_int8  # noqa: F401
