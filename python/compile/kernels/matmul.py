"""Layer-1 Pallas kernel: tiled matrix multiplication (PULP-NN analogue).

The paper's software DNN path and all of Fig. 6 run on PULP-NN-style
register-tiled matmul inner loops (4x2 output tiles, SIMD dot products,
int32 accumulation). The TPU analogue is a block-tiled matmul with the K
dimension as the innermost accumulation grid axis; int8 operands accumulate
into int32 exactly like the pv.sdotsp.b instruction chain.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(a_ref, b_ref, o_ref, *, accum_dtype):
    k = pl.program_id(2)
    a = a_ref[...].astype(accum_dtype)
    b = b_ref[...].astype(accum_dtype)
    prod = jnp.dot(a, b, preferred_element_type=accum_dtype)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = prod

    @pl.when(k != 0)
    def _accum():
        o_ref[...] += prod


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "accum_dtype")
)
def matmul(a, b, *, block_m=None, block_n=None, block_k=None,
           accum_dtype=jnp.int32):
    """Tiled matmul: (M, K) x (K, N) -> (M, N) in accum_dtype.

    Defaults tile the full axis (single grid step per dimension), which is
    right for the small AOT example shapes; larger shapes pick MXU-aligned
    tiles.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"K mismatch: {k} != {k2}"
    block_m = m if block_m is None else block_m
    block_n = n if block_n is None else block_n
    block_k = k if block_k is None else block_k
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0

    return pl.pallas_call(
        functools.partial(_matmul_kernel, accum_dtype=accum_dtype),
        grid=(m // block_m, n // block_n, k // block_k),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), accum_dtype),
        interpret=True,
    )(a, b)


def matmul_int8(a, b, **kw):
    """int8 x int8 -> int32 (the PULP-NN dot-product path)."""
    assert a.dtype == jnp.int8 and b.dtype == jnp.int8
    return matmul(a, b, accum_dtype=jnp.int32, **kw)


def matmul_f32(a, b, **kw):
    """f32 x f32 -> f32 (the shared-FPU FMA path)."""
    return matmul(a, b, accum_dtype=jnp.float32, **kw)
