"""Layer-1 Pallas kernel: the HWCE 3x3 convolution, re-thought for TPU.

The silicon HWCE (Vega, JSSC'21, Fig. 4) is a weight-stationary 3x3
convolver: three 3x3 filters live in a weight buffer, an input line buffer
materialises a sliding window, and carry-save reduction trees perform 27
MACs/cycle with partial-sum FIFOs accumulating across input channels.

TPU adaptation (DESIGN.md section 6 "Hardware-Adaptation"):
  * the line buffer becomes a VMEM-resident input tile (each input element
    is reused 9x once on-chip, exactly the reuse the line buffer buys);
  * the 27-MAC reduction tree becomes nine shifted (H*W, Cin) x (Cin, Cout)
    contractions, i.e. the sum-of-products is performed by the MXU with the
    weights held stationary across the whole output tile;
  * the partial-sum FIFO across input-channel passes becomes the innermost
    grid dimension: the output block is revisited per Cin tile and
    accumulated in place;
  * multi-precision 4/8/16-bit operands with 16-bit upscaling before the
    CSA tree becomes int8/int16 operands with int32 accumulation.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls, so the kernel is lowered to plain HLO (see aot_recipe).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv3x3_kernel(x_ref, w_ref, o_ref, *, accum_dtype):
    """One (Cout-tile, Cin-tile) grid step of the HWCE dataflow.

    x_ref: (H+2, W+2, Cin_blk)  pre-padded input tile (the "line buffer")
    w_ref: (3, 3, Cin_blk, Cout_blk)  stationary weights
    o_ref: (H, W, Cout_blk)  accumulator tile (partial-sum FIFO)
    """
    ci = pl.program_id(1)
    h, w, co = o_ref.shape
    x = x_ref[...].astype(accum_dtype)
    acc = jnp.zeros((h * w, co), accum_dtype)
    # Nine shifted contractions == the 3x3 reduction tree, weight-stationary.
    for dy in range(3):
        for dx in range(3):
            patch = x[dy : dy + h, dx : dx + w, :].reshape(h * w, -1)
            k = w_ref[dy, dx, :, :].astype(accum_dtype)
            acc = acc + jnp.dot(patch, k, preferred_element_type=accum_dtype)
    acc = acc.reshape(h, w, co)

    @pl.when(ci == 0)
    def _init():
        o_ref[...] = acc

    @pl.when(ci != 0)
    def _accum():
        o_ref[...] += acc


@functools.partial(
    jax.jit, static_argnames=("block_ci", "block_co", "accum_dtype")
)
def hwce_conv3x3(x, w, *, block_ci=None, block_co=None, accum_dtype=jnp.int32):
    """HWCE-style 3x3 valid convolution.

    Args:
      x: (H+2, W+2, Cin) pre-padded input (int8/int16/float32). Pre-padding
         mirrors the silicon flow where DORY pads tiles in L2.
      w: (3, 3, Cin, Cout) filters.
      block_ci / block_co: channel tile sizes (default: whole axis).
      accum_dtype: accumulator type; int32 for integer operands (the HWCE
         upscales sub-words to 16 bit and accumulates wider).

    Returns:
      (H, W, Cout) feature map in accum_dtype (requantisation is a separate
      step, as in PULP-NN / the HWCE's normalisation+shift output stage).
    """
    hp, wp, cin = x.shape
    kh, kw, wcin, cout = w.shape
    assert (kh, kw) == (3, 3), "HWCE supports 3x3 filters (5x5 via compose)"
    assert wcin == cin, f"Cin mismatch: {wcin} != {cin}"
    h, wout = hp - 2, wp - 2
    block_ci = cin if block_ci is None else block_ci
    block_co = cout if block_co is None else block_co
    assert cin % block_ci == 0 and cout % block_co == 0
    n_ci, n_co = cin // block_ci, cout // block_co

    return pl.pallas_call(
        functools.partial(_conv3x3_kernel, accum_dtype=accum_dtype),
        grid=(n_co, n_ci),  # ci innermost: output block revisited+accumulated
        in_specs=[
            pl.BlockSpec((hp, wp, block_ci), lambda co, ci: (0, 0, ci)),
            pl.BlockSpec((3, 3, block_ci, block_co), lambda co, ci: (0, 0, ci, co)),
        ],
        out_specs=pl.BlockSpec((h, wout, block_co), lambda co, ci: (0, 0, co)),
        out_shape=jax.ShapeDtypeStruct((h, wout, cout), accum_dtype),
        interpret=True,
    )(x, w)


def hwce_conv5x5(x, w, *, accum_dtype=jnp.int32):
    """5x5 convolution composed from the 3x3 datapath.

    The silicon HWCE reconfigures its three sum-of-products units into one
    5x5 unit; here we decompose the 5x5 filter into 3x3 sub-filters applied
    at offsets (zero-padding the remainder), which keeps the single 3x3
    kernel as the only compute primitive, like the hardware.
    """
    hp, wp, cin = x.shape
    kh, kw, wcin, cout = w.shape
    assert (kh, kw) == (5, 5)
    h, wout = hp - 4, wp - 4
    # Pad 5x5 to 6x6 and split into four 3x3 taps; the input gains one
    # zero row/col at the far edges so every tap's window is in range (the
    # out-of-range elements only ever multiply the zero filter padding).
    w6 = jnp.pad(w, ((0, 1), (0, 1), (0, 0), (0, 0)))
    xp = jnp.pad(x, ((0, 1), (0, 1), (0, 0)))
    out = jnp.zeros((h, wout, cout), accum_dtype)
    for oy in range(2):
        for ox in range(2):
            sub = w6[3 * oy : 3 * oy + 3, 3 * ox : 3 * ox + 3]
            xs = xp[3 * oy : 3 * oy + h + 2, 3 * ox : 3 * ox + wout + 2, :]
            out = out + hwce_conv3x3(xs, sub, accum_dtype=accum_dtype)
    return out
