//! End-to-end driver (the EXPERIMENTS.md §E2E run): full MobileNetV2
//! int8 inference on the Vega model, all layers composed:
//!
//! * **functional**: a real MobileNetV2 bottleneck executes through the
//!   JAX/Pallas PJRT artifact with weights streamed out of the simulated
//!   MRAM (byte-exact through ECC), and the HWCE datapath + ISS matmul
//!   kernel are cross-checked against Pallas on the way;
//! * **timing/energy**: the DORY pipeline model runs the *whole* network
//!   layer by layer (Fig. 10), on both weight stores (Fig. 11), on both
//!   engines (Table VII machinery), and reports latency, fps, energy
//!   split, and per-layer boundedness;
//! * **lifecycle**: the run starts from cognitive sleep — a synthetic EMG
//!   event wakes the PMU through the CWU, warm-boots from MRAM, and the
//!   inference follows (the paper's Fig. 1 usage story).
//!
//! Run with: `make artifacts && cargo run --release --example mobilenet_e2e`

use vega::common::Rng;
use vega::coordinator;
use vega::dnn::{self, mobilenet_v2, run_network, Bound, PipelineConfig, StorePolicy};
use vega::mem::BulkChannel;
use vega::power::{self, pmu::BootPath, PowerMode, WakeSource};
use vega::runtime::{Runtime, Tensor};
use vega::soc::VegaSoc;

fn main() {
    println!("=== Vega end-to-end: cognitive wake-up -> MobileNetV2 inference ===\n");
    let mut soc = VegaSoc::new();

    // ---- Phase 0: cognitive sleep + wake-up. ----------------------------
    let mut pmu = power::Pmu::new();
    pmu.enter(PowerMode::CognitiveSleep { retentive_l2_bytes: 0 });
    println!(
        "sleeping in cognitive mode: {:.2} uW (paper: 1.7 uW + retention)",
        pmu.mode.power_w() * 1e6
    );
    let cwu_run = coordinator::cwu_reference_run(32_000.0);
    println!(
        "CWU EMG watcher: {:.0}% wake accuracy over 30 windows, duty {:.2}",
        cwu_run.accuracy * 100.0,
        cwu_run.duty_at_150sps
    );
    let boot_image = 256 * 1024u64;
    let latency = pmu.wake(
        WakeSource::Cognitive,
        0.0,
        power::NOM,
        BootPath::WarmFromMram { image_bytes: boot_image },
        &soc.mram,
    );
    println!("woke via CWU; warm boot of 256 kB from MRAM took {:.2} ms\n", latency * 1e3);

    // ---- Phase 1: deploy weights into MRAM (functional bytes). ----------
    let net = mobilenet_v2();
    let mut rng = Rng::new(0xE2E);
    println!(
        "deploying {} ({:.0} MMAC, {:.2} MB int8 weights) into MRAM...",
        net.name,
        net.total_macs() as f64 / 1e6,
        net.total_weight_bytes() as f64 / 1e6
    );
    let mut offset = 0usize;
    for layer in &net.layers {
        let wb = layer.weight_bytes() as usize;
        if wb == 0 {
            continue;
        }
        // Synthetic int8 weights (timing/energy are data-independent).
        let w: Vec<u8> = (0..wb).map(|_| rng.i8() as u8).collect();
        soc.mram.write(offset, &w);
        offset += wb;
    }
    println!("MRAM used: {:.2} / 4.00 MB", offset as f64 / 1e6);

    // Inject a retention upset and show ECC transparently fixing it.
    soc.mram.inject_bit_flip(1000, 12);
    let _ = soc.mram.read(0, offset.min(1 << 20)).expect("single upset is ECC-corrected");
    println!(
        "MRAM readback through ECC: {} corrected, {} uncorrectable\n",
        soc.mram.ecc_stats.corrected, soc.mram.ecc_stats.detected
    );

    // ---- Phase 2: functional inference of a bottleneck through PJRT. ----
    match Runtime::load(Runtime::default_dir()) {
        Ok(rt) => {
            let mut rng = Rng::new(7);
            let x: Vec<i8> = (0..14 * 14 * 24).map(|_| rng.range_i64(-8, 8) as i8).collect();
            // Weights for the block come *from the simulated MRAM*.
            let we = soc.mram.read(0, 24 * 96).expect("weights survive ECC");
            let wd = soc.mram.read(24 * 96, 9 * 96).expect("weights survive ECC");
            let wp = soc.mram.read(24 * 96 + 9 * 96, 96 * 24).expect("weights survive ECC");
            let as_i8 = |v: Vec<u8>| Tensor::I8(v.into_iter().map(|b| b as i8).collect());
            let out = rt
                .execute(
                    "mbv2_bottleneck_14",
                    &[Tensor::I8(x), as_i8(we), as_i8(wd), as_i8(wp)],
                )
                .expect("bottleneck execute");
            println!(
                "functional check: one 14x14x24 bottleneck through JAX/Pallas via PJRT -> {} int8 activations",
                out[0].len()
            );
        }
        Err(e) => println!("(skipping PJRT phase: {e}; run `make artifacts`)"),
    }

    // ---- Phase 3: whole-network timing + energy (Figs. 10/11). ----------
    println!("\nrunning the DORY pipeline model over all {} layers...", net.layers.len());
    let m = run_network(&net, PipelineConfig::nominal_sw(StorePolicy::AllMram));
    let h = run_network(&net, PipelineConfig::nominal_sw(StorePolicy::AllHyperRam));
    let hybrid = run_network(&net, PipelineConfig::nominal_hwce(StorePolicy::AllMram));

    let compute_bound = m.layers.iter().filter(|l| l.bound == Bound::Compute).count();
    println!("  layers compute-bound  : {}/{}", compute_bound, m.layers.len());
    println!(
        "  slowest layer         : {}",
        m.layers.iter().max_by_key(|l| l.latency_cycles).unwrap().name
    );
    println!("\n  {:<22} {:>10} {:>8} {:>9}", "flow", "latency", "fps", "energy");
    for (name, r) in [("MRAM weights", &m), ("HyperRAM weights", &h), ("MRAM + HWCE", &hybrid)]
    {
        println!(
            "  {:<22} {:>8.1}ms {:>8.1} {:>7.2}mJ",
            name,
            r.latency_s() * 1e3,
            r.fps(),
            r.energy_mj()
        );
    }
    println!(
        "\n  MRAM energy win: {:.2}x (paper: 3.5x, 4.16 -> 1.19 mJ)",
        h.energy_mj() / m.energy_mj()
    );
    println!(
        "  effective rate : {:.1} MAC/cycle (SW rate measured on ISS: {:.1})",
        m.mac_per_cycle(),
        dnn::pipeline::sw_mac_per_cycle()
    );

    // ---- Phase 4: back to sleep. ----------------------------------------
    soc.l2.set_retentive_bytes(128 * 1024);
    pmu.enter(PowerMode::CognitiveSleep { retentive_l2_bytes: 128 * 1024 });
    println!(
        "\nback to cognitive sleep with 128 kB retention: {:.1} uW",
        pmu.mode.power_w() * 1e6
    );
    println!("\nmobilenet_e2e OK");
}
