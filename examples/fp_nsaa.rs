//! FP near-sensor-analytics suite (§IV-A, Table V, Fig. 8): run all
//! eight NSAA kernels on the simulated 8-core cluster in FP32 and packed
//! FP16, and print the Fig. 8 series with the paper anchors inline.
//!
//! Run with: `cargo run --release --example fp_nsaa`

use vega::coordinator::{self, NSAA_KERNELS};
use vega::kernels::fp_matmul::FpWidth;
use vega::power;

fn main() {
    println!("=== Vega FP NSAA suite (8 cores, shared FPUs) ===\n");
    println!(
        "{:<8} {:>10} {:>10} {:>12} {:>8} {:>9}",
        "kernel", "MOPS@LV", "MOPS@HV", "MOPS/mW@LV", "FP int%", "f16 gain"
    );
    let paper_intensity = [57.0, 55.0, 28.0, 63.0, 64.0, 46.0, 83.0, 35.0];
    let mut avg_gain = 0.0;
    for (name, paper_fi) in NSAA_KERNELS.iter().zip(paper_intensity) {
        let k32 = coordinator::bench_nsaa_kernel(name, FpWidth::F32);
        let k16 = coordinator::bench_nsaa_kernel(name, FpWidth::F16x2);
        let gain = (k32.stats.cycles as f64 / k32.ops as f64)
            / (k16.stats.cycles as f64 / k16.ops as f64);
        avg_gain += gain;
        let (_, eff) = coordinator::efficiency(&k32, power::LV, 0.0);
        println!(
            "{:<8} {:>10.0} {:>10.0} {:>12.2} {:>5.0}/{:<3.0} {:>8.2}x",
            name,
            k32.gops_at(power::LV.f_cl) * 1e3,
            k32.gops_at(power::HV.f_cl) * 1e3,
            eff,
            k32.fp_intensity() * 100.0,
            paper_fi,
            gain
        );
    }
    avg_gain /= NSAA_KERNELS.len() as f64;
    println!(
        "\naverage FP16 vectorization gain: {avg_gain:.2}x (paper: 1.46x)"
    );
    println!(
        "FPU contention on the MATMUL run: {:.1}% of issues",
        coordinator::bench_fp_matmul(FpWidth::F32, 8).stats.fpu_contention_rate * 100.0
    );
    println!("\nfp_nsaa OK");
}
