//! Cognitive wake-up scenario (§II-B): train an HDC model on synthetic
//! EMG gestures, generate Hypnos microcode, stream sensor data through
//! SPI → preprocessor → Hypnos, and report wake-up quality + power — the
//! Table I / Table II workload end to end. Also runs the language-id
//! workload (the "compute-intensive" configuration of Table I).
//!
//! Run with: `cargo run --release --example cognitive_wakeup`

use vega::common::Rng;
use vega::cwu::{ChannelConfig, Cwu, SpiMaster, SpiMode, SpiOp, SpiSensor};
use vega::hdc::{self, datasets, gen_microcode, EncoderConfig};
use vega::power;

/// An EMG electrode behind a SPI chip select, replaying generated windows.
struct EmgElectrode {
    samples: Vec<u32>,
    pos: usize,
}

impl SpiSensor for EmgElectrode {
    fn sample(&mut self) -> u32 {
        let v = self.samples[self.pos % self.samples.len()];
        self.pos += 1;
        v
    }
}

fn main() {
    println!("=== Vega cognitive wake-up: EMG gestures over SPI ===\n");
    let cfg = EncoderConfig {
        dim: 2048,
        input_width: 16,
        cim_max: 4095,
        channels: 3,
        window: 16,
        ngram: 1,
        discrete: false,
    };

    // ---- few-shot training (5 windows per class). -----------------------
    let mut gen = datasets::EmgGenerator::new(99);
    let model = hdc::train(cfg, &gen.dataset(5, cfg.window));
    println!(
        "trained {} prototypes (dim {}, {} training windows/class)",
        model.prototypes.len(),
        cfg.dim,
        5
    );
    let ucode = gen_microcode(&cfg, 1, (cfg.dim / 4) as u16);
    println!("generated microcode: {} of 64 slots used\n", ucode.len());

    // ---- wire the full CWU: SPI sensors -> preproc -> Hypnos. -----------
    let target_class = 1; // "fist"
    let mut stream: Vec<Vec<u32>> = Vec::new(); // label per window
    let mut labels = Vec::new();
    let mut rng = Rng::new(5);
    for _ in 0..40 {
        let class = rng.below(4) as usize;
        stream.push(gen.window(class, cfg.window).concat());
        labels.push(class);
    }
    // Three electrodes, one per channel, fed window by window.
    let mut tp = 0;
    let mut fp = 0;
    let mut fns = 0;
    for (win, &label) in stream.iter().zip(&labels) {
        let electrodes: Vec<Box<dyn SpiSensor>> = (0..3)
            .map(|c| {
                Box::new(EmgElectrode {
                    samples: win.iter().skip(c).step_by(3).copied().collect(),
                    pos: 0,
                }) as Box<dyn SpiSensor>
            })
            .collect();
        let spi = SpiMaster::new(
            SpiMode::Mode0,
            vec![
                SpiOp::Read { cs: 0, bits: 16, chan: 0 },
                SpiOp::Read { cs: 1, bits: 16, chan: 1 },
                SpiOp::Read { cs: 2, bits: 16, chan: 2 },
                SpiOp::Wait { n: 16 },
            ],
            electrodes,
        );
        let hypnos = model.program_hypnos(target_class, (cfg.dim / 4) as u16);
        let mut cwu = Cwu::with_config(
            Some(spi),
            &[ChannelConfig { in_width: 16, ..Default::default() }; 3],
            hypnos,
            32_000.0,
        );
        let mut woke = false;
        for _ in 0..cfg.window {
            if cwu.step().is_some() {
                woke = true;
            }
        }
        match (woke, label == target_class) {
            (true, true) => tp += 1,
            (true, false) => fp += 1,
            (false, true) => fns += 1,
            _ => {}
        }
    }
    let events = labels.iter().filter(|&&l| l == target_class).count();
    println!("streamed 40 windows over SPI: {events} true events");
    println!("  true positives : {tp}/{events}");
    println!("  false positives: {fp}/{}", 40 - events);
    println!("  false negatives: {fns}/{events}");

    // ---- power story (Table I + the duty-cycling argument). -------------
    let duty = 0.178;
    println!("\npower at the Table I operating points:");
    println!(
        "  cognitive sleep (32 kHz)  : {:.2} uW (paper 1.7)",
        power::cwu_power_w(32e3, duty, false) * 1e6
    );
    println!(
        "  CWU total w/ pads (32kHz) : {:.2} uW (paper 2.97)",
        power::cwu_power_w(32e3, duty, true) * 1e6
    );
    println!(
        "  CWU total w/ pads (200kHz): {:.2} uW (paper 14.9)",
        power::cwu_power_w(200e3, duty, true) * 1e6
    );

    // ---- language identification (the compute-intensive workload). ------
    println!("\n=== language identification (trigram HDC) ===");
    let lcfg = EncoderConfig {
        dim: 2048,
        input_width: 5,
        cim_max: 26,
        channels: 1,
        window: 64,
        ngram: 3,
        discrete: true,
    };
    let mut lgen = datasets::LangGenerator::new(3, 3);
    let lmodel = hdc::train(lcfg, &lgen.dataset(6, lcfg.window));
    let mut correct = 0;
    for class in 0..3 {
        for _ in 0..10 {
            if lmodel.classify(&lgen.window(class, lcfg.window)) == class {
                correct += 1;
            }
        }
    }
    println!("language id accuracy: {correct}/30");
    println!("\ncognitive_wakeup OK");
}
