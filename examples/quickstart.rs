//! Quickstart: the three-layer stack in one page.
//!
//! 1. Load the JAX/Pallas AOT artifacts through PJRT (Layer 1+2).
//! 2. Run the same int8 matmul on the simulated 8-core cluster (Layer 3)
//!    and check the numerics are bit-identical.
//! 3. Report the measured MAC/cycle and the chip-level efficiency at the
//!    paper's operating points.
//!
//! Run with: `make artifacts && cargo run --release --example quickstart`

use vega::cluster::Cluster;
use vega::common::Rng;
use vega::coordinator;
use vega::iss::FlatMem;
use vega::kernels::int_matmul::{self, IntWidth};
use vega::power;
use vega::runtime::{Runtime, Tensor};

fn main() {
    // ---- 1. PJRT side (the golden model). ------------------------------
    let rt = Runtime::load(Runtime::default_dir()).expect("run `make artifacts` first");
    println!("PJRT platform: {}", rt.platform());

    let mut rng = Rng::new(2024);
    let a: Vec<i8> = (0..64 * 64).map(|_| rng.range_i64(-128, 127) as i8).collect();
    let b: Vec<i8> = (0..64 * 64).map(|_| rng.range_i64(-128, 127) as i8).collect();
    let golden = rt
        .execute("matmul_int8_64", &[Tensor::I8(a.clone()), Tensor::I8(b.clone())])
        .expect("execute");
    println!("Pallas int8 matmul executed through PJRT.");

    // ---- 2. Simulator side (the chip model). ---------------------------
    let av: Vec<i32> = a.iter().map(|&v| v as i32).collect();
    let mut bt = vec![0i32; 64 * 64]; // kernel layout: B column-major
    for r in 0..64 {
        for c in 0..64 {
            bt[c * 64 + r] = b[r * 64 + c] as i32;
        }
    }
    let mut cluster = Cluster::new();
    let mut l2 = FlatMem::new(vega::cluster::L2_BASE, 4096);
    let (c_sim, kr) =
        int_matmul::run(&mut cluster, &mut l2, &av, &bt, 64, 64, 64, IntWidth::I8, 8);
    assert_eq!(&c_sim, golden[0].as_i32().unwrap(), "numerics must match");
    println!("ISS result is bit-identical to the Pallas artifact.");

    // ---- 3. The paper's headline metrics, emergent. ---------------------
    println!("\n8-core PULP-NN matmul on the simulated cluster:");
    println!("  cycles            : {}", kr.stats.cycles);
    println!("  MAC/cycle         : {:.2} (paper: up to 15.5)", kr.stats.mac_per_cycle());
    println!(
        "  TCDM conflicts    : {:.1}% (paper: <10%)",
        kr.stats.tcdm_conflict_rate * 100.0
    );
    let (gops_hv, _) = coordinator::efficiency(&kr, power::HV, 0.0);
    let (gops_lv, eff_lv) = coordinator::efficiency(&kr, power::LV, 0.0);
    println!("  perf @HV          : {gops_hv:.1} GOPS (paper: 15.6)");
    println!("  eff  @LV          : {eff_lv:.0} GOPS/W @ {gops_lv:.1} GOPS (paper: 614 @ 7.6)");
    println!("\nquickstart OK");
}
