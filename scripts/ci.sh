#!/usr/bin/env bash
# Tier-1 gate + perf smoke for the Vega reproduction.
#
#   scripts/ci.sh            full run (fmt, build, doc, test, bench smoke)
#   CI_SKIP_BENCH=1 ...      skip the bench smoke (e.g. resource-starved CI)
#
# The bench smoke runs every hotpath and sweep case once
# (VEGA_BENCH_ITERS=1) so a scheduler regression that hangs or panics is
# caught even where full benchmarking is too slow; BENCH_hotpath.json and
# BENCH_sweeps.json land in rust/. The determinism smokes diff --jobs 2
# runs of `vega repro` and `vega sweep` (including the fp8 precision
# cells) against serial runs byte-for-byte; the cache smokes run the same
# sweep grid / fp8 grid / fig9 repro twice against a fresh on-disk store,
# asserting the second run is served entirely from disk (kernel tier and
# network-report tier respectively); the fault smokes replay a fixed-seed
# `vega faults` campaign grid across worker counts, assert the SECDED
# invariants structurally (status ok everywhere, zero silent corruptions,
# classification covering every upset word), round-trip the `.flt` store
# tier, and run the panic-isolation regression tests by name; the
# lifecycle smokes (ISSUE 8) replay a fixed-seed `vega lifecycle`
# deployment grid across worker counts, assert the trace invariants
# structurally (status ok, true + false wakes partition the events,
# battery projections populated) and round-trip the `.lfc` store tier;
# the crash-safety smokes (ISSUE 7) resume a torn-journal grid
# byte-identically, reassemble a --shard 1/2 + 2/2 pair via --merge into
# the exact serial bytes, assert exit code 3 for grids with failed
# cells, and drive the cache-degradation paths (unusable and read-only
# store directories) to completed in-memory runs; the clippy
# gate fails on any
# non-allow-listed lint; the key-stability gate runs the
# golden-vector tests that pin the on-disk cache-key byte encoding (a
# drift there silently orphans every persisted entry everywhere — it must
# only ever happen as a deliberate ISA_ENCODING_VERSION/
# NET_ENCODING_VERSION bump that updates the vectors); the superblock
# smoke re-runs the table5 repro with VEGA_SUPERBLOCKS=off and asserts
# byte-identical output (the ISS trace-replay tier must be
# behaviour-invisible, see PERFORMANCE.md); and the docs link gate fails
# on any broken relative link between the top-level markdown docs
# (README/ARCHITECTURE/PERFORMANCE/EXPERIMENTS).
#
# Runs on the toolchain pinned by rust-toolchain.toml; the GitHub Actions
# workflow (.github/workflows/ci.yml) executes this script verbatim.

set -euo pipefail
cd "$(dirname "$0")/../rust"

# Default store location for anything not explicitly overridden below: a
# fresh per-run directory, so a cached target/ (e.g. the GitHub Actions
# target cache) can never carry persisted sim entries between runs. The
# cache-smoke sections switch to their own private dirs and switch back.
export VEGA_CACHE_DIR="${VEGA_CACHE_DIR:-$(mktemp -d)/vega-cache}"
CI_RUN_CACHE="$VEGA_CACHE_DIR"

echo "== cargo fmt --check =="
# Non-fatal: formatting drift should not mask real build/test failures,
# but it is reported loudly.
if ! cargo fmt --check 2>/dev/null; then
    echo "WARNING: cargo fmt --check reported drift (or rustfmt is unavailable)"
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo clippy --all-targets (warnings fatal) =="
# Gate added with ISSUE 5; the one-pass triage allow-list for stylistic
# lints lives in Cargo.toml [lints.clippy] — correctness lints are fatal.
cargo clippy --all-targets -- -D warnings

echo "== cargo doc --no-deps (warnings fatal) =="
# --lib: the bin target shares the crate name, and documenting both
# triggers cargo's output-filename-collision warning, which RUSTDOCFLAGS
# cannot gate; the bin is a thin CLI over the documented library.
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --lib --quiet

echo "== docs link gate (README/ARCHITECTURE/PERFORMANCE/EXPERIMENTS) =="
# Every relative markdown link between the top-level docs must resolve
# from the repo root (all four live there). External/fragment-only
# targets are skipped; in-repo targets are checked with test -e after
# stripping any #fragment. Pure grep/sed — no new tooling.
(
    cd ..
    fail=0
    for doc in README.md ARCHITECTURE.md PERFORMANCE.md EXPERIMENTS.md; do
        if [ ! -f "$doc" ]; then
            echo "FAIL: expected top-level doc $doc is missing"
            fail=1
            continue
        fi
        while IFS= read -r target; do
            case "$target" in
                http://*|https://*|mailto:*|'#'*) continue ;;
            esac
            path="${target%%#*}"
            [ -n "$path" ] || continue
            if [ ! -e "$path" ]; then
                echo "FAIL: $doc links to missing path: $target"
                fail=1
            fi
        done < <(grep -oE '\]\([^)]+\)' "$doc" | sed 's/^](//; s/)$//')
    done
    exit "$fail"
)
echo "every relative link between the top-level docs resolves"

echo "== static-verifier gate (vega verify all + analyzer goldens) =="
# ISSUE 9: every shipped kernel program must pass CFG/dataflow/memory-map
# analysis with zero error-severity findings (exit 0), and each seeded
# defect class must keep producing its golden diagnostic. The goldens run
# first and by name so an analyzer regression fails on its own line; the
# oracle layer (static claims vs the traced ISS) runs under the full
# `cargo test -q` below.
mkdir -p target/ci
./target/release/vega verify all > target/ci/verify_all.txt \
    || { echo "FAIL: vega verify all found error-severity findings:"; cat target/ci/verify_all.txt; exit 1; }
grep -q "0 error-severity finding(s)" target/ci/verify_all.txt \
    || { echo "FAIL: verify summary missing/unclean:"; cat target/ci/verify_all.txt; exit 1; }
cargo test -q --test verify_static golden

echo "== key-stability gate (golden byte/hash vectors) =="
# These run again under the full `cargo test -q` below; running them
# first and by name makes a key-encoding drift fail loudly on its own
# line instead of drowning in an unrelated test-suite failure.
cargo test -q --test isa_encoding golden
cargo test -q --lib dnn::encode::tests

echo "== sweep determinism smoke (vega repro table5: --jobs 2 vs serial) =="
mkdir -p target/ci
# Memory-only engines here: the repro smoke checks parallel determinism,
# the dedicated cache smoke below checks persistence.
VEGA_CACHE=off ./target/release/vega repro table5 --jobs 1 > target/ci/repro_table5_serial.txt
VEGA_CACHE=off ./target/release/vega repro table5 --jobs 2 > target/ci/repro_table5_jobs2.txt
diff target/ci/repro_table5_serial.txt target/ci/repro_table5_jobs2.txt
echo "parallel repro output is byte-identical to serial"

echo "== superblock smoke (vega repro table5: VEGA_SUPERBLOCKS=off vs default) =="
# The ISS trace-replay tier (PERFORMANCE.md) must be invisible in every
# reproduced number: the same serial repro with replay disabled has to
# produce the exact bytes of the default (replay-on) run above.
VEGA_CACHE=off VEGA_SUPERBLOCKS=off ./target/release/vega repro table5 --jobs 1 > target/ci/repro_table5_nosb.txt
diff target/ci/repro_table5_serial.txt target/ci/repro_table5_nosb.txt
echo "superblock replay on vs off is byte-identical"

echo "== vega sweep smoke grid (serial vs --jobs 2) =="
SWEEP_GRID=(--cores 1..2 --precision int8,fp16 --dvfs-steps 5 --format csv)
VEGA_CACHE=off ./target/release/vega sweep "${SWEEP_GRID[@]}" --jobs 1 > target/ci/sweep_serial.csv
VEGA_CACHE=off ./target/release/vega sweep "${SWEEP_GRID[@]}" --jobs 2 > target/ci/sweep_jobs2.csv
diff target/ci/sweep_serial.csv target/ci/sweep_jobs2.csv
echo "parallel sweep grid is byte-identical to serial"

echo "== fp8 sweep smoke (serial vs --jobs 2) =="
FP8_GRID=(--cores 1,9 --precision fp8 --dvfs-steps 3 --format csv)
VEGA_CACHE=off ./target/release/vega sweep "${FP8_GRID[@]}" --jobs 1 > target/ci/fp8_serial.csv
VEGA_CACHE=off ./target/release/vega sweep "${FP8_GRID[@]}" --jobs 2 > target/ci/fp8_jobs2.csv
diff target/ci/fp8_serial.csv target/ci/fp8_jobs2.csv
grep -q "^1,fp8," target/ci/fp8_serial.csv \
    || { echo "FAIL: fp8 grid rendered no fp8 rows:"; cat target/ci/fp8_serial.csv; exit 1; }
echo "parallel fp8 grid is byte-identical to serial"

echo "== fp8 on-disk cache smoke (cold vs warm process) =="
rm -rf target/ci/fp8-cache
export VEGA_CACHE_DIR=target/ci/fp8-cache
./target/release/vega sweep "${FP8_GRID[@]}" --stats > target/ci/fp8_cold.csv 2> target/ci/fp8_cold.log
./target/release/vega sweep "${FP8_GRID[@]}" --stats > target/ci/fp8_warm.csv 2> target/ci/fp8_warm.log
export VEGA_CACHE_DIR="$CI_RUN_CACHE"
diff target/ci/fp8_cold.csv target/ci/fp8_warm.csv
grep -q "disk: 0 hits / 2 misses / 2 writes" target/ci/fp8_cold.log \
    || { echo "FAIL: cold fp8 run did not populate the store:"; cat target/ci/fp8_cold.log; exit 1; }
grep -q "disk: 2 hits / 0 misses / 0 writes" target/ci/fp8_warm.log \
    || { echo "FAIL: warm fp8 run did not hit the on-disk cache:"; cat target/ci/fp8_warm.log; exit 1; }
echo "warm process served both fp8 cells from the on-disk cache"

echo "== on-disk cache smoke (cold vs warm process) =="
rm -rf target/ci/sweep-cache
export VEGA_CACHE_DIR=target/ci/sweep-cache
./target/release/vega sweep "${SWEEP_GRID[@]}" --stats > target/ci/sweep_cold.csv 2> target/ci/sweep_cold.log
./target/release/vega sweep "${SWEEP_GRID[@]}" --stats > target/ci/sweep_warm.csv 2> target/ci/sweep_warm.log
export VEGA_CACHE_DIR="$CI_RUN_CACHE"
diff target/ci/sweep_cold.csv target/ci/sweep_warm.csv
grep -q "disk: 0 hits / 4 misses / 4 writes" target/ci/sweep_cold.log \
    || { echo "FAIL: cold run did not populate the store:"; cat target/ci/sweep_cold.log; exit 1; }
grep -q "disk: 4 hits / 0 misses / 0 writes" target/ci/sweep_warm.log \
    || { echo "FAIL: warm run did not hit the on-disk cache:"; cat target/ci/sweep_warm.log; exit 1; }
echo "warm process served every simulation from the on-disk cache"

echo "== network-report store smoke (vega repro fig9: cold vs warm process) =="
rm -rf target/ci/net-cache
export VEGA_CACHE_DIR=target/ci/net-cache
./target/release/vega repro fig9 --stats > target/ci/fig9_cold.txt 2> target/ci/fig9_cold.log
./target/release/vega repro fig9 --stats > target/ci/fig9_warm.txt 2> target/ci/fig9_warm.log
export VEGA_CACHE_DIR="$CI_RUN_CACHE"
diff target/ci/fig9_cold.txt target/ci/fig9_warm.txt
grep -q "disk(net): 0 hits / 1 misses / 1 writes" target/ci/fig9_cold.log \
    || { echo "FAIL: cold fig9 did not populate the network store:"; cat target/ci/fig9_cold.log; exit 1; }
grep -q "disk(net): 1 hits / 0 misses / 0 writes" target/ci/fig9_warm.log \
    || { echo "FAIL: warm fig9 did not serve the NetworkReport from disk:"; cat target/ci/fig9_warm.log; exit 1; }
echo "warm process served the fig9 NetworkReport from the on-disk cache"

echo "== fault-injection smoke (vega faults: serial vs --jobs 2) =="
# Fixed-seed MRAM retention campaign. The rates keep the expected flip
# count per 64-bit word far below 3, so SECDED must correct or detect
# every upset — the silent-corruption column is asserted exactly zero.
FAULT_GRID=(--kernel matmul-f32 --cores 8 --seeds 7,8 --rates 1e-5,2e-5
            --tiers mram --sleep-s 3600 --format csv)
VEGA_CACHE=off ./target/release/vega faults "${FAULT_GRID[@]}" --jobs 1 > target/ci/faults_serial.csv
VEGA_CACHE=off ./target/release/vega faults "${FAULT_GRID[@]}" --jobs 2 > target/ci/faults_jobs2.csv
diff target/ci/faults_serial.csv target/ci/faults_jobs2.csv
echo "parallel fault grid is byte-identical to serial"
# Structural ECC invariants per data row (columns: 7 mram_flips,
# 8 mram_words, 9 corrected, 10 detected, 11 silent, 12 masked,
# last = status). No golden numbers: the identities must hold for any
# seed, and a panicking cell would surface in the status column.
awk -F, 'NR > 1 {
    if ($NF != "ok")   { print "FAIL: errored campaign cell: " $0; exit 1 }
    if ($7 + 0 < 1)    { print "FAIL: campaign injected no flips: " $0; exit 1 }
    if ($11 + 0 != 0)  { print "FAIL: silent corruption through SECDED: " $0; exit 1 }
    if ($9 + $10 + $11 + $12 != $8) {
        print "FAIL: classification does not cover every upset word: " $0; exit 1
    }
}' target/ci/faults_serial.csv
echo "every campaign cell ok: zero silent corruptions, every upset word classified"

echo "== fault-campaign store smoke (cold vs warm process) =="
rm -rf target/ci/flt-cache
export VEGA_CACHE_DIR=target/ci/flt-cache
./target/release/vega faults "${FAULT_GRID[@]}" --stats > target/ci/faults_cold.csv 2> target/ci/faults_cold.log
./target/release/vega faults "${FAULT_GRID[@]}" --stats > target/ci/faults_warm.csv 2> target/ci/faults_warm.log
export VEGA_CACHE_DIR="$CI_RUN_CACHE"
diff target/ci/faults_cold.csv target/ci/faults_warm.csv
grep -q "disk(flt): 0 hits / 4 misses / 4 writes" target/ci/faults_cold.log \
    || { echo "FAIL: cold faults run did not populate the .flt store:"; cat target/ci/faults_cold.log; exit 1; }
grep -q "disk(flt): 4 hits / 0 misses / 0 writes" target/ci/faults_warm.log \
    || { echo "FAIL: warm faults run did not hit the .flt store:"; cat target/ci/faults_warm.log; exit 1; }
echo "warm process served every campaign outcome from the .flt store tier"

echo "== lifecycle smoke (vega lifecycle: serial vs --jobs 2) =="
# ISSUE 8: fixed-seed deployment grid — 2 event rates × {cognitive,
# retentive} sleep × {l2, mram} boot over a 600 s trace. Structural
# invariants per row: status ok, every event classified exactly once
# (true_wakes + false_wakes == events), and a populated battery
# projection — no golden numbers, the identities hold for any seed.
LIFECYCLE_GRID=(--kernel matmul-i8 --cores 2 --seed 1 --duration-s 600 --rates 0.05,0.2
                --duty eager --sleep cognitive,retentive --boot l2,mram --format csv)
VEGA_CACHE=off ./target/release/vega lifecycle "${LIFECYCLE_GRID[@]}" --jobs 1 > target/ci/lifecycle_serial.csv
VEGA_CACHE=off ./target/release/vega lifecycle "${LIFECYCLE_GRID[@]}" --jobs 2 > target/ci/lifecycle_jobs2.csv
diff target/ci/lifecycle_serial.csv target/ci/lifecycle_jobs2.csv
echo "parallel lifecycle grid is byte-identical to serial"
# Columns: 8 events, 9 true_wakes, 10 false_wakes, 20 battery_hours,
# last = status.
awk -F, 'NR > 1 {
    if ($NF != "ok")      { print "FAIL: errored lifecycle cell: " $0; exit 1 }
    if ($9 + $10 != $8)   { print "FAIL: event not classified exactly once: " $0; exit 1 }
    if ($20 + 0 <= 0)     { print "FAIL: battery projection unpopulated: " $0; exit 1 }
}' target/ci/lifecycle_serial.csv
echo "every lifecycle cell ok: events partition into true/false, lifetimes populated"

echo "== lifecycle store smoke (cold vs warm process) =="
rm -rf target/ci/lfc-cache
export VEGA_CACHE_DIR=target/ci/lfc-cache
./target/release/vega lifecycle "${LIFECYCLE_GRID[@]}" --stats > target/ci/lifecycle_cold.csv 2> target/ci/lifecycle_cold.log
./target/release/vega lifecycle "${LIFECYCLE_GRID[@]}" --stats > target/ci/lifecycle_warm.csv 2> target/ci/lifecycle_warm.log
export VEGA_CACHE_DIR="$CI_RUN_CACHE"
diff target/ci/lifecycle_cold.csv target/ci/lifecycle_warm.csv
grep -q "disk(lfc): 0 hits / 8 misses / 8 writes" target/ci/lifecycle_cold.log \
    || { echo "FAIL: cold lifecycle run did not populate the .lfc store:"; cat target/ci/lifecycle_cold.log; exit 1; }
grep -q "disk(lfc): 8 hits / 0 misses / 0 writes" target/ci/lifecycle_warm.log \
    || { echo "FAIL: warm lifecycle run did not hit the .lfc store:"; cat target/ci/lifecycle_warm.log; exit 1; }
echo "warm process served every lifecycle report from the .lfc store tier"

echo "== resume smoke (torn journal tail, byte-identical --resume) =="
# ISSUE 7 acceptance (a): complete the 4-cell grid, tear the journal's
# trailing record the way SIGKILL mid-append does, and resume: the torn
# cell reads as not-done (3 prior / 1 recorded), every recomputation is
# a disk hit, and the bytes match the uninterrupted run exactly. (The
# full kill-and-resume path — a real SIGKILLed child — runs in
# tests/resume_kill.rs under `cargo test` below.)
rm -rf target/ci/resume-cache
export VEGA_CACHE_DIR=target/ci/resume-cache
./target/release/vega sweep "${SWEEP_GRID[@]}" --stats > target/ci/resume_full.csv 2> target/ci/resume_full.log
grep -q "journal: 0 prior / 4 recorded" target/ci/resume_full.log \
    || { echo "FAIL: seed run did not journal its cells:"; cat target/ci/resume_full.log; exit 1; }
truncate -s -7 target/ci/resume-cache/journals/*.jnl
./target/release/vega sweep "${SWEEP_GRID[@]}" --resume --stats > target/ci/resume_resumed.csv 2> target/ci/resume_resumed.log
export VEGA_CACHE_DIR="$CI_RUN_CACHE"
diff target/ci/resume_full.csv target/ci/resume_resumed.csv
grep -q "journal: 3 prior / 1 recorded" target/ci/resume_resumed.log \
    || { echo "FAIL: torn tail did not cost exactly one record:"; cat target/ci/resume_resumed.log; exit 1; }
grep -q "disk: 4 hits / 0 misses / 0 writes" target/ci/resume_resumed.log \
    || { echo "FAIL: resume recomputed instead of hitting the store:"; cat target/ci/resume_resumed.log; exit 1; }
echo "torn-tail resume is byte-identical with every cell served from disk"

echo "== shard smoke (1/2 + 2/2 + --merge 2 vs serial) =="
# ISSUE 7 acceptance (b): two shards over a shared store render disjoint
# row sets covering the grid, and --merge reassembles the serial bytes.
rm -rf target/ci/shard-cache
export VEGA_CACHE_DIR=target/ci/shard-cache
./target/release/vega sweep "${SWEEP_GRID[@]}" --shard 1/2 > target/ci/shard1.csv
./target/release/vega sweep "${SWEEP_GRID[@]}" --shard 2/2 > target/ci/shard2.csv
./target/release/vega sweep "${SWEEP_GRID[@]}" --merge 2 --stats > target/ci/shard_merged.csv 2> target/ci/shard_merged.log
export VEGA_CACHE_DIR="$CI_RUN_CACHE"
diff target/ci/shard_merged.csv target/ci/sweep_serial.csv
{ tail -n +2 target/ci/shard1.csv; tail -n +2 target/ci/shard2.csv; } | sort > target/ci/shard_union.csv
tail -n +2 target/ci/sweep_serial.csv | sort > target/ci/shard_expected.csv
diff target/ci/shard_union.csv target/ci/shard_expected.csv
grep -q "journal: 4 prior / 0 recorded" target/ci/shard_merged.log \
    || { echo "FAIL: merge did not replay the shard journals:"; cat target/ci/shard_merged.log; exit 1; }
echo "shard union equals the serial grid and --merge reassembles its bytes"

echo "== exit-code smoke (failed cells exit 3, grid still renders) =="
# ISSUE 7 satellite (a): keep-going semantics. --timeout-ms 0 times out
# every cell deterministically; the grid renders a status row per cell
# and the process exits 3 so CI cannot green a half-failed grid.
rm -rf target/ci/exit3-cache
rc=0
VEGA_CACHE_DIR=target/ci/exit3-cache ./target/release/vega sweep "${SWEEP_GRID[@]}" --timeout-ms 0 \
    > target/ci/exit3.csv 2> target/ci/exit3.log || rc=$?
[ "$rc" -eq 3 ] || { echo "FAIL: expected exit 3, got $rc:"; cat target/ci/exit3.log; exit 1; }
grep -q "timeout after 0 ms" target/ci/exit3.csv \
    || { echo "FAIL: timed-out cells did not render status rows:"; cat target/ci/exit3.csv; exit 1; }
grep -q "cell(s) ended in error/timeout" target/ci/exit3.log \
    || { echo "FAIL: stderr did not name the damage:"; cat target/ci/exit3.log; exit 1; }
echo "timed-out grid rendered every status row and exited 3"

echo "== cache-degradation smoke (VEGA_CACHE_DIR is a regular file) =="
# ISSUE 7 acceptance (c): an unusable cache dir degrades the store and
# the journal to counted warnings; the run completes in memory with the
# exact bytes of a cache-off run. A regular file fails under any uid
# (read-only permission bits would be bypassed by root CI containers).
DEGRADED_FILE=$(mktemp)
if VEGA_CACHE_DIR="$DEGRADED_FILE" ./target/release/vega sweep "${SWEEP_GRID[@]}" --jobs 2 --stats \
    > target/ci/degraded.csv 2> target/ci/degraded.log; then
    diff target/ci/degraded.csv target/ci/sweep_serial.csv
    grep -q "disabled" target/ci/degraded.log \
        || { echo "FAIL: degraded run did not warn:"; cat target/ci/degraded.log; exit 1; }
else
    echo "FAIL: degraded run did not complete:"; cat target/ci/degraded.log; exit 1
fi
rm -f "$DEGRADED_FILE"
echo "unusable cache dir degraded to a completed, byte-identical in-memory run"

# Read-only store directory variant: skipped when the uid can write
# through the permission bits anyway (root containers).
mkdir -p target/ci/readonly-cache && chmod a-w target/ci/readonly-cache
if touch target/ci/readonly-cache/probe 2>/dev/null; then
    rm -f target/ci/readonly-cache/probe
    echo "read-only-store smoke skipped (uid bypasses permission bits)"
else
    echo "== write-error smoke (read-only store directory) =="
    VEGA_CACHE_DIR=target/ci/readonly-cache ./target/release/vega sweep "${FP8_GRID[@]}" --stats \
        > target/ci/readonly.csv 2> target/ci/readonly.log
    diff target/ci/readonly.csv target/ci/fp8_serial.csv
    grep -q "disk: 0 hits / 2 misses / 0 writes / 2 write-errors" target/ci/readonly.log \
        || { echo "FAIL: failed writes not counted:"; cat target/ci/readonly.log; exit 1; }
    grep -q "disk cache write failed" target/ci/readonly.log \
        || { echo "FAIL: failed writes did not warn:"; cat target/ci/readonly.log; exit 1; }
    echo "read-only store degraded to counted write-errors with correct output"
fi
chmod u+w target/ci/readonly-cache

echo "== fault-isolation gate (panicking cell stays one SimError) =="
# Run the isolation regressions first and by name (like the key-stability
# gate): a broken catch_unwind path fails on its own line here instead of
# drowning in the full suite below.
cargo test -q --test sweep_determinism panic

echo "== cargo test -q (fresh cache dir, defense in depth) =="
# The regression oracles are memory-only by construction (paper_anchors'
# oracle(), fresh engines in sweep_determinism, private dirs in
# disk_cache); the per-run VEGA_CACHE_DIR is defense in depth so any
# code path that does open the default store during tests can never read
# entries written by an older build (stale if a timing-model change
# forgot its MODEL_EPOCH bump).
rm -rf target/ci/test-cache
VEGA_CACHE_DIR=target/ci/test-cache cargo test -q

if [ "${CI_SKIP_BENCH:-0}" != "1" ]; then
    # VEGA_CACHE=off: bench timings and the printed reproduction record
    # must reflect the live simulator, never a warm (possibly stale)
    # target/vega-cache left by an earlier run.
    echo "== hotpath bench smoke (VEGA_BENCH_ITERS=1) =="
    VEGA_CACHE=off VEGA_BENCH_ITERS=1 cargo bench --bench hotpath
    echo "== sweep-engine bench smoke (VEGA_BENCH_ITERS=1, VEGA_JOBS=2) =="
    VEGA_CACHE=off VEGA_BENCH_ITERS=1 VEGA_JOBS=2 cargo bench --bench sweeps
fi

echo "ci.sh: all gates passed"
