#!/usr/bin/env bash
# Tier-1 gate + perf smoke for the Vega reproduction.
#
#   scripts/ci.sh            full run (fmt, build, test, bench smoke)
#   CI_SKIP_BENCH=1 ...      skip the bench smoke (e.g. resource-starved CI)
#
# The bench smoke runs every hotpath and sweep case once
# (VEGA_BENCH_ITERS=1) so a scheduler regression that hangs or panics is
# caught even where full benchmarking is too slow; BENCH_hotpath.json and
# BENCH_sweeps.json land in rust/. The determinism smoke diffs a --jobs 2
# `vega repro` against the serial run byte-for-byte.

set -euo pipefail
cd "$(dirname "$0")/../rust"

echo "== cargo fmt --check =="
# Non-fatal: formatting drift should not mask real build/test failures,
# but it is reported loudly.
if ! cargo fmt --check 2>/dev/null; then
    echo "WARNING: cargo fmt --check reported drift (or rustfmt is unavailable)"
fi

echo "== cargo build --release =="
cargo build --release

echo "== sweep determinism smoke (vega repro table5: --jobs 2 vs serial) =="
mkdir -p target/ci
./target/release/vega repro table5 --jobs 1 > target/ci/repro_table5_serial.txt
./target/release/vega repro table5 --jobs 2 > target/ci/repro_table5_jobs2.txt
diff target/ci/repro_table5_serial.txt target/ci/repro_table5_jobs2.txt
echo "parallel repro output is byte-identical to serial"

echo "== cargo test -q =="
cargo test -q

if [ "${CI_SKIP_BENCH:-0}" != "1" ]; then
    echo "== hotpath bench smoke (VEGA_BENCH_ITERS=1) =="
    VEGA_BENCH_ITERS=1 cargo bench --bench hotpath
    echo "== sweep-engine bench smoke (VEGA_BENCH_ITERS=1, VEGA_JOBS=2) =="
    VEGA_BENCH_ITERS=1 VEGA_JOBS=2 cargo bench --bench sweeps
fi

echo "ci.sh: all gates passed"
