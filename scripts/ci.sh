#!/usr/bin/env bash
# Tier-1 gate + perf smoke for the Vega reproduction.
#
#   scripts/ci.sh            full run (fmt, build, test, bench smoke)
#   CI_SKIP_BENCH=1 ...      skip the bench smoke (e.g. resource-starved CI)
#
# The bench smoke runs every hotpath case once (VEGA_BENCH_ITERS=1) so a
# scheduler regression that hangs or panics is caught even where full
# benchmarking is too slow; BENCH_hotpath.json lands in rust/.

set -euo pipefail
cd "$(dirname "$0")/../rust"

echo "== cargo fmt --check =="
# Non-fatal: formatting drift should not mask real build/test failures,
# but it is reported loudly.
if ! cargo fmt --check 2>/dev/null; then
    echo "WARNING: cargo fmt --check reported drift (or rustfmt is unavailable)"
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

if [ "${CI_SKIP_BENCH:-0}" != "1" ]; then
    echo "== hotpath bench smoke (VEGA_BENCH_ITERS=1) =="
    VEGA_BENCH_ITERS=1 cargo bench --bench hotpath
fi

echo "ci.sh: all gates passed"
