//! The SoC domain (§II-A): fabric controller, L2, I/O DMA, clocks — and
//! the top-level [`VegaSoc`] composing every subsystem of Fig. 1.

pub mod fll;
pub mod io_dma;
pub mod l2;

pub use fll::{ClockTree, Fll};
pub use io_dma::{Channel, IoDma};
pub use l2::{L2, L2_BASE, L2_SIZE};

use crate::cluster::Cluster;
use crate::cwu::Cwu;
use crate::isa::{Program, Reg};
use crate::iss::{self, CoreStats};
use crate::mem::{HyperRam, Mram};

/// The whole chip: one instance per simulation.
///
/// Subsystems are public: experiment drivers compose them directly (e.g.
/// the DNN pipeline books I/O-DMA and cluster time itself), which mirrors
/// how the real software stack programs the hardware.
pub struct VegaSoc {
    pub l2: L2,
    pub cluster: Cluster,
    pub mram: Mram,
    pub hyperram: HyperRam,
    pub io_dma: IoDma,
    pub clocks: ClockTree,
    pub cwu: Cwu,
}

impl VegaSoc {
    pub fn new() -> Self {
        Self {
            l2: L2::new(),
            cluster: Cluster::new(),
            mram: Mram::new(),
            hyperram: HyperRam::new(8 * 1024 * 1024),
            io_dma: IoDma::new(),
            clocks: ClockTree::nominal(),
            cwu: Cwu::new(),
        }
    }

    /// Run a program on the fabric controller (single core against L2,
    /// no TCDM: the FC serves SoC management and light compute, §III).
    pub fn run_fc(
        &mut self,
        prog: &Program,
        init: &[(Reg, u32)],
        max_cycles: u64,
    ) -> CoreStats {
        iss::core::run_single(prog, &mut self.l2.mem, init, max_cycles)
    }

    /// Run a data-parallel kernel on the cluster (cores 0..n_active).
    pub fn run_cluster(
        &mut self,
        prog: &Program,
        n_active: usize,
        init: impl Fn(usize) -> Vec<(Reg, u32)>,
        max_cycles: u64,
    ) -> crate::cluster::ClusterStats {
        self.cluster.run_program(prog, n_active, &mut self.l2.mem, init, max_cycles)
    }
}

impl Default for VegaSoc {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{Asm, A0, T0, T1};

    #[test]
    fn fc_runs_against_l2() {
        let mut soc = VegaSoc::new();
        soc.l2.mem.write_i32s(L2_BASE + 0x100, &[20, 22]);
        let mut a = Asm::new("fc");
        a.lw(T0, A0, 0);
        a.lw(T1, A0, 4);
        a.add(T0, T0, T1);
        a.sw(T0, A0, 8);
        a.halt();
        let prog = a.finish().unwrap();
        let stats = soc.run_fc(&prog, &[(A0, L2_BASE + 0x100)], 10_000);
        assert_eq!(stats.by_class.load, 2);
        assert_eq!(soc.l2.mem.read_i32s(L2_BASE + 0x108, 1)[0], 42);
    }

    #[test]
    fn weight_flow_mram_to_l2_to_tcdm() {
        // The Fig. 9 data flow, functionally: MRAM -> L2 -> L1.
        let mut soc = VegaSoc::new();
        let weights: Vec<u8> = (0..64u8).collect();
        soc.mram.write(0, &weights);
        let w = soc.mram.read(0, 64).expect("clean MRAM read");
        soc.l2.mem.write_bytes(L2_BASE + 0x2000, &w);
        let w2 = soc.l2.mem.read_bytes(L2_BASE + 0x2000, 64).to_vec();
        soc.cluster.tcdm.mem.write_bytes(crate::cluster::TCDM_BASE, &w2);
        assert_eq!(
            soc.cluster.tcdm.mem.read_bytes(crate::cluster::TCDM_BASE, 64),
            &weights[..]
        );
    }
}
