//! SoC L2 memory: 4 word-interleaved banks (1.5 MB) + 64 kB private
//! (§II-A), selectively state-retentive in sleep.
//!
//! The interleaved banks give 6.7 GB/s aggregate to peripherals and
//! accelerators; each of the (up to) four concurrent masters (FC, I/O DMA,
//! cluster AXI, CSI) can stream from its own bank in the common case. For
//! the DNN pipeline what matters is that concurrent I/O-DMA and cluster-DMA
//! streams do not serialise — modelled by the port-booking helper.

use crate::iss::FlatMem;

pub const L2_BASE: u32 = 0x1C00_0000;
/// Interleaved portion: 1.5 MB in 4 word-interleaved banks.
pub const L2_INTERLEAVED: usize = 1536 * 1024;
/// FC-private portion: 64 kB.
pub const L2_PRIVATE: usize = 64 * 1024;
pub const L2_SIZE: usize = L2_INTERLEAVED + L2_PRIVATE;
pub const L2_BANKS: usize = 4;

/// Retention granularity: SRAM cuts of 16 kB can individually be held
/// retentive in sleep (1.2 µW for one cut … 112 µW for all, §II-A).
pub const RETENTION_CUT_BYTES: usize = 16 * 1024;

/// The L2 memory with retention configuration.
pub struct L2 {
    pub mem: FlatMem,
    /// Number of 16 kB cuts configured retentive for the next sleep.
    pub retentive_cuts: usize,
    /// Aggregate bytes served (for bandwidth accounting).
    pub bytes_served: u64,
}

impl L2 {
    pub fn new() -> Self {
        Self {
            mem: FlatMem::new(L2_BASE, L2_SIZE),
            retentive_cuts: 0,
            bytes_served: 0,
        }
    }

    pub fn bank_of(addr: u32) -> usize {
        ((addr >> 2) as usize) % L2_BANKS
    }

    /// Configure `bytes` of L2 (rounded up to 16 kB cuts) as retentive.
    pub fn set_retentive_bytes(&mut self, bytes: usize) {
        assert!(bytes <= L2_SIZE);
        self.retentive_cuts = bytes.div_ceil(RETENTION_CUT_BYTES);
    }

    pub fn retentive_bytes(&self) -> usize {
        self.retentive_cuts * RETENTION_CUT_BYTES
    }

    /// Sleep transition: non-retentive cuts lose state.
    pub fn enter_sleep(&mut self) {
        let keep = self.retentive_bytes().min(L2_SIZE);
        self.mem.data[keep..].fill(0);
    }

    /// Peak aggregate bandwidth in bytes/cycle (4 banks × 32-bit + the
    /// private port ≈ 6.7 GB/s at 400 MHz peripheral clock).
    pub fn peak_bytes_per_cycle() -> f64 {
        (L2_BANKS * 4) as f64 + 0.75 // interleaved banks + private port share
    }
}

impl Default for L2 {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interleaving() {
        assert_ne!(L2::bank_of(L2_BASE), L2::bank_of(L2_BASE + 4));
        assert_eq!(L2::bank_of(L2_BASE), L2::bank_of(L2_BASE + 16));
    }

    #[test]
    fn retention_rounds_to_cuts() {
        let mut l2 = L2::new();
        l2.set_retentive_bytes(20 * 1024);
        assert_eq!(l2.retentive_cuts, 2);
        assert_eq!(l2.retentive_bytes(), 32 * 1024);
    }

    #[test]
    fn sleep_wipes_non_retentive_state() {
        let mut l2 = L2::new();
        l2.mem.write_i32s(L2_BASE, &[7; 8]);
        l2.mem.write_i32s(L2_BASE + 64 * 1024, &[9; 8]);
        l2.set_retentive_bytes(16 * 1024);
        l2.enter_sleep();
        assert_eq!(l2.mem.read_i32s(L2_BASE, 8), vec![7; 8]); // retained
        assert_eq!(l2.mem.read_i32s(L2_BASE + 64 * 1024, 8), vec![0; 8]); // lost
    }

    #[test]
    fn full_retention_size_matches_paper() {
        // "1.6 MB of state-retentive L2" = 100 cuts of 16 kB.
        assert_eq!(L2_SIZE / RETENTION_CUT_BYTES, 100);
    }
}
