//! The I/O DMA subsystem (µDMA, §II-A, [11]).
//!
//! Every peripheral owns a dedicated DMA channel for autonomous transfers
//! into L2 without FC involvement; the MRAM controller is "managed just
//! like a peripheral" on an auxiliary channel. For the DNN flow the
//! relevant channels are MRAM→L2 and HyperBus→L2 (weight streaming,
//! Fig. 9 stage 1), which run concurrently with cluster compute.

use crate::common::Cycles;
use crate::mem::BulkChannel;

/// Peripheral channel identifiers (subset modelled).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Channel {
    Mram,
    HyperBus,
    Spi,
    I2s,
    Csi2,
    Sdio,
    Uart,
}

/// Per-channel transfer statistics.
#[derive(Debug, Clone, Copy, Default)]
pub struct ChannelStats {
    pub transfers: u64,
    pub bytes: u64,
    pub busy_cycles: Cycles,
}

/// The µDMA engine: timing + accounting (data movement is performed by the
/// caller against the functional backing stores, so it is exact).
#[derive(Debug, Default)]
pub struct IoDma {
    pub mram: ChannelStats,
    pub hyper: ChannelStats,
    pub spi: ChannelStats,
    pub other: ChannelStats,
}

impl IoDma {
    pub fn new() -> Self {
        Self::default()
    }

    fn stats_mut(&mut self, ch: Channel) -> &mut ChannelStats {
        match ch {
            Channel::Mram => &mut self.mram,
            Channel::HyperBus => &mut self.hyper,
            Channel::Spi => &mut self.spi,
            _ => &mut self.other,
        }
    }

    /// Account a bulk transfer of `bytes` on `ch` through `link` at SoC
    /// frequency `f_soc`; returns the channel-busy cycles.
    ///
    /// Channels are independent engines: transfers on different channels
    /// overlap (the caller composes latencies; see the DNN pipeline).
    pub fn transfer(
        &mut self,
        ch: Channel,
        link: &dyn BulkChannel,
        bytes: u64,
        f_soc: f64,
        write: bool,
    ) -> Cycles {
        let cycles = link.transfer_cycles(bytes, f_soc, write);
        let s = self.stats_mut(ch);
        s.transfers += 1;
        s.bytes += bytes;
        s.busy_cycles += cycles;
        cycles
    }

    pub fn total_bytes(&self) -> u64 {
        self.mram.bytes + self.hyper.bytes + self.spi.bytes + self.other.bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::{HyperRam, Mram};

    #[test]
    fn channels_account_independently() {
        let mut dma = IoDma::new();
        let mram = Mram::new();
        let hyper = HyperRam::new(1 << 20);
        let c1 = dma.transfer(Channel::Mram, &mram, 4096, 250e6, false);
        let c2 = dma.transfer(Channel::HyperBus, &hyper, 4096, 250e6, false);
        assert!(c1 > 0 && c2 > 0);
        assert_eq!(dma.mram.transfers, 1);
        assert_eq!(dma.hyper.transfers, 1);
        assert_eq!(dma.total_bytes(), 8192);
        // MRAM channel is faster than HyperBus per Table VI (corrected).
        assert!(c1 < c2);
    }
}
