//! Frequency-locked loops (§III): three FLLs multiply the 32 kHz crystal
//! up to the SoC, cluster and peripheral clocks.

use crate::common::Hertz;

/// Reference crystal (QOSC).
pub const F_REF: Hertz = 32_768.0;

/// One FLL channel.
#[derive(Debug, Clone)]
pub struct Fll {
    pub name: &'static str,
    mult: u32,
    /// Reference cycles to re-lock after a multiplier change.
    pub lock_ref_cycles: u32,
}

impl Fll {
    pub fn new(name: &'static str) -> Self {
        Self { name, mult: 1, lock_ref_cycles: 16 }
    }

    pub fn freq(&self) -> Hertz {
        F_REF * self.mult as f64
    }

    /// Program the output frequency (rounded to an integer multiple of the
    /// reference); returns the re-lock time in seconds.
    pub fn set_freq(&mut self, target: Hertz) -> f64 {
        let m = (target / F_REF).round().max(1.0) as u32;
        let changed = m != self.mult;
        self.mult = m;
        if changed {
            self.lock_ref_cycles as f64 / F_REF
        } else {
            0.0
        }
    }
}

/// The three Vega FLLs.
#[derive(Debug, Clone)]
pub struct ClockTree {
    pub soc: Fll,
    pub cluster: Fll,
    pub periph: Fll,
}

impl ClockTree {
    /// Nominal operating point of the DNN experiments (§IV-B):
    /// f_SoC = f_CL = 250 MHz.
    pub fn nominal() -> Self {
        let mut t = Self {
            soc: Fll::new("soc"),
            cluster: Fll::new("cluster"),
            periph: Fll::new("periph"),
        };
        t.soc.set_freq(250e6);
        t.cluster.set_freq(250e6);
        t.periph.set_freq(100e6);
        t
    }

    /// Low-voltage point: 0.6 V / 220 MHz (Fig. 8 "LV").
    pub fn low_voltage() -> Self {
        let mut t = Self::nominal();
        t.soc.set_freq(220e6);
        t.cluster.set_freq(220e6);
        t
    }

    /// High-voltage point: 0.8 V / 450 MHz (Fig. 8 "HV").
    pub fn high_voltage() -> Self {
        let mut t = Self::nominal();
        t.soc.set_freq(450e6);
        t.cluster.set_freq(450e6);
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fll_multiplies_reference() {
        let mut f = Fll::new("t");
        let lock = f.set_freq(250e6);
        assert!(lock > 0.0);
        let rel = (f.freq() - 250e6).abs() / 250e6;
        assert!(rel < 1e-4, "freq = {}", f.freq());
        // Same frequency again: no re-lock.
        assert_eq!(f.set_freq(f.freq()), 0.0);
    }

    #[test]
    fn operating_points() {
        assert!((ClockTree::high_voltage().cluster.freq() - 450e6).abs() < 1e4);
        assert!((ClockTree::low_voltage().cluster.freq() - 220e6).abs() < 1e4);
    }
}
