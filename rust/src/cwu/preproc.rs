//! The CWU's low-power preprocessor (§II-B, Fig. 2).
//!
//! Up to eight independent channels of lightweight conditioning between
//! the SPI master and Hypnos: data-width conversion, offset removal and
//! low-pass filtering (both exponential-moving-average based "to save
//! area and power"), subsampling, and local-binary-pattern filtering.

/// Configuration of one preprocessor channel (stages apply in the order
/// they appear in the struct, mirroring the hardware chain).
#[derive(Debug, Clone, Copy)]
pub struct ChannelConfig {
    /// Input width in bits (sensor word); output is `out_width` bits.
    pub in_width: u32,
    pub out_width: u32,
    /// Offset removal: subtract an EMA baseline with decay 2^-k (None =
    /// bypass).
    pub offset_k: Option<u32>,
    /// Low-pass: EMA with decay 2^-k (None = bypass).
    pub lowpass_k: Option<u32>,
    /// Keep one sample in `n` (1 = bypass).
    pub subsample: u32,
    /// Local-binary-pattern output: emit the 8-bit LBP code of the last 8
    /// samples instead of the amplitude.
    pub lbp: bool,
}

impl Default for ChannelConfig {
    fn default() -> Self {
        Self {
            in_width: 16,
            out_width: 16,
            offset_k: None,
            lowpass_k: None,
            subsample: 1,
            lbp: false,
        }
    }
}

/// Runtime state of one channel.
#[derive(Debug, Clone)]
pub struct ChannelState {
    cfg: ChannelConfig,
    /// EMA baseline accumulator (fixed point, <<16).
    offset_acc: i64,
    /// EMA low-pass accumulator (fixed point, <<16).
    lp_acc: i64,
    lp_init: bool,
    /// Subsample phase.
    phase: u32,
    /// Last 8 samples for LBP.
    history: [i32; 8],
    hist_len: usize,
    pub samples_in: u64,
    pub samples_out: u64,
}

impl ChannelState {
    pub fn new(cfg: ChannelConfig) -> Self {
        assert!(cfg.subsample >= 1);
        assert!(cfg.in_width <= 32 && cfg.out_width <= 32);
        Self {
            cfg,
            offset_acc: 0,
            lp_acc: 0,
            lp_init: false,
            phase: 0,
            history: [0; 8],
            hist_len: 0,
            samples_in: 0,
            samples_out: 0,
        }
    }

    /// Process one raw sensor word; returns the conditioned sample when
    /// one is emitted (subsampling swallows the rest).
    pub fn push(&mut self, raw: u32) -> Option<u32> {
        self.samples_in += 1;
        // Width conversion: sign-extend from in_width.
        let shift = 32 - self.cfg.in_width;
        let mut x = ((raw << shift) as i32) >> shift;

        // Offset removal: x - EMA(x).
        if let Some(k) = self.cfg.offset_k {
            let base = (self.offset_acc >> 16) as i32;
            self.offset_acc += ((x - base) as i64) << (16 - k.min(15) as i64);
            x -= (self.offset_acc >> 16) as i32;
        }

        // Low-pass: EMA(x).
        if let Some(k) = self.cfg.lowpass_k {
            if !self.lp_init {
                self.lp_acc = (x as i64) << 16;
                self.lp_init = true;
            }
            let y = (self.lp_acc >> 16) as i32;
            self.lp_acc += ((x - y) as i64) << (16 - k.min(15) as i64);
            x = (self.lp_acc >> 16) as i32;
        }

        // History for LBP (pre-subsample, like the hardware chain).
        self.history.rotate_left(1);
        self.history[7] = x;
        self.hist_len = (self.hist_len + 1).min(8);

        // Subsample.
        self.phase += 1;
        if self.phase < self.cfg.subsample {
            return None;
        }
        self.phase = 0;

        let out = if self.cfg.lbp {
            // LBP code: compare the 8 history samples to their mean.
            let n = self.hist_len.max(1);
            let mean: i64 =
                self.history[8 - n..].iter().map(|&v| v as i64).sum::<i64>() / n as i64;
            let mut code = 0u32;
            for (i, &v) in self.history.iter().enumerate() {
                if (v as i64) >= mean {
                    code |= 1 << i;
                }
            }
            code
        } else {
            // Width-convert to out_width (arithmetic truncate).
            let ow = self.cfg.out_width;
            let mask = if ow >= 32 { u32::MAX } else { (1u32 << ow) - 1 };
            (x as u32) & mask
        };
        self.samples_out += 1;
        Some(out)
    }
}

/// The 8-channel preprocessor.
pub struct Preprocessor {
    pub channels: Vec<ChannelState>,
}

impl Preprocessor {
    pub fn new(configs: &[ChannelConfig]) -> Self {
        assert!(configs.len() <= 8, "preprocessor supports up to 8 channels");
        Self {
            channels: configs.iter().map(|&c| ChannelState::new(c)).collect(),
        }
    }

    /// Push one raw word per channel; returns a full conditioned frame
    /// when *all* channels emitted (channels are configured to the same
    /// output rate in practice).
    pub fn push_frame(&mut self, raw: &[u32]) -> Option<Vec<u32>> {
        assert_eq!(raw.len(), self.channels.len());
        let outs: Vec<Option<u32>> =
            self.channels.iter_mut().zip(raw).map(|(ch, &r)| ch.push(r)).collect();
        if outs.iter().all(|o| o.is_some()) {
            Some(outs.into_iter().map(|o| o.unwrap()).collect())
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn width_conversion_sign_extends() {
        let mut ch = ChannelState::new(ChannelConfig {
            in_width: 12,
            out_width: 16,
            ..Default::default()
        });
        // 0xFFF as 12-bit = -1 -> 16-bit 0xFFFF
        assert_eq!(ch.push(0xFFF), Some(0xFFFF));
    }

    #[test]
    fn offset_removal_converges_to_zero_mean() {
        let mut ch = ChannelState::new(ChannelConfig {
            offset_k: Some(4),
            ..Default::default()
        });
        let mut last = 0i32;
        for _ in 0..500 {
            let out = ch.push(1000).unwrap();
            last = ((out << 16) as i32) >> 16;
        }
        assert!(last.abs() < 5, "residual offset = {last}");
    }

    #[test]
    fn lowpass_smooths_alternating_signal() {
        let mut ch = ChannelState::new(ChannelConfig {
            lowpass_k: Some(3),
            ..Default::default()
        });
        let mut outs = Vec::new();
        for i in 0..200 {
            let x = if i % 2 == 0 { 100u32 } else { 0 };
            outs.push(((ch.push(x).unwrap() << 16) as i32) >> 16);
        }
        // Settled output should hover near the mean (50), never the rails.
        let tail = &outs[100..];
        assert!(tail.iter().all(|&v| (30..=70).contains(&v)), "{tail:?}");
    }

    #[test]
    fn subsample_keeps_one_in_n() {
        let mut ch = ChannelState::new(ChannelConfig { subsample: 4, ..Default::default() });
        let mut emitted = 0;
        for i in 0..40 {
            if ch.push(i).is_some() {
                emitted += 1;
            }
        }
        assert_eq!(emitted, 10);
        assert_eq!(ch.samples_in, 40);
        assert_eq!(ch.samples_out, 10);
    }

    #[test]
    fn lbp_distinguishes_rising_from_constant() {
        let mk = || ChannelState::new(ChannelConfig { lbp: true, ..Default::default() });
        let mut rising = mk();
        let mut flat = mk();
        let mut r_code = 0;
        let mut f_code = 0;
        for i in 0..16 {
            if let Some(c) = rising.push(i * 100) {
                r_code = c;
            }
            if let Some(c) = flat.push(500) {
                f_code = c;
            }
        }
        assert_ne!(r_code, 0);
        assert_ne!(r_code, f_code);
        // Rising ramp: newest samples above mean -> high bits set.
        assert!(r_code & 0x80 != 0);
    }

    #[test]
    fn frame_assembly_waits_for_all_channels() {
        let cfgs = [
            ChannelConfig { subsample: 2, ..Default::default() },
            ChannelConfig { subsample: 2, ..Default::default() },
        ];
        let mut pp = Preprocessor::new(&cfgs);
        assert!(pp.push_frame(&[1, 2]).is_none());
        assert!(pp.push_frame(&[3, 4]).is_some());
    }
}
