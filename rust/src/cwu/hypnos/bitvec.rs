//! HD bit-vectors: the 512/1024/1536/2048-bit hypervectors Hypnos
//! operates on (§II-B), packed into u64 words.

/// Supported HD dimensions (§II-B: "512, 1024, 1536, or 2048-bit").
pub const HD_DIMS: [usize; 4] = [512, 1024, 1536, 2048];

/// Datapath width: 512 bits processed per cycle.
pub const DATAPATH_BITS: usize = 512;

/// A fixed-width binary hypervector.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HdVec {
    pub bits: usize,
    words: Vec<u64>,
}

impl HdVec {
    pub fn zero(bits: usize) -> Self {
        assert!(HD_DIMS.contains(&bits), "unsupported HD dimension {bits}");
        Self { bits, words: vec![0; bits / 64] }
    }

    pub fn from_words(bits: usize, words: Vec<u64>) -> Self {
        assert_eq!(words.len(), bits / 64);
        Self { bits, words }
    }

    pub fn words(&self) -> &[u64] {
        &self.words
    }

    pub(crate) fn words_mut(&mut self) -> &mut [u64] {
        &mut self.words
    }

    pub fn get(&self, i: usize) -> bool {
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    pub fn set(&mut self, i: usize, v: bool) {
        let (w, b) = (i / 64, i % 64);
        if v {
            self.words[w] |= 1 << b;
        } else {
            self.words[w] &= !(1 << b);
        }
    }

    pub fn flip(&mut self, i: usize) {
        self.words[i / 64] ^= 1 << (i % 64);
    }

    /// XOR (the HDC *bind* primitive).
    pub fn xor(&self, o: &Self) -> Self {
        assert_eq!(self.bits, o.bits);
        Self {
            bits: self.bits,
            words: self.words.iter().zip(&o.words).map(|(a, b)| a ^ b).collect(),
        }
    }

    pub fn and(&self, o: &Self) -> Self {
        assert_eq!(self.bits, o.bits);
        Self {
            bits: self.bits,
            words: self.words.iter().zip(&o.words).map(|(a, b)| a & b).collect(),
        }
    }

    pub fn not(&self) -> Self {
        let mut v = Self {
            bits: self.bits,
            words: self.words.iter().map(|a| !a).collect(),
        };
        v.mask_tail();
        v
    }

    fn mask_tail(&mut self) {
        let tail = self.bits % 64;
        if tail != 0 {
            let last = self.words.len() - 1;
            self.words[last] &= (1u64 << tail) - 1;
        }
    }

    /// Cyclic rotation by `n` bits (the HDC *permute* primitive ρ, used
    /// for sequence/n-gram encoding). Word-level: two shifts per word
    /// (§Perf — the bit-by-bit version dominated the encode loop).
    pub fn rotate(&self, n: usize) -> Self {
        let n = n % self.bits;
        if n == 0 {
            return self.clone();
        }
        let nw = self.words.len();
        let (ws, bs) = (n / 64, n % 64);
        let mut out = Self::zero(self.bits);
        for i in 0..nw {
            let w = self.words[i];
            let lo_idx = (i + ws) % nw;
            out.words[lo_idx] |= w << bs;
            if bs != 0 {
                let hi_idx = (i + ws + 1) % nw;
                out.words[hi_idx] |= w >> (64 - bs);
            }
        }
        out
    }

    /// Hamming distance (the AM similarity metric).
    pub fn hamming(&self, o: &Self) -> u32 {
        assert_eq!(self.bits, o.bits);
        self.words
            .iter()
            .zip(&o.words)
            .map(|(a, b)| (a ^ b).count_ones())
            .sum()
    }

    pub fn count_ones(&self) -> u32 {
        self.words.iter().map(|w| w.count_ones()).sum()
    }

    /// Datapath cycles to stream this vector through the 512-bit engine.
    pub fn datapath_cycles(&self) -> u64 {
        (self.bits / DATAPATH_BITS).max(1) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_flip() {
        let mut v = HdVec::zero(512);
        v.set(0, true);
        v.set(511, true);
        assert!(v.get(0) && v.get(511) && !v.get(100));
        v.flip(511);
        assert!(!v.get(511));
        assert_eq!(v.count_ones(), 1);
    }

    #[test]
    fn bind_is_involutive() {
        let mut a = HdVec::zero(512);
        let mut b = HdVec::zero(512);
        for i in (0..512).step_by(3) {
            a.set(i, true);
        }
        for i in (0..512).step_by(5) {
            b.set(i, true);
        }
        let bound = a.xor(&b);
        assert_eq!(bound.xor(&b), a); // unbind recovers the operand
        assert_eq!(a.hamming(&bound), b.count_ones());
    }

    #[test]
    fn rotate_preserves_ones_and_inverts() {
        let mut a = HdVec::zero(1024);
        for i in [0, 5, 900, 1023] {
            a.set(i, true);
        }
        let r = a.rotate(17);
        assert_eq!(r.count_ones(), a.count_ones());
        assert!(r.get(17) && r.get((1023 + 17) % 1024));
        assert_eq!(r.rotate(1024 - 17), a);
    }

    #[test]
    fn not_masks_tail() {
        let v = HdVec::zero(512).not();
        assert_eq!(v.count_ones(), 512);
    }

    #[test]
    fn hamming_basics() {
        let z = HdVec::zero(2048);
        let o = z.not();
        assert_eq!(z.hamming(&o), 2048);
        assert_eq!(z.hamming(&z), 0);
    }

    #[test]
    fn datapath_cycles_scale_with_dim() {
        assert_eq!(HdVec::zero(512).datapath_cycles(), 1);
        assert_eq!(HdVec::zero(2048).datapath_cycles(), 4);
    }

    #[test]
    #[should_panic]
    fn rejects_unsupported_dim() {
        HdVec::zero(777);
    }
}
