//! Item-memory rematerialization (§II-B).
//!
//! Instead of a ROM item memory, Hypnos *rematerialises* IM vectors: a
//! hardwired pseudo-random seed vector is passed through a chain of four
//! hardwired random permutations, selected per step by the serialized
//! input bits, producing a quasi-orthogonal hypervector in D cycles for a
//! D-bit input. Low-dimensional values that differ in even one bit diverge
//! onto unrelated permutation paths — giving IM's quasi-orthogonality
//! without storing any mapping.
//!
//! The silicon hardwires the permutations at tape-out; we hardwire them at
//! build time from fixed seeds (deterministic across runs).

use std::sync::OnceLock;

use crate::common::Rng;

use super::bitvec::HdVec;

/// Maximum HD dimension: permutation tables cover it; smaller dimensions
/// use the table modulo their size (still a bijection per dimension
/// because tables are built per supported size).
pub const N_PERMS: usize = 4;

/// One permutation table per (perm index, HD dim).
struct PermSet {
    /// tables[p] maps source bit -> destination bit.
    tables: [Vec<u32>; N_PERMS],
}

fn fisher_yates(n: usize, rng: &mut Rng) -> Vec<u32> {
    let mut t: Vec<u32> = (0..n as u32).collect();
    for i in (1..n).rev() {
        let j = rng.below((i + 1) as u64) as usize;
        t.swap(i, j);
    }
    t
}

static PERMS_BY_DIM: OnceLock<Vec<(usize, PermSet)>> = OnceLock::new();

fn perms_by_dim() -> &'static [(usize, PermSet)] {
    PERMS_BY_DIM.get_or_init(|| {
        super::bitvec::HD_DIMS
            .iter()
            .map(|&dim| {
                let tables = std::array::from_fn(|p| {
                    // Fixed seeds: "hardwired random permutations".
                    let mut rng = Rng::new(0x5EED_0000 + (p as u64) * 97 + dim as u64);
                    fisher_yates(dim, &mut rng)
                });
                (dim, PermSet { tables })
            })
            .collect()
    })
}

fn perm_table(dim: usize, p: usize) -> &'static [u32] {
    let set = &perms_by_dim()
        .iter()
        .find(|(d, _)| *d == dim)
        .expect("unsupported dim")
        .1;
    &set.tables[p]
}

/// Apply hardwired permutation `p` (0..4) to `v`.
///
/// Scatter only the set bits, walking source words with
/// `trailing_zeros` and writing destination words directly (§Perf: the
/// per-bit get/set version made IM rematerialization the simulator's
/// hottest loop).
pub fn apply(v: &HdVec, p: usize) -> HdVec {
    let table = perm_table(v.bits, p);
    let mut out = HdVec::zero(v.bits);
    let dst_words = out.words_mut();
    for (wi, &word) in v.words().iter().enumerate() {
        let mut w = word;
        while w != 0 {
            let b = w.trailing_zeros() as usize;
            let dst = table[wi * 64 + b] as usize;
            dst_words[dst >> 6] |= 1u64 << (dst & 63);
            w &= w - 1;
        }
    }
    out
}

/// The hardwired pseudo-random seed vector for dimension `dim`.
pub fn seed_vector(dim: usize) -> HdVec {
    let mut rng = Rng::new(0xB007_5EED ^ dim as u64);
    HdVec::from_words(dim, rng.bitvec(dim))
}

/// Rematerialise the IM hypervector for a `width`-bit input `value`:
/// D iterations, each selecting one of the four permutations from the
/// current input bit and the step parity (uses all four hardwired
/// permutations; one bit consumed per cycle as in the serialized silicon
/// datapath).
pub fn im_map(dim: usize, value: u32, width: u32) -> HdVec {
    let mut v = seed_vector(dim);
    for step in 0..width {
        let bit = (value >> step) & 1;
        let sel = (bit * 2 + (step & 1)) as usize;
        v = apply(&v, sel);
    }
    v
}

/// Datapath cycles for one IM mapping: D cycles for a D-bit input.
pub fn im_cycles(width: u32) -> u64 {
    width as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn permutations_are_bijections() {
        for p in 0..N_PERMS {
            let t = perm_table(512, p);
            let mut seen = vec![false; 512];
            for &d in t {
                assert!(!seen[d as usize]);
                seen[d as usize] = true;
            }
        }
    }

    #[test]
    fn permutation_preserves_popcount() {
        let s = seed_vector(1024);
        for p in 0..N_PERMS {
            assert_eq!(apply(&s, p).count_ones(), s.count_ones());
        }
    }

    #[test]
    fn seed_vector_is_dense_and_deterministic() {
        let s1 = seed_vector(2048);
        let s2 = seed_vector(2048);
        assert_eq!(s1, s2);
        let ones = s1.count_ones();
        assert!((900..1150).contains(&(ones * 2048 / 2048 / 2 * 2 / 2)) || ones > 900);
        assert!(ones > 900 && ones < 1150, "ones = {ones}");
    }

    #[test]
    fn im_vectors_are_quasi_orthogonal() {
        // Distinct values map to ~dim/2 Hamming distance.
        let dim = 2048;
        let vals = [0u32, 1, 2, 255, 256, 65535];
        for (i, &a) in vals.iter().enumerate() {
            for &b in &vals[i + 1..] {
                let d = im_map(dim, a, 16).hamming(&im_map(dim, b, 16));
                let frac = d as f64 / dim as f64;
                assert!(
                    (0.40..0.60).contains(&frac),
                    "im({a}) vs im({b}): {frac}"
                );
            }
        }
    }

    #[test]
    fn im_is_deterministic_rematerialization() {
        assert_eq!(im_map(512, 42, 16), im_map(512, 42, 16));
        assert_ne!(im_map(512, 42, 16), im_map(512, 43, 16));
    }

    #[test]
    fn im_cycle_cost_is_input_width() {
        assert_eq!(im_cycles(16), 16);
        assert_eq!(im_cycles(8), 8);
    }
}
