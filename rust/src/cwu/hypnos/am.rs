//! The 32-kbit standard-cell associative memory (§II-B).
//!
//! 16 rows of up to 2048 bits, latch-based with one integrated clock gate
//! per row as write enable. Doubles as scratchpad for intermediate
//! hypervectors and as the prototype store for the associative lookup:
//! rows are compared sequentially against the search vector, the Hamming
//! distance computed combinationally, and the minimum tracked. The lookup
//! result (index + distance) feeds the wake-up decision.

use super::bitvec::HdVec;

/// AM geometry: 16 rows × 2048 bits = 32 kbit.
pub const AM_ROWS: usize = 16;
pub const AM_ROW_BITS: usize = 2048;

/// Result of an associative lookup.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LookupResult {
    pub index: usize,
    pub distance: u32,
}

/// The associative memory.
#[derive(Debug, Clone)]
pub struct Am {
    dim: usize,
    rows: Vec<Option<HdVec>>,
    /// Rows participating in associative search (prototype rows); other
    /// occupied rows are scratchpad.
    search_mask: u16,
    pub lookups: u64,
    pub row_compares: u64,
}

impl Am {
    pub fn new(dim: usize) -> Self {
        assert!(dim <= AM_ROW_BITS);
        Self {
            dim,
            rows: vec![None; AM_ROWS],
            search_mask: 0,
            lookups: 0,
            row_compares: 0,
        }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn write(&mut self, row: usize, v: HdVec) {
        assert!(row < AM_ROWS, "AM has {AM_ROWS} rows");
        assert_eq!(v.bits, self.dim);
        self.rows[row] = Some(v);
    }

    pub fn read(&self, row: usize) -> Option<&HdVec> {
        self.rows.get(row).and_then(|r| r.as_ref())
    }

    pub fn clear(&mut self, row: usize) {
        self.rows[row] = None;
        self.search_mask &= !(1 << row);
    }

    /// Mark `row` as a prototype (included in associative search).
    pub fn mark_prototype(&mut self, row: usize, is_proto: bool) {
        assert!(row < AM_ROWS);
        if is_proto {
            assert!(self.rows[row].is_some(), "prototype row must be written");
            self.search_mask |= 1 << row;
        } else {
            self.search_mask &= !(1 << row);
        }
    }

    pub fn prototype_count(&self) -> usize {
        self.search_mask.count_ones() as usize
    }

    /// Sequential associative lookup: minimum-Hamming prototype row.
    /// Ties resolve to the lowest index (sequential scan order).
    pub fn lookup(&mut self, search: &HdVec) -> Option<LookupResult> {
        assert_eq!(search.bits, self.dim);
        self.lookups += 1;
        let mut best: Option<LookupResult> = None;
        for row in 0..AM_ROWS {
            if self.search_mask & (1 << row) == 0 {
                continue;
            }
            self.row_compares += 1;
            let d = self.rows[row].as_ref().unwrap().hamming(search);
            if best.map_or(true, |b| d < b.distance) {
                best = Some(LookupResult { index: row, distance: d });
            }
        }
        best
    }

    /// Cycles for one lookup: each prototype row streams through the
    /// 512-bit comparator in `dim/512` beats.
    pub fn lookup_cycles(&self) -> u64 {
        self.prototype_count() as u64 * (self.dim as u64).div_ceil(512)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cwu::hypnos::perm;

    #[test]
    fn capacity_is_32_kbit() {
        assert_eq!(AM_ROWS * AM_ROW_BITS, 32 * 1024);
    }

    #[test]
    fn lookup_finds_nearest_prototype() {
        let dim = 512;
        let mut am = Am::new(dim);
        let protos: Vec<_> = (0..4).map(|i| perm::im_map(dim, i, 8)).collect();
        for (i, p) in protos.iter().enumerate() {
            am.write(i, p.clone());
            am.mark_prototype(i, true);
        }
        // Search with a noisy copy of prototype 2.
        let mut q = protos[2].clone();
        for b in 0..40 {
            q.flip(b * 12);
        }
        let r = am.lookup(&q).unwrap();
        assert_eq!(r.index, 2);
        assert_eq!(r.distance, 40);
    }

    #[test]
    fn scratchpad_rows_excluded_from_search() {
        let dim = 512;
        let mut am = Am::new(dim);
        let a = perm::im_map(dim, 1, 8);
        let b = perm::im_map(dim, 2, 8);
        am.write(0, a.clone());
        am.mark_prototype(0, true);
        am.write(5, b.clone()); // scratch, not marked
        let r = am.lookup(&b).unwrap();
        assert_eq!(r.index, 0); // found the only prototype, not row 5
        assert!(r.distance > 0);
    }

    #[test]
    fn lookup_cycles_scale_with_rows_and_dim() {
        let mut am = Am::new(2048);
        for i in 0..3 {
            am.write(i, HdVec::zero(2048));
            am.mark_prototype(i, true);
        }
        assert_eq!(am.lookup_cycles(), 3 * 4);
    }

    #[test]
    fn empty_am_lookup_is_none() {
        let mut am = Am::new(512);
        assert!(am.lookup(&HdVec::zero(512)).is_none());
    }
}
