//! Hypnos: the programmable HDC accelerator at the heart of the CWU
//! (§II-B, Fig. 2).
//!
//! Composition: the Vector Encoder (IM rematerialization through four
//! hardwired permutations, CIM similarity manipulator, 512 Encoder Units
//! with saturating 8-bit bundling counters), the 16-row associative
//! memory, and the 64×26-bit microcode sequencer. The whole engine runs
//! autonomously on preprocessed sensor frames and raises a wake-up
//! interrupt when an associative lookup matches the configured class
//! within the configured Hamming threshold.

pub mod am;
pub mod bitvec;
pub mod encoder;
pub mod microcode;
pub mod perm;

pub use am::{Am, LookupResult, AM_ROWS};
pub use bitvec::{HdVec, DATAPATH_BITS, HD_DIMS};
pub use encoder::EuArray;
pub use microcode::{MicroOp, MicroProgram};

/// A wake-up event raised by the Search op.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WakeEvent {
    pub class_index: usize,
    pub distance: u32,
}

/// Activity counters feeding the CWU power model (Table I splits dynamic
/// datapath power from pad power; datapath activity is what we count).
#[derive(Debug, Clone, Copy, Default)]
pub struct HypnosStats {
    /// Active datapath cycles (the engine clock-gates when idle).
    pub datapath_cycles: u64,
    pub frames: u64,
    pub searches: u64,
    pub wakeups: u64,
}

/// The engine.
pub struct Hypnos {
    pub dim: usize,
    /// Input sample width per channel (D bits → D-cycle IM mapping).
    pub input_width: u32,
    /// CIM full-scale value.
    pub cim_max: u32,
    program: MicroProgram,
    pc: usize,
    repeat: Option<(u16, usize, usize)>, // (remaining, body_start, body_len)
    res: HdVec,
    tmp: HdVec,
    eu: EuArray,
    pub am: Am,
    pub stats: HypnosStats,
}

impl Hypnos {
    pub fn new(dim: usize, input_width: u32, cim_max: u32) -> Self {
        Self {
            dim,
            input_width,
            cim_max,
            program: MicroProgram::new(vec![MicroOp::NextFrame]),
            pc: 0,
            repeat: None,
            res: HdVec::zero(dim),
            tmp: HdVec::zero(dim),
            eu: EuArray::new(dim),
            am: Am::new(dim),
            stats: HypnosStats::default(),
        }
    }

    /// Load a microcode program and reset the sequencer.
    pub fn load_program(&mut self, program: MicroProgram) {
        self.program = program;
        self.pc = 0;
        self.repeat = None;
        self.res = HdVec::zero(self.dim);
        self.tmp = HdVec::zero(self.dim);
        self.eu.reset();
    }

    pub fn result(&self) -> &HdVec {
        &self.res
    }

    /// Software-visible encoder primitives (shared with the host-side
    /// training stack so trained prototypes are bit-compatible).
    pub fn encode_im(&self, value: u32) -> HdVec {
        perm::im_map(self.dim, value, self.input_width)
    }

    pub fn encode_cim(&self, value: u32) -> HdVec {
        encoder::cim_map(self.dim, value, self.cim_max)
    }

    fn chunk_cycles(&self) -> u64 {
        (self.dim as u64).div_ceil(DATAPATH_BITS as u64)
    }

    /// Feed one preprocessed sample frame (one value per channel).
    ///
    /// `NextFrame` *acquires* a frame: the first one hit in this call
    /// consumes `frame` and execution continues; hitting a second
    /// `NextFrame` blocks (the sequencer parks on it until the next frame
    /// arrives). Encode ops therefore follow their `NextFrame` in program
    /// order, and window-final ops (threshold, search) run within the call
    /// that delivered the window's last frame. The sequencer wraps to slot
    /// 0 at the end of the store ("fetches these instructions in an
    /// infinite loop"). Returns a wake event if a Search matched.
    pub fn on_frame(&mut self, frame: &[u32]) -> Option<WakeEvent> {
        self.stats.frames += 1;
        let mut wake = None;
        let mut consumed = false;
        let mut guard = 0u32;
        loop {
            guard += 1;
            assert!(guard < 100_000, "microcode made no frame progress");
            let op = self.program.ops[self.pc];
            if matches!(op, MicroOp::NextFrame) && consumed {
                // Park on this NextFrame awaiting the next frame.
                return wake;
            }
            let mut next_pc = self.pc + 1;
            match op {
                MicroOp::ImMap { chan } => {
                    let v = frame.get(chan as usize).copied().unwrap_or(0);
                    self.tmp = self.encode_im(v);
                    self.stats.datapath_cycles += perm::im_cycles(self.input_width);
                }
                MicroOp::ImLabel { chan } => {
                    self.tmp = self.encode_im(chan as u32);
                    self.stats.datapath_cycles += perm::im_cycles(self.input_width);
                }
                MicroOp::CimMap { chan } => {
                    let v = frame.get(chan as usize).copied().unwrap_or(0);
                    self.tmp = self.encode_cim(v);
                    self.stats.datapath_cycles += self.chunk_cycles();
                }
                MicroOp::MovTmp => {
                    self.res = self.tmp.clone();
                    self.stats.datapath_cycles += self.chunk_cycles();
                }
                MicroOp::BindTmp => {
                    self.res = self.res.xor(&self.tmp);
                    self.stats.datapath_cycles += self.chunk_cycles();
                }
                MicroOp::Permute { n } => {
                    self.res = self.res.rotate(n as usize);
                    self.stats.datapath_cycles += self.chunk_cycles();
                }
                MicroOp::BundleAcc => {
                    self.eu.accumulate(&self.res);
                    self.stats.datapath_cycles += self.chunk_cycles();
                }
                MicroOp::BundleReset => {
                    self.eu.reset();
                    self.stats.datapath_cycles += 1;
                }
                MicroOp::BundleThr => {
                    self.res = self.eu.threshold();
                    self.stats.datapath_cycles += self.chunk_cycles();
                }
                MicroOp::BindAm { row } => {
                    if let Some(v) = self.am.read(row as usize) {
                        self.res = self.res.xor(&v.clone());
                    }
                    self.stats.datapath_cycles += self.chunk_cycles();
                }
                MicroOp::LoadAm { row } => {
                    if let Some(v) = self.am.read(row as usize) {
                        self.res = v.clone();
                    }
                    self.stats.datapath_cycles += self.chunk_cycles();
                }
                MicroOp::StoreAm { row } => {
                    self.am.write(row as usize, self.res.clone());
                    self.stats.datapath_cycles += self.chunk_cycles();
                }
                MicroOp::NextFrame => {
                    consumed = true;
                    self.stats.datapath_cycles += 1;
                }
                MicroOp::Repeat { count, len } => {
                    if count > 0 && len > 0 {
                        self.repeat = Some((count, self.pc + 1, len as usize));
                    } else {
                        next_pc = self.pc + 1 + len as usize;
                    }
                    self.stats.datapath_cycles += 1;
                }
                MicroOp::Search { threshold, target } => {
                    self.stats.searches += 1;
                    self.stats.datapath_cycles += self.am.lookup_cycles();
                    if let Some(r) = self.am.lookup(&self.res) {
                        if r.index == target as usize && r.distance <= threshold as u32 {
                            self.stats.wakeups += 1;
                            wake = Some(WakeEvent {
                                class_index: r.index,
                                distance: r.distance,
                            });
                        }
                    }
                }
            }

            // Hardware repeat channel.
            if let Some((remaining, start, len)) = self.repeat {
                if next_pc == start + len {
                    if remaining > 1 {
                        self.repeat = Some((remaining - 1, start, len));
                        next_pc = start;
                    } else {
                        self.repeat = None;
                    }
                }
            }
            self.pc = if next_pc >= self.program.len() { 0 } else { next_pc };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Per-frame: acquire, CIM-encode, bundle over a window, then search.
    fn window_program(window: u16) -> MicroProgram {
        MicroProgram::new(vec![
            MicroOp::BundleReset,
            MicroOp::Repeat { count: window, len: 4 },
            MicroOp::NextFrame,
            MicroOp::CimMap { chan: 0 },
            MicroOp::MovTmp,
            MicroOp::BundleAcc,
            MicroOp::BundleThr,
            MicroOp::Search { threshold: 200, target: 0 },
        ])
    }

    #[test]
    fn window_classify_and_wake() {
        let mut h = Hypnos::new(512, 16, 4095);
        // Prototype 0 = bundle of CIM(100); prototype 1 = CIM(4000).
        let p0 = h.encode_cim(100);
        let p1 = h.encode_cim(4000);
        h.am.write(0, p0);
        h.am.write(1, p1);
        h.am.mark_prototype(0, true);
        h.am.mark_prototype(1, true);
        h.load_program(window_program(4));

        // Stream 4 frames near value 100: expect a wake on the 4th.
        let mut wake = None;
        for v in [100u32, 105, 95, 102] {
            wake = h.on_frame(&[v]);
        }
        let w = wake.expect("expected wake-up");
        assert_eq!(w.class_index, 0);

        // Stream 4 frames near 4000: no wake (class 1 wins the lookup).
        let mut wake = None;
        for v in [4000u32, 3990, 4010, 4005] {
            wake = h.on_frame(&[v]);
        }
        assert!(wake.is_none());
        assert_eq!(h.stats.searches, 2);
        assert_eq!(h.stats.wakeups, 1);
    }

    #[test]
    fn sequencer_wraps_infinitely() {
        let mut h = Hypnos::new(512, 16, 4095);
        h.load_program(MicroProgram::new(vec![MicroOp::NextFrame]));
        for _ in 0..10 {
            assert!(h.on_frame(&[0]).is_none());
        }
        assert_eq!(h.stats.frames, 10);
    }

    #[test]
    fn datapath_cycles_fit_the_32khz_budget() {
        // §II-B Table I: 3 channels × 150 SPS at 32 kHz. Budget per frame
        // = 32000 / 150 ≈ 213 cycles for a 3-channel frame program.
        let mut h = Hypnos::new(512, 16, 4095);
        h.am.write(0, HdVec::zero(512));
        h.am.mark_prototype(0, true);
        h.load_program(MicroProgram::new(vec![
            MicroOp::BundleReset,
            MicroOp::Repeat { count: 16, len: 8 },
            MicroOp::NextFrame,
            MicroOp::CimMap { chan: 0 },
            MicroOp::MovTmp,
            MicroOp::CimMap { chan: 1 },
            MicroOp::BindTmp,
            MicroOp::CimMap { chan: 2 },
            MicroOp::BindTmp,
            MicroOp::BundleAcc,
            MicroOp::BundleThr,
            MicroOp::Search { threshold: 100, target: 0 },
        ]));
        let before = h.stats.datapath_cycles;
        h.on_frame(&[1, 2, 3]);
        let per_frame = h.stats.datapath_cycles - before;
        assert!(per_frame < 213, "cycles/frame = {per_frame}");
    }
}
