//! The 64×26-bit microcode store and lightweight sequencer (§II-B).
//!
//! "The CWU contains another 64×26-bit SCM to encode the HDC algorithm in
//! a sequence of compact micro-code instructions. The lightweight
//! controller fetches these instructions in an infinite loop and
//! reconfigures AM and Vector Encoder accordingly in each cycle."
//!
//! The micro-ISA below is our register-transfer-level reading of that
//! description: one architectural result register (RES), a temporary from
//! the mapper (TMP), the EU counter array, the AM, and a single hardware
//! repeat counter. Every op packs into 26 bits (opcode ≤ 5 bits, operands
//! ≤ 21), asserted by `encoding_fits_26_bits`.

/// One microcode instruction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MicroOp {
    /// TMP = IM(sample[chan]) — item-memory rematerialization of the
    /// channel's *value* (discrete symbols, e.g. characters).
    ImMap { chan: u8 },
    /// TMP = IM(chan) — item-memory mapping of the channel *label*
    /// ("IM mapping is used to encode channel labels", §II-B).
    ImLabel { chan: u8 },
    /// TMP = CIM(sample[chan]) — continuous (similarity-preserving) map.
    CimMap { chan: u8 },
    /// RES = TMP.
    MovTmp,
    /// RES ^= TMP (bind).
    BindTmp,
    /// RES = ρ(RES, n) — cyclic rotate (sequence encoding).
    Permute { n: u8 },
    /// EU counters accumulate RES (bundle).
    BundleAcc,
    /// Clear EU counters.
    BundleReset,
    /// RES = majority(EU counters).
    BundleThr,
    /// RES ^= AM[row].
    BindAm { row: u8 },
    /// RES = AM[row].
    LoadAm { row: u8 },
    /// AM[row] = RES (scratchpad write).
    StoreAm { row: u8 },
    /// Block until the next preprocessed sample frame.
    NextFrame,
    /// Repeat the next `len` instructions `count` times.
    Repeat { count: u16, len: u8 },
    /// Associative lookup of RES; wake-up when the best row == `target`
    /// and Hamming distance ≤ `threshold`.
    Search { threshold: u16, target: u8 },
}

/// Microcode store capacity.
pub const UCODE_DEPTH: usize = 64;

/// Bit width of one instruction slot.
pub const UCODE_BITS: usize = 26;

impl MicroOp {
    /// Pack into the 26-bit SCM encoding (5-bit opcode + operands).
    /// Round-trips with [`MicroOp::decode`]; used to prove the ISA fits
    /// the silicon's instruction width.
    pub fn encode(self) -> u32 {
        match self {
            MicroOp::ImMap { chan } => (chan as u32) << 5,
            MicroOp::CimMap { chan } => 1 | ((chan as u32) << 5),
            MicroOp::MovTmp => 2,
            MicroOp::BindTmp => 3,
            MicroOp::Permute { n } => 4 | ((n as u32) << 5),
            MicroOp::BundleAcc => 5,
            MicroOp::BundleReset => 6,
            MicroOp::BundleThr => 7,
            MicroOp::BindAm { row } => 8 | ((row as u32) << 5),
            MicroOp::LoadAm { row } => 9 | ((row as u32) << 5),
            MicroOp::StoreAm { row } => 10 | ((row as u32) << 5),
            MicroOp::NextFrame => 11,
            MicroOp::Repeat { count, len } => {
                12 | ((count as u32 & 0xFFF) << 5) | ((len as u32 & 0x3F) << 17)
            }
            MicroOp::Search { threshold, target } => {
                13 | ((threshold as u32 & 0xFFF) << 5) | ((target as u32 & 0xF) << 17)
            }
            MicroOp::ImLabel { chan } => 14 | ((chan as u32) << 5),
        }
    }

    pub fn decode(w: u32) -> Option<MicroOp> {
        let operand = w >> 5;
        Some(match w & 0x1F {
            0 => MicroOp::ImMap { chan: operand as u8 },
            1 => MicroOp::CimMap { chan: operand as u8 },
            2 => MicroOp::MovTmp,
            3 => MicroOp::BindTmp,
            4 => MicroOp::Permute { n: operand as u8 },
            5 => MicroOp::BundleAcc,
            6 => MicroOp::BundleReset,
            7 => MicroOp::BundleThr,
            8 => MicroOp::BindAm { row: operand as u8 },
            9 => MicroOp::LoadAm { row: operand as u8 },
            10 => MicroOp::StoreAm { row: operand as u8 },
            11 => MicroOp::NextFrame,
            12 => MicroOp::Repeat {
                count: (operand & 0xFFF) as u16,
                len: ((w >> 17) & 0x3F) as u8,
            },
            13 => MicroOp::Search {
                threshold: (operand & 0xFFF) as u16,
                target: ((w >> 17) & 0xF) as u8,
            },
            14 => MicroOp::ImLabel { chan: operand as u8 },
            _ => return None,
        })
    }
}

/// A validated microcode program (≤ 64 slots).
#[derive(Debug, Clone, Default)]
pub struct MicroProgram {
    pub ops: Vec<MicroOp>,
}

impl MicroProgram {
    pub fn new(ops: Vec<MicroOp>) -> Self {
        assert!(ops.len() <= UCODE_DEPTH, "microcode exceeds 64 slots");
        assert!(!ops.is_empty(), "empty microcode");
        Self { ops }
    }

    pub fn len(&self) -> usize {
        self.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encoding_fits_26_bits_and_roundtrips() {
        let ops = [
            MicroOp::ImMap { chan: 7 },
            MicroOp::ImLabel { chan: 7 },
            MicroOp::CimMap { chan: 3 },
            MicroOp::MovTmp,
            MicroOp::BindTmp,
            MicroOp::Permute { n: 31 },
            MicroOp::BundleAcc,
            MicroOp::BundleReset,
            MicroOp::BundleThr,
            MicroOp::BindAm { row: 15 },
            MicroOp::LoadAm { row: 15 },
            MicroOp::StoreAm { row: 15 },
            MicroOp::NextFrame,
            MicroOp::Repeat { count: 4095, len: 63 },
            MicroOp::Search { threshold: 4095, target: 15 },
        ];
        for op in ops {
            let w = op.encode();
            assert!(w < (1 << UCODE_BITS), "{op:?} needs more than 26 bits");
            assert_eq!(MicroOp::decode(w), Some(op), "{op:?} roundtrip");
        }
    }

    #[test]
    fn program_capacity_enforced() {
        let p = MicroProgram::new(vec![MicroOp::NextFrame; 64]);
        assert_eq!(p.len(), 64);
    }

    #[test]
    #[should_panic]
    fn oversized_program_rejected() {
        MicroProgram::new(vec![MicroOp::NextFrame; 65]);
    }
}
