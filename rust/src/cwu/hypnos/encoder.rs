//! The Vector Encoder: 512 Encoder Units + the similarity manipulator.
//!
//! Each Encoder Unit (EU) owns one bit lane: XOR/AND/NOT logic plus a
//! saturating bidirectional 8-bit counter for *bundling* (majority
//! accumulation). Hypnos instantiates 512 EUs — one per datapath bit; for
//! 1024/1536/2048-bit vectors the engine iterates 512-bit chunks, so the
//! counters are modelled per HD bit with the cycle cost scaled by
//! `bits / 512`.
//!
//! The *similarity manipulator* implements continuous item memory (CIM):
//! flipping a value-proportional number of bits of a base hypervector so
//! that nearby input values land at nearby Hamming distances (§II-B).

use super::bitvec::HdVec;
use super::perm;

/// Saturating bidirectional counter range (8-bit signed in the EUs).
pub const COUNTER_MAX: i16 = 127;
pub const COUNTER_MIN: i16 = -128;

/// The EU array state: one bundling counter per HD bit.
#[derive(Debug, Clone)]
pub struct EuArray {
    pub bits: usize,
    counters: Vec<i16>,
}

impl EuArray {
    pub fn new(bits: usize) -> Self {
        Self { bits, counters: vec![0; bits] }
    }

    pub fn reset(&mut self) {
        self.counters.fill(0);
    }

    /// Bundle-accumulate: +1 for a one-bit, −1 for a zero-bit, saturating.
    pub fn accumulate(&mut self, v: &HdVec) {
        assert_eq!(v.bits, self.bits);
        for i in 0..self.bits {
            let c = &mut self.counters[i];
            if v.get(i) {
                *c = (*c + 1).min(COUNTER_MAX);
            } else {
                *c = (*c - 1).max(COUNTER_MIN);
            }
        }
    }

    /// Majority threshold: counter > 0 → 1, < 0 → 0, tie broken by lane
    /// parity (a fixed hardware tie-break keeps bundles unbiased).
    pub fn threshold(&self) -> HdVec {
        let mut out = HdVec::zero(self.bits);
        for i in 0..self.bits {
            let bit = match self.counters[i].cmp(&0) {
                std::cmp::Ordering::Greater => true,
                std::cmp::Ordering::Less => false,
                std::cmp::Ordering::Equal => i % 2 == 0,
            };
            out.set(i, bit);
        }
        out
    }

    pub fn counter(&self, i: usize) -> i16 {
        self.counters[i]
    }
}

/// CIM base vector for a channel: a fixed quasi-orthogonal anchor.
pub fn cim_base(dim: usize) -> HdVec {
    perm::apply(&perm::seed_vector(dim), 3)
}

/// Continuous item-memory mapping: flip `round(value/max · dim/2)` bits of
/// the base vector in a hardwired order. Values close in input space stay
/// close in Hamming space; the extremes are ~dim/2 apart (quasi-
/// orthogonal), the standard CIM construction [23].
pub fn cim_map(dim: usize, value: u32, max_value: u32) -> HdVec {
    let mut v = cim_base(dim);
    let flips = ((value.min(max_value) as u64 * (dim as u64 / 2)) / max_value.max(1) as u64)
        as usize;
    // Hardwired flip order: the identity scan over lane indices scrambled
    // by permutation 1 (fixed in silicon; any fixed order works).
    let order = flip_order(dim);
    for &bit in order.iter().take(flips) {
        v.flip(bit);
    }
    v
}

fn flip_order(dim: usize) -> Vec<usize> {
    // Reuse hardwired permutation 1 as the flip schedule.
    let mut probe = HdVec::zero(dim);
    probe.set(0, true);
    // Build order by permuting an index vector once: table lookup through
    // the perm module's public API (apply on unit vectors would be O(n²));
    // instead derive a deterministic LCG-style order.
    let mut order: Vec<usize> = (0..dim).collect();
    let mut state = 0x9E37u64;
    for i in (1..dim).rev() {
        state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        let j = (state >> 33) as usize % (i + 1);
        order.swap(i, j);
    }
    order
}

/// Cycle cost of one EU-array pass (bundle/threshold/bind) for `bits`-bit
/// vectors on the 512-bit datapath.
pub fn eu_pass_cycles(bits: usize) -> u64 {
    (bits as u64).div_ceil(512).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bundling_majority() {
        let dim = 512;
        let mut eu = EuArray::new(dim);
        let a = perm::im_map(dim, 1, 8);
        let b = perm::im_map(dim, 2, 8);
        // Bundle a twice, b once: result should be closer to a.
        eu.accumulate(&a);
        eu.accumulate(&a);
        eu.accumulate(&b);
        let bundle = eu.threshold();
        assert!(bundle.hamming(&a) < bundle.hamming(&b));
    }

    #[test]
    fn counters_saturate() {
        let dim = 512;
        let mut eu = EuArray::new(dim);
        let ones = HdVec::zero(dim).not();
        for _ in 0..300 {
            eu.accumulate(&ones);
        }
        assert_eq!(eu.counter(0), COUNTER_MAX);
        let zeros = HdVec::zero(dim);
        for _ in 0..300 {
            eu.accumulate(&zeros);
        }
        assert_eq!(eu.counter(0), COUNTER_MIN);
    }

    #[test]
    fn bundle_of_one_is_identity() {
        let dim = 1024;
        let mut eu = EuArray::new(dim);
        let a = perm::im_map(dim, 7, 16);
        eu.accumulate(&a);
        assert_eq!(eu.threshold(), a);
    }

    #[test]
    fn cim_preserves_locality() {
        let dim = 2048;
        let max = 4095;
        let near = cim_map(dim, 100, max).hamming(&cim_map(dim, 110, max));
        let far = cim_map(dim, 100, max).hamming(&cim_map(dim, 4000, max));
        assert!(near < 40, "near = {near}");
        assert!(far > 700, "far = {far}");
        // Monotone-ish: mid value sits between.
        let mid = cim_map(dim, 100, max).hamming(&cim_map(dim, 2000, max));
        assert!(near < mid && mid < far, "{near} {mid} {far}");
    }

    #[test]
    fn cim_extremes_quasi_orthogonal() {
        let dim = 2048;
        let d = cim_map(dim, 0, 4095).hamming(&cim_map(dim, 4095, 4095));
        let frac = d as f64 / dim as f64;
        assert!((0.42..0.58).contains(&frac), "frac = {frac}");
    }

    #[test]
    fn pass_cycles_scale() {
        assert_eq!(eu_pass_cycles(512), 1);
        assert_eq!(eu_pass_cycles(1536), 3);
    }
}
