//! The CWU's autonomous SPI master (§II-B, Fig. 2).
//!
//! A dedicated SPI master peripheral with an integrated micro-instruction
//! memory executes a configured transaction pattern in an endless loop:
//! all four CPOL/CPHA modes, up to four chip selects, programmable wait
//! cycles, and arbitrary read/write transactions against multiple
//! external devices — no core involvement after configuration.
//!
//! External sensors are modelled as [`SpiSensor`] waveform generators
//! attached per chip select (the substitution for real EMG/IMU parts,
//! DESIGN.md §5); pad-toggle counts feed the Table I pad-power term.

/// SPI clock phase/polarity mode (all four supported).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpiMode {
    Mode0,
    Mode1,
    Mode2,
    Mode3,
}

/// One micro-instruction of the SPI sequencer.
#[derive(Debug, Clone, Copy)]
pub enum SpiOp {
    /// Assert CS `cs` and clock `bits` in from the device into channel
    /// `chan` of the preprocessor.
    Read { cs: u8, bits: u8, chan: u8 },
    /// Clock `bits` of `data` out to device `cs` (sensor configuration).
    Write { cs: u8, bits: u8, data: u32 },
    /// Idle for `n` SPI clock cycles (rate pacing).
    Wait { n: u16 },
}

/// A sensor behind a chip select: produces one sample per read.
pub trait SpiSensor {
    fn sample(&mut self) -> u32;
    /// Configuration writes land here (ignored by simple sensors).
    fn configure(&mut self, _data: u32) {}
}

/// Pad-activity statistics (dynamic pad power is proportional to
/// transitions; Table I shows pads dominate CWU dynamic power).
#[derive(Debug, Clone, Copy, Default)]
pub struct SpiStats {
    pub bits_read: u64,
    pub bits_written: u64,
    pub wait_cycles: u64,
    pub transactions: u64,
    /// SPI clock cycles consumed (bits + waits).
    pub clock_cycles: u64,
}

/// The autonomous SPI master.
pub struct SpiMaster {
    pub mode: SpiMode,
    program: Vec<SpiOp>,
    pc: usize,
    sensors: Vec<Box<dyn SpiSensor>>,
    pub stats: SpiStats,
}

impl SpiMaster {
    pub fn new(mode: SpiMode, program: Vec<SpiOp>, sensors: Vec<Box<dyn SpiSensor>>) -> Self {
        assert!(!program.is_empty(), "empty SPI program");
        assert!(sensors.len() <= 4, "up to four chip selects");
        Self { mode, program, pc: 0, sensors, stats: SpiStats::default() }
    }

    /// Execute micro-instructions until one full pass over the program
    /// completes (the hardware loops endlessly; one pass = one sampling
    /// round). Returns the raw words read, as (channel, value) pairs.
    pub fn run_round(&mut self) -> Vec<(u8, u32)> {
        let mut out = Vec::new();
        let len = self.program.len();
        for _ in 0..len {
            let op = self.program[self.pc];
            self.pc = (self.pc + 1) % len;
            match op {
                SpiOp::Read { cs, bits, chan } => {
                    let v = self
                        .sensors
                        .get_mut(cs as usize)
                        .map(|s| s.sample())
                        .unwrap_or(0);
                    let mask = if bits >= 32 { u32::MAX } else { (1u32 << bits) - 1 };
                    out.push((chan, v & mask));
                    self.stats.bits_read += bits as u64;
                    self.stats.clock_cycles += bits as u64 + 2; // CS setup/hold
                    self.stats.transactions += 1;
                }
                SpiOp::Write { cs, bits, data } => {
                    if let Some(s) = self.sensors.get_mut(cs as usize) {
                        s.configure(data);
                    }
                    self.stats.bits_written += bits as u64;
                    self.stats.clock_cycles += bits as u64 + 2;
                    self.stats.transactions += 1;
                }
                SpiOp::Wait { n } => {
                    self.stats.wait_cycles += n as u64;
                    self.stats.clock_cycles += n as u64;
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Counter(u32);
    impl SpiSensor for Counter {
        fn sample(&mut self) -> u32 {
            self.0 += 1;
            self.0
        }
    }

    #[test]
    fn round_reads_all_configured_channels() {
        let prog = vec![
            SpiOp::Read { cs: 0, bits: 16, chan: 0 },
            SpiOp::Read { cs: 1, bits: 16, chan: 1 },
            SpiOp::Wait { n: 8 },
        ];
        let mut spi = SpiMaster::new(
            SpiMode::Mode0,
            prog,
            vec![Box::new(Counter(0)), Box::new(Counter(100))],
        );
        let r1 = spi.run_round();
        assert_eq!(r1, vec![(0, 1), (1, 101)]);
        let r2 = spi.run_round();
        assert_eq!(r2, vec![(0, 2), (1, 102)]);
        assert_eq!(spi.stats.bits_read, 64);
        assert_eq!(spi.stats.wait_cycles, 16);
    }

    #[test]
    fn read_masks_to_transfer_width() {
        struct Wide;
        impl SpiSensor for Wide {
            fn sample(&mut self) -> u32 {
                0xDEAD_BEEF
            }
        }
        let mut spi = SpiMaster::new(
            SpiMode::Mode3,
            vec![SpiOp::Read { cs: 0, bits: 12, chan: 0 }],
            vec![Box::new(Wide)],
        );
        assert_eq!(spi.run_round(), vec![(0, 0xEEF)]);
    }

    #[test]
    fn writes_reach_the_sensor() {
        struct Cfg(u32);
        impl SpiSensor for Cfg {
            fn sample(&mut self) -> u32 {
                self.0
            }
            fn configure(&mut self, d: u32) {
                self.0 = d;
            }
        }
        let mut spi = SpiMaster::new(
            SpiMode::Mode1,
            vec![
                SpiOp::Write { cs: 0, bits: 8, data: 0x5A },
                SpiOp::Read { cs: 0, bits: 8, chan: 0 },
            ],
            vec![Box::new(Cfg(0))],
        );
        assert_eq!(spi.run_round(), vec![(0, 0x5A)]);
    }
}
