//! The Cognitive Wake-Up unit (§II-B, Fig. 2): SPI master → preprocessor
//! → Hypnos, operating fully autonomously in its own 0.6 V UHVT power
//! domain at tens of kHz, and raising the PMU wake-up interrupt on a
//! positive classification.

pub mod hypnos;
pub mod preproc;
pub mod spi;

pub use hypnos::{Hypnos, MicroOp, MicroProgram, WakeEvent};
pub use preproc::{ChannelConfig, Preprocessor};
pub use spi::{SpiMaster, SpiMode, SpiOp, SpiSensor};

/// Area of the CWU macro (Table II / IV): 0.147 mm².
pub const CWU_AREA_MM2: f64 = 0.147;

/// The CWU clock of the cognitive sleep mode (Table I's 32 kHz
/// configuration — the one behind the 1.7 µW §III figure). Shared by
/// [`crate::power::PowerMode::CognitiveSleep`] and the lifecycle
/// engine's classification-latency model so the two can never drift.
pub const SLEEP_CLK_HZ: f64 = 32_000.0;

/// The assembled always-on pipeline.
pub struct Cwu {
    pub spi: Option<SpiMaster>,
    pub preproc: Preprocessor,
    pub hypnos: Hypnos,
    /// CWU clock in Hz (32 kHz or 200 kHz in Table I).
    pub f_clk: f64,
    /// Wake events raised so far.
    pub wake_count: u64,
}

impl Cwu {
    /// A default CWU: 3×16-bit channels, 2048-bit vectors (the language /
    /// EMG configuration of the paper's measurement).
    pub fn new() -> Self {
        Self {
            spi: None,
            preproc: Preprocessor::new(&[ChannelConfig::default(); 3]),
            hypnos: Hypnos::new(2048, 16, 65535),
            f_clk: 32_000.0,
            wake_count: 0,
        }
    }

    pub fn with_config(
        spi: Option<SpiMaster>,
        channel_cfgs: &[ChannelConfig],
        hypnos: Hypnos,
        f_clk: f64,
    ) -> Self {
        assert!(channel_cfgs.len() <= 8, "preprocessor supports 8 channels");
        Self {
            spi,
            preproc: Preprocessor::new(channel_cfgs),
            hypnos,
            f_clk,
            wake_count: 0,
        }
    }

    /// Run one sampling round: SPI acquires one raw word per channel, the
    /// preprocessor conditions it, and Hypnos consumes the frame when one
    /// is emitted. Returns a wake event on positive classification.
    pub fn step(&mut self) -> Option<WakeEvent> {
        let spi = self.spi.as_mut().expect("no SPI program configured");
        let reads = spi.run_round();
        let mut raw = vec![0u32; self.preproc.channels.len()];
        for (chan, v) in reads {
            if (chan as usize) < raw.len() {
                raw[chan as usize] = v;
            }
        }
        let frame = self.preproc.push_frame(&raw)?;
        let wake = self.hypnos.on_frame(&frame);
        if wake.is_some() {
            self.wake_count += 1;
        }
        wake
    }

    /// Feed a frame directly (bypassing SPI; used when the host streams a
    /// recorded dataset through the preprocessor).
    pub fn step_with_raw(&mut self, raw: &[u32]) -> Option<WakeEvent> {
        let frame = self.preproc.push_frame(raw)?;
        let wake = self.hypnos.on_frame(&frame);
        if wake.is_some() {
            self.wake_count += 1;
        }
        wake
    }

    /// Duty factor of the Hypnos datapath at the configured sample rate:
    /// active datapath cycles per second over f_clk. Feeds the Table I
    /// dynamic-power scaling.
    pub fn datapath_duty(&self, frames_per_second: f64) -> f64 {
        if self.hypnos.stats.frames == 0 {
            return 0.0;
        }
        let cycles_per_frame =
            self.hypnos.stats.datapath_cycles as f64 / self.hypnos.stats.frames as f64;
        (cycles_per_frame * frames_per_second / self.f_clk).min(1.0)
    }

    /// Maximum sustainable sample rate per channel at `f_clk` (Table I:
    /// 150 SPS/channel @ 32 kHz, 1 kSPS @ 200 kHz).
    pub fn max_sample_rate(&self) -> f64 {
        if self.hypnos.stats.frames == 0 {
            // Analytic bound for the paper's 3-channel 16-bit program:
            // ~70 datapath cycles/frame + SPI acquisition.
            return self.f_clk / 213.0;
        }
        let cycles_per_frame =
            self.hypnos.stats.datapath_cycles as f64 / self.hypnos.stats.frames as f64;
        self.f_clk / cycles_per_frame
    }
}

impl Default for Cwu {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A synthetic slowly-varying sensor.
    struct Sine {
        t: f64,
        freq: f64,
        amp: f64,
    }

    impl SpiSensor for Sine {
        fn sample(&mut self) -> u32 {
            self.t += 1.0;
            let v = (self.t * self.freq).sin() * self.amp + 2048.0;
            v as u32
        }
    }

    #[test]
    fn end_to_end_spi_preproc_hypnos() {
        let spi = SpiMaster::new(
            SpiMode::Mode0,
            vec![
                SpiOp::Read { cs: 0, bits: 16, chan: 0 },
                SpiOp::Wait { n: 4 },
            ],
            vec![Box::new(Sine { t: 0.0, freq: 0.05, amp: 500.0 })],
        );
        let mut hyp = Hypnos::new(512, 16, 4095);
        // One prototype: bundle of CIM around 2048 (the sine's mean).
        let p = hyp.encode_cim(2048);
        hyp.am.write(0, p);
        hyp.am.mark_prototype(0, true);
        hyp.load_program(MicroProgram::new(vec![
            MicroOp::NextFrame,
            MicroOp::CimMap { chan: 0 },
            MicroOp::MovTmp,
            MicroOp::Search { threshold: 120, target: 0 },
        ]));
        let mut cwu = Cwu::with_config(
            Some(spi),
            &[ChannelConfig { lowpass_k: Some(2), ..Default::default() }],
            hyp,
            32_000.0,
        );
        // Smoothed sine spends time near its mean: expect ≥1 wake.
        let mut wakes = 0;
        for _ in 0..200 {
            if cwu.step().is_some() {
                wakes += 1;
            }
        }
        assert!(wakes > 0, "no wake-ups fired");
        assert_eq!(cwu.wake_count, wakes);
    }

    #[test]
    fn duty_factor_is_small_at_150sps() {
        let mut hyp = Hypnos::new(512, 16, 4095);
        hyp.am.write(0, hyp.encode_cim(0));
        hyp.am.mark_prototype(0, true);
        hyp.load_program(MicroProgram::new(vec![
            MicroOp::NextFrame,
            MicroOp::CimMap { chan: 0 },
            MicroOp::MovTmp,
            MicroOp::BundleAcc,
        ]));
        let mut cwu = Cwu::with_config(None, &[ChannelConfig::default()], hyp, 32_000.0);
        for i in 0..100 {
            cwu.step_with_raw(&[i]);
        }
        let duty = cwu.datapath_duty(150.0);
        assert!(duty > 0.0 && duty < 0.2, "duty = {duty}");
    }
}
