//! Canonical byte encoding of the DNN pipeline types (the network-memo
//! analogue of [`crate::isa::encode`]).
//!
//! Three jobs, all in service of making [`NetworkReport`]s persistable
//! across processes and toolchains (`sweep/persist.rs` stores them beside
//! the kernel `SimResult`s):
//!
//! 1. **Structure hash** ([`network_struct_hash`]): FNV-1a over an
//!    explicit per-[`Layer`] byte record — never over `Debug` formatting
//!    or derived `Hash`, neither of which is a stability contract — so a
//!    topology edit that preserves the network's name can never serve a
//!    stale per-layer breakdown.
//! 2. **Canonical key string** ([`net_key`]): the full
//!    (network, [`PipelineConfig`]) identity as text — file-name tag and
//!    in-file echo of the on-disk network store, and the in-memory memo
//!    key of [`crate::sweep::SweepEngine::network_report`].
//! 3. **Report serialization** ([`encode_report`] / [`decode_report`]):
//!    bit-exact round trip of a whole [`NetworkReport`] (f64s travel as
//!    IEEE bit patterns). Decoding is corruption-tolerant: any malformed
//!    field reads as `None` and the caller recomputes.
//!
//! Changing any code or layout here is a breaking change to persisted
//! network entries: bump [`NET_ENCODING_VERSION`] (it is baked into both
//! the struct hash and the payload) so old entries read as misses.

use crate::common::{ByteReader, ByteWriter};
use crate::power::tables::OperatingPoint;

use super::graph::{Layer, LayerKind, Network};
use super::pipeline::{
    Bound, Engine, LayerReport, NetworkReport, PipelineConfig, StorePolicy, WeightStore,
};

/// Version of the DNN byte layout (struct-hash records, key string
/// fields, report payload). Bump on any change here.
pub const NET_ENCODING_VERSION: u32 = 1;

impl Engine {
    /// Stable wire code (golden-asserted; append-only).
    pub fn code(self) -> u8 {
        match self {
            Engine::Software => 0,
            Engine::HwceOnly => 1,
            Engine::HwceHybrid => 2,
        }
    }

    /// Stable key-string tag.
    pub fn tag(self) -> &'static str {
        match self {
            Engine::Software => "sw",
            Engine::HwceOnly => "hwce",
            Engine::HwceHybrid => "hybrid",
        }
    }

    fn from_code(c: u8) -> Option<Self> {
        Some(match c {
            0 => Engine::Software,
            1 => Engine::HwceOnly,
            2 => Engine::HwceHybrid,
            _ => return None,
        })
    }
}

impl StorePolicy {
    /// Stable wire code (golden-asserted; append-only).
    pub fn code(self) -> u8 {
        match self {
            StorePolicy::AllMram => 0,
            StorePolicy::AllHyperRam => 1,
            StorePolicy::GreedyMram => 2,
        }
    }

    /// Stable key-string tag.
    pub fn tag(self) -> &'static str {
        match self {
            StorePolicy::AllMram => "mram",
            StorePolicy::AllHyperRam => "hyper",
            StorePolicy::GreedyMram => "greedy",
        }
    }

    fn from_code(c: u8) -> Option<Self> {
        Some(match c {
            0 => StorePolicy::AllMram,
            1 => StorePolicy::AllHyperRam,
            2 => StorePolicy::GreedyMram,
            _ => return None,
        })
    }
}

impl WeightStore {
    /// Stable wire code (golden-asserted; append-only).
    pub fn code(self) -> u8 {
        match self {
            WeightStore::Mram => 0,
            WeightStore::HyperRam => 1,
        }
    }

    fn from_code(c: u8) -> Option<Self> {
        Some(match c {
            0 => WeightStore::Mram,
            1 => WeightStore::HyperRam,
            _ => return None,
        })
    }
}

impl Bound {
    /// Stable wire code (golden-asserted; append-only).
    pub fn code(self) -> u8 {
        match self {
            Bound::Compute => 0,
            Bound::L2L1 => 1,
            Bound::L3 => 2,
        }
    }

    fn from_code(c: u8) -> Option<Self> {
        Some(match c {
            0 => Bound::Compute,
            1 => Bound::L2L1,
            2 => Bound::L3,
            _ => return None,
        })
    }
}

/// Append one layer's structural record: kind code, kind parameters in
/// declaration order (u32 LE), input geometry, then the layer name
/// (names appear verbatim in rendered reports, so a rename must change
/// the hash).
pub fn encode_layer(w: &mut ByteWriter, layer: &Layer) {
    match layer.kind {
        LayerKind::Conv { k, stride, cin, cout } => {
            w.u8(1);
            w.u32(k as u32);
            w.u32(stride as u32);
            w.u32(cin as u32);
            w.u32(cout as u32);
        }
        LayerKind::DwConv { stride, c } => {
            w.u8(2);
            w.u32(stride as u32);
            w.u32(c as u32);
        }
        LayerKind::Linear { cin, cout } => {
            w.u8(3);
            w.u32(cin as u32);
            w.u32(cout as u32);
        }
        LayerKind::Add { c } => {
            w.u8(4);
            w.u32(c as u32);
        }
        LayerKind::GlobalPool { c } => {
            w.u8(5);
            w.u32(c as u32);
        }
    }
    w.u32(layer.in_h as u32);
    w.u32(layer.in_w as u32);
    w.str(&layer.name);
}

/// FNV-1a over [`NET_ENCODING_VERSION`], the layer count, and every
/// layer's explicit record — the persistable identity of a network's
/// structure (the DNN analogue of
/// [`crate::isa::Program::content_hash`]).
pub fn network_struct_hash(net: &Network) -> u64 {
    use std::hash::Hasher;
    let mut w = ByteWriter::with_capacity(64 + net.layers.len() * 40);
    w.u32(NET_ENCODING_VERSION);
    w.u32(net.layers.len() as u32);
    for layer in &net.layers {
        encode_layer(&mut w, layer);
    }
    let mut h = crate::common::Fnv1a::new();
    h.write(w.as_slice());
    h.finish()
}

/// Canonical textual key of one (network, config) pipeline run: memo key
/// of [`crate::sweep::SweepEngine::network_report`], file-name tag and
/// in-file echo of the on-disk network store. Every field is explicit:
/// the structure hash from [`network_struct_hash`], operating-point
/// floats by IEEE bit pattern, engine/policy by their stable tags.
pub fn net_key(net: &Network, cfg: &PipelineConfig) -> String {
    format!(
        "{}|{}l/{:016x}|{}@{:016x}/{:016x}/{:016x}|{}|{}",
        net.name,
        net.layers.len(),
        network_struct_hash(net),
        cfg.op.name,
        cfg.op.vdd.to_bits(),
        cfg.op.f_soc.to_bits(),
        cfg.op.f_cl.to_bits(),
        cfg.engine.tag(),
        cfg.policy.tag(),
    )
}

/// Operating-point names that may appear in persisted reports.
/// [`OperatingPoint::name`] is `&'static str`, so decoding interns
/// against this table; an unknown name fails the decode (reads as a
/// miss, and the recompute writes back a known one — correctness is
/// never at risk, but an uninterned point would recompute every warm
/// process). The entries reference the `power::tables` constants
/// directly so a rename cannot desynchronise them; when *adding* an
/// operating-point constant that reaches `network_report`, extend this
/// table (the `every_table_operating_point_interns` test is the
/// reminder).
const OP_NAMES: [&str; 5] = [
    crate::power::tables::LV.name,
    crate::power::tables::NOM.name,
    crate::power::tables::HV.name,
    crate::power::tables::DNN.name,
    // `vega sweep`'s interpolated DVFS ladder (explore::operating_points).
    "sweep",
];

fn intern_op_name(s: &str) -> Option<&'static str> {
    OP_NAMES.iter().find(|&&n| n == s).copied()
}

/// Whether `s` names an operating point the decoder can intern — the
/// static-verifier side of the [`OP_NAMES`] completeness contract
/// (`isa::analyze` asserts the table covers every `power::tables`
/// constant, so a new operating point cannot silently decode as a miss).
pub fn is_interned_op_name(s: &str) -> bool {
    intern_op_name(s).is_some()
}

fn encode_op(w: &mut ByteWriter, op: &OperatingPoint) {
    w.str(op.name);
    w.f64(op.vdd);
    w.f64(op.f_soc);
    w.f64(op.f_cl);
}

fn decode_op(r: &mut ByteReader) -> Option<OperatingPoint> {
    let name = intern_op_name(&r.str()?)?;
    Some(OperatingPoint { name, vdd: r.f64()?, f_soc: r.f64()?, f_cl: r.f64()? })
}

fn encode_layer_report(w: &mut ByteWriter, l: &LayerReport) {
    w.str(&l.name);
    w.u64(l.macs);
    w.u8(l.store.code());
    w.u64(l.compute_cycles);
    w.u64(l.l2l1_cycles);
    w.u64(l.l3_cycles);
    w.u64(l.latency_cycles);
    w.u8(l.bound.code());
    w.u64(l.weight_bytes);
    w.u64(l.l2l1_bytes);
    w.u64(l.l1_bytes);
    w.f64(l.hwce_fraction);
}

fn decode_layer_report(r: &mut ByteReader) -> Option<LayerReport> {
    Some(LayerReport {
        name: r.str()?,
        macs: r.u64()?,
        store: WeightStore::from_code(r.u8()?)?,
        compute_cycles: r.u64()?,
        l2l1_cycles: r.u64()?,
        l3_cycles: r.u64()?,
        latency_cycles: r.u64()?,
        bound: Bound::from_code(r.u8()?)?,
        weight_bytes: r.u64()?,
        l2l1_bytes: r.u64()?,
        l1_bytes: r.u64()?,
        hwce_fraction: r.f64()?,
    })
}

/// Largest plausible layer count in a persisted report; a corrupt length
/// prefix beyond it is rejected outright rather than trusted with an
/// allocation.
const MAX_LAYERS: usize = 4096;

/// Serialize a whole [`NetworkReport`] (bit-exact; see
/// [`decode_report`]). Layout: [`NET_ENCODING_VERSION`], network name,
/// engine/policy codes, operating point, `mram_up_to`
/// (presence byte + u64), the five energy-ledger components, then the
/// length-prefixed layer reports.
pub fn encode_report(rep: &NetworkReport) -> Vec<u8> {
    let mut w = ByteWriter::with_capacity(256 + rep.layers.len() * 128);
    w.u32(NET_ENCODING_VERSION);
    w.str(&rep.network);
    w.u8(rep.engine.code());
    w.u8(rep.policy.code());
    encode_op(&mut w, &rep.op);
    match rep.mram_up_to {
        Some(i) => {
            w.u8(1);
            w.u64(i as u64);
        }
        None => {
            w.u8(0);
            w.u64(0);
        }
    }
    w.f64(rep.energy.compute_pj);
    w.f64(rep.energy.l2l1_pj);
    w.f64(rep.energy.l1_pj);
    w.f64(rep.energy.mram_pj);
    w.f64(rep.energy.hyperram_pj);
    w.u32(rep.layers.len() as u32);
    for l in &rep.layers {
        encode_layer_report(&mut w, l);
    }
    w.into_vec()
}

/// Inverse of [`encode_report`]. Any malformed field — wrong version,
/// unknown code, truncation, trailing bytes, absurd layer count —
/// returns `None`; callers recompute and overwrite.
pub fn decode_report(bytes: &[u8]) -> Option<NetworkReport> {
    let mut r = ByteReader::new(bytes);
    if r.u32()? != NET_ENCODING_VERSION {
        return None;
    }
    let network = r.str()?;
    let engine = Engine::from_code(r.u8()?)?;
    let policy = StorePolicy::from_code(r.u8()?)?;
    let op = decode_op(&mut r)?;
    let mram_up_to = match (r.u8()?, r.u64()?) {
        (0, _) => None,
        (1, i) => Some(i as usize),
        _ => return None,
    };
    let energy = crate::power::EnergyLedger {
        compute_pj: r.f64()?,
        l2l1_pj: r.f64()?,
        l1_pj: r.f64()?,
        mram_pj: r.f64()?,
        hyperram_pj: r.f64()?,
    };
    let n = r.u32()? as usize;
    if n > MAX_LAYERS {
        return None;
    }
    let mut layers = Vec::with_capacity(n);
    for _ in 0..n {
        layers.push(decode_layer_report(&mut r)?);
    }
    if !r.done() {
        return None;
    }
    Some(NetworkReport { network, engine, policy, op, layers, energy, mram_up_to })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::mobilenetv2::mobilenet_v2;
    use crate::dnn::pipeline::run_network;

    fn sample() -> NetworkReport {
        run_network(&mobilenet_v2(), PipelineConfig::nominal_sw(StorePolicy::GreedyMram))
    }

    #[test]
    fn report_round_trips_bit_exactly() {
        let rep = sample();
        let back = decode_report(&encode_report(&rep)).unwrap();
        assert_eq!(back.network, rep.network);
        assert_eq!(back.engine, rep.engine);
        assert_eq!(back.policy, rep.policy);
        assert_eq!(back.op.name, rep.op.name);
        assert_eq!(back.op.vdd.to_bits(), rep.op.vdd.to_bits());
        assert_eq!(back.mram_up_to, rep.mram_up_to);
        assert_eq!(back.energy.total_pj().to_bits(), rep.energy.total_pj().to_bits());
        assert_eq!(back.layers.len(), rep.layers.len());
        for (a, b) in back.layers.iter().zip(&rep.layers) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.latency_cycles, b.latency_cycles);
            assert_eq!(a.bound, b.bound);
            assert_eq!(a.hwce_fraction.to_bits(), b.hwce_fraction.to_bits());
        }
        assert_eq!(back.total_cycles(), rep.total_cycles());
        assert_eq!(back.energy_mj().to_bits(), rep.energy_mj().to_bits());
    }

    #[test]
    fn struct_hash_sees_topology_name_and_geometry() {
        let base = mobilenet_v2();
        let h = network_struct_hash(&base);
        assert_eq!(h, network_struct_hash(&mobilenet_v2()), "hash is deterministic");

        let mut renamed = mobilenet_v2();
        renamed.layers[0].name.push('!');
        assert_ne!(h, network_struct_hash(&renamed), "layer rename must change the hash");

        let mut reshaped = mobilenet_v2();
        reshaped.layers[0].in_h += 1;
        assert_ne!(h, network_struct_hash(&reshaped), "geometry edit must change the hash");

        // Net-level name is in the key string, not the struct hash.
        let mut retitled = mobilenet_v2();
        retitled.name.push('!');
        assert_eq!(h, network_struct_hash(&retitled));
        let cfg = PipelineConfig::nominal_sw(StorePolicy::AllMram);
        assert_ne!(net_key(&base, &cfg), net_key(&retitled, &cfg));
    }

    #[test]
    fn keys_distinguish_every_config_axis() {
        let net = mobilenet_v2();
        let base = PipelineConfig::nominal_sw(StorePolicy::AllMram);
        let k = net_key(&net, &base);
        assert_ne!(k, net_key(&net, &PipelineConfig::nominal_sw(StorePolicy::AllHyperRam)));
        assert_ne!(k, net_key(&net, &PipelineConfig::nominal_hwce(StorePolicy::AllMram)));
        assert_ne!(k, net_key(&net, &PipelineConfig::table7_hwce(StorePolicy::AllMram)));
        let mut op_edit = base;
        op_edit.op.f_cl += 1.0;
        assert_ne!(k, net_key(&net, &op_edit));
    }

    #[test]
    fn wire_codes_are_golden() {
        assert_eq!(
            [Engine::Software.code(), Engine::HwceOnly.code(), Engine::HwceHybrid.code()],
            [0, 1, 2]
        );
        assert_eq!(
            [
                StorePolicy::AllMram.code(),
                StorePolicy::AllHyperRam.code(),
                StorePolicy::GreedyMram.code()
            ],
            [0, 1, 2]
        );
        assert_eq!([WeightStore::Mram.code(), WeightStore::HyperRam.code()], [0, 1]);
        assert_eq!([Bound::Compute.code(), Bound::L2L1.code(), Bound::L3.code()], [0, 1, 2]);
        assert_eq!(Engine::HwceHybrid.tag(), "hybrid");
        assert_eq!(StorePolicy::GreedyMram.tag(), "greedy");
    }

    /// The DNN half of the key-stability gate (the analogue of
    /// `tests/isa_encoding.rs::golden_content_hashes`): hard-coded
    /// struct hash and canonical key string for a fixed synthetic
    /// network, cross-computed offline with a reference FNV-1a. If
    /// either changes, every persisted `.net` entry everywhere is
    /// orphaned — only ever acceptable as a deliberate
    /// `NET_ENCODING_VERSION` bump updating these constants.
    #[test]
    fn golden_struct_hash_and_net_key() {
        assert_eq!(NET_ENCODING_VERSION, 1);
        let net = Network {
            name: "golden-net".into(),
            layers: vec![
                Layer {
                    name: "c0".into(),
                    kind: LayerKind::Conv { k: 3, stride: 2, cin: 3, cout: 8 },
                    in_h: 8,
                    in_w: 8,
                },
                Layer {
                    name: "gp".into(),
                    kind: LayerKind::GlobalPool { c: 8 },
                    in_h: 4,
                    in_w: 4,
                },
            ],
        };
        assert_eq!(network_struct_hash(&net), 0x5e1fb6ae4c04569c);
        let cfg = PipelineConfig::nominal_sw(StorePolicy::AllMram);
        assert_eq!(
            net_key(&net, &cfg),
            "golden-net|2l/5e1fb6ae4c04569c|DNN@3fe3333333333333/41adcd6500000000/41adcd6500000000|sw|mram"
        );
    }

    /// Every operating-point constant in `power::tables` (and the sweep
    /// ladder's name) interns, so a persisted report at any of them
    /// round-trips. Add new constants to `OP_NAMES` or their reports
    /// recompute on every warm process.
    #[test]
    fn every_table_operating_point_interns() {
        use crate::power::tables;
        for op in [tables::LV, tables::NOM, tables::HV, tables::DNN] {
            assert!(
                intern_op_name(op.name).is_some(),
                "operating point '{}' missing from OP_NAMES",
                op.name
            );
        }
        for op in crate::sweep::explore::operating_points(3) {
            assert!(intern_op_name(op.name).is_some(), "sweep ladder name must intern");
        }
    }

    #[test]
    fn corrupt_reports_decode_as_none() {
        let good = encode_report(&sample());
        assert!(decode_report(&good).is_some());
        for cut in [0, 3, good.len() / 2, good.len() - 1] {
            assert!(decode_report(&good[..cut]).is_none(), "truncated at {cut}");
        }
        let mut versioned = good.clone();
        versioned[0] ^= 0xFF;
        assert!(decode_report(&versioned).is_none(), "version mismatch");
        let mut trailing = good.clone();
        trailing.push(0);
        assert!(decode_report(&trailing).is_none(), "trailing garbage");
        let mut bad_engine = good;
        // engine code sits right after version + name (4 + 4 + len).
        let name_len = u32::from_le_bytes([bad_engine[4], bad_engine[5], bad_engine[6], bad_engine[7]]) as usize;
        bad_engine[8 + name_len] = 0x7F;
        assert!(decode_report(&bad_engine).is_none(), "unknown engine code");
    }

    #[test]
    fn unknown_op_names_fail_the_decode() {
        let mut rep = sample();
        rep.op.name = "LV";
        assert!(decode_report(&encode_report(&rep)).is_some());
        // All persisted configs use the intern table's names.
        for n in OP_NAMES {
            assert!(intern_op_name(n).is_some());
        }
        assert!(intern_op_name("bespoke").is_none());
    }
}
