//! The four-stage double-buffered DNN execution pipeline (Fig. 9) and its
//! latency/energy model (Figs. 10/11, Table VII).
//!
//! Stages per layer: (1) weights L3→L2 on the I/O DMA; (2) tile copy-in
//! L2→L1 on the cluster DMA; (3) compute on 8 cores (PULP-NN rate
//! *measured on the ISS*, cached) and/or the HWCE; (4) copy-out L1→L2.
//! All stages overlap, so a layer's latency is the max of its stage
//! totals (plus a pipeline-fill term), and the network latency is the sum
//! over layers — exactly the model the paper uses to explain Fig. 10
//! ("all layers except for the final one are compute-bound").

use std::sync::OnceLock;

use crate::cluster::{dma, Cluster, DmaJob};
use crate::common::Cycles;
use crate::hwce::{ConvJob, Precision};
use crate::iss::FlatMem;
use crate::kernels::int_matmul::{self, IntWidth};
use crate::mem::{BulkChannel, HyperRam, Mram};
use crate::power::{self, tables::OperatingPoint, EnergyLedger};

use super::graph::{Layer, LayerKind, Network};
use super::tiler::{self, L1_BUDGET};

/// Where a layer's weights live (Fig. 11 comparison; Table VII greedy).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WeightStore {
    Mram,
    HyperRam,
}

/// Weight allocation policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StorePolicy {
    AllMram,
    AllHyperRam,
    /// Keep early layers in MRAM until it fills, rest in HyperRAM
    /// (Table VII "MRAM up to layer").
    GreedyMram,
}

/// Compute engine selection (Table VII SW vs HWCE columns).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Engine {
    Software,
    /// 3×3 convs on the HWCE alone (cores clock-gated except the
    /// orchestrator), software elsewhere — the Table VII "HWCE" column:
    /// run at HV, its ~26 MAC/cycle engine rate reproduces the measured
    /// 3× latency gain over the 250 MHz software flow.
    HwceOnly,
    /// HWCE *in parallel with* the 8 cores (output-channel split) — "HWCE
    /// is activated to accelerate the available software programmable
    /// processors" (§III): the 32.2 GOPS peak-ML configuration of
    /// Table VIII.
    HwceHybrid,
}

static SW_MAC_PER_CYCLE: OnceLock<f64> = OnceLock::new();

/// The measured PULP-NN software rate: run the int8 matmul kernel once on
/// the simulated cluster and cache MAC/cycle. This is the link that makes
/// the DNN model *emergent* from the ISS rather than assumed.
pub fn sw_mac_per_cycle() -> f64 {
    *SW_MAC_PER_CYCLE.get_or_init(|| {
        let mut cl = Cluster::new();
        let mut l2 = FlatMem::new(crate::cluster::L2_BASE, 4096);
        let mut rng = crate::common::Rng::new(0xD0DE);
        let (m, n, k) = (64, 64, 64);
        let av: Vec<i32> = (0..m * k).map(|_| rng.range_i64(-128, 127) as i32).collect();
        let bv: Vec<i32> = (0..n * k).map(|_| rng.range_i64(-128, 127) as i32).collect();
        let (_, kr) = int_matmul::run(&mut cl, &mut l2, &av, &bv, m, n, k, IntWidth::I8, 8);
        kr.stats.mac_per_cycle()
    })
}

/// Shared channel models for the timing pipeline. `run_network` only
/// reads their timing parameters (`capacity`, `transfer_cycles`), so one
/// instance serves every run — it used to allocate the 8 MB MRAM + 32 MB
/// HyperRAM backing stores per invocation (§Perf).
static CHANNELS: OnceLock<(Mram, HyperRam)> = OnceLock::new();

fn channels() -> &'static (Mram, HyperRam) {
    CHANNELS.get_or_init(|| (Mram::new(), HyperRam::new(32 * 1024 * 1024)))
}

/// Depthwise convolutions have no filter reuse and byte-granular streams:
/// PULP-NN reaches roughly a third of the matmul rate (documented
/// modelling constant; the paper's Fig. 10 profile shows dw layers far
/// from the 15.5 MAC/cycle peak).
pub const DW_MAC_PER_CYCLE: f64 = 5.0;

/// Elementwise adds/pools: 8 cores × ~1 op/2 cycles.
pub const ELTWISE_OPS_PER_CYCLE: f64 = 4.0;

/// What bounds a layer (Fig. 10 colour coding).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Bound {
    Compute,
    L2L1,
    L3,
}

/// Per-layer report (one bar group of Fig. 10).
#[derive(Debug, Clone)]
pub struct LayerReport {
    pub name: String,
    pub macs: u64,
    pub store: WeightStore,
    pub compute_cycles: Cycles,
    pub l2l1_cycles: Cycles,
    pub l3_cycles: Cycles,
    pub latency_cycles: Cycles,
    pub bound: Bound,
    pub weight_bytes: u64,
    pub l2l1_bytes: u64,
    pub l1_bytes: u64,
    pub hwce_fraction: f64,
}

/// Whole-network report (Figs. 10/11 and Table VII rows).
#[derive(Debug, Clone)]
pub struct NetworkReport {
    pub network: String,
    pub engine: Engine,
    pub policy: StorePolicy,
    pub op: OperatingPoint,
    pub layers: Vec<LayerReport>,
    pub energy: EnergyLedger,
    /// Index of the last layer whose weights fit MRAM (greedy policy).
    pub mram_up_to: Option<usize>,
}

impl NetworkReport {
    pub fn total_cycles(&self) -> Cycles {
        self.layers.iter().map(|l| l.latency_cycles).sum()
    }

    pub fn latency_s(&self) -> f64 {
        self.total_cycles() as f64 / self.op.f_cl
    }

    pub fn fps(&self) -> f64 {
        1.0 / self.latency_s()
    }

    pub fn energy_mj(&self) -> f64 {
        self.energy.total_mj()
    }

    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs).sum()
    }

    pub fn mac_per_cycle(&self) -> f64 {
        self.total_macs() as f64 / self.total_cycles() as f64
    }
}

/// Configuration of one inference run.
#[derive(Debug, Clone, Copy)]
pub struct PipelineConfig {
    pub op: OperatingPoint,
    pub engine: Engine,
    pub policy: StorePolicy,
}

impl PipelineConfig {
    pub fn nominal_sw(policy: StorePolicy) -> Self {
        Self { op: power::tables::DNN, engine: Engine::Software, policy }
    }

    pub fn nominal_hwce(policy: StorePolicy) -> Self {
        Self { op: power::tables::DNN, engine: Engine::HwceHybrid, policy }
    }

    /// The Table VII accelerated configuration: HWCE-only at HV.
    pub fn table7_hwce(policy: StorePolicy) -> Self {
        Self { op: power::tables::HV, engine: Engine::HwceOnly, policy }
    }
}

fn compute_cycles_sw(layer: &Layer) -> Cycles {
    let macs = layer.macs() as f64;
    let cycles = match layer.kind {
        LayerKind::Conv { .. } | LayerKind::Linear { .. } => macs / sw_mac_per_cycle(),
        LayerKind::DwConv { .. } => macs / DW_MAC_PER_CYCLE,
        LayerKind::Add { .. } | LayerKind::GlobalPool { .. } => {
            2.0 * macs / ELTWISE_OPS_PER_CYCLE
        }
    };
    cycles.ceil() as Cycles
}

/// HWCE-hybrid compute: 3×3 convs split output channels between the
/// engine and the cores so both finish together; other layers run SW.
/// Returns (cycles, hwce_fraction of MACs).
///
/// The HWCE gets its own tile shape: its weight buffer holds exactly
/// three filters, so the natural tile is `cout = 3` with as many output
/// rows as L1 affords — tall tiles amortise the line-buffer prologue
/// (the generic DORY tile, sized for the 4×2 software kernel, would
/// starve the engine at 2-row tiles).
fn compute_cycles_hwce(layer: &Layer, hybrid: bool) -> (Cycles, f64) {
    if !layer.hwce_eligible() {
        return (compute_cycles_sw(layer), 0.0);
    }
    let (oh, ow) = layer.out_hw();
    let LayerKind::Conv { cin, cout, .. } = layer.kind else { unreachable!() };
    // HWCE tile: 3 output channels, h rows bounded by the L1 budget
    // (halved in hybrid mode, where the software kernel owns the rest).
    let budget = if hybrid { (L1_BUDGET / 2) as u64 } else { L1_BUDGET as u64 };
    let mut h = oh;
    while h > 2 {
        let in_b = ((h + 2) * (ow + 2) * cin) as u64;
        let w_b = (9 * cin * 3) as u64;
        let out_b = (h * ow * 3) as u64;
        if 2 * (in_b + w_b + out_b) <= budget {
            break;
        }
        h = h.div_ceil(2);
    }
    let job = ConvJob {
        h,
        w: ow,
        cin,
        cout,
        precision: Precision::Int8,
        // With cin processed innermost per row band, the three internal
        // partial-sum FIFOs absorb the cross-channel accumulation ("or
        // from one of three internal partial sum buffers", §II-C), so
        // partials do not round-trip through L1 on this schedule.
        partials_in_l1: false,
    };
    let hwce_rate = job.mac_per_cycle();
    let combined = if hybrid { hwce_rate + sw_mac_per_cycle() } else { hwce_rate };
    let cycles = (layer.macs() as f64 / combined).ceil() as Cycles;
    (cycles, hwce_rate / combined)
}

/// Run the pipeline model over `net`.
pub fn run_network(net: &Network, cfg: PipelineConfig) -> NetworkReport {
    let (mram, hyper) = channels();
    let mut mram_left: u64 = mram.capacity() as u64;
    let mut mram_open = true; // strictly-prefix greedy ("MRAM up to layer")
    let mut mram_up_to = None;
    let mut reports = Vec::new();
    let mut energy = EnergyLedger::default();

    for (i, layer) in net.layers.iter().enumerate() {
        let tiling = tiler::tile_layer(layer, L1_BUDGET);

        // --- stage 1: weights L3 -> L2.
        let wb = layer.weight_bytes();
        let store = match cfg.policy {
            StorePolicy::AllMram => WeightStore::Mram,
            StorePolicy::AllHyperRam => WeightStore::HyperRam,
            StorePolicy::GreedyMram => {
                if mram_open && wb <= mram_left {
                    mram_left -= wb;
                    if wb > 0 {
                        mram_up_to = Some(i);
                    }
                    WeightStore::Mram
                } else {
                    mram_open = false;
                    WeightStore::HyperRam
                }
            }
        };
        let l3_cycles = if wb == 0 {
            0
        } else {
            match store {
                WeightStore::Mram => mram.transfer_cycles(wb, cfg.op.f_soc, false),
                WeightStore::HyperRam => hyper.transfer_cycles(wb, cfg.op.f_soc, false),
            }
        };

        // --- stages 2+4: cluster DMA traffic.
        let per_tile = DmaJob::linear(tiling.tile_bytes());
        let l2l1_cycles = tiling.n_tiles as u64
            * (dma::ClusterDma::job_cycles(per_tile))
            .max(tiling.l2l1_bytes / tiling.n_tiles as u64 / 7);

        // --- stage 3: compute.
        let (compute_cycles, hwce_fraction) = match cfg.engine {
            Engine::Software => (compute_cycles_sw(layer), 0.0),
            Engine::HwceOnly => compute_cycles_hwce(layer, false),
            Engine::HwceHybrid => compute_cycles_hwce(layer, true),
        };

        // Double-buffered overlap: latency = max stage + one tile fill.
        let fill = dma::ClusterDma::job_cycles(per_tile);
        let latency = compute_cycles.max(l2l1_cycles).max(l3_cycles) + fill;
        let bound = if compute_cycles >= l2l1_cycles && compute_cycles >= l3_cycles {
            Bound::Compute
        } else if l2l1_cycles >= l3_cycles {
            Bound::L2L1
        } else {
            Bound::L3
        };

        // --- energy.
        let seconds = latency as f64 / cfg.op.f_cl;
        let core_util = compute_cycles as f64 / latency as f64 * (1.0 - hwce_fraction);
        let hwce_util = compute_cycles as f64 / latency as f64 * hwce_fraction;
        let p = power::cluster_power_w(cfg.op, core_util.min(1.0), hwce_util.min(1.0))
            + power::soc_power_w(cfg.op, 0.15);
        energy.add_compute(p, seconds);
        energy.add_l2l1(tiling.l2l1_bytes);
        // L1 operand traffic: PULP-NN reads 8 operand bytes per 32 MACs
        // and writes each output once.
        let l1_bytes = layer.macs() / 4 + layer.out_bytes();
        energy.add_l1(l1_bytes);
        match store {
            WeightStore::Mram => energy.add_mram(wb),
            WeightStore::HyperRam => energy.add_hyperram(wb),
        }

        reports.push(LayerReport {
            name: layer.name.clone(),
            macs: layer.macs(),
            store,
            compute_cycles,
            l2l1_cycles,
            l3_cycles,
            latency_cycles: latency,
            bound,
            weight_bytes: wb,
            l2l1_bytes: tiling.l2l1_bytes,
            l1_bytes,
            hwce_fraction,
        });
    }

    NetworkReport {
        network: net.name.clone(),
        engine: cfg.engine,
        policy: cfg.policy,
        op: cfg.op,
        layers: reports,
        energy,
        mram_up_to,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::rel_err;
    use crate::dnn::mobilenetv2::mobilenet_v2;
    use crate::dnn::repvgg::{repvgg, Variant};

    #[test]
    fn sw_rate_is_measured_not_assumed() {
        let r = sw_mac_per_cycle();
        assert!((13.0..17.5).contains(&r), "SW rate = {r}");
    }

    #[test]
    fn mobilenet_compute_bound_except_final(){
        // Fig. 10: "all layers except for the final one are compute-bound
        // by a considerable margin".
        let net = mobilenet_v2();
        let rep = run_network(&net, PipelineConfig::nominal_sw(StorePolicy::AllMram));
        let n = rep.layers.len();
        let non_compute: Vec<&LayerReport> = rep.layers[..n - 1]
            .iter()
            .filter(|l| l.bound != Bound::Compute && l.macs > 100_000)
            .collect();
        assert!(
            non_compute.is_empty(),
            "unexpected non-compute-bound: {:?}",
            non_compute.iter().map(|l| &l.name).collect::<Vec<_>>()
        );
        assert_eq!(rep.layers[n - 1].bound, Bound::L3, "fc should be L3-bound");
    }

    #[test]
    fn mobilenet_latency_realtime() {
        // "compatible with real-time computation at more than 10 fps".
        let net = mobilenet_v2();
        let rep = run_network(&net, PipelineConfig::nominal_sw(StorePolicy::AllMram));
        assert!(rep.fps() > 10.0, "fps = {}", rep.fps());
        assert!(rep.fps() < 20.0, "suspiciously fast: {}", rep.fps());
    }

    #[test]
    fn fig11_energy_anchors() {
        let net = mobilenet_v2();
        let m = run_network(&net, PipelineConfig::nominal_sw(StorePolicy::AllMram));
        let h = run_network(&net, PipelineConfig::nominal_sw(StorePolicy::AllHyperRam));
        // 1.19 mJ vs 4.16 mJ, ratio 3.5x.
        assert!(rel_err(m.energy_mj(), 1.19) < 0.25, "MRAM = {} mJ", m.energy_mj());
        assert!(rel_err(h.energy_mj(), 4.16) < 0.25, "Hyper = {} mJ", h.energy_mj());
        let ratio = h.energy_mj() / m.energy_mj();
        assert!((2.8..4.2).contains(&ratio), "ratio = {ratio}");
        // "the time per inference is essentially the same" (few ms delta).
        let dt = (h.latency_s() - m.latency_s()).abs();
        assert!(dt < 8e-3, "latency delta = {dt}");
        assert!(h.latency_s() > m.latency_s(), "MRAM must be slightly faster");
    }

    #[test]
    fn table7_repvgg_a0_shape() {
        let net = repvgg(Variant::A0);
        let sw = run_network(&net, PipelineConfig::nominal_sw(StorePolicy::GreedyMram));
        let hw = run_network(&net, PipelineConfig::table7_hwce(StorePolicy::GreedyMram));
        // SW 358 ms (250 MHz), HWCE 118 ms (3.03x; HWCE-only at HV).
        assert!(rel_err(sw.latency_s(), 0.358) < 0.2, "SW = {} s", sw.latency_s());
        let speedup = sw.latency_s() / hw.latency_s();
        assert!((2.2..3.6).contains(&speedup), "speedup = {speedup}");
        // Energy: 8.5 -> 4.4 mJ.
        assert!(rel_err(sw.energy_mj(), 8.5) < 0.35, "SW = {} mJ", sw.energy_mj());
        assert!(hw.energy_mj() < sw.energy_mj(), "HWCE must save energy");
        // Greedy split point exists (network exceeds MRAM).
        assert!(hw.mram_up_to.is_some());
        let up_to = hw.mram_up_to.unwrap();
        assert!(up_to < net.layers.len() - 1, "split inside the network");
    }

    #[test]
    fn hwce_fraction_only_on_3x3() {
        let net = mobilenet_v2();
        let rep = run_network(&net, PipelineConfig::nominal_hwce(StorePolicy::AllMram));
        for l in &rep.layers {
            if l.name.contains("expand") || l.name.contains("project") {
                assert_eq!(l.hwce_fraction, 0.0, "{}", l.name);
            }
        }
        // MobileNetV2 on HWCE: "a modest ~5% speedup on the overall
        // network" — only conv0 is 3x3 here.
        let sw = run_network(&net, PipelineConfig::nominal_sw(StorePolicy::AllMram));
        let ratio = sw.total_cycles() as f64 / rep.total_cycles() as f64;
        assert!((1.0..1.15).contains(&ratio), "mobilenet hwce ratio = {ratio}");
    }
}
