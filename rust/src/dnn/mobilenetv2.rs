//! MobileNetV2 1.0 / 224×224 (§IV-B case study; [33]).
//!
//! 16 BottleNecks (expand 1×1 → depthwise 3×3 → project 1×1, residual
//! when stride 1 and channels match) in 7 parameter groups, plus the
//! front conv, the 1×1×1280 head, pooling, and the classifier — "a total
//! of 16 bottleneck layers with 7 different parameter combinations, plus
//! 3 other layers at the front and back end".

use super::graph::{Layer, LayerKind, Network};

struct Builder {
    layers: Vec<Layer>,
    h: usize,
    w: usize,
    c: usize,
}

impl Builder {
    fn push(&mut self, name: String, kind: LayerKind) {
        let l = Layer { name, kind, in_h: self.h, in_w: self.w };
        let (oh, ow) = l.out_hw();
        self.h = oh;
        self.w = ow;
        self.c = l.out_c();
        self.layers.push(l);
    }

    fn bottleneck(&mut self, idx: usize, t: usize, cout: usize, stride: usize) {
        let cin = self.c;
        let cexp = cin * t;
        let residual = stride == 1 && cin == cout;
        if t != 1 {
            self.push(
                format!("bneck{idx}.expand"),
                LayerKind::Conv { k: 1, stride: 1, cin, cout: cexp },
            );
        }
        self.push(format!("bneck{idx}.dw"), LayerKind::DwConv { stride, c: cexp });
        self.push(
            format!("bneck{idx}.project"),
            LayerKind::Conv { k: 1, stride: 1, cin: cexp, cout },
        );
        if residual {
            self.push(format!("bneck{idx}.add"), LayerKind::Add { c: cout });
        }
    }
}

/// Build MobileNetV2 1.0/224.
pub fn mobilenet_v2() -> Network {
    let mut b = Builder { layers: Vec::new(), h: 224, w: 224, c: 3 };
    b.push("conv0".into(), LayerKind::Conv { k: 3, stride: 2, cin: 3, cout: 32 });
    // (t, c, n, s) per the paper's Table 2 of [33].
    let cfg = [
        (1, 16, 1, 1),
        (6, 24, 2, 2),
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ];
    let mut idx = 0;
    for &(t, c, n, s) in &cfg {
        for i in 0..n {
            b.bottleneck(idx, t, c, if i == 0 { s } else { 1 });
            idx += 1;
        }
    }
    b.push("head".into(), LayerKind::Conv { k: 1, stride: 1, cin: 320, cout: 1280 });
    b.push("pool".into(), LayerKind::GlobalPool { c: 1280 });
    b.push("fc".into(), LayerKind::Linear { cin: 1280, cout: 1000 });
    let net = Network { name: "MobileNetV2-1.0-224".into(), layers: b.layers };
    net.validate();
    net
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn has_the_standard_bottleneck_count() {
        let net = mobilenet_v2();
        let n_dw = net
            .layers
            .iter()
            .filter(|l| matches!(l.kind, super::LayerKind::DwConv { .. }))
            .count();
        // The standard template [33] has 17 blocks (1+2+3+4+3+3+1); the
        // paper's text says "16 bottleneck layers" — we keep the standard
        // template, whose MAC/parameter totals match the published model.
        assert_eq!(n_dw, 17);
    }

    #[test]
    fn macs_and_params_match_published() {
        let net = mobilenet_v2();
        let mmacs = net.total_macs() as f64 / 1e6;
        // Published: ~300 MMAC, ~3.4 M parameters.
        assert!((270.0..330.0).contains(&mmacs), "MMACs = {mmacs}");
        let params_m = net.total_weight_bytes() as f64 / 1e6;
        assert!((3.0..3.8).contains(&params_m), "params = {params_m} M");
    }

    #[test]
    fn weights_fit_mram() {
        // The §IV-B premise: MobileNetV2 weights fit the 4 MB MRAM.
        let net = mobilenet_v2();
        assert!(net.total_weight_bytes() < 4 * 1024 * 1024);
    }

    #[test]
    fn activations_fit_l2() {
        // Peak in+out activation must fit the 1.5 MB shared L2 (§IV-B).
        let net = mobilenet_v2();
        assert!(
            net.peak_activation_bytes() < 1536 * 1024,
            "peak = {}",
            net.peak_activation_bytes()
        );
    }

    #[test]
    fn final_spatial_size_is_7x7() {
        let net = mobilenet_v2();
        let head = net.layers.iter().find(|l| l.name == "head").unwrap();
        assert_eq!((head.in_h, head.in_w), (7, 7));
    }
}
