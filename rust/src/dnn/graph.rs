//! Layer-graph IR for int8 inference networks (§IV-B data flow).

/// Layer operator kinds (int8 tensors, int32 accumulation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerKind {
    /// Standard convolution `kxk`, `stride`, `cin → cout`.
    Conv { k: usize, stride: usize, cin: usize, cout: usize },
    /// Depthwise 3×3 convolution over `c` channels.
    DwConv { stride: usize, c: usize },
    /// Fully connected `cin → cout` (spatial 1×1 at this point).
    Linear { cin: usize, cout: usize },
    /// Residual addition with the saved input of the block.
    Add { c: usize },
    /// Global average pool over `c` channels.
    GlobalPool { c: usize },
}

/// One layer instance with its input geometry.
#[derive(Debug, Clone)]
pub struct Layer {
    pub name: String,
    pub kind: LayerKind,
    pub in_h: usize,
    pub in_w: usize,
}

impl Layer {
    pub fn out_hw(&self) -> (usize, usize) {
        match self.kind {
            LayerKind::Conv { stride, .. } | LayerKind::DwConv { stride, .. } => {
                (self.in_h.div_ceil(stride), self.in_w.div_ceil(stride))
            }
            LayerKind::Linear { .. } | LayerKind::Add { .. } => (self.in_h, self.in_w),
            LayerKind::GlobalPool { .. } => (1, 1),
        }
    }

    pub fn out_c(&self) -> usize {
        match self.kind {
            LayerKind::Conv { cout, .. } => cout,
            LayerKind::DwConv { c, .. } => c,
            LayerKind::Linear { cout, .. } => cout,
            LayerKind::Add { c } => c,
            LayerKind::GlobalPool { c } => c,
        }
    }

    pub fn in_c(&self) -> usize {
        match self.kind {
            LayerKind::Conv { cin, .. } => cin,
            LayerKind::DwConv { c, .. } => c,
            LayerKind::Linear { cin, .. } => cin,
            LayerKind::Add { c } => c,
            LayerKind::GlobalPool { c } => c,
        }
    }

    /// Multiply-accumulates.
    pub fn macs(&self) -> u64 {
        let (oh, ow) = self.out_hw();
        match self.kind {
            LayerKind::Conv { k, cin, cout, .. } => (oh * ow * k * k * cin * cout) as u64,
            LayerKind::DwConv { c, .. } => (oh * ow * 9 * c) as u64,
            LayerKind::Linear { cin, cout } => (oh * ow * cin * cout) as u64,
            LayerKind::Add { c } => (oh * ow * c) as u64 / 2, // adds, not MACs
            LayerKind::GlobalPool { c } => (self.in_h * self.in_w * c) as u64 / 2,
        }
    }

    /// Weight bytes (int8).
    pub fn weight_bytes(&self) -> u64 {
        match self.kind {
            LayerKind::Conv { k, cin, cout, .. } => (k * k * cin * cout) as u64,
            LayerKind::DwConv { c, .. } => (9 * c) as u64,
            LayerKind::Linear { cin, cout } => (cin * cout) as u64,
            LayerKind::Add { .. } | LayerKind::GlobalPool { .. } => 0,
        }
    }

    /// Input/output activation bytes (int8).
    pub fn in_bytes(&self) -> u64 {
        (self.in_h * self.in_w * self.in_c()) as u64
    }

    pub fn out_bytes(&self) -> u64 {
        let (oh, ow) = self.out_hw();
        (oh * ow * self.out_c()) as u64
    }

    /// Is this a 3×3 standard conv (HWCE-eligible)?
    pub fn hwce_eligible(&self) -> bool {
        matches!(self.kind, LayerKind::Conv { k: 3, .. })
    }
}

/// A whole network.
#[derive(Debug, Clone)]
pub struct Network {
    pub name: String,
    pub layers: Vec<Layer>,
}

impl Network {
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs()).sum()
    }

    pub fn total_weight_bytes(&self) -> u64 {
        self.layers.iter().map(|l| l.weight_bytes()).sum()
    }

    /// Peak simultaneous activation footprint in L2 (input + output of
    /// the widest layer — §IV-B "intermediate activation tensors are
    /// allocated in the L2 shared memory and immediately deallocated").
    pub fn peak_activation_bytes(&self) -> u64 {
        self.layers
            .iter()
            .map(|l| l.in_bytes() + l.out_bytes())
            .max()
            .unwrap_or(0)
    }

    /// Consistency: each layer's input channels match the previous
    /// layer's output channels (skipping residual Add bookkeeping).
    pub fn validate(&self) {
        for pair in self.layers.windows(2) {
            let (a, b) = (&pair[0], &pair[1]);
            let (oh, ow) = a.out_hw();
            assert_eq!(oh, b.in_h, "{} -> {}: H mismatch", a.name, b.name);
            assert_eq!(ow, b.in_w, "{} -> {}: W mismatch", a.name, b.name);
            assert_eq!(a.out_c(), b.in_c(), "{} -> {}: C mismatch", a.name, b.name);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_geometry() {
        let l = Layer {
            name: "c".into(),
            kind: LayerKind::Conv { k: 3, stride: 2, cin: 3, cout: 32 },
            in_h: 224,
            in_w: 224,
        };
        assert_eq!(l.out_hw(), (112, 112));
        assert_eq!(l.macs(), 112 * 112 * 9 * 3 * 32);
        assert_eq!(l.weight_bytes(), 9 * 3 * 32);
        assert!(l.hwce_eligible());
    }

    #[test]
    fn dw_and_linear() {
        let dw = Layer {
            name: "dw".into(),
            kind: LayerKind::DwConv { stride: 1, c: 96 },
            in_h: 14,
            in_w: 14,
        };
        assert_eq!(dw.macs(), 14 * 14 * 9 * 96);
        assert!(!dw.hwce_eligible());
        let fc = Layer {
            name: "fc".into(),
            kind: LayerKind::Linear { cin: 1280, cout: 1000 },
            in_h: 1,
            in_w: 1,
        };
        assert_eq!(fc.weight_bytes(), 1_280_000);
    }
}
