//! The DNN deployment stack (§IV-B): layer-graph IR, the MobileNetV2 and
//! RepVGG-A topologies of the evaluation, the DORY-style tiling solver,
//! and the four-stage double-buffered pipeline latency/energy model.

pub mod encode;
pub mod graph;
pub mod mobilenetv2;
pub mod pipeline;
pub mod repvgg;
pub mod tiler;

pub use encode::{net_key, network_struct_hash, NET_ENCODING_VERSION};
pub use graph::{Layer, LayerKind, Network};
pub use mobilenetv2::mobilenet_v2;
pub use pipeline::{
    run_network, Bound, Engine, NetworkReport, PipelineConfig, StorePolicy, WeightStore,
};
pub use repvgg::{repvgg, Variant};
pub use tiler::{tile_layer, Tiling, L1_BUDGET};
