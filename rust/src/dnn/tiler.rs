//! DORY-style tiling solver (§IV-B, [32]).
//!
//! "Both weights and input activation have to be divided into tiles that
//! fit within the 128 KB of cluster L1 shared memory." The solver keeps
//! the full input-channel depth per tile (partial sums never spill to
//! L2), halves the output-row count, then the output-channel count, until
//! the double-buffered working set fits. DORY's actual solver is an ILP;
//! this greedy variant reproduces its constraint set and, for every layer
//! of the evaluated networks, a feasible near-maximal tile.

use super::graph::{Layer, LayerKind};

/// Usable L1 for kernel buffers (128 kB minus stack/runtime margin).
pub const L1_BUDGET: usize = 120 * 1024;

/// A tiling solution for one layer.
#[derive(Debug, Clone)]
pub struct Tiling {
    /// Output rows per tile.
    pub h_tile: usize,
    /// Output columns per tile (wide deep layers must split W too).
    pub w_tile: usize,
    /// Output channels per tile.
    pub cout_tile: usize,
    /// Total tiles.
    pub n_tiles: usize,
    /// Per-tile buffer bytes (single buffer; ×2 when double-buffered).
    pub in_tile_bytes: u64,
    pub w_tile_bytes: u64,
    pub out_tile_bytes: u64,
    /// Total L2↔L1 traffic for the layer (input re-fetched once per
    /// output-channel tile pass, weights once, outputs once).
    pub l2l1_bytes: u64,
}

impl Tiling {
    pub fn tile_bytes(&self) -> u64 {
        self.in_tile_bytes + self.w_tile_bytes + self.out_tile_bytes
    }
}

/// Geometry helpers for one candidate tile of `layer`.
fn tile_bytes(
    layer: &Layer,
    h_tile: usize,
    w_tile: usize,
    cout_tile: usize,
) -> (u64, u64, u64) {
    let cin = layer.in_c();
    match layer.kind {
        LayerKind::Conv { k, stride, .. } => {
            let in_rows = h_tile * stride + k.saturating_sub(stride);
            let in_cols = w_tile * stride + k.saturating_sub(stride);
            let in_b = (in_rows * in_cols * cin) as u64;
            let w_b = (k * k * cin * cout_tile) as u64;
            let out_b = (h_tile * w_tile * cout_tile) as u64;
            (in_b, w_b, out_b)
        }
        LayerKind::DwConv { stride, .. } => {
            let in_rows = h_tile * stride + 3usize.saturating_sub(stride);
            let in_cols = w_tile * stride + 3usize.saturating_sub(stride);
            // depthwise: channel tile == cout tile
            let in_b = (in_rows * in_cols * cout_tile) as u64;
            let w_b = (9 * cout_tile) as u64;
            let out_b = (h_tile * w_tile * cout_tile) as u64;
            (in_b, w_b, out_b)
        }
        LayerKind::Linear { cin, .. } => {
            let in_b = cin as u64;
            let w_b = (cin * cout_tile) as u64;
            let out_b = cout_tile as u64;
            (in_b, w_b, out_b)
        }
        LayerKind::Add { c } | LayerKind::GlobalPool { c } => {
            let in_b = (h_tile * w_tile * c.min(cout_tile) * 2) as u64;
            (in_b, 0, (h_tile * w_tile * cout_tile) as u64)
        }
    }
}

/// Solve the tiling for `layer` under `l1_budget` bytes (double-buffered).
pub fn tile_layer(layer: &Layer, l1_budget: usize) -> Tiling {
    let (oh, ow) = layer.out_hw();
    let cout = layer.out_c();
    let mut h_tile = oh;
    let mut w_tile = ow;
    let mut cout_tile = cout;
    loop {
        let (in_b, w_b, out_b) = tile_bytes(layer, h_tile, w_tile, cout_tile);
        // Double buffering: two live copies of every stream (Fig. 9).
        if 2 * (in_b + w_b + out_b) <= l1_budget as u64 {
            break;
        }
        // Shrink whichever stream dominates the working set: weight-
        // dominated layers (1x1 projections) split output channels so the
        // weight buffer shrinks; activation-dominated layers split rows
        // first (weight reuse + linear DMA), then columns.
        if w_b >= in_b.max(out_b) && cout_tile > 1 {
            cout_tile = cout_tile.div_ceil(2);
        } else if h_tile > 1 {
            h_tile = h_tile.div_ceil(2);
        } else if w_tile > 1 {
            w_tile = w_tile.div_ceil(2);
        } else if cout_tile > 1 {
            cout_tile = cout_tile.div_ceil(2);
        } else {
            panic!(
                "{}: single-pixel tile exceeds L1 ({} B)",
                layer.name,
                in_b + w_b + out_b
            );
        }
    }
    let n_h = oh.div_ceil(h_tile);
    let n_w = ow.div_ceil(w_tile);
    let n_c = cout.div_ceil(cout_tile);
    let (in_b, w_b, out_b) = tile_bytes(layer, h_tile, w_tile, cout_tile);
    // Inputs stream once per cout-tile pass (with halo re-fetch when W is
    // split); weights and outputs once.
    let halo = if n_w > 1 { (w_tile + 2) as u64 } else { w_tile as u64 };
    let l2l1 = layer.in_bytes() * n_c as u64 * halo / w_tile as u64
        + layer.weight_bytes()
        + layer.out_bytes();
    Tiling {
        h_tile,
        w_tile,
        cout_tile,
        n_tiles: n_h * n_w * n_c,
        in_tile_bytes: in_b,
        w_tile_bytes: w_b,
        out_tile_bytes: out_b,
        l2l1_bytes: l2l1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::mobilenetv2::mobilenet_v2;
    use crate::dnn::repvgg::{repvgg, Variant};

    #[test]
    fn every_mobilenet_layer_tiles_within_l1() {
        for l in &mobilenet_v2().layers {
            let t = tile_layer(l, L1_BUDGET);
            assert!(
                2 * t.tile_bytes() <= L1_BUDGET as u64,
                "{}: {} B double-buffered",
                l.name,
                2 * t.tile_bytes()
            );
            assert!(t.n_tiles >= 1);
        }
    }

    #[test]
    fn every_repvgg_layer_tiles_within_l1() {
        for v in [Variant::A0, Variant::A1, Variant::A2] {
            for l in &repvgg(v).layers {
                let t = tile_layer(l, L1_BUDGET);
                assert!(2 * t.tile_bytes() <= L1_BUDGET as u64, "{}", l.name);
            }
        }
    }

    #[test]
    fn pool_runs_untiled_and_projections_tile_by_channel() {
        let net = mobilenet_v2();
        let pool = net.layers.iter().find(|l| l.name == "pool").unwrap();
        assert_eq!(tile_layer(pool, L1_BUDGET).n_tiles, 1);
        // Weight-dominated 1x1 projections split along output channels.
        let proj = net.layers.iter().find(|l| l.name == "bneck16.project").unwrap();
        let t = tile_layer(proj, L1_BUDGET);
        assert!(t.cout_tile < proj.out_c(), "{t:?}");
    }

    #[test]
    fn random_layer_geometries_always_tile() {
        use crate::common::{property, Rng};
        use crate::dnn::graph::Layer;
        property("tiler-feasible", 60, |rng: &mut Rng| {
            let k = [1usize, 3][rng.below(2) as usize];
            let stride = 1 + rng.below(2) as usize;
            let l = Layer {
                name: "rand".into(),
                kind: LayerKind::Conv {
                    k,
                    stride,
                    cin: 1 + rng.below(512) as usize,
                    cout: 1 + rng.below(512) as usize,
                },
                in_h: (1 + rng.below(224)) as usize,
                in_w: (1 + rng.below(224)) as usize,
            };
            let t = tile_layer(&l, L1_BUDGET);
            // Feasible, double-buffered, and covers the full output.
            assert!(2 * t.tile_bytes() <= L1_BUDGET as u64, "{l:?} -> {t:?}");
            let (oh, _) = l.out_hw();
            assert!(t.h_tile * oh.div_ceil(t.h_tile) >= oh);
            assert!(t.cout_tile * l.out_c().div_ceil(t.cout_tile) >= l.out_c());
            assert!(t.l2l1_bytes >= l.in_bytes() + l.weight_bytes() + l.out_bytes());
        });
    }

    #[test]
    fn l2l1_traffic_at_least_tensor_sizes() {
        for l in &mobilenet_v2().layers {
            let t = tile_layer(l, L1_BUDGET);
            assert!(t.l2l1_bytes >= l.in_bytes() + l.weight_bytes() + l.out_bytes());
        }
    }
}
