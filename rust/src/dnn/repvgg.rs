//! RepVGG-A0/A1/A2 in deploy form (§IV-B, Table VII; [30]).
//!
//! "Divided into 5 stages composed of 1, 2, 4, 14, and 1 layers,
//! respectively — all implemented as 3×3 convolutions, plus a final fully
//! connected layer." Deploy mode re-parameterises each block to a single
//! 3×3 conv (the identity the HWCE datapath tests prove), so every
//! compute layer is HWCE-eligible.

use super::graph::{Layer, LayerKind, Network};

/// RepVGG-A variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    A0,
    A1,
    A2,
}

impl Variant {
    /// Stage widths (a-scaled 64,128,256 + b-scaled 512 head).
    fn widths(self) -> [usize; 5] {
        match self {
            Variant::A0 => [48, 48, 96, 192, 1280],
            Variant::A1 => [64, 64, 128, 256, 1280],
            Variant::A2 => [96, 96, 192, 384, 1408],
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Variant::A0 => "RepVGG-A0",
            Variant::A1 => "RepVGG-A1",
            Variant::A2 => "RepVGG-A2",
        }
    }

    /// Published ImageNet top-1 (Table VII; quoted, not re-measured —
    /// DESIGN.md §5).
    pub fn top1(self) -> f64 {
        match self {
            Variant::A0 => 72.41,
            Variant::A1 => 74.46,
            Variant::A2 => 76.48,
        }
    }
}

/// Stage depths: 1, 2, 4, 14, 1 (all variants).
pub const DEPTHS: [usize; 5] = [1, 2, 4, 14, 1];

pub fn repvgg(v: Variant) -> Network {
    let widths = v.widths();
    let mut layers = Vec::new();
    let (mut h, mut w, mut c) = (224usize, 224usize, 3usize);
    for (s, (&width, &depth)) in widths.iter().zip(DEPTHS.iter()).enumerate() {
        for i in 0..depth {
            let stride = if i == 0 { 2 } else { 1 };
            let l = Layer {
                name: format!("stage{s}.conv{i}"),
                kind: LayerKind::Conv { k: 3, stride, cin: c, cout: width },
                in_h: h,
                in_w: w,
            };
            let (oh, ow) = l.out_hw();
            h = oh;
            w = ow;
            c = width;
            layers.push(l);
        }
    }
    layers.push(Layer {
        name: "pool".into(),
        kind: LayerKind::GlobalPool { c },
        in_h: h,
        in_w: w,
    });
    layers.push(Layer {
        name: "fc".into(),
        kind: LayerKind::Linear { cin: c, cout: 1000 },
        in_h: 1,
        in_w: 1,
    });
    let net = Network { name: v.name().into(), layers };
    net.validate();
    net
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a0_matches_table7_row() {
        let net = repvgg(Variant::A0);
        // Table VII: 1389 MMAC, 8116 KB int8 parameters.
        let mmacs = net.total_macs() as f64 / 1e6;
        assert!((1250.0..1530.0).contains(&mmacs), "MMACs = {mmacs}");
        let kb = net.total_weight_bytes() as f64 / 1024.0;
        assert!((7500.0..8700.0).contains(&kb), "params = {kb} KB");
    }

    #[test]
    fn a1_and_a2_match_table7() {
        let a1 = repvgg(Variant::A1);
        let m1 = a1.total_macs() as f64 / 1e6; // 2364 MMAC
        assert!((2100.0..2600.0).contains(&m1), "A1 MMACs = {m1}");
        let k1 = a1.total_weight_bytes() as f64 / 1024.0; // 12484 KB
        assert!((11500.0..13500.0).contains(&k1), "A1 KB = {k1}");

        let a2 = repvgg(Variant::A2);
        let m2 = a2.total_macs() as f64 / 1e6; // 5117 MMAC
        assert!((4600.0..5600.0).contains(&m2), "A2 MMACs = {m2}");
        let k2 = a2.total_weight_bytes() as f64 / 1024.0; // 24769 KB
        assert!((23000.0..26500.0).contains(&k2), "A2 KB = {k2}");
    }

    #[test]
    fn all_compute_layers_are_hwce_eligible() {
        let net = repvgg(Variant::A0);
        for l in &net.layers {
            if matches!(l.kind, LayerKind::Conv { .. }) {
                assert!(l.hwce_eligible(), "{}", l.name);
            }
        }
    }

    #[test]
    fn too_big_for_mram_alone() {
        // The Table VII premise: all three exceed the 4 MB MRAM, forcing
        // the greedy MRAM/HyperRAM split.
        for v in [Variant::A0, Variant::A1, Variant::A2] {
            assert!(repvgg(v).total_weight_bytes() > 4 * 1024 * 1024, "{v:?}");
        }
    }

    #[test]
    fn depths_sum_to_22_convs() {
        let net = repvgg(Variant::A0);
        let convs = net
            .layers
            .iter()
            .filter(|l| matches!(l.kind, LayerKind::Conv { .. }))
            .count();
        assert_eq!(convs, DEPTHS.iter().sum::<usize>());
    }
}
