//! FP MATMUL (Table V row 1): FP32 scalar FMA, FP16 packed-SIMD
//! (`vfdotpex.s.h`) and FP8 packed-SIMD (`vfdotpex.s.b`) variants — the
//! Fig. 8 leader thanks to fused multiply-accumulate ("2 FP operations
//! per cycle"; 4 MACs per issue in the 8-bit smallFloat mode).
//!
//! 2×2 register tiling (the shared-FPU fabric sustains one FP issue per
//! two cores, so deeper unrolling only piles up contention stalls), same
//! padded SPMD layout as the integer kernels. The fp8 variant quantizes
//! inputs to E5M2 on the host, packs four lanes per TCDM word, and
//! accumulates every dot product in f32 (the multi-format DotpEx
//! datapath), so its numerics are the quantization error only.

use crate::cluster::{Cluster, ClusterStats};
use crate::isa::{Asm, Program, A0, A1, A2, A3, A4, A5, A6, A7, S0, S1, S3, S4, S5, S6, S7,
    S8, S9, T0, T1, T4, T5};
use crate::iss::{softfloat as sf, FlatMem};

use super::{check_program, require, KernelRun, TcdmAlloc};

/// FP operand width.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FpWidth {
    F32,
    /// Packed 2×binary16 (smallFloat SIMD).
    F16x2,
    /// Packed 4×binary8 E5M2 (smallFloat fp8 SIMD; matmul-only — the
    /// NSAA kernel family stops at fp16).
    F8x4,
}

/// Build the SPMD FP matmul for `(m, n, k)`.
pub fn build(m: usize, n: usize, k: usize, w: FpWidth) -> Program {
    let name = match w {
        FpWidth::F32 => "fp_matmul_f32",
        FpWidth::F16x2 => "fp_matmul_f16",
        FpWidth::F8x4 => "fp_matmul_f8",
    };
    require(m % 2 == 0, name, "M % 2 == 0");
    require(n % 2 == 0, name, "N % 2 == 0");
    let (esz, per_word) = match w {
        FpWidth::F32 => (4usize, 1usize),
        FpWidth::F16x2 => (2, 2),
        FpWidth::F8x4 => (1, 4),
    };
    require(k % per_word == 0, name, "K multiple of SIMD lanes");
    let row = (k * esz) as i32 + 4; // +pad word against bank aliasing
    let crow = (n * 4) as i32;
    let kiter = (k / per_word) as u32;

    let mut a = Asm::new(name);
    let done = a.label();
    let m_loop = a.label();
    let n_loop = a.label();
    let end_k = a.label();

    a.slli(S0, A1, 1); // m stride = 2*n_cores
    a.slli(S3, A0, 1); // m = 2*core_id

    a.bind(m_loop);
    a.bge(S3, A5, done);
    a.li(S4, 0);

    a.bind(n_loop);
    a.li(S1, row);
    a.mul(S5, S3, S1);
    a.add(S5, S5, A2);
    a.mul(S6, S4, S1);
    a.add(S6, S6, A3);
    a.mul(S7, S3, A6);
    a.add(S7, S7, S4);
    a.slli(S7, S7, 2);
    a.add(S7, S7, A4);
    for r in [A0, A1, S8, S9] {
        a.li(r, 0); // f32 accumulators (0.0 bits == 0)
    }

    // Inner loop: 4 loads + 4 FMA-class ops per word of K.
    a.lp_setup_imm(0, kiter, end_k);
    a.lw_pi(T0, S5, 4); // a row 0
    a.lw(T1, S5, row - 4); // a row 1
    a.lw_pi(T4, S6, 4); // b col 0
    a.lw(T5, S6, row - 4); // b col 1
    match w {
        FpWidth::F32 => {
            a.fmac_s(A0, T0, T4);
            a.fmac_s(A1, T0, T5);
            a.fmac_s(S8, T1, T4);
            a.fmac_s(S9, T1, T5);
        }
        FpWidth::F16x2 => {
            a.vfdotpex_s_h(A0, T0, T4);
            a.vfdotpex_s_h(A1, T0, T5);
            a.vfdotpex_s_h(S8, T1, T4);
            a.vfdotpex_s_h(S9, T1, T5);
        }
        FpWidth::F8x4 => {
            a.vfdotpex_s_b(A0, T0, T4);
            a.vfdotpex_s_b(A1, T0, T5);
            a.vfdotpex_s_b(S8, T1, T4);
            a.vfdotpex_s_b(S9, T1, T5);
        }
    }
    a.bind(end_k);

    a.sw(A0, S7, 0);
    a.sw(A1, S7, 4);
    a.sw(S8, S7, crow);
    a.sw(S9, S7, crow + 4);

    a.addi(S4, S4, 2);
    a.blt(S4, A6, n_loop);
    a.add(S3, S3, S0);
    a.j(m_loop);
    a.bind(done);
    a.halt();

    let p = a.finish().expect("assembly");
    check_program(&p);
    p
}

/// Host fp8 reference: inputs quantized through E5M2 (the same
/// quantization [`run`] applies when packing TCDM words), lane products
/// and accumulation in f32 following the SIMD path's exact association —
/// so the cluster's fp8 result must match this reference **bit for bit**
/// (asserted by `f8_matches_scalar_reference_bit_exactly`). The only
/// numerics difference vs [`host_ref`] is the 2-mantissa-bit input
/// quantization; accumulation stays full f32 (the multi-format DotpEx
/// contract, §II-C).
pub fn host_ref_f8(av: &[f32], bv: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    assert_eq!(k % 4, 0, "fp8 reference needs K % 4 == 0");
    let q = |v: f32| sf::f8_to_f32(sf::f32_to_f8(v));
    let mut c = vec![0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0f32;
            for kk in (0..k).step_by(4) {
                // One vfdotpex.s.b: lane products summed lane 0 → 3,
                // accumulator added last.
                let mut s = 0f32;
                for l in 0..4 {
                    s += q(av[i * k + kk + l]) * q(bv[j * k + kk + l]);
                }
                acc += s;
            }
            c[i * n + j] = acc;
        }
    }
    c
}

/// Host reference in f32 (A row-major, B column-major).
pub fn host_ref(av: &[f32], bv: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    let mut c = vec![0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0f32;
            for kk in 0..k {
                acc = av[i * k + kk].mul_add(bv[j * k + kk], acc);
            }
            c[i * n + j] = acc;
        }
    }
    c
}

fn write_rows(mem: &mut FlatMem, base: u32, vals: &[f32], rows: usize, k: usize, w: FpWidth) {
    let esz = match w {
        FpWidth::F32 => 4,
        FpWidth::F16x2 => 2,
        FpWidth::F8x4 => 1,
    };
    let stride = (k * esz + 4) as u32;
    for r in 0..rows {
        let row = &vals[r * k..(r + 1) * k];
        match w {
            FpWidth::F32 => mem.write_f32s(base + r as u32 * stride, row),
            FpWidth::F16x2 => mem.write_f16s(base + r as u32 * stride, row),
            FpWidth::F8x4 => mem.write_f8s(base + r as u32 * stride, row),
        }
    }
}

/// Run on the cluster; returns C (f32) and the run record.
#[allow(clippy::too_many_arguments)]
pub fn run(
    cluster: &mut Cluster,
    l2: &mut FlatMem,
    av: &[f32],
    bv: &[f32],
    m: usize,
    n: usize,
    k: usize,
    w: FpWidth,
    n_cores: usize,
) -> (Vec<f32>, KernelRun) {
    assert_eq!(av.len(), m * k);
    assert_eq!(bv.len(), n * k);
    let prog = build(m, n, k, w);
    let esz = match w {
        FpWidth::F32 => 4,
        FpWidth::F16x2 => 2,
        FpWidth::F8x4 => 1,
    };
    let stride = k * esz + 4;
    let mut alloc = TcdmAlloc::new();
    let a_base = alloc.alloc(m * stride);
    let b_base = alloc.alloc(n * stride);
    let c_base = alloc.alloc(m * n * 4);
    write_rows(&mut cluster.tcdm.mem, a_base, av, m, k, w);
    write_rows(&mut cluster.tcdm.mem, b_base, bv, n, k, w);

    let stats: ClusterStats = cluster.run_program(
        &prog,
        n_cores,
        l2,
        |id| {
            vec![
                (A0, id as u32),
                (A1, n_cores as u32),
                (A2, a_base),
                (A3, b_base),
                (A4, c_base),
                (A5, m as u32),
                (A6, n as u32),
                (A7, k as u32),
            ]
        },
        500_000_000,
    );
    let c = cluster.tcdm.mem.read_f32s(c_base, m * n);
    let flops = 2 * (m * n * k) as u64;
    (c, KernelRun::new(prog.name.clone(), stats, flops))
}

/// Static-verification target mirroring [`run`]'s layout and registers.
pub fn verify_target(
    m: usize,
    n: usize,
    k: usize,
    w: FpWidth,
    n_cores: usize,
) -> super::VerifyTarget {
    let prog = build(m, n, k, w);
    let esz = match w {
        FpWidth::F32 => 4,
        FpWidth::F16x2 => 2,
        FpWidth::F8x4 => 1,
    };
    let stride = k * esz + 4;
    let mut alloc = TcdmAlloc::new();
    let a_base = alloc.alloc(m * stride);
    let b_base = alloc.alloc(n * stride);
    let c_base = alloc.alloc(m * n * 4);
    let entry = (0..n_cores)
        .map(|id| {
            vec![
                (A0, id as u32),
                (A1, n_cores as u32),
                (A2, a_base),
                (A3, b_base),
                (A4, c_base),
                (A5, m as u32),
                (A6, n as u32),
                (A7, k as u32),
            ]
        })
        .collect();
    let name = prog.name.clone();
    super::VerifyTarget { name, prog, n_cores, entry }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::L2_BASE;
    use crate::common::Rng;

    fn setup(m: usize, n: usize, k: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let av: Vec<f32> = (0..m * k).map(|_| rng.f32_pm1()).collect();
        let bv: Vec<f32> = (0..n * k).map(|_| rng.f32_pm1()).collect();
        (av, bv)
    }

    fn check(m: usize, n: usize, k: usize, w: FpWidth, cores: usize, tol: f32) -> KernelRun {
        let (av, bv) = setup(m, n, k, 3);
        let mut cl = Cluster::new();
        let mut l2 = FlatMem::new(L2_BASE, 4096);
        let (c, kr) = run(&mut cl, &mut l2, &av, &bv, m, n, k, w, cores);
        let want = host_ref(&av, &bv, m, n, k);
        for (i, (&g, &r)) in c.iter().zip(&want).enumerate() {
            assert!(
                (g - r).abs() <= tol * r.abs().max(1.0),
                "{w:?} elem {i}: {g} vs {r}"
            );
        }
        kr
    }

    #[test]
    fn f32_matches_host() {
        check(8, 8, 16, FpWidth::F32, 8, 1e-5);
        check(2, 2, 4, FpWidth::F32, 1, 1e-5);
        check(16, 16, 32, FpWidth::F32, 4, 1e-5);
    }

    #[test]
    fn f16_matches_host_to_half_precision() {
        // inputs rounded to f16, accumulation exact in f32 (vfdotpex).
        check(8, 8, 16, FpWidth::F16x2, 8, 2e-2);
        check(16, 16, 32, FpWidth::F16x2, 8, 2e-2);
    }

    #[test]
    fn fp32_throughput_near_2gflops_shape() {
        // Table VIII: 2 GFLOPS at 450 MHz ⇒ ~4.4 FLOP/cycle on 8 cores.
        let kr = check(32, 32, 32, FpWidth::F32, 8, 1e-4);
        let fpc = kr.stats.flops_per_cycle();
        assert!((3.0..6.5).contains(&fpc), "flops/cycle = {fpc}");
    }

    #[test]
    fn f16_vectorization_speedup() {
        // Packed f16 halves the K loop: expect >1.4x (paper's matmul gain
        // is above the 1.46x suite average).
        let f32r = check(32, 32, 32, FpWidth::F32, 8, 1e-4);
        let f16r = check(32, 32, 32, FpWidth::F16x2, 8, 3e-2);
        let speedup = f32r.stats.cycles as f64 / f16r.stats.cycles as f64;
        assert!(speedup > 1.4, "speedup = {speedup}");
    }

    /// The fp8 SIMD path against [`host_ref_f8`], bit for bit: same E5M2
    /// quantization, same f32 association — any divergence is a real
    /// datapath bug, not float noise.
    #[test]
    fn f8_matches_scalar_reference_bit_exactly() {
        for (m, n, k, cores) in [(8, 8, 16, 8), (2, 2, 4, 1), (16, 16, 32, 4), (32, 32, 64, 8)] {
            let (av, bv) = setup(m, n, k, 3);
            let mut cl = Cluster::new();
            let mut l2 = FlatMem::new(L2_BASE, 4096);
            let (c, _) = run(&mut cl, &mut l2, &av, &bv, m, n, k, FpWidth::F8x4, cores);
            let want = host_ref_f8(&av, &bv, m, n, k);
            for (i, (&g, &r)) in c.iter().zip(&want).enumerate() {
                assert_eq!(
                    g.to_bits(),
                    r.to_bits(),
                    "{m}x{n}x{k}@{cores}: elem {i}: {g} vs {r}"
                );
            }
        }
    }

    /// fp8 halves the fp16 K loop again: 4 lanes per load/issue. Expect
    /// clearly more than the fp16 gain over f32, and >2x vs f32 overall.
    #[test]
    fn f8_vectorization_speedup() {
        let f32r = check(32, 32, 32, FpWidth::F32, 8, 1e-4);
        let f16r = check(32, 32, 32, FpWidth::F16x2, 8, 3e-2);
        let (av, bv) = setup(32, 32, 32, 3);
        let mut cl = Cluster::new();
        let mut l2 = FlatMem::new(L2_BASE, 4096);
        let (_, f8r) = run(&mut cl, &mut l2, &av, &bv, 32, 32, 32, FpWidth::F8x4, 8);
        let vs_f32 = f32r.stats.cycles as f64 / f8r.stats.cycles as f64;
        let vs_f16 = f16r.stats.cycles as f64 / f8r.stats.cycles as f64;
        assert!(vs_f32 > 2.0, "fp8 speedup vs f32 = {vs_f32}");
        assert!(vs_f16 > 1.2, "fp8 speedup vs f16 = {vs_f16}");
        // 4 MACs = 8 FLOPs per DotpEx issue reach the FLOP counters.
        assert!(
            f8r.stats.flops_per_cycle() > f16r.stats.flops_per_cycle(),
            "fp8 {} vs fp16 {} FLOP/cycle",
            f8r.stats.flops_per_cycle(),
            f16r.stats.flops_per_cycle()
        );
    }

    #[test]
    fn fp_intensity_near_table5() {
        // Table V: MATMUL 57% FP intensity.
        let kr = check(32, 32, 32, FpWidth::F32, 8, 1e-4);
        let fi = kr.fp_intensity();
        assert!((0.40..0.62).contains(&fi), "fp intensity = {fi}");
    }
}
