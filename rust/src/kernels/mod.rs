//! The kernel library: PULP-NN-style integer kernels and the eight FP
//! NSAA kernels of Table V, authored as ISS instruction streams through
//! the in-Rust assembler (DESIGN.md §5) and executed on the simulated
//! cluster.
//!
//! Every kernel follows the PULP SPMD model: all active cores run the
//! same program, parameterised by `core_id` / `n_cores` in registers;
//! data lives in L1 TCDM; results are read back by the host driver and
//! checked against a host-side reference.

pub mod fp_conv;
pub mod fp_fft;
pub mod fp_filters;
pub mod fp_kmeans;
pub mod fp_matmul;
pub mod fp_svm;
pub mod int_matmul;

use crate::cluster::{ClusterStats, TCDM_BASE, TCDM_SIZE};
use crate::isa::analyze::{self, AnalysisReport};
use crate::isa::{Program, Reg};

/// Simple bump allocator over the 128 kB TCDM for kernel buffers.
pub struct TcdmAlloc {
    next: u32,
}

impl TcdmAlloc {
    pub fn new() -> Self {
        Self { next: TCDM_BASE }
    }

    /// Allocate `bytes`, 16-byte aligned (SIMD-word friendly).
    pub fn alloc(&mut self, bytes: usize) -> u32 {
        let addr = (self.next + 15) & !15;
        let end = addr as usize + bytes;
        assert!(
            end <= TCDM_BASE as usize + TCDM_SIZE,
            "TCDM overflow: need {bytes} at {addr:#x}"
        );
        self.next = end as u32;
        addr
    }

    pub fn used(&self) -> usize {
        (self.next - TCDM_BASE) as usize
    }
}

impl Default for TcdmAlloc {
    fn default() -> Self {
        Self::new()
    }
}

/// Uniform result of a kernel run (feeds the figure/table generators).
#[derive(Debug, Clone, PartialEq)]
pub struct KernelRun {
    pub name: String,
    pub stats: ClusterStats,
    /// Work per run, in the paper's metric for the kernel family
    /// (int ops for integer kernels, FLOPs for FP kernels).
    pub ops: u64,
}

impl KernelRun {
    pub fn new(name: impl Into<String>, stats: ClusterStats, ops: u64) -> Self {
        Self { name: name.into(), stats, ops }
    }

    /// Ops (or FLOPs) per cluster cycle.
    pub fn ops_per_cycle(&self) -> f64 {
        if self.stats.cycles == 0 {
            return 0.0;
        }
        self.ops as f64 / self.stats.cycles as f64
    }

    /// GOPS (or GFLOPS) at frequency `f` Hz.
    pub fn gops_at(&self, f: f64) -> f64 {
        self.ops_per_cycle() * f / 1e9
    }

    /// Dynamic FP intensity of the executed stream (Table V).
    pub fn fp_intensity(&self) -> f64 {
        if self.stats.total.retired == 0 {
            return 0.0;
        }
        self.stats.total.by_class.fp as f64 / self.stats.total.retired as f64
    }
}

/// A (program, launch state) pair the static verifier can analyze
/// without running anything: exactly the program and per-core entry
/// registers the kernel driver would hand to the cluster.
///
/// Each kernel module exposes a `verify_target` constructor that
/// replicates its `run()` buffer layout (same `TcdmAlloc` calls, same
/// register file), so `vega verify` checks what actually ships.
pub struct VerifyTarget {
    pub name: String,
    pub prog: Program,
    pub n_cores: usize,
    /// Per-core launch register state (`entry[core_id]`).
    pub entry: Vec<Vec<(Reg, u32)>>,
}

impl VerifyTarget {
    /// Analyze the program under one core's entry state.
    pub fn analyze_core(&self, core: usize) -> AnalysisReport {
        analyze::analyze(&self.prog, &self.entry[core])
    }

    /// Analyze under every core's entry state (the SPMD program is one,
    /// but constant propagation sees each core's registers).
    pub fn analyze_all(&self) -> Vec<AnalysisReport> {
        (0..self.n_cores).map(|c| self.analyze_core(c)).collect()
    }

    /// Error-severity findings summed over all cores.
    pub fn error_count(&self) -> usize {
        self.analyze_all().iter().map(AnalysisReport::error_count).sum()
    }
}

/// Pack 4 i8 into the TCDM word layout used by the SIMD kernels.
pub fn pack_i8x4(v: &[i8]) -> u32 {
    debug_assert_eq!(v.len(), 4);
    (v[0] as u8 as u32)
        | ((v[1] as u8 as u32) << 8)
        | ((v[2] as u8 as u32) << 16)
        | ((v[3] as u8 as u32) << 24)
}

/// Guard for kernel shape preconditions, with a kernel-named message.
pub fn require(cond: bool, kernel: &str, what: &str) {
    assert!(cond, "{kernel}: shape constraint violated: {what}");
}

/// Shared sanity assertions on a finished program.
pub fn check_program(p: &Program) {
    assert!(!p.is_empty(), "{}: empty program", p.name);
    assert!(
        matches!(p.insts.last(), Some(crate::isa::Inst::Halt)),
        "{}: program must end in Halt",
        p.name
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tcdm_alloc_aligns_and_bounds() {
        let mut a = TcdmAlloc::new();
        let p1 = a.alloc(3);
        let p2 = a.alloc(64);
        assert_eq!(p1 % 16, 0);
        assert_eq!(p2 % 16, 0);
        assert!(p2 >= p1 + 3);
        assert_eq!(a.used() % 16, 0);
    }

    #[test]
    #[should_panic]
    fn tcdm_alloc_overflow_panics() {
        let mut a = TcdmAlloc::new();
        a.alloc(TCDM_SIZE + 1);
    }

    #[test]
    fn pack_roundtrip() {
        let w = pack_i8x4(&[1, -1, 127, -128]);
        assert_eq!(w & 0xFF, 1);
        assert_eq!((w >> 8) & 0xFF, 0xFF);
        assert_eq!((w >> 16) & 0xFF, 0x7F);
        assert_eq!(w >> 24, 0x80);
    }
}
