//! FP KMEANS (Table V row 7): the assignment step — for each point, the
//! squared-Euclidean-nearest of K centroids. Centroids live in registers
//! (K=3 × D=4), which is what pushes KMEANS to the highest FP intensity
//! of the suite (83% in Table V: almost nothing but subtract/FMA).
//!
//! FP16 packs two dimensions per word: distance accumulates via
//! `vfsub.h` + `vfdotpex.s.h` of the difference with itself.

use crate::cluster::{Cluster, ClusterStats};
use crate::isa::{Asm, Program, Reg, A2, A3, A4, A5, GP, RA, S1, S10, S11, S2, S4, S5,
    S6, S7, S8, S9, SP, T0, T1, T2, T3, T4, T5, TP};
use crate::iss::FlatMem;

use super::fp_matmul::FpWidth;
use super::{check_program, require, KernelRun, TcdmAlloc};

pub const K: usize = 3;
pub const D: usize = 4;

/// Params: a2=&points a3=&labels(out i32) a4=&centroids a5=n_points.
pub(crate) fn build_f32() -> Program {
    let name = "fp_kmeans_f32";
    // Centroid registers: 3 × 4.
    let cent: [[Reg; D]; K] = [
        [S8, S9, S10, S11],
        [RA, SP, GP, TP],
        [S1, S2, S4, S5],
    ];
    let mut a = Asm::new(name);
    let end = a.label();
    for (k, row) in cent.iter().enumerate() {
        for (d, &r) in row.iter().enumerate() {
            a.lw(r, A4, ((k * D + d) * 4) as i32);
        }
    }
    a.lp_setup(0, A5, end);
    a.lw(T3, A2, 12); // dim 3 first, then post-inc walk dims 0..2
    a.lw_pi(T0, A2, 16); // dim 0, advance to next point
    a.lw(T1, A2, 4 - 16);
    a.lw(T2, A2, 8 - 16);
    // Distances per centroid into T4; best in S6, best index in S7.
    let mut first = true;
    for (k, row) in cent.iter().enumerate() {
        // d = Σ (x_d − c_d)².
        a.fsub_s(T5, T0, row[0]);
        a.fmul_s(T4, T5, T5);
        for d in 1..D {
            a.fsub_s(T5, [T0, T1, T2, T3][d], row[d]);
            a.fmac_s(T4, T5, T5);
        }
        if first {
            a.mv(S6, T4);
            a.li(S7, 0);
            first = false;
        } else {
            // if T4 < best { best = T4; idx = k }
            let skip = a.label();
            a.flt_s(T5, T4, S6);
            a.beq(T5, 0, skip);
            a.mv(S6, T4);
            a.li(S7, k as i32);
            a.bind(skip);
        }
    }
    a.sw_pi(S7, A3, 4);
    a.bind(end);
    a.halt();
    let p = a.finish().expect("assembly");
    check_program(&p);
    p
}

/// FP16: dims packed two per word (D=4 → 2 words/point).
pub(crate) fn build_f16() -> Program {
    let name = "fp_kmeans_f16";
    let cent: [[Reg; 2]; K] = [[S8, S9], [S10, S11], [RA, SP]];
    let mut a = Asm::new(name);
    let end = a.label();
    for (k, row) in cent.iter().enumerate() {
        for (d, &r) in row.iter().enumerate() {
            a.lw(r, A4, ((k * 2 + d) * 4) as i32);
        }
    }
    a.lp_setup(0, A5, end);
    a.lw(T1, A2, 4); // dims 2,3
    a.lw_pi(T0, A2, 8); // dims 0,1; advance point
    let mut first = true;
    for (k, row) in cent.iter().enumerate() {
        a.vfsub_h(T2, T0, row[0]);
        a.vfsub_h(T3, T1, row[1]);
        a.li(T4, 0);
        a.vfdotpex_s_h(T4, T2, T2);
        a.vfdotpex_s_h(T4, T3, T3);
        if first {
            a.mv(S6, T4);
            a.li(S7, 0);
            first = false;
        } else {
            let skip = a.label();
            a.flt_s(T5, T4, S6);
            a.beq(T5, 0, skip);
            a.mv(S6, T4);
            a.li(S7, k as i32);
            a.bind(skip);
        }
    }
    a.sw_pi(S7, A3, 4);
    a.bind(end);
    a.halt();
    let p = a.finish().expect("assembly");
    check_program(&p);
    p
}

pub fn host_ref(points: &[f32], centroids: &[f32]) -> Vec<i32> {
    points
        .chunks(D)
        .map(|p| {
            let mut best = f32::INFINITY;
            let mut idx = 0;
            for k in 0..K {
                let d: f32 = (0..D).map(|i| (p[i] - centroids[k * D + i]).powi(2)).sum();
                if d < best {
                    best = d;
                    idx = k as i32;
                }
            }
            idx
        })
        .collect()
}

/// Run the assignment step over `n_points` (SPMD contiguous chunks).
pub fn run(
    cluster: &mut Cluster,
    l2: &mut FlatMem,
    points: &[f32],
    centroids: &[f32],
    fw: FpWidth,
    n_cores: usize,
) -> (Vec<i32>, KernelRun) {
    let n_points = points.len() / D;
    assert_eq!(centroids.len(), K * D);
    let chunk = n_points / n_cores;
    require(chunk >= 1, "kmeans", "points >= cores");
    require(n_points % n_cores == 0, "kmeans", "points divisible by cores");
    let prog = match fw {
        FpWidth::F32 => build_f32(),
        FpWidth::F16x2 => build_f16(),
        FpWidth::F8x4 => panic!("fp_kmeans: no fp8 variant (fp8 is matmul-only)"),
    };
    let psz = match fw {
        FpWidth::F32 => D * 4,
        FpWidth::F16x2 => D * 2,
        FpWidth::F8x4 => unreachable!("rejected above"),
    };
    let mut alloc = TcdmAlloc::new();
    let p_base = alloc.alloc(n_points * psz + 16);
    let l_base = alloc.alloc(n_points * 4);
    let c_base = alloc.alloc(K * D * 4);
    match fw {
        FpWidth::F32 => {
            cluster.tcdm.mem.write_f32s(p_base, points);
            cluster.tcdm.mem.write_f32s(c_base, centroids);
        }
        FpWidth::F16x2 => {
            cluster.tcdm.mem.write_f16s(p_base, points);
            cluster.tcdm.mem.write_f16s(c_base, centroids);
        }
        FpWidth::F8x4 => unreachable!("rejected above"),
    }
    let stats: ClusterStats = cluster.run_program(
        &prog,
        n_cores,
        l2,
        |id| {
            vec![
                (A2, p_base + (id * chunk * psz) as u32),
                (A3, l_base + (id * chunk * 4) as u32),
                (A4, c_base),
                (A5, chunk as u32),
            ]
        },
        500_000_000,
    );
    let labels = cluster.tcdm.mem.read_i32s(l_base, n_points);
    let flops = (K * (2 * D) * n_points) as u64 + (K as u64 - 1) * n_points as u64;
    (labels, KernelRun::new(prog.name.clone(), stats, flops))
}

/// Static-verification target mirroring [`run`]'s layout and registers.
pub fn verify_target(n_points: usize, fw: FpWidth, n_cores: usize) -> super::VerifyTarget {
    let chunk = n_points / n_cores;
    require(chunk >= 1, "kmeans", "points >= cores");
    require(n_points % n_cores == 0, "kmeans", "points divisible by cores");
    let prog = match fw {
        FpWidth::F32 => build_f32(),
        FpWidth::F16x2 => build_f16(),
        FpWidth::F8x4 => panic!("fp_kmeans: no fp8 variant (fp8 is matmul-only)"),
    };
    let psz = match fw {
        FpWidth::F32 => D * 4,
        FpWidth::F16x2 => D * 2,
        FpWidth::F8x4 => unreachable!("rejected above"),
    };
    let mut alloc = TcdmAlloc::new();
    let p_base = alloc.alloc(n_points * psz + 16);
    let l_base = alloc.alloc(n_points * 4);
    let c_base = alloc.alloc(K * D * 4);
    let entry = (0..n_cores)
        .map(|id| {
            vec![
                (A2, p_base + (id * chunk * psz) as u32),
                (A3, l_base + (id * chunk * 4) as u32),
                (A4, c_base),
                (A5, chunk as u32),
            ]
        })
        .collect();
    let name = prog.name.clone();
    super::VerifyTarget { name, prog, n_cores, entry }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::L2_BASE;
    use crate::common::Rng;

    fn l2m() -> FlatMem {
        FlatMem::new(L2_BASE, 4096)
    }

    fn setup(n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        // Well-separated centroids so f16 rounding can't flip labels.
        let centroids = vec![
            -2.0, -2.0, -2.0, -2.0, //
            0.0, 2.0, 0.0, 2.0, //
            2.0, -1.0, 2.0, -1.0,
        ];
        let points: Vec<f32> = (0..n)
            .flat_map(|_| {
                let k = rng.below(K as u64) as usize;
                (0..D)
                    .map(|d| centroids[k * D + d] + 0.4 * rng.f32_pm1())
                    .collect::<Vec<_>>()
            })
            .collect();
        (points, centroids)
    }

    #[test]
    fn f32_matches_host() {
        let (p, c) = setup(64, 70);
        let mut cl = Cluster::new();
        let (labels, kr) = run(&mut cl, &mut l2m(), &p, &c, FpWidth::F32, 8);
        assert_eq!(labels, host_ref(&p, &c));
        // Table V: KMEANS 83% — the suite's highest FP intensity.
        let fi = kr.fp_intensity();
        assert!(fi > 0.55, "intensity = {fi}");
    }

    #[test]
    fn f16_matches_host() {
        let (p, c) = setup(64, 71);
        let mut cl = Cluster::new();
        let (labels, _) = run(&mut cl, &mut l2m(), &p, &c, FpWidth::F16x2, 8);
        assert_eq!(labels, host_ref(&p, &c));
    }

    #[test]
    fn single_core_matches_multi() {
        let (p, c) = setup(32, 72);
        let mut cl = Cluster::new();
        let (l1, _) = run(&mut cl, &mut l2m(), &p, &c, FpWidth::F32, 1);
        let mut cl = Cluster::new();
        let (l8, _) = run(&mut cl, &mut l2m(), &p, &c, FpWidth::F32, 8);
        assert_eq!(l1, l8);
    }
}
