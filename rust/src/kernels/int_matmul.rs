//! PULP-NN-style integer matrix multiplication (§IV-B, Fig. 6).
//!
//! The inner loop is the PULP-NN signature: a 4×2 register-tiled output
//! block, operands streamed with post-incremented loads, and `pv.sdotsp`
//! SIMD dot products accumulating four (int8) or two (int16) MACs per
//! instruction into 32-bit registers. 14 instructions per K-step yield
//! 32 MACs (int8), which is what makes the measured ~15.5 MAC/cycle on 8
//! cores emerge from the cluster model.
//!
//! Layout: A row-major `(M, K)`, B **column-major** `(N, K)` (the
//! PULP-NN im2col buffer layout — both operand streams are unit-stride),
//! C row-major `(M, N)` int32.
//!
//! Register convention (SPMD; parameters placed by the driver):
//! a0=core_id a1=n_cores a2=&A a3=&B a4=&C a5=M a6=N a7=K. The kernel
//! owns the full file; ra/sp double as accumulators (leaf kernels make
//! no calls — a standard PULP-NN trick to win two registers).

use crate::cluster::{Cluster, ClusterStats};
use crate::isa::{Asm, Program, A0, A1, A2, A3, A4, A5, A6, A7, RA, S0, S1, S10, S11, S3,
    S4, S5, S6, S7, S8, S9, SP, T0, T1, T2, T3, T4, T5};
use crate::iss::FlatMem;

use super::{check_program, require, KernelRun, TcdmAlloc};

/// Operand width of the integer matmul.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum IntWidth {
    I8,
    I16,
    I32,
}

impl IntWidth {
    pub fn bytes(self) -> usize {
        match self {
            IntWidth::I8 => 1,
            IntWidth::I16 => 2,
            IntWidth::I32 => 4,
        }
    }

    /// K-elements consumed per 32-bit load.
    fn per_word(self) -> usize {
        4 / self.bytes()
    }
}

/// Build the SPMD matmul program for compile-time shape `(m, n, k)`.
pub fn build(m: usize, n: usize, k: usize, w: IntWidth) -> Program {
    build_padded(m, n, k, w, 1)
}

/// As [`build`] with an explicit row-pad word count (0 disables the
/// bank-conflict padding — the layout ablation of `vega repro ablations`).
pub fn build_padded(m: usize, n: usize, k: usize, w: IntWidth, pad_words: usize) -> Program {
    let name = format!("matmul_i{}", w.bytes() * 8);
    require(m % 4 == 0, &name, "M % 4 == 0");
    require(n % 2 == 0, &name, "N % 2 == 0");
    require(k % w.per_word() == 0, &name, "K multiple of SIMD width");
    require(k * w.bytes() % 4 == 0, &name, "row bytes word-aligned");

    let row = (k * w.bytes() + pad_words * 4) as i32; // operand row stride
    let crow = (n * 4) as i32; // C row stride in bytes
    let kiter = (k / w.per_word()) as u32;

    let mut a = Asm::new(&name);
    let done = a.label();
    let m_loop = a.label();
    let n_loop = a.label();
    let end_k = a.label();

    // Derived constants.
    a.slli(S0, A1, 2); // m stride = 4*n_cores (in rows)
    a.slli(S3, A0, 2); // m = 4*core_id

    a.bind(m_loop);
    a.bge(S3, A5, done);
    a.li(S4, 0); // n = 0

    a.bind(n_loop);
    // aptr = &A + m*row ; bptr = &B + n*row ; cptr = &C + (m*N + n)*4
    a.li(S1, row);
    a.mul(S5, S3, S1);
    a.add(S5, S5, A2);
    a.mul(S6, S4, S1);
    a.add(S6, S6, A3);
    a.mul(S7, S3, A6);
    a.add(S7, S7, S4);
    a.slli(S7, S7, 2);
    a.add(S7, S7, A4);
    // Zero the 4x2 accumulator tile.
    for r in [A0, A1, S8, S9, S10, S11, RA, SP] {
        a.li(r, 0);
    }

    // Inner K loop: 6 loads + 8 MAC ops = 14 instructions.
    a.lp_setup_imm(0, kiter, end_k);
    a.lw_pi(T0, S5, 4); // a row 0 (post-inc)
    a.lw(T1, S5, row - 4); // a row 1 (S5 already advanced by 4)
    a.lw(T2, S5, 2 * row - 4); // a row 2
    a.lw(T3, S5, 3 * row - 4); // a row 3
    a.lw_pi(T4, S6, 4); // b col 0 (post-inc)
    a.lw(T5, S6, row - 4); // b col 1
    match w {
        IntWidth::I8 => {
            a.sdotsp_b(A0, T0, T4);
            a.sdotsp_b(A1, T0, T5);
            a.sdotsp_b(S8, T1, T4);
            a.sdotsp_b(S9, T1, T5);
            a.sdotsp_b(S10, T2, T4);
            a.sdotsp_b(S11, T2, T5);
            a.sdotsp_b(RA, T3, T4);
            a.sdotsp_b(SP, T3, T5);
        }
        IntWidth::I16 => {
            a.sdotsp_h(A0, T0, T4);
            a.sdotsp_h(A1, T0, T5);
            a.sdotsp_h(S8, T1, T4);
            a.sdotsp_h(S9, T1, T5);
            a.sdotsp_h(S10, T2, T4);
            a.sdotsp_h(S11, T2, T5);
            a.sdotsp_h(RA, T3, T4);
            a.sdotsp_h(SP, T3, T5);
        }
        IntWidth::I32 => {
            a.mac(A0, T0, T4);
            a.mac(A1, T0, T5);
            a.mac(S8, T1, T4);
            a.mac(S9, T1, T5);
            a.mac(S10, T2, T4);
            a.mac(S11, T2, T5);
            a.mac(RA, T3, T4);
            a.mac(SP, T3, T5);
        }
    }
    a.bind(end_k);

    // Store the tile (offsets constant at build time).
    a.sw(A0, S7, 0);
    a.sw(A1, S7, 4);
    a.sw(S8, S7, crow);
    a.sw(S9, S7, crow + 4);
    a.sw(S10, S7, 2 * crow);
    a.sw(S11, S7, 2 * crow + 4);
    a.sw(RA, S7, 3 * crow);
    a.sw(SP, S7, 3 * crow + 4);

    a.addi(S4, S4, 2);
    a.blt(S4, A6, n_loop);
    a.add(S3, S3, S0);
    a.j(m_loop);
    a.bind(done);
    a.halt();

    let p = a.finish().expect("assembly");
    check_program(&p);
    p
}

/// Host reference: plain i64 accumulation truncated to i32.
pub fn host_ref(av: &[i32], bv: &[i32], m: usize, n: usize, k: usize) -> Vec<i32> {
    let mut c = vec![0i32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0i64;
            for kk in 0..k {
                acc += av[i * k + kk] as i64 * bv[j * k + kk] as i64; // B col-major
            }
            c[i * n + j] = acc as i32;
        }
    }
    c
}

/// Write an operand matrix into TCDM in the kernel layout (row stride
/// padded by one word, see module docs).
fn write_operand(
    mem: &mut FlatMem,
    base: u32,
    vals: &[i32],
    rows: usize,
    k: usize,
    w: IntWidth,
    pad_words: usize,
) {
    let stride = (k * w.bytes() + pad_words * 4) as u32;
    for r in 0..rows {
        let row = &vals[r * k..(r + 1) * k];
        let addr = base + r as u32 * stride;
        match w {
            IntWidth::I8 => {
                mem.write_i8s(addr, &row.iter().map(|&v| v as i8).collect::<Vec<_>>())
            }
            IntWidth::I16 => {
                for (i, &v) in row.iter().enumerate() {
                    mem.write_bytes(addr + (i * 2) as u32, &(v as i16).to_le_bytes());
                }
            }
            IntWidth::I32 => mem.write_i32s(addr, row),
        }
    }
}

/// Run the matmul on `n_cores` cluster cores; returns C and the run info.
///
/// `av` is row-major (M,K); `bv` is column-major (N,K). Values must fit
/// the operand width.
#[allow(clippy::too_many_arguments)]
pub fn run(
    cluster: &mut Cluster,
    l2: &mut FlatMem,
    av: &[i32],
    bv: &[i32],
    m: usize,
    n: usize,
    k: usize,
    w: IntWidth,
    n_cores: usize,
) -> (Vec<i32>, KernelRun) {
    run_padded(cluster, l2, av, bv, m, n, k, w, n_cores, 1)
}

/// As [`run`] with an explicit pad word count (layout ablation).
#[allow(clippy::too_many_arguments)]
pub fn run_padded(
    cluster: &mut Cluster,
    l2: &mut FlatMem,
    av: &[i32],
    bv: &[i32],
    m: usize,
    n: usize,
    k: usize,
    w: IntWidth,
    n_cores: usize,
    pad_words: usize,
) -> (Vec<i32>, KernelRun) {
    assert_eq!(av.len(), m * k);
    assert_eq!(bv.len(), n * k);
    let prog = build_padded(m, n, k, w, pad_words);

    let stride = k * w.bytes() + pad_words * 4;
    let mut alloc = TcdmAlloc::new();
    let a_base = alloc.alloc(m * stride);
    let b_base = alloc.alloc(n * stride);
    let c_base = alloc.alloc(m * n * 4);
    write_operand(&mut cluster.tcdm.mem, a_base, av, m, k, w, pad_words);
    write_operand(&mut cluster.tcdm.mem, b_base, bv, n, k, w, pad_words);

    let stats: ClusterStats = cluster.run_program(
        &prog,
        n_cores,
        l2,
        |id| {
            vec![
                (A0, id as u32),
                (A1, n_cores as u32),
                (A2, a_base),
                (A3, b_base),
                (A4, c_base),
                (A5, m as u32),
                (A6, n as u32),
                (A7, k as u32),
            ]
        },
        500_000_000,
    );
    let c = cluster.tcdm.mem.read_i32s(c_base, m * n);
    let ops = 2 * (m * n * k) as u64;
    let name = format!("matmul_i{}", w.bytes() * 8);
    (c, KernelRun::new(name, stats, ops))
}

/// Static-verification target: the same program and per-core launch
/// registers [`run`] uses (pad 1), with no data or simulation.
pub fn verify_target(
    m: usize,
    n: usize,
    k: usize,
    w: IntWidth,
    n_cores: usize,
) -> super::VerifyTarget {
    let prog = build(m, n, k, w);
    let stride = k * w.bytes() + 4;
    let mut alloc = TcdmAlloc::new();
    let a_base = alloc.alloc(m * stride);
    let b_base = alloc.alloc(n * stride);
    let c_base = alloc.alloc(m * n * 4);
    let entry = (0..n_cores)
        .map(|id| {
            vec![
                (A0, id as u32),
                (A1, n_cores as u32),
                (A2, a_base),
                (A3, b_base),
                (A4, c_base),
                (A5, m as u32),
                (A6, n as u32),
                (A7, k as u32),
            ]
        })
        .collect();
    let name = prog.name.clone();
    super::VerifyTarget { name, prog, n_cores, entry }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::Rng;
    use crate::cluster::L2_BASE;

    fn rand_vals(rng: &mut Rng, n: usize, w: IntWidth) -> Vec<i32> {
        let (lo, hi) = match w {
            IntWidth::I8 => (-128, 127),
            IntWidth::I16 => (-2048, 2047), // keep i32 accum exact
            IntWidth::I32 => (-1000, 1000),
        };
        (0..n).map(|_| rng.range_i64(lo, hi) as i32).collect()
    }

    fn check(m: usize, n: usize, k: usize, w: IntWidth, cores: usize, seed: u64) -> KernelRun {
        let mut rng = Rng::new(seed);
        let av = rand_vals(&mut rng, m * k, w);
        let bv = rand_vals(&mut rng, n * k, w);
        let mut cl = Cluster::new();
        let mut l2 = FlatMem::new(L2_BASE, 64 * 1024);
        let (c, run) = run(&mut cl, &mut l2, &av, &bv, m, n, k, w, cores);
        assert_eq!(c, host_ref(&av, &bv, m, n, k), "{m}x{n}x{k} {w:?} c{cores}");
        run
    }

    #[test]
    fn int8_correct_across_shapes_and_cores() {
        for &(m, n, k, cores) in
            &[(4, 2, 4, 1), (8, 8, 16, 2), (16, 16, 32, 8), (32, 10, 8, 8), (4, 4, 64, 3)]
        {
            check(m, n, k, IntWidth::I8, cores, 42 + m as u64);
        }
    }

    #[test]
    fn int16_and_int32_correct() {
        check(8, 8, 16, IntWidth::I16, 8, 7);
        check(8, 8, 16, IntWidth::I32, 8, 8);
        check(16, 8, 32, IntWidth::I16, 4, 9);
    }

    #[test]
    fn int8_throughput_emerges_near_pulp_nn() {
        // Paper: PULP-NN reaches up to 15.5 MAC/cycle on 8 cores.
        let run = check(64, 64, 64, IntWidth::I8, 8, 1);
        let mpc = run.stats.mac_per_cycle();
        assert!(
            (13.0..=17.5).contains(&mpc),
            "int8 matmul: {mpc} MAC/cycle (want ~15.5)"
        );
    }

    #[test]
    fn width_scaling_matches_simd_lanes() {
        // int8 ~2x int16 ~2x int32 in MAC/cycle.
        let r8 = check(32, 32, 32, IntWidth::I8, 8, 2).stats.mac_per_cycle();
        let r16 = check(32, 32, 32, IntWidth::I16, 8, 3).stats.mac_per_cycle();
        let r32 = check(32, 32, 32, IntWidth::I32, 8, 4).stats.mac_per_cycle();
        assert!(r8 / r16 > 1.6 && r8 / r16 < 2.4, "8/16 = {}", r8 / r16);
        assert!(r16 / r32 > 1.6 && r16 / r32 < 2.4, "16/32 = {}", r16 / r32);
    }

    #[test]
    fn single_core_is_8x_slower() {
        let r1 = check(32, 32, 32, IntWidth::I8, 1, 5);
        let r8 = check(32, 32, 32, IntWidth::I8, 8, 5);
        let speedup = r1.stats.cycles as f64 / r8.stats.cycles as f64;
        assert!(speedup > 6.5, "speedup = {speedup}");
    }

    #[test]
    #[should_panic]
    fn rejects_bad_shapes() {
        build(5, 2, 4, IntWidth::I8);
    }
}
