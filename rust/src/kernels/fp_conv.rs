//! FP CONV (Table V row 2): single-channel 3×3 convolution, FP32 scalar
//! and FP16 packed-SIMD variants.
//!
//! FP32 keeps the nine taps resident in registers and software-pipelines
//! the window loads against the FMAs (no load-use stalls). FP16 computes
//! two adjacent outputs from aligned packed pairs using shifted tap packs
//! and `vfdotpex.s.h` — the "data packing and shuffling of vector
//! elements" optimisation of §IV-A. SPMD over output rows.

use crate::cluster::{Cluster, ClusterStats};
use crate::isa::{Asm, Program, A0, A1, A2, A3, A4, A5, A6, A7, GP, RA, S0, S1, S10, S11,
    S3, S4, S5, S6, S7, S8, S9, SP, T0, T1, T2, T3, T4, T5, T6, TP};
use crate::iss::softfloat::f32_to_f16;
use crate::iss::FlatMem;

use super::{check_program, require, KernelRun, TcdmAlloc};
use super::fp_matmul::FpWidth;

/// In-TCDM row stride for the padded input, in bytes (+pad word).
fn in_stride(w_padded: usize, esz: usize) -> i32 {
    (w_padded * esz + 4) as i32
}

/// Build the 3×3 FP conv for an `(h, w)` output on an `(h+2, w+2)` input.
pub fn build(h: usize, w: usize, fw: FpWidth) -> Program {
    match fw {
        FpWidth::F32 => build_f32(h, w),
        FpWidth::F16x2 => build_f16(h, w),
        FpWidth::F8x4 => panic!("fp_conv: no fp8 variant (fp8 is matmul-only)"),
    }
}

/// Register plan (f32): taps k0..k8 = S8,S9,S10,S11,RA,SP,GP,TP,S1;
/// row ptrs S5,S6,S7; out ptr S4; acc T5; window temps T0..T2.
/// Params: a0=core_id a1=n_cores a2=&in a3=&out a5=H a6=W.
fn build_f32(_h: usize, w: usize) -> Program {
    let name = "fp_conv_f32";
    let istride = in_stride(w + 2, 4);
    let taps = [S8, S9, S10, S11, RA, SP, GP, TP, S1];

    let mut a = Asm::new(name);
    let done = a.label();
    let row_loop = a.label();
    let end_c = a.label();

    // Load the 9 taps from &taps (a4) once.
    for (i, &t) in taps.iter().enumerate() {
        a.lw(t, A4, (i * 4) as i32);
    }
    // S0 = row step per core = n_cores (rows), S3 = row = core_id.
    a.mv(S0, A1);
    a.mv(S3, A0);

    a.bind(row_loop);
    a.bge(S3, A5, done);
    // Row pointers: in + row*istride (+1,+2 rows); out + row*W*4.
    a.li(T6, istride);
    a.mul(S5, S3, T6);
    a.add(S5, S5, A2);
    a.addi(S6, S5, istride);
    a.addi(S7, S6, istride);
    a.slli(S4, S3, 2);
    a.mul(S4, S4, A6);
    a.add(S4, S4, A3);

    a.lp_setup(0, A6, end_c); // W output columns
    // Row 0 of the window: start the accumulator with a multiply.
    a.lw_pi(T0, S5, 4);
    a.lw(T1, S5, 0);
    a.fmul_s(T5, T0, taps[0]);
    a.lw(T2, S5, 4);
    a.fmac_s(T5, T1, taps[1]);
    // Row 1.
    a.lw_pi(T0, S6, 4);
    a.fmac_s(T5, T2, taps[2]);
    a.lw(T1, S6, 0);
    a.fmac_s(T5, T0, taps[3]);
    a.lw(T2, S6, 4);
    a.fmac_s(T5, T1, taps[4]);
    // Row 2.
    a.lw_pi(T0, S7, 4);
    a.fmac_s(T5, T2, taps[5]);
    a.lw(T1, S7, 0);
    a.fmac_s(T5, T0, taps[6]);
    a.lw(T2, S7, 4);
    a.fmac_s(T5, T1, taps[7]);
    a.fmac_s(T5, T2, taps[8]);
    a.sw_pi(T5, S4, 4);
    a.bind(end_c);

    a.add(S3, S3, S0);
    a.j(row_loop);
    a.bind(done);
    a.halt();
    let p = a.finish().expect("assembly");
    check_program(&p);
    p
}

/// f16 variant: two outputs per iteration from aligned pairs.
///
/// For even output c (pairs P0=(x_c,x_{c+1}), P1=(x_{c+2},x_{c+3})):
///   out_even += P0·(k0,k1) + P1·(k2,0)
///   out_odd  += P0·(0,k0)  + P1·(k1,k2)
/// per row — 12 packed tap registers, 4 dotpex per row.
fn build_f16(_h: usize, w: usize) -> Program {
    let name = "fp_conv_f16";
    require(w % 2 == 0, name, "W % 2 == 0 (pairs)");
    let istride = in_stride(w + 2, 2);
    // Packed taps per row r: [ (k0,k1), (k2,0), (0,k0), (k1,k2) ].
    let taps: [[crate::isa::Reg; 4]; 3] = [
        [S8, S9, S10, S11],
        [RA, SP, GP, TP],
        [S1, T6, A0, A1],
    ];

    let mut a = Asm::new(name);
    let done2 = a.label();
    let row_loop2 = a.label();
    let end_c2 = a.label();
    // A0/A1 are consumed as tap registers: bank core_id/n_cores first.
    a.mv(S0, A1); // step (rows)
    a.mv(S3, A0); // row = core_id
    for (r, row) in taps.iter().enumerate() {
        for (i, &t) in row.iter().enumerate() {
            a.lw(t, A4, ((r * 4 + i) * 4) as i32);
        }
    }

    a.bind(row_loop2);
    a.bge(S3, A5, done2);
    a.li(T5, istride);
    a.mul(S5, S3, T5);
    a.add(S5, S5, A2);
    a.addi(S6, S5, istride);
    a.addi(S7, S6, istride);
    a.slli(S4, S3, 1); // out f16: row*W*2 bytes
    a.mul(S4, S4, A6);
    a.add(S4, S4, A3);

    a.srli(T5, A6, 1);
    a.lp_setup(0, T5, end_c2); // W/2 iterations
    // acc_even = T3 (f32), acc_odd = T4 (f32); +0.0 has all-zero bits, so
    // `li 0` initialises the dotpex accumulators.
    a.lw_pi(T0, S5, 4); // P0 row0 (advance one pair)
    a.lw(T1, S5, 0); // P1 row0
    a.li(T3, 0);
    a.li(T4, 0);
    a.vfdotpex_s_h(T3, T0, taps[0][0]);
    a.vfdotpex_s_h(T3, T1, taps[0][1]);
    a.vfdotpex_s_h(T4, T0, taps[0][2]);
    a.vfdotpex_s_h(T4, T1, taps[0][3]);
    a.lw_pi(T0, S6, 4);
    a.lw(T1, S6, 0);
    a.vfdotpex_s_h(T3, T0, taps[1][0]);
    a.vfdotpex_s_h(T3, T1, taps[1][1]);
    a.vfdotpex_s_h(T4, T0, taps[1][2]);
    a.vfdotpex_s_h(T4, T1, taps[1][3]);
    a.lw_pi(T0, S7, 4);
    a.lw(T1, S7, 0);
    a.vfdotpex_s_h(T3, T0, taps[2][0]);
    a.vfdotpex_s_h(T3, T1, taps[2][1]);
    a.vfdotpex_s_h(T4, T0, taps[2][2]);
    a.vfdotpex_s_h(T4, T1, taps[2][3]);
    // Pack the two f32 results to f16 pair and store.
    a.vfcpka_h_s(T3, T3, T4);
    a.sw_pi(T3, S4, 4);
    a.bind(end_c2);

    a.add(S3, S3, S0);
    a.j(row_loop2);
    a.bind(done2);
    a.halt();
    let p = a.finish().expect("assembly");
    check_program(&p);
    p
}

/// Host reference: valid 3×3 conv, f32.
pub fn host_ref(x: &[f32], k: &[f32], h: usize, w: usize) -> Vec<f32> {
    let wp = w + 2;
    let mut out = vec![0f32; h * w];
    for r in 0..h {
        for c in 0..w {
            let mut acc = 0f32;
            for dy in 0..3 {
                for dx in 0..3 {
                    acc += x[(r + dy) * wp + c + dx] * k[dy * 3 + dx];
                }
            }
            out[r * w + c] = acc;
        }
    }
    out
}

/// Run the conv; input `x` is `(h+2, w+2)` pre-padded, `k` is 9 taps.
pub fn run(
    cluster: &mut Cluster,
    l2: &mut FlatMem,
    x: &[f32],
    k: &[f32],
    h: usize,
    w: usize,
    fw: FpWidth,
    n_cores: usize,
) -> (Vec<f32>, KernelRun) {
    assert_eq!(x.len(), (h + 2) * (w + 2));
    assert_eq!(k.len(), 9);
    let prog = build(h, w, fw);
    let esz = match fw {
        FpWidth::F32 => 4,
        FpWidth::F16x2 => 2,
        FpWidth::F8x4 => unreachable!("rejected by build()"),
    };
    let istride = in_stride(w + 2, esz) as usize;
    let mut alloc = TcdmAlloc::new();
    let in_base = alloc.alloc((h + 2) * istride);
    let out_base = alloc.alloc(h * w * 4);
    let tap_base = alloc.alloc(16 * 4);

    for r in 0..h + 2 {
        let row = &x[r * (w + 2)..(r + 1) * (w + 2)];
        let addr = in_base + (r * istride) as u32;
        match fw {
            FpWidth::F32 => cluster.tcdm.mem.write_f32s(addr, row),
            FpWidth::F16x2 => cluster.tcdm.mem.write_f16s(addr, row),
            FpWidth::F8x4 => unreachable!("rejected by build()"),
        }
    }
    match fw {
        FpWidth::F32 => cluster.tcdm.mem.write_f32s(tap_base, k),
        FpWidth::F16x2 => {
            // Pack the shifted tap pairs per row (see build_f16 docs).
            let pack = |a: f32, b: f32| -> i32 {
                ((f32_to_f16(b) as u32) << 16 | f32_to_f16(a) as u32) as i32
            };
            let mut words = Vec::new();
            for r in 0..3 {
                let (k0, k1, k2) = (k[r * 3], k[r * 3 + 1], k[r * 3 + 2]);
                words.push(pack(k0, k1));
                words.push(pack(k2, 0.0));
                words.push(pack(0.0, k0));
                words.push(pack(k1, k2));
            }
            cluster.tcdm.mem.write_i32s(tap_base, &words);
        }
        FpWidth::F8x4 => unreachable!("rejected by build()"),
    }

    let stats: ClusterStats = cluster.run_program(
        &prog,
        n_cores,
        l2,
        |id| {
            vec![
                (A0, id as u32),
                (A1, n_cores as u32),
                (A2, in_base),
                (A3, out_base),
                (A4, tap_base),
                (A5, h as u32),
                (A6, w as u32),
                (A7, 0),
            ]
        },
        500_000_000,
    );
    let out = match fw {
        FpWidth::F32 => cluster.tcdm.mem.read_f32s(out_base, h * w),
        FpWidth::F16x2 => cluster.tcdm.mem.read_f16s(out_base, h * w),
        FpWidth::F8x4 => unreachable!("rejected by build()"),
    };
    let flops = 2 * 9 * (h * w) as u64;
    (out, KernelRun::new(prog.name.clone(), stats, flops))
}

/// Static-verification target mirroring [`run`]'s layout and registers.
pub fn verify_target(h: usize, w: usize, fw: FpWidth, n_cores: usize) -> super::VerifyTarget {
    let prog = build(h, w, fw);
    let esz = match fw {
        FpWidth::F32 => 4,
        FpWidth::F16x2 => 2,
        FpWidth::F8x4 => unreachable!("rejected by build()"),
    };
    let istride = in_stride(w + 2, esz) as usize;
    let mut alloc = TcdmAlloc::new();
    let in_base = alloc.alloc((h + 2) * istride);
    let out_base = alloc.alloc(h * w * 4);
    let tap_base = alloc.alloc(16 * 4);
    let entry = (0..n_cores)
        .map(|id| {
            vec![
                (A0, id as u32),
                (A1, n_cores as u32),
                (A2, in_base),
                (A3, out_base),
                (A4, tap_base),
                (A5, h as u32),
                (A6, w as u32),
                (A7, 0),
            ]
        })
        .collect();
    let name = prog.name.clone();
    super::VerifyTarget { name, prog, n_cores, entry }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::L2_BASE;
    use crate::common::Rng;

    fn check(h: usize, w: usize, fw: FpWidth, cores: usize, tol: f32) -> KernelRun {
        let mut rng = Rng::new(17);
        let x: Vec<f32> = (0..(h + 2) * (w + 2)).map(|_| rng.f32_pm1()).collect();
        let k: Vec<f32> = (0..9).map(|_| rng.f32_pm1()).collect();
        let mut cl = Cluster::new();
        let mut l2 = FlatMem::new(L2_BASE, 4096);
        let (out, kr) = run(&mut cl, &mut l2, &x, &k, h, w, fw, cores);
        let want = host_ref(&x, &k, h, w);
        for (i, (&g, &r)) in out.iter().zip(&want).enumerate() {
            assert!((g - r).abs() <= tol * r.abs().max(1.0), "{fw:?} {i}: {g} vs {r}");
        }
        kr
    }

    #[test]
    fn f32_matches_host() {
        check(4, 6, FpWidth::F32, 1, 1e-5);
        check(8, 16, FpWidth::F32, 8, 1e-5);
        check(5, 10, FpWidth::F32, 3, 1e-5);
    }

    #[test]
    fn f16_matches_host_to_half_precision() {
        check(8, 16, FpWidth::F16x2, 8, 4e-2);
        check(4, 8, FpWidth::F16x2, 2, 4e-2);
    }

    #[test]
    fn f16_is_faster_than_f32() {
        let f32r = check(16, 32, FpWidth::F32, 8, 1e-4);
        let f16r = check(16, 32, FpWidth::F16x2, 8, 5e-2);
        let speedup = f32r.stats.cycles as f64 / f16r.stats.cycles as f64;
        assert!(speedup > 1.2, "speedup = {speedup}");
    }

    #[test]
    fn fp_intensity_near_table5() {
        // Table V: CONV 55%.
        let kr = check(16, 32, FpWidth::F32, 8, 1e-4);
        let fi = kr.fp_intensity();
        assert!((0.40..0.62).contains(&fi), "intensity = {fi}");
    }
}
