//! FP FIR, IIR and DWT kernels (Table V rows 3, 5, 6).
//!
//! * **FIR**: 8 taps resident in registers, 4-output unrolling so each
//!   loaded sample feeds up to four accumulators (the register-reuse that
//!   gives FIR its high FP intensity in Table V). FP16 variant: packed
//!   sample pairs with shifted packed-tap `vfdotpex`.
//! * **IIR**: cascade of two direct-form-II-transposed biquads, states
//!   and coefficients in registers, one sample per trip. SPMD over
//!   independent channels.
//! * **DWT**: Haar analysis (scaled lifting), multi-level; SPMD over
//!   segments at each level.

use crate::cluster::{Cluster, ClusterStats};
use crate::isa::{Asm, Program, Reg, A2, A3, A4, A5, A6, A7, GP, RA, S1, S10, S11,
    S2, S4, S5, S6, S7, S8, S9, SP, T0, T1, T2, T3, T4, T5, T6, TP};
use crate::iss::softfloat::f32_to_f16;
use crate::iss::FlatMem;

use super::fp_matmul::FpWidth;
use super::{check_program, require, KernelRun, TcdmAlloc};

pub const FIR_TAPS: usize = 8;

// ------------------------------------------------------------------ FIR

/// FP32 FIR: y[j] = Σ_i x[j+i]·t_i, 4 outputs per iteration.
/// Params: a2=&x a3=&y a4=&taps a5=n_outputs (per core chunk handled by
/// driver-set pointers; SPMD over contiguous chunks).
pub(crate) fn build_fir_f32() -> Program {
    let name = "fp_fir_f32";
    let taps: [Reg; FIR_TAPS] = [S8, S9, S10, S11, RA, SP, GP, TP];
    let accs = [S4, S5, S6, S7];
    let mut a = Asm::new(name);
    let end = a.label();
    for (i, &t) in taps.iter().enumerate() {
        a.lw(t, A4, (i * 4) as i32);
    }
    a.srli(T6, A5, 2); // n/4 iterations
    a.lp_setup(0, T6, end);
    for &acc in &accs {
        a.li(acc, 0);
    }
    // 11 loads cover x[j .. j+10]; sample x[j+i] feeds acc_k with tap
    // t_{i-k} when 0 <= i-k < 8. Rotate through T0..T2 as load targets,
    // scheduling each load ≥2 before first use.
    let xreg = |i: usize| [T0, T1, T2][i % 3];
    for i in 0..(4 + FIR_TAPS - 1) {
        if i < 4 {
            a.lw_pi(xreg(i), A2, 4); // advance the stream by one sample
        } else {
            a.lw(xreg(i), A2, ((i - 4) * 4) as i32);
        }
        // Consume sample i-1 (loaded last iteration) to hide load-use.
        if i >= 1 {
            let s = i - 1;
            for (k, &acc) in accs.iter().enumerate() {
                if s >= k && s - k < FIR_TAPS {
                    a.fmac_s(acc, xreg(s), taps[s - k]);
                }
            }
        }
    }
    // Last sample.
    let s = 4 + FIR_TAPS - 2;
    for (k, &acc) in accs.iter().enumerate() {
        if s >= k && s - k < FIR_TAPS {
            a.fmac_s(acc, xreg(s), taps[s - k]);
        }
    }
    for &acc in &accs {
        a.sw_pi(acc, A3, 4);
    }
    a.bind(end);
    a.halt();
    let p = a.finish().expect("assembly");
    check_program(&p);
    p
}

/// FP16 FIR: even/odd output pair per iteration from 5 packed loads and
/// 9 `vfdotpex` with shifted tap packs:
///   even: P0·(t0,t1) P1·(t2,t3) P2·(t4,t5) P3·(t6,t7)
///   odd:  P0·(0,t0)  P1·(t1,t2) P2·(t3,t4) P3·(t5,t6) P4·(t7,0)
pub(crate) fn build_fir_f16() -> Program {
    let name = "fp_fir_f16";
    let even_t: [Reg; 4] = [S8, S9, S10, S11];
    let odd_t: [Reg; 5] = [RA, SP, GP, TP, S1];
    let mut a = Asm::new(name);
    let end = a.label();
    for (i, &t) in even_t.iter().enumerate() {
        a.lw(t, A4, (i * 4) as i32);
    }
    for (i, &t) in odd_t.iter().enumerate() {
        a.lw(t, A4, ((4 + i) * 4) as i32);
    }
    a.srli(T6, A5, 1); // n/2 iterations
    a.lp_setup(0, T6, end);
    a.li(S4, 0); // even acc (f32)
    a.li(S5, 0); // odd acc
    a.lw_pi(T0, A2, 4); // P0, advance one pair
    a.lw(T1, A2, 0); // P1
    a.lw(T2, A2, 4); // P2
    a.lw(T3, A2, 8); // P3
    a.lw(T4, A2, 12); // P4
    a.vfdotpex_s_h(S4, T0, even_t[0]);
    a.vfdotpex_s_h(S5, T0, odd_t[0]);
    a.vfdotpex_s_h(S4, T1, even_t[1]);
    a.vfdotpex_s_h(S5, T1, odd_t[1]);
    a.vfdotpex_s_h(S4, T2, even_t[2]);
    a.vfdotpex_s_h(S5, T2, odd_t[2]);
    a.vfdotpex_s_h(S4, T3, even_t[3]);
    a.vfdotpex_s_h(S5, T3, odd_t[3]);
    a.vfdotpex_s_h(S5, T4, odd_t[4]);
    a.vfcpka_h_s(S4, S4, S5);
    a.sw_pi(S4, A3, 4);
    a.bind(end);
    a.halt();
    let p = a.finish().expect("assembly");
    check_program(&p);
    p
}

pub fn fir_host_ref(x: &[f32], taps: &[f32], n_out: usize) -> Vec<f32> {
    (0..n_out)
        .map(|j| (0..FIR_TAPS).map(|i| x[j + i] * taps[i]).sum())
        .collect()
}

/// Run the FIR over `n_out` outputs, SPMD chunks of `n_out / n_cores`.
pub fn run_fir(
    cluster: &mut Cluster,
    l2: &mut FlatMem,
    x: &[f32],
    taps: &[f32],
    n_out: usize,
    fw: FpWidth,
    n_cores: usize,
) -> (Vec<f32>, KernelRun) {
    assert_eq!(taps.len(), FIR_TAPS);
    assert!(x.len() >= n_out + FIR_TAPS - 1 + 3);
    let chunk = n_out / n_cores;
    require(chunk % 4 == 0, "fir", "chunk % 4 == 0");
    let prog = match fw {
        FpWidth::F32 => build_fir_f32(),
        FpWidth::F16x2 => build_fir_f16(),
        FpWidth::F8x4 => panic!("fir: no fp8 variant (fp8 is matmul-only)"),
    };
    let esz = if fw == FpWidth::F32 { 4 } else { 2 };
    let mut alloc = TcdmAlloc::new();
    let x_base = alloc.alloc(x.len() * esz + 16);
    let y_base = alloc.alloc(n_out * esz + 16);
    let tap_base = alloc.alloc(16 * 4);
    match fw {
        FpWidth::F32 => {
            cluster.tcdm.mem.write_f32s(x_base, x);
            cluster.tcdm.mem.write_f32s(tap_base, taps);
        }
        FpWidth::F16x2 => {
            cluster.tcdm.mem.write_f16s(x_base, x);
            let pack = |a: f32, b: f32| -> i32 {
                ((f32_to_f16(b) as u32) << 16 | f32_to_f16(a) as u32) as i32
            };
            let t = taps;
            let words = vec![
                pack(t[0], t[1]),
                pack(t[2], t[3]),
                pack(t[4], t[5]),
                pack(t[6], t[7]),
                pack(0.0, t[0]),
                pack(t[1], t[2]),
                pack(t[3], t[4]),
                pack(t[5], t[6]),
                pack(t[7], 0.0),
            ];
            cluster.tcdm.mem.write_i32s(tap_base, &words);
        }
        FpWidth::F8x4 => unreachable!("rejected above"),
    }
    let stats: ClusterStats = cluster.run_program(
        &prog,
        n_cores,
        l2,
        |id| {
            let off = (id * chunk * esz) as u32;
            vec![
                (A2, x_base + off),
                (A3, y_base + off),
                (A4, tap_base),
                (A5, chunk as u32),
            ]
        },
        500_000_000,
    );
    let y = match fw {
        FpWidth::F32 => cluster.tcdm.mem.read_f32s(y_base, n_out),
        FpWidth::F16x2 => cluster.tcdm.mem.read_f16s(y_base, n_out),
        FpWidth::F8x4 => unreachable!("rejected above"),
    };
    let flops = 2 * (FIR_TAPS * n_out) as u64;
    (y, KernelRun::new(prog.name.clone(), stats, flops))
}

// ------------------------------------------------------------------ IIR

/// Biquad coefficients (direct form II transposed):
/// y = b0·x + d1 ; d1' = b1·x − a1·y + d2 ; d2' = b2·x − a2·y.
#[derive(Debug, Clone, Copy)]
pub struct Biquad {
    pub b0: f32,
    pub b1: f32,
    pub b2: f32,
    pub a1: f32,
    pub a2: f32,
}

impl Biquad {
    /// A gentle low-pass used by tests/benches (stable, unity-ish gain).
    pub fn lowpass() -> Self {
        Biquad { b0: 0.2, b1: 0.4, b2: 0.2, a1: -0.3, a2: 0.1 }
    }
}

/// FP32 IIR: 2-stage cascade, one sample per trip.
/// a2=&x a3=&y a4=&coeffs(10 f32) a5=n.
pub(crate) fn build_iir_f32() -> Program {
    let name = "fp_iir_f32";
    // Stage coeffs: (b0,b1,b2,a1,a2) ×2 → 10 registers.
    let c: [Reg; 10] = [S8, S9, S10, S11, RA, SP, GP, TP, S1, S2];
    let (d11, d12, d21, d22) = (S4, S5, S6, S7); // states
    let mut a = Asm::new(name);
    let end = a.label();
    for (i, &r) in c.iter().enumerate() {
        a.lw(r, A4, (i * 4) as i32);
    }
    for r in [d11, d12, d21, d22] {
        a.li(r, 0);
    }
    a.lp_setup(0, A5, end);
    a.lw_pi(T0, A2, 4); // x
    // Stage 1: y1 = b0·x + d1.
    a.mv(T1, d11);
    a.fmac_s(T1, c[0], T0);
    // d1 = d2 + b1·x − a1·y1.
    a.mv(d11, d12);
    a.fmac_s(d11, c[1], T0);
    a.fmsu_s(d11, c[3], T1);
    // d2 = b2·x − a2·y1.
    a.fmul_s(d12, c[2], T0);
    a.fmsu_s(d12, c[4], T1);
    // Stage 2 on y1.
    a.mv(T2, d21);
    a.fmac_s(T2, c[5], T1);
    a.mv(d21, d22);
    a.fmac_s(d21, c[6], T1);
    a.fmsu_s(d21, c[8], T2);
    a.fmul_s(d22, c[7], T1);
    a.fmsu_s(d22, c[9], T2);
    a.sw_pi(T2, A3, 4);
    a.bind(end);
    a.halt();
    let p = a.finish().expect("assembly");
    check_program(&p);
    p
}

/// FP16 IIR: identical structure on packed lanes — each core filters two
/// interleaved channels at once (`vfmac`/packed states).
pub(crate) fn build_iir_f16() -> Program {
    let name = "fp_iir_f16";
    let c: [Reg; 10] = [S8, S9, S10, S11, RA, SP, GP, TP, S1, S2];
    let (d11, d12, d21, d22) = (S4, S5, S6, S7);
    let mut a = Asm::new(name);
    let end = a.label();
    for (i, &r) in c.iter().enumerate() {
        a.lw(r, A4, (i * 4) as i32); // packed (coef, coef) pairs
    }
    for r in [d11, d12, d21, d22] {
        a.li(r, 0);
    }
    a.lp_setup(0, A5, end);
    a.lw_pi(T0, A2, 4); // packed pair: (ch0[t], ch1[t])
    a.mv(T1, d11);
    a.vfmac_h(T1, c[0], T0);
    a.mv(d11, d12);
    a.vfmac_h(d11, c[1], T0);
    // packed msub: d -= a1*y  ==  d = d + (-a1)*y with negated coeff pack.
    a.vfmac_h(d11, c[3], T1);
    a.vfmul_h(d12, c[2], T0);
    a.vfmac_h(d12, c[4], T1);
    a.mv(T2, d21);
    a.vfmac_h(T2, c[5], T1);
    a.mv(d21, d22);
    a.vfmac_h(d21, c[6], T1);
    a.vfmac_h(d21, c[8], T2);
    a.vfmul_h(d22, c[7], T1);
    a.vfmac_h(d22, c[9], T2);
    a.sw_pi(T2, A3, 4);
    a.bind(end);
    a.halt();
    let p = a.finish().expect("assembly");
    check_program(&p);
    p
}

pub fn iir_host_ref(x: &[f32], s1: Biquad, s2: Biquad) -> Vec<f32> {
    let mut out = Vec::with_capacity(x.len());
    let (mut d11, mut d12, mut d21, mut d22) = (0f32, 0f32, 0f32, 0f32);
    for &xv in x {
        let y1 = s1.b0.mul_add(xv, d11);
        d11 = d12 + s1.b1 * xv - s1.a1 * y1;
        d12 = s1.b2 * xv - s1.a2 * y1;
        let y2 = s2.b0.mul_add(y1, d21);
        d21 = d22 + s2.b1 * y1 - s2.a1 * y2;
        d22 = s2.b2 * y1 - s2.a2 * y2;
        out.push(y2);
    }
    out
}

/// Run the IIR cascade; each core filters its own channel (f32) or two
/// packed channels (f16). `x` holds `channels = n_cores (×2 for f16)`
/// equal-length signals, channel-major.
pub fn run_iir(
    cluster: &mut Cluster,
    l2: &mut FlatMem,
    x: &[Vec<f32>],
    s1: Biquad,
    s2: Biquad,
    fw: FpWidth,
) -> (Vec<Vec<f32>>, KernelRun) {
    let n = x[0].len();
    assert!(x.iter().all(|c| c.len() == n));
    let prog = match fw {
        FpWidth::F32 => build_iir_f32(),
        FpWidth::F16x2 => build_iir_f16(),
        FpWidth::F8x4 => panic!("iir: no fp8 variant (fp8 is matmul-only)"),
    };
    let lanes = if fw == FpWidth::F32 { 1 } else { 2 };
    let n_cores = x.len() / lanes;
    assert!(n_cores >= 1 && n_cores <= 8);
    let mut alloc = TcdmAlloc::new();
    let per = n * 4; // both layouts use one 32-bit word per sample slot
    let x_base = alloc.alloc(x.len() * per);
    let y_base = alloc.alloc(x.len() * per);
    let c_base = alloc.alloc(10 * 4);
    match fw {
        FpWidth::F32 => {
            for (c, sig) in x.iter().enumerate() {
                cluster.tcdm.mem.write_f32s(x_base + (c * per) as u32, sig);
            }
            let coeffs = [s1.b0, s1.b1, s1.b2, s1.a1, s1.a2, s2.b0, s2.b1, s2.b2, s2.a1, s2.a2];
            cluster.tcdm.mem.write_f32s(c_base, &coeffs);
        }
        FpWidth::F16x2 => {
            // Interleave channel pairs: word t = (ch0[t], ch1[t]).
            for pair in 0..n_cores {
                let (c0, c1) = (&x[2 * pair], &x[2 * pair + 1]);
                let mut inter = Vec::with_capacity(2 * n);
                for t in 0..n {
                    inter.push(c0[t]);
                    inter.push(c1[t]);
                }
                cluster.tcdm.mem.write_f16s(x_base + (pair * per) as u32, &inter);
            }
            // Packed duplicated coefficients; a1/a2 negated (vfmac-only
            // datapath, see build_iir_f16).
            let pk = |v: f32| -> i32 {
                let h = f32_to_f16(v) as u32;
                ((h << 16) | h) as i32
            };
            let words = [
                pk(s1.b0), pk(s1.b1), pk(s1.b2), pk(-s1.a1), pk(-s1.a2),
                pk(s2.b0), pk(s2.b1), pk(s2.b2), pk(-s2.a1), pk(-s2.a2),
            ];
            cluster.tcdm.mem.write_i32s(c_base, &words);
        }
        FpWidth::F8x4 => unreachable!("rejected above"),
    }
    let stats = cluster.run_program(
        &prog,
        n_cores,
        l2,
        |id| {
            let off = (id * per) as u32;
            vec![(A2, x_base + off), (A3, y_base + off), (A4, c_base), (A5, n as u32)]
        },
        500_000_000,
    );
    let mut out = Vec::new();
    match fw {
        FpWidth::F32 => {
            for c in 0..x.len() {
                out.push(cluster.tcdm.mem.read_f32s(y_base + (c * per) as u32, n));
            }
        }
        FpWidth::F16x2 => {
            for pair in 0..n_cores {
                let inter = cluster.tcdm.mem.read_f16s(y_base + (pair * per) as u32, 2 * n);
                out.push(inter.iter().step_by(2).copied().collect());
                out.push(inter.iter().skip(1).step_by(2).copied().collect());
            }
        }
        FpWidth::F8x4 => unreachable!("rejected above"),
    }
    let flops = (10 * n * x.len()) as u64 * if lanes == 2 { 1 } else { 1 };
    (out, KernelRun::new(prog.name.clone(), stats, flops))
}

// ------------------------------------------------------------------ DWT

/// FP32 Haar DWT, one level: approx[i] = (x[2i]+x[2i+1])·c,
/// detail[i] = (x[2i]−x[2i+1])·c with c = 1/√2.
/// a2=&x a3=&approx a4=&detail a5=n_pairs a6=c (f32 bits).
pub(crate) fn build_dwt_f32() -> Program {
    let name = "fp_dwt_f32";
    let mut a = Asm::new(name);
    let end = a.label();
    a.lp_setup(0, A5, end);
    a.lw_pi(T0, A2, 4);
    a.lw_pi(T1, A2, 4);
    a.fadd_s(T2, T0, T1);
    a.fsub_s(T3, T0, T1);
    a.fmul_s(T2, T2, A6);
    a.fmul_s(T3, T3, A6);
    a.sw_pi(T2, A3, 4);
    a.sw_pi(T3, A4, 4);
    a.bind(end);
    a.halt();
    let p = a.finish().expect("assembly");
    check_program(&p);
    p
}

/// FP16 Haar DWT: one packed load per pair; sum/difference emerge as
/// `vfdotpex` against constant packs (c, c) and (c, −c); two results are
/// re-packed per two pairs.
pub(crate) fn build_dwt_f16() -> Program {
    let name = "fp_dwt_f16";
    let mut a = Asm::new(name);
    let end = a.label();
    // A6 = pack(c, c), A7 = pack(c, -c).
    a.srli(T6, A5, 1); // pairs/2 iterations (process 2 pairs)
    a.lp_setup(0, T6, end);
    a.lw_pi(T0, A2, 4); // pair 0
    a.lw_pi(T1, A2, 4); // pair 1
    a.li(T2, 0);
    a.li(T3, 0);
    a.li(T4, 0);
    a.li(T5, 0);
    a.vfdotpex_s_h(T2, T0, A6); // approx0
    a.vfdotpex_s_h(T3, T0, A7); // detail0
    a.vfdotpex_s_h(T4, T1, A6); // approx1
    a.vfdotpex_s_h(T5, T1, A7); // detail1
    a.vfcpka_h_s(T2, T2, T4);
    a.vfcpka_h_s(T3, T3, T5);
    a.sw_pi(T2, A3, 4);
    a.sw_pi(T3, A4, 4);
    a.bind(end);
    a.halt();
    let p = a.finish().expect("assembly");
    check_program(&p);
    p
}

pub fn dwt_host_ref(x: &[f32]) -> (Vec<f32>, Vec<f32>) {
    let c = std::f32::consts::FRAC_1_SQRT_2;
    let mut ap = Vec::new();
    let mut de = Vec::new();
    for p in x.chunks(2) {
        ap.push((p[0] + p[1]) * c);
        de.push((p[0] - p[1]) * c);
    }
    (ap, de)
}

/// Run one DWT level SPMD over `n_cores` contiguous chunks.
pub fn run_dwt(
    cluster: &mut Cluster,
    l2: &mut FlatMem,
    x: &[f32],
    fw: FpWidth,
    n_cores: usize,
) -> (Vec<f32>, Vec<f32>, KernelRun) {
    let n_pairs = x.len() / 2;
    let chunk = n_pairs / n_cores;
    require(chunk >= 2 && chunk % 2 == 0, "dwt", "pairs/core even and >= 2");
    let prog = match fw {
        FpWidth::F32 => build_dwt_f32(),
        FpWidth::F16x2 => build_dwt_f16(),
        FpWidth::F8x4 => panic!("dwt: no fp8 variant (fp8 is matmul-only)"),
    };
    let esz = if fw == FpWidth::F32 { 4 } else { 2 };
    let mut alloc = TcdmAlloc::new();
    let x_base = alloc.alloc(x.len() * esz + 16);
    let a_base = alloc.alloc(n_pairs * esz + 16);
    let d_base = alloc.alloc(n_pairs * esz + 16);
    let c = std::f32::consts::FRAC_1_SQRT_2;
    match fw {
        FpWidth::F32 => cluster.tcdm.mem.write_f32s(x_base, x),
        FpWidth::F16x2 => cluster.tcdm.mem.write_f16s(x_base, x),
        FpWidth::F8x4 => unreachable!("rejected above"),
    }
    let stats = cluster.run_program(
        &prog,
        n_cores,
        l2,
        |id| {
            let xo = (id * chunk * 2 * esz) as u32;
            let oo = (id * chunk * esz) as u32;
            let mut regs = vec![
                (A2, x_base + xo),
                (A3, a_base + oo),
                (A4, d_base + oo),
                (A5, chunk as u32),
            ];
            match fw {
                FpWidth::F32 => regs.push((A6, c.to_bits())),
                FpWidth::F16x2 => {
                    let h = f32_to_f16(c) as u32;
                    let hn = f32_to_f16(-c) as u32;
                    regs.push((A6, (h << 16) | h));
                    regs.push((A7, (hn << 16) | h));
                }
                FpWidth::F8x4 => unreachable!("rejected above"),
            }
            regs
        },
        500_000_000,
    );
    let (ap, de) = match fw {
        FpWidth::F32 => (
            cluster.tcdm.mem.read_f32s(a_base, n_pairs),
            cluster.tcdm.mem.read_f32s(d_base, n_pairs),
        ),
        FpWidth::F16x2 => (
            cluster.tcdm.mem.read_f16s(a_base, n_pairs),
            cluster.tcdm.mem.read_f16s(d_base, n_pairs),
        ),
        FpWidth::F8x4 => unreachable!("rejected above"),
    };
    let flops = 4 * n_pairs as u64;
    (ap, de, KernelRun::new(prog.name.clone(), stats, flops))
}

/// Static-verification target mirroring [`run_fir`]'s layout. `x_len`
/// is the driver's input length (it sizes `x_base`, so it shifts every
/// downstream buffer address).
pub fn verify_target_fir(
    x_len: usize,
    n_out: usize,
    fw: FpWidth,
    n_cores: usize,
) -> super::VerifyTarget {
    assert!(x_len >= n_out + FIR_TAPS - 1 + 3);
    let chunk = n_out / n_cores;
    require(chunk % 4 == 0, "fir", "chunk % 4 == 0");
    let prog = match fw {
        FpWidth::F32 => build_fir_f32(),
        FpWidth::F16x2 => build_fir_f16(),
        FpWidth::F8x4 => panic!("fir: no fp8 variant (fp8 is matmul-only)"),
    };
    let esz = if fw == FpWidth::F32 { 4 } else { 2 };
    let mut alloc = TcdmAlloc::new();
    let x_base = alloc.alloc(x_len * esz + 16);
    let y_base = alloc.alloc(n_out * esz + 16);
    let tap_base = alloc.alloc(16 * 4);
    let entry = (0..n_cores)
        .map(|id| {
            let off = (id * chunk * esz) as u32;
            vec![(A2, x_base + off), (A3, y_base + off), (A4, tap_base), (A5, chunk as u32)]
        })
        .collect();
    let name = prog.name.clone();
    super::VerifyTarget { name, prog, n_cores, entry }
}

/// Static-verification target mirroring [`run_iir`]'s layout for
/// `channels` input channels of `n` samples each.
pub fn verify_target_iir(channels: usize, n: usize, fw: FpWidth) -> super::VerifyTarget {
    let prog = match fw {
        FpWidth::F32 => build_iir_f32(),
        FpWidth::F16x2 => build_iir_f16(),
        FpWidth::F8x4 => panic!("iir: no fp8 variant (fp8 is matmul-only)"),
    };
    let lanes = if fw == FpWidth::F32 { 1 } else { 2 };
    let n_cores = channels / lanes;
    assert!(n_cores >= 1 && n_cores <= 8);
    let mut alloc = TcdmAlloc::new();
    let per = n * 4;
    let x_base = alloc.alloc(channels * per);
    let y_base = alloc.alloc(channels * per);
    let c_base = alloc.alloc(10 * 4);
    let entry = (0..n_cores)
        .map(|id| {
            let off = (id * per) as u32;
            vec![(A2, x_base + off), (A3, y_base + off), (A4, c_base), (A5, n as u32)]
        })
        .collect();
    let name = prog.name.clone();
    super::VerifyTarget { name, prog, n_cores, entry }
}

/// Static-verification target mirroring [`run_dwt`]'s layout for an
/// input of `x_len` samples.
pub fn verify_target_dwt(x_len: usize, fw: FpWidth, n_cores: usize) -> super::VerifyTarget {
    let n_pairs = x_len / 2;
    let chunk = n_pairs / n_cores;
    require(chunk >= 2 && chunk % 2 == 0, "dwt", "pairs/core even and >= 2");
    let prog = match fw {
        FpWidth::F32 => build_dwt_f32(),
        FpWidth::F16x2 => build_dwt_f16(),
        FpWidth::F8x4 => panic!("dwt: no fp8 variant (fp8 is matmul-only)"),
    };
    let esz = if fw == FpWidth::F32 { 4 } else { 2 };
    let mut alloc = TcdmAlloc::new();
    let x_base = alloc.alloc(x_len * esz + 16);
    let a_base = alloc.alloc(n_pairs * esz + 16);
    let d_base = alloc.alloc(n_pairs * esz + 16);
    let c = std::f32::consts::FRAC_1_SQRT_2;
    let entry = (0..n_cores)
        .map(|id| {
            let xo = (id * chunk * 2 * esz) as u32;
            let oo = (id * chunk * esz) as u32;
            let mut regs = vec![
                (A2, x_base + xo),
                (A3, a_base + oo),
                (A4, d_base + oo),
                (A5, chunk as u32),
            ];
            match fw {
                FpWidth::F32 => regs.push((A6, c.to_bits())),
                FpWidth::F16x2 => {
                    let h = f32_to_f16(c) as u32;
                    let hn = f32_to_f16(-c) as u32;
                    regs.push((A6, (h << 16) | h));
                    regs.push((A7, (hn << 16) | h));
                }
                FpWidth::F8x4 => unreachable!("rejected above"),
            }
            regs
        })
        .collect();
    let name = prog.name.clone();
    super::VerifyTarget { name, prog, n_cores, entry }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::L2_BASE;
    use crate::common::Rng;

    fn signal(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.f32_pm1()).collect()
    }

    fn l2m() -> FlatMem {
        FlatMem::new(L2_BASE, 4096)
    }

    #[test]
    fn fir_f32_matches_host() {
        let taps: Vec<f32> = signal(FIR_TAPS, 1);
        let x = signal(256 + FIR_TAPS + 3, 2);
        let mut cl = Cluster::new();
        let (y, kr) = run_fir(&mut cl, &mut l2m(), &x, &taps, 256, FpWidth::F32, 8);
        let want = fir_host_ref(&x, &taps, 256);
        for (i, (&g, &r)) in y.iter().zip(&want).enumerate() {
            assert!((g - r).abs() < 1e-4, "{i}: {g} vs {r}");
        }
        // Table V: FIR 64% FP intensity (register-resident taps).
        let fi = kr.fp_intensity();
        assert!((0.5..0.75).contains(&fi), "intensity = {fi}");
    }

    #[test]
    fn fir_f16_matches_host() {
        let taps: Vec<f32> = signal(FIR_TAPS, 3);
        let x = signal(128 + FIR_TAPS + 5, 4);
        let mut cl = Cluster::new();
        let (y, _) = run_fir(&mut cl, &mut l2m(), &x, &taps, 128, FpWidth::F16x2, 8);
        let want = fir_host_ref(&x, &taps, 128);
        for (i, (&g, &r)) in y.iter().zip(&want).enumerate() {
            assert!((g - r).abs() < 3e-2, "{i}: {g} vs {r}");
        }
    }

    #[test]
    fn fir_f16_faster() {
        let taps: Vec<f32> = signal(FIR_TAPS, 5);
        let x = signal(512 + 16, 6);
        let mut cl = Cluster::new();
        let (_, k32) = run_fir(&mut cl, &mut l2m(), &x, &taps, 512, FpWidth::F32, 8);
        let mut cl = Cluster::new();
        let (_, k16) = run_fir(&mut cl, &mut l2m(), &x, &taps, 512, FpWidth::F16x2, 8);
        let s = k32.stats.cycles as f64 / k16.stats.cycles as f64;
        assert!(s > 1.3, "speedup = {s}");
    }

    #[test]
    fn iir_f32_matches_host() {
        let (s1, s2) = (Biquad::lowpass(), Biquad::lowpass());
        let chans: Vec<Vec<f32>> = (0..8).map(|i| signal(128, 10 + i)).collect();
        let mut cl = Cluster::new();
        let (ys, kr) = run_iir(&mut cl, &mut l2m(), &chans, s1, s2, FpWidth::F32);
        for (c, y) in ys.iter().enumerate() {
            let want = iir_host_ref(&chans[c], s1, s2);
            for (i, (&g, &r)) in y.iter().zip(&want).enumerate() {
                assert!((g - r).abs() < 1e-4, "ch{c}[{i}]: {g} vs {r}");
            }
        }
        let fi = kr.fp_intensity();
        assert!((0.35..0.70).contains(&fi), "intensity = {fi}"); // Table V: 46%
    }

    #[test]
    fn iir_f16_matches_host_loosely() {
        let (s1, s2) = (Biquad::lowpass(), Biquad::lowpass());
        let chans: Vec<Vec<f32>> = (0..4).map(|i| signal(64, 20 + i)).collect();
        let mut cl = Cluster::new();
        let (ys, _) = run_iir(&mut cl, &mut l2m(), &chans, s1, s2, FpWidth::F16x2);
        for (c, y) in ys.iter().enumerate() {
            let want = iir_host_ref(&chans[c], s1, s2);
            for (i, (&g, &r)) in y.iter().zip(&want).enumerate() {
                // f16 state recursion accumulates rounding error.
                assert!((g - r).abs() < 0.05, "ch{c}[{i}]: {g} vs {r}");
            }
        }
    }

    #[test]
    fn dwt_both_widths_match_host() {
        let x = signal(256, 30);
        let (wa, wd) = dwt_host_ref(&x);
        for (fw, tol) in [(FpWidth::F32, 1e-5f32), (FpWidth::F16x2, 2e-2)] {
            let mut cl = Cluster::new();
            let (ap, de, _) = run_dwt(&mut cl, &mut l2m(), &x, fw, 8);
            for i in 0..wa.len() {
                assert!((ap[i] - wa[i]).abs() < tol, "{fw:?} a[{i}]");
                assert!((de[i] - wd[i]).abs() < tol, "{fw:?} d[{i}]");
            }
        }
    }

    #[test]
    fn dwt_perfect_reconstruction_property() {
        // approx/detail must reconstruct the input (orthonormal Haar).
        let x = signal(64, 40);
        let mut cl = Cluster::new();
        let (ap, de, _) = run_dwt(&mut cl, &mut l2m(), &x, FpWidth::F32, 4);
        let c = std::f32::consts::FRAC_1_SQRT_2;
        for i in 0..32 {
            let x0 = (ap[i] + de[i]) * c;
            let x1 = (ap[i] - de[i]) * c;
            assert!((x0 - x[2 * i]).abs() < 1e-4);
            assert!((x1 - x[2 * i + 1]).abs() < 1e-4);
        }
    }
}
