//! FP FFT (Table V row 4): iterative radix-2 DIT complex FFT.
//!
//! The stage structure is unrolled at build time (N is a compile-time
//! parameter of the program builder), so no divisions appear on the hot
//! path. Early stages parallelise across butterfly *groups*; once groups
//! run out (late stages), cores split the butterflies *within* each group
//! with stride `n_cores` — the event-unit barrier separates stages.
//!
//! Input arrives bit-reversed (the driver permutes; on silicon this is
//! the standard int-only reorder pass). FP32 stores complex as two f32
//! words; FP16 packs one complex value per 32-bit word (re,im) and runs
//! the twiddle rotation as two `vfdotpex` against the pre-packed
//! `(wr,−wi)` / `(wi,wr)` twiddle table — cast-and-pack re-packs the
//! results (§IV-A's "intrinsics for data packing").

use crate::cluster::{Cluster, ClusterStats};
use crate::isa::{Asm, Program, A0, A1, A2, A3, S0, S3, S5, S6, S7, S8, S9, T0, T1, T2, T3,
    T4, T5, T6};
use crate::iss::softfloat::f32_to_f16;
use crate::iss::FlatMem;

use super::fp_matmul::FpWidth;
use super::{check_program, require, KernelRun, TcdmAlloc};

/// Build the FFT program for size `n` (power of two) on `n_cores`
/// (power of two) cores. Params: a0=core_id a1=n_cores a2=&x a3=&twiddles.
pub fn build(n: usize, n_cores: usize, fw: FpWidth) -> Program {
    let name = match fw {
        FpWidth::F32 => "fp_fft_f32",
        FpWidth::F16x2 => "fp_fft_f16",
        FpWidth::F8x4 => panic!("fp_fft: no fp8 variant (fp8 is matmul-only)"),
    };
    require(n.is_power_of_two() && n >= 4, name, "N power of two >= 4");
    require(n_cores.is_power_of_two(), name, "n_cores power of two");
    let csz: i32 = match fw {
        FpWidth::F32 => 8, // complex = 2 × f32
        FpWidth::F16x2 => 4, // complex = packed (re,im) f16
        FpWidth::F8x4 => unreachable!("rejected above"),
    };
    // Twiddle record: f32 = (wr, wi) 8 B; f16 = (w1, w2) packed pair 8 B.
    let tsz: i32 = 8;

    let mut a = Asm::new(name);
    a.mv(S0, A1); // n_cores

    let stages = n.trailing_zeros() as usize;
    for s in 0..stages {
        let h = 1usize << s; // half-size
        let n_groups = n / (2 * h);
        let step = n / (2 * h); // twiddle index stride

        if n_groups >= n_cores {
            // Group-parallel: my groups are core_id, core_id+P, ...
            let next_group = a.label();
            let stage_done = a.label();
            let end_bf = a.label();
            a.mv(S3, A0); // group = core_id
            a.bind(next_group);
            a.li(T6, n_groups as i32);
            a.bge(S3, T6, stage_done);
            // pa = x + group*2h*csz ; pb = pa + h*csz ; tw = twbase.
            a.li(T6, 2 * h as i32 * csz);
            a.mul(S5, S3, T6);
            a.add(S5, S5, A2);
            a.addi(S6, S5, h as i32 * csz);
            a.mv(S7, A3);
            a.lp_setup_imm(0, h as u32, end_bf);
            emit_butterfly(&mut a, fw, csz, step as i32 * tsz);
            a.bind(end_bf);
            a.add(S3, S3, S0);
            a.j(next_group);
            a.bind(stage_done);
        } else {
            // Butterfly-parallel inside each group: k = core_id,
            // core_id+P, ... When h < n_cores (small N on many cores)
            // only cores with id < h participate, one butterfly each.
            let kiter = (h / n_cores).max(1) as u32;
            for g in 0..n_groups {
                let end_bf = a.label();
                let skip = a.label();
                if h < n_cores {
                    a.li(T6, h as i32);
                    a.bge(A0, T6, skip);
                }
                let base = (g * 2 * h) as i32 * csz;
                // pa = x + base + core_id*csz.
                a.li(T6, csz);
                a.mul(S5, A0, T6);
                a.add(S5, S5, A2);
                a.addi(S5, S5, base);
                a.addi(S6, S5, h as i32 * csz);
                // tw = twbase + core_id*step*tsz.
                a.li(T6, step as i32 * tsz);
                a.mul(S7, A0, T6);
                a.add(S7, S7, A3);
                a.lp_setup_imm(0, kiter, end_bf);
                emit_butterfly_strided(
                    &mut a,
                    fw,
                    csz * n_cores as i32,
                    step as i32 * tsz * n_cores as i32,
                );
                a.bind(end_bf);
                a.bind(skip);
            }
        }
        a.barrier();
    }
    a.halt();
    let p = a.finish().expect("assembly");
    check_program(&p);
    p
}

/// One butterfly with unit stride (post-inc by element size).
fn emit_butterfly(a: &mut Asm, fw: FpWidth, csz: i32, twstride: i32) {
    emit_butterfly_strided(a, fw, csz, twstride);
}

/// Butterfly with configurable pointer strides.
fn emit_butterfly_strided(a: &mut Asm, fw: FpWidth, cstride: i32, twstride: i32) {
    match fw {
        FpWidth::F32 => {
            a.lw(T0, S5, 0); // ar
            a.lw(T1, S5, 4); // ai
            a.lw(T2, S6, 0); // br
            a.lw(T3, S6, 4); // bi
            a.lw_pi(T4, S7, twstride); // wr (advance twiddle ptr)
            a.lw(T5, S7, 4 - twstride); // wi
            // t = w·b (complex).
            a.fmul_s(S8, T4, T2);
            a.fmsu_s(S8, T5, T3); // tr = wr·br − wi·bi
            a.fmul_s(S9, T4, T3);
            a.fmac_s(S9, T5, T2); // ti = wr·bi + wi·br
            // a' = a + t ; b' = a − t.
            a.fadd_s(T4, T0, S8);
            a.sw(T4, S5, 0); // a'r
            a.fsub_s(T5, T0, S8);
            a.sw(T5, S6, 0); // b'r
            a.fadd_s(T4, T1, S9);
            a.sw(T4, S5, 4); // a'i
            a.fsub_s(T5, T1, S9);
            a.sw(T5, S6, 4); // b'i
            a.addi(S5, S5, cstride);
            a.addi(S6, S6, cstride);
        }
        FpWidth::F16x2 => {
            a.lw(T0, S5, 0); // a packed
            a.lw(T1, S6, 0); // b packed
            a.lw_pi(T2, S7, twstride); // w1 = (wr, −wi)
            a.lw(T3, S7, 4 - twstride); // w2 = (wi, wr)
            a.li(S8, 0);
            a.li(S9, 0);
            a.vfdotpex_s_h(S8, T2, T1); // tr = wr·br − wi·bi (f32)
            a.vfdotpex_s_h(S9, T3, T1); // ti = wi·br + wr·bi (f32)
            a.vfcpka_h_s(T4, S8, S9); // t packed
            a.vfadd_h(T5, T0, T4);
            a.vfsub_h(T6, T0, T4);
            a.sw_pi(T5, S5, cstride);
            a.sw_pi(T6, S6, cstride);
        }
        FpWidth::F8x4 => unreachable!("rejected by build()"),
    }
}

/// Host reference FFT (f64 precision, same radix-2 DIT schedule).
pub fn host_ref(x: &[(f32, f32)]) -> Vec<(f32, f32)> {
    let n = x.len();
    let mut re: Vec<f64> = x.iter().map(|&(r, _)| r as f64).collect();
    let mut im: Vec<f64> = x.iter().map(|&(_, i)| i as f64).collect();
    // Bit-reverse.
    let bits = n.trailing_zeros();
    for i in 0..n {
        let j = (i as u32).reverse_bits() >> (32 - bits);
        let j = j as usize;
        if j > i {
            re.swap(i, j);
            im.swap(i, j);
        }
    }
    let mut h = 1;
    while h < n {
        let step = n / (2 * h);
        for g in 0..(n / (2 * h)) {
            for k in 0..h {
                let (wr, wi) = {
                    let ang = -2.0 * std::f64::consts::PI * (k * step) as f64 / n as f64;
                    (ang.cos(), ang.sin())
                };
                let ia = g * 2 * h + k;
                let ib = ia + h;
                let tr = wr * re[ib] - wi * im[ib];
                let ti = wr * im[ib] + wi * re[ib];
                let (ar, ai) = (re[ia], im[ia]);
                re[ia] = ar + tr;
                im[ia] = ai + ti;
                re[ib] = ar - tr;
                im[ib] = ai - ti;
            }
        }
        h *= 2;
    }
    re.into_iter().zip(im).map(|(r, i)| (r as f32, i as f32)).collect()
}

/// Run the FFT; input in natural order (driver bit-reverses), output in
/// frequency order.
pub fn run(
    cluster: &mut Cluster,
    l2: &mut FlatMem,
    x: &[(f32, f32)],
    fw: FpWidth,
    n_cores: usize,
) -> (Vec<(f32, f32)>, KernelRun) {
    let n = x.len();
    let prog = build(n, n_cores, fw);
    let csz = if fw == FpWidth::F32 { 8 } else { 4 };
    let mut alloc = TcdmAlloc::new();
    let x_base = alloc.alloc(n * csz + 16);
    let tw_base = alloc.alloc(n / 2 * 8 + 16);

    // Bit-reversed input.
    let bits = n.trailing_zeros();
    let mut xr = vec![(0f32, 0f32); n];
    for i in 0..n {
        let j = ((i as u32).reverse_bits() >> (32 - bits)) as usize;
        xr[j] = x[i];
    }
    match fw {
        FpWidth::F32 => {
            let flat: Vec<f32> = xr.iter().flat_map(|&(r, i)| [r, i]).collect();
            cluster.tcdm.mem.write_f32s(x_base, &flat);
            let tw: Vec<f32> = (0..n / 2)
                .flat_map(|j| {
                    let ang = -2.0 * std::f32::consts::PI * j as f32 / n as f32;
                    [ang.cos(), ang.sin()]
                })
                .collect();
            cluster.tcdm.mem.write_f32s(tw_base, &tw);
        }
        FpWidth::F16x2 => {
            let flat: Vec<f32> = xr.iter().flat_map(|&(r, i)| [r, i]).collect();
            cluster.tcdm.mem.write_f16s(x_base, &flat);
            let pack = |a: f32, b: f32| -> i32 {
                ((f32_to_f16(b) as u32) << 16 | f32_to_f16(a) as u32) as i32
            };
            let tw: Vec<i32> = (0..n / 2)
                .flat_map(|j| {
                    let ang = -2.0 * std::f32::consts::PI * j as f32 / n as f32;
                    let (wr, wi) = (ang.cos(), ang.sin());
                    [pack(wr, -wi), pack(wi, wr)]
                })
                .collect();
            cluster.tcdm.mem.write_i32s(tw_base, &tw);
        }
        FpWidth::F8x4 => unreachable!("rejected by build()"),
    }

    let stats: ClusterStats = cluster.run_program(
        &prog,
        n_cores,
        l2,
        |id| {
            vec![(A0, id as u32), (A1, n_cores as u32), (A2, x_base), (A3, tw_base)]
        },
        500_000_000,
    );
    let out = match fw {
        FpWidth::F32 => {
            let flat = cluster.tcdm.mem.read_f32s(x_base, 2 * n);
            flat.chunks(2).map(|c| (c[0], c[1])).collect()
        }
        FpWidth::F16x2 => {
            let flat = cluster.tcdm.mem.read_f16s(x_base, 2 * n);
            flat.chunks(2).map(|c| (c[0], c[1])).collect()
        }
        FpWidth::F8x4 => unreachable!("rejected by build()"),
    };
    // 10 real FLOPs per butterfly, N/2·log2(N) butterflies.
    let flops = 10 * (n as u64 / 2) * n.trailing_zeros() as u64;
    (out, KernelRun::new(prog.name.clone(), stats, flops))
}

/// Static-verification target mirroring [`run`]'s layout and registers.
pub fn verify_target(n: usize, fw: FpWidth, n_cores: usize) -> super::VerifyTarget {
    let prog = build(n, n_cores, fw);
    let csz = if fw == FpWidth::F32 { 8 } else { 4 };
    let mut alloc = TcdmAlloc::new();
    let x_base = alloc.alloc(n * csz + 16);
    let tw_base = alloc.alloc(n / 2 * 8 + 16);
    let entry = (0..n_cores)
        .map(|id| vec![(A0, id as u32), (A1, n_cores as u32), (A2, x_base), (A3, tw_base)])
        .collect();
    let name = prog.name.clone();
    super::VerifyTarget { name, prog, n_cores, entry }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::L2_BASE;
    use crate::common::Rng;

    fn signal(n: usize, seed: u64) -> Vec<(f32, f32)> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| (rng.f32_pm1(), rng.f32_pm1())).collect()
    }

    fn l2m() -> FlatMem {
        FlatMem::new(L2_BASE, 4096)
    }

    fn check(n: usize, cores: usize, fw: FpWidth, tol: f32) -> KernelRun {
        let x = signal(n, 50 + n as u64);
        let mut cl = Cluster::new();
        let (got, kr) = run(&mut cl, &mut l2m(), &x, fw, cores);
        let want = host_ref(&x);
        let scale = (n as f32).sqrt();
        for (i, (&(gr, gi), &(wr, wi))) in got.iter().zip(&want).enumerate() {
            assert!(
                (gr - wr).abs() < tol * scale && (gi - wi).abs() < tol * scale,
                "{fw:?} N={n} c{cores} bin {i}: ({gr},{gi}) vs ({wr},{wi})"
            );
        }
        kr
    }

    #[test]
    fn f32_matches_host_across_sizes_and_cores() {
        check(8, 1, FpWidth::F32, 1e-4);
        check(64, 4, FpWidth::F32, 1e-4);
        check(128, 8, FpWidth::F32, 1e-4);
    }

    #[test]
    fn f16_matches_host_to_half_precision() {
        check(64, 8, FpWidth::F16x2, 4e-2);
    }

    #[test]
    fn impulse_gives_flat_spectrum() {
        let mut x = vec![(0f32, 0f32); 32];
        x[0] = (1.0, 0.0);
        let mut cl = Cluster::new();
        let (got, _) = run(&mut cl, &mut l2m(), &x, FpWidth::F32, 8);
        for (i, &(r, im)) in got.iter().enumerate() {
            assert!((r - 1.0).abs() < 1e-4 && im.abs() < 1e-4, "bin {i}");
        }
    }

    #[test]
    fn parallel_fft_speeds_up() {
        let x = signal(256, 60);
        let mut cl = Cluster::new();
        let (_, k1) = run(&mut cl, &mut l2m(), &x, FpWidth::F32, 1);
        let mut cl = Cluster::new();
        let (_, k8) = run(&mut cl, &mut l2m(), &x, FpWidth::F32, 8);
        let s = k1.stats.cycles as f64 / k8.stats.cycles as f64;
        assert!(s > 3.0, "speedup = {s}");
    }

    #[test]
    fn fp_intensity_reasonable() {
        // Table V: FFT 63%.
        let kr = check(128, 8, FpWidth::F32, 1e-4);
        let fi = kr.fp_intensity();
        assert!((0.30..0.70).contains(&fi), "intensity = {fi}");
    }
}
