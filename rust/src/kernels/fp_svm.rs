//! FP SVM (Table V row 8): multi-class linear SVM inference —
//! `argmax_c (w_c · x + b_c)` over C=3 one-vs-rest classifiers.
//!
//! Weights stream from TCDM (D is too large for registers), which keeps
//! the FP intensity moderate (35% in Table V: loads + control around the
//! FMA chain). FP16 packs two dimensions per word via `vfdotpex`.

use crate::cluster::{Cluster, ClusterStats};
use crate::isa::{Asm, Program, A2, A3, A4, A5, A6, S1, S2, S4, S5, S6, S7, S8, T0,
    T1, T2, T3, T4, T5, T6};
use crate::iss::FlatMem;

use super::fp_matmul::FpWidth;
use super::{check_program, require, KernelRun, TcdmAlloc};

pub const CLASSES: usize = 3;

/// Params: a2=&x(points) a3=&labels a4=&W (C rows of D, then C biases)
/// a5=n_points a6=D.
pub(crate) fn build(d: usize, fw: FpWidth) -> Program {
    let name = match fw {
        FpWidth::F32 => "fp_svm_f32",
        FpWidth::F16x2 => "fp_svm_f16",
        FpWidth::F8x4 => panic!("fp_svm: no fp8 variant (fp8 is matmul-only)"),
    };
    let esz = if fw == FpWidth::F32 { 4usize } else { 2 };
    let per_word = 4 / esz;
    require(d % per_word == 0, name, "D multiple of lanes");
    let row = (d * esz) as i32; // W row stride (no pad: 3 streams differ)
    let kiter = (d / per_word) as u32;

    let mut a = Asm::new(name);
    let point_end = a.label();
    for (c, reg) in [S1, S2, S4].iter().enumerate() {
        // biases preloaded: b_c at W + C*row + c*4 (always f32).
        a.lw(*reg, A4, CLASSES as i32 * row + (c * 4) as i32);
    }
    a.lp_setup(0, A5, point_end);
    // Scores start from biases.
    a.mv(S5, S1);
    a.mv(S6, S2);
    a.mv(S7, S4);
    // Weight row pointers.
    a.mv(T4, A4);
    a.addi(T5, A4, row);
    a.addi(T6, A4, 2 * row);
    {
        let end_d = a.label();
        a.lp_setup_imm(1, kiter, end_d);
        a.lw_pi(T0, A2, 4); // x word (advance)
        a.lw_pi(T1, T4, 4); // w0
        a.lw_pi(T2, T5, 4); // w1
        a.lw_pi(T3, T6, 4); // w2
        match fw {
            FpWidth::F32 => {
                a.fmac_s(S5, T0, T1);
                a.fmac_s(S6, T0, T2);
                a.fmac_s(S7, T0, T3);
            }
            FpWidth::F16x2 => {
                a.vfdotpex_s_h(S5, T0, T1);
                a.vfdotpex_s_h(S6, T0, T2);
                a.vfdotpex_s_h(S7, T0, T3);
            }
            FpWidth::F8x4 => unreachable!("rejected above"),
        }
        a.bind(end_d);
    }
    // argmax over (S5, S6, S7) -> S8.
    a.li(S8, 0);
    let keep1 = a.label();
    a.fle_s(T0, S6, S5);
    a.bne(T0, 0, keep1);
    a.mv(S5, S6);
    a.li(S8, 1);
    a.bind(keep1);
    let keep2 = a.label();
    a.fle_s(T0, S7, S5);
    a.bne(T0, 0, keep2);
    a.li(S8, 2);
    a.bind(keep2);
    a.sw_pi(S8, A3, 4);
    a.bind(point_end);
    a.halt();
    let p = a.finish().expect("assembly");
    check_program(&p);
    p
}

pub fn host_ref(points: &[f32], w: &[f32], b: &[f32], d: usize) -> Vec<i32> {
    points
        .chunks(d)
        .map(|x| {
            let mut best = f32::NEG_INFINITY;
            let mut idx = 0;
            for c in 0..CLASSES {
                let s: f32 = b[c]
                    + (0..d).map(|i| x[i] * w[c * d + i]).sum::<f32>();
                if s > best {
                    best = s;
                    idx = c as i32;
                }
            }
            idx
        })
        .collect()
}

/// Run SVM inference over `points` (SPMD chunks).
#[allow(clippy::too_many_arguments)]
pub fn run(
    cluster: &mut Cluster,
    l2: &mut FlatMem,
    points: &[f32],
    w: &[f32],
    b: &[f32],
    d: usize,
    fw: FpWidth,
    n_cores: usize,
) -> (Vec<i32>, KernelRun) {
    let n_points = points.len() / d;
    assert_eq!(w.len(), CLASSES * d);
    assert_eq!(b.len(), CLASSES);
    require(n_points % n_cores == 0, "svm", "points divisible by cores");
    let chunk = n_points / n_cores;
    let prog = build(d, fw);
    let esz = if fw == FpWidth::F32 { 4 } else { 2 };
    let mut alloc = TcdmAlloc::new();
    let p_base = alloc.alloc(points.len() * esz + 16);
    let l_base = alloc.alloc(n_points * 4);
    let w_base = alloc.alloc(CLASSES * d * esz + CLASSES * 4 + 16);
    match fw {
        FpWidth::F32 => {
            cluster.tcdm.mem.write_f32s(p_base, points);
            cluster.tcdm.mem.write_f32s(w_base, w);
        }
        FpWidth::F16x2 => {
            cluster.tcdm.mem.write_f16s(p_base, points);
            cluster.tcdm.mem.write_f16s(w_base, w);
        }
        FpWidth::F8x4 => unreachable!("rejected by build()"),
    }
    // Biases always f32, appended after the weight rows.
    cluster
        .tcdm
        .mem
        .write_f32s(w_base + (CLASSES * d * esz) as u32, b);

    let stats: ClusterStats = cluster.run_program(
        &prog,
        n_cores,
        l2,
        |id| {
            vec![
                (A2, p_base + (id * chunk * d * esz) as u32),
                (A3, l_base + (id * chunk * 4) as u32),
                (A4, w_base),
                (A5, chunk as u32),
                (A6, d as u32),
            ]
        },
        500_000_000,
    );
    let labels = cluster.tcdm.mem.read_i32s(l_base, n_points);
    let flops = (2 * CLASSES * d * n_points) as u64;
    (labels, KernelRun::new(prog.name.clone(), stats, flops))
}

/// Static-verification target mirroring [`run`]'s layout and registers.
pub fn verify_target(n_points: usize, d: usize, fw: FpWidth, n_cores: usize) -> super::VerifyTarget {
    require(n_points % n_cores == 0, "svm", "points divisible by cores");
    let chunk = n_points / n_cores;
    let prog = build(d, fw);
    let esz = if fw == FpWidth::F32 { 4 } else { 2 };
    let mut alloc = TcdmAlloc::new();
    let p_base = alloc.alloc(n_points * d * esz + 16);
    let l_base = alloc.alloc(n_points * 4);
    let w_base = alloc.alloc(CLASSES * d * esz + CLASSES * 4 + 16);
    let entry = (0..n_cores)
        .map(|id| {
            vec![
                (A2, p_base + (id * chunk * d * esz) as u32),
                (A3, l_base + (id * chunk * 4) as u32),
                (A4, w_base),
                (A5, chunk as u32),
                (A6, d as u32),
            ]
        })
        .collect();
    let name = prog.name.clone();
    super::VerifyTarget { name, prog, n_cores, entry }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::L2_BASE;
    use crate::common::Rng;

    fn l2m() -> FlatMem {
        FlatMem::new(L2_BASE, 4096)
    }

    fn setup(n: usize, d: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let w: Vec<f32> = (0..CLASSES * d).map(|_| rng.f32_pm1()).collect();
        let b: Vec<f32> = (0..CLASSES).map(|_| rng.f32_pm1()).collect();
        let points: Vec<f32> = (0..n * d).map(|_| rng.f32_pm1()).collect();
        (points, w, b)
    }

    #[test]
    fn f32_matches_host() {
        let d = 16;
        let (p, w, b) = setup(64, d, 80);
        let mut cl = Cluster::new();
        let (labels, kr) = run(&mut cl, &mut l2m(), &p, &w, &b, d, FpWidth::F32, 8);
        assert_eq!(labels, host_ref(&p, &w, &b, d));
        // Table V: SVM 35% — the streaming-weights regime.
        let fi = kr.fp_intensity();
        assert!((0.25..0.55).contains(&fi), "intensity = {fi}");
    }

    #[test]
    fn f16_mostly_matches_host() {
        // f16 weight rounding can flip near-ties; check the margin cases.
        let d = 16;
        let (p, w, b) = setup(64, d, 81);
        let mut cl = Cluster::new();
        let (labels, _) = run(&mut cl, &mut l2m(), &p, &w, &b, d, FpWidth::F16x2, 8);
        let want = host_ref(&p, &w, &b, d);
        let agree = labels.iter().zip(&want).filter(|(a, b)| a == b).count();
        assert!(agree as f64 / want.len() as f64 > 0.9, "agreement {agree}/{}", want.len());
    }

    #[test]
    fn f16_is_faster() {
        let d = 32;
        let (p, w, b) = setup(64, d, 82);
        let mut cl = Cluster::new();
        let (_, k32) = run(&mut cl, &mut l2m(), &p, &w, &b, d, FpWidth::F32, 8);
        let mut cl = Cluster::new();
        let (_, k16) = run(&mut cl, &mut l2m(), &p, &w, &b, d, FpWidth::F16x2, 8);
        let s = k32.stats.cycles as f64 / k16.stats.cycles as f64;
        assert!(s > 1.3, "speedup = {s}");
    }
}
