//! Host-side HDC software stack: the part of the paper's flow that runs
//! on the FC (or offline) to *train* Hypnos.
//!
//! HDC training is one-shot/few-shot (§II-B [21]): encode training
//! windows with exactly the hardware's encoding primitives (we call into
//! `cwu::hypnos` directly, so prototypes are bit-compatible with what the
//! engine computes online), bundle per class, threshold, and write the
//! prototype hypervectors into the AM. [`gen_microcode`] then emits the
//! 64-slot microcode program that replays the same encoding autonomously.
//!
//! Encoding scheme (the network templates of [23] for ExG and [19] for
//! language, §II-B):
//! * **spatial**: channels combine by permuted binding —
//!   `sv = ρ^(C-1)(m(v₀)) ⊕ ρ^(C-2)(m(v₁)) ⊕ … ⊕ m(v_{C-1})` where `m` is
//!   CIM for analog channels or IM for discrete symbols. Rotation makes
//!   the binding channel-asymmetric (plain XOR binding would collapse
//!   mirrored channel patterns).
//! * **temporal**: `ngram = 1` bundles samples (bag, the ExG template);
//!   `ngram = n > 1` bundles n-grams
//!   `g_t = sv_t ⊕ ρ(sv_{t-1}) ⊕ … ⊕ ρ^{n-1}(sv_{t-n+1})` (the language
//!   template), with missing history as zero vectors. The n-gram shift
//!   registers live in AM scratchpad rows — exactly the "scratchpad
//!   memory to store intermediate HD-vectors" usage of §II-B.

pub mod datasets;

use crate::cwu::hypnos::{
    bitvec::HdVec, encoder, encoder::EuArray, microcode::MicroOp, microcode::MicroProgram,
    perm, Hypnos,
};

/// AM scratchpad rows used by the n-gram shift chain (prototypes occupy
/// the low rows; 16 rows total).
pub const SCRATCH_SV: u8 = 12;
pub const SCRATCH_S1: u8 = 13;
pub const SCRATCH_S2: u8 = 14;

/// Encoding configuration shared between training and the engine.
#[derive(Debug, Clone, Copy)]
pub struct EncoderConfig {
    pub dim: usize,
    pub input_width: u32,
    pub cim_max: u32,
    pub channels: usize,
    /// Samples bundled per classification window.
    pub window: usize,
    /// Temporal n-gram order (1 = bag of samples).
    pub ngram: usize,
    /// Discrete symbols (IM mapping) vs analog values (CIM mapping).
    pub discrete: bool,
}

impl EncoderConfig {
    fn map_value(&self, v: u32) -> HdVec {
        if self.discrete {
            perm::im_map(self.dim, v, self.input_width)
        } else {
            encoder::cim_map(self.dim, v, self.cim_max)
        }
    }

    /// Spatial encoding of one frame: permuted channel binding.
    pub fn encode_frame(&self, frame: &[u32]) -> HdVec {
        assert_eq!(frame.len(), self.channels);
        let mut sv: Option<HdVec> = None;
        for &v in frame {
            let m = self.map_value(v);
            sv = Some(match sv {
                None => m,
                // RES = ρ(RES) ⊕ m(v_c), exactly the microcode's
                // Permute-then-BindTmp order.
                Some(s) => s.rotate(1).xor(&m),
            });
        }
        sv.unwrap()
    }

    /// Encode one window exactly as the generated microcode does.
    pub fn encode_window(&self, window: &[Vec<u32>]) -> HdVec {
        assert!(!window.is_empty());
        assert!(self.ngram >= 1 && self.ngram <= 3, "ngram in 1..=3");
        let mut eu = EuArray::new(self.dim);
        let mut s1 = HdVec::zero(self.dim); // ρ(sv_{t-1})
        let mut s2 = HdVec::zero(self.dim); // ρ²(sv_{t-2})
        for frame in window {
            let sv = self.encode_frame(frame);
            let gram = match self.ngram {
                1 => sv.clone(),
                2 => sv.xor(&s1),
                _ => sv.xor(&s1).xor(&s2),
            };
            eu.accumulate(&gram);
            if self.ngram == 3 {
                s2 = s1.rotate(1);
            }
            if self.ngram >= 2 {
                s1 = sv.rotate(1);
            }
        }
        eu.threshold()
    }
}

/// A trained HDC classifier: per-class prototypes.
#[derive(Debug, Clone)]
pub struct HdcModel {
    pub config: EncoderConfig,
    pub prototypes: Vec<HdVec>,
}

/// Train prototypes by bundling the encoded training windows per class.
///
/// `data[class]` = list of windows; each window = frames of `channels`
/// values. Few-shot: a handful of windows per class suffices.
pub fn train(config: EncoderConfig, data: &[Vec<Vec<Vec<u32>>>]) -> HdcModel {
    assert!(data.len() <= SCRATCH_SV as usize, "prototype rows collide with scratch");
    let prototypes = data
        .iter()
        .map(|windows| {
            let mut eu = EuArray::new(config.dim);
            for w in windows {
                eu.accumulate(&config.encode_window(w));
            }
            eu.threshold()
        })
        .collect();
    HdcModel { config, prototypes }
}

impl HdcModel {
    /// Classify one window (software path, for accuracy evaluation).
    pub fn classify(&self, window: &[Vec<u32>]) -> usize {
        self.margin(window).0
    }

    /// (best class, Hamming distance) for one window.
    pub fn margin(&self, window: &[Vec<u32>]) -> (usize, u32) {
        let q = self.config.encode_window(window);
        self.prototypes
            .iter()
            .enumerate()
            .map(|(i, p)| (i, p.hamming(&q)))
            .min_by_key(|&(_, d)| d)
            .unwrap()
    }

    /// Program a Hypnos engine: prototypes into the AM, zeroed n-gram
    /// scratch rows, and the generated microcode watching `target_class`.
    pub fn program_hypnos(&self, target_class: usize, threshold: u16) -> Hypnos {
        let cfg = self.config;
        let mut h = Hypnos::new(cfg.dim, cfg.input_width, cfg.cim_max);
        for (i, p) in self.prototypes.iter().enumerate() {
            h.am.write(i, p.clone());
            h.am.mark_prototype(i, true);
        }
        for row in [SCRATCH_SV, SCRATCH_S1, SCRATCH_S2] {
            h.am.write(row as usize, HdVec::zero(cfg.dim));
        }
        h.load_program(gen_microcode(&cfg, target_class, threshold));
        h
    }
}

/// Emit the autonomous microcode replaying [`EncoderConfig::encode_window`].
pub fn gen_microcode(cfg: &EncoderConfig, target: usize, threshold: u16) -> MicroProgram {
    assert!(cfg.ngram >= 1 && cfg.ngram <= 3);
    let mut ops = vec![MicroOp::BundleReset];
    // Per-frame body: acquire the frame, spatial-encode it, n-gram, bundle.
    let mut body = vec![MicroOp::NextFrame];
    for c in 0..cfg.channels {
        let map = if cfg.discrete {
            MicroOp::ImMap { chan: c as u8 }
        } else {
            MicroOp::CimMap { chan: c as u8 }
        };
        map_into(&mut body, map, c == 0);
    }
    if cfg.ngram > 1 {
        body.push(MicroOp::StoreAm { row: SCRATCH_SV }); // sv_t
        body.push(MicroOp::BindAm { row: SCRATCH_S1 }); // ⊕ ρ(sv_{t-1})
        if cfg.ngram == 3 {
            body.push(MicroOp::BindAm { row: SCRATCH_S2 }); // ⊕ ρ²(sv_{t-2})
        }
        body.push(MicroOp::BundleAcc);
        if cfg.ngram == 3 {
            // s2 = ρ(s1)
            body.push(MicroOp::LoadAm { row: SCRATCH_S1 });
            body.push(MicroOp::Permute { n: 1 });
            body.push(MicroOp::StoreAm { row: SCRATCH_S2 });
        }
        // s1 = ρ(sv)
        body.push(MicroOp::LoadAm { row: SCRATCH_SV });
        body.push(MicroOp::Permute { n: 1 });
        body.push(MicroOp::StoreAm { row: SCRATCH_S1 });
    } else {
        body.push(MicroOp::BundleAcc);
    }
    ops.push(MicroOp::Repeat { count: cfg.window as u16, len: body.len() as u8 });
    ops.extend(body);
    ops.push(MicroOp::BundleThr);
    ops.push(MicroOp::Search { threshold, target: target as u8 });
    MicroProgram::new(ops)
}

/// Emit "map channel c into the running spatial vector": first channel
/// moves, later channels permute-then-bind (ρ(RES) ⊕ m(v_c)).
fn map_into(body: &mut Vec<MicroOp>, map: MicroOp, first: bool) {
    body.push(map);
    if first {
        body.push(MicroOp::MovTmp);
    } else {
        body.push(MicroOp::Permute { n: 1 });
        body.push(MicroOp::BindTmp);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::Rng;

    fn emg_cfg() -> EncoderConfig {
        EncoderConfig {
            dim: 2048,
            input_width: 12,
            cim_max: 4095,
            channels: 2,
            window: 8,
            ngram: 1,
            discrete: false,
        }
    }

    fn noisy_window(rng: &mut Rng, base: [u32; 2], noise: u32, len: usize) -> Vec<Vec<u32>> {
        (0..len)
            .map(|_| {
                base.iter()
                    .map(|&b| {
                        (b as i64 + rng.range_i64(-(noise as i64), noise as i64))
                            .clamp(0, 4095) as u32
                    })
                    .collect()
            })
            .collect()
    }

    #[test]
    fn few_shot_training_separates_classes() {
        let cfg = emg_cfg();
        let mut rng = Rng::new(11);
        // Includes the mirrored pair ([500,3000] vs [3000,500]) that plain
        // XOR role-binding cannot distinguish.
        let classes = [[500u32, 3000u32], [3000, 500], [1800, 1800]];
        let train_data: Vec<Vec<Vec<Vec<u32>>>> = classes
            .iter()
            .map(|&b| (0..5).map(|_| noisy_window(&mut rng, b, 150, 8)).collect())
            .collect();
        let model = train(cfg, &train_data);

        let mut correct = 0;
        let mut total = 0;
        for (ci, &b) in classes.iter().enumerate() {
            for _ in 0..20 {
                let w = noisy_window(&mut rng, b, 150, 8);
                if model.classify(&w) == ci {
                    correct += 1;
                }
                total += 1;
            }
        }
        let acc = correct as f64 / total as f64;
        assert!(acc > 0.9, "accuracy = {acc}");
    }

    #[test]
    fn microcode_replays_software_encoding_bit_exactly() {
        let cfg = emg_cfg();
        let mut rng = Rng::new(5);
        let train_data: Vec<Vec<Vec<Vec<u32>>>> = vec![
            (0..3).map(|_| noisy_window(&mut rng, [400, 2800], 100, 8)).collect(),
            (0..3).map(|_| noisy_window(&mut rng, [2800, 400], 100, 8)).collect(),
        ];
        let model = train(cfg, &train_data);
        let mut h = model.program_hypnos(0, (cfg.dim / 3) as u16);

        // Feed a class-0 window through the engine and compare its RES
        // against the software encoder.
        let w = noisy_window(&mut rng, [400, 2800], 100, 8);
        let mut wake = None;
        for frame in &w {
            wake = h.on_frame(frame);
        }
        assert_eq!(h.result(), &cfg.encode_window(&w), "engine/software divergence");
        assert!(wake.is_some(), "class-0 window should wake");

        // A class-1 window must not wake (watching class 0).
        let w1 = noisy_window(&mut rng, [2800, 400], 100, 8);
        let mut wake = None;
        for frame in &w1 {
            wake = h.on_frame(frame);
        }
        assert!(wake.is_none());
    }

    #[test]
    fn ngram_microcode_matches_software() {
        // Language-style config: discrete symbols, trigrams.
        let cfg = EncoderConfig {
            dim: 1024,
            input_width: 5,
            cim_max: 26,
            channels: 1,
            window: 16,
            ngram: 3,
            discrete: true,
        };
        let mut rng = Rng::new(9);
        let w: Vec<Vec<u32>> = (0..16).map(|_| vec![rng.below(27) as u32]).collect();
        let model = HdcModel {
            config: cfg,
            prototypes: vec![cfg.encode_window(&w)],
        };
        let mut h = model.program_hypnos(0, 0);
        let mut wake = None;
        for frame in &w {
            wake = h.on_frame(frame);
        }
        assert_eq!(h.result(), &cfg.encode_window(&w), "ngram divergence");
        assert!(wake.is_some(), "identical window has distance 0");
    }

    #[test]
    fn temporal_ngrams_distinguish_order() {
        let mk = |ngram| EncoderConfig {
            dim: 2048,
            input_width: 4,
            cim_max: 15,
            channels: 1,
            window: 8,
            ngram,
            discrete: true,
        };
        let rising: Vec<Vec<u32>> = (0..8).map(|t| vec![t]).collect();
        let falling: Vec<Vec<u32>> = (0..8).map(|t| vec![7 - t]).collect();
        let tri = mk(3);
        let bag = mk(1);
        let d_tri = tri.encode_window(&rising).hamming(&tri.encode_window(&falling));
        let d_bag = bag.encode_window(&rising).hamming(&bag.encode_window(&falling));
        // Same multiset of symbols: the bag collapses; trigrams don't.
        assert_eq!(d_bag, 0, "bag should be order-blind");
        assert!(d_tri > 500, "d_tri = {d_tri}");
    }

    #[test]
    fn microcode_fits_64_slots_for_8_channels() {
        let cfg = EncoderConfig {
            dim: 2048,
            input_width: 16,
            cim_max: 65535,
            channels: 8,
            window: 32,
            ngram: 3,
            discrete: false,
        };
        let p = gen_microcode(&cfg, 0, 300);
        assert!(p.len() <= 64, "len = {}", p.len());
    }
}
