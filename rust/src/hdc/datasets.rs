//! Synthetic datasets for the CWU evaluation (DESIGN.md §5 substitution
//! for the paper's real sensor data).
//!
//! * **EMG gestures** — the "typical always-on classification algorithm
//!   for EMG data" of Table I: 3 electrode channels; each gesture is a
//!   characteristic per-channel activation envelope + tremor + noise.
//! * **Language identification** — the "compute-intensive language
//!   classification algorithm" of Table I (the classic HDC benchmark
//!   [19]): character streams drawn from per-language digraph statistics.

use crate::common::Rng;

/// One multi-channel window: `window[t][channel]`.
pub type Window = Vec<Vec<u32>>;

/// EMG gesture generator: 3 channels, 12-bit samples around mid-scale.
pub struct EmgGenerator {
    rng: Rng,
    /// Per-gesture, per-channel activation amplitude (the muscle map).
    profiles: Vec<[f64; 3]>,
    pub noise: f64,
}

impl EmgGenerator {
    pub fn new(seed: u64) -> Self {
        Self {
            rng: Rng::new(seed),
            // rest, fist, wrist-flex, wrist-extend: distinct channel maps.
            profiles: vec![
                [0.05, 0.05, 0.05],
                [0.9, 0.7, 0.2],
                [0.2, 0.8, 0.7],
                [0.7, 0.15, 0.85],
            ],
            noise: 0.06,
        }
    }

    pub fn n_classes(&self) -> usize {
        self.profiles.len()
    }

    /// Generate one `len`-sample window of gesture `class`.
    pub fn window(&mut self, class: usize, len: usize) -> Window {
        let prof = self.profiles[class];
        (0..len)
            .map(|t| {
                (0..3)
                    .map(|c| {
                        // Envelope ramps in, tremor at ~40-70 "Hz"
                        // (arbitrary units of the sample clock).
                        let env = prof[c] * (1.0 - (-(t as f64) / 6.0).exp());
                        let tremor =
                            0.25 * prof[c] * ((t as f64) * (0.9 + 0.2 * c as f64)).sin();
                        let noise = self.noise * (self.rng.f64() * 2.0 - 1.0);
                        let v = 2048.0 + 1800.0 * (env + tremor) * 0.5 + 1800.0 * noise;
                        v.clamp(0.0, 4095.0) as u32
                    })
                    .collect()
            })
            .collect()
    }

    /// A labelled dataset: `out[class]` = `n` windows.
    pub fn dataset(&mut self, n: usize, len: usize) -> Vec<Vec<Window>> {
        (0..self.n_classes())
            .map(|c| (0..n).map(|_| self.window(c, len)).collect())
            .collect()
    }
}

/// Language-identification generator: character streams (1 channel,
/// values 0..26) from per-language digraph chains.
pub struct LangGenerator {
    rng: Rng,
    /// Per-language digraph transition tables (27×27, row-stochastic in
    /// fixed point).
    tables: Vec<Vec<u16>>,
}

pub const LANG_ALPHABET: u32 = 27; // a..z + space

impl LangGenerator {
    pub fn new(seed: u64, n_langs: usize) -> Self {
        let mut rng = Rng::new(seed);
        let tables = (0..n_langs)
            .map(|_| {
                // A sparse, peaky digraph structure per language: each row
                // concentrates mass on a few language-specific successors.
                let mut t = vec![1u16; (LANG_ALPHABET * LANG_ALPHABET) as usize];
                for row in 0..LANG_ALPHABET {
                    for _ in 0..4 {
                        let col = rng.below(LANG_ALPHABET as u64) as u32;
                        t[(row * LANG_ALPHABET + col) as usize] += 40;
                    }
                }
                t
            })
            .collect();
        Self { rng, tables }
    }

    pub fn n_classes(&self) -> usize {
        self.tables.len()
    }

    /// Sample a character stream of `len` from language `class` as a
    /// 1-channel window.
    pub fn window(&mut self, class: usize, len: usize) -> Window {
        let table = &self.tables[class];
        let mut c = self.rng.below(LANG_ALPHABET as u64) as u32;
        (0..len)
            .map(|_| {
                let row = &table[(c * LANG_ALPHABET) as usize..((c + 1) * LANG_ALPHABET) as usize];
                let total: u64 = row.iter().map(|&w| w as u64).sum();
                let mut pick = self.rng.below(total);
                let mut next = 0u32;
                for (i, &w) in row.iter().enumerate() {
                    if pick < w as u64 {
                        next = i as u32;
                        break;
                    }
                    pick -= w as u64;
                }
                c = next;
                vec![c]
            })
            .collect()
    }

    pub fn dataset(&mut self, n: usize, len: usize) -> Vec<Vec<Window>> {
        (0..self.n_classes())
            .map(|c| (0..n).map(|_| self.window(c, len)).collect())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn emg_windows_have_expected_shape_and_range() {
        let mut g = EmgGenerator::new(1);
        let w = g.window(1, 32);
        assert_eq!(w.len(), 32);
        assert!(w.iter().all(|f| f.len() == 3));
        assert!(w.iter().flatten().all(|&v| v < 4096));
    }

    #[test]
    fn emg_classes_differ_in_channel_energy() {
        let mut g = EmgGenerator::new(2);
        let energy = |w: &Window, c: usize| -> f64 {
            w.iter().map(|f| ((f[c] as f64) - 2048.0).abs()).sum::<f64>() / w.len() as f64
        };
        let rest = g.window(0, 64);
        let fist = g.window(1, 64);
        assert!(energy(&fist, 0) > 3.0 * energy(&rest, 0));
    }

    #[test]
    fn lang_streams_are_in_alphabet() {
        let mut g = LangGenerator::new(3, 4);
        let w = g.window(2, 100);
        assert!(w.iter().all(|f| f[0] < LANG_ALPHABET));
    }

    #[test]
    fn lang_digraph_statistics_differ() {
        let mut g = LangGenerator::new(4, 2);
        // Count digraphs of each language; distributions should diverge.
        let digraphs = |w: &Window| -> Vec<u32> {
            let mut h = vec![0u32; (LANG_ALPHABET * LANG_ALPHABET) as usize];
            for pair in w.windows(2) {
                h[(pair[0][0] * LANG_ALPHABET + pair[1][0]) as usize] += 1;
            }
            h
        };
        let a = digraphs(&g.window(0, 2000));
        let b = digraphs(&g.window(1, 2000));
        let overlap: u64 = a.iter().zip(&b).map(|(&x, &y)| x.min(y) as u64).sum();
        let total: u64 = a.iter().map(|&x| x as u64).sum();
        assert!((overlap as f64) < 0.8 * total as f64, "overlap {overlap}/{total}");
    }
}
