//! Scenario descriptors: the unit of work in the sweep engine.
//!
//! A [`Scenario`] is a value describing one distinct cluster simulation of
//! the reproduction suite (which kernel, which problem size, which
//! precision, how many cores, which fabric configuration). It is `Copy`,
//! hashable, and knows how to
//!
//! * assemble its [`Program`] (hashed into the cache key so a kernel
//!   change can never serve stale cached stats),
//! * canonicalise itself (Table V's MATMUL row *is* the Fig. 6 FP matmul,
//!   so both map to one cache entry), and
//! * simulate itself on a caller-owned [`SimArena`].
//!
//! The input data of every scenario is generated from a fixed seed, so a
//! scenario's result is a pure function of its descriptor — the property
//! that makes both the memoization and the parallel fan-out exact. Seeds
//! and problem sizes are transplanted verbatim from the original
//! coordinator drivers (EXPERIMENTS.md records them); the coordinator's
//! `bench_*` entry points now delegate here.

use crate::cluster::{Cluster, L2_BASE, L2_SIZE};
use crate::common::Rng;
use crate::isa::Program;
use crate::iss::FlatMem;
use crate::kernels::fp_matmul::FpWidth;
use crate::kernels::int_matmul::IntWidth;
use crate::kernels::{
    fp_conv, fp_fft, fp_filters, fp_kmeans, fp_matmul, fp_svm, int_matmul, KernelRun,
    VerifyTarget,
};

/// One worker's owned simulation state: a cluster fabric plus its L2 view,
/// allocated once and zeroed between scenarios ([`SimArena::reset`] is
/// bit-equivalent to building a fresh pair, without the allocations).
pub struct SimArena {
    pub cluster: Cluster,
    pub l2: FlatMem,
}

impl SimArena {
    pub fn new() -> Self {
        Self { cluster: Cluster::new(), l2: FlatMem::new(L2_BASE, L2_SIZE) }
    }

    /// Restore the freshly-built state in place. Pins the scheduler back
    /// to the default cycle-skip fast path too: the cache key has no
    /// scheduler component, so a scenario must never be simulated (and
    /// cached) on anything but the default scheduler. Superblock replay
    /// is likewise pinned to the process default (`VEGA_SUPERBLOCKS`) —
    /// also keyless, which is safe because replay is bit-identical to
    /// the interpreter (tests/scheduler_equivalence.rs), so cached
    /// results never depend on the setting.
    pub fn reset(&mut self) {
        self.cluster.reset();
        self.cluster.scheduler = crate::cluster::SchedulerMode::CycleSkip;
        self.cluster.superblocks = crate::iss::superblock::env_default();
        self.l2.reset();
    }
}

impl Default for SimArena {
    fn default() -> Self {
        Self::new()
    }
}

/// Cache key of one distinct simulation (ISSUE: kernel id, problem size,
/// precision, core count, plus the assembled program's content hash).
///
/// `prog_hash` is [`Program::content_hash`] — FNV-1a over the explicit
/// versioned byte encoding of [`crate::isa::encode`], never a derived
/// `Hash` impl — so keys are stable across toolchains and safe to
/// persist / share between machines. (The `Hash` derive below only feeds
/// the in-process `HashMap`; no derived hash ever reaches disk.)
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct SimKey {
    pub kernel: String,
    pub size: (usize, usize, usize),
    pub precision: &'static str,
    pub cores: usize,
    pub prog_hash: u64,
}

/// Cached outcome of one simulation: the stats bundle every report renders
/// from, plus a digest of the kernel's functional outputs (so equivalence
/// checks don't need to retain megabytes of result tensors).
#[derive(Debug, Clone)]
pub struct SimResult {
    pub run: KernelRun,
    pub outputs_digest: u64,
}

// Canonical problem sizes, shared by `program()` (the hashed cache-key
// program), `key()` (the size field) and `simulate()` (the driver run) so
// the three can never drift apart — the prog_hash staleness guard is only
// as good as program() assembling the exact program the driver executes.
const INT_MATMUL_DIMS: (usize, usize, usize) = (64, 64, 64);
const FP_MATMUL_DIMS: (usize, usize, usize) = (32, 32, 64);
const FPU_ABLATION_DIMS: (usize, usize, usize) = (32, 32, 32);
const CONV_HW: (usize, usize) = (16, 32);
const DWT_N: usize = 1024;
const FFT_N: usize = 256;
const FIR_N: usize = 512;
const IIR_CHANNELS: usize = 8;
const IIR_N: usize = 256;
const KMEANS_POINTS: usize = 256;
const SVM_POINTS: usize = 128;
const SVM_DIM: usize = 16;

/// One distinct simulated workload of the reproduction suite.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Scenario {
    /// PULP-NN integer matmul, 64×64×64 (Fig. 6 / Table VIII).
    IntMatmul { w: IntWidth, cores: usize },
    /// Layout-ablation variant with an explicit row pad (ablation 1).
    IntMatmulPadded { w: IntWidth, cores: usize, pad_words: usize },
    /// FP matmul, 32×32×64 (Fig. 6 / Fig. 8 / Table V MATMUL row).
    FpMatmul { w: FpWidth, cores: usize },
    /// FPU-fabric ablation variant, 32×32×32 (ablation 2).
    FpMatmulFpu { w: FpWidth, cores: usize, private_fpu: bool },
    /// One Table V / Fig. 8 NSAA kernel on 8 cores.
    Nsaa { name: &'static str, w: FpWidth },
}

impl IntWidth {
    fn precision_str(self) -> &'static str {
        match self {
            IntWidth::I8 => "i8",
            IntWidth::I16 => "i16",
            IntWidth::I32 => "i32",
        }
    }
}

impl FpWidth {
    fn precision_str(self) -> &'static str {
        match self {
            FpWidth::F32 => "f32",
            FpWidth::F16x2 => "f16x2",
            FpWidth::F8x4 => "f8x4",
        }
    }
}

impl Scenario {
    /// Collapse aliases onto one cache entry: Table V's MATMUL row runs
    /// the same program on the same inputs as the Fig. 6 FP matmul.
    pub fn canonical(self) -> Self {
        match self {
            Scenario::Nsaa { name: "MATMUL", w } => Scenario::FpMatmul { w, cores: 8 },
            s => s,
        }
    }

    /// Assemble the scenario's program (cache-key component only; the
    /// simulation assembles its own copy through the kernel driver).
    pub fn program(&self) -> Program {
        let (im, ik, il) = INT_MATMUL_DIMS;
        let (fm, fk, fl) = FP_MATMUL_DIMS;
        let (am, ak, al) = FPU_ABLATION_DIMS;
        match self.canonical() {
            Scenario::IntMatmul { w, .. } => int_matmul::build(im, ik, il, w),
            Scenario::IntMatmulPadded { w, pad_words, .. } => {
                int_matmul::build_padded(im, ik, il, w, pad_words)
            }
            Scenario::FpMatmul { w, .. } => fp_matmul::build(fm, fk, fl, w),
            Scenario::FpMatmulFpu { w, .. } => fp_matmul::build(am, ak, al, w),
            Scenario::Nsaa { name, w } => match name {
                "CONV" => fp_conv::build(CONV_HW.0, CONV_HW.1, w),
                "DWT" => match w {
                    FpWidth::F32 => fp_filters::build_dwt_f32(),
                    FpWidth::F16x2 => fp_filters::build_dwt_f16(),
                    FpWidth::F8x4 => panic!("NSAA kernels stop at fp16"),
                },
                "FFT" => fp_fft::build(FFT_N, 8, w),
                "FIR" => match w {
                    FpWidth::F32 => fp_filters::build_fir_f32(),
                    FpWidth::F16x2 => fp_filters::build_fir_f16(),
                    FpWidth::F8x4 => panic!("NSAA kernels stop at fp16"),
                },
                "IIR" => match w {
                    FpWidth::F32 => fp_filters::build_iir_f32(),
                    FpWidth::F16x2 => fp_filters::build_iir_f16(),
                    FpWidth::F8x4 => panic!("NSAA kernels stop at fp16"),
                },
                "KMEANS" => match w {
                    FpWidth::F32 => fp_kmeans::build_f32(),
                    FpWidth::F16x2 => fp_kmeans::build_f16(),
                    FpWidth::F8x4 => panic!("NSAA kernels stop at fp16"),
                },
                "SVM" => fp_svm::build(SVM_DIM, w),
                other => panic!("unknown NSAA kernel {other}"),
            },
        }
    }

    /// Program content hash of the canonical scenario, assembled once per
    /// process per scenario (kernel code is fixed for a process lifetime,
    /// and `key()` sits on the cache-lookup hot path — hits must not pay
    /// for a full program assembly).
    fn prog_hash(self) -> u64 {
        use std::sync::OnceLock;
        static HASHES: OnceLock<super::cache::OnceMap<Scenario, u64>> = OnceLock::new();
        let c = self.canonical();
        HASHES
            .get_or_init(|| super::cache::OnceMap::new(true))
            .get_or_compute(c, || c.program().content_hash())
    }

    /// The memoization key (canonicalised).
    pub fn key(&self) -> SimKey {
        let c = self.canonical();
        let prog_hash = c.prog_hash();
        match c {
            Scenario::IntMatmul { w, cores } => SimKey {
                kernel: format!("matmul_i{}", w.bytes() * 8),
                size: INT_MATMUL_DIMS,
                precision: w.precision_str(),
                cores,
                prog_hash,
            },
            Scenario::IntMatmulPadded { w, cores, pad_words } => SimKey {
                kernel: format!("matmul_i{}_pad{pad_words}", w.bytes() * 8),
                size: INT_MATMUL_DIMS,
                precision: w.precision_str(),
                cores,
                prog_hash,
            },
            Scenario::FpMatmul { w, cores } => SimKey {
                kernel: "fp_matmul".into(),
                size: FP_MATMUL_DIMS,
                precision: w.precision_str(),
                cores,
                prog_hash,
            },
            Scenario::FpMatmulFpu { w, cores, private_fpu } => SimKey {
                kernel: format!(
                    "fp_matmul_{}_fpu",
                    if private_fpu { "private" } else { "shared" }
                ),
                size: FPU_ABLATION_DIMS,
                precision: w.precision_str(),
                cores,
                prog_hash,
            },
            Scenario::Nsaa { name, w } => SimKey {
                kernel: format!("nsaa_{}", name.to_lowercase()),
                size: nsaa_size(name),
                precision: w.precision_str(),
                cores: 8,
                prog_hash,
            },
        }
    }

    /// Simulate this scenario on `arena` (reset first; results are a pure
    /// function of the descriptor).
    pub fn simulate(&self, arena: &mut SimArena) -> SimResult {
        self.run_on(arena, &self.gen_inputs())
    }

    /// Generate this scenario's canonical input tensors from its fixed
    /// seed. Split out of `simulate` (ISSUE 6) so fault campaigns can
    /// serialize, corrupt and re-materialize the inputs while the RNG
    /// streams — and therefore every digest and cached result — stay
    /// bit-identical to the pre-split code.
    pub(crate) fn gen_inputs(&self) -> Inputs {
        match self.canonical() {
            Scenario::IntMatmul { w, .. } => {
                let mut rng = Rng::new(0xF16_6);
                let (m, n, k) = INT_MATMUL_DIMS;
                let lim = match w {
                    IntWidth::I8 => 127,
                    IntWidth::I16 => 2047,
                    IntWidth::I32 => 1000,
                };
                let a: Vec<i32> = (0..m * k).map(|_| rng.range_i64(-lim, lim) as i32).collect();
                let b: Vec<i32> = (0..n * k).map(|_| rng.range_i64(-lim, lim) as i32).collect();
                Inputs::IntMatmul { a, b }
            }
            Scenario::IntMatmulPadded { .. } => {
                let mut rng = Rng::new(0xAB1);
                let (m, n, k) = INT_MATMUL_DIMS;
                let a: Vec<i32> = (0..m * k).map(|_| rng.range_i64(-128, 127) as i32).collect();
                let b: Vec<i32> = (0..n * k).map(|_| rng.range_i64(-128, 127) as i32).collect();
                Inputs::IntMatmul { a, b }
            }
            Scenario::FpMatmul { .. } => {
                let mut rng = Rng::new(0xF16_8);
                let (m, n, k) = FP_MATMUL_DIMS;
                let a: Vec<f32> = (0..m * k).map(|_| rng.f32_pm1()).collect();
                let b: Vec<f32> = (0..n * k).map(|_| rng.f32_pm1()).collect();
                Inputs::FpMatmul { a, b }
            }
            Scenario::FpMatmulFpu { .. } => {
                let mut rng = Rng::new(0xAB2);
                let (m, n, k) = FPU_ABLATION_DIMS;
                let a: Vec<f32> = (0..m * k).map(|_| rng.f32_pm1()).collect();
                let b: Vec<f32> = (0..n * k).map(|_| rng.f32_pm1()).collect();
                Inputs::FpMatmul { a, b }
            }
            Scenario::Nsaa { name, .. } => {
                let mut rng = Rng::new(0x85AA ^ name.len() as u64);
                match name {
                    "CONV" => {
                        let (h, wd) = CONV_HW;
                        let x: Vec<f32> =
                            (0..(h + 2) * (wd + 2)).map(|_| rng.f32_pm1()).collect();
                        let k: Vec<f32> = (0..9).map(|_| rng.f32_pm1()).collect();
                        Inputs::Conv { x, k }
                    }
                    "DWT" => Inputs::Dwt { x: (0..DWT_N).map(|_| rng.f32_pm1()).collect() },
                    "FFT" => Inputs::Fft {
                        x: (0..FFT_N).map(|_| (rng.f32_pm1(), rng.f32_pm1())).collect(),
                    },
                    "FIR" => {
                        let taps: Vec<f32> =
                            (0..fp_filters::FIR_TAPS).map(|_| rng.f32_pm1()).collect();
                        let x: Vec<f32> = (0..FIR_N + 16).map(|_| rng.f32_pm1()).collect();
                        Inputs::Fir { taps, x }
                    }
                    "IIR" => Inputs::Iir {
                        chans: (0..IIR_CHANNELS)
                            .map(|_| (0..IIR_N).map(|_| rng.f32_pm1()).collect())
                            .collect(),
                    },
                    "KMEANS" => {
                        let centroids: Vec<f32> = (0..fp_kmeans::K * fp_kmeans::D)
                            .map(|_| 2.0 * rng.f32_pm1())
                            .collect();
                        let pts: Vec<f32> = (0..KMEANS_POINTS * fp_kmeans::D)
                            .map(|_| 2.0 * rng.f32_pm1())
                            .collect();
                        Inputs::Kmeans { centroids, pts }
                    }
                    "SVM" => {
                        let w: Vec<f32> =
                            (0..fp_svm::CLASSES * SVM_DIM).map(|_| rng.f32_pm1()).collect();
                        let b: Vec<f32> = (0..fp_svm::CLASSES).map(|_| rng.f32_pm1()).collect();
                        let pts: Vec<f32> =
                            (0..SVM_POINTS * SVM_DIM).map(|_| rng.f32_pm1()).collect();
                        Inputs::Svm { w, b, pts }
                    }
                    other => panic!("unknown NSAA kernel {other}"),
                }
            }
        }
    }

    /// Reconstruct this scenario's [`Inputs`] from a serialized image
    /// (the inverse of [`Inputs::to_bytes`], using the scenario's
    /// canonical shapes). Panics if `bytes` is not exactly the right
    /// length — a campaign must never silently mis-slice a tensor.
    pub(crate) fn with_bytes(&self, bytes: &[u8]) -> Inputs {
        let mut r = ImageReader::new(bytes);
        let inputs = match self.canonical() {
            Scenario::IntMatmul { .. } | Scenario::IntMatmulPadded { .. } => {
                let (m, n, k) = INT_MATMUL_DIMS;
                Inputs::IntMatmul { a: r.i32s(m * k), b: r.i32s(n * k) }
            }
            Scenario::FpMatmul { .. } => {
                let (m, n, k) = FP_MATMUL_DIMS;
                Inputs::FpMatmul { a: r.f32s(m * k), b: r.f32s(n * k) }
            }
            Scenario::FpMatmulFpu { .. } => {
                let (m, n, k) = FPU_ABLATION_DIMS;
                Inputs::FpMatmul { a: r.f32s(m * k), b: r.f32s(n * k) }
            }
            Scenario::Nsaa { name, .. } => match name {
                "CONV" => {
                    let (h, wd) = CONV_HW;
                    Inputs::Conv { x: r.f32s((h + 2) * (wd + 2)), k: r.f32s(9) }
                }
                "DWT" => Inputs::Dwt { x: r.f32s(DWT_N) },
                "FFT" => Inputs::Fft { x: (0..FFT_N).map(|_| (r.f32(), r.f32())).collect() },
                "FIR" => {
                    Inputs::Fir { taps: r.f32s(fp_filters::FIR_TAPS), x: r.f32s(FIR_N + 16) }
                }
                "IIR" => {
                    Inputs::Iir { chans: (0..IIR_CHANNELS).map(|_| r.f32s(IIR_N)).collect() }
                }
                "KMEANS" => Inputs::Kmeans {
                    centroids: r.f32s(fp_kmeans::K * fp_kmeans::D),
                    pts: r.f32s(KMEANS_POINTS * fp_kmeans::D),
                },
                "SVM" => Inputs::Svm {
                    w: r.f32s(fp_svm::CLASSES * SVM_DIM),
                    b: r.f32s(fp_svm::CLASSES),
                    pts: r.f32s(SVM_POINTS * SVM_DIM),
                },
                other => panic!("unknown NSAA kernel {other}"),
            },
        };
        r.done();
        inputs
    }

    /// Run this scenario's kernel on `arena` with the given inputs
    /// (reset first). `inputs` must match the scenario's shape —
    /// [`Scenario::gen_inputs`] or [`Scenario::with_bytes`] output.
    pub(crate) fn run_on(&self, arena: &mut SimArena, inputs: &Inputs) -> SimResult {
        arena.reset();
        let (cl, l2) = (&mut arena.cluster, &mut arena.l2);
        match (self.canonical(), inputs) {
            (Scenario::IntMatmul { w, cores }, Inputs::IntMatmul { a, b }) => {
                let (m, n, k) = INT_MATMUL_DIMS;
                let (c, kr) = int_matmul::run(cl, l2, a, b, m, n, k, w, cores);
                SimResult { outputs_digest: digest_i32s(&c), run: kr }
            }
            (
                Scenario::IntMatmulPadded { w, cores, pad_words },
                Inputs::IntMatmul { a, b },
            ) => {
                let (m, n, k) = INT_MATMUL_DIMS;
                let (c, kr) = int_matmul::run_padded(cl, l2, a, b, m, n, k, w, cores, pad_words);
                SimResult { outputs_digest: digest_i32s(&c), run: kr }
            }
            (Scenario::FpMatmul { w, cores }, Inputs::FpMatmul { a, b }) => {
                let (m, n, k) = FP_MATMUL_DIMS;
                let (c, kr) = fp_matmul::run(cl, l2, a, b, m, n, k, w, cores);
                SimResult { outputs_digest: digest_f32s(&c), run: kr }
            }
            (Scenario::FpMatmulFpu { w, cores, private_fpu }, Inputs::FpMatmul { a, b }) => {
                let (m, n, k) = FPU_ABLATION_DIMS;
                cl.fpus.private_per_core = private_fpu;
                let (c, kr) = fp_matmul::run(cl, l2, a, b, m, n, k, w, cores);
                cl.fpus.private_per_core = false;
                SimResult { outputs_digest: digest_f32s(&c), run: kr }
            }
            (Scenario::Nsaa { name, w }, inp) => match (name, inp) {
                ("CONV", Inputs::Conv { x, k }) => {
                    let (h, wd) = CONV_HW;
                    let (c, kr) = fp_conv::run(cl, l2, x, k, h, wd, w, 8);
                    SimResult { outputs_digest: digest_f32s(&c), run: kr }
                }
                ("DWT", Inputs::Dwt { x }) => {
                    let (lo, hi, kr) = fp_filters::run_dwt(cl, l2, x, w, 8);
                    let mut d = OutDigest::new();
                    d.f32s(&lo);
                    d.f32s(&hi);
                    SimResult { outputs_digest: d.finish(), run: kr }
                }
                ("FFT", Inputs::Fft { x }) => {
                    let (c, kr) = fp_fft::run(cl, l2, x, w, 8);
                    let mut d = OutDigest::new();
                    for (re, im) in &c {
                        d.f32s(&[*re, *im]);
                    }
                    SimResult { outputs_digest: d.finish(), run: kr }
                }
                ("FIR", Inputs::Fir { taps, x }) => {
                    let (c, kr) = fp_filters::run_fir(cl, l2, x, taps, FIR_N, w, 8);
                    SimResult { outputs_digest: digest_f32s(&c), run: kr }
                }
                ("IIR", Inputs::Iir { chans }) => {
                    let bq = fp_filters::Biquad::lowpass();
                    let (c, kr) = fp_filters::run_iir(cl, l2, chans, bq, bq, w);
                    let mut d = OutDigest::new();
                    for ch in &c {
                        d.f32s(ch);
                    }
                    SimResult { outputs_digest: d.finish(), run: kr }
                }
                ("KMEANS", Inputs::Kmeans { centroids, pts }) => {
                    let (c, kr) = fp_kmeans::run(cl, l2, pts, centroids, w, 8);
                    SimResult { outputs_digest: digest_i32s(&c), run: kr }
                }
                ("SVM", Inputs::Svm { w: wv, b, pts }) => {
                    let (c, kr) = fp_svm::run(cl, l2, pts, wv, b, SVM_DIM, w, 8);
                    SimResult { outputs_digest: digest_i32s(&c), run: kr }
                }
                (other, _) => panic!("scenario/input shape mismatch for NSAA {other}"),
            },
            (s, _) => panic!("scenario/input shape mismatch for {s:?}"),
        }
    }
}

/// The canonical input tensors of one scenario, materialized (ISSUE 6).
///
/// Normal simulation generates these from the fixed seed and consumes
/// them immediately; fault campaigns serialize them ([`Inputs::to_bytes`]),
/// stage the bytes through a memory tier under injected upsets, and
/// rebuild the (possibly corrupted) tensors with [`Scenario::with_bytes`].
#[derive(Debug, Clone, PartialEq)]
pub(crate) enum Inputs {
    IntMatmul { a: Vec<i32>, b: Vec<i32> },
    FpMatmul { a: Vec<f32>, b: Vec<f32> },
    Conv { x: Vec<f32>, k: Vec<f32> },
    Dwt { x: Vec<f32> },
    Fft { x: Vec<(f32, f32)> },
    Fir { taps: Vec<f32>, x: Vec<f32> },
    Iir { chans: Vec<Vec<f32>> },
    Kmeans { centroids: Vec<f32>, pts: Vec<f32> },
    Svm { w: Vec<f32>, b: Vec<f32>, pts: Vec<f32> },
}

impl Inputs {
    /// Serialize every tensor, in declaration order, as little-endian
    /// 4-byte scalars (f32 via its IEEE bit pattern) — the byte image a
    /// fault campaign stages through a memory tier.
    pub(crate) fn to_bytes(&self) -> Vec<u8> {
        fn i32s(out: &mut Vec<u8>, v: &[i32]) {
            for x in v {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        fn f32s(out: &mut Vec<u8>, v: &[f32]) {
            for x in v {
                out.extend_from_slice(&x.to_bits().to_le_bytes());
            }
        }
        let mut out = Vec::new();
        match self {
            Inputs::IntMatmul { a, b } => {
                i32s(&mut out, a);
                i32s(&mut out, b);
            }
            Inputs::FpMatmul { a, b } => {
                f32s(&mut out, a);
                f32s(&mut out, b);
            }
            Inputs::Conv { x, k } => {
                f32s(&mut out, x);
                f32s(&mut out, k);
            }
            Inputs::Dwt { x } => f32s(&mut out, x),
            Inputs::Fft { x } => {
                for &(re, im) in x {
                    f32s(&mut out, &[re, im]);
                }
            }
            Inputs::Fir { taps, x } => {
                f32s(&mut out, taps);
                f32s(&mut out, x);
            }
            Inputs::Iir { chans } => {
                for ch in chans {
                    f32s(&mut out, ch);
                }
            }
            Inputs::Kmeans { centroids, pts } => {
                f32s(&mut out, centroids);
                f32s(&mut out, pts);
            }
            Inputs::Svm { w, b, pts } => {
                f32s(&mut out, w);
                f32s(&mut out, b);
                f32s(&mut out, pts);
            }
        }
        out
    }
}

/// Cursor over a serialized input image (strict: `done` asserts full
/// consumption, so a shape drift can never silently truncate).
struct ImageReader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> ImageReader<'a> {
    fn new(bytes: &'a [u8]) -> Self {
        Self { bytes, pos: 0 }
    }

    fn take4(&mut self) -> [u8; 4] {
        let b: [u8; 4] =
            self.bytes[self.pos..self.pos + 4].try_into().expect("4-byte scalar");
        self.pos += 4;
        b
    }

    fn f32(&mut self) -> f32 {
        f32::from_bits(u32::from_le_bytes(self.take4()))
    }

    fn i32s(&mut self, n: usize) -> Vec<i32> {
        (0..n).map(|_| i32::from_le_bytes(self.take4())).collect()
    }

    fn f32s(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.f32()).collect()
    }

    fn done(self) {
        assert_eq!(self.pos, self.bytes.len(), "input image length mismatch");
    }
}

/// Canonical problem-size triple per NSAA kernel (cache-key component).
fn nsaa_size(name: &str) -> (usize, usize, usize) {
    match name {
        "CONV" => (CONV_HW.0, CONV_HW.1, 9),
        "DWT" => (DWT_N, 0, 0),
        "FFT" => (FFT_N, 0, 0),
        "FIR" => (FIR_N, fp_filters::FIR_TAPS, 0),
        "IIR" => (IIR_CHANNELS, IIR_N, 0),
        "KMEANS" => (KMEANS_POINTS, fp_kmeans::K, fp_kmeans::D),
        "SVM" => (SVM_POINTS, SVM_DIM, fp_svm::CLASSES),
        other => panic!("unknown NSAA kernel {other}"),
    }
}

/// Output-tensor digest over the crate's pinned FNV-1a (bit-exact across
/// runs; f32s are digested by their IEEE bit patterns).
struct OutDigest(crate::common::Fnv1a);

impl OutDigest {
    fn new() -> Self {
        Self(crate::common::Fnv1a::new())
    }

    fn bytes(&mut self, bytes: &[u8]) {
        use std::hash::Hasher;
        self.0.write(bytes);
    }

    fn i32s(&mut self, v: &[i32]) {
        for &x in v {
            self.bytes(&x.to_le_bytes());
        }
    }

    fn f32s(&mut self, v: &[f32]) {
        for &x in v {
            self.bytes(&x.to_bits().to_le_bytes());
        }
    }

    fn finish(self) -> u64 {
        use std::hash::Hasher;
        self.0.finish()
    }
}

fn digest_i32s(v: &[i32]) -> u64 {
    let mut d = OutDigest::new();
    d.i32s(v);
    d.finish()
}

fn digest_f32s(v: &[f32]) -> u64 {
    let mut d = OutDigest::new();
    d.f32s(v);
    d.finish()
}

/// Every shipped kernel program at its canonical sweep dimensions,
/// packaged for static verification (`vega verify`): the assembled
/// [`Program`] plus each core's entry-register state, mirroring the
/// allocation layout the corresponding `run()` driver would set up.
///
/// Covers the full matmul family (three int and three fp precisions)
/// and every NSAA kernel at F32 and F16x2 — the same canonical sizes
/// [`Scenario`] simulates, so a static finding here is a finding about
/// a program the sweep actually executes.
pub fn verify_targets() -> Vec<VerifyTarget> {
    let mut out = Vec::new();
    let (im, in_, ik) = INT_MATMUL_DIMS;
    for w in [IntWidth::I8, IntWidth::I16, IntWidth::I32] {
        out.push(int_matmul::verify_target(im, in_, ik, w, 8));
    }
    let (fm, fn_, fk) = FP_MATMUL_DIMS;
    for w in [FpWidth::F32, FpWidth::F16x2, FpWidth::F8x4] {
        out.push(fp_matmul::verify_target(fm, fn_, fk, w, 8));
    }
    for w in [FpWidth::F32, FpWidth::F16x2] {
        out.push(fp_conv::verify_target(CONV_HW.0, CONV_HW.1, w, 8));
        out.push(fp_filters::verify_target_dwt(DWT_N, w, 8));
        out.push(fp_fft::verify_target(FFT_N, w, 8));
        out.push(fp_filters::verify_target_fir(FIR_N + 16, FIR_N, w, 8));
        out.push(fp_filters::verify_target_iir(IIR_CHANNELS, IIR_N, w));
        out.push(fp_kmeans::verify_target(KMEANS_POINTS, w, 8));
        out.push(fp_svm::verify_target(SVM_POINTS, SVM_DIM, w, 8));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn verify_targets_cover_the_canonical_suite() {
        let ts = verify_targets();
        // 3 int matmul + 3 fp matmul + 7 NSAA kernels × 2 precisions.
        assert_eq!(ts.len(), 20);
        let names: std::collections::BTreeSet<&str> =
            ts.iter().map(|t| t.name.as_str()).collect();
        assert_eq!(names.len(), ts.len(), "target names must be unique");
        for t in &ts {
            assert_eq!(t.entry.len(), t.n_cores, "{}: one entry state per core", t.name);
            assert!(!t.prog.insts.is_empty(), "{}: empty program", t.name);
        }
    }

    #[test]
    fn matmul_row_canonicalises_to_fp_matmul() {
        let a = Scenario::Nsaa { name: "MATMUL", w: FpWidth::F32 };
        let b = Scenario::FpMatmul { w: FpWidth::F32, cores: 8 };
        assert_eq!(a.canonical(), b);
        assert_eq!(a.key(), b.key());
    }

    #[test]
    fn keys_distinguish_precision_cores_and_padding() {
        let base = Scenario::IntMatmul { w: IntWidth::I8, cores: 8 };
        assert_ne!(base.key(), Scenario::IntMatmul { w: IntWidth::I16, cores: 8 }.key());
        assert_ne!(base.key(), Scenario::IntMatmul { w: IntWidth::I8, cores: 4 }.key());
        assert_ne!(
            Scenario::IntMatmulPadded { w: IntWidth::I8, cores: 8, pad_words: 0 }.key(),
            Scenario::IntMatmulPadded { w: IntWidth::I8, cores: 8, pad_words: 1 }.key(),
        );
        assert_ne!(
            Scenario::FpMatmulFpu { w: FpWidth::F32, cores: 8, private_fpu: true }.key(),
            Scenario::FpMatmulFpu { w: FpWidth::F32, cores: 8, private_fpu: false }.key(),
        );
    }

    #[test]
    fn fp8_matmul_scenario_simulates_and_keys_distinctly() {
        let f8 = Scenario::FpMatmul { w: FpWidth::F8x4, cores: 8 };
        assert_eq!(f8.key().precision, "f8x4");
        assert_eq!(f8.key().kernel, "fp_matmul");
        assert_ne!(f8.key(), Scenario::FpMatmul { w: FpWidth::F16x2, cores: 8 }.key());
        assert_ne!(f8.key(), Scenario::FpMatmul { w: FpWidth::F8x4, cores: 4 }.key());
        let mut arena = SimArena::new();
        let a = f8.simulate(&mut arena);
        let b = f8.simulate(&mut arena);
        assert_eq!(a.outputs_digest, b.outputs_digest);
        assert_eq!(a.run.stats, b.run.stats);
        assert_eq!(a.run.name, "fp_matmul_f8");
    }

    #[test]
    fn simulate_is_a_pure_function_of_the_descriptor() {
        let s = Scenario::IntMatmul { w: IntWidth::I8, cores: 4 };
        let mut arena = SimArena::new();
        let a = s.simulate(&mut arena);
        // Interleave an unrelated scenario on the same arena, then re-run.
        let _ = Scenario::Nsaa { name: "FIR", w: FpWidth::F32 }.simulate(&mut arena);
        let b = s.simulate(&mut arena);
        assert_eq!(a.outputs_digest, b.outputs_digest);
        assert_eq!(a.run.stats, b.run.stats);
        assert_eq!(a.run.ops, b.run.ops);
    }

    /// The ISSUE 6 input split is transparent: serializing every
    /// scenario's inputs and rebuilding them from bytes reproduces the
    /// tensors exactly, and running on the rebuilt inputs matches
    /// `simulate` digest-for-digest.
    #[test]
    fn inputs_round_trip_through_bytes_and_match_simulate() {
        let scenarios = [
            Scenario::IntMatmul { w: IntWidth::I16, cores: 2 },
            Scenario::IntMatmulPadded { w: IntWidth::I8, cores: 2, pad_words: 1 },
            Scenario::FpMatmul { w: FpWidth::F32, cores: 2 },
            Scenario::FpMatmulFpu { w: FpWidth::F32, cores: 2, private_fpu: true },
            Scenario::Nsaa { name: "CONV", w: FpWidth::F32 },
            Scenario::Nsaa { name: "DWT", w: FpWidth::F32 },
            Scenario::Nsaa { name: "FFT", w: FpWidth::F32 },
            Scenario::Nsaa { name: "FIR", w: FpWidth::F32 },
            Scenario::Nsaa { name: "IIR", w: FpWidth::F32 },
            Scenario::Nsaa { name: "KMEANS", w: FpWidth::F32 },
            Scenario::Nsaa { name: "SVM", w: FpWidth::F32 },
        ];
        let mut arena = SimArena::new();
        for s in scenarios {
            let inputs = s.gen_inputs();
            let rebuilt = s.with_bytes(&inputs.to_bytes());
            assert_eq!(rebuilt, inputs, "{s:?}: byte round-trip must be exact");
            let via_bytes = s.run_on(&mut arena, &rebuilt);
            let direct = s.simulate(&mut arena);
            assert_eq!(via_bytes.outputs_digest, direct.outputs_digest, "{s:?}");
            assert_eq!(via_bytes.run.stats, direct.run.stats, "{s:?}");
        }
    }

    #[test]
    fn fpu_ablation_restores_the_shared_fabric() {
        let mut arena = SimArena::new();
        let _ = Scenario::FpMatmulFpu { w: FpWidth::F32, cores: 8, private_fpu: true }
            .simulate(&mut arena);
        assert!(!arena.cluster.fpus.private_per_core);
    }
}
