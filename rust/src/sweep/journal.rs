//! Crash-safe checkpoint journal for sweep and fault grids (ISSUE 7).
//!
//! The paper's headline property is state retention across power cycles
//! (1.7 µW MRAM-retentive sleep); this module gives the *host-side*
//! campaign infrastructure the same property: a multi-hour grid survives
//! a killed process without losing completed work. Three pieces:
//!
//! * **Per-grid journal** — an append-only file of checksummed records,
//!   one per completed cell, under `<cache-root>/journals/`. The file is
//!   keyed by a versioned byte encoding of the full grid ([`grid_key`],
//!   built on [`crate::common::ByteWriter`] like every persisted key
//!   since PR 4), so two different grids can never share a journal and a
//!   stale journal is never misapplied. Replay ([`replay`]) is
//!   **torn-tail-tolerant**: a half-written trailing record — the
//!   expected state after `SIGKILL` mid-append — reads as "cell not
//!   done", never as a corruption abort, and resuming truncates the torn
//!   tail before appending so the file stays a valid record prefix.
//! * **Deterministic sharding** ([`ShardSpec`]) — `--shard I/N`
//!   partitions a grid by the FNV-1a hash of each cell's stable ID
//!   (the same content-addressed key strings the [`super::persist`]
//!   store files live under), so N independent processes own disjoint,
//!   machine-independent slices. [`GridMode::Merge`] reassembles the
//!   shard journals into the exact serial-order report.
//! * **[`GridSession`]** — the handle the engine threads share: the
//!   prior-record map consulted before computing a cell, the ownership
//!   predicate, and the (mutex-serialised) append side. Everything is
//!   best-effort: any journal I/O failure warns once, counts in
//!   [`GridSession::write_errors`], disables journaling for the rest of
//!   the run, and the grid completes in memory — a full or read-only
//!   disk degrades, it never panics.
//!
//! ## Journal file format (version [`JOURNAL_VERSION`])
//!
//! ```text
//! header   magic b"VEGAJRNL"              8 bytes
//!          version  u32 LE                JOURNAL_VERSION
//!          grid id  u32 LE len + UTF-8    "{kind}:{grid_key:016x}"
//!          shard    u32 LE index, u32 LE total   (0, 0) = unsharded
//! record*  len      u32 LE                payload byte length
//!          payload  len bytes             see below
//!          checksum u64 LE                FNV-1a of the payload bytes
//! ```
//!
//! Record payload: `cell id` (u32-length-prefixed UTF-8), `status` (u8:
//! 0 done, 1 error, 2 timeout), `digest` (u64 — the result's output
//! digest for done cells, 0 otherwise), `message` (length-prefixed
//! UTF-8 — empty for done cells, the verbatim failure message
//! otherwise, so a resumed grid renders byte-identical status rows).
//!
//! Records are advisory, not authoritative: a done record only asserts
//! "this cell completed and its result is (re)computable through the
//! cache tiers". Losing a record (torn tail, missed append between the
//! disk-store write and the journal append at kill time) costs at most
//! one recomputation — which the [`super::persist::DiskStore`] usually
//! turns into a disk hit anyway. That is why appends are flushed but not
//! fsynced, and why replay prefers "not done" over any strict reading.

use std::collections::HashMap;
use std::fs;
use std::hash::Hasher;
use std::io::{Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, Once};

use crate::common::{ByteReader, ByteWriter, Fnv1a};

/// Journal layout version: part of the header and of [`grid_key`], so a
/// format change orphans old journals (they replay as empty) instead of
/// misreading them.
pub const JOURNAL_VERSION: u32 = 1;

const JRN_MAGIC: &[u8; 8] = b"VEGAJRNL";

/// Upper bound on one record's payload (a cell id plus a panic message);
/// a larger length prefix is garbage, and replay stops there.
const MAX_RECORD_LEN: usize = 1 << 20;

/// Terminal state of one journaled cell.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CellStatus {
    /// The cell completed; its digest is journaled.
    Done,
    /// The cell failed deterministically (or exhausted its transient
    /// retries); its message is journaled and replayed verbatim.
    Error,
    /// The cell exceeded its wall-clock budget.
    Timeout,
}

impl CellStatus {
    fn to_u8(self) -> u8 {
        match self {
            CellStatus::Done => 0,
            CellStatus::Error => 1,
            CellStatus::Timeout => 2,
        }
    }

    fn from_u8(v: u8) -> Option<CellStatus> {
        match v {
            0 => Some(CellStatus::Done),
            1 => Some(CellStatus::Error),
            2 => Some(CellStatus::Timeout),
            _ => None,
        }
    }
}

/// One replayed journal record: a cell that reached a terminal state in
/// a prior (or the current) run of the same grid.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CellRecord {
    /// The cell's stable content-addressed ID (a
    /// [`super::persist`] key string or a
    /// [`crate::faults::Campaign::key`] string).
    pub cell_id: String,
    /// Terminal state.
    pub status: CellStatus,
    /// Output digest of a done cell (0 for error/timeout).
    pub digest: u64,
    /// Verbatim failure message of an error/timeout cell (empty for
    /// done), replayed so resumed status rows are byte-identical.
    pub message: String,
}

/// One slice of a sharded grid: `--shard I/N` (1-based `I`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSpec {
    /// 1-based shard index.
    pub index: u32,
    /// Total shard count.
    pub total: u32,
}

impl ShardSpec {
    /// Parse an `I/N` token (`1 <= I <= N`).
    pub fn parse(s: &str) -> Result<ShardSpec, String> {
        let bad = || format!("--shard must be I/N with 1 <= I <= N, got '{s}'");
        let (i, n) = s.trim().split_once('/').ok_or_else(bad)?;
        let index: u32 = i.trim().parse().map_err(|_| bad())?;
        let total: u32 = n.trim().parse().map_err(|_| bad())?;
        if index == 0 || total == 0 || index > total {
            return Err(bad());
        }
        Ok(ShardSpec { index, total })
    }

    /// Whether this shard owns `cell_id`. The partition is the FNV-1a
    /// hash of the id modulo the shard count — a pure function of the
    /// content-addressed id, so every process (on any machine) agrees on
    /// the slices, and the N slices are disjoint and covering.
    pub fn owns(&self, cell_id: &str) -> bool {
        let mut h = Fnv1a::new();
        h.write(cell_id.as_bytes());
        (h.finish() % self.total as u64) as u32 == self.index - 1
    }
}

impl std::fmt::Display for ShardSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}/{}", self.index, self.total)
    }
}

/// The grid identity a journal is keyed by: an FNV-1a hash over the
/// versioned byte encoding of the grid kind (`"sweep"` / `"faults"` /
/// `"lifecycle"`),
/// its scalar parameters, and every cell's stable ID in grid order. Any
/// change to the grid — a core count, a precision, a seed, a format —
/// changes the key and therefore selects a different journal file; a
/// `--resume` can never skip cells of a *different* grid.
pub fn grid_key(kind: &str, params: &[&str], cell_ids: &[String]) -> u64 {
    let mut e = ByteWriter::with_capacity(64 + 32 * cell_ids.len());
    e.u32(JOURNAL_VERSION);
    e.str(kind);
    e.u32(params.len() as u32);
    for p in params {
        e.str(p);
    }
    e.u32(cell_ids.len() as u32);
    for id in cell_ids {
        e.str(id);
    }
    let mut h = Fnv1a::new();
    h.write(e.as_slice());
    h.finish()
}

/// How a [`GridSession`] treats existing journal state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GridMode {
    /// Truncate any prior journal and record from scratch (the default
    /// CLI behaviour — every run journals, so any run can be resumed).
    Fresh,
    /// Replay the prior journal (torn tail truncated), skip replayed
    /// cells, append the rest (`--resume`).
    Resume,
    /// Read-only union of the `N` shard journals (plus any unsharded
    /// one) of the same grid: reassemble the full serial-order report
    /// without recomputing journaled cells (`--merge N`).
    Merge(u32),
}

/// Default journal root: the `journals/` subdirectory of the cache-dir
/// resolution used by [`super::persist::DiskStore::open_default`]
/// (`$VEGA_CACHE_DIR`, else `$CARGO_TARGET_DIR/vega-cache`, else
/// `target/vega-cache`). Journaling is independent of `VEGA_CACHE=off`:
/// with the store disabled, resumed done-cells recompute (simulations
/// are pure, so the output is still byte-identical).
pub fn default_root() -> PathBuf {
    let dir = match std::env::var_os("VEGA_CACHE_DIR") {
        Some(d) => PathBuf::from(d),
        None => match std::env::var_os("CARGO_TARGET_DIR") {
            Some(t) => Path::new(&t).join("vega-cache"),
            None => PathBuf::from("target").join("vega-cache"),
        },
    };
    dir.join("journals")
}

/// Journal file name for a grid key and optional shard: shards of one
/// grid share a directory but never a file.
fn file_name(key: u64, shard: Option<ShardSpec>) -> String {
    match shard {
        Some(s) => format!("j{key:016x}.s{}of{}.jnl", s.index, s.total),
        None => format!("j{key:016x}.jnl"),
    }
}

fn encode_header(grid_id: &str, shard: Option<ShardSpec>) -> Vec<u8> {
    let mut e = ByteWriter::with_capacity(64);
    e.bytes(JRN_MAGIC);
    e.u32(JOURNAL_VERSION);
    e.str(grid_id);
    e.u32(shard.map_or(0, |s| s.index));
    e.u32(shard.map_or(0, |s| s.total));
    e.into_vec()
}

fn encode_record(rec: &CellRecord) -> Vec<u8> {
    let mut p = ByteWriter::with_capacity(64 + rec.cell_id.len() + rec.message.len());
    p.str(&rec.cell_id);
    p.u8(rec.status.to_u8());
    p.u64(rec.digest);
    p.str(&rec.message);
    let payload = p.into_vec();
    let mut h = Fnv1a::new();
    h.write(&payload);
    let mut e = ByteWriter::with_capacity(payload.len() + 12);
    e.u32(payload.len() as u32);
    e.bytes(&payload);
    e.u64(h.finish());
    e.into_vec()
}

fn decode_record(payload: &[u8]) -> Option<CellRecord> {
    let mut d = ByteReader::new(payload);
    let cell_id = d.str()?;
    let status = CellStatus::from_u8(d.u8()?)?;
    let digest = d.u64()?;
    let message = d.str()?;
    if !d.done() {
        return None;
    }
    Some(CellRecord { cell_id, status, digest, message })
}

/// Replay a journal's bytes against the expected grid identity and shard.
///
/// Returns `None` when the header does not match byte-for-byte (wrong
/// magic, version, grid, or shard — the caller treats the file as
/// belonging to something else and starts fresh). Otherwise returns the
/// valid record prefix plus its end offset: replay *stops* at the first
/// torn or garbage record (bad length, truncated frame, checksum or
/// payload-shape mismatch) — trailing damage costs the records behind
/// it, it never aborts the resume or corrupts a result.
pub fn replay(bytes: &[u8], grid_id: &str, shard: Option<ShardSpec>) -> Option<(Vec<CellRecord>, usize)> {
    let header = encode_header(grid_id, shard);
    if bytes.len() < header.len() || bytes[..header.len()] != header[..] {
        return None;
    }
    let mut off = header.len();
    let mut out = Vec::new();
    while bytes.len() - off >= 4 {
        let len = u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap()) as usize;
        if len > MAX_RECORD_LEN {
            break;
        }
        let end = off + 4 + len + 8;
        if end > bytes.len() {
            break;
        }
        let payload = &bytes[off + 4..off + 4 + len];
        let checksum = u64::from_le_bytes(bytes[end - 8..end].try_into().unwrap());
        let mut h = Fnv1a::new();
        h.write(payload);
        if h.finish() != checksum {
            break;
        }
        let Some(rec) = decode_record(payload) else {
            break;
        };
        out.push(rec);
        off = end;
    }
    Some((out, off))
}

/// Warn exactly once per process that journaling degraded (the grid
/// itself is unaffected — records are advisory).
fn warn_journal_once(what: &str, path: &Path, err: &std::io::Error) {
    static WARN: Once = Once::new();
    WARN.call_once(|| {
        eprintln!(
            "vega: journal disabled ({what} failed at {}: {err}); \
             the grid completes but this run cannot be resumed",
            path.display()
        )
    });
}

fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// The per-grid execution session the engine's worker threads share:
/// shard ownership, replayed prior records, and the append side of the
/// journal. Obtained from [`GridSession::open`] (CLI runs),
/// [`GridSession::with_shard`] (journal-less sharding), or
/// [`GridSession::off`] (the library default: own everything, journal
/// nothing — exactly the pre-ISSUE-7 behaviour).
pub struct GridSession {
    shard: Option<ShardSpec>,
    prior: HashMap<String, CellRecord>,
    file: Mutex<Option<fs::File>>,
    recorded: AtomicU64,
    write_errors: AtomicU64,
}

impl GridSession {
    /// A session that owns every cell, replays nothing and journals
    /// nothing.
    pub fn off() -> GridSession {
        GridSession {
            shard: None,
            prior: HashMap::new(),
            file: Mutex::new(None),
            recorded: AtomicU64::new(0),
            write_errors: AtomicU64::new(0),
        }
    }

    /// A journal-less sharded session: owns this shard's slice, replays
    /// and records nothing (pure in-process partitioning, used by the
    /// library-level shard tests).
    pub fn with_shard(shard: ShardSpec) -> GridSession {
        GridSession { shard: Some(shard), ..GridSession::off() }
    }

    /// Open the journal session for grid `key` of `kind` under `root`.
    ///
    /// * [`GridMode::Fresh`] — truncate any prior journal for this
    ///   (grid, shard) and start recording.
    /// * [`GridMode::Resume`] — replay the prior journal (truncating a
    ///   torn tail so appends extend a valid prefix) and record the
    ///   cells it didn't cover. A missing file, or one belonging to a
    ///   different grid/shard/version, degrades to `Fresh`.
    /// * [`GridMode::Merge`] — read-only union of the grid's shard
    ///   journals (plus any unsharded journal); nothing is recorded.
    ///
    /// Every I/O failure is non-fatal: it warns once, counts in
    /// [`GridSession::write_errors`], and leaves journaling off.
    pub fn open(kind: &str, key: u64, shard: Option<ShardSpec>, mode: GridMode, root: &Path) -> GridSession {
        let grid_id = format!("{kind}:{key:016x}");
        let mut session = GridSession { shard, ..GridSession::off() };

        if let GridMode::Merge(total) = mode {
            session.shard = None;
            for index in 1..=total {
                let s = ShardSpec { index, total };
                let path = root.join(file_name(key, Some(s)));
                session.merge_file(&path, &grid_id, Some(s));
            }
            session.merge_file(&root.join(file_name(key, None)), &grid_id, None);
            return session;
        }

        let path = root.join(file_name(key, shard));
        if let Err(e) = fs::create_dir_all(root) {
            warn_journal_once("creating the journal directory", root, &e);
            session.write_errors.fetch_add(1, Ordering::Relaxed);
            return session;
        }

        let mut valid_len = 0u64;
        if mode == GridMode::Resume {
            if let Ok(bytes) = fs::read(&path) {
                match replay(&bytes, &grid_id, shard) {
                    Some((records, len)) => {
                        valid_len = len as u64;
                        for rec in records {
                            session.prior.insert(rec.cell_id.clone(), rec);
                        }
                    }
                    None => eprintln!(
                        "vega: journal at {} belongs to a different grid or version; \
                         starting fresh",
                        path.display()
                    ),
                }
            }
        }

        let opened = if valid_len > 0 {
            // Extend the replayed prefix: drop the torn tail, append.
            fs::OpenOptions::new().write(true).open(&path).and_then(|mut f| {
                f.set_len(valid_len)?;
                f.seek(SeekFrom::End(0))?;
                Ok(f)
            })
        } else {
            // Fresh journal (also the resume-with-nothing-replayed path):
            // truncate and rewrite the header.
            fs::OpenOptions::new().create(true).write(true).truncate(true).open(&path).and_then(
                |mut f| {
                    f.write_all(&encode_header(&grid_id, shard))?;
                    f.flush()?;
                    Ok(f)
                },
            )
        };
        match opened {
            Ok(f) => *session.file.get_mut().unwrap_or_else(std::sync::PoisonError::into_inner) = Some(f),
            Err(e) => {
                warn_journal_once("opening the journal", &path, &e);
                session.write_errors.fetch_add(1, Ordering::Relaxed);
            }
        }
        session
    }

    /// Fold one shard journal into the prior map (merge mode). A missing
    /// or foreign file is reported and skipped — its cells simply
    /// recompute live through the cache tiers.
    fn merge_file(&mut self, path: &Path, grid_id: &str, shard: Option<ShardSpec>) {
        let Ok(bytes) = fs::read(path) else {
            if shard.is_some() {
                eprintln!(
                    "vega: merge: no journal at {} (its cells recompute live)",
                    path.display()
                );
            }
            return;
        };
        match replay(&bytes, grid_id, shard) {
            Some((records, _)) => {
                for rec in records {
                    self.prior.insert(rec.cell_id.clone(), rec);
                }
            }
            None => eprintln!(
                "vega: merge: journal at {} belongs to a different grid or version; skipped",
                path.display()
            ),
        }
    }

    /// Whether this session's shard owns `cell_id` (always true when
    /// unsharded).
    pub fn owns(&self, cell_id: &str) -> bool {
        self.shard.map_or(true, |s| s.owns(cell_id))
    }

    /// The replayed prior record of `cell_id`, if any.
    pub fn prior(&self, cell_id: &str) -> Option<&CellRecord> {
        self.prior.get(cell_id)
    }

    /// Number of prior records replayed at open.
    pub fn prior_count(&self) -> u64 {
        self.prior.len() as u64
    }

    /// Append one terminal-cell record (best-effort; flushed, not
    /// fsynced — see the module docs on why records are advisory). Any
    /// write failure warns once, counts, and disables further appends.
    pub fn record(&self, cell_id: &str, status: CellStatus, digest: u64, message: &str) {
        let mut guard = lock_unpoisoned(&self.file);
        let Some(f) = guard.as_mut() else { return };
        let rec = CellRecord {
            cell_id: cell_id.to_string(),
            status,
            digest,
            message: message.to_string(),
        };
        let bytes = encode_record(&rec);
        match f.write_all(&bytes).and_then(|_| f.flush()) {
            Ok(()) => {
                self.recorded.fetch_add(1, Ordering::Relaxed);
            }
            Err(e) => {
                warn_journal_once("appending a record", Path::new("<journal>"), &e);
                self.write_errors.fetch_add(1, Ordering::Relaxed);
                *guard = None;
            }
        }
    }

    /// Number of records appended by this session.
    pub fn recorded(&self) -> u64 {
        self.recorded.load(Ordering::Relaxed)
    }

    /// Number of journal I/O failures absorbed (warn-once, then counted
    /// silently).
    pub fn write_errors(&self) -> u64 {
        self.write_errors.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::Rng;

    fn rec(id: &str, status: CellStatus, digest: u64, message: &str) -> CellRecord {
        CellRecord { cell_id: id.into(), status, digest, message: message.into() }
    }

    fn sample_journal(grid_id: &str, shard: Option<ShardSpec>) -> (Vec<u8>, Vec<CellRecord>) {
        let records = vec![
            rec("cell-a", CellStatus::Done, 0xDEAD_BEEF, ""),
            rec("cell-b", CellStatus::Error, 0, "unknown NSAA kernel BOGUS"),
            rec("cell-c", CellStatus::Timeout, 0, "timeout after 5 ms"),
        ];
        let mut bytes = encode_header(grid_id, shard);
        for r in &records {
            bytes.extend_from_slice(&encode_record(r));
        }
        (bytes, records)
    }

    #[test]
    fn shard_parse_accepts_i_of_n_and_rejects_malformed() {
        assert_eq!(ShardSpec::parse("1/2").unwrap(), ShardSpec { index: 1, total: 2 });
        assert_eq!(ShardSpec::parse(" 3/8 ").unwrap(), ShardSpec { index: 3, total: 8 });
        assert_eq!(ShardSpec::parse("1/1").unwrap().to_string(), "1/1");
        for bad in ["0/2", "3/2", "1/0", "x/2", "1/y", "12", "", "1/2/3"] {
            assert!(ShardSpec::parse(bad).is_err(), "'{bad}' must be rejected");
        }
    }

    /// The shard partition is disjoint and covering for any N: every id
    /// is owned by exactly one of the N shards.
    #[test]
    fn shard_partition_is_disjoint_and_covering() {
        let ids: Vec<String> = (0..100).map(|i| format!("matmul_i8|16x16x16|int8|{i}c|{i:016x}")).collect();
        for total in [1u32, 2, 3, 7] {
            for id in &ids {
                let owners: Vec<u32> = (1..=total)
                    .filter(|&index| ShardSpec { index, total }.owns(id))
                    .collect();
                assert_eq!(owners.len(), 1, "N={total}: '{id}' owned by {owners:?}");
            }
        }
        // The partition actually splits (not everything on one shard).
        let on_first = ids.iter().filter(|id| ShardSpec { index: 1, total: 2 }.owns(id)).count();
        assert!(on_first > 0 && on_first < ids.len(), "1/2 owns {on_first}/100");
    }

    #[test]
    fn grid_key_is_stable_and_sensitive_to_every_input() {
        let ids = vec!["a".to_string(), "b".to_string()];
        let k = grid_key("sweep", &["dvfs=4", "format=csv"], &ids);
        assert_eq!(k, grid_key("sweep", &["dvfs=4", "format=csv"], &ids), "deterministic");
        assert_ne!(k, grid_key("faults", &["dvfs=4", "format=csv"], &ids), "kind");
        assert_ne!(k, grid_key("sweep", &["dvfs=5", "format=csv"], &ids), "params");
        assert_ne!(k, grid_key("sweep", &["dvfs=4", "format=csv"], &ids[..1].to_vec()), "cells");
        let swapped = vec!["b".to_string(), "a".to_string()];
        assert_ne!(k, grid_key("sweep", &["dvfs=4", "format=csv"], &swapped), "cell order");
    }

    #[test]
    fn replay_round_trips_and_rejects_foreign_headers() {
        let (bytes, records) = sample_journal("sweep:00000000000000ab", None);
        let (got, len) = replay(&bytes, "sweep:00000000000000ab", None).unwrap();
        assert_eq!(got, records);
        assert_eq!(len, bytes.len());
        // Wrong grid, wrong shard, wrong version: not this journal.
        assert!(replay(&bytes, "sweep:00000000000000ac", None).is_none());
        assert!(replay(&bytes, "sweep:00000000000000ab", Some(ShardSpec { index: 1, total: 2 })).is_none());
        let mut wrong_version = bytes.clone();
        wrong_version[8] ^= 0xFF;
        assert!(replay(&wrong_version, "sweep:00000000000000ab", None).is_none());
    }

    /// Torn-tail tolerance: every possible truncation point reads back
    /// as a valid record *prefix* — never a parse abort — and the valid
    /// length points at the end of that prefix.
    #[test]
    fn every_truncation_reads_as_a_record_prefix() {
        let grid_id = "faults:0000000000000007";
        let (bytes, records) = sample_journal(grid_id, None);
        let header_len = encode_header(grid_id, None).len();
        for cut in 0..bytes.len() {
            let out = replay(&bytes[..cut], grid_id, None);
            if cut < header_len {
                assert!(out.is_none(), "cut {cut}: inside the header");
                continue;
            }
            let (got, len) = out.expect("header intact");
            assert!(len <= cut, "cut {cut}");
            assert_eq!(got[..], records[..got.len()], "cut {cut}: must be a prefix");
            // Everything up to `len` replays identically on the real file.
            let (again, len2) = replay(&bytes[..len], grid_id, None).unwrap();
            assert_eq!(again, got, "cut {cut}");
            assert_eq!(len2, len, "cut {cut}");
        }
    }

    /// Seeded single-byte corruption fuzz in the style of the PR 6 store
    /// fuzzer: any flipped byte in the record region yields a prefix of
    /// the true records (usually shorter), never a panic and never a
    /// record that differs from the one actually written.
    #[test]
    fn seeded_garbage_fuzz_always_replays_a_true_prefix() {
        let grid_id = "sweep:00000000000000ff";
        let (bytes, records) = sample_journal(grid_id, None);
        let header_len = encode_header(grid_id, None).len();
        let mut rng = Rng::new(0x70C4);
        for _ in 0..64 {
            let off = header_len + rng.below((bytes.len() - header_len) as u64) as usize;
            let xor = 1 + rng.below(255) as u8;
            let mut bad = bytes.clone();
            bad[off] ^= xor;
            let (got, len) = replay(&bad, grid_id, None).expect("header untouched");
            assert!(len <= bad.len());
            // A mutated record can only be *dropped* (checksum/shape
            // mismatch stops the replay) — anything replayed matches the
            // original prefix byte-for-byte.
            assert_eq!(got[..], records[..got.len()], "byte {off} ^ {xor:#04x}");
            assert!(got.len() < records.len(), "byte {off} ^ {xor:#04x}: a flip must cost its record");
        }
        // Garbage *appended* after valid records costs nothing.
        let mut trailing = bytes.clone();
        trailing.extend_from_slice(&[0xFF; 13]);
        let (got, len) = replay(&trailing, grid_id, None).unwrap();
        assert_eq!(got, records);
        assert_eq!(len, bytes.len(), "valid length excludes the garbage tail");
    }

    fn temp_root(case: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("vega-journal-test-{}-{case}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn session_fresh_record_resume_cycle() {
        let root = temp_root("cycle");
        let s = GridSession::open("sweep", 0xAB, None, GridMode::Fresh, &root);
        assert_eq!((s.prior_count(), s.write_errors()), (0, 0));
        s.record("cell-a", CellStatus::Done, 7, "");
        s.record("cell-b", CellStatus::Error, 0, "boom");
        assert_eq!(s.recorded(), 2);
        drop(s);

        let s = GridSession::open("sweep", 0xAB, None, GridMode::Resume, &root);
        assert_eq!(s.prior_count(), 2);
        assert_eq!(s.prior("cell-a").unwrap().digest, 7);
        assert_eq!(s.prior("cell-b").unwrap().message, "boom");
        assert!(s.prior("cell-c").is_none());
        s.record("cell-c", CellStatus::Timeout, 0, "timeout after 1 ms");
        drop(s);

        // Appends extended the replayed prefix: all three survive.
        let s = GridSession::open("sweep", 0xAB, None, GridMode::Resume, &root);
        assert_eq!(s.prior_count(), 3);
        // A different grid key never sees these records.
        let other = GridSession::open("sweep", 0xAC, None, GridMode::Resume, &root);
        assert_eq!(other.prior_count(), 0);
        // Fresh mode truncates.
        let fresh = GridSession::open("sweep", 0xAB, None, GridMode::Fresh, &root);
        assert_eq!(fresh.prior_count(), 0);
        drop(fresh);
        let s = GridSession::open("sweep", 0xAB, None, GridMode::Resume, &root);
        assert_eq!(s.prior_count(), 0, "fresh truncated the journal");

        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn resume_truncates_a_torn_tail_and_appends_after_it() {
        let root = temp_root("torn");
        let s = GridSession::open("faults", 0x77, None, GridMode::Fresh, &root);
        s.record("cell-a", CellStatus::Done, 1, "");
        s.record("cell-b", CellStatus::Done, 2, "");
        drop(s);
        let path = root.join(file_name(0x77, None));
        let bytes = fs::read(&path).unwrap();
        fs::write(&path, &bytes[..bytes.len() - 7]).unwrap(); // tear the last record

        let s = GridSession::open("faults", 0x77, None, GridMode::Resume, &root);
        assert_eq!(s.prior_count(), 1, "the torn record reads as not-done");
        s.record("cell-b", CellStatus::Done, 2, "");
        s.record("cell-c", CellStatus::Done, 3, "");
        drop(s);

        let s = GridSession::open("faults", 0x77, None, GridMode::Resume, &root);
        assert_eq!(s.prior_count(), 3, "appends extended the truncated prefix");

        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn sharded_sessions_use_distinct_files_and_merge_unions_them() {
        let root = temp_root("merge");
        let s1 = ShardSpec { index: 1, total: 2 };
        let s2 = ShardSpec { index: 2, total: 2 };
        let a = GridSession::open("sweep", 0x5A, Some(s1), GridMode::Fresh, &root);
        let b = GridSession::open("sweep", 0x5A, Some(s2), GridMode::Fresh, &root);
        a.record("cell-a", CellStatus::Done, 1, "");
        b.record("cell-b", CellStatus::Done, 2, "");
        b.record("cell-c", CellStatus::Error, 0, "boom");
        drop(a);
        drop(b);

        let merged = GridSession::open("sweep", 0x5A, None, GridMode::Merge(2), &root);
        assert_eq!(merged.prior_count(), 3);
        assert!(merged.owns("cell-a") && merged.owns("cell-b"), "merge owns everything");
        merged.record("cell-d", CellStatus::Done, 4, "");
        assert_eq!(merged.recorded(), 0, "merge sessions are read-only");

        // Merging more shards than exist: the missing ones just warn.
        let partial = GridSession::open("sweep", 0x5A, None, GridMode::Merge(3), &root);
        assert_eq!(partial.prior_count(), 3);

        let _ = fs::remove_dir_all(&root);
    }

    /// Acceptance (c): an unusable journal root degrades to a counted
    /// warning, never a panic, and the session still owns its cells.
    #[test]
    fn unusable_root_degrades_without_panicking() {
        let root = temp_root("degraded");
        fs::create_dir_all(root.parent().unwrap()).unwrap();
        fs::write(&root, b"a file where the journal dir should be").unwrap();
        let s = GridSession::open("sweep", 0x99, None, GridMode::Fresh, &root);
        assert_eq!(s.write_errors(), 1);
        assert!(s.owns("anything"));
        s.record("cell-a", CellStatus::Done, 1, "");
        assert_eq!(s.recorded(), 0, "journaling is off, the run continues");
        let _ = fs::remove_file(&root);
    }
}
