//! The simulation memo: each distinct key is computed exactly once per
//! cache lifetime, even under concurrent lookups.
//!
//! This layer is in-memory only and may use derived `Hash`/`HashMap`
//! machinery freely; everything *persisted* (the on-disk key strings and
//! payloads of [`crate::sweep::persist`]) is byte-defined by the
//! explicit encoders instead.
//!
//! Concurrency protocol (`OnceMap`): the global map only hands out
//! per-key slots; the computation itself runs while holding that key's
//! slot lock, so a second worker asking for an in-flight key blocks until
//! the first finishes and then reads the stored result (no duplicated
//! simulation, no global lock held during multi-millisecond simulations).
//! Hit/miss totals are therefore deterministic for a fixed lookup
//! multiset regardless of the worker count: `misses == distinct keys`,
//! `hits == lookups - misses`.

use std::collections::HashMap;
use std::hash::Hash;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

use super::scenario::{SimKey, SimResult};

/// Lock a mutex, recovering from poisoning (ISSUE 6): a `compute` that
/// panicked while holding a slot lock leaves the slot `None` — nothing
/// was cached — so the only correct recovery is to carry on and let the
/// next lookup recompute. Without this, one panicking scenario would
/// poison its memo slot and turn every later lookup of any key touching
/// the same mutex into a second panic.
fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Generic compute-once map with hit/miss counters (backs the scenario
/// cache and the engine's network-report memo).
pub(crate) struct OnceMap<K, V> {
    enabled: bool,
    entries: Mutex<HashMap<K, Arc<Mutex<Option<V>>>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl<K: Eq + Hash, V: Clone> OnceMap<K, V> {
    pub(crate) fn new(enabled: bool) -> Self {
        Self {
            enabled,
            entries: Mutex::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Look `key` up, running `compute` (exactly once per distinct key)
    /// on miss. With `enabled = false` every lookup recomputes.
    pub(crate) fn get_or_compute(&self, key: K, compute: impl FnOnce() -> V) -> V {
        if !self.enabled {
            self.misses.fetch_add(1, Ordering::Relaxed);
            return compute();
        }
        let slot = {
            let mut map = lock_unpoisoned(&self.entries);
            Arc::clone(map.entry(key).or_insert_with(|| Arc::new(Mutex::new(None))))
        };
        let mut guard = lock_unpoisoned(&slot);
        match &*guard {
            Some(cached) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                cached.clone()
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                let value = compute();
                *guard = Some(value.clone());
                value
            }
        }
    }

    pub(crate) fn counters(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }

    pub(crate) fn len(&self) -> usize {
        lock_unpoisoned(&self.entries).len()
    }

    pub(crate) fn enabled(&self) -> bool {
        self.enabled
    }
}

/// Keyed kernel-simulation results plus hit/miss counters.
pub struct SimCache {
    map: OnceMap<SimKey, SimResult>,
}

impl SimCache {
    pub fn new() -> Self {
        Self::with_enabled(true)
    }

    /// `enabled = false` turns every lookup into a fresh simulation (the
    /// memoization-off baseline of `cargo bench --bench sweeps`).
    pub fn with_enabled(enabled: bool) -> Self {
        Self { map: OnceMap::new(enabled) }
    }

    /// Look `key` up, running `sim` (exactly once per distinct key) on miss.
    pub fn get_or_sim(&self, key: SimKey, sim: impl FnOnce() -> SimResult) -> SimResult {
        self.map.get_or_compute(key, sim)
    }

    /// (hits, misses) so far. With the cache enabled, `misses` equals the
    /// number of distinct keys ever looked up.
    pub fn counters(&self) -> (u64, u64) {
        self.map.counters()
    }

    /// Number of distinct keys resident.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Whether lookups are memoized (false = every lookup re-simulates).
    pub fn enabled(&self) -> bool {
        self.map.enabled()
    }
}

impl Default for SimCache {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::fp_matmul::FpWidth;
    use crate::sweep::{Scenario, SimArena};

    fn key_a() -> SimKey {
        Scenario::FpMatmul { w: FpWidth::F32, cores: 2 }.key()
    }

    fn result_a() -> SimResult {
        Scenario::FpMatmul { w: FpWidth::F32, cores: 2 }.simulate(&mut SimArena::new())
    }

    #[test]
    fn second_lookup_hits_without_simulating() {
        let cache = SimCache::new();
        let mut sims = 0;
        for _ in 0..3 {
            cache.get_or_sim(key_a(), || {
                sims += 1;
                result_a()
            });
        }
        assert_eq!(sims, 1);
        assert_eq!(cache.counters(), (2, 1));
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn disabled_cache_always_simulates() {
        let cache = SimCache::with_enabled(false);
        let mut sims = 0;
        for _ in 0..2 {
            cache.get_or_sim(key_a(), || {
                sims += 1;
                result_a()
            });
        }
        assert_eq!(sims, 2);
        assert_eq!(cache.counters(), (0, 2));
        assert!(cache.is_empty());
    }

    /// A panicking compute caches nothing and poisons nothing: the next
    /// lookup of the same key recomputes, and the one after that hits.
    #[test]
    fn panicked_compute_poisons_nothing_and_recomputes() {
        let m: OnceMap<u32, u32> = OnceMap::new(true);
        let attempt = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            m.get_or_compute(1, || panic!("injected"))
        }));
        assert!(attempt.is_err());
        assert_eq!(m.get_or_compute(1, || 7), 7, "recompute after the panic");
        assert_eq!(m.get_or_compute(1, || 8), 7, "the recomputed value is cached");
        assert_eq!(m.counters(), (1, 2), "panic attempt + recompute are misses");
    }

    #[test]
    fn once_map_is_generic_over_values() {
        let m: OnceMap<&'static str, u32> = OnceMap::new(true);
        assert_eq!(m.get_or_compute("a", || 1), 1);
        assert_eq!(m.get_or_compute("a", || 2), 1);
        assert_eq!(m.get_or_compute("b", || 3), 3);
        assert_eq!(m.counters(), (1, 2));
        assert_eq!(m.len(), 2);
    }
}
