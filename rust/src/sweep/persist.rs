//! Persistent on-disk [`SimResult`] store: one file per [`SimKey`],
//! shared across processes by every *persistent* engine — the `vega`
//! CLI's repro/sweep commands and anything built on
//! [`crate::sweep::SweepEngine::persistent`] /
//! [`crate::sweep::SweepEngine::global`].
//!
//! The in-memory [`crate::sweep::SimCache`] dies with its engine, so
//! every CLI invocation used to re-simulate the same programs. The
//! [`DiskStore`] sits *inside* the in-memory cache's compute closure: an
//! in-memory miss first probes the store, and only simulates (then
//! writes back) when the disk misses too. In-memory hit/miss semantics —
//! and therefore every counter the determinism tests assert — are
//! unchanged by the disk layer. The *test suite* deliberately stays off
//! the shared store: the regression oracles (`paper_anchors`,
//! `sweep_determinism`, the coordinator unit tests) run memory-only so a
//! stale entry can never satisfy them, and `tests/disk_cache.rs`
//! exercises persistence against private per-test directories.
//!
//! ## File format (version [`STORE_VERSION`], model epoch [`MODEL_EPOCH`])
//!
//! ```text
//! magic    b"VEGASIMC"                    8 bytes
//! version  u32 LE  = STORE_VERSION        layout of this very file
//! epoch    u32 LE  = MODEL_EPOCH          timing-model generation
//! key      u32 LE length + UTF-8 bytes    full SimKey echo (collision guard)
//! payload  u64 LE length + bytes          serialized SimResult
//! checksum u64 LE                         FNV-1a of the payload bytes
//! ```
//!
//! Reads are corruption-tolerant by construction: any mismatch — magic,
//! version, epoch, key echo, truncation, checksum, trailing garbage —
//! makes [`DiskStore::load`] return `None` and the caller re-simulates
//! (overwriting the entry). Writes go to a per-process temp file and are
//! `rename`d into place, so a concurrent reader can never observe a
//! partial entry and concurrent writers of the same key race benignly
//! (both write identical bytes: simulations are pure).
//!
//! ## Staleness guards
//!
//! * A *kernel* change changes `Program::content_hash`, which is part of
//!   the [`SimKey`] (and of the file name), so stale entries are simply
//!   never looked up again.
//! * A *timing-model* change (scheduler, stall costs) can change the
//!   stats of an unchanged program. Bump [`MODEL_EPOCH`] with any such
//!   change; every older entry then reads as a miss.
//! * `Program::content_hash` feeds derived `Hash` impls, which Rust does
//!   not guarantee stable across toolchains — after a toolchain change,
//!   old entries are orphaned (never hit), not wrong. `ROADMAP.md` tracks
//!   the explicit `Inst` byte serialization that would make keys
//!   toolchain-portable.
//!
//! The store location is `$VEGA_CACHE_DIR` if set, else
//! `$CARGO_TARGET_DIR/vega-cache`, else `target/vega-cache` relative to
//! the working directory; `VEGA_CACHE=off` disables persistence entirely
//! (see [`DiskStore::open_default`]).

use std::fs;
use std::hash::Hasher;
use std::io;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use super::scenario::{SimKey, SimResult};
use crate::cluster::ClusterStats;
use crate::iss::stats::{ClassCounts, CoreStats};
use crate::kernels::KernelRun;

/// On-disk layout version of one store entry. Bump when the serialized
/// byte layout itself changes.
pub const STORE_VERSION: u32 = 1;

/// Timing-model generation. Bump whenever a change to the simulator can
/// alter the [`ClusterStats`] of an *unchanged* program (scheduler
/// rework, stall-cost recalibration, arbitration changes) — the program
/// content hash cannot see those, and a stale entry would otherwise serve
/// pre-change cycle counts.
pub const MODEL_EPOCH: u32 = 1;

const MAGIC: &[u8; 8] = b"VEGASIMC";

/// A directory of serialized [`SimResult`]s, one file per [`SimKey`].
///
/// All methods are best-effort and lock-free: `load` treats every failure
/// mode as a miss, `store` silently drops entries it cannot write (a
/// read-only cache directory degrades to the in-memory-only behaviour,
/// it never fails a simulation).
pub struct DiskStore {
    dir: PathBuf,
    hits: AtomicU64,
    misses: AtomicU64,
    writes: AtomicU64,
    /// Per-process temp-file disambiguator (concurrent writers).
    tmp_seq: AtomicU64,
}

impl DiskStore {
    /// Open (creating if needed) a store rooted at `dir`.
    pub fn at(dir: impl Into<PathBuf>) -> io::Result<Self> {
        let dir = dir.into();
        fs::create_dir_all(&dir)?;
        Ok(Self {
            dir,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            writes: AtomicU64::new(0),
            tmp_seq: AtomicU64::new(0),
        })
    }

    /// Open the default store: `$VEGA_CACHE_DIR` if set, else
    /// `$CARGO_TARGET_DIR/vega-cache`, else `target/vega-cache`.
    /// Returns `Ok(None)` when persistence is disabled via
    /// `VEGA_CACHE=off` (or `0`).
    pub fn open_default() -> io::Result<Option<Self>> {
        if let Ok(v) = std::env::var("VEGA_CACHE") {
            if v == "off" || v == "0" {
                return Ok(None);
            }
        }
        let dir = match std::env::var_os("VEGA_CACHE_DIR") {
            Some(d) => PathBuf::from(d),
            None => match std::env::var_os("CARGO_TARGET_DIR") {
                Some(t) => Path::new(&t).join("vega-cache"),
                None => PathBuf::from("target").join("vega-cache"),
            },
        };
        Self::at(dir).map(Some)
    }

    /// The directory entries live in.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// (hits, misses, writes) so far. Every [`DiskStore::load`] counts as
    /// exactly one hit or miss; every successful [`DiskStore::store`] as
    /// one write.
    pub fn counters(&self) -> (u64, u64, u64) {
        (
            self.hits.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
            self.writes.load(Ordering::Relaxed),
        )
    }

    /// Look `key` up. Any read/format/checksum failure is a miss.
    pub fn load(&self, key: &SimKey) -> Option<SimResult> {
        let res = fs::read(self.path_for(key)).ok().and_then(|bytes| decode_entry(key, &bytes));
        match &res {
            Some(_) => self.hits.fetch_add(1, Ordering::Relaxed),
            None => self.misses.fetch_add(1, Ordering::Relaxed),
        };
        res
    }

    /// Write `result` under `key` (atomic temp-file + rename;
    /// best-effort — errors are swallowed, the entry is simply absent).
    pub fn store(&self, key: &SimKey, result: &SimResult) {
        let bytes = encode_entry(key, result);
        let tmp = self.dir.join(format!(
            ".tmp-{}-{}",
            std::process::id(),
            self.tmp_seq.fetch_add(1, Ordering::Relaxed)
        ));
        if fs::write(&tmp, &bytes).is_ok() && fs::rename(&tmp, self.path_for(key)).is_ok() {
            self.writes.fetch_add(1, Ordering::Relaxed);
        } else {
            // Drop the temp file whether the write or the rename failed —
            // names are never reused, so litter would accumulate forever.
            let _ = fs::remove_file(&tmp);
        }
    }

    /// File an entry lives in: an FNV-1a tag of the canonical key string
    /// (the full string is echoed inside the file, so a tag collision
    /// reads as a miss, never as wrong data).
    fn path_for(&self, key: &SimKey) -> PathBuf {
        let mut h = crate::common::Fnv1a::new();
        h.write(key_string(key).as_bytes());
        self.dir.join(format!("{:016x}.sim", h.finish()))
    }
}

/// Canonical textual form of a [`SimKey`] (file-name tag + in-file echo).
fn key_string(key: &SimKey) -> String {
    format!(
        "{}|{}x{}x{}|{}|{}c|{:016x}",
        key.kernel, key.size.0, key.size.1, key.size.2, key.precision, key.cores, key.prog_hash
    )
}

// ---------------------------------------------------------------------
// Byte-level encode/decode (std-only; serde is unavailable offline).
// ---------------------------------------------------------------------

struct Enc(Vec<u8>);

impl Enc {
    fn u32(&mut self, v: u32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }

    fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.0.extend_from_slice(s.as_bytes());
    }
}

struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn take(&mut self, n: usize) -> Option<&'a [u8]> {
        let end = self.pos.checked_add(n)?;
        if end > self.buf.len() {
            return None;
        }
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Some(s)
    }

    fn u32(&mut self) -> Option<u32> {
        Some(u32::from_le_bytes(self.take(4)?.try_into().ok()?))
    }

    fn u64(&mut self) -> Option<u64> {
        Some(u64::from_le_bytes(self.take(8)?.try_into().ok()?))
    }

    fn f64(&mut self) -> Option<f64> {
        Some(f64::from_bits(self.u64()?))
    }

    fn str(&mut self) -> Option<String> {
        let n = self.u32()? as usize;
        String::from_utf8(self.take(n)?.to_vec()).ok()
    }

    fn done(&self) -> bool {
        self.pos == self.buf.len()
    }
}

fn encode_core_stats(e: &mut Enc, s: &CoreStats) {
    e.u64(s.cycles);
    e.u64(s.retired);
    e.u64(s.int_ops);
    e.u64(s.flops);
    e.u64(s.bytes_loaded);
    e.u64(s.bytes_stored);
    e.u64(s.stall_loaduse);
    e.u64(s.stall_tcdm);
    e.u64(s.stall_fpu);
    e.u64(s.stall_divsqrt);
    e.u64(s.stall_icache);
    e.u64(s.stall_barrier);
    e.u64(s.branch_penalty);
    e.u64(s.multicycle_busy);
    let c = &s.by_class;
    for v in [c.alu, c.mul, c.div, c.load, c.store, c.branch, c.fp, c.simd, c.control] {
        e.u64(v);
    }
}

fn decode_core_stats(d: &mut Dec) -> Option<CoreStats> {
    Some(CoreStats {
        cycles: d.u64()?,
        retired: d.u64()?,
        int_ops: d.u64()?,
        flops: d.u64()?,
        bytes_loaded: d.u64()?,
        bytes_stored: d.u64()?,
        stall_loaduse: d.u64()?,
        stall_tcdm: d.u64()?,
        stall_fpu: d.u64()?,
        stall_divsqrt: d.u64()?,
        stall_icache: d.u64()?,
        stall_barrier: d.u64()?,
        branch_penalty: d.u64()?,
        multicycle_busy: d.u64()?,
        by_class: ClassCounts {
            alu: d.u64()?,
            mul: d.u64()?,
            div: d.u64()?,
            load: d.u64()?,
            store: d.u64()?,
            branch: d.u64()?,
            fp: d.u64()?,
            simd: d.u64()?,
            control: d.u64()?,
        },
    })
}

fn encode_payload(r: &SimResult) -> Vec<u8> {
    let mut e = Enc(Vec::with_capacity(2048));
    e.u64(r.outputs_digest);
    e.str(&r.run.name);
    e.u64(r.run.ops);
    let s = &r.run.stats;
    e.u64(s.cycles);
    e.f64(s.tcdm_conflict_rate);
    e.f64(s.fpu_contention_rate);
    e.u64(s.barrier_gated_cycles);
    encode_core_stats(&mut e, &s.total);
    e.u32(s.per_core.len() as u32);
    for core in &s.per_core {
        encode_core_stats(&mut e, core);
    }
    e.0
}

fn decode_payload(bytes: &[u8]) -> Option<SimResult> {
    let mut d = Dec { buf: bytes, pos: 0 };
    let outputs_digest = d.u64()?;
    let name = d.str()?;
    let ops = d.u64()?;
    let cycles = d.u64()?;
    let tcdm_conflict_rate = d.f64()?;
    let fpu_contention_rate = d.f64()?;
    let barrier_gated_cycles = d.u64()?;
    let total = decode_core_stats(&mut d)?;
    let n = d.u32()? as usize;
    // Per-core lists are bounded by the 9-core cluster; reject anything
    // larger outright rather than trusting a corrupt length prefix.
    if n > crate::cluster::N_CORES {
        return None;
    }
    let mut per_core = Vec::with_capacity(n);
    for _ in 0..n {
        per_core.push(decode_core_stats(&mut d)?);
    }
    if !d.done() {
        return None;
    }
    Some(SimResult {
        run: KernelRun::new(
            name,
            ClusterStats {
                cycles,
                per_core,
                total,
                tcdm_conflict_rate,
                fpu_contention_rate,
                barrier_gated_cycles,
            },
            ops,
        ),
        outputs_digest,
    })
}

fn encode_entry(key: &SimKey, result: &SimResult) -> Vec<u8> {
    let payload = encode_payload(result);
    let mut h = crate::common::Fnv1a::new();
    h.write(&payload);
    let mut e = Enc(Vec::with_capacity(payload.len() + 64));
    e.0.extend_from_slice(MAGIC);
    e.u32(STORE_VERSION);
    e.u32(MODEL_EPOCH);
    e.str(&key_string(key));
    e.u64(payload.len() as u64);
    e.0.extend_from_slice(&payload);
    e.u64(h.finish());
    e.0
}

fn decode_entry(key: &SimKey, bytes: &[u8]) -> Option<SimResult> {
    let mut d = Dec { buf: bytes, pos: 0 };
    if d.take(MAGIC.len())? != MAGIC {
        return None;
    }
    if d.u32()? != STORE_VERSION || d.u32()? != MODEL_EPOCH {
        return None;
    }
    if d.str()? != key_string(key) {
        return None;
    }
    let len = d.u64()? as usize;
    let payload = d.take(len)?;
    let checksum = d.u64()?;
    if !d.done() {
        return None;
    }
    let mut h = crate::common::Fnv1a::new();
    h.write(payload);
    if h.finish() != checksum {
        return None;
    }
    decode_payload(payload)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::int_matmul::IntWidth;
    use crate::sweep::{Scenario, SimArena};

    fn sample() -> (SimKey, SimResult) {
        let s = Scenario::IntMatmul { w: IntWidth::I8, cores: 2 };
        (s.key(), s.simulate(&mut SimArena::new()))
    }

    fn assert_same(a: &SimResult, b: &SimResult) {
        assert_eq!(a.outputs_digest, b.outputs_digest);
        assert_eq!(a.run.name, b.run.name);
        assert_eq!(a.run.ops, b.run.ops);
        assert_eq!(a.run.stats, b.run.stats);
    }

    #[test]
    fn payload_round_trips_bit_exactly() {
        let (_, r) = sample();
        let back = decode_payload(&encode_payload(&r)).unwrap();
        assert_same(&r, &back);
    }

    #[test]
    fn entry_round_trips_and_guards_the_key() {
        let (key, r) = sample();
        let bytes = encode_entry(&key, &r);
        assert_same(&r, &decode_entry(&key, &bytes).unwrap());
        // Same bytes probed under a different key (tag collision) = miss.
        let other = Scenario::IntMatmul { w: IntWidth::I8, cores: 3 }.key();
        assert!(decode_entry(&other, &bytes).is_none());
    }

    #[test]
    fn version_epoch_truncation_and_checksum_mismatches_are_misses() {
        let (key, r) = sample();
        let good = encode_entry(&key, &r);

        let mut wrong_version = good.clone();
        wrong_version[8] ^= 0xFF; // first byte of the version field
        assert!(decode_entry(&key, &wrong_version).is_none());

        let mut wrong_epoch = good.clone();
        wrong_epoch[12] ^= 0xFF; // first byte of the epoch field
        assert!(decode_entry(&key, &wrong_epoch).is_none());

        for cut in [0, 7, good.len() / 2, good.len() - 1] {
            assert!(decode_entry(&key, &good[..cut]).is_none(), "truncated at {cut}");
        }

        let mut flipped = good.clone();
        let mid = flipped.len() / 2;
        flipped[mid] ^= 0x01;
        assert!(decode_entry(&key, &flipped).is_none());

        let mut trailing = good.clone();
        trailing.push(0);
        assert!(decode_entry(&key, &trailing).is_none());

        assert_same(&r, &decode_entry(&key, &good).unwrap());
    }
}
