//! Persistent on-disk store for simulation results: one file per key,
//! shared across processes by every *persistent* engine — the `vega`
//! CLI's repro/sweep commands and anything built on
//! [`crate::sweep::SweepEngine::persistent`] /
//! [`crate::sweep::SweepEngine::global`].
//!
//! Three entry types share the directory and the entry format:
//!
//! * **kernel entries** (`<fnv>.sim`): one [`SimResult`] per [`SimKey`]
//!   — the cluster simulations behind tables/figures and `vega sweep`;
//! * **network entries** (`<fnv>.net`): one
//!   [`NetworkReport`](crate::dnn::NetworkReport) per canonical
//!   [`crate::dnn::net_key`] — the DNN pipeline runs recurring across
//!   Figs. 9–11, Tables VII/VIII and the ablations;
//! * **fault-campaign entries** (`<fnv>.flt`): one
//!   [`CampaignOutcome`](crate::faults::CampaignOutcome) per
//!   [`Campaign::key`](crate::faults::Campaign::key) string — the `vega
//!   faults` grid cells. The key embeds
//!   [`crate::faults::FAULT_MODEL_VERSION`], so a fault-model change
//!   orphans old entries without touching [`STORE_VERSION`];
//! * **lifecycle entries** (`<fnv>.lfc`): one
//!   [`LifecycleReport`](crate::lifecycle::LifecycleReport) per
//!   [`LifecycleScenario::key`](crate::lifecycle::LifecycleScenario::key)
//!   string — the `vega lifecycle` grid cells. The key embeds
//!   [`crate::lifecycle::LIFECYCLE_MODEL_VERSION`] the same way.
//!
//! The in-memory memos ([`crate::sweep::SimCache`] and the engine's
//! network map) die with their engine, so every CLI invocation used to
//! re-simulate the same programs and re-run the same pipelines. The
//! [`DiskStore`] sits *inside* the in-memory miss path: an in-memory miss
//! first probes the store, and only computes (then writes back) when the
//! disk misses too. In-memory hit/miss semantics — and therefore every
//! counter the determinism tests assert — are unchanged by the disk
//! layer. The *test suite* deliberately stays off the shared store: the
//! regression oracles (`paper_anchors`, `sweep_determinism`, the
//! coordinator unit tests) run memory-only so a stale entry can never
//! satisfy them, and `tests/disk_cache.rs` / `tests/network_store.rs`
//! exercise persistence against private per-test directories.
//!
//! ## File format (version [`STORE_VERSION`], model epoch [`MODEL_EPOCH`])
//!
//! ```text
//! magic    b"VEGASIMC" / b"VEGANETR"     8 bytes   (entry type)
//! version  u32 LE  = STORE_VERSION       layout of this very file
//! epoch    u32 LE  = MODEL_EPOCH         timing-model generation
//! key      u32 LE length + UTF-8 bytes   full key echo (collision guard)
//! payload  u64 LE length + bytes         serialized result
//! checksum u64 LE                        FNV-1a of the payload bytes
//! ```
//!
//! Reads are corruption-tolerant by construction: any mismatch — magic,
//! version, epoch, key echo, truncation, checksum, trailing garbage —
//! reads as a miss and the caller recomputes (overwriting the entry).
//! Writes go to a temp file named from the PID plus a per-process
//! sequence number — two concurrent processes on one cache directory can
//! never collide on a temp path — and are `rename`d into place, so a
//! concurrent reader can never observe a partial entry and same-key
//! racers are benign (both write identical bytes: simulations are pure).
//!
//! ## Staleness guards
//!
//! * A *kernel* change changes `Program::content_hash`; a *topology*
//!   change changes [`crate::dnn::network_struct_hash`]. Both are part
//!   of their key (and of the file name), so stale entries are simply
//!   never looked up again. Since PR 4 both hashes run over the explicit
//!   byte encodings of [`crate::isa::encode`] / [`crate::dnn::encode`] —
//!   no derived `Hash` feeds any persisted key, so keys survive
//!   toolchain bumps and may be shared across machines.
//! * A *timing-model* change (scheduler, stall costs, pipeline-model
//!   constants) can change the stats of an unchanged program or network.
//!   Bump [`MODEL_EPOCH`] with any such change; every older entry then
//!   reads as a miss.
//!
//! The store location is `$VEGA_CACHE_DIR` if set, else
//! `$CARGO_TARGET_DIR/vega-cache`, else `target/vega-cache` relative to
//! the working directory; `VEGA_CACHE=off|0|false|no` (case-insensitive)
//! disables persistence entirely (see [`DiskStore::open_default`], the
//! one place the accepted values are defined).

use std::fs;
use std::hash::Hasher;
use std::io;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use super::scenario::{SimKey, SimResult};
use crate::cluster::ClusterStats;
use crate::common::{ByteReader, ByteWriter};
use crate::dnn::NetworkReport;
use crate::faults::{CampaignOutcome, TierFaults};
use crate::iss::stats::{ClassCounts, CoreStats};
use crate::kernels::KernelRun;
use crate::mem::mram::EccStats;

/// On-disk layout version of one store entry. Bump when the serialized
/// byte layout itself changes. Version 2: cache keys derive from the
/// explicit ISA/DNN byte encodings (toolchain-portable) and the network
/// entry type exists; version-1 entries (derived-`Hash` keys) read as
/// misses.
pub const STORE_VERSION: u32 = 2;

/// Timing-model generation. Bump whenever a change to the simulator can
/// alter the [`ClusterStats`] (or a
/// [`NetworkReport`](crate::dnn::NetworkReport)) of an *unchanged*
/// program — the content hashes cannot see those, and a stale entry
/// would otherwise serve pre-change cycle counts.
pub const MODEL_EPOCH: u32 = 1;

const SIM_MAGIC: &[u8; 8] = b"VEGASIMC";
const NET_MAGIC: &[u8; 8] = b"VEGANETR";
const FLT_MAGIC: &[u8; 8] = b"VEGAFLTR";
const LFC_MAGIC: &[u8; 8] = b"VEGALFCR";

/// Hit/miss/write/write-error counters of one entry tier.
#[derive(Debug, Default)]
struct TierCounters {
    hits: AtomicU64,
    misses: AtomicU64,
    writes: AtomicU64,
    errors: AtomicU64,
}

impl TierCounters {
    fn observe(&self, hit: bool) {
        if hit {
            self.hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.misses.fetch_add(1, Ordering::Relaxed);
        }
    }

    fn snapshot(&self) -> (u64, u64, u64) {
        (
            self.hits.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
            self.writes.load(Ordering::Relaxed),
        )
    }
}

/// A directory of serialized results: kernel [`SimResult`]s (`.sim`) and
/// [`NetworkReport`](crate::dnn::NetworkReport)s (`.net`), one file per
/// key, with independent hit/miss/write counters per tier.
///
/// All methods are best-effort and lock-free: loads treat every failure
/// mode as a miss, and stores drop entries they cannot write (a
/// read-only or full cache directory degrades to the in-memory-only
/// behaviour, it never fails a simulation). Dropped writes are *not*
/// silent (ISSUE 7): the first failure warns on stderr, and every
/// failure counts in the per-tier error counters surfaced by
/// [`DiskStore::write_error_counters`] and the CLI's `--stats`.
pub struct DiskStore {
    dir: PathBuf,
    sim: TierCounters,
    net: TierCounters,
    flt: TierCounters,
    lfc: TierCounters,
    /// Per-process temp-file disambiguator (paired with the PID in the
    /// temp name; see `write_entry`).
    tmp_seq: AtomicU64,
}

impl DiskStore {
    /// Open (creating if needed) a store rooted at `dir`.
    pub fn at(dir: impl Into<PathBuf>) -> io::Result<Self> {
        let dir = dir.into();
        fs::create_dir_all(&dir)?;
        Ok(Self {
            dir,
            sim: TierCounters::default(),
            net: TierCounters::default(),
            flt: TierCounters::default(),
            lfc: TierCounters::default(),
            tmp_seq: AtomicU64::new(0),
        })
    }

    /// Open the default store: `$VEGA_CACHE_DIR` if set, else
    /// `$CARGO_TARGET_DIR/vega-cache`, else `target/vega-cache`.
    ///
    /// Returns `Ok(None)` when persistence is disabled via the
    /// `VEGA_CACHE` environment variable. Accepted disable values
    /// (case-insensitive, whitespace-trimmed): `off`, `0`, `false`,
    /// `no`. Anything else — including empty — leaves persistence on.
    /// README.md's cache section documents the same list and defers here.
    pub fn open_default() -> io::Result<Option<Self>> {
        if let Ok(v) = std::env::var("VEGA_CACHE") {
            if matches!(v.trim().to_ascii_lowercase().as_str(), "off" | "0" | "false" | "no") {
                return Ok(None);
            }
        }
        let dir = match std::env::var_os("VEGA_CACHE_DIR") {
            Some(d) => PathBuf::from(d),
            None => match std::env::var_os("CARGO_TARGET_DIR") {
                Some(t) => Path::new(&t).join("vega-cache"),
                None => PathBuf::from("target").join("vega-cache"),
            },
        };
        Self::at(dir).map(Some)
    }

    /// The directory entries live in.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// (hits, misses, writes) of the kernel tier so far. Every
    /// [`DiskStore::load`] counts as exactly one hit or miss; every
    /// successful [`DiskStore::store`] as one write.
    pub fn counters(&self) -> (u64, u64, u64) {
        self.sim.snapshot()
    }

    /// (hits, misses, writes) of the network-report tier
    /// ([`DiskStore::load_net`] / [`DiskStore::store_net`]).
    pub fn net_counters(&self) -> (u64, u64, u64) {
        self.net.snapshot()
    }

    /// (hits, misses, writes) of the fault-campaign tier
    /// ([`DiskStore::load_fault`] / [`DiskStore::store_fault`]).
    pub fn fault_counters(&self) -> (u64, u64, u64) {
        self.flt.snapshot()
    }

    /// (hits, misses, writes) of the lifecycle tier
    /// ([`DiskStore::load_lifecycle`] / [`DiskStore::store_lifecycle`]).
    pub fn lifecycle_counters(&self) -> (u64, u64, u64) {
        self.lfc.snapshot()
    }

    /// Failed entry writes per tier — (sim, net, fault, lifecycle).
    /// Non-zero means some results could not be persisted (read-only
    /// dir, full disk, path collision) and the run continued in memory;
    /// the first failure also warned on stderr.
    pub fn write_error_counters(&self) -> (u64, u64, u64, u64) {
        (
            self.sim.errors.load(Ordering::Relaxed),
            self.net.errors.load(Ordering::Relaxed),
            self.flt.errors.load(Ordering::Relaxed),
            self.lfc.errors.load(Ordering::Relaxed),
        )
    }

    /// Look a kernel `key` up. Any read/format/checksum failure is a miss.
    pub fn load(&self, key: &SimKey) -> Option<SimResult> {
        let key_str = key_string(key);
        let res = fs::read(self.path_for(&key_str, "sim"))
            .ok()
            .and_then(|bytes| decode_entry(SIM_MAGIC, &key_str, &bytes))
            .and_then(|payload| decode_payload(&payload));
        self.sim.observe(res.is_some());
        res
    }

    /// Write `result` under `key` (atomic temp-file + rename;
    /// best-effort — a failed write warns once, counts in the tier's
    /// error counter, and the entry is simply absent).
    pub fn store(&self, key: &SimKey, result: &SimResult) {
        let key_str = key_string(key);
        let bytes = encode_entry(SIM_MAGIC, &key_str, &encode_payload(result));
        self.finish_write(&self.sim, &self.path_for(&key_str, "sim"), &bytes);
    }

    /// Look a network-report `key` (a [`crate::dnn::net_key`] string) up.
    /// Any read/format/checksum failure is a miss.
    pub fn load_net(&self, key: &str) -> Option<NetworkReport> {
        let res = fs::read(self.path_for(key, "net"))
            .ok()
            .and_then(|bytes| decode_entry(NET_MAGIC, key, &bytes))
            .and_then(|payload| crate::dnn::encode::decode_report(&payload));
        self.net.observe(res.is_some());
        res
    }

    /// Write `report` under a [`crate::dnn::net_key`] string (same
    /// temp-file + rename protocol as [`DiskStore::store`]).
    pub fn store_net(&self, key: &str, report: &NetworkReport) {
        let bytes = encode_entry(NET_MAGIC, key, &crate::dnn::encode::encode_report(report));
        self.finish_write(&self.net, &self.path_for(key, "net"), &bytes);
    }

    /// Look a fault-campaign `key` (a [`crate::faults::Campaign::key`]
    /// string) up. Any read/format/checksum failure is a miss.
    pub fn load_fault(&self, key: &str) -> Option<CampaignOutcome> {
        let res = fs::read(self.path_for(key, "flt"))
            .ok()
            .and_then(|bytes| decode_entry(FLT_MAGIC, key, &bytes))
            .and_then(|payload| decode_fault_payload(&payload));
        self.flt.observe(res.is_some());
        res
    }

    /// Write `outcome` under a [`crate::faults::Campaign::key`] string
    /// (same temp-file + rename protocol as [`DiskStore::store`]).
    pub fn store_fault(&self, key: &str, outcome: &CampaignOutcome) {
        let bytes = encode_entry(FLT_MAGIC, key, &encode_fault_payload(outcome));
        self.finish_write(&self.flt, &self.path_for(key, "flt"), &bytes);
    }

    /// Look a lifecycle `key` (a
    /// [`crate::lifecycle::LifecycleScenario::key`] string) up. Any
    /// read/format/checksum failure is a miss.
    pub fn load_lifecycle(&self, key: &str) -> Option<crate::lifecycle::LifecycleReport> {
        let res = fs::read(self.path_for(key, "lfc"))
            .ok()
            .and_then(|bytes| decode_entry(LFC_MAGIC, key, &bytes))
            .and_then(|payload| crate::lifecycle::decode_report(&payload));
        self.lfc.observe(res.is_some());
        res
    }

    /// Write `report` under a
    /// [`crate::lifecycle::LifecycleScenario::key`] string (same
    /// temp-file + rename protocol as [`DiskStore::store`]).
    pub fn store_lifecycle(&self, key: &str, report: &crate::lifecycle::LifecycleReport) {
        let bytes = encode_entry(LFC_MAGIC, key, &crate::lifecycle::encode_report(report));
        self.finish_write(&self.lfc, &self.path_for(key, "lfc"), &bytes);
    }

    /// Count a completed write attempt: a landed entry bumps the tier's
    /// write counter; a failed one bumps its error counter and warns
    /// once per process that the store degraded to memory-only.
    fn finish_write(&self, tier: &TierCounters, dest: &Path, bytes: &[u8]) {
        match self.write_entry(dest, bytes) {
            Ok(()) => {
                tier.writes.fetch_add(1, Ordering::Relaxed);
            }
            Err(e) => {
                tier.errors.fetch_add(1, Ordering::Relaxed);
                warn_write_failure_once(dest, &e);
            }
        }
    }

    /// Write `bytes` to `dest` atomically: a temp file named from the
    /// PID *and* a per-process sequence number (concurrent processes on
    /// one directory can never collide on the temp path; concurrent
    /// writes within a process get distinct sequence numbers), renamed
    /// into place.
    fn write_entry(&self, dest: &Path, bytes: &[u8]) -> io::Result<()> {
        let tmp = self.dir.join(format!(
            ".tmp-{}-{}",
            std::process::id(),
            self.tmp_seq.fetch_add(1, Ordering::Relaxed)
        ));
        let out = fs::write(&tmp, bytes).and_then(|_| fs::rename(&tmp, dest));
        if out.is_err() {
            // Drop the temp file whether the write or the rename failed —
            // names are never reused, so litter would accumulate forever.
            let _ = fs::remove_file(&tmp);
        }
        out
    }

    /// File an entry lives in: an FNV-1a tag of the canonical key string
    /// (the full string is echoed inside the file, so a tag collision
    /// reads as a miss, never as wrong data) plus the tier extension.
    fn path_for(&self, key_str: &str, ext: &str) -> PathBuf {
        let mut h = crate::common::Fnv1a::new();
        h.write(key_str.as_bytes());
        self.dir.join(format!("{:016x}.{ext}", h.finish()))
    }
}

/// Warn once per process that entry writes are failing; thereafter the
/// per-tier error counters keep score silently.
fn warn_write_failure_once(dest: &Path, err: &io::Error) {
    use std::sync::Once;
    static WARN: Once = Once::new();
    WARN.call_once(|| {
        eprintln!(
            "vega: disk cache write failed at {} ({err}); \
             continuing in memory (see --stats write-errors)",
            dest.display()
        )
    });
}

/// Canonical textual form of a [`SimKey`] (file-name tag + in-file
/// echo; also embedded in [`crate::faults::Campaign::key`] strings).
pub(crate) fn key_string(key: &SimKey) -> String {
    format!(
        "{}|{}x{}x{}|{}|{}c|{:016x}",
        key.kernel, key.size.0, key.size.1, key.size.2, key.precision, key.cores, key.prog_hash
    )
}

// ---------------------------------------------------------------------
// Entry framing (shared by both tiers) and the SimResult payload codec
// (the NetworkReport payload codec lives in `crate::dnn::encode`).
// ---------------------------------------------------------------------

fn encode_core_stats(e: &mut ByteWriter, s: &CoreStats) {
    e.u64(s.cycles);
    e.u64(s.retired);
    e.u64(s.int_ops);
    e.u64(s.flops);
    e.u64(s.bytes_loaded);
    e.u64(s.bytes_stored);
    e.u64(s.stall_loaduse);
    e.u64(s.stall_tcdm);
    e.u64(s.stall_fpu);
    e.u64(s.stall_divsqrt);
    e.u64(s.stall_icache);
    e.u64(s.stall_barrier);
    e.u64(s.branch_penalty);
    e.u64(s.multicycle_busy);
    let c = &s.by_class;
    for v in [c.alu, c.mul, c.div, c.load, c.store, c.branch, c.fp, c.simd, c.control] {
        e.u64(v);
    }
}

fn decode_core_stats(d: &mut ByteReader) -> Option<CoreStats> {
    Some(CoreStats {
        cycles: d.u64()?,
        retired: d.u64()?,
        int_ops: d.u64()?,
        flops: d.u64()?,
        bytes_loaded: d.u64()?,
        bytes_stored: d.u64()?,
        stall_loaduse: d.u64()?,
        stall_tcdm: d.u64()?,
        stall_fpu: d.u64()?,
        stall_divsqrt: d.u64()?,
        stall_icache: d.u64()?,
        stall_barrier: d.u64()?,
        branch_penalty: d.u64()?,
        multicycle_busy: d.u64()?,
        by_class: ClassCounts {
            alu: d.u64()?,
            mul: d.u64()?,
            div: d.u64()?,
            load: d.u64()?,
            store: d.u64()?,
            branch: d.u64()?,
            fp: d.u64()?,
            simd: d.u64()?,
            control: d.u64()?,
        },
    })
}

/// Serialize a [`KernelRun`] minus its fault ledger (the `.sim` tier
/// only ever stores fault-free runs, so the ledger is omitted there and
/// reconstructed as all-zeros; the `.flt` tier re-attaches it).
fn encode_run(e: &mut ByteWriter, run: &KernelRun) {
    e.str(&run.name);
    e.u64(run.ops);
    let s = &run.stats;
    e.u64(s.cycles);
    e.f64(s.tcdm_conflict_rate);
    e.f64(s.fpu_contention_rate);
    e.u64(s.barrier_gated_cycles);
    encode_core_stats(e, &s.total);
    e.u32(s.per_core.len() as u32);
    for core in &s.per_core {
        encode_core_stats(e, core);
    }
}

fn decode_run(d: &mut ByteReader) -> Option<KernelRun> {
    let name = d.str()?;
    let ops = d.u64()?;
    let cycles = d.u64()?;
    let tcdm_conflict_rate = d.f64()?;
    let fpu_contention_rate = d.f64()?;
    let barrier_gated_cycles = d.u64()?;
    let total = decode_core_stats(d)?;
    let n = d.u32()? as usize;
    // Per-core lists are bounded by the 9-core cluster; reject anything
    // larger outright rather than trusting a corrupt length prefix.
    if n > crate::cluster::N_CORES {
        return None;
    }
    let mut per_core = Vec::with_capacity(n);
    for _ in 0..n {
        per_core.push(decode_core_stats(d)?);
    }
    Some(KernelRun::new(
        name,
        ClusterStats {
            cycles,
            per_core,
            total,
            tcdm_conflict_rate,
            fpu_contention_rate,
            barrier_gated_cycles,
            faults: Default::default(),
        },
        ops,
    ))
}

fn encode_payload(r: &SimResult) -> Vec<u8> {
    let mut e = ByteWriter::with_capacity(2048);
    e.u64(r.outputs_digest);
    encode_run(&mut e, &r.run);
    e.into_vec()
}

fn decode_payload(bytes: &[u8]) -> Option<SimResult> {
    let mut d = ByteReader::new(bytes);
    let outputs_digest = d.u64()?;
    let run = decode_run(&mut d)?;
    if !d.done() {
        return None;
    }
    Some(SimResult { run, outputs_digest })
}

fn encode_tier_faults(e: &mut ByteWriter, t: &TierFaults) {
    for v in [t.flips, t.words, t.corrected, t.detected, t.silent, t.masked] {
        e.u64(v);
    }
}

fn decode_tier_faults(d: &mut ByteReader) -> Option<TierFaults> {
    Some(TierFaults {
        flips: d.u64()?,
        words: d.u64()?,
        corrected: d.u64()?,
        detected: d.u64()?,
        silent: d.u64()?,
        masked: d.u64()?,
    })
}

/// `.flt` payload: the faulted run, the per-tier classification ledger
/// (written once — it is by construction identical to
/// `run.stats.faults`, and both are rebuilt from the single copy), the
/// MRAM controller counters, and the divergence verdict.
fn encode_fault_payload(o: &CampaignOutcome) -> Vec<u8> {
    let mut e = ByteWriter::with_capacity(2048);
    encode_run(&mut e, &o.run);
    for t in [&o.stats.mram, &o.stats.l2, &o.stats.tcdm] {
        encode_tier_faults(&mut e, t);
    }
    e.u64(o.ecc.corrected);
    e.u64(o.ecc.detected);
    e.u64(o.poisoned_words);
    e.u64(o.oracle_digest);
    e.u64(o.faulted_digest);
    e.u8(o.diverged as u8);
    e.into_vec()
}

fn decode_fault_payload(bytes: &[u8]) -> Option<CampaignOutcome> {
    let mut d = ByteReader::new(bytes);
    let mut run = decode_run(&mut d)?;
    let stats = crate::faults::FaultStats {
        mram: decode_tier_faults(&mut d)?,
        l2: decode_tier_faults(&mut d)?,
        tcdm: decode_tier_faults(&mut d)?,
    };
    run.stats.faults = stats;
    let ecc = EccStats { corrected: d.u64()?, detected: d.u64()? };
    let poisoned_words = d.u64()?;
    let oracle_digest = d.u64()?;
    let faulted_digest = d.u64()?;
    let diverged = match d.u8()? {
        0 => false,
        1 => true,
        _ => return None,
    };
    if !d.done() {
        return None;
    }
    Some(CampaignOutcome {
        run,
        stats,
        ecc,
        poisoned_words,
        oracle_digest,
        faulted_digest,
        diverged,
    })
}

/// Frame a payload: magic, version, epoch, key echo, length-prefixed
/// payload, FNV checksum of the payload bytes.
fn encode_entry(magic: &[u8; 8], key_str: &str, payload: &[u8]) -> Vec<u8> {
    let mut h = crate::common::Fnv1a::new();
    h.write(payload);
    let mut e = ByteWriter::with_capacity(payload.len() + 64);
    e.bytes(magic);
    e.u32(STORE_VERSION);
    e.u32(MODEL_EPOCH);
    e.str(key_str);
    e.u64(payload.len() as u64);
    e.bytes(payload);
    e.u64(h.finish());
    e.into_vec()
}

/// Unframe an entry, verifying magic, version, epoch, key echo, length,
/// checksum, and the absence of trailing bytes. Returns the payload.
fn decode_entry(magic: &[u8; 8], key_str: &str, bytes: &[u8]) -> Option<Vec<u8>> {
    let mut d = ByteReader::new(bytes);
    if d.take(magic.len())? != magic {
        return None;
    }
    if d.u32()? != STORE_VERSION || d.u32()? != MODEL_EPOCH {
        return None;
    }
    if d.str()? != key_str {
        return None;
    }
    let len = d.u64()? as usize;
    let payload = d.take(len)?;
    let checksum = d.u64()?;
    if !d.done() {
        return None;
    }
    let mut h = crate::common::Fnv1a::new();
    h.write(payload);
    if h.finish() != checksum {
        return None;
    }
    Some(payload.to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::int_matmul::IntWidth;
    use crate::sweep::{Scenario, SimArena};

    fn sample() -> (SimKey, SimResult) {
        let s = Scenario::IntMatmul { w: IntWidth::I8, cores: 2 };
        (s.key(), s.simulate(&mut SimArena::new()))
    }

    fn assert_same(a: &SimResult, b: &SimResult) {
        assert_eq!(a.outputs_digest, b.outputs_digest);
        assert_eq!(a.run.name, b.run.name);
        assert_eq!(a.run.ops, b.run.ops);
        assert_eq!(a.run.stats, b.run.stats);
    }

    fn entry_for(key: &SimKey, r: &SimResult) -> Vec<u8> {
        encode_entry(SIM_MAGIC, &key_string(key), &encode_payload(r))
    }

    fn decode_for(key: &SimKey, bytes: &[u8]) -> Option<SimResult> {
        decode_entry(SIM_MAGIC, &key_string(key), bytes).and_then(|p| decode_payload(&p))
    }

    #[test]
    fn payload_round_trips_bit_exactly() {
        let (_, r) = sample();
        let back = decode_payload(&encode_payload(&r)).unwrap();
        assert_same(&r, &back);
    }

    #[test]
    fn entry_round_trips_and_guards_the_key() {
        let (key, r) = sample();
        let bytes = entry_for(&key, &r);
        assert_same(&r, &decode_for(&key, &bytes).unwrap());
        // Same bytes probed under a different key (tag collision) = miss.
        let other = Scenario::IntMatmul { w: IntWidth::I8, cores: 3 }.key();
        assert!(decode_for(&other, &bytes).is_none());
        // And under the other entry type's magic = miss.
        assert!(decode_entry(NET_MAGIC, &key_string(&key), &bytes).is_none());
    }

    #[test]
    fn fault_payload_round_trips_bit_exactly() {
        let (_, r) = sample();
        let mut run = r.run.clone();
        run.stats.faults.mram =
            TierFaults { flips: 5, words: 4, corrected: 2, detected: 1, silent: 0, masked: 1 };
        run.stats.faults.tcdm =
            TierFaults { flips: 3, words: 3, corrected: 0, detected: 0, silent: 3, masked: 0 };
        let out = CampaignOutcome {
            stats: run.stats.faults,
            ecc: EccStats { corrected: 2, detected: 1 },
            poisoned_words: 1,
            oracle_digest: r.outputs_digest,
            faulted_digest: r.outputs_digest ^ 1,
            diverged: true,
            run,
        };
        let back = decode_fault_payload(&encode_fault_payload(&out)).unwrap();
        assert_eq!(out, back);
        // The single stored ledger is re-attached to the run on decode.
        assert_eq!(back.run.stats.faults, back.stats);
        // A non-boolean divergence byte is a corrupt entry, not `true`.
        let mut bytes = encode_fault_payload(&out);
        *bytes.last_mut().unwrap() = 2;
        assert!(decode_fault_payload(&bytes).is_none());
    }

    #[test]
    fn lifecycle_entries_frame_under_their_own_magic() {
        let report = crate::lifecycle::LifecycleReport {
            events: 7,
            true_wakes: 4,
            false_wakes: 3,
            boots: 4,
            total_s: 600.0,
            sleep_s: 599.0,
            diverged: true,
            ..Default::default()
        };
        let key = "lifecycle-v1|test-key";
        let bytes = encode_entry(LFC_MAGIC, key, &crate::lifecycle::encode_report(&report));
        let payload = decode_entry(LFC_MAGIC, key, &bytes).unwrap();
        assert_eq!(crate::lifecycle::decode_report(&payload).unwrap(), report);
        // Wrong key echo or another tier's magic = miss.
        assert!(decode_entry(LFC_MAGIC, "lifecycle-v1|other", &bytes).is_none());
        assert!(decode_entry(FLT_MAGIC, key, &bytes).is_none());
    }

    #[test]
    fn version_epoch_truncation_and_checksum_mismatches_are_misses() {
        let (key, r) = sample();
        let good = entry_for(&key, &r);

        let mut wrong_version = good.clone();
        wrong_version[8] ^= 0xFF; // first byte of the version field
        assert!(decode_for(&key, &wrong_version).is_none());

        let mut wrong_epoch = good.clone();
        wrong_epoch[12] ^= 0xFF; // first byte of the epoch field
        assert!(decode_for(&key, &wrong_epoch).is_none());

        for cut in [0, 7, good.len() / 2, good.len() - 1] {
            assert!(decode_for(&key, &good[..cut]).is_none(), "truncated at {cut}");
        }

        let mut flipped = good.clone();
        let mid = flipped.len() / 2;
        flipped[mid] ^= 0x01;
        assert!(decode_for(&key, &flipped).is_none());

        let mut trailing = good.clone();
        trailing.push(0);
        assert!(decode_for(&key, &trailing).is_none());

        assert_same(&r, &decode_for(&key, &good).unwrap());
    }
}
