//! The sweep execution engine (§Perf, suite level).
//!
//! The paper's evaluation is a grid of sweeps — core counts, V/f operating
//! points, precisions, store policies (Figs. 6–11, Tables V–VIII) — and the
//! reported *cycle counts* are frequency-independent: only the power/energy
//! numbers change per [`crate::power::tables::OperatingPoint`]. This module
//! exploits that structure twice:
//!
//! 1. **Memoization** ([`SimCache`]): every distinct simulated program —
//!    keyed by (kernel id, problem size, precision, core count) plus a
//!    content hash of the assembled [`crate::isa::Program`] — is simulated
//!    exactly once. V/f sweeps derive each point analytically from the
//!    cached [`crate::cluster::ClusterStats`], and matmul programs that
//!    recur across tables and figures are shared when a whole suite runs
//!    through one engine (`vega repro all`). A sibling memo does the same
//!    for DNN pipeline runs ([`SweepEngine::network_report`]): MobileNetV2
//!    store-policy flows recur across Figs. 9–11 and the ablations.
//! 2. **Parallel fan-out** ([`SweepEngine`]): a `std::thread::scope`-based
//!    worker pool (no dependencies — the build is offline) drains a work
//!    queue of [`Scenario`] descriptors and of whole report ids. Each
//!    worker owns its [`SimArena`] (a `Cluster` + L2 `FlatMem` pair), and
//!    results are index-tagged so reports are assembled in deterministic
//!    paper order regardless of completion order.
//!
//! Determinism invariant: the rendered reports are **byte-identical** for
//! any `--jobs` value (asserted by `tests/sweep_determinism.rs`) because
//! every scenario simulation is a pure function of its descriptor and the
//! cache only ever stores the first (hence: the only possible) result.
//!
//! Two layers extend the engine beyond the paper's fixed reproduction
//! suite (see `ARCHITECTURE.md` for the full dataflow):
//!
//! * [`explore`] — user-defined design-space grids (`vega sweep`): core
//!   counts 1–9 × precisions × an arbitrarily fine DVFS ladder, rendered
//!   as CSV/Markdown/JSON through the same cache and worker pool.
//! * [`persist`] — the on-disk [`DiskStore`] (one versioned, checksummed
//!   file per [`SimKey`], per DNN network run, per fault campaign and
//!   per lifecycle report) that lets persistent engines — chiefly the
//!   CLI's — share simulations, network reports, campaign outcomes
//!   **and lifecycle reports** across processes. Keys derive from the explicit byte encodings
//!   ([`crate::isa::encode`], [`crate::dnn::encode`]), so the store
//!   survives toolchain bumps and can be shared across machines; the
//!   test suite's regression oracles deliberately stay memory-only.
//!
//! Fault isolation (ISSUE 6): every work item the engine fans out runs
//! under `catch_unwind`, so one panicking scenario (or campaign) yields
//! a structured [`SimError`] cell — index plus panic message — while
//! every other cell completes normally. [`SweepEngine::run_scenarios`]
//! keeps the panicking behaviour for callers that want it;
//! [`SweepEngine::try_run_scenarios`] and
//! [`SweepEngine::run_campaigns`] surface the per-cell `Result`s.
//!
//! Crash safety (ISSUE 7): the [`journal`] layer makes grids survive a
//! killed process the way the paper's SoC survives a power cycle. Every
//! CLI grid run appends one checksummed record per completed cell to an
//! append-only per-grid journal (keyed by a versioned byte encoding of
//! the full grid), `--resume` replays it — torn trailing records read
//! as "not done", never as corruption — and serves completed cells
//! through the cache tiers for output byte-identical to an
//! uninterrupted run. `--shard I/N` partitions any grid by stable cell
//! ID into N disjoint machine-portable slices, and `--merge N`
//! reassembles the shard journals into the exact serial-order report —
//! the `--jobs` byte-identity invariant, extended across process
//! boundaries. On top, [`SweepEngine`] runs every cell under a
//! [`CellPolicy`]: [`Transient`]-marked failures get bounded retries,
//! deterministic panics fail once (PR 6 contract), and an optional
//! watchdog turns runaway cells into `timeout` rows instead of hung
//! grids.

pub mod cache;
pub mod engine;
pub mod explore;
pub mod journal;
pub mod persist;
pub mod scenario;

pub use cache::SimCache;
pub use engine::{default_jobs, CellPolicy, FailKind, SimError, SweepEngine, Transient};
pub use journal::{CellRecord, CellStatus, GridMode, GridSession, ShardSpec};
pub use persist::DiskStore;
pub use scenario::{verify_targets, Scenario, SimArena, SimKey, SimResult};
