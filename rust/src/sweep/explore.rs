//! Design-space exploration: user-defined grids beyond the paper's tables
//! (the `vega sweep` subcommand).
//!
//! The paper's evaluation fixes a handful of operating points (Figs. 6–8:
//! 1 or 8 cores, LV/HV); TinyVers and SamurAI (PAPERS.md) frame the same
//! class of SoC as a *design space* instead. This module renders that
//! space on demand: any subset of core counts 1–9 × the kernel library's
//! precisions × an arbitrarily fine DVFS ladder, as CSV, Markdown or
//! JSON. Each (cores, precision) cell is **one** simulation pulled
//! through the [`SweepEngine`] — cycle counts are frequency-independent,
//! so every DVFS row of a cell derives analytically from the same cached
//! [`crate::cluster::ClusterStats`] — and the grid fans out across the
//! engine's worker pool (`--jobs N`), warm-starting from the on-disk
//! [`crate::sweep::DiskStore`] when the engine is persistent (since the
//! cache keys are byte-defined, a warm store may even have been produced
//! by a different toolchain or machine).
//!
//! Determinism: rows are emitted in nested grid order (cores, then
//! precision, then DVFS point), never completion order, so the rendered
//! bytes are identical for any `--jobs` value (asserted by
//! `tests/sweep_determinism.rs`).

use crate::cluster::N_CORES;
use crate::coordinator;
use crate::kernels::fp_matmul::FpWidth;
use crate::kernels::int_matmul::IntWidth;
use crate::power::tables::OperatingPoint;
use crate::sweep::journal::{self, GridSession, ShardSpec};
use crate::sweep::{default_jobs, CellPolicy, Scenario, SweepEngine};

/// A matmul precision of the exploration grid (the kernel library's
/// supported data formats).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    /// PULP-NN int8 SIMD matmul.
    Int8,
    /// PULP-NN int16 SIMD matmul.
    Int16,
    /// int32 matmul.
    Int32,
    /// fp8 SIMD (4-way packed E5M2 smallFloat) matmul — the 8-bit mode
    /// of the shared FPUs, completing the precision axis.
    Fp8,
    /// fp16 SIMD (2-way packed) matmul.
    Fp16,
    /// fp32 matmul.
    Fp32,
}

impl Precision {
    /// Every supported precision, in grid order.
    pub const ALL: [Precision; 6] = [
        Precision::Int8,
        Precision::Int16,
        Precision::Int32,
        Precision::Fp8,
        Precision::Fp16,
        Precision::Fp32,
    ];

    /// Parse one `--precision` token.
    pub fn parse(s: &str) -> Result<Precision, String> {
        match s.trim().to_ascii_lowercase().as_str() {
            "int8" | "i8" => Ok(Precision::Int8),
            "int16" | "i16" => Ok(Precision::Int16),
            "int32" | "i32" => Ok(Precision::Int32),
            "fp8" | "f8" => Ok(Precision::Fp8),
            "fp16" | "f16" => Ok(Precision::Fp16),
            "fp32" | "f32" => Ok(Precision::Fp32),
            other => Err(format!(
                "unknown precision '{other}' (supported: int8,int16,int32,fp8,fp16,fp32)"
            )),
        }
    }

    /// Column label.
    pub fn name(self) -> &'static str {
        match self {
            Precision::Int8 => "int8",
            Precision::Int16 => "int16",
            Precision::Int32 => "int32",
            Precision::Fp8 => "fp8",
            Precision::Fp16 => "fp16",
            Precision::Fp32 => "fp32",
        }
    }

    /// The scenario one grid cell simulates (the canonical matmul of the
    /// reproduction suite at this precision, on `cores` cores).
    pub fn scenario(self, cores: usize) -> Scenario {
        match self {
            Precision::Int8 => Scenario::IntMatmul { w: IntWidth::I8, cores },
            Precision::Int16 => Scenario::IntMatmul { w: IntWidth::I16, cores },
            Precision::Int32 => Scenario::IntMatmul { w: IntWidth::I32, cores },
            Precision::Fp8 => Scenario::FpMatmul { w: FpWidth::F8x4, cores },
            Precision::Fp16 => Scenario::FpMatmul { w: FpWidth::F16x2, cores },
            Precision::Fp32 => Scenario::FpMatmul { w: FpWidth::F32, cores },
        }
    }
}

/// Output format of the rendered grid.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GridFormat {
    /// Comma-separated values with a header row.
    Csv,
    /// A GitHub-flavoured Markdown pipe table.
    Markdown,
    /// A single JSON object: `{"grid": {...}, "rows": [...]}`.
    Json,
}

impl GridFormat {
    /// Parse one `--format` token.
    pub fn parse(s: &str) -> Result<GridFormat, String> {
        match s.trim().to_ascii_lowercase().as_str() {
            "csv" => Ok(GridFormat::Csv),
            "md" | "markdown" => Ok(GridFormat::Markdown),
            "json" => Ok(GridFormat::Json),
            other => Err(format!("unknown format '{other}' (supported: csv,md,json)")),
        }
    }

    /// Canonical token (fed into the grid's journal key — the format
    /// shapes the output bytes, so it is part of the grid identity).
    pub fn name(self) -> &'static str {
        match self {
            GridFormat::Csv => "csv",
            GridFormat::Markdown => "md",
            GridFormat::Json => "json",
        }
    }
}

/// A user-defined exploration grid: the cross product of core counts,
/// precisions and DVFS points.
#[derive(Debug, Clone)]
pub struct GridSpec {
    /// Active core counts (1..=9, the physical cluster).
    pub cores: Vec<usize>,
    /// Data formats to sweep.
    pub precisions: Vec<Precision>,
    /// Number of evenly spaced V/f points over 0.5–0.8 V (≥ 2; 4 lands
    /// exactly on the paper's Fig. 6b anchors, more is finer-than-paper).
    pub dvfs_steps: usize,
    /// Output renderer.
    pub format: GridFormat,
}

impl Default for GridSpec {
    fn default() -> Self {
        Self {
            cores: vec![2, 4, 8],
            precisions: vec![Precision::Int8, Precision::Fp32],
            dvfs_steps: 4,
            format: GridFormat::Markdown,
        }
    }
}

impl GridSpec {
    /// The distinct scenarios this grid simulates (one per
    /// (cores, precision) cell; DVFS points are derived analytically).
    pub fn scenarios(&self) -> Vec<Scenario> {
        let mut v = Vec::with_capacity(self.cores.len() * self.precisions.len());
        for &cores in &self.cores {
            for &p in &self.precisions {
                v.push(p.scenario(cores));
            }
        }
        v
    }

    /// Number of rendered data rows.
    pub fn rows(&self) -> usize {
        self.cores.len() * self.precisions.len() * self.dvfs_steps
    }
}

/// A parsed `vega sweep` invocation.
#[derive(Debug, Clone)]
pub struct SweepCmd {
    /// The grid to render.
    pub spec: GridSpec,
    /// Worker count (`--jobs`, default `VEGA_JOBS`/all cores).
    pub jobs: usize,
    /// Print cache statistics to stderr after rendering (`--stats`).
    pub stats: bool,
    /// Replay this grid's checkpoint journal and skip completed cells
    /// (`--resume`).
    pub resume: bool,
    /// Own only one deterministic slice of the grid (`--shard I/N`).
    pub shard: Option<ShardSpec>,
    /// Reassemble N shard journals into the full serial-order report
    /// (`--merge N`).
    pub merge: Option<u32>,
    /// Per-cell retry/timeout policy (`--retries`, `--backoff-ms`,
    /// `--timeout-ms`).
    pub policy: CellPolicy,
}

impl SweepCmd {
    /// Parse the arguments following `vega sweep`. Unknown flags and
    /// malformed values are errors (listed in the returned message).
    pub fn parse(args: &[String]) -> Result<SweepCmd, String> {
        let mut spec = GridSpec::default();
        let mut jobs = default_jobs();
        let mut stats = false;
        let mut resume = false;
        let mut shard = None;
        let mut merge = None;
        let mut policy = CellPolicy::default();
        let mut it = args.iter();
        while let Some(a) = it.next() {
            let mut value = |flag: &str| {
                it.next().map(String::as_str).ok_or_else(|| format!("{flag} needs a value"))
            };
            match a.as_str() {
                "--cores" => spec.cores = parse_cores(value("--cores")?)?,
                "--precision" => spec.precisions = parse_precisions(value("--precision")?)?,
                "--dvfs-steps" => {
                    let v = value("--dvfs-steps")?;
                    spec.dvfs_steps = v
                        .parse::<usize>()
                        .ok()
                        .filter(|&n| (2..=64).contains(&n))
                        .ok_or_else(|| format!("--dvfs-steps must be 2..=64, got '{v}'"))?;
                }
                "--format" => spec.format = GridFormat::parse(value("--format")?)?,
                "--jobs" => {
                    let v = value("--jobs")?;
                    jobs = v
                        .parse::<usize>()
                        .ok()
                        .filter(|&n| n > 0)
                        .ok_or_else(|| format!("--jobs must be a positive integer, got '{v}'"))?;
                }
                "--stats" => stats = true,
                "--resume" => resume = true,
                "--shard" => shard = Some(ShardSpec::parse(value("--shard")?)?),
                "--merge" => merge = Some(parse_merge(value("--merge")?)?),
                "--retries" => policy.retries = parse_retries(value("--retries")?)?,
                "--backoff-ms" => policy.backoff_cap_ms = parse_ms("--backoff-ms", value("--backoff-ms")?)?,
                "--timeout-ms" => policy.timeout_ms = Some(parse_ms("--timeout-ms", value("--timeout-ms")?)?),
                other => return Err(format!("unknown flag '{other}'")),
            }
        }
        if merge.is_some() && (shard.is_some() || resume) {
            return Err("--merge reassembles existing shard journals; it conflicts with --shard and --resume".into());
        }
        Ok(SweepCmd { spec, jobs, stats, resume, shard, merge, policy })
    }
}

/// Parse a `--merge` shard count (shared with `vega faults`).
pub(crate) fn parse_merge(v: &str) -> Result<u32, String> {
    v.parse::<u32>()
        .ok()
        .filter(|&n| (1..=4096).contains(&n))
        .ok_or_else(|| format!("--merge must be a shard count in 1..=4096, got '{v}'"))
}

/// Parse a `--retries` budget (shared with `vega faults`).
pub(crate) fn parse_retries(v: &str) -> Result<u32, String> {
    v.parse::<u32>()
        .ok()
        .filter(|&n| n <= 100)
        .ok_or_else(|| format!("--retries must be 0..=100, got '{v}'"))
}

/// Parse a millisecond flag value (`--backoff-ms`, `--timeout-ms`; 0 is
/// allowed — a zero backoff never sleeps, a zero timeout times every
/// cell out deterministically).
pub(crate) fn parse_ms(flag: &str, v: &str) -> Result<u64, String> {
    v.parse::<u64>().map_err(|_| format!("{flag} must be a millisecond count, got '{v}'"))
}

/// Parse a `--cores` value: comma-separated core counts and/or inclusive
/// ranges in either `a..b` or `a-b` form, e.g. `1..9`, `1-9`, `1,2,4,8`,
/// `1..4,8`. Duplicates collapse, first occurrence wins the ordering.
pub fn parse_cores(s: &str) -> Result<Vec<usize>, String> {
    let mut out = Vec::new();
    let mut push = |n: usize| -> Result<(), String> {
        if !(1..=N_CORES).contains(&n) {
            return Err(format!("core count {n} outside the physical cluster (1..={N_CORES})"));
        }
        if !out.contains(&n) {
            out.push(n);
        }
        Ok(())
    };
    for tok in s.split(',') {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        if let Some((a, b)) = tok.split_once("..").or_else(|| tok.split_once('-')) {
            let lo: usize =
                a.trim().parse().map_err(|_| format!("bad range start in '{tok}'"))?;
            let hi: usize = b.trim().parse().map_err(|_| format!("bad range end in '{tok}'"))?;
            if lo > hi {
                return Err(format!("empty range '{tok}'"));
            }
            for n in lo..=hi {
                push(n)?;
            }
        } else {
            push(tok.parse().map_err(|_| format!("bad core count '{tok}'"))?)?;
        }
    }
    if out.is_empty() {
        return Err("--cores selected no core counts".into());
    }
    Ok(out)
}

/// Parse a `--precision` value: comma-separated precision tokens.
pub fn parse_precisions(s: &str) -> Result<Vec<Precision>, String> {
    let mut out = Vec::new();
    for tok in s.split(',') {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        let p = Precision::parse(tok)?;
        if !out.contains(&p) {
            out.push(p);
        }
    }
    if out.is_empty() {
        return Err("--precision selected no formats".into());
    }
    Ok(out)
}

/// Cluster frequency at `vdd`, by piecewise-linear interpolation through
/// the paper's measured V/f anchors
/// ([`crate::power::tables::VF_ANCHORS`]: 0.5 V/120 MHz … 0.8 V/450
/// MHz), clamped at the ends.
pub fn vf_hz(vdd: f64) -> f64 {
    let pts = crate::power::tables::VF_ANCHORS;
    if vdd <= pts[0].0 {
        return pts[0].1;
    }
    for w in pts.windows(2) {
        let ((v0, f0), (v1, f1)) = (w[0], w[1]);
        if vdd <= v1 {
            return f0 + (f1 - f0) * (vdd - v0) / (v1 - v0);
        }
    }
    pts[pts.len() - 1].1
}

/// `steps` evenly spaced operating points over the 0.5–0.8 V DVFS range
/// (`steps` ≥ 2; 4 reproduces the paper's anchors exactly, larger values
/// are the finer-than-paper ladder the exploration exists for).
pub fn operating_points(steps: usize) -> Vec<OperatingPoint> {
    assert!(steps >= 2, "a DVFS ladder needs at least 2 points");
    let pts = crate::power::tables::VF_ANCHORS;
    let (lo, hi) = (pts[0].0, pts[pts.len() - 1].0);
    (0..steps)
        .map(|i| {
            let vdd = lo + (hi - lo) * i as f64 / (steps - 1) as f64;
            let f = vf_hz(vdd);
            OperatingPoint { name: "sweep", vdd, f_soc: f, f_cl: f }
        })
        .collect()
}

/// The derived values of one ok row (one cached simulation at one
/// operating point).
struct Point {
    vdd: f64,
    f_mhz: f64,
    cycles: u64,
    gops: f64,
    gops_per_w: f64,
    tcdm_pct: f64,
    fpu_pct: f64,
}

/// One rendered grid row: an operating point of an ok cell, or the
/// status row of an errored cell (ISSUE 6 — a panicking scenario yields
/// one `status` row and the rest of the grid still renders).
struct Row {
    cores: usize,
    precision: &'static str,
    point: Option<Point>,
    status: String,
}

/// Keep a panic message one-cell-safe: commas, pipes and newlines would
/// break the CSV/Markdown framing (shared with the `vega faults` grid).
pub(crate) fn sanitize_cell(msg: &str) -> String {
    msg.replace(['\n', '\r'], " ").replace([',', '|'], ";")
}

/// The journal identity of a sweep grid (ISSUE 7): a versioned hash of
/// the grid kind, every rendering parameter that shapes the output
/// bytes, and the stable ID of every cell in grid order. Feeds
/// [`journal::GridSession::open`] — two different grids can never share
/// a journal.
pub fn grid_key(spec: &GridSpec) -> u64 {
    let dvfs = format!("dvfs={}", spec.dvfs_steps);
    let format = format!("format={}", spec.format.name());
    let ids: Vec<String> = spec
        .scenarios()
        .iter()
        .map(|s| super::persist::key_string(&s.canonical().key()))
        .collect();
    journal::grid_key("sweep", &[&dvfs, &format], &ids)
}

/// A rendered grid plus the cell accounting the CLI's exit code and
/// stats line need.
pub struct RenderedGrid {
    /// The rendered table (ends in exactly one newline).
    pub text: String,
    /// Cells that ended in `error`/`timeout` (renders still complete;
    /// the CLI exits non-zero when this is > 0).
    pub failed: usize,
    /// Cells skipped because this session's shard does not own them.
    pub skipped: usize,
}

/// Render `spec` through `eng`: fan the distinct cells out across the
/// engine's worker pool (fault-isolated — see [`Row`]), then emit rows
/// in deterministic grid order. The returned string ends in exactly one
/// newline.
pub fn render(eng: &SweepEngine, spec: &GridSpec) -> String {
    render_with(eng, spec, &GridSession::off()).text
}

/// As [`render`], but through a [`GridSession`] (ISSUE 7): journaled
/// prior cells replay, shard-unowned cells emit no rows at all, and the
/// returned [`RenderedGrid`] carries the failed/skipped cell counts.
pub fn render_with(eng: &SweepEngine, spec: &GridSpec, session: &GridSession) -> RenderedGrid {
    // Fault-isolated parallel prefetch of every distinct cell; an
    // errored cell becomes its own status row below instead of tearing
    // the whole grid down.
    let results = eng.run_scenarios_with(&spec.scenarios(), session);
    let ops = operating_points(spec.dvfs_steps);
    let mut rows = Vec::with_capacity(spec.rows());
    let mut failed = 0;
    let mut skipped = 0;
    let mut cell = 0;
    for &cores in &spec.cores {
        for &p in &spec.precisions {
            match &results[cell] {
                None => skipped += 1,
                Some(Ok(res)) => {
                    let kr = &res.run;
                    for op in &ops {
                        let (gops, gops_per_w) = coordinator::efficiency(kr, *op, 0.0);
                        rows.push(Row {
                            cores,
                            precision: p.name(),
                            point: Some(Point {
                                vdd: op.vdd,
                                f_mhz: op.f_cl / 1e6,
                                cycles: kr.stats.cycles,
                                gops,
                                gops_per_w,
                                tcdm_pct: kr.stats.tcdm_conflict_rate * 100.0,
                                fpu_pct: kr.stats.fpu_contention_rate * 100.0,
                            }),
                            status: "ok".into(),
                        });
                    }
                }
                Some(Err(e)) => {
                    failed += 1;
                    rows.push(Row {
                        cores,
                        precision: p.name(),
                        point: None,
                        status: sanitize_cell(&e.message),
                    });
                }
            }
            cell += 1;
        }
    }
    let text = match spec.format {
        GridFormat::Csv => render_csv(&rows),
        GridFormat::Markdown => render_md(&rows),
        GridFormat::Json => render_json(spec, &rows),
    };
    RenderedGrid { text, failed, skipped }
}

const COLUMNS: [&str; 10] = [
    "cores",
    "precision",
    "vdd_v",
    "f_mhz",
    "cycles",
    "gops",
    "gops_per_w",
    "tcdm_conflict_pct",
    "fpu_contention_pct",
    "status",
];

impl Row {
    fn cells(&self) -> [String; 10] {
        match &self.point {
            Some(pt) => [
                self.cores.to_string(),
                self.precision.to_string(),
                format!("{:.3}", pt.vdd),
                format!("{:.1}", pt.f_mhz),
                pt.cycles.to_string(),
                format!("{:.3}", pt.gops),
                format!("{:.1}", pt.gops_per_w),
                format!("{:.2}", pt.tcdm_pct),
                format!("{:.2}", pt.fpu_pct),
                self.status.clone(),
            ],
            // Errored cell: coordinates + status only, numerics blank —
            // unmistakable for a real measurement.
            None => [
                self.cores.to_string(),
                self.precision.to_string(),
                String::new(),
                String::new(),
                String::new(),
                String::new(),
                String::new(),
                String::new(),
                String::new(),
                self.status.clone(),
            ],
        }
    }
}

fn render_csv(rows: &[Row]) -> String {
    let mut out = COLUMNS.join(",");
    out.push('\n');
    for r in rows {
        out.push_str(&r.cells().join(","));
        out.push('\n');
    }
    out
}

fn render_md(rows: &[Row]) -> String {
    let mut out = format!("| {} |\n", COLUMNS.join(" | "));
    out.push_str(&format!("|{}\n", "---:|".repeat(COLUMNS.len())));
    for r in rows {
        out.push_str(&format!("| {} |\n", r.cells().join(" | ")));
    }
    out
}

fn render_json(spec: &GridSpec, rows: &[Row]) -> String {
    let cores: Vec<String> = spec.cores.iter().map(|c| c.to_string()).collect();
    let precs: Vec<String> =
        spec.precisions.iter().map(|p| format!("\"{}\"", p.name())).collect();
    let mut out = format!(
        "{{\n  \"grid\": {{\"cores\": [{}], \"precisions\": [{}], \"dvfs_steps\": {}}},\n  \"rows\": [\n",
        cores.join(", "),
        precs.join(", "),
        spec.dvfs_steps
    );
    for (i, r) in rows.iter().enumerate() {
        let sep = if i + 1 == rows.len() { "" } else { "," };
        match &r.point {
            Some(pt) => out.push_str(&format!(
                "    {{\"cores\": {}, \"precision\": \"{}\", \"vdd_v\": {:.3}, \"f_mhz\": {:.1}, \
                 \"cycles\": {}, \"gops\": {:.3}, \"gops_per_w\": {:.1}, \
                 \"tcdm_conflict_pct\": {:.2}, \"fpu_contention_pct\": {:.2}, \
                 \"status\": \"ok\"}}{sep}\n",
                r.cores,
                r.precision,
                pt.vdd,
                pt.f_mhz,
                pt.cycles,
                pt.gops,
                pt.gops_per_w,
                pt.tcdm_pct,
                pt.fpu_pct,
            )),
            None => out.push_str(&format!(
                "    {{\"cores\": {}, \"precision\": \"{}\", \"status\": \"{}\"}}{sep}\n",
                r.cores, r.precision, r.status,
            )),
        }
    }
    out.push_str("  ]\n}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cores_parse_ranges_lists_and_mixes() {
        assert_eq!(parse_cores("1..9").unwrap(), vec![1, 2, 3, 4, 5, 6, 7, 8, 9]);
        assert_eq!(parse_cores("1-9").unwrap(), vec![1, 2, 3, 4, 5, 6, 7, 8, 9]);
        assert_eq!(parse_cores("1-3,8").unwrap(), vec![1, 2, 3, 8]);
        assert_eq!(parse_cores("1,2,4,8").unwrap(), vec![1, 2, 4, 8]);
        assert_eq!(parse_cores("1..3,8,2").unwrap(), vec![1, 2, 3, 8]);
        assert!(parse_cores("0..2").is_err());
        assert!(parse_cores("10").is_err());
        assert!(parse_cores("4..2").is_err());
        assert!(parse_cores("").is_err());
        assert!(parse_cores("two").is_err());
    }

    #[test]
    fn precision_parse_accepts_the_full_axis_including_fp8() {
        assert_eq!(parse_precisions("int8,fp16").unwrap(), vec![Precision::Int8, Precision::Fp16]);
        assert_eq!(parse_precisions("i32").unwrap(), vec![Precision::Int32]);
        assert_eq!(Precision::parse("fp8").unwrap(), Precision::Fp8);
        assert_eq!(Precision::parse("f8").unwrap(), Precision::Fp8);
        assert_eq!(
            parse_precisions("int8,fp8,fp16").unwrap(),
            vec![Precision::Int8, Precision::Fp8, Precision::Fp16]
        );
        assert!(Precision::parse("bf16").is_err());
        assert!(Precision::ALL.contains(&Precision::Fp8), "fp8 is a first-class grid axis");
    }

    #[test]
    fn fp8_cells_render_real_rows() {
        let spec = GridSpec {
            cores: vec![1, 2],
            precisions: vec![Precision::Fp8],
            dvfs_steps: 2,
            format: GridFormat::Csv,
        };
        let eng = SweepEngine::serial();
        let out = render(&eng, &spec);
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines.len(), 1 + spec.rows());
        assert!(lines[1].starts_with("1,fp8,0.500,120.0,"));
        // Real cycle counts, not placeholders.
        let cycles: u64 = lines[1].split(',').nth(4).unwrap().parse().unwrap();
        assert!(cycles > 0);
        let (_, misses) = eng.cache().counters();
        assert_eq!(misses, 2, "one simulation per fp8 (cores, precision) cell");
    }

    #[test]
    fn default_ladder_lands_on_the_paper_anchors() {
        let ops = operating_points(4);
        let vf: Vec<(f64, f64)> = ops.iter().map(|o| (o.vdd, o.f_cl)).collect();
        for ((v, f), (ev, ef)) in
            vf.iter().zip([(0.5, 120e6), (0.6, 220e6), (0.7, 330e6), (0.8, 450e6)])
        {
            assert!((v - ev).abs() < 1e-12, "vdd {v} vs {ev}");
            assert!((f - ef).abs() < 1.0, "f {f} vs {ef}");
        }
        // Finer-than-paper ladder interpolates monotonically.
        let fine = operating_points(7);
        assert_eq!(fine.len(), 7);
        assert!(fine.windows(2).all(|w| w[1].f_cl > w[0].f_cl));
    }

    #[test]
    fn cmd_parse_round_trips_the_acceptance_invocation() {
        let args: Vec<String> = ["--cores", "1..9", "--precision", "int8,fp16", "--format", "csv"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let cmd = SweepCmd::parse(&args).unwrap();
        assert_eq!(cmd.spec.cores.len(), 9);
        assert_eq!(cmd.spec.precisions, vec![Precision::Int8, Precision::Fp16]);
        assert_eq!(cmd.spec.format, GridFormat::Csv);
        assert_eq!(cmd.spec.rows(), 9 * 2 * 4);
        assert!(SweepCmd::parse(&["--bogus".to_string()]).is_err());
        assert!(SweepCmd::parse(&["--cores".to_string()]).is_err());
    }

    /// ISSUE 7 flags: resume/shard/merge/policy parse, and merge
    /// conflicts with the flags that *produce* journals.
    #[test]
    fn cmd_parse_handles_resume_shard_merge_and_policy() {
        let args = |toks: &[&str]| -> Vec<String> { toks.iter().map(|s| s.to_string()).collect() };
        let cmd = SweepCmd::parse(&args(&[
            "--resume",
            "--shard",
            "2/4",
            "--retries",
            "0",
            "--backoff-ms",
            "0",
            "--timeout-ms",
            "5000",
        ]))
        .unwrap();
        assert!(cmd.resume);
        assert_eq!(cmd.shard, Some(ShardSpec { index: 2, total: 4 }));
        assert_eq!(cmd.merge, None);
        assert_eq!(
            cmd.policy,
            CellPolicy { retries: 0, backoff_cap_ms: 0, timeout_ms: Some(5000) }
        );
        let merged = SweepCmd::parse(&args(&["--merge", "2"])).unwrap();
        assert_eq!(merged.merge, Some(2));
        assert!(SweepCmd::parse(&args(&["--merge", "2", "--shard", "1/2"])).is_err());
        assert!(SweepCmd::parse(&args(&["--merge", "2", "--resume"])).is_err());
        assert!(SweepCmd::parse(&args(&["--shard", "3/2"])).is_err());
        assert!(SweepCmd::parse(&args(&["--merge", "0"])).is_err());
        assert!(SweepCmd::parse(&args(&["--timeout-ms", "soon"])).is_err());
    }

    /// The journal key tracks everything that shapes the rendered bytes.
    #[test]
    fn sweep_grid_key_tracks_cells_and_render_params() {
        let base = GridSpec {
            cores: vec![1, 2],
            precisions: vec![Precision::Int8],
            dvfs_steps: 2,
            format: GridFormat::Csv,
        };
        let k = grid_key(&base);
        assert_eq!(k, grid_key(&base.clone()), "deterministic");
        assert_ne!(k, grid_key(&GridSpec { cores: vec![1, 3], ..base.clone() }));
        assert_ne!(k, grid_key(&GridSpec { dvfs_steps: 3, ..base.clone() }));
        assert_ne!(k, grid_key(&GridSpec { format: GridFormat::Json, ..base.clone() }));
        assert_ne!(
            k,
            grid_key(&GridSpec { precisions: vec![Precision::Fp16], ..base.clone() })
        );
    }

    #[test]
    fn csv_grid_renders_every_row_of_a_small_grid() {
        let spec = GridSpec {
            cores: vec![1, 2],
            precisions: vec![Precision::Int8],
            dvfs_steps: 3,
            format: GridFormat::Csv,
        };
        let eng = SweepEngine::serial();
        let out = render(&eng, &spec);
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines.len(), 1 + spec.rows());
        assert_eq!(lines[0], COLUMNS.join(","));
        assert!(lines[1].starts_with("1,int8,0.500,120.0,"));
        // 3 DVFS rows per cell share one simulation (same cycle count).
        let cyc = |l: &str| l.split(',').nth(4).unwrap().to_string();
        assert_eq!(cyc(lines[1]), cyc(lines[2]));
        assert_eq!(cyc(lines[1]), cyc(lines[3]));
        let (_, misses) = eng.cache().counters();
        assert_eq!(misses, 2, "one simulation per (cores, precision) cell");
    }

    /// ISSUE 6: an errored cell renders coordinates + status with every
    /// numeric column blank, and the message is framing-safe.
    #[test]
    fn errored_cells_render_as_status_rows() {
        let r = Row {
            cores: 3,
            precision: "int8",
            point: None,
            status: sanitize_cell("boom, with | bars\nand a newline"),
        };
        let cells = r.cells();
        assert_eq!(cells[0], "3");
        assert_eq!(cells[1], "int8");
        assert!(cells[2..9].iter().all(|c| c.is_empty()));
        assert_eq!(cells[9], "boom; with ; bars and a newline");
        assert_eq!(COLUMNS[9], "status");
    }

    #[test]
    fn md_and_json_render_consistent_row_counts() {
        let base = GridSpec {
            cores: vec![2],
            precisions: vec![Precision::Fp32],
            dvfs_steps: 2,
            format: GridFormat::Markdown,
        };
        let eng = SweepEngine::serial();
        let md = render(&eng, &base);
        assert_eq!(md.lines().count(), 2 + base.rows());
        let json = render(&eng, &GridSpec { format: GridFormat::Json, ..base.clone() });
        assert!(json.contains("\"dvfs_steps\": 2"));
        assert_eq!(json.matches("\"cores\": 2,").count(), base.rows());
        // JSON reuses the Markdown render's cached simulation.
        let (hits, misses) = eng.cache().counters();
        assert_eq!(misses, 1);
        assert!(hits >= 1);
    }
}
