//! The worker pool: scenario fan-out, report fan-out, memoized lookup.
//!
//! `std::thread::scope` keeps everything dependency-free and borrow-safe;
//! the work queue is an atomic index over the input slice and results land
//! in index-tagged `OnceLock` slots, so output order is the input (paper)
//! order no matter which worker finishes when. Each worker thread owns one
//! lazily-built [`SimArena`] (thread-local), reused across every scenario
//! it drains — no per-scenario `Cluster`/L2 allocations.
//!
//! Fault isolation (ISSUE 6): every work item runs under
//! `catch_unwind`, so one panicking scenario yields one structured
//! [`SimError`] cell instead of tearing down the whole sweep. Errored
//! cells are never written to any cache tier (the panic unwinds out of
//! the memo's compute before a value exists to store).
//!
//! Crash-safety & policies (ISSUE 7): grid drains go through
//! [`SweepEngine::run_scenarios_with`] / [`SweepEngine::run_campaigns_with`]
//! / [`SweepEngine::run_lifecycles_with`],
//! which thread a [`GridSession`] (shard ownership + checkpoint journal,
//! see [`super::journal`]) around every cell, and every cell executes
//! under a [`CellPolicy`]: deterministic panics fail once and are never
//! retried (retrying a deterministic model bug only wastes the grid's
//! time), panics carrying the [`Transient`] marker get bounded retries
//! with capped exponential backoff (the [`super::cache::OnceMap`] memo
//! is retry-safe — a panicking compute caches nothing), and an optional
//! per-cell wall-clock watchdog marks runaway cells
//! [`FailKind::Timeout`] instead of hanging the grid.

use std::cell::RefCell;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Once, OnceLock};
use std::time::Duration;

use super::cache::{OnceMap, SimCache};
use super::journal::{CellStatus, GridSession};
use super::persist::DiskStore;
use super::scenario::{Scenario, SimArena, SimResult};
use crate::coordinator::CwuSummary;
use crate::dnn::{run_network, Network, NetworkReport, PipelineConfig};
use crate::faults::{run_campaign, Campaign, CampaignOutcome, FaultPlan, TierMask};
use crate::kernels::KernelRun;
use crate::lifecycle::{run_lifecycle, LifecycleReport, LifecycleScenario, SleepKind};

/// One errored sweep cell: work item `index` panicked with `message`.
///
/// The replacement for the worker pool's old
/// `expect("every work item produced a result")` — a panicking scenario
/// now surfaces as data, every other cell completes normally, and
/// nothing of the errored cell reaches a cache.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SimError {
    /// Index of the failed item in the submitted work list.
    pub index: usize,
    /// Failure classification (drives the retry policy and the
    /// journaled/rendered status).
    pub kind: FailKind,
    /// The panic payload, stringified.
    pub message: String,
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "work item {}: {}", self.index, self.message)
    }
}

/// Why a cell failed — the classification behind the ISSUE 7 retry
/// policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailKind {
    /// An ordinary panic: a model bug or invalid input. Re-running the
    /// same pure simulation re-raises the same panic, so these are
    /// never retried (the PR 6 contract).
    Deterministic,
    /// A panic carrying the [`Transient`] marker — an environmental
    /// failure (I/O hiccup, resource pressure) worth bounded retries
    /// with capped backoff.
    Transient,
    /// The cell exceeded [`CellPolicy::timeout_ms`] and was abandoned
    /// by the watchdog.
    Timeout,
}

impl FailKind {
    /// The journal status a terminal failure of this kind records.
    pub fn status(self) -> CellStatus {
        match self {
            FailKind::Deterministic | FailKind::Transient => CellStatus::Error,
            FailKind::Timeout => CellStatus::Timeout,
        }
    }
}

/// Panic-payload marker for *transient* failures: code on the cell path
/// that hits a retryable environmental error raises it with
/// `std::panic::panic_any(Transient("..".into()))`, and
/// [`SweepEngine`]'s policy layer retries the cell (bounded, capped
/// backoff) instead of failing it outright. An ordinary `panic!` stays
/// deterministic and is never retried.
pub struct Transient(pub String);

/// Panic-payload marker raised by the watchdog when a cell overruns its
/// wall-clock budget; classified as [`FailKind::Timeout`].
struct CellTimeout {
    ms: u64,
}

/// Per-cell execution policy: retry budget for [`Transient`] failures
/// and an optional wall-clock watchdog.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CellPolicy {
    /// Max *re*-tries of a transiently failing cell (attempts = 1 +
    /// retries). Deterministic panics ignore this and fail on the
    /// first attempt.
    pub retries: u32,
    /// Cap on the exponential backoff between retries (10 ms, 20 ms,
    /// 40 ms, … clamped here; 0 disables sleeping entirely).
    pub backoff_cap_ms: u64,
    /// Wall-clock budget per cell simulation. `None` (the default)
    /// trusts cells to terminate; `Some(ms)` runs each simulation under
    /// a watchdog that abandons it after `ms` milliseconds and marks
    /// the cell [`FailKind::Timeout`]. `Some(0)` times every simulated
    /// cell out immediately (a deterministic CI aid for exercising the
    /// timeout path).
    pub timeout_ms: Option<u64>,
}

impl Default for CellPolicy {
    fn default() -> Self {
        CellPolicy { retries: 2, backoff_cap_ms: 250, timeout_ms: None }
    }
}

/// Classify a caught panic payload into (kind, message).
fn classify(payload: &(dyn std::any::Any + Send)) -> (FailKind, String) {
    if let Some(t) = payload.downcast_ref::<Transient>() {
        (FailKind::Transient, t.0.clone())
    } else if let Some(t) = payload.downcast_ref::<CellTimeout>() {
        (FailKind::Timeout, format!("timeout after {} ms", t.ms))
    } else {
        (FailKind::Deterministic, panic_message(payload))
    }
}

/// Test/CI aid: `VEGA_CELL_DELAY_MS` sleeps this long before every cell
/// attempt, widening the window the kill-and-resume integration test
/// shoots at. Parsed once; zero-cost when unset.
fn test_delay() {
    static DELAY_MS: OnceLock<u64> = OnceLock::new();
    let ms = *DELAY_MS.get_or_init(|| {
        std::env::var("VEGA_CELL_DELAY_MS").ok().and_then(|s| s.parse().ok()).unwrap_or(0)
    });
    if ms > 0 {
        std::thread::sleep(Duration::from_millis(ms));
    }
}

/// Run `work` under a wall-clock watchdog: the value (or the panic) of
/// `work` is forwarded if it finishes within `ms` milliseconds;
/// otherwise the runaway worker thread is abandoned (detached — it can
/// finish into the void) and a [`CellTimeout`] panic is raised on the
/// calling thread for [`classify`] to pick up.
fn with_watchdog<T: Send + 'static>(ms: u64, work: impl FnOnce() -> T + Send + 'static) -> T {
    if ms == 0 {
        std::panic::panic_any(CellTimeout { ms });
    }
    let (tx, rx) = mpsc::channel();
    let handle = std::thread::spawn(move || {
        let _ = tx.send(catch_unwind(AssertUnwindSafe(work)));
    });
    match rx.recv_timeout(Duration::from_millis(ms)) {
        Ok(Ok(v)) => {
            let _ = handle.join();
            v
        }
        Ok(Err(p)) => {
            let _ = handle.join();
            resume_unwind(p)
        }
        // Timeout or a worker that died without sending: the cell is
        // gone either way. The thread is deliberately not joined.
        Err(_) => std::panic::panic_any(CellTimeout { ms }),
    }
}

/// Warn once per process when a resumed cell's recomputed digest differs
/// from its journaled one (a changed model/cache between runs — the
/// recomputed result wins).
fn warn_digest_mismatch_once(cell_id: &str) {
    static WARN: Once = Once::new();
    WARN.call_once(|| {
        eprintln!(
            "vega: journaled digest mismatch for cell {cell_id}; \
             keeping the recomputed result (model or cache changed between runs)"
        )
    });
}

/// Stringify a panic payload (the two shapes `panic!` produces).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

thread_local! {
    /// The calling thread's owned simulation arena (one per worker).
    static ARENA: RefCell<SimArena> = RefCell::new(SimArena::new());
}

/// Worker count to use when the caller doesn't pass `--jobs`: `VEGA_JOBS`
/// if set, else the machine's available parallelism.
pub fn default_jobs() -> usize {
    std::env::var("VEGA_JOBS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| std::thread::available_parallelism().map_or(1, |n| n.get()))
}

/// The sweep execution engine: a [`SimCache`] (kernel scenarios), sibling
/// memos for DNN pipeline runs, the CWU reference workload and the HD
/// ablation, an optional persistent [`DiskStore`], and a worker count.
pub struct SweepEngine {
    jobs: usize,
    cache: SimCache,
    nets: OnceMap<String, NetworkReport>,
    cwu: OnceMap<u64, CwuSummary>,
    hd: OnceMap<usize, f64>,
    faults: OnceMap<String, CampaignOutcome>,
    lifecycles: OnceMap<String, LifecycleReport>,
    disk: Option<DiskStore>,
    policy: CellPolicy,
}

impl SweepEngine {
    /// In-memory engine with `jobs` workers (no cross-process
    /// persistence; see [`SweepEngine::persistent`]).
    pub fn new(jobs: usize) -> Self {
        Self {
            jobs: jobs.max(1),
            cache: SimCache::new(),
            nets: OnceMap::new(true),
            cwu: OnceMap::new(true),
            hd: OnceMap::new(true),
            faults: OnceMap::new(true),
            lifecycles: OnceMap::new(true),
            disk: None,
            policy: CellPolicy::default(),
        }
    }

    /// Single-worker engine (unit tests, deterministic baselines).
    pub fn serial() -> Self {
        Self::new(1)
    }

    /// Engine with memoization off — every lookup re-simulates. The
    /// serial-without-cache baseline of `cargo bench --bench sweeps`.
    pub fn without_cache(jobs: usize) -> Self {
        Self {
            jobs: jobs.max(1),
            cache: SimCache::with_enabled(false),
            nets: OnceMap::new(false),
            cwu: OnceMap::new(false),
            hd: OnceMap::new(false),
            faults: OnceMap::new(false),
            lifecycles: OnceMap::new(false),
            disk: None,
            policy: CellPolicy::default(),
        }
    }

    /// Engine backed by an explicit on-disk store: in-memory misses probe
    /// `store` before simulating, and freshly simulated results are
    /// written back, so a later engine (or process) on the same directory
    /// starts warm.
    pub fn with_disk(jobs: usize, store: DiskStore) -> Self {
        Self { disk: Some(store), ..Self::new(jobs) }
    }

    /// Engine backed by the default on-disk store (`$VEGA_CACHE_DIR`,
    /// else `target/vega-cache`; `VEGA_CACHE=off` disables). The CLI's
    /// engine. Falls back to a memory-only engine — with a warning on
    /// stderr — when the store directory cannot be created.
    pub fn persistent(jobs: usize) -> Self {
        match DiskStore::open_default() {
            Ok(Some(store)) => Self::with_disk(jobs, store),
            Ok(None) => Self::new(jobs),
            Err(e) => {
                eprintln!("vega: on-disk sim cache disabled ({e})");
                Self::new(jobs)
            }
        }
    }

    /// The process-wide shared engine behind the per-id compatibility
    /// paths ([`crate::bench::run`], the `coordinator::bench_*` drivers):
    /// persistent and sized by [`default_jobs`], so repeated per-id calls
    /// — and repeated CLI invocations across processes — reuse cached
    /// cycle results instead of rebuilding Cluster/L2 state per call.
    pub fn global() -> &'static SweepEngine {
        static GLOBAL: OnceLock<SweepEngine> = OnceLock::new();
        GLOBAL.get_or_init(|| SweepEngine::persistent(default_jobs()))
    }

    pub fn jobs(&self) -> usize {
        self.jobs
    }

    /// Replace the per-cell retry/timeout policy (see [`CellPolicy`]).
    pub fn set_cell_policy(&mut self, policy: CellPolicy) {
        self.policy = policy;
    }

    /// The active per-cell retry/timeout policy.
    pub fn cell_policy(&self) -> CellPolicy {
        self.policy
    }

    pub fn cache(&self) -> &SimCache {
        &self.cache
    }

    /// Memoized result of one scenario: in-memory cache first, then the
    /// on-disk store (when persistent), then a simulation on this
    /// thread's arena (written back to disk). Disk probes happen inside
    /// the in-memory miss path, so [`SimCache`] hit/miss counters — and
    /// every determinism invariant built on them — are unaffected by
    /// persistence.
    pub fn result(&self, s: Scenario) -> SimResult {
        let s = s.canonical();
        let key = s.key();
        self.cache.get_or_sim(key.clone(), || {
            if let Some(disk) = &self.disk {
                if let Some(cached) = disk.load(&key) {
                    return cached;
                }
                let fresh = self.simulate_cell(s);
                disk.store(&key, &fresh);
                return fresh;
            }
            self.simulate_cell(s)
        })
    }

    /// Run one simulation under the policy's optional watchdog. The
    /// watchdog needs a `'static` worker, so a watched simulation uses
    /// a fresh arena on a disposable thread; the unwatched default path
    /// keeps the thread-local arena reuse.
    fn simulate_cell(&self, s: Scenario) -> SimResult {
        match self.policy.timeout_ms {
            Some(ms) => with_watchdog(ms, move || s.simulate(&mut SimArena::new())),
            None => ARENA.with(|a| s.simulate(&mut a.borrow_mut())),
        }
    }

    /// Memoized [`KernelRun`] of one scenario (what the table/figure
    /// renderers consume; per-operating-point energy is derived from it
    /// analytically, which is what makes V/f sweeps one simulation each).
    pub fn kernel_run(&self, s: Scenario) -> KernelRun {
        self.result(s).run
    }

    /// Memoized DNN pipeline run (Figs. 9–11, Table VII/VIII rows and the
    /// store-policy / double-buffering ablations). `run_network` is a pure
    /// function of the network and config, so recurring (network, config)
    /// pairs across reports — e.g. MobileNetV2 `AllMram`, used by Fig. 9,
    /// Fig. 10, Fig. 11 and an ablation — run once per engine, and, on a
    /// persistent engine, once per *store directory*: in-memory misses
    /// probe the on-disk network tier before running the pipeline, then
    /// write back (the same layering as [`SweepEngine::result`], with the
    /// same counter transparency).
    ///
    /// The memo key is the canonical [`crate::dnn::net_key`] string: an
    /// explicit byte-encoded structure hash of the per-layer topology
    /// (the DNN analogue of the kernel cache's `Program::content_hash`)
    /// plus the full operating point, engine and policy — so a topology
    /// edit that preserves name and aggregate totals can never serve a
    /// stale per-layer breakdown, on disk or in memory.
    pub fn network_report(&self, net: &Network, config: PipelineConfig) -> NetworkReport {
        let key = crate::dnn::net_key(net, &config);
        self.nets.get_or_compute(key.clone(), || {
            if let Some(disk) = &self.disk {
                if let Some(cached) = disk.load_net(&key) {
                    return cached;
                }
                let fresh = run_network(net, config);
                disk.store_net(&key, &fresh);
                return fresh;
            }
            run_network(net, config)
        })
    }

    /// (hits, misses) of the network-report memo.
    pub fn network_counters(&self) -> (u64, u64) {
        self.nets.counters()
    }

    /// Memoized CWU reference workload (Table I's measurement setup —
    /// dominated by HDC training, which is a pure function of the CWU
    /// clock and the fixed encoder config/seed). One training run per
    /// distinct `f_clk` per engine, however many times Table I renders.
    pub fn cwu_summary(&self, f_clk: f64) -> CwuSummary {
        self.cwu.get_or_compute(f_clk.to_bits(), || crate::coordinator::cwu_summary(f_clk))
    }

    /// (hits, misses) of the CWU reference-workload memo.
    pub fn cwu_counters(&self) -> (u64, u64) {
        self.cwu.counters()
    }

    /// Memoized HD-dimension ablation accuracy (a pure function of the
    /// Hypnos vector dimension; the 2-shot noisy EMG training inside is
    /// the most expensive part of the ablation report).
    pub fn hd_accuracy(&self, dim: usize) -> f64 {
        self.hd.get_or_compute(dim, || crate::bench::ablations::hd_ablation_accuracy(dim))
    }

    /// (hits, misses) of the HD-dimension ablation memo.
    pub fn hd_counters(&self) -> (u64, u64) {
        self.hd.counters()
    }

    /// (hits, misses, writes) of the on-disk store's kernel tier, or
    /// `None` for a memory-only engine. Disk lookups happen once per
    /// in-memory miss, so on a warm store `hits` equals the in-memory
    /// miss count and `misses`/`writes` are zero.
    pub fn disk_counters(&self) -> Option<(u64, u64, u64)> {
        self.disk.as_ref().map(|d| d.counters())
    }

    /// (hits, misses, writes) of the on-disk store's network-report
    /// tier, or `None` for a memory-only engine. Same layering as
    /// [`SweepEngine::disk_counters`]: one disk probe per in-memory
    /// network-memo miss.
    pub fn disk_net_counters(&self) -> Option<(u64, u64, u64)> {
        self.disk.as_ref().map(|d| d.net_counters())
    }

    /// Drain a scenario list through the worker pool; `out[i]` corresponds
    /// to `list[i]` regardless of completion order. A panicking scenario
    /// aborts the call (re-raising the first failure); callers that need
    /// to survive faults use [`SweepEngine::try_run_scenarios`].
    pub fn run_scenarios(&self, list: &[Scenario]) -> Vec<SimResult> {
        self.try_run_scenarios(list)
            .into_iter()
            .map(|r| r.unwrap_or_else(|e| panic!("scenario {}: {}", e.index, e.message)))
            .collect()
    }

    /// As [`SweepEngine::run_scenarios`], but fault-isolated (ISSUE 6):
    /// each cell is a `Result`, a panicking scenario yields its own
    /// [`SimError`] while every other cell completes and matches a
    /// fault-free run, and errored cells are never cached.
    pub fn try_run_scenarios(&self, list: &[Scenario]) -> Vec<Result<SimResult, SimError>> {
        self.run_scenarios_with(list, &GridSession::off())
            .into_iter()
            .map(|c| c.expect("an unsharded session owns every cell"))
            .collect()
    }

    /// Drain a scenario grid through a [`GridSession`] (ISSUE 7):
    /// `out[i]` is `None` when the session's shard does not own cell
    /// `i`, and otherwise the cell's result — served from a journaled
    /// prior record (done cells recompute through the cache tiers,
    /// which a warm store turns into disk hits; failed cells replay
    /// their journaled message verbatim) or executed live under the
    /// engine's [`CellPolicy`] and journaled on completion. Cell IDs
    /// are the stable content-addressed store key strings, so shard
    /// ownership and journal identity are machine-portable.
    pub fn run_scenarios_with(
        &self,
        list: &[Scenario],
        session: &GridSession,
    ) -> Vec<Option<Result<SimResult, SimError>>> {
        self.run_cells(
            list.len(),
            session,
            |i| super::persist::key_string(&list[i].canonical().key()),
            |i| self.result(list[i]),
            |r| r.outputs_digest,
        )
    }

    /// Memoized fault-campaign outcome: in-memory memo first, then the
    /// on-disk `.flt` tier (when persistent), then a live run. The
    /// fault-free oracle goes through the ordinary [`SweepEngine::result`]
    /// path — so it is cached and shared — but the *faulted* simulation
    /// inside the campaign never touches the `.sim` tier: corrupted
    /// results must not be mistakable for clean ones.
    pub fn campaign(&self, c: &Campaign) -> CampaignOutcome {
        let key = c.key();
        let c = *c;
        self.faults.get_or_compute(key.clone(), || {
            if let Some(disk) = &self.disk {
                if let Some(cached) = disk.load_fault(&key) {
                    return cached;
                }
                let fresh = self.run_campaign_live(&c);
                disk.store_fault(&key, &fresh);
                return fresh;
            }
            self.run_campaign_live(&c)
        })
    }

    fn run_campaign_live(&self, c: &Campaign) -> CampaignOutcome {
        let oracle = self.result(c.scenario);
        match self.policy.timeout_ms {
            Some(ms) => {
                let c = *c;
                with_watchdog(ms, move || run_campaign(&c, &oracle, &mut SimArena::new()))
            }
            None => ARENA.with(|a| run_campaign(c, &oracle, &mut a.borrow_mut())),
        }
    }

    /// Drain a campaign grid through the worker pool, fault-isolated:
    /// `out[i]` corresponds to `grid[i]`, and a panicking campaign yields
    /// a [`SimError`] cell instead of aborting the grid.
    pub fn run_campaigns(&self, grid: &[Campaign]) -> Vec<Result<CampaignOutcome, SimError>> {
        self.run_campaigns_with(grid, &GridSession::off())
            .into_iter()
            .map(|c| c.expect("an unsharded session owns every cell"))
            .collect()
    }

    /// Campaign-grid analogue of [`SweepEngine::run_scenarios_with`]:
    /// shard-aware, journal-replaying, policy-driven. Cell IDs are the
    /// campaigns' versioned [`Campaign::key`] strings.
    pub fn run_campaigns_with(
        &self,
        grid: &[Campaign],
        session: &GridSession,
    ) -> Vec<Option<Result<CampaignOutcome, SimError>>> {
        self.run_cells(
            grid.len(),
            session,
            |i| grid[i].key(),
            |i| self.campaign(&grid[i]),
            |o| o.faulted_digest,
        )
    }

    /// Memoized lifecycle report: in-memory memo first, then the
    /// on-disk `.lfc` tier (when persistent), then a live trace replay.
    /// The true-event inference inside goes through the ordinary
    /// [`SweepEngine::result`] path (cached, shared across cells), and a
    /// cognitive cell pulls the memoized CWU reference summary — so a
    /// whole `vega lifecycle` grid simulates its kernel exactly once.
    pub fn lifecycle(&self, lc: &LifecycleScenario) -> LifecycleReport {
        let key = lc.key();
        let lc = *lc;
        self.lifecycles.get_or_compute(key.clone(), || {
            if let Some(disk) = &self.disk {
                if let Some(cached) = disk.load_lifecycle(&key) {
                    return cached;
                }
                let fresh = self.run_lifecycle_live(&lc);
                disk.store_lifecycle(&key, &fresh);
                return fresh;
            }
            self.run_lifecycle_live(&lc)
        })
    }

    fn run_lifecycle_live(&self, lc: &LifecycleScenario) -> LifecycleReport {
        let inference = self.result(lc.scenario);
        let cwu = (lc.sleep == SleepKind::Cognitive)
            .then(|| self.cwu_summary(crate::cwu::SLEEP_CLK_HZ));
        let mut report = run_lifecycle(lc, &inference, cwu.as_ref());
        if lc.upset_rate > 0.0 {
            // PR 6 retention-upset campaign, scaled by the deployment's
            // *actual* accumulated sleep time (not a nominal figure).
            let campaign = Campaign {
                scenario: lc.scenario,
                plan: FaultPlan {
                    seed: lc.trace.seed,
                    sleep_s: report.sleep_s,
                    mram_rate: lc.upset_rate,
                    sram_rate: 0.0,
                    tiers: TierMask { mram: true, l2: false, tcdm: false },
                },
            };
            report.attach_faults(&self.campaign(&campaign));
        }
        report
    }

    /// Drain a lifecycle grid through the worker pool, fault-isolated:
    /// `out[i]` corresponds to `grid[i]`, and a panicking cell yields a
    /// [`SimError`] instead of aborting the grid.
    pub fn run_lifecycles(
        &self,
        grid: &[LifecycleScenario],
    ) -> Vec<Result<LifecycleReport, SimError>> {
        self.run_lifecycles_with(grid, &GridSession::off())
            .into_iter()
            .map(|c| c.expect("an unsharded session owns every cell"))
            .collect()
    }

    /// Lifecycle-grid analogue of [`SweepEngine::run_campaigns_with`]:
    /// shard-aware, journal-replaying, policy-driven. Cell IDs are the
    /// cells' versioned [`LifecycleScenario::key`] strings; replay
    /// integrity uses [`LifecycleReport::digest`].
    pub fn run_lifecycles_with(
        &self,
        grid: &[LifecycleScenario],
        session: &GridSession,
    ) -> Vec<Option<Result<LifecycleReport, SimError>>> {
        self.run_cells(
            grid.len(),
            session,
            |i| grid[i].key(),
            |i| self.lifecycle(&grid[i]),
            |r| r.digest(),
        )
    }

    /// The shared cell driver behind both grid kinds: compute the
    /// stable cell ID (a panicking ID — e.g. an unknown kernel name —
    /// is itself a deterministic cell failure and is never journaled,
    /// since no stable identity exists), apply shard ownership, consult
    /// the session's replayed prior records, and otherwise execute
    /// under the retry policy and journal the terminal state.
    fn run_cells<T, I, C>(
        &self,
        n: usize,
        session: &GridSession,
        id_of: I,
        compute: C,
        digest_of: fn(&T) -> u64,
    ) -> Vec<Option<Result<T, SimError>>>
    where
        T: Send + Sync,
        I: Fn(usize) -> String + Sync,
        C: Fn(usize) -> T + Sync,
    {
        let one = |i: usize| -> Option<Result<T, SimError>> {
            let id = match catch_unwind(AssertUnwindSafe(|| id_of(i))) {
                Ok(id) => id,
                Err(p) => {
                    let (_, message) = classify(p.as_ref());
                    return Some(Err(SimError { index: i, kind: FailKind::Deterministic, message }));
                }
            };
            if !session.owns(&id) {
                return None;
            }
            if let Some(rec) = session.prior(&id) {
                return Some(match rec.status {
                    // A journaled done cell is recomputable through the
                    // cache tiers (usually a disk hit); re-journaling it
                    // would duplicate the record.
                    CellStatus::Done => self.run_policied(i, || compute(i)).inspect(|v| {
                        if digest_of(v) != rec.digest {
                            warn_digest_mismatch_once(&id);
                        }
                    }),
                    // Failed cells replay verbatim so a resumed report
                    // is byte-identical; a fresh (non-resume) run is the
                    // way to retry them.
                    CellStatus::Error => Err(SimError {
                        index: i,
                        kind: FailKind::Deterministic,
                        message: rec.message.clone(),
                    }),
                    CellStatus::Timeout => Err(SimError {
                        index: i,
                        kind: FailKind::Timeout,
                        message: rec.message.clone(),
                    }),
                });
            }
            let out = self.run_policied(i, || compute(i));
            match &out {
                Ok(v) => session.record(&id, CellStatus::Done, digest_of(v), ""),
                Err(e) => session.record(&id, e.kind.status(), 0, &e.message),
            }
            Some(out)
        };
        fan_out(self.jobs, n, one)
            .into_iter()
            .map(|cell| match cell {
                Ok(inner) => inner,
                Err(e) => Some(Err(e)),
            })
            .collect()
    }

    /// Execute one cell under the engine's [`CellPolicy`]: forward a
    /// success, retry [`Transient`] panics up to the retry budget with
    /// capped exponential backoff, and turn the terminal panic into a
    /// classified [`SimError`].
    fn run_policied<T>(&self, index: usize, work: impl Fn() -> T) -> Result<T, SimError> {
        let mut attempt = 0u32;
        loop {
            test_delay();
            match catch_unwind(AssertUnwindSafe(&work)) {
                Ok(v) => return Ok(v),
                Err(p) => {
                    let (kind, message) = classify(p.as_ref());
                    if kind == FailKind::Transient && attempt < self.policy.retries {
                        attempt += 1;
                        let backoff = (10u64 << (attempt - 1).min(16)).min(self.policy.backoff_cap_ms);
                        if backoff > 0 {
                            std::thread::sleep(Duration::from_millis(backoff));
                        }
                        continue;
                    }
                    return Err(SimError { index, kind, message });
                }
            }
        }
    }

    /// (hits, misses) of the fault-campaign memo.
    pub fn fault_counters(&self) -> (u64, u64) {
        self.faults.counters()
    }

    /// (hits, misses, writes) of the on-disk store's fault tier, or
    /// `None` for a memory-only engine.
    pub fn disk_fault_counters(&self) -> Option<(u64, u64, u64)> {
        self.disk.as_ref().map(|d| d.fault_counters())
    }

    /// (hits, misses) of the lifecycle memo.
    pub fn lifecycle_counters(&self) -> (u64, u64) {
        self.lifecycles.counters()
    }

    /// (hits, misses, writes) of the on-disk store's lifecycle tier, or
    /// `None` for a memory-only engine.
    pub fn disk_lifecycle_counters(&self) -> Option<(u64, u64, u64)> {
        self.disk.as_ref().map(|d| d.lifecycle_counters())
    }

    /// Failed entry writes per store tier — (sim, net, fault,
    /// lifecycle) — or `None` for a memory-only engine. A full or
    /// read-only store degrades to warn-once-and-continue-in-memory;
    /// these counters are how `--stats` surfaces the damage (ISSUE 7
    /// satellite).
    pub fn disk_write_errors(&self) -> Option<(u64, u64, u64, u64)> {
        self.disk.as_ref().map(|d| d.write_error_counters())
    }

    /// Render whole reproduction reports through the worker pool (ids as
    /// accepted by [`crate::bench::run_with`]); output order is `ids`
    /// order. Reports share this engine's cache, so kernels recurring
    /// across tables and figures are simulated once. Uses the
    /// prefetch-free renderer: report workers read caches directly and
    /// never spawn a nested per-report scenario pool.
    pub fn render_reports(&self, ids: &[&str]) -> Vec<Option<String>> {
        fan_out(self.jobs, ids.len(), |i| crate::bench::render(ids[i], self))
            .into_iter()
            .map(|r| r.unwrap_or_else(|e| panic!("report {}: {}", e.index, e.message)))
            .collect()
    }
}

impl Default for SweepEngine {
    fn default() -> Self {
        Self::new(default_jobs())
    }
}

/// Index-tagged fan-out of `n` work items over at most `jobs` scoped
/// workers. Results are returned in index order.
///
/// Each item runs under `catch_unwind` (ISSUE 6): a panicking item
/// resolves to `Err(SimError)` in its own slot — it can never poison an
/// unrelated slot, and the worker that caught it carries on draining the
/// queue. The old `expect("every work item produced a result")` is gone;
/// an unfilled slot (a worker killed mid-item by a double panic) also
/// degrades to a structured error instead of a crash.
fn fan_out<T, F>(jobs: usize, n: usize, work: F) -> Vec<Result<T, SimError>>
where
    T: Send + Sync,
    F: Fn(usize) -> T + Sync,
{
    let run = |i: usize| {
        catch_unwind(AssertUnwindSafe(|| work(i))).map_err(|p| {
            let (kind, message) = classify(p.as_ref());
            SimError { index: i, kind, message }
        })
    };
    if jobs <= 1 || n <= 1 {
        return (0..n).map(run).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<OnceLock<Result<T, SimError>>> = (0..n).map(|_| OnceLock::new()).collect();
    std::thread::scope(|s| {
        for _ in 0..jobs.min(n) {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let value = run(i);
                let _ = slots[i].set(value);
            });
        }
    });
    slots
        .into_iter()
        .enumerate()
        .map(|(i, slot)| {
            slot.into_inner().unwrap_or_else(|| {
                Err(SimError {
                    index: i,
                    kind: FailKind::Deterministic,
                    message: "worker produced no result".into(),
                })
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::int_matmul::IntWidth;

    #[test]
    fn fan_out_preserves_index_order() {
        let out: Vec<usize> = fan_out(4, 17, |i| i * i).into_iter().map(|r| r.unwrap()).collect();
        assert_eq!(out, (0..17).map(|i| i * i).collect::<Vec<_>>());
    }

    /// ISSUE 6: a panicking item yields exactly its own `Err` slot — at
    /// one worker and at many — while every other slot completes.
    #[test]
    fn fan_out_isolates_a_panicking_item_per_slot() {
        for jobs in [1, 4] {
            let out = fan_out(jobs, 5, |i| {
                if i == 2 {
                    panic!("boom {i}");
                }
                i * 10
            });
            assert_eq!(out.len(), 5, "jobs={jobs}");
            for (i, cell) in out.iter().enumerate() {
                if i == 2 {
                    let e = cell.as_ref().unwrap_err();
                    assert_eq!(e.index, 2);
                    assert_eq!(e.message, "boom 2", "jobs={jobs}");
                } else {
                    assert_eq!(*cell.as_ref().unwrap(), i * 10, "jobs={jobs}");
                }
            }
        }
    }

    #[test]
    fn duplicate_scenarios_simulate_once() {
        let eng = SweepEngine::new(2);
        let s = Scenario::IntMatmul { w: IntWidth::I8, cores: 2 };
        let out = eng.run_scenarios(&[s, s, s, s]);
        assert_eq!(out.len(), 4);
        let (hits, misses) = eng.cache().counters();
        assert_eq!(misses, 1);
        assert_eq!(hits, 3);
        assert!(out.windows(2).all(|w| w[0].outputs_digest == w[1].outputs_digest));
    }

    #[test]
    fn parallel_results_match_serial() {
        let list = [
            Scenario::IntMatmul { w: IntWidth::I8, cores: 1 },
            Scenario::IntMatmul { w: IntWidth::I16, cores: 2 },
            Scenario::IntMatmul { w: IntWidth::I8, cores: 1 },
        ];
        let serial = SweepEngine::serial().run_scenarios(&list);
        let parallel = SweepEngine::new(4).run_scenarios(&list);
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.outputs_digest, b.outputs_digest);
            assert_eq!(a.run.stats, b.run.stats);
        }
    }

    use crate::sweep::journal::ShardSpec;
    use std::sync::atomic::AtomicU32;

    fn policied_engine(policy: CellPolicy) -> SweepEngine {
        let mut eng = SweepEngine::serial();
        eng.set_cell_policy(policy);
        eng
    }

    /// ISSUE 7: a `Transient` panic is retried (bounded) and the cell
    /// succeeds once the environment recovers.
    #[test]
    fn transient_failures_retry_until_success() {
        let eng = policied_engine(CellPolicy { retries: 3, backoff_cap_ms: 0, timeout_ms: None });
        let attempts = AtomicU32::new(0);
        let out = eng.run_policied(7, || {
            if attempts.fetch_add(1, Ordering::Relaxed) < 2 {
                std::panic::panic_any(Transient("flaky read".into()));
            }
            42
        });
        assert_eq!(out.unwrap(), 42);
        assert_eq!(attempts.load(Ordering::Relaxed), 3, "two transient failures, one success");
    }

    /// The PR 6 contract survives the policy layer: an ordinary panic is
    /// deterministic and fails on the first attempt, whatever the retry
    /// budget says.
    #[test]
    fn deterministic_failures_are_never_retried() {
        let eng = policied_engine(CellPolicy { retries: 5, backoff_cap_ms: 0, timeout_ms: None });
        let attempts = AtomicU32::new(0);
        let out: Result<u32, SimError> = eng.run_policied(3, || {
            attempts.fetch_add(1, Ordering::Relaxed);
            panic!("model bug");
        });
        let e = out.unwrap_err();
        assert_eq!((e.index, e.kind), (3, FailKind::Deterministic));
        assert_eq!(e.message, "model bug");
        assert_eq!(attempts.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn transient_failures_exhaust_the_retry_budget() {
        let eng = policied_engine(CellPolicy { retries: 1, backoff_cap_ms: 0, timeout_ms: None });
        let attempts = AtomicU32::new(0);
        let out: Result<u32, SimError> = eng.run_policied(0, || {
            attempts.fetch_add(1, Ordering::Relaxed);
            std::panic::panic_any(Transient("still flaky".into()));
        });
        let e = out.unwrap_err();
        assert_eq!(e.kind, FailKind::Transient);
        assert_eq!(e.message, "still flaky");
        assert_eq!(attempts.load(Ordering::Relaxed), 2, "1 attempt + 1 retry");
    }

    /// The watchdog abandons a runaway cell and classifies it `Timeout`.
    #[test]
    fn watchdog_marks_runaway_cells_timeout() {
        let eng = SweepEngine::serial();
        let out: Result<u32, SimError> = eng.run_policied(5, || {
            with_watchdog(10, || {
                std::thread::sleep(Duration::from_millis(300));
                7u32
            })
        });
        let e = out.unwrap_err();
        assert_eq!((e.index, e.kind), (5, FailKind::Timeout));
        assert!(e.message.contains("timeout after 10 ms"), "{}", e.message);
    }

    /// In-budget work passes its value (and its panics) straight through
    /// the watchdog.
    #[test]
    fn watchdog_forwards_values_and_inner_panics() {
        assert_eq!(with_watchdog(5_000, || 41 + 1), 42);
        let caught = catch_unwind(|| with_watchdog(5_000, || panic!("inner boom"))).unwrap_err();
        assert_eq!(panic_message(caught.as_ref()), "inner boom");
    }

    /// `--timeout-ms 0` end-to-end: every simulated cell times out
    /// deterministically (the CI exit-code smoke relies on this).
    #[test]
    fn zero_timeout_times_out_every_cell() {
        let mut eng = SweepEngine::serial();
        eng.set_cell_policy(CellPolicy { timeout_ms: Some(0), ..CellPolicy::default() });
        let out = eng.try_run_scenarios(&[Scenario::IntMatmul { w: IntWidth::I8, cores: 1 }]);
        let e = out[0].as_ref().unwrap_err();
        assert_eq!(e.kind, FailKind::Timeout);
        assert!(e.message.contains("timeout after 0 ms"), "{}", e.message);
    }

    /// ISSUE 7 sharding: every cell of a grid is owned by exactly one
    /// shard session, and the union of the shard drains equals the
    /// unsharded drain.
    #[test]
    fn sharded_sessions_partition_a_grid_exactly() {
        let list: Vec<Scenario> =
            (1..=6usize).map(|c| Scenario::IntMatmul { w: IntWidth::I8, cores: c }).collect();
        let eng = SweepEngine::new(2);
        let full: Vec<SimResult> =
            eng.try_run_scenarios(&list).into_iter().map(|r| r.unwrap()).collect();
        let total = 3u32;
        let mut owned = vec![0usize; list.len()];
        for index in 1..=total {
            let session = GridSession::with_shard(ShardSpec { index, total });
            for (i, cell) in eng.run_scenarios_with(&list, &session).iter().enumerate() {
                if let Some(r) = cell {
                    owned[i] += 1;
                    assert_eq!(
                        r.as_ref().unwrap().outputs_digest,
                        full[i].outputs_digest,
                        "shard {index}/{total} cell {i}"
                    );
                }
            }
        }
        assert_eq!(owned, vec![1; list.len()], "each cell owned by exactly one shard");
    }
}
