//! The worker pool: scenario fan-out, report fan-out, memoized lookup.
//!
//! `std::thread::scope` keeps everything dependency-free and borrow-safe;
//! the work queue is an atomic index over the input slice and results land
//! in index-tagged `OnceLock` slots, so output order is the input (paper)
//! order no matter which worker finishes when. Each worker thread owns one
//! lazily-built [`SimArena`] (thread-local), reused across every scenario
//! it drains — no per-scenario `Cluster`/L2 allocations.
//!
//! Fault isolation (ISSUE 6): every work item runs under
//! `catch_unwind`, so one panicking scenario yields one structured
//! [`SimError`] cell instead of tearing down the whole sweep. Errored
//! cells are never written to any cache tier (the panic unwinds out of
//! the memo's compute before a value exists to store).

use std::cell::RefCell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

use super::cache::{OnceMap, SimCache};
use super::persist::DiskStore;
use super::scenario::{Scenario, SimArena, SimResult};
use crate::coordinator::CwuSummary;
use crate::dnn::{run_network, Network, NetworkReport, PipelineConfig};
use crate::faults::{run_campaign, Campaign, CampaignOutcome};
use crate::kernels::KernelRun;

/// One errored sweep cell: work item `index` panicked with `message`.
///
/// The replacement for the worker pool's old
/// `expect("every work item produced a result")` — a panicking scenario
/// now surfaces as data, every other cell completes normally, and
/// nothing of the errored cell reaches a cache.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SimError {
    /// Index of the failed item in the submitted work list.
    pub index: usize,
    /// The panic payload, stringified.
    pub message: String,
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "work item {}: {}", self.index, self.message)
    }
}

/// Stringify a panic payload (the two shapes `panic!` produces).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

thread_local! {
    /// The calling thread's owned simulation arena (one per worker).
    static ARENA: RefCell<SimArena> = RefCell::new(SimArena::new());
}

/// Worker count to use when the caller doesn't pass `--jobs`: `VEGA_JOBS`
/// if set, else the machine's available parallelism.
pub fn default_jobs() -> usize {
    std::env::var("VEGA_JOBS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| std::thread::available_parallelism().map_or(1, |n| n.get()))
}

/// The sweep execution engine: a [`SimCache`] (kernel scenarios), sibling
/// memos for DNN pipeline runs, the CWU reference workload and the HD
/// ablation, an optional persistent [`DiskStore`], and a worker count.
pub struct SweepEngine {
    jobs: usize,
    cache: SimCache,
    nets: OnceMap<String, NetworkReport>,
    cwu: OnceMap<u64, CwuSummary>,
    hd: OnceMap<usize, f64>,
    faults: OnceMap<String, CampaignOutcome>,
    disk: Option<DiskStore>,
}

impl SweepEngine {
    /// In-memory engine with `jobs` workers (no cross-process
    /// persistence; see [`SweepEngine::persistent`]).
    pub fn new(jobs: usize) -> Self {
        Self {
            jobs: jobs.max(1),
            cache: SimCache::new(),
            nets: OnceMap::new(true),
            cwu: OnceMap::new(true),
            hd: OnceMap::new(true),
            faults: OnceMap::new(true),
            disk: None,
        }
    }

    /// Single-worker engine (unit tests, deterministic baselines).
    pub fn serial() -> Self {
        Self::new(1)
    }

    /// Engine with memoization off — every lookup re-simulates. The
    /// serial-without-cache baseline of `cargo bench --bench sweeps`.
    pub fn without_cache(jobs: usize) -> Self {
        Self {
            jobs: jobs.max(1),
            cache: SimCache::with_enabled(false),
            nets: OnceMap::new(false),
            cwu: OnceMap::new(false),
            hd: OnceMap::new(false),
            faults: OnceMap::new(false),
            disk: None,
        }
    }

    /// Engine backed by an explicit on-disk store: in-memory misses probe
    /// `store` before simulating, and freshly simulated results are
    /// written back, so a later engine (or process) on the same directory
    /// starts warm.
    pub fn with_disk(jobs: usize, store: DiskStore) -> Self {
        Self { disk: Some(store), ..Self::new(jobs) }
    }

    /// Engine backed by the default on-disk store (`$VEGA_CACHE_DIR`,
    /// else `target/vega-cache`; `VEGA_CACHE=off` disables). The CLI's
    /// engine. Falls back to a memory-only engine — with a warning on
    /// stderr — when the store directory cannot be created.
    pub fn persistent(jobs: usize) -> Self {
        match DiskStore::open_default() {
            Ok(Some(store)) => Self::with_disk(jobs, store),
            Ok(None) => Self::new(jobs),
            Err(e) => {
                eprintln!("vega: on-disk sim cache disabled ({e})");
                Self::new(jobs)
            }
        }
    }

    /// The process-wide shared engine behind the per-id compatibility
    /// paths ([`crate::bench::run`], the `coordinator::bench_*` drivers):
    /// persistent and sized by [`default_jobs`], so repeated per-id calls
    /// — and repeated CLI invocations across processes — reuse cached
    /// cycle results instead of rebuilding Cluster/L2 state per call.
    pub fn global() -> &'static SweepEngine {
        static GLOBAL: OnceLock<SweepEngine> = OnceLock::new();
        GLOBAL.get_or_init(|| SweepEngine::persistent(default_jobs()))
    }

    pub fn jobs(&self) -> usize {
        self.jobs
    }

    pub fn cache(&self) -> &SimCache {
        &self.cache
    }

    /// Memoized result of one scenario: in-memory cache first, then the
    /// on-disk store (when persistent), then a simulation on this
    /// thread's arena (written back to disk). Disk probes happen inside
    /// the in-memory miss path, so [`SimCache`] hit/miss counters — and
    /// every determinism invariant built on them — are unaffected by
    /// persistence.
    pub fn result(&self, s: Scenario) -> SimResult {
        let s = s.canonical();
        let key = s.key();
        self.cache.get_or_sim(key.clone(), || {
            if let Some(disk) = &self.disk {
                if let Some(cached) = disk.load(&key) {
                    return cached;
                }
                let fresh = ARENA.with(|a| s.simulate(&mut a.borrow_mut()));
                disk.store(&key, &fresh);
                return fresh;
            }
            ARENA.with(|a| s.simulate(&mut a.borrow_mut()))
        })
    }

    /// Memoized [`KernelRun`] of one scenario (what the table/figure
    /// renderers consume; per-operating-point energy is derived from it
    /// analytically, which is what makes V/f sweeps one simulation each).
    pub fn kernel_run(&self, s: Scenario) -> KernelRun {
        self.result(s).run
    }

    /// Memoized DNN pipeline run (Figs. 9–11, Table VII/VIII rows and the
    /// store-policy / double-buffering ablations). `run_network` is a pure
    /// function of the network and config, so recurring (network, config)
    /// pairs across reports — e.g. MobileNetV2 `AllMram`, used by Fig. 9,
    /// Fig. 10, Fig. 11 and an ablation — run once per engine, and, on a
    /// persistent engine, once per *store directory*: in-memory misses
    /// probe the on-disk network tier before running the pipeline, then
    /// write back (the same layering as [`SweepEngine::result`], with the
    /// same counter transparency).
    ///
    /// The memo key is the canonical [`crate::dnn::net_key`] string: an
    /// explicit byte-encoded structure hash of the per-layer topology
    /// (the DNN analogue of the kernel cache's `Program::content_hash`)
    /// plus the full operating point, engine and policy — so a topology
    /// edit that preserves name and aggregate totals can never serve a
    /// stale per-layer breakdown, on disk or in memory.
    pub fn network_report(&self, net: &Network, config: PipelineConfig) -> NetworkReport {
        let key = crate::dnn::net_key(net, &config);
        self.nets.get_or_compute(key.clone(), || {
            if let Some(disk) = &self.disk {
                if let Some(cached) = disk.load_net(&key) {
                    return cached;
                }
                let fresh = run_network(net, config);
                disk.store_net(&key, &fresh);
                return fresh;
            }
            run_network(net, config)
        })
    }

    /// (hits, misses) of the network-report memo.
    pub fn network_counters(&self) -> (u64, u64) {
        self.nets.counters()
    }

    /// Memoized CWU reference workload (Table I's measurement setup —
    /// dominated by HDC training, which is a pure function of the CWU
    /// clock and the fixed encoder config/seed). One training run per
    /// distinct `f_clk` per engine, however many times Table I renders.
    pub fn cwu_summary(&self, f_clk: f64) -> CwuSummary {
        self.cwu.get_or_compute(f_clk.to_bits(), || crate::coordinator::cwu_summary(f_clk))
    }

    /// (hits, misses) of the CWU reference-workload memo.
    pub fn cwu_counters(&self) -> (u64, u64) {
        self.cwu.counters()
    }

    /// Memoized HD-dimension ablation accuracy (a pure function of the
    /// Hypnos vector dimension; the 2-shot noisy EMG training inside is
    /// the most expensive part of the ablation report).
    pub fn hd_accuracy(&self, dim: usize) -> f64 {
        self.hd.get_or_compute(dim, || crate::bench::ablations::hd_ablation_accuracy(dim))
    }

    /// (hits, misses) of the HD-dimension ablation memo.
    pub fn hd_counters(&self) -> (u64, u64) {
        self.hd.counters()
    }

    /// (hits, misses, writes) of the on-disk store's kernel tier, or
    /// `None` for a memory-only engine. Disk lookups happen once per
    /// in-memory miss, so on a warm store `hits` equals the in-memory
    /// miss count and `misses`/`writes` are zero.
    pub fn disk_counters(&self) -> Option<(u64, u64, u64)> {
        self.disk.as_ref().map(|d| d.counters())
    }

    /// (hits, misses, writes) of the on-disk store's network-report
    /// tier, or `None` for a memory-only engine. Same layering as
    /// [`SweepEngine::disk_counters`]: one disk probe per in-memory
    /// network-memo miss.
    pub fn disk_net_counters(&self) -> Option<(u64, u64, u64)> {
        self.disk.as_ref().map(|d| d.net_counters())
    }

    /// Drain a scenario list through the worker pool; `out[i]` corresponds
    /// to `list[i]` regardless of completion order. A panicking scenario
    /// aborts the call (re-raising the first failure); callers that need
    /// to survive faults use [`SweepEngine::try_run_scenarios`].
    pub fn run_scenarios(&self, list: &[Scenario]) -> Vec<SimResult> {
        self.try_run_scenarios(list)
            .into_iter()
            .map(|r| r.unwrap_or_else(|e| panic!("scenario {}: {}", e.index, e.message)))
            .collect()
    }

    /// As [`SweepEngine::run_scenarios`], but fault-isolated (ISSUE 6):
    /// each cell is a `Result`, a panicking scenario yields its own
    /// [`SimError`] while every other cell completes and matches a
    /// fault-free run, and errored cells are never cached.
    pub fn try_run_scenarios(&self, list: &[Scenario]) -> Vec<Result<SimResult, SimError>> {
        fan_out(self.jobs, list.len(), |i| self.result(list[i]))
    }

    /// Memoized fault-campaign outcome: in-memory memo first, then the
    /// on-disk `.flt` tier (when persistent), then a live run. The
    /// fault-free oracle goes through the ordinary [`SweepEngine::result`]
    /// path — so it is cached and shared — but the *faulted* simulation
    /// inside the campaign never touches the `.sim` tier: corrupted
    /// results must not be mistakable for clean ones.
    pub fn campaign(&self, c: &Campaign) -> CampaignOutcome {
        let key = c.key();
        let c = *c;
        self.faults.get_or_compute(key.clone(), || {
            if let Some(disk) = &self.disk {
                if let Some(cached) = disk.load_fault(&key) {
                    return cached;
                }
                let fresh = self.run_campaign_live(&c);
                disk.store_fault(&key, &fresh);
                return fresh;
            }
            self.run_campaign_live(&c)
        })
    }

    fn run_campaign_live(&self, c: &Campaign) -> CampaignOutcome {
        let oracle = self.result(c.scenario);
        ARENA.with(|a| run_campaign(c, &oracle, &mut a.borrow_mut()))
    }

    /// Drain a campaign grid through the worker pool, fault-isolated:
    /// `out[i]` corresponds to `grid[i]`, and a panicking campaign yields
    /// a [`SimError`] cell instead of aborting the grid.
    pub fn run_campaigns(&self, grid: &[Campaign]) -> Vec<Result<CampaignOutcome, SimError>> {
        fan_out(self.jobs, grid.len(), |i| self.campaign(&grid[i]))
    }

    /// (hits, misses) of the fault-campaign memo.
    pub fn fault_counters(&self) -> (u64, u64) {
        self.faults.counters()
    }

    /// (hits, misses, writes) of the on-disk store's fault tier, or
    /// `None` for a memory-only engine.
    pub fn disk_fault_counters(&self) -> Option<(u64, u64, u64)> {
        self.disk.as_ref().map(|d| d.fault_counters())
    }

    /// Render whole reproduction reports through the worker pool (ids as
    /// accepted by [`crate::bench::run_with`]); output order is `ids`
    /// order. Reports share this engine's cache, so kernels recurring
    /// across tables and figures are simulated once. Uses the
    /// prefetch-free renderer: report workers read caches directly and
    /// never spawn a nested per-report scenario pool.
    pub fn render_reports(&self, ids: &[&str]) -> Vec<Option<String>> {
        fan_out(self.jobs, ids.len(), |i| crate::bench::render(ids[i], self))
            .into_iter()
            .map(|r| r.unwrap_or_else(|e| panic!("report {}: {}", e.index, e.message)))
            .collect()
    }
}

impl Default for SweepEngine {
    fn default() -> Self {
        Self::new(default_jobs())
    }
}

/// Index-tagged fan-out of `n` work items over at most `jobs` scoped
/// workers. Results are returned in index order.
///
/// Each item runs under `catch_unwind` (ISSUE 6): a panicking item
/// resolves to `Err(SimError)` in its own slot — it can never poison an
/// unrelated slot, and the worker that caught it carries on draining the
/// queue. The old `expect("every work item produced a result")` is gone;
/// an unfilled slot (a worker killed mid-item by a double panic) also
/// degrades to a structured error instead of a crash.
fn fan_out<T, F>(jobs: usize, n: usize, work: F) -> Vec<Result<T, SimError>>
where
    T: Send + Sync,
    F: Fn(usize) -> T + Sync,
{
    let run = |i: usize| {
        catch_unwind(AssertUnwindSafe(|| work(i)))
            .map_err(|p| SimError { index: i, message: panic_message(p.as_ref()) })
    };
    if jobs <= 1 || n <= 1 {
        return (0..n).map(run).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<OnceLock<Result<T, SimError>>> = (0..n).map(|_| OnceLock::new()).collect();
    std::thread::scope(|s| {
        for _ in 0..jobs.min(n) {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let value = run(i);
                let _ = slots[i].set(value);
            });
        }
    });
    slots
        .into_iter()
        .enumerate()
        .map(|(i, slot)| {
            slot.into_inner().unwrap_or_else(|| {
                Err(SimError { index: i, message: "worker produced no result".into() })
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::int_matmul::IntWidth;

    #[test]
    fn fan_out_preserves_index_order() {
        let out: Vec<usize> = fan_out(4, 17, |i| i * i).into_iter().map(|r| r.unwrap()).collect();
        assert_eq!(out, (0..17).map(|i| i * i).collect::<Vec<_>>());
    }

    /// ISSUE 6: a panicking item yields exactly its own `Err` slot — at
    /// one worker and at many — while every other slot completes.
    #[test]
    fn fan_out_isolates_a_panicking_item_per_slot() {
        for jobs in [1, 4] {
            let out = fan_out(jobs, 5, |i| {
                if i == 2 {
                    panic!("boom {i}");
                }
                i * 10
            });
            assert_eq!(out.len(), 5, "jobs={jobs}");
            for (i, cell) in out.iter().enumerate() {
                if i == 2 {
                    let e = cell.as_ref().unwrap_err();
                    assert_eq!(e.index, 2);
                    assert_eq!(e.message, "boom 2", "jobs={jobs}");
                } else {
                    assert_eq!(*cell.as_ref().unwrap(), i * 10, "jobs={jobs}");
                }
            }
        }
    }

    #[test]
    fn duplicate_scenarios_simulate_once() {
        let eng = SweepEngine::new(2);
        let s = Scenario::IntMatmul { w: IntWidth::I8, cores: 2 };
        let out = eng.run_scenarios(&[s, s, s, s]);
        assert_eq!(out.len(), 4);
        let (hits, misses) = eng.cache().counters();
        assert_eq!(misses, 1);
        assert_eq!(hits, 3);
        assert!(out.windows(2).all(|w| w[0].outputs_digest == w[1].outputs_digest));
    }

    #[test]
    fn parallel_results_match_serial() {
        let list = [
            Scenario::IntMatmul { w: IntWidth::I8, cores: 1 },
            Scenario::IntMatmul { w: IntWidth::I16, cores: 2 },
            Scenario::IntMatmul { w: IntWidth::I8, cores: 1 },
        ];
        let serial = SweepEngine::serial().run_scenarios(&list);
        let parallel = SweepEngine::new(4).run_scenarios(&list);
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.outputs_digest, b.outputs_digest);
            assert_eq!(a.run.stats, b.run.stats);
        }
    }
}
