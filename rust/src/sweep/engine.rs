//! The worker pool: scenario fan-out, report fan-out, memoized lookup.
//!
//! `std::thread::scope` keeps everything dependency-free and borrow-safe;
//! the work queue is an atomic index over the input slice and results land
//! in index-tagged `OnceLock` slots, so output order is the input (paper)
//! order no matter which worker finishes when. Each worker thread owns one
//! lazily-built [`SimArena`] (thread-local), reused across every scenario
//! it drains — no per-scenario `Cluster`/L2 allocations.

use std::cell::RefCell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

use super::cache::{OnceMap, SimCache};
use super::persist::DiskStore;
use super::scenario::{Scenario, SimArena, SimResult};
use crate::coordinator::CwuSummary;
use crate::dnn::{run_network, Network, NetworkReport, PipelineConfig};
use crate::kernels::KernelRun;

thread_local! {
    /// The calling thread's owned simulation arena (one per worker).
    static ARENA: RefCell<SimArena> = RefCell::new(SimArena::new());
}

/// Worker count to use when the caller doesn't pass `--jobs`: `VEGA_JOBS`
/// if set, else the machine's available parallelism.
pub fn default_jobs() -> usize {
    std::env::var("VEGA_JOBS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| std::thread::available_parallelism().map_or(1, |n| n.get()))
}

/// The sweep execution engine: a [`SimCache`] (kernel scenarios), sibling
/// memos for DNN pipeline runs, the CWU reference workload and the HD
/// ablation, an optional persistent [`DiskStore`], and a worker count.
pub struct SweepEngine {
    jobs: usize,
    cache: SimCache,
    nets: OnceMap<String, NetworkReport>,
    cwu: OnceMap<u64, CwuSummary>,
    hd: OnceMap<usize, f64>,
    disk: Option<DiskStore>,
}

impl SweepEngine {
    /// In-memory engine with `jobs` workers (no cross-process
    /// persistence; see [`SweepEngine::persistent`]).
    pub fn new(jobs: usize) -> Self {
        Self {
            jobs: jobs.max(1),
            cache: SimCache::new(),
            nets: OnceMap::new(true),
            cwu: OnceMap::new(true),
            hd: OnceMap::new(true),
            disk: None,
        }
    }

    /// Single-worker engine (unit tests, deterministic baselines).
    pub fn serial() -> Self {
        Self::new(1)
    }

    /// Engine with memoization off — every lookup re-simulates. The
    /// serial-without-cache baseline of `cargo bench --bench sweeps`.
    pub fn without_cache(jobs: usize) -> Self {
        Self {
            jobs: jobs.max(1),
            cache: SimCache::with_enabled(false),
            nets: OnceMap::new(false),
            cwu: OnceMap::new(false),
            hd: OnceMap::new(false),
            disk: None,
        }
    }

    /// Engine backed by an explicit on-disk store: in-memory misses probe
    /// `store` before simulating, and freshly simulated results are
    /// written back, so a later engine (or process) on the same directory
    /// starts warm.
    pub fn with_disk(jobs: usize, store: DiskStore) -> Self {
        Self { disk: Some(store), ..Self::new(jobs) }
    }

    /// Engine backed by the default on-disk store (`$VEGA_CACHE_DIR`,
    /// else `target/vega-cache`; `VEGA_CACHE=off` disables). The CLI's
    /// engine. Falls back to a memory-only engine — with a warning on
    /// stderr — when the store directory cannot be created.
    pub fn persistent(jobs: usize) -> Self {
        match DiskStore::open_default() {
            Ok(Some(store)) => Self::with_disk(jobs, store),
            Ok(None) => Self::new(jobs),
            Err(e) => {
                eprintln!("vega: on-disk sim cache disabled ({e})");
                Self::new(jobs)
            }
        }
    }

    /// The process-wide shared engine behind the per-id compatibility
    /// paths ([`crate::bench::run`], the `coordinator::bench_*` drivers):
    /// persistent and sized by [`default_jobs`], so repeated per-id calls
    /// — and repeated CLI invocations across processes — reuse cached
    /// cycle results instead of rebuilding Cluster/L2 state per call.
    pub fn global() -> &'static SweepEngine {
        static GLOBAL: OnceLock<SweepEngine> = OnceLock::new();
        GLOBAL.get_or_init(|| SweepEngine::persistent(default_jobs()))
    }

    pub fn jobs(&self) -> usize {
        self.jobs
    }

    pub fn cache(&self) -> &SimCache {
        &self.cache
    }

    /// Memoized result of one scenario: in-memory cache first, then the
    /// on-disk store (when persistent), then a simulation on this
    /// thread's arena (written back to disk). Disk probes happen inside
    /// the in-memory miss path, so [`SimCache`] hit/miss counters — and
    /// every determinism invariant built on them — are unaffected by
    /// persistence.
    pub fn result(&self, s: Scenario) -> SimResult {
        let s = s.canonical();
        let key = s.key();
        self.cache.get_or_sim(key.clone(), || {
            if let Some(disk) = &self.disk {
                if let Some(cached) = disk.load(&key) {
                    return cached;
                }
                let fresh = ARENA.with(|a| s.simulate(&mut a.borrow_mut()));
                disk.store(&key, &fresh);
                return fresh;
            }
            ARENA.with(|a| s.simulate(&mut a.borrow_mut()))
        })
    }

    /// Memoized [`KernelRun`] of one scenario (what the table/figure
    /// renderers consume; per-operating-point energy is derived from it
    /// analytically, which is what makes V/f sweeps one simulation each).
    pub fn kernel_run(&self, s: Scenario) -> KernelRun {
        self.result(s).run
    }

    /// Memoized DNN pipeline run (Figs. 9–11, Table VII/VIII rows and the
    /// store-policy / double-buffering ablations). `run_network` is a pure
    /// function of the network and config, so recurring (network, config)
    /// pairs across reports — e.g. MobileNetV2 `AllMram`, used by Fig. 9,
    /// Fig. 10, Fig. 11 and an ablation — run once per engine, and, on a
    /// persistent engine, once per *store directory*: in-memory misses
    /// probe the on-disk network tier before running the pipeline, then
    /// write back (the same layering as [`SweepEngine::result`], with the
    /// same counter transparency).
    ///
    /// The memo key is the canonical [`crate::dnn::net_key`] string: an
    /// explicit byte-encoded structure hash of the per-layer topology
    /// (the DNN analogue of the kernel cache's `Program::content_hash`)
    /// plus the full operating point, engine and policy — so a topology
    /// edit that preserves name and aggregate totals can never serve a
    /// stale per-layer breakdown, on disk or in memory.
    pub fn network_report(&self, net: &Network, config: PipelineConfig) -> NetworkReport {
        let key = crate::dnn::net_key(net, &config);
        self.nets.get_or_compute(key.clone(), || {
            if let Some(disk) = &self.disk {
                if let Some(cached) = disk.load_net(&key) {
                    return cached;
                }
                let fresh = run_network(net, config);
                disk.store_net(&key, &fresh);
                return fresh;
            }
            run_network(net, config)
        })
    }

    /// (hits, misses) of the network-report memo.
    pub fn network_counters(&self) -> (u64, u64) {
        self.nets.counters()
    }

    /// Memoized CWU reference workload (Table I's measurement setup —
    /// dominated by HDC training, which is a pure function of the CWU
    /// clock and the fixed encoder config/seed). One training run per
    /// distinct `f_clk` per engine, however many times Table I renders.
    pub fn cwu_summary(&self, f_clk: f64) -> CwuSummary {
        self.cwu.get_or_compute(f_clk.to_bits(), || crate::coordinator::cwu_summary(f_clk))
    }

    /// (hits, misses) of the CWU reference-workload memo.
    pub fn cwu_counters(&self) -> (u64, u64) {
        self.cwu.counters()
    }

    /// Memoized HD-dimension ablation accuracy (a pure function of the
    /// Hypnos vector dimension; the 2-shot noisy EMG training inside is
    /// the most expensive part of the ablation report).
    pub fn hd_accuracy(&self, dim: usize) -> f64 {
        self.hd.get_or_compute(dim, || crate::bench::ablations::hd_ablation_accuracy(dim))
    }

    /// (hits, misses) of the HD-dimension ablation memo.
    pub fn hd_counters(&self) -> (u64, u64) {
        self.hd.counters()
    }

    /// (hits, misses, writes) of the on-disk store's kernel tier, or
    /// `None` for a memory-only engine. Disk lookups happen once per
    /// in-memory miss, so on a warm store `hits` equals the in-memory
    /// miss count and `misses`/`writes` are zero.
    pub fn disk_counters(&self) -> Option<(u64, u64, u64)> {
        self.disk.as_ref().map(|d| d.counters())
    }

    /// (hits, misses, writes) of the on-disk store's network-report
    /// tier, or `None` for a memory-only engine. Same layering as
    /// [`SweepEngine::disk_counters`]: one disk probe per in-memory
    /// network-memo miss.
    pub fn disk_net_counters(&self) -> Option<(u64, u64, u64)> {
        self.disk.as_ref().map(|d| d.net_counters())
    }

    /// Drain a scenario list through the worker pool; `out[i]` corresponds
    /// to `list[i]` regardless of completion order.
    pub fn run_scenarios(&self, list: &[Scenario]) -> Vec<SimResult> {
        fan_out(self.jobs, list.len(), |i| self.result(list[i]))
    }

    /// Render whole reproduction reports through the worker pool (ids as
    /// accepted by [`crate::bench::run_with`]); output order is `ids`
    /// order. Reports share this engine's cache, so kernels recurring
    /// across tables and figures are simulated once. Uses the
    /// prefetch-free renderer: report workers read caches directly and
    /// never spawn a nested per-report scenario pool.
    pub fn render_reports(&self, ids: &[&str]) -> Vec<Option<String>> {
        fan_out(self.jobs, ids.len(), |i| crate::bench::render(ids[i], self))
    }
}

impl Default for SweepEngine {
    fn default() -> Self {
        Self::new(default_jobs())
    }
}

/// Index-tagged fan-out of `n` work items over at most `jobs` scoped
/// workers. Results are returned in index order.
fn fan_out<T, F>(jobs: usize, n: usize, work: F) -> Vec<T>
where
    T: Send + Sync,
    F: Fn(usize) -> T + Sync,
{
    if jobs <= 1 || n <= 1 {
        return (0..n).map(work).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<OnceLock<T>> = (0..n).map(|_| OnceLock::new()).collect();
    std::thread::scope(|s| {
        for _ in 0..jobs.min(n) {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let value = work(i);
                let _ = slots[i].set(value);
            });
        }
    });
    slots
        .into_iter()
        .map(|slot| slot.into_inner().expect("every work item produced a result"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::int_matmul::IntWidth;

    #[test]
    fn fan_out_preserves_index_order() {
        let out = fan_out(4, 17, |i| i * i);
        assert_eq!(out, (0..17).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn duplicate_scenarios_simulate_once() {
        let eng = SweepEngine::new(2);
        let s = Scenario::IntMatmul { w: IntWidth::I8, cores: 2 };
        let out = eng.run_scenarios(&[s, s, s, s]);
        assert_eq!(out.len(), 4);
        let (hits, misses) = eng.cache().counters();
        assert_eq!(misses, 1);
        assert_eq!(hits, 3);
        assert!(out.windows(2).all(|w| w[0].outputs_digest == w[1].outputs_digest));
    }

    #[test]
    fn parallel_results_match_serial() {
        let list = [
            Scenario::IntMatmul { w: IntWidth::I8, cores: 1 },
            Scenario::IntMatmul { w: IntWidth::I16, cores: 2 },
            Scenario::IntMatmul { w: IntWidth::I8, cores: 1 },
        ];
        let serial = SweepEngine::serial().run_scenarios(&list);
        let parallel = SweepEngine::new(4).run_scenarios(&list);
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.outputs_digest, b.outputs_digest);
            assert_eq!(a.run.stats, b.run.stats);
        }
    }
}
