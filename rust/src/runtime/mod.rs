//! PJRT runtime bridge (Layer-3 ← Layer-2/1).
//!
//! Loads the HLO-text artifacts produced by `python/compile/aot.py`,
//! compiles them once on the PJRT CPU client, and executes them from the
//! coordinator. In this reproduction the artifacts serve as *golden
//! functional models*: the simulator's HWCE datapath and PULP-NN kernels
//! are checked bit-for-bit against the JAX/Pallas numerics, playing the
//! role silicon-vs-RTL equivalence plays for the real chip.
//!
//! Python never runs on this path: after `make artifacts` the `vega`
//! binary is self-contained.

mod manifest;

pub use manifest::{Manifest, Signature, TensorSig};

#[cfg(feature = "xla")]
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::common::{Result, VegaError};

/// Supported artifact element types (matching `aot.py`'s manifest names).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    I8,
    I32,
    F32,
}

impl DType {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "s8" => Ok(DType::I8),
            "s32" => Ok(DType::I32),
            "f32" => Ok(DType::F32),
            other => Err(VegaError::Runtime(format!("unsupported dtype {other}"))),
        }
    }

    pub fn size_bytes(self) -> usize {
        match self {
            DType::I8 => 1,
            DType::I32 | DType::F32 => 4,
        }
    }
}

/// A host-side tensor crossing the PJRT boundary.
#[derive(Debug, Clone, PartialEq)]
pub enum Tensor {
    I8(Vec<i8>),
    I32(Vec<i32>),
    F32(Vec<f32>),
}

impl Tensor {
    pub fn dtype(&self) -> DType {
        match self {
            Tensor::I8(_) => DType::I8,
            Tensor::I32(_) => DType::I32,
            Tensor::F32(_) => DType::F32,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Tensor::I8(v) => v.len(),
            Tensor::I32(v) => v.len(),
            Tensor::F32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_i32(&self) -> Option<&[i32]> {
        match self {
            Tensor::I32(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_i8(&self) -> Option<&[i8]> {
        match self {
            Tensor::I8(v) => Some(v),
            _ => None,
        }
    }

    #[cfg(feature = "xla")]
    fn to_literal(&self, shape: &[usize]) -> Result<xla::Literal> {
        // i8 implements ArrayElement but not NativeType in xla 0.1.6, so
        // literals are built from raw bytes (little-endian host == XLA
        // layout for these scalar types).
        let (ty, bytes): (xla::ElementType, Vec<u8>) = match self {
            Tensor::I8(v) => (
                xla::ElementType::S8,
                v.iter().map(|&x| x as u8).collect(),
            ),
            Tensor::I32(v) => (
                xla::ElementType::S32,
                v.iter().flat_map(|x| x.to_le_bytes()).collect(),
            ),
            Tensor::F32(v) => (
                xla::ElementType::F32,
                v.iter().flat_map(|x| x.to_le_bytes()).collect(),
            ),
        };
        xla::Literal::create_from_shape_and_untyped_data(ty, shape, &bytes)
            .map_err(|e| VegaError::Runtime(format!("create literal: {e}")))
    }

    #[cfg(feature = "xla")]
    fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let ty = lit
            .ty()
            .map_err(|e| VegaError::Runtime(format!("literal ty: {e}")))?;
        let err = |e: xla::Error| VegaError::Runtime(format!("literal to_vec: {e}"));
        match ty {
            xla::ElementType::S8 => Ok(Tensor::I8(lit.to_vec().map_err(err)?)),
            xla::ElementType::S32 => Ok(Tensor::I32(lit.to_vec().map_err(err)?)),
            xla::ElementType::F32 => Ok(Tensor::F32(lit.to_vec().map_err(err)?)),
            other => Err(VegaError::Runtime(format!("unsupported output {other:?}"))),
        }
    }
}

/// The compiled-artifact registry: one PJRT executable per HLO artifact.
///
/// Without the `xla` feature (the offline default) this still parses the
/// manifest, but [`Runtime::execute`] reports that the bridge is absent —
/// golden checks skip when artifacts are missing, so plain `cargo test`
/// works in a fresh checkout either way.
pub struct Runtime {
    #[cfg(feature = "xla")]
    client: xla::PjRtClient,
    #[cfg(feature = "xla")]
    execs: HashMap<String, xla::PjRtLoadedExecutable>,
    manifest: Manifest,
    dir: PathBuf,
}

impl Runtime {
    /// Load `manifest.txt` and compile every artifact in `dir`.
    #[cfg(feature = "xla")]
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.txt"))?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| VegaError::Runtime(format!("PjRtClient::cpu: {e}")))?;
        let mut execs = HashMap::new();
        for sig in &manifest.entries {
            let path = dir.join(format!("{}.hlo.txt", sig.name));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().expect("utf-8 path"),
            )
            .map_err(|e| VegaError::Runtime(format!("parse {}: {e}", path.display())))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| VegaError::Runtime(format!("compile {}: {e}", sig.name)))?;
            execs.insert(sig.name.clone(), exe);
        }
        Ok(Self { client, manifest, execs, dir })
    }

    /// Parse `manifest.txt` only (no PJRT available in this build).
    #[cfg(not(feature = "xla"))]
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.txt"))?;
        Ok(Self { manifest, dir })
    }

    /// The default artifact directory (`$VEGA_ARTIFACTS` or `./artifacts`).
    pub fn default_dir() -> PathBuf {
        std::env::var_os("VEGA_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    #[cfg(feature = "xla")]
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    #[cfg(not(feature = "xla"))]
    pub fn platform(&self) -> String {
        "unavailable (built without the `xla` feature)".into()
    }

    pub fn signature(&self, name: &str) -> Option<&Signature> {
        self.manifest.entries.iter().find(|s| s.name == name)
    }

    /// Execute artifact `name` with `inputs`; returns the output tensors.
    #[cfg(not(feature = "xla"))]
    pub fn execute(&self, name: &str, _inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        Err(VegaError::Runtime(format!(
            "cannot execute artifact {name}: vega was built without the `xla` \
             feature (PJRT golden checks are disabled in offline builds)"
        )))
    }

    /// Execute artifact `name` with `inputs`; returns the output tensors.
    ///
    /// Inputs are validated against the manifest signature (dtype, element
    /// count) before crossing the FFI boundary.
    #[cfg(feature = "xla")]
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let sig = self
            .signature(name)
            .ok_or_else(|| VegaError::Runtime(format!("unknown artifact {name}")))?
            .clone();
        if inputs.len() != sig.inputs.len() {
            return Err(VegaError::Runtime(format!(
                "{name}: expected {} inputs, got {}",
                sig.inputs.len(),
                inputs.len()
            )));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (t, ts)) in inputs.iter().zip(&sig.inputs).enumerate() {
            if t.dtype() != ts.dtype || t.len() != ts.elems() {
                return Err(VegaError::Runtime(format!(
                    "{name}: input {i} mismatch: got {:?}x{}, want {:?}x{}",
                    t.dtype(),
                    t.len(),
                    ts.dtype,
                    ts.elems()
                )));
            }
            literals.push(t.to_literal(&ts.shape)?);
        }
        let exe = &self.execs[name];
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| VegaError::Runtime(format!("execute {name}: {e}")))?;
        let root = result[0][0]
            .to_literal_sync()
            .map_err(|e| VegaError::Runtime(format!("to_literal {name}: {e}")))?;
        // aot.py lowers with return_tuple=True: unpack the root tuple.
        let parts = root
            .to_tuple()
            .map_err(|e| VegaError::Runtime(format!("untuple {name}: {e}")))?;
        parts.iter().map(Tensor::from_literal).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_parse_roundtrip() {
        assert_eq!(DType::parse("s8").unwrap(), DType::I8);
        assert_eq!(DType::parse("s32").unwrap(), DType::I32);
        assert_eq!(DType::parse("f32").unwrap(), DType::F32);
        assert!(DType::parse("u8").is_err());
    }

    #[test]
    fn tensor_accessors() {
        let t = Tensor::I8(vec![1, 2, 3]);
        assert_eq!(t.dtype(), DType::I8);
        assert_eq!(t.len(), 3);
        assert!(t.as_i32().is_none());
        assert_eq!(t.as_i8().unwrap(), &[1, 2, 3]);
    }
}
