//! Parser for `artifacts/manifest.txt` (written by `aot.py`).
//!
//! Line format: `name;in=s8[64,64],s8[64,64];out=s32[64,64]`

use std::path::Path;

use crate::common::{Result, VegaError};

use super::DType;

/// One tensor in an artifact signature.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSig {
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl TensorSig {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn size_bytes(&self) -> usize {
        self.elems() * self.dtype.size_bytes()
    }

    fn parse(s: &str) -> Result<Self> {
        let (dt, rest) = s
            .split_once('[')
            .ok_or_else(|| VegaError::Runtime(format!("bad tensor sig {s}")))?;
        let dims = rest
            .strip_suffix(']')
            .ok_or_else(|| VegaError::Runtime(format!("bad tensor sig {s}")))?;
        let shape = dims
            .split(',')
            .filter(|d| !d.is_empty())
            .map(|d| {
                d.parse::<usize>()
                    .map_err(|e| VegaError::Runtime(format!("bad dim {d}: {e}")))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(TensorSig { dtype: DType::parse(dt)?, shape })
    }
}

/// Split `s8[1,2],f32[3]` on the commas *between* tensors.
fn split_tensors(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '[' => depth += 1,
            ']' => depth = depth.saturating_sub(1),
            ',' if depth == 0 => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    if start < s.len() {
        out.push(&s[start..]);
    }
    out
}

/// One artifact's full signature.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Signature {
    pub name: String,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
}

impl Signature {
    pub fn parse(line: &str) -> Result<Self> {
        let mut parts = line.trim().split(';');
        let name = parts
            .next()
            .filter(|n| !n.is_empty())
            .ok_or_else(|| VegaError::Runtime(format!("bad manifest line {line}")))?
            .to_string();
        let ins = parts
            .next()
            .and_then(|p| p.strip_prefix("in="))
            .ok_or_else(|| VegaError::Runtime(format!("missing in= in {line}")))?;
        let outs = parts
            .next()
            .and_then(|p| p.strip_prefix("out="))
            .ok_or_else(|| VegaError::Runtime(format!("missing out= in {line}")))?;
        Ok(Signature {
            name,
            inputs: split_tensors(ins)
                .iter()
                .map(|t| TensorSig::parse(t))
                .collect::<Result<_>>()?,
            outputs: split_tensors(outs)
                .iter()
                .map(|t| TensorSig::parse(t))
                .collect::<Result<_>>()?,
        })
    }
}

/// The parsed artifact manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub entries: Vec<Signature>,
}

impl Manifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let entries = text
            .lines()
            .filter(|l| !l.trim().is_empty())
            .map(Signature::parse)
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest { entries })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_matmul_line() {
        let sig =
            Signature::parse("matmul_int8_64;in=s8[64,64],s8[64,64];out=s32[64,64]").unwrap();
        assert_eq!(sig.name, "matmul_int8_64");
        assert_eq!(sig.inputs.len(), 2);
        assert_eq!(sig.inputs[0].shape, vec![64, 64]);
        assert_eq!(sig.inputs[0].dtype, DType::I8);
        assert_eq!(sig.outputs[0].dtype, DType::I32);
        assert_eq!(sig.inputs[0].elems(), 4096);
        assert_eq!(sig.outputs[0].size_bytes(), 4096 * 4);
    }

    #[test]
    fn parses_multirank_tensors() {
        let sig = Signature::parse("x;in=s8[18,18,16],s8[3,3,16,16];out=s32[16,16,16]").unwrap();
        assert_eq!(sig.inputs[1].shape, vec![3, 3, 16, 16]);
        assert_eq!(sig.outputs[0].elems(), 16 * 16 * 16);
    }

    #[test]
    fn split_tensors_respects_brackets() {
        assert_eq!(split_tensors("s8[1,2],f32[3]"), vec!["s8[1,2]", "f32[3]"]);
        assert_eq!(split_tensors("s8[1]"), vec!["s8[1]"]);
    }

    #[test]
    fn manifest_parse_multiline() {
        let m = Manifest::parse("a;in=s8[1];out=s8[1]\n\nb;in=f32[2];out=f32[2]\n").unwrap();
        assert_eq!(m.entries.len(), 2);
        assert_eq!(m.entries[1].name, "b");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Signature::parse("nope").is_err());
        assert!(Signature::parse("x;in=s8[a];out=s8[1]").is_err());
        assert!(Signature::parse("x;in=u64[1];out=s8[1]").is_err());
    }
}
