//! The device-lifecycle state machine: Fig. 7's sleep↔wake trajectory
//! replayed over a whole sensor-event trace, with per-state time and
//! energy accounting.
//!
//! One [`LifecycleScenario`] pins the full deployment: the cluster
//! workload a true event triggers (a [`Scenario`]), the seeded
//! [`TraceSpec`] stimulus, the sleep mode (cognitive vs plain
//! retentive), the boot path (warm-from-L2 vs MRAM restore) and the
//! duty policy (back to sleep eagerly vs linger awake). [`run_lifecycle`]
//! walks the trace event by event through the real [`Pmu`] — waking via
//! [`Pmu::wake`] so boot latency and the active-wake guard are the PMU's
//! own — and integrates energy per state from [`PowerMode::power_w`].
//! The output [`LifecycleReport`] is a pure function of the scenario
//! descriptor plus the (memoized) inference and CWU results, which is
//! what lets the sweep engine cache it, journal it, and persist it to
//! the `.lfc` disk tier byte-exactly.

use crate::common::{ByteReader, ByteWriter, Fnv1a};
use crate::coordinator::CwuSummary;
use crate::faults::CampaignOutcome;
use crate::mem::Mram;
use crate::power::tables::PJ_PER_BYTE_MRAM;
use crate::power::{LifecycleError, Pmu, PowerMode, WakeSource};
use crate::sweep::{Scenario, SimResult};

use super::trace::TraceSpec;

/// Version stamped into every lifecycle cache key and `.lfc` payload.
/// Bump on ANY change to the state machine, the energy model, or the
/// report encoding — stale persisted reports must read as misses.
pub const LIFECYCLE_MODEL_VERSION: u32 = 1;

/// FC cycles to triage a wake-up on the SoC (IRQ dispatch, sensor
/// readback, decide whether to launch the cluster): 50 k cycles = 0.2 ms
/// at the NOM 250 MHz fabric controller.
pub const TRIAGE_CYCLES: u64 = 50_000;

/// How long the `linger` duty policy keeps the SoC awake after handling
/// an event, absorbing bursts without paying another boot.
pub const LINGER_S: f64 = 0.1;

/// Battery terminal voltage for the lifetime projection (a 3 V lithium
/// coin cell, the IoT end-node reference of §I).
pub const BATTERY_V: f64 = 3.0;

/// Sleep mode of the duty cycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SleepKind {
    /// Cognitive sleep: the CWU classifies autonomously; false events
    /// are absorbed without waking the SoC (§II-B).
    Cognitive,
    /// Plain retentive sleep: every sensor event is an external-pad
    /// wake-up the SoC must triage itself.
    Retentive,
}

impl SleepKind {
    pub fn label(&self) -> &'static str {
        match self {
            SleepKind::Cognitive => "cognitive",
            SleepKind::Retentive => "retentive",
        }
    }
}

/// Boot path after wake-up (the §II-A retention-vs-restore trade-off).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BootKind {
    /// Image held in retentive L2: instant resume, standing retention
    /// power all through sleep.
    WarmL2,
    /// Zero retention power; the image is restored from MRAM on every
    /// boot (restore time via the MRAM channel, 20 pJ/B read energy).
    MramRestore,
}

impl BootKind {
    pub fn label(&self) -> &'static str {
        match self {
            BootKind::WarmL2 => "l2",
            BootKind::MramRestore => "mram",
        }
    }
}

/// What the SoC does after handling an event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DutyPolicy {
    /// Re-enter sleep immediately.
    Eager,
    /// Stay awake [`LINGER_S`] after each event, absorbing bursts
    /// without another boot (and without CWU filtering while awake).
    Linger,
}

impl DutyPolicy {
    pub fn label(&self) -> &'static str {
        match self {
            DutyPolicy::Eager => "eager",
            DutyPolicy::Linger => "linger",
        }
    }
}

/// A full deployment descriptor: everything [`run_lifecycle`] needs,
/// and everything its cache key must cover.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LifecycleScenario {
    /// The cluster workload a true event triggers.
    pub scenario: Scenario,
    /// The seeded sensor-event stimulus.
    pub trace: TraceSpec,
    pub sleep: SleepKind,
    pub boot: BootKind,
    pub duty: DutyPolicy,
    /// Application image restored from MRAM (and, on the L2 path, held
    /// retentive) in bytes.
    pub image_bytes: u64,
    /// Battery budget for the lifetime projection, in mAh.
    pub battery_mah: f64,
    /// MRAM retention-upset rate for the optional fault campaign
    /// (upsets per second of sleep, as in `faults::FaultPlan`); 0
    /// disables the campaign.
    pub upset_rate: f64,
}

impl LifecycleScenario {
    /// The sleep-state [`PowerMode`]: the L2 boot path pays retention on
    /// the image through sleep, the MRAM path retains nothing.
    pub fn sleep_mode(&self) -> PowerMode {
        let retained = match self.boot {
            BootKind::WarmL2 => self.image_bytes as usize,
            BootKind::MramRestore => 0,
        };
        match self.sleep {
            SleepKind::Cognitive => PowerMode::CognitiveSleep { retentive_l2_bytes: retained },
            SleepKind::Retentive => PowerMode::RetentiveSleep { retentive_l2_bytes: retained },
        }
    }

    /// Versioned, collision-free cache key (the `faults::Campaign::key`
    /// discipline: human-readable axes, every f64 bit-exact).
    pub fn key(&self) -> String {
        format!(
            "lifecycle-v{}|{}|{}|sleep={}|boot={}|duty={}|img={}|mah={:016x}|ur={:016x}",
            LIFECYCLE_MODEL_VERSION,
            crate::sweep::persist::key_string(&self.scenario.canonical().key()),
            self.trace.key_fragment(),
            self.sleep.label(),
            self.boot.label(),
            self.duty.label(),
            self.image_bytes,
            self.battery_mah.to_bits(),
            self.upset_rate.to_bits()
        )
    }
}

/// Per-state time/energy breakdown and the derived deployment figures.
/// All fields are pure functions of the [`LifecycleScenario`]; the byte
/// encoding ([`encode_report`]) fixes their order, so treat the field
/// order as part of the `.lfc` format.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct LifecycleReport {
    // ---- trace accounting (counts) ----
    /// Sensor events in the trace. Invariant: `true_wakes +
    /// false_wakes == events`, always.
    pub events: u64,
    /// True-positive events (each ran a cluster inference).
    pub true_wakes: u64,
    /// False-positive events (absorbed by the CWU, or a spurious boot).
    pub false_wakes: u64,
    /// False events the CWU absorbed in sleep, without any SoC boot
    /// (cognitive sleep only — the §II-B power saving, made countable).
    pub absorbed_events: u64,
    /// Actual [`Pmu::wake`] transitions.
    pub boots: u64,
    /// Boots that restored the image from MRAM.
    pub mram_restores: u64,
    // ---- time breakdown (seconds; sums to total_s) ----
    pub total_s: f64,
    pub sleep_s: f64,
    /// CWU classification bursts (cognitive sleep only).
    pub classify_s: f64,
    /// Boot latency: domain switch + MRAM restore.
    pub wake_s: f64,
    /// SoC-active triage bursts plus linger idle.
    pub triage_s: f64,
    /// Cluster inference bursts.
    pub infer_s: f64,
    // ---- energy breakdown (joules; sums to total_j) ----
    pub sleep_j: f64,
    pub classify_j: f64,
    pub wake_j: f64,
    pub triage_j: f64,
    pub infer_j: f64,
    /// MRAM read energy of the image restores (20 pJ/B, Fig. 11 model).
    pub restore_j: f64,
    // ---- derived deployment figures ----
    pub total_j: f64,
    pub avg_power_w: f64,
    pub energy_per_event_j: f64,
    /// `false_wakes / events` (0 for an empty trace).
    pub false_wake_rate: f64,
    /// Projected lifetime on the configured battery, in hours.
    pub battery_hours: f64,
    /// CWU wake-decision accuracy on the reference workload (0 when the
    /// sleep mode has no CWU).
    pub cwu_accuracy: f64,
    // ---- optional retention-upset campaign (zeros when upset_rate=0) ----
    pub mram_flips: u64,
    pub mram_corrected: u64,
    pub mram_detected: u64,
    pub mram_silent: u64,
    pub diverged: bool,
}

impl LifecycleReport {
    /// Fill the derived figures from the accumulated breakdown.
    fn finalize(&mut self, battery_mah: f64) {
        self.total_j = self.sleep_j
            + self.classify_j
            + self.wake_j
            + self.triage_j
            + self.infer_j
            + self.restore_j;
        self.avg_power_w = if self.total_s > 0.0 { self.total_j / self.total_s } else { 0.0 };
        self.energy_per_event_j =
            if self.events > 0 { self.total_j / self.events as f64 } else { 0.0 };
        self.false_wake_rate =
            if self.events > 0 { self.false_wakes as f64 / self.events as f64 } else { 0.0 };
        // mAh × V = mWh; /1e3 → Wh; Wh / W = hours.
        self.battery_hours = if self.avg_power_w > 0.0 {
            battery_mah * 1e-3 * BATTERY_V / self.avg_power_w
        } else {
            0.0
        };
    }

    /// Copy the MRAM-tier counters of a retention-upset campaign run
    /// over this deployment's actual sleep time.
    pub fn attach_faults(&mut self, out: &CampaignOutcome) {
        self.mram_flips = out.stats.mram.flips;
        self.mram_corrected = out.stats.mram.corrected;
        self.mram_detected = out.stats.mram.detected;
        self.mram_silent = out.stats.mram.silent;
        self.diverged = out.diverged;
    }

    /// FNV-1a digest of the canonical byte encoding — the journal's
    /// replay-integrity digest for lifecycle cells.
    pub fn digest(&self) -> u64 {
        let mut h = Fnv1a::new();
        h.write(&encode_report(self));
        h.finish()
    }
}

/// Replay the trace through the Fig. 7 state machine.
///
/// `inference` is the (cached) simulation of the true-event workload;
/// `cwu` the (cached) CWU reference summary, `Some` iff the sleep mode
/// is cognitive. Panics with a [`LifecycleError`] message on a malformed
/// trace — under the sweep engine's per-cell `catch_unwind` that renders
/// as one structured `status=error` row.
pub fn run_lifecycle(
    lc: &LifecycleScenario,
    inference: &SimResult,
    cwu: Option<&CwuSummary>,
) -> LifecycleReport {
    let spec = lc.trace;
    if !(spec.duration_s.is_finite() && spec.duration_s > 0.0) {
        let e = LifecycleError::MalformedTrace {
            what: format!("duration_s={} must be finite and positive", spec.duration_s),
        };
        panic!("{e}");
    }
    if !(spec.rate_hz.is_finite() && spec.rate_hz >= 0.0) {
        let e = LifecycleError::MalformedTrace {
            what: format!("rate_hz={} must be finite and non-negative", spec.rate_hz),
        };
        panic!("{e}");
    }
    if !(0.0..=1.0).contains(&spec.true_fraction) {
        let e = LifecycleError::MalformedTrace {
            what: format!("true_fraction={} must be in [0, 1]", spec.true_fraction),
        };
        panic!("{e}");
    }

    let op = crate::power::NOM;
    let mram = Mram::new();
    let sleep_mode = lc.sleep_mode();
    let sleep_p = sleep_mode.power_w();
    let soc_mode = PowerMode::SocActive { op, fc_util: 0.5 };
    let soc_p = soc_mode.power_w();
    let cores = inference.run.stats.per_core.len().max(1);
    let cluster_mode = PowerMode::ClusterActive {
        op,
        fc_util: 0.3,
        core_util: cores as f64 / crate::cluster::N_CORES as f64,
        hwce_active: 0.0,
    };
    let cluster_p = cluster_mode.power_w();

    let infer_t = inference.run.stats.cycles as f64 / op.f_cl;
    let triage_t = TRIAGE_CYCLES as f64 / op.f_soc;
    // CWU classification burst: mean datapath cycles per frame at the
    // 32 kHz sleep clock; burst energy at full datapath duty (marginal
    // over the ref-duty power already inside the cognitive sleep mode).
    let classify_t = match cwu {
        Some(c) if c.frames > 0 => {
            c.datapath_cycles as f64 / c.frames as f64 / crate::cwu::SLEEP_CLK_HZ
        }
        _ => 0.0,
    };
    let classify_p = crate::power::cwu_power_w(crate::cwu::SLEEP_CLK_HZ, 1.0, false);
    let restore_j_per_boot = lc.image_bytes as f64 * PJ_PER_BYTE_MRAM * 1e-12;
    let boot_path = match lc.boot {
        BootKind::WarmL2 => crate::power::BootPath::WarmFromL2,
        BootKind::MramRestore => crate::power::BootPath::WarmFromMram { image_bytes: lc.image_bytes },
    };
    let wake_source = match lc.sleep {
        SleepKind::Cognitive => WakeSource::Cognitive,
        SleepKind::Retentive => WakeSource::ExternalPad,
    };

    let mut r = LifecycleReport {
        cwu_accuracy: cwu.map(|c| c.accuracy).unwrap_or(0.0),
        ..Default::default()
    };
    let mut pmu = Pmu::new();
    pmu.enter(sleep_mode);

    let mut t = 0.0; // simulated-time cursor
    let mut awake_until = 0.0; // > t while lingering SoC-active

    for e in spec.expand() {
        r.events += 1;
        if e.is_true {
            r.true_wakes += 1;
        } else {
            r.false_wakes += 1;
        }
        // Events that arrive while a burst is still being processed
        // queue until the machine is free.
        let at = e.at_s.max(t);

        let awake = awake_until > t;
        if awake && at < awake_until {
            // Inside an open linger window: handle directly, no boot,
            // no CWU (the SoC is up, the CWU idle).
            r.triage_s += at - t;
            r.triage_j += (at - t) * soc_p;
            t = at;
            if e.is_true {
                pmu.enter(cluster_mode);
                r.infer_s += infer_t;
                r.infer_j += infer_t * cluster_p;
                t += infer_t;
                pmu.enter(soc_mode);
            } else {
                r.triage_s += triage_t;
                r.triage_j += triage_t * soc_p;
                t += triage_t;
            }
            awake_until = t + LINGER_S;
            continue;
        }
        if awake {
            // Window expired before this event: idle out, back to sleep.
            r.triage_s += awake_until - t;
            r.triage_j += (awake_until - t) * soc_p;
            t = awake_until;
            pmu.enter(sleep_mode);
        }

        // Asleep until the event arrives.
        r.sleep_s += at - t;
        r.sleep_j += (at - t) * sleep_p;
        t = at;

        if lc.sleep == SleepKind::Cognitive {
            // The CWU classifies every event in sleep.
            r.classify_s += classify_t;
            r.classify_j += classify_t * classify_p;
            t += classify_t;
            if !e.is_true {
                // Absorbed: the SoC never wakes. The paper's saving.
                r.absorbed_events += 1;
                continue;
            }
        }

        // Wake the SoC through the real PMU state machine.
        let latency = pmu
            .wake(wake_source, t, op, boot_path, &mram)
            .unwrap_or_else(|err| panic!("{err}"));
        r.boots += 1;
        r.wake_s += latency;
        r.wake_j += latency * soc_p;
        t += latency;
        if lc.boot == BootKind::MramRestore {
            r.mram_restores += 1;
            r.restore_j += restore_j_per_boot;
        }

        // SoC triage, then (true events) the cluster inference.
        r.triage_s += triage_t;
        r.triage_j += triage_t * soc_p;
        t += triage_t;
        if e.is_true {
            pmu.enter(cluster_mode);
            r.infer_s += infer_t;
            r.infer_j += infer_t * cluster_p;
            t += infer_t;
        }
        pmu.enter(soc_mode);

        match lc.duty {
            DutyPolicy::Eager => pmu.enter(sleep_mode),
            DutyPolicy::Linger => awake_until = t + LINGER_S,
        }
    }

    // Tail: close any open linger window, then sleep out the trace.
    let end = spec.duration_s.max(t);
    if awake_until > t {
        let close = awake_until.min(end);
        r.triage_s += close - t;
        r.triage_j += (close - t) * soc_p;
        t = close;
        pmu.enter(sleep_mode);
    }
    r.sleep_s += end - t;
    r.sleep_j += (end - t) * sleep_p;
    r.total_s = end;

    r.finalize(lc.battery_mah);
    r
}

/// Canonical byte encoding of a report: every field in declaration
/// order, u64/f64 little-endian, the bool as a strict 0/1 byte. This is
/// the `.lfc` disk payload and the digest pre-image — goldens in
/// `tests/lifecycle.rs` pin it.
pub fn encode_report(r: &LifecycleReport) -> Vec<u8> {
    let mut w = ByteWriter::with_capacity(225);
    w.u64(r.events);
    w.u64(r.true_wakes);
    w.u64(r.false_wakes);
    w.u64(r.absorbed_events);
    w.u64(r.boots);
    w.u64(r.mram_restores);
    w.f64(r.total_s);
    w.f64(r.sleep_s);
    w.f64(r.classify_s);
    w.f64(r.wake_s);
    w.f64(r.triage_s);
    w.f64(r.infer_s);
    w.f64(r.sleep_j);
    w.f64(r.classify_j);
    w.f64(r.wake_j);
    w.f64(r.triage_j);
    w.f64(r.infer_j);
    w.f64(r.restore_j);
    w.f64(r.total_j);
    w.f64(r.avg_power_w);
    w.f64(r.energy_per_event_j);
    w.f64(r.false_wake_rate);
    w.f64(r.battery_hours);
    w.f64(r.cwu_accuracy);
    w.u64(r.mram_flips);
    w.u64(r.mram_corrected);
    w.u64(r.mram_detected);
    w.u64(r.mram_silent);
    w.u8(u8::from(r.diverged));
    w.into_vec()
}

/// Strict inverse of [`encode_report`]: rejects short input, trailing
/// bytes, and any bool byte other than 0/1.
pub fn decode_report(bytes: &[u8]) -> Option<LifecycleReport> {
    let mut d = ByteReader::new(bytes);
    let r = LifecycleReport {
        events: d.u64()?,
        true_wakes: d.u64()?,
        false_wakes: d.u64()?,
        absorbed_events: d.u64()?,
        boots: d.u64()?,
        mram_restores: d.u64()?,
        total_s: d.f64()?,
        sleep_s: d.f64()?,
        classify_s: d.f64()?,
        wake_s: d.f64()?,
        triage_s: d.f64()?,
        infer_s: d.f64()?,
        sleep_j: d.f64()?,
        classify_j: d.f64()?,
        wake_j: d.f64()?,
        triage_j: d.f64()?,
        infer_j: d.f64()?,
        restore_j: d.f64()?,
        total_j: d.f64()?,
        avg_power_w: d.f64()?,
        energy_per_event_j: d.f64()?,
        false_wake_rate: d.f64()?,
        battery_hours: d.f64()?,
        cwu_accuracy: d.f64()?,
        mram_flips: d.u64()?,
        mram_corrected: d.u64()?,
        mram_detected: d.u64()?,
        mram_silent: d.u64()?,
        diverged: match d.u8()? {
            0 => false,
            1 => true,
            _ => return None,
        },
    };
    if !d.done() {
        return None;
    }
    Some(r)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::IntWidth;
    use crate::sweep::SimArena;

    fn scenario() -> Scenario {
        Scenario::IntMatmul { w: IntWidth::I8, cores: 8 }
    }

    fn inference() -> SimResult {
        let mut arena = SimArena::new();
        scenario().simulate(&mut arena)
    }

    fn lc(sleep: SleepKind, boot: BootKind, duty: DutyPolicy) -> LifecycleScenario {
        LifecycleScenario {
            scenario: scenario(),
            trace: TraceSpec { seed: 5, duration_s: 3600.0, rate_hz: 0.05, true_fraction: 0.5 },
            sleep,
            boot,
            duty,
            image_bytes: 256 * 1024,
            battery_mah: 225.0,
            upset_rate: 0.0,
        }
    }

    fn summary() -> CwuSummary {
        // A plausible fixed summary (the real one is expensive; engine
        // tests cover the live path).
        CwuSummary { accuracy: 0.93, frames: 100, datapath_cycles: 7_000, duty_at_150sps: 0.17 }
    }

    #[test]
    fn report_balances_time_energy_and_counts() {
        let inf = inference();
        let sum = summary();
        let r = run_lifecycle(&lc(SleepKind::Cognitive, BootKind::WarmL2, DutyPolicy::Eager), &inf, Some(&sum));
        assert_eq!(r.true_wakes + r.false_wakes, r.events);
        assert_eq!(r.boots, r.true_wakes, "cognitive+eager boots only on true events");
        assert_eq!(r.absorbed_events, r.false_wakes);
        assert_eq!(r.mram_restores, 0);
        let t_sum = r.sleep_s + r.classify_s + r.wake_s + r.triage_s + r.infer_s;
        assert!((t_sum - r.total_s).abs() < 1e-9 * r.total_s, "{t_sum} vs {}", r.total_s);
        let j_sum = r.sleep_j + r.classify_j + r.wake_j + r.triage_j + r.infer_j + r.restore_j;
        assert!((j_sum - r.total_j).abs() <= 1e-12 * r.total_j.max(1.0));
        assert!(r.avg_power_w > 0.0 && r.battery_hours > 0.0);
    }

    #[test]
    fn retentive_sleep_boots_on_every_event() {
        let inf = inference();
        let r = run_lifecycle(&lc(SleepKind::Retentive, BootKind::WarmL2, DutyPolicy::Eager), &inf, None);
        assert_eq!(r.boots, r.events, "no CWU: every event wakes the SoC");
        assert_eq!(r.absorbed_events, 0);
        assert_eq!(r.classify_s, 0.0);
        assert_eq!(r.cwu_accuracy, 0.0);
    }

    #[test]
    fn cognitive_filtering_undercuts_retentive_wakeups() {
        let inf = inference();
        let sum = summary();
        let cog = run_lifecycle(&lc(SleepKind::Cognitive, BootKind::WarmL2, DutyPolicy::Eager), &inf, Some(&sum));
        let ret = run_lifecycle(&lc(SleepKind::Retentive, BootKind::WarmL2, DutyPolicy::Eager), &inf, None);
        // Same trace, same workload; the CWU absorbs the false half in
        // sleep — but its standing power only pays off when spurious
        // boots are what dominates; at this event rate the wake tax of
        // the retentive path exceeds the CWU's standing cost.
        assert!(cog.boots < ret.boots);
        assert!(cog.wake_j + cog.triage_j < ret.wake_j + ret.triage_j);
    }

    #[test]
    fn mram_boot_trades_retention_for_restore_energy() {
        let inf = inference();
        let r_l2 = run_lifecycle(&lc(SleepKind::Retentive, BootKind::WarmL2, DutyPolicy::Eager), &inf, None);
        let r_mr = run_lifecycle(&lc(SleepKind::Retentive, BootKind::MramRestore, DutyPolicy::Eager), &inf, None);
        assert_eq!(r_l2.restore_j, 0.0);
        assert_eq!(r_mr.mram_restores, r_mr.boots);
        // 256 kB × 20 pJ/B per restore.
        let per_boot = 256.0 * 1024.0 * 20e-12;
        assert!((r_mr.restore_j - per_boot * r_mr.boots as f64).abs() < 1e-15 * r_mr.boots as f64 + 1e-18);
        // MRAM boots take longer (the restore), L2 sleeps cost more.
        assert!(r_mr.wake_s > r_l2.wake_s);
        assert!(r_l2.sleep_j > r_mr.sleep_j);
    }

    #[test]
    fn linger_absorbs_bursts_into_fewer_boots() {
        let inf = inference();
        // A dense trace: 2 events/s over 100 s — bursts well inside the
        // 100 ms linger window are absorbed.
        let mut base = lc(SleepKind::Retentive, BootKind::WarmL2, DutyPolicy::Eager);
        base.trace = TraceSpec { seed: 9, duration_s: 100.0, rate_hz: 2.0, true_fraction: 0.5 };
        let eager = run_lifecycle(&base, &inf, None);
        let mut ling = base;
        ling.duty = DutyPolicy::Linger;
        let linger = run_lifecycle(&ling, &inf, None);
        assert_eq!(eager.boots, eager.events);
        assert!(linger.boots < eager.boots, "linger {} vs eager {}", linger.boots, eager.boots);
        assert!(linger.triage_s > eager.triage_s, "linger pays idle time instead");
        let t_sum = linger.sleep_s + linger.classify_s + linger.wake_s + linger.triage_s + linger.infer_s;
        assert!((t_sum - linger.total_s).abs() < 1e-9 * linger.total_s);
    }

    #[test]
    fn reports_are_deterministic_and_digest_stable() {
        let inf = inference();
        let sum = summary();
        let spec = lc(SleepKind::Cognitive, BootKind::MramRestore, DutyPolicy::Linger);
        let a = run_lifecycle(&spec, &inf, Some(&sum));
        let b = run_lifecycle(&spec, &inf, Some(&sum));
        assert_eq!(a, b);
        assert_eq!(a.digest(), b.digest());
        assert_eq!(encode_report(&a), encode_report(&b));
    }

    #[test]
    fn report_round_trips_bit_exactly() {
        let inf = inference();
        let sum = summary();
        for (s, b, d) in [
            (SleepKind::Cognitive, BootKind::WarmL2, DutyPolicy::Eager),
            (SleepKind::Cognitive, BootKind::MramRestore, DutyPolicy::Linger),
            (SleepKind::Retentive, BootKind::WarmL2, DutyPolicy::Linger),
            (SleepKind::Retentive, BootKind::MramRestore, DutyPolicy::Eager),
        ] {
            let cwu = matches!(s, SleepKind::Cognitive).then_some(&sum);
            let r = run_lifecycle(&lc(s, b, d), &inf, cwu);
            let bytes = encode_report(&r);
            let back = decode_report(&bytes).expect("round trip");
            assert_eq!(back, r);
            assert!(decode_report(&bytes[..bytes.len() - 1]).is_none(), "truncation rejected");
            let mut long = bytes.clone();
            long.push(0);
            assert!(decode_report(&long).is_none(), "trailing bytes rejected");
            let mut bad_bool = bytes;
            *bad_bool.last_mut().unwrap() = 2;
            assert!(decode_report(&bad_bool).is_none(), "bool must be 0/1");
        }
    }

    #[test]
    fn empty_trace_sleeps_the_whole_duration() {
        let inf = inference();
        let mut spec = lc(SleepKind::Retentive, BootKind::MramRestore, DutyPolicy::Eager);
        spec.trace = TraceSpec { seed: 1, duration_s: 1000.0, rate_hz: 0.0, true_fraction: 0.5 };
        let r = run_lifecycle(&spec, &inf, None);
        assert_eq!(r.events, 0);
        assert_eq!(r.boots, 0);
        assert_eq!(r.sleep_s, 1000.0);
        assert_eq!(r.energy_per_event_j, 0.0);
        assert_eq!(r.false_wake_rate, 0.0);
        // Pure deep-sleep-grade power: retentive, nothing retained.
        assert!((r.avg_power_w - crate::power::tables::DEEP_SLEEP_W).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "malformed trace")]
    fn malformed_duration_panics_with_the_typed_message() {
        let inf = inference();
        let mut spec = lc(SleepKind::Retentive, BootKind::WarmL2, DutyPolicy::Eager);
        spec.trace.duration_s = f64::NAN;
        run_lifecycle(&spec, &inf, None);
    }

    #[test]
    fn key_covers_every_axis() {
        let base = lc(SleepKind::Cognitive, BootKind::WarmL2, DutyPolicy::Eager);
        let k = base.key();
        assert!(k.starts_with("lifecycle-v1|"));
        for variant in [
            LifecycleScenario { sleep: SleepKind::Retentive, ..base },
            LifecycleScenario { boot: BootKind::MramRestore, ..base },
            LifecycleScenario { duty: DutyPolicy::Linger, ..base },
            LifecycleScenario { image_bytes: 128 * 1024, ..base },
            LifecycleScenario { battery_mah: 100.0, ..base },
            LifecycleScenario { upset_rate: 1e-3, ..base },
            LifecycleScenario {
                trace: TraceSpec { seed: 6, ..base.trace },
                ..base
            },
            LifecycleScenario {
                scenario: Scenario::IntMatmul { w: IntWidth::I8, cores: 4 },
                ..base
            },
        ] {
            assert_ne!(variant.key(), k, "axis not in key: {variant:?}");
        }
    }
}
