//! The device-lifecycle engine: Fig. 7's sleep↔wake duty cycle driven
//! by a seeded sensor-event trace, end to end (§II-A/§II-B/§III).
//!
//! The paper's headline IoT claim is not a kernel number — it is a
//! *deployment* number: a 1.7 µW cognitive sleep mode whose CWU absorbs
//! false sensor events autonomously, MRAM-retentive state so wake-up
//! restores instead of reboots, and a cluster that bursts through the
//! real inference before the SoC drops back to sleep. This module
//! closes that loop over simulated days:
//!
//! * [`trace`] — seeded, replayable sensor-event traces
//!   ([`TraceSpec`] → time-ordered [`SensorEvent`] list).
//! * [`sim`] — the state machine itself ([`run_lifecycle`]): sleep →
//!   CWU classify → false-wake absorb / true-wake [`crate::power::Pmu`]
//!   boot → triage → cluster inference → sleep, accumulating per-state
//!   time and energy into a [`LifecycleReport`] (battery lifetime,
//!   false-wake rate, energy per event), with an optional MRAM
//!   retention-upset campaign scaled by the actual sleep time.
//! * [`cli`] — the `vega lifecycle` grid renderer (rate × duty × sleep
//!   × boot), with the full `--jobs`/`--resume`/`--shard`/`--merge`
//!   crash-safety surface and the persistent `.lfc` store tier behind
//!   it.
//!
//! Everything is a pure function of the descriptors: one
//! [`LifecycleScenario`] yields one byte-exact [`LifecycleReport`] at
//! any parallelism, which is what the determinism suite
//! (`tests/lifecycle.rs`) pins.

pub mod cli;
pub mod sim;
pub mod trace;

pub use cli::{grid_key, render, render_with, LifecycleCmd};
pub use sim::{
    decode_report, encode_report, run_lifecycle, BootKind, DutyPolicy, LifecycleReport,
    LifecycleScenario, SleepKind, BATTERY_V, LIFECYCLE_MODEL_VERSION, LINGER_S, TRIAGE_CYCLES,
};
pub use trace::{SensorEvent, TraceSpec};
