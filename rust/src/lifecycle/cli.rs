//! The `vega lifecycle` subcommand: sweep a deployment grid — event
//! rate × duty policy × sleep mode × boot path — over one seeded trace
//! and kernel, and render battery-lifetime / false-wake / per-state
//! energy figures as CSV, Markdown or JSON.
//!
//! Grid cells fan out across the engine's worker pool, memoize through
//! the persistent `.lfc` store tier, and render in deterministic grid
//! order (rate-major, then duty, sleep, boot) — byte-identical for any
//! `--jobs`, like every other renderer in the crate. The full ISSUE 7
//! surface rides along: `--resume` replays the grid journal, `--shard
//! I/N` slices it, `--merge N` reassembles, and a panicking cell
//! renders as its own `status` column error while the rest completes.

use crate::sweep::explore::{
    parse_merge, parse_ms, parse_retries, sanitize_cell, GridFormat, RenderedGrid,
};
use crate::sweep::journal::{self, GridSession, ShardSpec};
use crate::sweep::{default_jobs, CellPolicy, Scenario, SweepEngine};

use super::sim::{BootKind, DutyPolicy, LifecycleReport, LifecycleScenario, SleepKind};
use super::trace::TraceSpec;

/// Cap on λ = rate × duration: the trace is expanded in memory, one
/// event at a time, and 5 M events is already a ~decade at 1 Hz.
const MAX_EXPECTED_EVENTS: f64 = 5e6;

/// Largest restorable image: the full 1600 kB of L2.
const MAX_IMAGE_KB: u64 = 1600;

/// A parsed `vega lifecycle` invocation.
#[derive(Debug, Clone)]
pub struct LifecycleCmd {
    /// The true-event workload (canonical CLI token, for report labels).
    pub kernel: &'static str,
    /// The scenario every true wake-up of the grid runs.
    pub scenario: Scenario,
    /// Active cores (matmul kernels only; NSAA kernels pin 8).
    pub cores: usize,
    /// Trace seed (`--seed`; one trace per rate, shared across policies).
    pub seed: u64,
    /// Simulated deployment length in seconds (`--duration-s`).
    pub duration_s: f64,
    /// True-positive fraction of the trace (`--true-fraction`).
    pub true_fraction: f64,
    /// Event-rate ladder in events/s (`--rates`, grid-major axis).
    pub rates: Vec<f64>,
    /// Duty policies (`--duty eager,linger`).
    pub duties: Vec<DutyPolicy>,
    /// Sleep modes (`--sleep cognitive,retentive`).
    pub sleeps: Vec<SleepKind>,
    /// Boot paths (`--boot l2,mram`, grid-minor axis).
    pub boots: Vec<BootKind>,
    /// Application image in kB (`--image-kb`): restored from MRAM on
    /// the mram path, held retentive on the l2 path.
    pub image_kb: u64,
    /// Battery budget for the lifetime column (`--battery-mah`).
    pub battery_mah: f64,
    /// MRAM retention-upset rate for the optional fault campaign
    /// (`--upset-rate`, upsets per Mbit per hour of sleep; 0 = off).
    pub upset_rate: f64,
    /// Output renderer (`--format csv|md|json`).
    pub format: GridFormat,
    /// Worker count (`--jobs`, default `VEGA_JOBS`/all cores).
    pub jobs: usize,
    /// Print memo/store counters to stderr after rendering (`--stats`).
    pub stats: bool,
    /// Replay this grid's checkpoint journal (`--resume`).
    pub resume: bool,
    /// Own only one deterministic slice of the grid (`--shard I/N`).
    pub shard: Option<ShardSpec>,
    /// Reassemble N shard journals (`--merge N`).
    pub merge: Option<u32>,
    /// Per-cell retry/timeout policy (`--retries`, `--backoff-ms`,
    /// `--timeout-ms`).
    pub policy: CellPolicy,
}

fn parse_rates(s: &str) -> Result<Vec<f64>, String> {
    let mut out = Vec::new();
    for tok in s.split(',').map(str::trim).filter(|t| !t.is_empty()) {
        let r = tok
            .parse::<f64>()
            .ok()
            .filter(|r| r.is_finite() && *r >= 0.0)
            .ok_or_else(|| format!("bad rate '{tok}' (must be finite events/s, >= 0)"))?;
        out.push(r);
    }
    if out.is_empty() {
        return Err("--rates selected no rates".into());
    }
    Ok(out)
}

fn parse_duties(s: &str) -> Result<Vec<DutyPolicy>, String> {
    let mut out = Vec::new();
    for tok in s.split(',').map(str::trim).filter(|t| !t.is_empty()) {
        out.push(match tok.to_ascii_lowercase().as_str() {
            "eager" => DutyPolicy::Eager,
            "linger" => DutyPolicy::Linger,
            other => return Err(format!("unknown duty policy '{other}' (eager|linger)")),
        });
    }
    if out.is_empty() {
        return Err("--duty selected no policies".into());
    }
    Ok(out)
}

fn parse_sleeps(s: &str) -> Result<Vec<SleepKind>, String> {
    let mut out = Vec::new();
    for tok in s.split(',').map(str::trim).filter(|t| !t.is_empty()) {
        out.push(match tok.to_ascii_lowercase().as_str() {
            "cognitive" => SleepKind::Cognitive,
            "retentive" => SleepKind::Retentive,
            other => return Err(format!("unknown sleep mode '{other}' (cognitive|retentive)")),
        });
    }
    if out.is_empty() {
        return Err("--sleep selected no modes".into());
    }
    Ok(out)
}

fn parse_boots(s: &str) -> Result<Vec<BootKind>, String> {
    let mut out = Vec::new();
    for tok in s.split(',').map(str::trim).filter(|t| !t.is_empty()) {
        out.push(match tok.to_ascii_lowercase().as_str() {
            "l2" => BootKind::WarmL2,
            "mram" => BootKind::MramRestore,
            other => return Err(format!("unknown boot path '{other}' (l2|mram)")),
        });
    }
    if out.is_empty() {
        return Err("--boot selected no paths".into());
    }
    Ok(out)
}

impl LifecycleCmd {
    /// Parse the arguments following `vega lifecycle`. Unknown flags and
    /// malformed values are errors.
    pub fn parse(args: &[String]) -> Result<LifecycleCmd, String> {
        let mut kernel_tok = "matmul-i8".to_string();
        let mut cores = 8usize;
        let mut seed = 1u64;
        let mut duration_s = 86_400.0f64;
        let mut true_fraction = 0.5f64;
        let mut rates = vec![0.01, 0.1, 1.0];
        let mut duties = vec![DutyPolicy::Eager];
        let mut sleeps = vec![SleepKind::Cognitive, SleepKind::Retentive];
        let mut boots = vec![BootKind::WarmL2, BootKind::MramRestore];
        let mut image_kb = 256u64;
        let mut battery_mah = 225.0f64;
        let mut upset_rate = 0.0f64;
        let mut format = GridFormat::Csv;
        let mut jobs = default_jobs();
        let mut stats = false;
        let mut resume = false;
        let mut shard = None;
        let mut merge = None;
        let mut policy = CellPolicy::default();
        let mut it = args.iter();
        while let Some(a) = it.next() {
            let mut value = |flag: &str| {
                it.next().map(String::as_str).ok_or_else(|| format!("{flag} needs a value"))
            };
            match a.as_str() {
                "--kernel" => kernel_tok = value("--kernel")?.to_string(),
                "--cores" => {
                    let v = value("--cores")?;
                    cores = v
                        .parse::<usize>()
                        .ok()
                        .filter(|&n| (1..=crate::cluster::N_CORES).contains(&n))
                        .ok_or_else(|| {
                            format!("--cores must be 1..={}, got '{v}'", crate::cluster::N_CORES)
                        })?;
                }
                "--seed" => {
                    let v = value("--seed")?;
                    seed = v.parse::<u64>().map_err(|_| format!("bad seed '{v}'"))?;
                }
                "--duration-s" => {
                    let v = value("--duration-s")?;
                    duration_s = v
                        .parse::<f64>()
                        .ok()
                        .filter(|d| d.is_finite() && *d > 0.0 && *d <= 1e8)
                        .ok_or_else(|| {
                            format!("--duration-s must be in (0, 1e8] seconds, got '{v}'")
                        })?;
                }
                "--true-fraction" => {
                    let v = value("--true-fraction")?;
                    true_fraction = v
                        .parse::<f64>()
                        .ok()
                        .filter(|f| (0.0..=1.0).contains(f))
                        .ok_or_else(|| format!("--true-fraction must be in [0, 1], got '{v}'"))?;
                }
                "--rates" => rates = parse_rates(value("--rates")?)?,
                "--duty" => duties = parse_duties(value("--duty")?)?,
                "--sleep" => sleeps = parse_sleeps(value("--sleep")?)?,
                "--boot" => boots = parse_boots(value("--boot")?)?,
                "--image-kb" => {
                    let v = value("--image-kb")?;
                    image_kb = v
                        .parse::<u64>()
                        .ok()
                        .filter(|&k| k <= MAX_IMAGE_KB)
                        .ok_or_else(|| {
                            format!("--image-kb must be 0..={MAX_IMAGE_KB}, got '{v}'")
                        })?;
                }
                "--battery-mah" => {
                    let v = value("--battery-mah")?;
                    battery_mah = v
                        .parse::<f64>()
                        .ok()
                        .filter(|b| b.is_finite() && *b > 0.0)
                        .ok_or_else(|| format!("--battery-mah must be positive, got '{v}'"))?;
                }
                "--upset-rate" => {
                    let v = value("--upset-rate")?;
                    upset_rate = v
                        .parse::<f64>()
                        .ok()
                        .filter(|r| r.is_finite() && *r >= 0.0)
                        .ok_or_else(|| format!("--upset-rate must be >= 0, got '{v}'"))?;
                }
                "--format" => format = GridFormat::parse(value("--format")?)?,
                "--jobs" => {
                    let v = value("--jobs")?;
                    jobs = v
                        .parse::<usize>()
                        .ok()
                        .filter(|&n| n > 0)
                        .ok_or_else(|| format!("--jobs must be a positive integer, got '{v}'"))?;
                }
                "--stats" => stats = true,
                "--resume" => resume = true,
                "--shard" => shard = Some(ShardSpec::parse(value("--shard")?)?),
                "--merge" => merge = Some(parse_merge(value("--merge")?)?),
                "--retries" => policy.retries = parse_retries(value("--retries")?)?,
                "--backoff-ms" => {
                    policy.backoff_cap_ms = parse_ms("--backoff-ms", value("--backoff-ms")?)?
                }
                "--timeout-ms" => {
                    policy.timeout_ms = Some(parse_ms("--timeout-ms", value("--timeout-ms")?)?)
                }
                other => return Err(format!("unknown flag '{other}'")),
            }
        }
        if merge.is_some() && (shard.is_some() || resume) {
            return Err("--merge reassembles existing shard journals; it conflicts with --shard and --resume".into());
        }
        for &r in &rates {
            if r * duration_s > MAX_EXPECTED_EVENTS {
                return Err(format!(
                    "rate {r} events/s over {duration_s} s expands to > {MAX_EXPECTED_EVENTS:e} \
                     events; shorten --duration-s or lower --rates"
                ));
            }
        }
        let (kernel, scenario) = crate::faults::cli::parse_kernel(&kernel_tok, cores)?;
        Ok(LifecycleCmd {
            kernel,
            scenario,
            cores,
            seed,
            duration_s,
            true_fraction,
            rates,
            duties,
            sleeps,
            boots,
            image_kb,
            battery_mah,
            upset_rate,
            format,
            jobs,
            stats,
            resume,
            shard,
            merge,
            policy,
        })
    }

    /// The grid's cells in render order: rate-major, then duty, sleep,
    /// boot. Every cell of one rate replays the identical trace — the
    /// policies are compared against the same stimulus.
    pub fn cells(&self) -> Vec<LifecycleScenario> {
        let mut v = Vec::with_capacity(
            self.rates.len() * self.duties.len() * self.sleeps.len() * self.boots.len(),
        );
        for &rate_hz in &self.rates {
            for &duty in &self.duties {
                for &sleep in &self.sleeps {
                    for &boot in &self.boots {
                        v.push(LifecycleScenario {
                            scenario: self.scenario,
                            trace: TraceSpec {
                                seed: self.seed,
                                duration_s: self.duration_s,
                                rate_hz,
                                true_fraction: self.true_fraction,
                            },
                            sleep,
                            boot,
                            duty,
                            image_bytes: self.image_kb * 1024,
                            battery_mah: self.battery_mah,
                            upset_rate: self.upset_rate,
                        });
                    }
                }
            }
        }
        v
    }
}

const COLUMNS: [&str; 24] = [
    "kernel",
    "cores",
    "seed",
    "rate",
    "sleep",
    "boot",
    "duty",
    "events",
    "true_wakes",
    "false_wakes",
    "absorbed",
    "boots",
    "mram_restores",
    "sleep_s",
    "classify_s",
    "active_s",
    "avg_power_uw",
    "energy_per_event_uj",
    "false_wake_rate",
    "battery_hours",
    "cwu_accuracy",
    "mram_silent",
    "diverged",
    "status",
];

/// One rendered grid row: the cell's coordinates plus either its report
/// or the cell's structured error.
struct Row<'a> {
    cmd: &'a LifecycleCmd,
    lc: LifecycleScenario,
    cell: Result<LifecycleReport, String>,
}

impl Row<'_> {
    fn cells(&self) -> [String; 24] {
        let mut out: [String; 24] = Default::default();
        out[0] = self.cmd.kernel.to_string();
        out[1] = self.cmd.cores.to_string();
        out[2] = self.cmd.seed.to_string();
        out[3] = format!("{:e}", self.lc.trace.rate_hz);
        out[4] = self.lc.sleep.label().to_string();
        out[5] = self.lc.boot.label().to_string();
        out[6] = self.lc.duty.label().to_string();
        match &self.cell {
            Ok(r) => {
                for (i, v) in [
                    r.events,
                    r.true_wakes,
                    r.false_wakes,
                    r.absorbed_events,
                    r.boots,
                    r.mram_restores,
                ]
                .into_iter()
                .enumerate()
                {
                    out[7 + i] = v.to_string();
                }
                out[13] = format!("{:.3}", r.sleep_s);
                out[14] = format!("{:.3}", r.classify_s);
                out[15] = format!("{:.6}", r.wake_s + r.triage_s + r.infer_s);
                out[16] = format!("{:.3}", r.avg_power_w * 1e6);
                out[17] = format!("{:.3}", r.energy_per_event_j * 1e6);
                out[18] = format!("{:.4}", r.false_wake_rate);
                out[19] = format!("{:.1}", r.battery_hours);
                out[20] = format!("{:.3}", r.cwu_accuracy);
                out[21] = r.mram_silent.to_string();
                out[22] = if r.diverged { "1" } else { "0" }.to_string();
                out[23] = "ok".to_string();
            }
            // Errored cell: coordinates + status only, numerics blank —
            // unmistakable for an all-asleep row.
            Err(msg) => out[23] = sanitize_cell(msg),
        }
        out
    }
}

/// The journal identity of a lifecycle grid: kind, the parameters that
/// shape the rendered bytes, and each cell's versioned key in grid
/// order. The cell keys embed [`super::LIFECYCLE_MODEL_VERSION`] plus
/// every deployment axis, so a model bump orphans old journals along
/// with old `.lfc` entries.
pub fn grid_key(cmd: &LifecycleCmd) -> u64 {
    let params = [
        format!("kernel={}", cmd.kernel),
        format!("cores={}", cmd.cores),
        format!("format={}", cmd.format.name()),
    ];
    let params: Vec<&str> = params.iter().map(String::as_str).collect();
    let ids: Vec<String> = cmd.cells().iter().map(LifecycleScenario::key).collect();
    journal::grid_key("lifecycle", &params, &ids)
}

/// Render `cmd`'s grid through `eng`. The returned string ends in
/// exactly one newline and is byte-identical for any `--jobs`.
pub fn render(eng: &SweepEngine, cmd: &LifecycleCmd) -> String {
    render_with(eng, cmd, &GridSession::off()).text
}

/// As [`render`], but through a [`GridSession`]: journaled prior cells
/// replay, shard-unowned cells emit no rows, and the returned
/// [`RenderedGrid`] carries the failed/skipped counts the CLI's exit
/// code needs.
pub fn render_with(eng: &SweepEngine, cmd: &LifecycleCmd, session: &GridSession) -> RenderedGrid {
    let grid = cmd.cells();
    let cells = eng.run_lifecycles_with(&grid, session);
    let mut failed = 0;
    let mut skipped = 0;
    let rows: Vec<Row> = grid
        .iter()
        .zip(cells)
        .filter_map(|(lc, cell)| match cell {
            None => {
                skipped += 1;
                None
            }
            Some(cell) => {
                if cell.is_err() {
                    failed += 1;
                }
                Some(Row { cmd, lc: *lc, cell: cell.map_err(|e| e.message) })
            }
        })
        .collect();
    let text = match cmd.format {
        GridFormat::Csv => render_csv(&rows),
        GridFormat::Markdown => render_md(&rows),
        GridFormat::Json => render_json(cmd, &rows),
    };
    RenderedGrid { text, failed, skipped }
}

fn render_csv(rows: &[Row]) -> String {
    let mut out = COLUMNS.join(",");
    out.push('\n');
    for r in rows {
        out.push_str(&r.cells().join(","));
        out.push('\n');
    }
    out
}

fn render_md(rows: &[Row]) -> String {
    let mut out = format!("| {} |\n", COLUMNS.join(" | "));
    out.push_str(&format!("|{}\n", "---:|".repeat(COLUMNS.len())));
    for r in rows {
        out.push_str(&format!("| {} |\n", r.cells().join(" | ")));
    }
    out
}

fn render_json(cmd: &LifecycleCmd, rows: &[Row]) -> String {
    let rates: Vec<String> = cmd.rates.iter().map(|r| format!("{r:e}")).collect();
    let mut out = format!(
        "{{\n  \"grid\": {{\"kernel\": \"{}\", \"cores\": {}, \"seed\": {}, \
         \"duration_s\": {:.1}, \"rates\": [{}]}},\n  \"rows\": [\n",
        cmd.kernel,
        cmd.cores,
        cmd.seed,
        cmd.duration_s,
        rates.join(", ")
    );
    for (i, r) in rows.iter().enumerate() {
        let cells = r.cells();
        out.push_str(&format!(
            "    {{\"rate\": {}, \"sleep\": \"{}\", \"boot\": \"{}\", \"duty\": \"{}\", ",
            cells[3], cells[4], cells[5], cells[6]
        ));
        match &r.cell {
            Ok(r) => {
                for (name, cell) in COLUMNS.iter().zip(cells.iter()).skip(7).take(14) {
                    out.push_str(&format!("\"{name}\": {cell}, "));
                }
                out.push_str(&format!(
                    "\"mram_silent\": {}, \"diverged\": {}, \"status\": \"ok\"}}",
                    r.mram_silent,
                    if r.diverged { "true" } else { "false" }
                ));
            }
            Err(_) => out.push_str(&format!("\"status\": \"{}\"}}", cells[23])),
        }
        out.push_str(if i + 1 == rows.len() { "\n" } else { ",\n" });
    }
    out.push_str("  ]\n}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::fp_matmul::FpWidth;

    fn argv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parse_round_trips_the_acceptance_invocation() {
        let cmd = LifecycleCmd::parse(&argv(&[
            "--kernel",
            "matmul-f32",
            "--cores",
            "8",
            "--seed",
            "7",
            "--duration-s",
            "86400",
            "--true-fraction",
            "0.3",
            "--rates",
            "0.01,0.1",
            "--duty",
            "eager,linger",
            "--sleep",
            "cognitive,retentive",
            "--boot",
            "l2,mram",
            "--image-kb",
            "512",
            "--battery-mah",
            "100",
            "--format",
            "csv",
        ]))
        .unwrap();
        assert_eq!(cmd.kernel, "matmul-f32");
        assert_eq!(cmd.scenario, Scenario::FpMatmul { w: FpWidth::F32, cores: 8 });
        assert_eq!(cmd.rates, vec![0.01, 0.1]);
        assert_eq!(cmd.image_kb, 512);
        assert_eq!(cmd.cells().len(), 16, "2 rates x 2 duties x 2 sleeps x 2 boots");
        // Rate-major order; boot is the minor axis.
        let cells = cmd.cells();
        assert_eq!(cells[0].trace.rate_hz, 0.01);
        assert_eq!(cells[0].boot, BootKind::WarmL2);
        assert_eq!(cells[1].boot, BootKind::MramRestore);
        assert_eq!(cells[8].trace.rate_hz, 0.1);
        assert!(LifecycleCmd::parse(&argv(&["--kernel", "bogus"])).is_err());
        assert!(LifecycleCmd::parse(&argv(&["--duration-s", "0"])).is_err());
        assert!(LifecycleCmd::parse(&argv(&["--duration-s", "nan"])).is_err());
        assert!(LifecycleCmd::parse(&argv(&["--true-fraction", "1.5"])).is_err());
        assert!(LifecycleCmd::parse(&argv(&["--rates", "-1"])).is_err());
        assert!(LifecycleCmd::parse(&argv(&["--duty", "lazy"])).is_err());
        assert!(LifecycleCmd::parse(&argv(&["--sleep", "rem"])).is_err());
        assert!(LifecycleCmd::parse(&argv(&["--boot", "cold"])).is_err());
        assert!(LifecycleCmd::parse(&argv(&["--image-kb", "2048"])).is_err());
        assert!(LifecycleCmd::parse(&argv(&["--cores", "10"])).is_err());
        assert!(LifecycleCmd::parse(&argv(&["--frobnicate"])).is_err());
        // λ guard: 10 events/s for 1e7 s would expand 1e8 events.
        assert!(LifecycleCmd::parse(&argv(&["--duration-s", "1e7", "--rates", "10"])).is_err());
    }

    #[test]
    fn csv_grid_renders_and_balances_wake_counts() {
        let cmd = LifecycleCmd::parse(&argv(&[
            "--kernel",
            "matmul-i8",
            "--cores",
            "2",
            "--seed",
            "3",
            "--duration-s",
            "600",
            "--rates",
            "0.05",
            "--sleep",
            "retentive",
            "--boot",
            "l2,mram",
        ]))
        .unwrap();
        let eng = SweepEngine::serial();
        let out = render(&eng, &cmd);
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines.len(), 1 + 2);
        assert_eq!(lines[0], COLUMNS.join(","));
        for line in &lines[1..] {
            assert!(line.starts_with("matmul-i8,2,3,5e-2,retentive,"));
            assert!(line.ends_with(",ok"));
            assert_eq!(line.split(',').count(), COLUMNS.len());
            assert!(line.split(',').all(|c| !c.is_empty()));
            // The CI invariant, asserted at the source: true + false == events.
            let f: Vec<&str> = line.split(',').collect();
            let events: u64 = f[7].parse().unwrap();
            let tw: u64 = f[8].parse().unwrap();
            let fw: u64 = f[9].parse().unwrap();
            assert_eq!(tw + fw, events);
        }
    }

    #[test]
    fn parse_handles_resume_shard_merge_and_policy() {
        let cmd = LifecycleCmd::parse(&argv(&["--resume", "--shard", "1/2", "--timeout-ms", "0"]))
            .unwrap();
        assert!(cmd.resume);
        assert_eq!(cmd.shard, Some(ShardSpec { index: 1, total: 2 }));
        assert_eq!(cmd.policy.timeout_ms, Some(0));
        assert!(LifecycleCmd::parse(&argv(&["--merge", "2", "--resume"])).is_err());
        assert!(LifecycleCmd::parse(&argv(&["--merge", "2", "--shard", "0/2"])).is_err());
        assert!(LifecycleCmd::parse(&argv(&["--shard", "0/2"])).is_err());
    }

    #[test]
    fn lifecycle_grid_key_tracks_every_axis() {
        let base = argv(&["--kernel", "matmul-i8", "--rates", "0.1", "--duration-s", "600"]);
        let k = grid_key(&LifecycleCmd::parse(&base).unwrap());
        assert_eq!(k, grid_key(&LifecycleCmd::parse(&base).unwrap()), "deterministic");
        for delta in [
            argv(&["--kernel", "matmul-i16", "--rates", "0.1", "--duration-s", "600"]),
            argv(&["--kernel", "matmul-i8", "--rates", "0.2", "--duration-s", "600"]),
            argv(&["--kernel", "matmul-i8", "--rates", "0.1", "--duration-s", "601"]),
            argv(&["--kernel", "matmul-i8", "--rates", "0.1", "--duration-s", "600", "--seed", "2"]),
            argv(&["--kernel", "matmul-i8", "--rates", "0.1", "--duration-s", "600", "--duty", "linger"]),
            argv(&["--kernel", "matmul-i8", "--rates", "0.1", "--duration-s", "600", "--sleep", "cognitive"]),
            argv(&["--kernel", "matmul-i8", "--rates", "0.1", "--duration-s", "600", "--boot", "l2"]),
            argv(&["--kernel", "matmul-i8", "--rates", "0.1", "--duration-s", "600", "--image-kb", "128"]),
            argv(&["--kernel", "matmul-i8", "--rates", "0.1", "--duration-s", "600", "--battery-mah", "100"]),
            argv(&["--kernel", "matmul-i8", "--rates", "0.1", "--duration-s", "600", "--upset-rate", "1e-4"]),
            argv(&["--kernel", "matmul-i8", "--rates", "0.1", "--duration-s", "600", "--format", "md"]),
            argv(&["--kernel", "matmul-i8", "--rates", "0.1", "--duration-s", "600", "--true-fraction", "0.4"]),
        ] {
            assert_ne!(k, grid_key(&LifecycleCmd::parse(&delta).unwrap()), "{delta:?}");
        }
    }
}
