//! Seeded sensor-event traces: the stimulus side of the lifecycle
//! engine.
//!
//! A [`TraceSpec`] describes a trace as *rates* — a mean event rate over
//! a duration, and a true-positive fraction — and expands it, via the
//! repo's own xorshift [`Rng`], into an exact time-ordered
//! [`SensorEvent`] list. The discipline is [`crate::faults::FaultPlan`]'s
//! flip-list expansion verbatim: the expected count λ = rate × duration
//! rounds stochastically (⌊λ⌋ plus one Bernoulli draw on the fraction),
//! every event draws its arrival time and truth label from the same
//! salted stream, and the list sorts by arrival time — so the whole
//! trace is replayable from the seed alone, on any machine, at any
//! `--jobs`, and its parameters serialize bit-exactly into the
//! lifecycle cache key.

use crate::common::Rng;

/// Salt XORed into the trace seed so the event stream is independent of
/// any fault-plan stream derived from the same campaign seed
/// (`b"EVNT"` as a little-endian u32, the `faults::plan` convention).
const SALT_EVENTS: u64 = 0x4556_4E54;

/// One sensor event of a trace.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SensorEvent {
    /// Arrival time in seconds from trace start, in `[0, duration_s)`.
    pub at_s: f64,
    /// Whether the event is a true positive (worth a cluster inference)
    /// or a false positive (noise the wake-up path must absorb).
    pub is_true: bool,
}

/// A seeded sensor-event trace, described by rates and expanded on
/// demand ([`TraceSpec::expand`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceSpec {
    /// Expansion seed — the whole trace derives from it.
    pub seed: u64,
    /// Simulated wall-clock duration in seconds.
    pub duration_s: f64,
    /// Mean sensor-event rate in events per second.
    pub rate_hz: f64,
    /// Probability that an event is a true positive, in `[0, 1]`.
    pub true_fraction: f64,
}

impl TraceSpec {
    /// Expand the spec into its exact, time-ordered event list.
    pub fn expand(&self) -> Vec<SensorEvent> {
        let mut rng = Rng::new(self.seed ^ SALT_EVENTS);
        let lambda = (self.rate_hz * self.duration_s).max(0.0);
        let count = lambda as u64 + u64::from(rng.f64() < lambda.fract());
        let mut events: Vec<SensorEvent> = (0..count)
            .map(|_| SensorEvent {
                at_s: rng.f64() * self.duration_s,
                is_true: rng.f64() < self.true_fraction,
            })
            .collect();
        events.sort_by(|a, b| a.at_s.partial_cmp(&b.at_s).expect("finite event times"));
        events
    }

    /// Bit-exact parameter fragment for the lifecycle cache key (the
    /// [`crate::faults::FaultPlan::key_fragment`] discipline: every f64
    /// as its `to_bits` hex, so no formatting ambiguity ever aliases two
    /// different traces).
    pub fn key_fragment(&self) -> String {
        format!(
            "seed={:016x}|dur={:016x}|rate={:016x}|tp={:016x}",
            self.seed,
            self.duration_s.to_bits(),
            self.rate_hz.to_bits(),
            self.true_fraction.to_bits()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> TraceSpec {
        TraceSpec { seed: 7, duration_s: 3600.0, rate_hz: 0.05, true_fraction: 0.3 }
    }

    #[test]
    fn expansion_is_deterministic() {
        let a = spec().expand();
        let b = spec().expand();
        assert_eq!(a, b, "same seed, same trace — bit-exact");
        assert!(!a.is_empty());
        let c = TraceSpec { seed: 8, ..spec() }.expand();
        assert_ne!(a, c, "a different seed draws a different trace");
    }

    #[test]
    fn events_stay_in_bounds_and_time_ordered() {
        let events = spec().expand();
        for w in events.windows(2) {
            assert!(w[0].at_s <= w[1].at_s, "events sort by arrival time");
        }
        for e in &events {
            assert!(e.at_s >= 0.0 && e.at_s < 3600.0, "event at {} out of range", e.at_s);
        }
    }

    #[test]
    fn count_is_floor_or_ceil_of_lambda() {
        // λ = 0.05/s × 3600 s = 180 exactly; fractional λ rounds to one
        // of its two neighbours, per seed.
        assert_eq!(spec().expand().len(), 180);
        for seed in 0..32 {
            let s = TraceSpec { seed, duration_s: 100.0, rate_hz: 0.125, true_fraction: 0.5 };
            let n = s.expand().len();
            assert!(n == 12 || n == 13, "λ=12.5 must expand to 12 or 13, got {n}");
        }
    }

    #[test]
    fn true_fraction_shapes_the_label_mix() {
        let all_false =
            TraceSpec { seed: 3, duration_s: 1e4, rate_hz: 0.1, true_fraction: 0.0 }.expand();
        assert!(all_false.iter().all(|e| !e.is_true));
        let all_true =
            TraceSpec { seed: 3, duration_s: 1e4, rate_hz: 0.1, true_fraction: 1.0 }.expand();
        assert!(all_true.iter().all(|e| e.is_true));
        let mixed =
            TraceSpec { seed: 3, duration_s: 1e4, rate_hz: 0.1, true_fraction: 0.5 }.expand();
        let trues = mixed.iter().filter(|e| e.is_true).count();
        assert!(trues > 0 && trues < mixed.len(), "a 0.5 mix has both labels");
    }

    #[test]
    fn empty_trace_expands_to_no_events() {
        let s = TraceSpec { seed: 1, duration_s: 10.0, rate_hz: 0.0, true_fraction: 0.5 };
        assert!(s.expand().is_empty());
    }

    #[test]
    fn key_fragment_is_bit_exact() {
        let s = spec();
        assert_eq!(
            s.key_fragment(),
            format!(
                "seed=0000000000000007|dur={:016x}|rate={:016x}|tp={:016x}",
                3600.0f64.to_bits(),
                0.05f64.to_bits(),
                0.3f64.to_bits()
            )
        );
        assert_ne!(s.key_fragment(), TraceSpec { rate_hz: 0.051, ..s }.key_fragment());
    }
}
