//! `vega` — the coordinator CLI.
//!
//! ```text
//! vega list                 list reproduction ids
//! vega repro <id>|all [--jobs N] [--stats]
//!                           regenerate a paper table/figure through the
//!                           sweep engine (N workers; output is byte-
//!                           identical for any N — default VEGA_JOBS or
//!                           the machine's parallelism); --stats prints
//!                           the kernel- and network-cache counters
//!                           (memory + both on-disk tiers) and the
//!                           superblock replay hit/bail counters to
//!                           stderr
//! vega sweep [--cores 1..9] [--precision int8,fp16,...]
//!            [--dvfs-steps N] [--format csv|md|json] [--jobs N] [--stats]
//!            [--resume] [--shard I/N] [--merge N]
//!            [--retries K] [--backoff-ms B] [--timeout-ms T]
//!                           render a user-defined design-space grid
//!                           (cores × precision × DVFS) beyond the
//!                           paper's tables; one simulation per cell,
//!                           DVFS rows derived analytically
//! vega faults [--kernel K] [--cores N] [--seeds a,b] [--rates r1,r2]
//!             [--tiers mram,l2,tcdm] [--sleep-s S]
//!             [--format csv|md|json] [--jobs N] [--stats]
//!             [--resume] [--shard I/N] [--merge N]
//!             [--retries K] [--backoff-ms B] [--timeout-ms T]
//!                           run a seeded bit-upset campaign grid
//!                           (seeds × upset rates × tier mask) over one
//!                           kernel and report SECDED coverage: per-tier
//!                           corrected/detected/silent/masked counts and
//!                           output divergence vs the fault-free oracle
//! vega lifecycle [--kernel K] [--cores N] [--seed S] [--duration-s D]
//!                [--true-fraction F] [--rates r1,r2] [--duty eager,linger]
//!                [--sleep cognitive,retentive] [--boot l2,mram]
//!                [--image-kb KB] [--battery-mah MAH] [--upset-rate R]
//!                [--format csv|md|json] [--jobs N] [--stats]
//!                [--resume] [--shard I/N] [--merge N]
//!                [--retries K] [--backoff-ms B] [--timeout-ms T]
//!                           replay a seeded sensor-event trace through
//!                           Fig. 7's sleep↔wake state machine over a
//!                           rate × duty × sleep × boot grid and report
//!                           battery lifetime, false-wake rate and
//!                           per-state energy per cell
//! vega verify [kernel|all]  statically analyze every shipped kernel
//!                           program (CFG, reaching definitions, memory
//!                           map bounds/alignment, loop shape) and exit
//!                           non-zero on any error-severity finding;
//!                           a kernel name substring narrows the run and
//!                           also prints the info-level notes
//!                           (superblock candidates, trip counts)
//! vega runtime              show the PJRT artifact registry
//! vega golden <name>        run one artifact and cross-check the
//!                           simulator's functional model against it
//! vega sim <kernel> [--cores N] [--size S]
//!                           run a kernel on the simulated cluster and
//!                           report cycles / rates / contention
//! ```
//!
//! `repro`, `sweep`, `faults` and `lifecycle` run on a *persistent*
//! engine: kernel simulations, DNN network reports, fault-campaign
//! outcomes and lifecycle reports land in the on-disk cache
//! (`$VEGA_CACHE_DIR`, default `target/vega-cache`), so a re-invocation
//! of the same grid or report serves everything from disk.
//! `VEGA_CACHE=off|0|false|no`
//! (case-insensitive) disables persistence — see
//! `sweep::persist::DiskStore::open_default`. `VEGA_SUPERBLOCKS=off`
//! (same spellings) disables the ISS superblock replay tier — results
//! are bit-identical either way (see PERFORMANCE.md), only wall-clock
//! changes. (Hand-rolled argument parsing: clap is unavailable offline,
//! DESIGN.md §5.)
//!
//! Crash safety (ISSUE 7): every `sweep`/`faults`/`lifecycle` grid run
//! journals one checksummed record per completed cell under
//! `<cache-dir>/journals/`, keyed by the full grid; `--resume` replays
//! the journal and skips
//! completed cells (output byte-identical to an uninterrupted run),
//! `--shard I/N` owns one deterministic slice of the grid, and
//! `--merge N` reassembles the shard journals into the serial-order
//! report. Grids always run to completion (keep-going semantics) but
//! exit 3 when any cell ended in `error`/`timeout`, so CI cannot green
//! a half-failed grid; exit 2 stays "usage error" and exit 1 "unknown
//! id / environment failure".

use vega::bench;
use vega::runtime::{Runtime, Tensor};
use vega::sweep::{GridMode, GridSession, SweepEngine};

fn usage() -> ! {
    eprintln!(
        "usage: vega <command>\n\
         commands:\n\
           list                 list reproduction ids\n\
           repro <id>|all [--jobs N] [--stats]\n\
                                regenerate a paper table/figure\n\
           sweep [--cores 1..9] [--precision int8,fp16,...]\n\
                 [--dvfs-steps N] [--format csv|md|json] [--jobs N] [--stats]\n\
                 [--resume] [--shard I/N] [--merge N]\n\
                 [--retries K] [--backoff-ms B] [--timeout-ms T]\n\
                                render a custom design-space grid\n\
           faults [--kernel K] [--cores N] [--seeds a,b] [--rates r1,r2]\n\
                  [--tiers mram,l2,tcdm] [--sleep-s S]\n\
                  [--format csv|md|json] [--jobs N] [--stats]\n\
                  [--resume] [--shard I/N] [--merge N]\n\
                  [--retries K] [--backoff-ms B] [--timeout-ms T]\n\
                                seeded bit-upset campaigns through SECDED\n\
           lifecycle [--kernel K] [--cores N] [--seed S] [--duration-s D]\n\
                     [--true-fraction F] [--rates r1,r2]\n\
                     [--duty eager,linger] [--sleep cognitive,retentive]\n\
                     [--boot l2,mram] [--image-kb KB] [--battery-mah MAH]\n\
                     [--upset-rate R] [--format csv|md|json] [--jobs N]\n\
                     [--stats] [--resume] [--shard I/N] [--merge N]\n\
                     [--retries K] [--backoff-ms B] [--timeout-ms T]\n\
                                trace-driven sleep<->wake duty cycling:\n\
                                battery lifetime / false-wake rate grid\n\
           verify [kernel|all]  static CFG/dataflow/memory-map analysis\n\
                                over every shipped kernel program; exits\n\
                                non-zero on error-severity findings\n\
           runtime              show the PJRT artifact registry\n\
           golden <artifact>    cross-check simulator vs PJRT artifact\n\
           sim <kernel> [--cores N] [--size S]\n\
                                kernels: matmul-i8|matmul-i16|matmul-i32|\n\
                                matmul-f32|matmul-f16|matmul-f8|fft|MATMUL|\n\
                                CONV|DWT|FFT|FIR|IIR|KMEANS|SVM"
    );
    std::process::exit(2);
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("list") => {
            for id in bench::ALL_WITH_FIG11 {
                println!("{id}");
            }
        }
        Some("repro") => {
            let id = args.get(1).map(String::as_str).unwrap_or_else(|| usage());
            let mut jobs = vega::sweep::default_jobs();
            let mut stats = false;
            let mut it = args[2..].iter();
            while let Some(a) = it.next() {
                match a.as_str() {
                    "--jobs" => {
                        jobs = it.next().and_then(|v| v.parse().ok()).unwrap_or_else(|| usage())
                    }
                    "--stats" => stats = true,
                    _ => usage(),
                }
            }
            let eng = SweepEngine::persistent(jobs);
            if id == "all" {
                for report in bench::run_many(&bench::ALL_WITH_FIG11, &eng) {
                    println!("{}", report.expect("known id"));
                }
            } else {
                match bench::run_with(id, &eng) {
                    Some(report) => println!("{report}"),
                    None => {
                        eprintln!("unknown reproduction id '{id}' (try `vega list`)");
                        std::process::exit(1);
                    }
                }
            }
            if stats {
                let (sh, sm) = eng.cache().counters();
                let (nh, nm) = eng.network_counters();
                let we = eng.disk_write_errors().unwrap_or((0, 0, 0, 0));
                eprintln!(
                    "repro stats: sims: {sh} hits / {sm} misses; nets: {nh} hits / {nm} misses; \
                     disk(sim): {}; disk(net): {}",
                    fmt_disk(eng.disk_counters(), we.0),
                    fmt_disk(eng.disk_net_counters(), we.1),
                );
                let (sbh, sbb, sbi) = vega::iss::superblock::counters();
                eprintln!(
                    "superblocks: {sbh} windows replayed / {sbb} bails / \
                     {sbi} loop iterations batched"
                );
            }
        }
        Some("sweep") => {
            let cmd = vega::sweep::explore::SweepCmd::parse(&args[1..]).unwrap_or_else(|e| {
                eprintln!("vega sweep: {e}");
                std::process::exit(2);
            });
            let mut eng = SweepEngine::persistent(cmd.jobs);
            eng.set_cell_policy(cmd.policy);
            let session = GridSession::open(
                "sweep",
                vega::sweep::explore::grid_key(&cmd.spec),
                cmd.shard,
                grid_mode(cmd.merge, cmd.resume),
                &vega::sweep::journal::default_root(),
            );
            let grid = vega::sweep::explore::render_with(&eng, &cmd.spec, &session);
            print!("{}", grid.text);
            if cmd.stats {
                let (h, m) = eng.cache().counters();
                let we = eng.disk_write_errors().unwrap_or((0, 0, 0, 0));
                eprintln!(
                    "sweep stats: rows={} sims: {h} hits / {m} misses; disk: {}; journal: {}",
                    cmd.spec.rows(),
                    fmt_disk(eng.disk_counters(), we.0),
                    fmt_journal(&session),
                );
            }
            exit_for_grid("sweep", &grid);
        }
        Some("faults") => {
            let cmd = vega::faults::FaultsCmd::parse(&args[1..]).unwrap_or_else(|e| {
                eprintln!("vega faults: {e}");
                std::process::exit(2);
            });
            let mut eng = SweepEngine::persistent(cmd.jobs);
            eng.set_cell_policy(cmd.policy);
            let session = GridSession::open(
                "faults",
                vega::faults::cli::grid_key(&cmd),
                cmd.shard,
                grid_mode(cmd.merge, cmd.resume),
                &vega::sweep::journal::default_root(),
            );
            let grid = vega::faults::cli::render_with(&eng, &cmd, &session);
            print!("{}", grid.text);
            if cmd.stats {
                let (h, m) = eng.fault_counters();
                let we = eng.disk_write_errors().unwrap_or((0, 0, 0, 0));
                eprintln!(
                    "faults stats: cells={} campaigns: {h} hits / {m} misses; disk(flt): {}; \
                     journal: {}",
                    cmd.seeds.len() * cmd.rates.len(),
                    fmt_disk(eng.disk_fault_counters(), we.2),
                    fmt_journal(&session),
                );
            }
            exit_for_grid("faults", &grid);
        }
        Some("lifecycle") => {
            let cmd = vega::lifecycle::LifecycleCmd::parse(&args[1..]).unwrap_or_else(|e| {
                eprintln!("vega lifecycle: {e}");
                std::process::exit(2);
            });
            let mut eng = SweepEngine::persistent(cmd.jobs);
            eng.set_cell_policy(cmd.policy);
            let session = GridSession::open(
                "lifecycle",
                vega::lifecycle::grid_key(&cmd),
                cmd.shard,
                grid_mode(cmd.merge, cmd.resume),
                &vega::sweep::journal::default_root(),
            );
            let grid = vega::lifecycle::render_with(&eng, &cmd, &session);
            print!("{}", grid.text);
            if cmd.stats {
                let (h, m) = eng.lifecycle_counters();
                let we = eng.disk_write_errors().unwrap_or((0, 0, 0, 0));
                eprintln!(
                    "lifecycle stats: cells={} reports: {h} hits / {m} misses; disk(lfc): {}; \
                     journal: {}",
                    cmd.rates.len() * cmd.duties.len() * cmd.sleeps.len() * cmd.boots.len(),
                    fmt_disk(eng.disk_lifecycle_counters(), we.3),
                    fmt_journal(&session),
                );
            }
            exit_for_grid("lifecycle", &grid);
        }
        Some("verify") => {
            let which = args.get(1).map(String::as_str).unwrap_or("all");
            if which.starts_with('-') || args.len() > 2 {
                usage();
            }
            run_verify(which);
        }
        Some("runtime") => {
            let rt = Runtime::load(Runtime::default_dir()).unwrap_or_else(|e| {
                eprintln!("failed to load artifacts (run `make artifacts`): {e}");
                std::process::exit(1);
            });
            println!("platform: {}", rt.platform());
            for sig in &rt.manifest().entries {
                println!("  {sig:?}");
            }
        }
        Some("golden") => {
            let name = args.get(1).map(String::as_str).unwrap_or("matmul_int8_64");
            match golden_check(name) {
                Ok(msg) => println!("{msg}"),
                Err(e) => {
                    eprintln!("golden check failed: {e}");
                    std::process::exit(1);
                }
            }
        }
        Some("sim") => {
            let kernel = args.get(1).map(String::as_str).unwrap_or_else(|| usage());
            let mut cores = 8usize;
            let mut size = 64usize;
            let mut it = args[2..].iter();
            while let Some(a) = it.next() {
                match a.as_str() {
                    "--cores" => {
                        cores = it.next().and_then(|v| v.parse().ok()).unwrap_or_else(|| usage())
                    }
                    "--size" => {
                        size = it.next().and_then(|v| v.parse().ok()).unwrap_or_else(|| usage())
                    }
                    _ => usage(),
                }
            }
            run_sim(kernel, cores, size);
        }
        _ => usage(),
    }
}

/// Render one disk-tier counter triple (plus its write-error count) for
/// the `--stats` lines.
fn fmt_disk(counters: Option<(u64, u64, u64)>, write_errors: u64) -> String {
    match counters {
        Some((h, m, w)) => {
            format!("{h} hits / {m} misses / {w} writes / {write_errors} write-errors")
        }
        None => "off".into(),
    }
}

/// Render a grid session's journal counters for the `--stats` lines.
fn fmt_journal(session: &GridSession) -> String {
    format!(
        "{} prior / {} recorded / {} write-errors",
        session.prior_count(),
        session.recorded(),
        session.write_errors()
    )
}

/// Map the CLI's `--merge`/`--resume` flags onto a journal mode (the
/// parser already rejected conflicting combinations).
fn grid_mode(merge: Option<u32>, resume: bool) -> GridMode {
    match (merge, resume) {
        (Some(n), _) => GridMode::Merge(n),
        (None, true) => GridMode::Resume,
        (None, false) => GridMode::Fresh,
    }
}

/// Keep-going exit semantics (ISSUE 7): the grid always renders to
/// completion, but a run whose cells include an `error`/`timeout` exits
/// 3 so CI cannot green a half-failed grid.
fn exit_for_grid(what: &str, grid: &vega::sweep::explore::RenderedGrid) {
    if grid.failed > 0 {
        eprintln!(
            "vega {what}: {} cell(s) ended in error/timeout (grid completed; \
             rerun without --resume to retry them)",
            grid.failed
        );
        std::process::exit(3);
    }
}

/// `vega verify`: run the static verifier (ISSUE 9) over the canonical
/// kernel suite — one analysis per (program, core entry state) — and
/// exit 1 if any error-severity finding survives.
///
/// All cores of a target run the same program, so the per-target header
/// reports core 0's CFG shape; findings are deduplicated across cores
/// (core-dependent entry pointers can resolve to different addresses,
/// so distinct diagnostics per core are possible and all shown).
fn run_verify(which: &str) {
    use std::collections::BTreeSet;
    use vega::isa::analyze::Severity;

    let all = vega::sweep::verify_targets();
    let (targets, show_info): (Vec<_>, bool) = if which == "all" {
        (all, false)
    } else {
        let sel: Vec<_> = all.into_iter().filter(|t| t.name.contains(which)).collect();
        if sel.is_empty() {
            eprintln!("vega verify: no kernel program matches '{which}' (try `vega verify all`)");
            std::process::exit(1);
        }
        (sel, true)
    };
    let mut total_errors = 0usize;
    for t in &targets {
        let reports = t.analyze_all();
        let (mut errors, mut warnings, mut notes) = (0, 0, 0);
        for r in &reports {
            errors += r.count(Severity::Error);
            warnings += r.count(Severity::Warning);
            notes += r.count(Severity::Info);
        }
        println!(
            "{:<16} {} cores  {:>3} insts  {:>2} blocks  {} loops  \
             {errors} errors  {warnings} warnings  {notes} notes",
            t.name,
            t.n_cores,
            t.prog.insts.len(),
            reports[0].n_blocks,
            reports[0].n_loops,
        );
        let mut shown = BTreeSet::new();
        for (core, r) in reports.iter().enumerate() {
            for f in &r.findings {
                if f.severity == Severity::Info && !show_info {
                    continue;
                }
                if shown.insert(f.to_string()) {
                    println!("    core {core}: {f}");
                }
            }
        }
        total_errors += errors;
    }
    println!("verify: {} program(s), {total_errors} error-severity finding(s)", targets.len());
    if total_errors > 0 {
        std::process::exit(1);
    }
}

/// `vega sim`: run one kernel on the simulated cluster and report the
/// microarchitectural outcome (the downstream-user profiling tool).
fn run_sim(kernel: &str, cores: usize, size: usize) {
    use vega::cluster::{Cluster, L2_BASE};
    use vega::common::Rng;
    use vega::iss::FlatMem;
    use vega::kernels::fp_matmul::{self, FpWidth};
    use vega::kernels::int_matmul::{self, IntWidth};

    let mut rng = Rng::new(0x51A1);
    let mut cl = Cluster::new();
    let mut l2 = FlatMem::new(L2_BASE, 64 * 1024);
    let kr = match kernel {
        "matmul-i8" | "matmul-i16" | "matmul-i32" => {
            let w = match kernel {
                "matmul-i8" => IntWidth::I8,
                "matmul-i16" => IntWidth::I16,
                _ => IntWidth::I32,
            };
            let lim = if w == IntWidth::I8 { 127 } else { 1000 };
            let av: Vec<i32> =
                (0..size * size).map(|_| rng.range_i64(-lim, lim) as i32).collect();
            let bv: Vec<i32> =
                (0..size * size).map(|_| rng.range_i64(-lim, lim) as i32).collect();
            int_matmul::run(&mut cl, &mut l2, &av, &bv, size, size, size, w, cores).1
        }
        "matmul-f32" | "matmul-f16" | "matmul-f8" => {
            let w = match kernel {
                "matmul-f32" => FpWidth::F32,
                "matmul-f16" => FpWidth::F16x2,
                _ => FpWidth::F8x4,
            };
            let av: Vec<f32> = (0..size * size).map(|_| rng.f32_pm1()).collect();
            let bv: Vec<f32> = (0..size * size).map(|_| rng.f32_pm1()).collect();
            fp_matmul::run(&mut cl, &mut l2, &av, &bv, size, size, size, w, cores).1
        }
        "fft" => {
            let x: Vec<(f32, f32)> =
                (0..size).map(|_| (rng.f32_pm1(), rng.f32_pm1())).collect();
            vega::kernels::fp_fft::run(&mut cl, &mut l2, &x, FpWidth::F32, cores).1
        }
        name => vega::coordinator::bench_nsaa_kernel(name, FpWidth::F32),
    };
    let s = &kr.stats;
    println!("kernel          : {} ({cores} cores, size {size})", kr.name);
    println!("cycles          : {}", s.cycles);
    println!("instructions    : {}", s.total.retired);
    println!("IPC (aggregate) : {:.2}", s.total.retired as f64 / s.cycles as f64);
    println!("MAC/cycle       : {:.2}", s.mac_per_cycle());
    println!("FLOP/cycle      : {:.2}", s.flops_per_cycle());
    println!("TCDM conflicts  : {:.1}%", s.tcdm_conflict_rate * 100.0);
    println!("FPU contention  : {:.1}%", s.fpu_contention_rate * 100.0);
    println!("barrier-gated   : {} core-cycles", s.barrier_gated_cycles);
    for op in [vega::power::LV, vega::power::HV] {
        let (gops, eff) = vega::coordinator::efficiency(&kr, op, 0.0);
        println!(
            "@{:<3} {:>4.0} MHz   : {:.2} GOPS, {:.0} GOPS/W",
            op.name,
            op.f_cl / 1e6,
            gops,
            eff
        );
    }
}

/// Execute an artifact through PJRT and cross-check the simulator's
/// functional datapath against it (the silicon-vs-RTL equivalence role).
fn golden_check(name: &str) -> Result<String, String> {
    let rt = Runtime::load(Runtime::default_dir()).map_err(|e| e.to_string())?;
    let sig = rt.signature(name).ok_or_else(|| format!("unknown artifact {name}"))?.clone();
    let mut rng = vega::common::Rng::new(0x601D);
    let inputs: Vec<Tensor> = sig
        .inputs
        .iter()
        .map(|ts| Tensor::I8((0..ts.elems()).map(|_| rng.range_i64(-8, 8) as i8).collect()))
        .collect();
    let outs = rt.execute(name, &inputs).map_err(|e| e.to_string())?;

    match name {
        "matmul_int8_64" => {
            let a: Vec<i32> = inputs[0].as_i8().unwrap().iter().map(|&v| v as i32).collect();
            let b: Vec<i32> = inputs[1].as_i8().unwrap().iter().map(|&v| v as i32).collect();
            // PJRT matmul is (M,K)x(K,N); the simulator kernel wants B
            // column-major (N,K) — transpose.
            let mut bt = vec![0i32; 64 * 64];
            for r in 0..64 {
                for c in 0..64 {
                    bt[c * 64 + r] = b[r * 64 + c];
                }
            }
            let mut cl = vega::cluster::Cluster::new();
            let mut l2 = vega::iss::FlatMem::new(vega::cluster::L2_BASE, 4096);
            let (c_sim, kr) = vega::kernels::int_matmul::run(
                &mut cl,
                &mut l2,
                &a,
                &bt,
                64,
                64,
                64,
                vega::kernels::int_matmul::IntWidth::I8,
                8,
            );
            if c_sim != *outs[0].as_i32().unwrap() {
                return Err("simulator/PJRT divergence on int8 matmul".into());
            }
            Ok(format!(
                "golden OK: {name}: ISS (8 cores, {} cycles, {:.1} MAC/cycle) == PJRT/Pallas",
                kr.stats.cycles,
                kr.stats.mac_per_cycle()
            ))
        }
        "hwce_conv3x3_16" => {
            let x: Vec<i32> = inputs[0].as_i8().unwrap().iter().map(|&v| v as i32).collect();
            let w: Vec<i32> = inputs[1].as_i8().unwrap().iter().map(|&v| v as i32).collect();
            let sim = vega::hwce::conv3x3(&x, &w, 16, 16, 16, 16, vega::hwce::Precision::Int8);
            if sim != *outs[0].as_i32().unwrap() {
                return Err("HWCE datapath/PJRT divergence".into());
            }
            Ok(format!("golden OK: {name}: HWCE datapath == PJRT/Pallas"))
        }
        other => Ok(format!(
            "executed {other} through PJRT ({} outputs); no simulator cross-check wired",
            outs.len()
        )),
    }
}
