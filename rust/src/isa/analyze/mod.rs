//! ISA-level static verifier: CFG + dataflow over guest programs.
//!
//! `vega verify` runs this over every shipped kernel/bench program (see
//! [`crate::sweep::scenario::verify_targets`]) and fails on any
//! [`Severity::Error`] finding. The pipeline:
//!
//! 1. [`cfg`] — basic-block CFG with hardware-loop back edges,
//!    reachability, dominators, loop records;
//! 2. [`dataflow`] — register definite-assignment and liveness
//!    (uninit reads, dead writes);
//! 3. [`memcheck`] — constant propagation through the live executor and
//!    memory-map/alignment/dead-store checks, producing the
//!    [`MemFact`]s the static-vs-dynamic oracle replays against the
//!    traced ISS ([`crate::iss::trace`]).
//!
//! Everything lands in one severity-sorted [`AnalysisReport`] per
//! (program, entry state). The CFG/loop output (straight-line hardware
//! loops with static trip bounds) is the same shape the ISS superblock
//! layer consumes: a [`FindingKind::SuperblockCandidate`] here is the
//! static view of what [`crate::iss::superblock`] promotes into a
//! cached replay trace at run time — both sides share the
//! straight-line-body test in
//! [`crate::isa::predecode::is_straight_line_body`].

pub mod cfg;
pub mod dataflow;
pub mod memcheck;
pub mod report;

pub use cfg::{Block, Cfg, LoopInfo};
pub use report::{AnalysisReport, Finding, FindingKind, MemFact, Severity};

use crate::isa::{Program, Reg};

/// Analyze `prog` under the launch register state `entry`
/// (`(register, value)` pairs, exactly what the kernel drivers pass to
/// the ISS). Returns the severity-sorted report.
pub fn analyze(prog: &Program, entry: &[(Reg, u32)]) -> AnalysisReport {
    analyze_full(prog, entry).0
}

/// [`analyze`], additionally returning the [`Cfg`] (with loop trip
/// counts upgraded by constant propagation) for consumers that want the
/// structure itself — the dynamic twin of this analysis,
/// [`crate::iss::superblock`], feeds on the same loop shapes.
pub fn analyze_full(prog: &Program, entry: &[(Reg, u32)]) -> (AnalysisReport, Cfg) {
    let mut report = AnalysisReport::new(&prog.name, prog.insts.len());
    let mut cfg = Cfg::build(prog, &mut report);

    let mut entry_mask = 0u32;
    for &(r, _) in entry {
        entry_mask |= 1 << r;
    }
    dataflow::run(prog, &cfg, entry_mask, &mut report);
    let trips = memcheck::run(prog, &cfg, entry, &mut report);

    // Upgrade register-count hardware loops whose trip constant-folded,
    // then surface straight-line loops as superblock candidates.
    for l in &mut cfg.loops {
        if let (Some(setup), None) = (l.setup_pc, l.trip) {
            l.trip = trips.get(&setup).copied();
        }
        if l.straight_line {
            let trip = match l.trip {
                Some(t) => format!("static trip count {t}"),
                None => "run-time trip count".to_string(),
            };
            report.push(
                Severity::Info,
                FindingKind::SuperblockCandidate,
                Some(l.body_start),
                format!(
                    "straight-line hardware-loop body [{}..{}), {trip}: \
                     replayable as a superblock",
                    l.body_start, l.body_end
                ),
            );
        }
    }

    report.n_blocks = cfg.blocks.len();
    report.n_loops = cfg.loops.len();
    for pc in 0..prog.insts.len() {
        report.reachable_pcs[pc] = cfg.pc_reachable(pc);
    }
    report.sort();
    (report, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{Asm, A0, A1, T0};

    #[test]
    fn full_pipeline_on_clean_loop_kernel() {
        use crate::cluster::tcdm::TCDM_BASE;
        let mut a = Asm::new("t");
        let end = a.label();
        a.li(A1, TCDM_BASE as i32);
        a.li(T0, 0);
        a.lp_setup_imm(0, 16, end);
        a.lw_pi(A0, A1, 4);
        a.mac(T0, A0, A0);
        a.bind(end);
        a.li(A1, (TCDM_BASE + 256) as i32);
        a.sw(T0, A1, 0);
        a.halt();
        let p = a.finish().unwrap();
        let (r, cfg) = analyze_full(&p, &[]);
        assert_eq!(r.error_count(), 0, "clean kernel:\n{}", r.render());
        assert_eq!(r.n_loops, 1);
        assert_eq!(cfg.loops[0].trip, Some(16));
        assert!(cfg.loops[0].straight_line);
        assert!(r.findings.iter().any(|f| f.kind == FindingKind::SuperblockCandidate));
        assert!(r.reachable_pcs.iter().all(|&x| x));
        // mac defines T0, lw_pi defines A0 and bumps A1, li defines both.
        assert_eq!(r.may_def_mask & (1 << A0 | 1 << A1 | 1 << T0), (1 << A0 | 1 << A1 | 1 << T0));
    }

    #[test]
    fn register_trip_count_upgrades_loop_info() {
        let mut a = Asm::new("t");
        let end = a.label();
        a.li(T0, 7);
        a.lp_setup(0, T0, end);
        a.addi(A0, A0, 1);
        a.bind(end);
        a.sw(A0, A1, 0);
        a.halt();
        let p = a.finish().unwrap();
        let (r, cfg) = analyze_full(&p, &[(A0, 0), (A1, crate::cluster::tcdm::TCDM_BASE)]);
        assert_eq!(cfg.loops[0].trip, Some(7));
        let sb = r
            .findings
            .iter()
            .find(|f| f.kind == FindingKind::SuperblockCandidate)
            .expect("superblock info");
        assert!(sb.message.contains("static trip count 7"), "{}", sb.message);
    }

    #[test]
    fn op_name_table_covers_every_operating_point() {
        // The exhaustiveness contract runs both ways: `analyze/` matches
        // every `Inst` variant without wildcards (compile-time), and the
        // persisted-report name table must intern every operating-point
        // constant plus the DVFS-ladder sentinel (runtime, asserted here
        // from the analyzer side so the verifier PR owns the guard).
        use crate::power::tables::{DNN, HV, LV, NOM};
        for op in [LV, NOM, HV, DNN] {
            assert!(
                crate::dnn::encode::is_interned_op_name(op.name),
                "OP_NAMES missing operating point {:?}",
                op.name
            );
        }
        assert!(crate::dnn::encode::is_interned_op_name("sweep"));
        assert!(!crate::dnn::encode::is_interned_op_name("no-such-point"));
    }

    #[test]
    fn report_is_sorted_most_severe_first() {
        let mut a = Asm::new("t");
        let end = a.label();
        a.j(end);
        a.li(A0, 1); // unreachable (Error)
        a.bind(end);
        a.li(A1, 2); // dead write (Warning)
        a.halt();
        let p = a.finish().unwrap();
        let r = analyze(&p, &[]);
        assert!(r.error_count() >= 1);
        for w in r.findings.windows(2) {
            assert!(w[0].severity >= w[1].severity);
        }
    }
}
