//! Register dataflow: definite assignment and liveness over the CFG.
//!
//! Four checks, all on 32-bit register bitmasks (bit `r` = register
//! `x{r}`), iterated to fixpoint over the reachable blocks of the
//! [`Cfg`]:
//!
//! * **uninit-read** (`Error`) — a reachable instruction reads a
//!   register that *no* reachable instruction defines and the entry
//!   state does not initialize. Wrong on every execution.
//! * **maybe-uninit-read** (`Warning`) — forward definite-assignment
//!   (meet = intersection): the register is defined somewhere, but some
//!   path from entry reaches the read without passing a definition.
//! * **dead-reg-write** (`Warning`) — backward may-liveness: the
//!   written value can never be read on any path. Warning, not error:
//!   the kmeans/svm argmin loops end with a conditional-select `mv`
//!   whose final iteration is genuinely (and harmlessly) dead.
//! * **write-to-zero** (`Warning`) — a computation into hardwired x0
//!   (`jal`/`jalr` with `rd = x0` are the idiomatic discard and exempt).
//!
//! [`defs`] and [`mnemonic`] are deliberately wildcard-free matches
//! over [`Inst`]: adding a variant without deciding its analyzer
//! behavior is a compile error (the exhaustiveness-guard satellite).

use crate::isa::inst::Inst;
use crate::isa::{Program, Reg};

use super::cfg::Cfg;
use super::report::{AnalysisReport, FindingKind, Severity};

/// Registers *written* by this instruction, including side-effect defs
/// the ISS applies outside the primary destination: post-increment
/// loads/stores bump `rs1` after the access.
///
/// Exhaustive on purpose — no wildcard arm. A new [`Inst`] variant
/// fails to compile until its def set is stated here.
pub fn defs(inst: &Inst) -> [Option<Reg>; 2] {
    match *inst {
        Inst::Alu { rd, .. }
        | Inst::AluImm { rd, .. }
        | Inst::Li { rd, .. }
        | Inst::Mac { rd, .. }
        | Inst::Msu { rd, .. }
        | Inst::Simd { rd, .. }
        | Inst::Fp { rd, .. }
        | Inst::Jal { rd, .. }
        | Inst::Jalr { rd, .. } => [Some(rd), None],
        Inst::Load { rd, rs1, post_inc, .. } => {
            [Some(rd), if post_inc { Some(rs1) } else { None }]
        }
        Inst::Store { rs1, post_inc, .. } => [if post_inc { Some(rs1) } else { None }, None],
        Inst::Branch { .. } | Inst::LpSetup { .. } | Inst::Barrier | Inst::Halt | Inst::Nop => {
            [None, None]
        }
    }
}

/// Stable mnemonic per variant — the analyzer-side name table.
/// Exhaustive on purpose (see [`defs`]).
pub fn mnemonic(inst: &Inst) -> &'static str {
    match inst {
        Inst::Alu { .. } => "alu",
        Inst::AluImm { .. } => "alui",
        Inst::Li { .. } => "li",
        Inst::Load { .. } => "load",
        Inst::Store { .. } => "store",
        Inst::Branch { .. } => "branch",
        Inst::Jal { .. } => "jal",
        Inst::Jalr { .. } => "jalr",
        Inst::Mac { .. } => "mac",
        Inst::Msu { .. } => "msu",
        Inst::Simd { .. } => "simd",
        Inst::LpSetup { .. } => "lp.setup",
        Inst::Fp { .. } => "fp",
        Inst::Barrier => "barrier",
        Inst::Halt => "halt",
        Inst::Nop => "nop",
    }
}

fn def_bits(inst: &Inst) -> u32 {
    let mut m = 0u32;
    for d in defs(inst).into_iter().flatten() {
        m |= 1 << d;
    }
    m & !1 // x0 is hardwired; writes to it do not define anything
}

fn use_bits(inst: &Inst) -> u32 {
    let mut m = 0u32;
    for s in inst.srcs().into_iter().flatten() {
        m |= 1 << s;
    }
    m
}

fn rname(r: Reg) -> String {
    format!("x{r}")
}

/// Run all register-dataflow checks. `entry_mask` holds the registers
/// the launch state initializes (bit 0 / x0 is implied).
pub fn run(prog: &Program, cfg: &Cfg, entry_mask: u32, report: &mut AnalysisReport) {
    let entry_mask = entry_mask | 1;
    let nb = cfg.blocks.len();

    // -- global may-def over reachable code ------------------------------
    let mut may_def = 0u32;
    for (pc, inst) in prog.insts.iter().enumerate() {
        if cfg.pc_reachable(pc) {
            may_def |= def_bits(inst);
        }
    }
    report.may_def_mask = may_def;
    let ever_defined = may_def | entry_mask;

    // uninit-read: a read outside everything any path could define.
    for (pc, inst) in prog.insts.iter().enumerate() {
        if !cfg.pc_reachable(pc) {
            continue;
        }
        let undef = use_bits(inst) & !ever_defined;
        for r in 0..32u8 {
            if undef & (1 << r) != 0 {
                report.push(
                    Severity::Error,
                    FindingKind::UninitRead,
                    Some(pc),
                    format!(
                        "{} reads {}, which no instruction writes and entry does not set",
                        mnemonic(inst),
                        rname(r)
                    ),
                );
            }
        }
    }

    // -- forward definite assignment (meet = intersection) ---------------
    let block_def: Vec<u32> = cfg
        .blocks
        .iter()
        .map(|b| (b.start..b.end).map(|pc| def_bits(&prog.insts[pc])).fold(0, |a, m| a | m))
        .collect();
    let mut din = vec![u32::MAX; nb];
    din[0] = entry_mask;
    let mut changed = true;
    while changed {
        changed = false;
        for b in 0..nb {
            if !cfg.reachable[b] {
                continue;
            }
            let mut inb = if b == 0 { entry_mask } else { u32::MAX };
            for &p in &cfg.blocks[b].preds {
                if cfg.reachable[p] {
                    inb &= din[p] | block_def[p];
                }
            }
            if inb != din[b] {
                din[b] = inb;
                changed = true;
            }
        }
    }
    for (b, blk) in cfg.blocks.iter().enumerate() {
        if !cfg.reachable[b] {
            continue;
        }
        let mut cur = din[b];
        for pc in blk.start..blk.end {
            let inst = &prog.insts[pc];
            // Only registers that *are* defined somewhere — otherwise the
            // uninit-read Error above already covers them.
            let maybe = use_bits(inst) & !cur & ever_defined;
            for r in 0..32u8 {
                if maybe & (1 << r) != 0 {
                    report.push(
                        Severity::Warning,
                        FindingKind::MaybeUninitRead,
                        Some(pc),
                        format!(
                            "{} reads {}, which is not assigned on every path from entry",
                            mnemonic(inst),
                            rname(r)
                        ),
                    );
                }
            }
            cur |= def_bits(inst);
        }
    }

    // -- backward may-liveness -------------------------------------------
    // use[b] = upward-exposed uses; kill[b] = defined-before-used.
    let mut b_use = vec![0u32; nb];
    let mut b_kill = vec![0u32; nb];
    for (b, blk) in cfg.blocks.iter().enumerate() {
        let (mut u, mut k) = (0u32, 0u32);
        for pc in blk.start..blk.end {
            let inst = &prog.insts[pc];
            u |= use_bits(inst) & !k;
            k |= def_bits(inst);
        }
        b_use[b] = u;
        b_kill[b] = k;
    }
    let mut lout = vec![0u32; nb];
    let mut changed = true;
    while changed {
        changed = false;
        for b in (0..nb).rev() {
            let mut o = 0u32;
            for &s in &cfg.blocks[b].succs {
                o |= b_use[s] | (lout[s] & !b_kill[s]);
            }
            if o != lout[b] {
                lout[b] = o;
                changed = true;
            }
        }
    }
    for (b, blk) in cfg.blocks.iter().enumerate() {
        if !cfg.reachable[b] {
            continue;
        }
        let mut live = lout[b];
        for pc in (blk.start..blk.end).rev() {
            let inst = &prog.insts[pc];
            if let Some(rd) = inst.dst() {
                if rd == 0 {
                    if !matches!(inst, Inst::Jal { .. } | Inst::Jalr { .. }) {
                        report.push(
                            Severity::Warning,
                            FindingKind::WriteToZero,
                            Some(pc),
                            format!("{} writes x0, which is hardwired zero", mnemonic(inst)),
                        );
                    }
                } else if live & (1 << rd) == 0
                    && !matches!(inst, Inst::Jal { .. } | Inst::Jalr { .. })
                {
                    report.push(
                        Severity::Warning,
                        FindingKind::DeadRegWrite,
                        Some(pc),
                        format!(
                            "{} writes {}, but no path reads it back",
                            mnemonic(inst),
                            rname(rd)
                        ),
                    );
                }
            }
            live &= !def_bits(inst);
            live |= use_bits(inst);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{Asm, A0, A1, T0, T1};

    fn analyze_with(prog: &Program, entry: u32) -> AnalysisReport {
        let mut r = AnalysisReport::new(&prog.name, prog.insts.len());
        let cfg = Cfg::build(prog, &mut r);
        run(prog, &cfg, entry, &mut r);
        r
    }

    #[test]
    fn defs_cover_post_increment_pointer() {
        use crate::isa::inst::MemSize;
        let ld = Inst::Load { size: MemSize::W, rd: 10, rs1: 11, imm: 4, post_inc: true };
        assert_eq!(defs(&ld), [Some(10), Some(11)]);
        let st = Inst::Store { size: MemSize::W, rs2: 10, rs1: 11, imm: 4, post_inc: true };
        assert_eq!(defs(&st), [Some(11), None]);
        let st2 = Inst::Store { size: MemSize::W, rs2: 10, rs1: 11, imm: 4, post_inc: false };
        assert_eq!(defs(&st2), [None, None]);
    }

    #[test]
    fn uninit_read_is_error() {
        let mut a = Asm::new("t");
        a.add(A0, T0, T1); // T0/T1 never written, not in entry
        a.halt();
        let p = a.finish().unwrap();
        let r = analyze_with(&p, 0);
        assert!(r.has_error(FindingKind::UninitRead));
        assert_eq!(r.findings.iter().filter(|f| f.kind == FindingKind::UninitRead).count(), 2);
    }

    #[test]
    fn entry_regs_are_initialized() {
        let mut a = Asm::new("t");
        a.add(A1, A0, A0);
        a.halt();
        let p = a.finish().unwrap();
        let r = analyze_with(&p, 1 << A0);
        assert!(!r.has_error(FindingKind::UninitRead));
    }

    #[test]
    fn branch_defined_register_warns_maybe_uninit() {
        let mut a = Asm::new("t");
        let skip = a.label();
        a.beq(A0, 0, skip); // A0 from entry
        a.li(T0, 7); // only on fall-through
        a.bind(skip);
        a.add(A1, T0, A0); // T0 unset when branch taken
        a.halt();
        let p = a.finish().unwrap();
        let r = analyze_with(&p, 1 << A0);
        assert!(!r.has_error(FindingKind::UninitRead));
        assert!(r.findings.iter().any(|f| f.kind == FindingKind::MaybeUninitRead));
    }

    #[test]
    fn dead_write_and_write_to_zero_warn() {
        let mut a = Asm::new("t");
        a.li(T0, 1); // never read
        a.li(0, 9); // x0
        a.halt();
        let p = a.finish().unwrap();
        let r = analyze_with(&p, 0);
        assert_eq!(r.error_count(), 0);
        assert!(r.findings.iter().any(|f| f.kind == FindingKind::DeadRegWrite));
        assert!(r.findings.iter().any(|f| f.kind == FindingKind::WriteToZero));
    }

    #[test]
    fn loop_carried_accumulator_is_live() {
        let mut a = Asm::new("t");
        let end = a.label();
        a.li(T0, 0);
        a.lp_setup_imm(0, 8, end);
        a.addi(T0, T0, 3); // live across the hw-loop back edge
        a.bind(end);
        a.add(A0, T0, T0);
        a.halt();
        let p = a.finish().unwrap();
        let r = analyze_with(&p, 0);
        assert!(!r.findings.iter().any(|f| {
            f.kind == FindingKind::DeadRegWrite && f.pc == Some(2)
        }));
    }
}
