//! Basic-block CFG over a symbolic [`Program`], with hardware-loop
//! edges, reachability, dominators and loop classification.
//!
//! Control flow in the guest ISA comes from five places: two-way
//! branches, direct jumps (`jal`), indirect jumps (`jalr` — never
//! emitted by the kernel builders, reported as unresolvable), `halt`,
//! and the two zero-overhead hardware-loop channels. The hardware loops
//! are the subtle part: `lp.setup lp, count, end` marks the body
//! `[setup+1, end)`, and the *retire* of the instruction at `end - 1`
//! either falls out to `end` or loops back to `setup + 1`
//! ([`crate::iss::core`]'s `finish_retire`). The CFG models that as two
//! successors of `end - 1`, which over-approximates every dynamic
//! iteration pattern including nested loops sharing an end pc.
//!
//! Branches are always treated as two-way (both successors), so
//! reachability over-approximates: a block reported unreachable is
//! unreachable on *every* execution — which is what lets
//! [`super::report::FindingKind::UnreachableBlock`] carry `Error`
//! severity without false positives on data-dependent guards.

use crate::isa::inst::{Inst, LoopCount};
use crate::isa::Program;

use super::report::{AnalysisReport, FindingKind, Severity};

/// A maximal straight-line run of instructions `[start, end)`.
#[derive(Debug, Clone)]
pub struct Block {
    pub start: usize,
    pub end: usize,
    /// Successor block ids.
    pub succs: Vec<usize>,
    /// Predecessor block ids.
    pub preds: Vec<usize>,
}

/// One loop in the program (hardware loop or branch back-edge).
#[derive(Debug, Clone)]
pub struct LoopInfo {
    /// Header block id (the block control re-enters each iteration).
    pub head: usize,
    /// First pc of the loop body.
    pub body_start: usize,
    /// One past the last body pc (hw loops: the `body_end` target).
    pub body_end: usize,
    /// `lp.setup` pc for hardware loops, `None` for branch loops.
    pub setup_pc: Option<usize>,
    /// Static trip count, when derivable (immediate count, or a
    /// register count const-propagated by [`super::memcheck`]).
    pub trip: Option<u32>,
    /// Body contains no control flow: a superblock candidate.
    pub straight_line: bool,
}

/// The control-flow graph plus derived structure.
pub struct Cfg {
    pub blocks: Vec<Block>,
    /// pc -> block id.
    pub block_of: Vec<usize>,
    /// Per-block: is there a path from the entry block?
    pub reachable: Vec<bool>,
    pub loops: Vec<LoopInfo>,
}

impl Cfg {
    /// Is the instruction at `pc` in a reachable block?
    pub fn pc_reachable(&self, pc: usize) -> bool {
        self.reachable[self.block_of[pc]]
    }

    /// Build the CFG and emit structural findings (unreachable blocks,
    /// indirect jumps, irreducible retreating edges, superblock
    /// candidates) into `report`.
    pub fn build(prog: &Program, report: &mut AnalysisReport) -> Cfg {
        let n = prog.insts.len();
        assert!(n > 0, "cannot analyze an empty program");

        // -- pc-level successors ----------------------------------------
        let mut succs: Vec<Vec<usize>> = Vec::with_capacity(n);
        for (pc, inst) in prog.insts.iter().enumerate() {
            let s = match *inst {
                Inst::Branch { target, .. } => vec![pc + 1, target],
                Inst::Jal { target, .. } => vec![target],
                Inst::Jalr { .. } => {
                    report.push(
                        Severity::Warning,
                        FindingKind::IndirectJump,
                        Some(pc),
                        "jalr target is run-time-computed; control flow past it is unmodeled",
                    );
                    vec![]
                }
                Inst::Halt => vec![],
                Inst::LpSetup { count, body_end, .. } => match count {
                    LoopCount::Imm(0) => vec![body_end],
                    LoopCount::Imm(_) => vec![pc + 1],
                    LoopCount::Reg(_) => vec![pc + 1, body_end],
                },
                _ => vec![pc + 1],
            };
            succs.push(s);
        }
        // Hardware-loop back edges: the retire at `end - 1` may return
        // to the body start. Applies on the fall-through path only, so
        // instructions that always jump (jal/jalr/halt) don't get one.
        for (pc, inst) in prog.insts.iter().enumerate() {
            if let Inst::LpSetup { body_end, .. } = *inst {
                let body_start = pc + 1;
                if body_end > body_start && body_end <= n {
                    let last = body_end - 1;
                    if !matches!(
                        prog.insts[last],
                        Inst::Jal { .. } | Inst::Jalr { .. } | Inst::Halt
                    ) && !succs[last].contains(&body_start)
                    {
                        succs[last].push(body_start);
                    }
                }
            }
        }
        // Drop fall-offs past the program end (a well-formed program
        // ends in halt; the assembler's finish() enforces bounds).
        for s in &mut succs {
            s.retain(|&t| t < n);
        }

        // -- leaders and blocks -----------------------------------------
        let mut leader = vec![false; n];
        leader[0] = true;
        for (pc, s) in succs.iter().enumerate() {
            if !(s.len() == 1 && s[0] == pc + 1) {
                // Terminator: successors start blocks, and so does the
                // textual next instruction.
                for &t in s {
                    leader[t] = true;
                }
                if pc + 1 < n {
                    leader[pc + 1] = true;
                }
            }
        }
        let starts: Vec<usize> = (0..n).filter(|&pc| leader[pc]).collect();
        let mut block_of = vec![0usize; n];
        let mut blocks: Vec<Block> = Vec::with_capacity(starts.len());
        for (b, &start) in starts.iter().enumerate() {
            let end = starts.get(b + 1).copied().unwrap_or(n);
            for pc in start..end {
                block_of[pc] = b;
            }
            blocks.push(Block { start, end, succs: Vec::new(), preds: Vec::new() });
        }
        for b in 0..blocks.len() {
            let last = blocks[b].end - 1;
            let mut bs: Vec<usize> = succs[last].iter().map(|&t| block_of[t]).collect();
            bs.sort_unstable();
            bs.dedup();
            for &t in &bs {
                blocks[t].preds.push(b);
            }
            blocks[b].succs = bs;
        }

        // -- reachability ------------------------------------------------
        let mut reachable = vec![false; blocks.len()];
        let mut stack = vec![0usize];
        while let Some(b) = stack.pop() {
            if reachable[b] {
                continue;
            }
            reachable[b] = true;
            stack.extend(blocks[b].succs.iter().copied());
        }
        for (b, blk) in blocks.iter().enumerate() {
            if !reachable[b] {
                report.push(
                    Severity::Error,
                    FindingKind::UnreachableBlock,
                    Some(blk.start),
                    format!("block [{}..{}) is unreachable from entry", blk.start, blk.end),
                );
            }
        }

        // -- dominators (Cooper-Harvey-Kennedy on reachable blocks) ------
        let rpo = reverse_postorder(&blocks, &reachable);
        let mut rpo_index = vec![usize::MAX; blocks.len()];
        for (i, &b) in rpo.iter().enumerate() {
            rpo_index[b] = i;
        }
        let idom = dominators(&blocks, &rpo, &rpo_index);

        // -- loops -------------------------------------------------------
        let mut cfg = Cfg { blocks, block_of, reachable, loops: Vec::new() };
        for &u in &rpo {
            for &v in &cfg.blocks[u].succs.clone() {
                if rpo_index[v] == usize::MAX || rpo_index[v] > rpo_index[u] {
                    continue; // unreachable target or forward edge
                }
                // Retreating edge u -> v.
                if dominates(v, u, &idom, &rpo_index) {
                    // Natural loop (non-hw back edges get a LoopInfo too
                    // so n_loops reflects every cycle in the graph).
                    let head = v;
                    let body_start = cfg.blocks[head].start;
                    let body_end = cfg.blocks[u].end;
                    if !cfg.loops.iter().any(|l| l.head == head && l.body_end == body_end) {
                        cfg.loops.push(LoopInfo {
                            head,
                            body_start,
                            body_end,
                            setup_pc: None,
                            trip: None,
                            straight_line: false,
                        });
                    }
                } else {
                    report.push(
                        Severity::Warning,
                        FindingKind::IrreducibleLoop,
                        Some(cfg.blocks[u].end - 1),
                        format!(
                            "retreating edge to pc {} whose block does not dominate it \
                             (multi-entry loop)",
                            cfg.blocks[v].start
                        ),
                    );
                }
            }
        }
        // Hardware loops: refine the matching LoopInfo (or add one) with
        // the setup pc, immediate trip bound and straight-line shape.
        for (pc, inst) in prog.insts.iter().enumerate() {
            let Inst::LpSetup { count, body_end, .. } = *inst else {
                continue;
            };
            if !cfg.pc_reachable(pc) || body_end <= pc + 1 {
                continue;
            }
            let body_start = pc + 1;
            let trip = match count {
                LoopCount::Imm(t) => Some(t),
                LoopCount::Reg(_) => None,
            };
            // Shared with predecode's superblock table: the analyzer's
            // SuperblockCandidate findings and the ISS replay layer use
            // the same straight-line test by construction.
            let straight_line =
                crate::isa::predecode::is_straight_line_body(prog, body_start, body_end);
            let head = cfg.block_of[body_start];
            if let Some(l) = cfg
                .loops
                .iter_mut()
                .find(|l| l.head == head && l.setup_pc.is_none())
            {
                l.setup_pc = Some(pc);
                l.body_start = body_start;
                l.body_end = body_end;
                l.trip = trip;
                l.straight_line = straight_line;
            } else {
                cfg.loops.push(LoopInfo {
                    head,
                    body_start,
                    body_end,
                    setup_pc: Some(pc),
                    trip,
                    straight_line,
                });
            }
        }
        cfg.loops.sort_by_key(|l| (l.body_start, l.body_end));
        cfg
    }
}

fn reverse_postorder(blocks: &[Block], reachable: &[bool]) -> Vec<usize> {
    let mut order = Vec::with_capacity(blocks.len());
    let mut state = vec![0u8; blocks.len()]; // 0 unvisited, 1 open, 2 done
    let mut stack: Vec<(usize, usize)> = vec![(0, 0)];
    state[0] = 1;
    while let Some(&mut (b, ref mut i)) = stack.last_mut() {
        if *i < blocks[b].succs.len() {
            let s = blocks[b].succs[*i];
            *i += 1;
            if reachable[s] && state[s] == 0 {
                state[s] = 1;
                stack.push((s, 0));
            }
        } else {
            state[b] = 2;
            order.push(b);
            stack.pop();
        }
    }
    order.reverse();
    order
}

/// Immediate dominators over the reachable subgraph, indexed by block id
/// (`idom[entry] == entry`; unreachable blocks stay `usize::MAX`).
fn dominators(blocks: &[Block], rpo: &[usize], rpo_index: &[usize]) -> Vec<usize> {
    let mut idom = vec![usize::MAX; blocks.len()];
    if rpo.is_empty() {
        return idom;
    }
    let entry = rpo[0];
    idom[entry] = entry;
    let mut changed = true;
    while changed {
        changed = false;
        for &b in rpo.iter().skip(1) {
            let mut new_idom = usize::MAX;
            for &p in &blocks[b].preds {
                if idom[p] == usize::MAX {
                    continue; // pred not yet processed / unreachable
                }
                new_idom = if new_idom == usize::MAX {
                    p
                } else {
                    intersect(new_idom, p, &idom, rpo_index)
                };
            }
            if new_idom != usize::MAX && idom[b] != new_idom {
                idom[b] = new_idom;
                changed = true;
            }
        }
    }
    idom
}

fn intersect(mut a: usize, mut b: usize, idom: &[usize], rpo_index: &[usize]) -> usize {
    while a != b {
        while rpo_index[a] > rpo_index[b] {
            a = idom[a];
        }
        while rpo_index[b] > rpo_index[a] {
            b = idom[b];
        }
    }
    a
}

/// Does block `a` dominate block `b`?
fn dominates(a: usize, b: usize, idom: &[usize], rpo_index: &[usize]) -> bool {
    if idom[a] == usize::MAX || idom[b] == usize::MAX {
        return false;
    }
    let mut x = b;
    loop {
        if x == a {
            return true;
        }
        if idom[x] == x {
            return false; // reached entry
        }
        // idom strictly decreases rpo index, so this terminates.
        debug_assert!(rpo_index[idom[x]] < rpo_index[x]);
        x = idom[x];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{Asm, A0, A1, T0};

    fn build(prog: &Program) -> (Cfg, AnalysisReport) {
        let mut r = AnalysisReport::new(&prog.name, prog.insts.len());
        let cfg = Cfg::build(prog, &mut r);
        (cfg, r)
    }

    #[test]
    fn straight_line_is_one_block() {
        let mut a = Asm::new("t");
        a.li(A0, 1);
        a.addi(A0, A0, 1);
        a.halt();
        let p = a.finish().unwrap();
        let (cfg, r) = build(&p);
        assert_eq!(cfg.blocks.len(), 1);
        assert!(cfg.reachable[0]);
        assert_eq!(r.findings.len(), 0);
    }

    #[test]
    fn branch_splits_blocks_both_ways() {
        let mut a = Asm::new("t");
        let skip = a.label();
        a.li(A0, 0);
        a.beq(A0, 0, skip);
        a.li(A1, 1); // fall-through arm
        a.bind(skip);
        a.halt();
        let p = a.finish().unwrap();
        let (cfg, _) = build(&p);
        // [li, beq], [li], [halt]
        assert_eq!(cfg.blocks.len(), 3);
        assert_eq!(cfg.blocks[0].succs, vec![1, 2]);
        assert!(cfg.reachable.iter().all(|&r| r));
    }

    #[test]
    fn code_after_jump_is_unreachable_error() {
        let mut a = Asm::new("t");
        let end = a.label();
        a.j(end);
        a.li(A0, 1); // dead
        a.bind(end);
        a.halt();
        let p = a.finish().unwrap();
        let (cfg, r) = build(&p);
        assert!(!cfg.pc_reachable(1));
        assert!(r.has_error(FindingKind::UnreachableBlock));
    }

    #[test]
    fn hw_loop_gets_back_edge_and_superblock_shape() {
        let mut a = Asm::new("t");
        let end = a.label();
        a.lp_setup_imm(0, 10, end);
        a.addi(A0, A0, 1);
        a.mac(A1, A0, A0);
        a.bind(end);
        a.halt();
        let p = a.finish().unwrap();
        let (cfg, r) = build(&p);
        assert_eq!(cfg.loops.len(), 1);
        let l = &cfg.loops[0];
        assert_eq!(l.setup_pc, Some(0));
        assert_eq!(l.trip, Some(10));
        assert!(l.straight_line);
        assert_eq!((l.body_start, l.body_end), (1, 3));
        // Body block loops to itself and exits to the halt block.
        let body = cfg.block_of[1];
        assert!(cfg.blocks[body].succs.contains(&body));
        assert_eq!(r.error_count(), 0);
    }

    #[test]
    fn branch_loop_is_reducible_natural_loop() {
        let mut a = Asm::new("t");
        let head = a.label();
        a.li(T0, 10);
        a.bind(head);
        a.addi(T0, T0, -1);
        a.bne(T0, 0, head);
        a.halt();
        let p = a.finish().unwrap();
        let (cfg, r) = build(&p);
        assert_eq!(cfg.loops.len(), 1);
        assert_eq!(cfg.loops[0].setup_pc, None);
        assert!(!r.findings.iter().any(|f| f.kind == FindingKind::IrreducibleLoop));
        assert_eq!(r.error_count(), 0);
    }

    #[test]
    fn jalr_reports_indirect_jump() {
        use crate::isa::Inst;
        let mut a = Asm::new("t");
        a.li(RA_SCRATCH, 3);
        a.halt();
        a.halt();
        let mut p = a.finish().unwrap();
        p.insts[1] = Inst::Jalr { rd: 0, rs1: RA_SCRATCH };
        let (_, r) = build(&p);
        assert!(r.findings.iter().any(|f| f.kind == FindingKind::IndirectJump));
    }

    const RA_SCRATCH: u8 = 5;

    #[test]
    fn nested_loops_shared_end() {
        let mut a = Asm::new("t");
        let end1 = a.label();
        let end0 = a.label();
        a.lp_setup_imm(1, 5, end1);
        a.lp_setup_imm(0, 3, end0);
        a.addi(A0, A0, 1);
        a.bind(end0);
        a.addi(A1, A1, 1);
        a.bind(end1);
        a.halt();
        let p = a.finish().unwrap();
        let (cfg, r) = build(&p);
        assert_eq!(cfg.loops.len(), 2);
        assert!(cfg.loops.iter().any(|l| l.trip == Some(3)));
        assert!(cfg.loops.iter().any(|l| l.trip == Some(5)));
        assert_eq!(r.error_count(), 0);
    }
}
