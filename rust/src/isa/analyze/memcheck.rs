//! Constant propagation and static memory-access checking against the
//! SoC memory map.
//!
//! A Kildall worklist runs a flat constant lattice (`Top` / known `u32`)
//! over the reachable blocks of the [`Cfg`], transferring through the
//! *live executor* ([`crate::iss::exec::alu`] is total, so folding an
//! ALU op can never disagree with what the ISS computes). Every memory
//! access whose address resolves to a constant is then checked against
//! the memory map the fabrics actually decode:
//!
//! * TCDM: `[TCDM_BASE, TCDM_BASE + TCDM_SIZE)`, 16 word-interleaved
//!   banks ([`crate::cluster::tcdm`]);
//! * L2: `[L2_BASE, L2_BASE + L2_SIZE)` ([`crate::soc::l2`]);
//! * MRAM is *not* core-addressable (it DMAs images into L2/TCDM), so
//!   no guest access may land there.
//!
//! Out-of-range or element-misaligned constant accesses are `Error`s:
//! the address holds on every execution, so the program faults on every
//! execution. Resolved accesses are recorded as [`MemFact`]s for the
//! static-vs-dynamic oracle; run-time-computed addresses are counted
//! into one `Info` finding and left to the oracle's traced run.
//! Also found here: block-local dead stores (same constant address and
//! width stored twice with no possible intervening read — `Error`) and
//! register-count hardware-loop trip bounds for the superblock report.

use std::collections::HashMap;

use crate::cluster::tcdm::{TCDM_BANKS, TCDM_BASE, TCDM_SIZE};
use crate::isa::inst::Inst;
use crate::isa::predecode::DecodedKind;
use crate::isa::{Program, Reg};
use crate::iss::exec;
use crate::soc::l2::{L2_BASE, L2_SIZE};

use super::cfg::Cfg;
use super::report::{AnalysisReport, FindingKind, MemFact, Severity};

/// Flat constant lattice: unknown, or one proven 32-bit value.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Val {
    Top,
    C(u32),
}

impl Val {
    fn join(self, other: Val) -> Val {
        match (self, other) {
            (Val::C(a), Val::C(b)) if a == b => Val::C(a),
            _ => Val::Top,
        }
    }
}

/// One abstract register file. x0 stays `C(0)` by construction.
type Env = [Val; 32];

fn set(env: &mut Env, r: Reg, v: Val) {
    if r != 0 {
        env[r as usize] = v;
    }
}

/// Abstract transfer of one instruction, mirroring `Core::exec_local` /
/// the retire paths. Anything not provably constant becomes `Top`.
fn transfer(env: &mut Env, inst: &Inst) {
    match *inst {
        Inst::Alu { op, rd, rs1, rs2 } => {
            let v = match (env[rs1 as usize], env[rs2 as usize]) {
                // exec::alu is total (div-by-zero and overflow defined),
                // so folding through it is unconditionally safe.
                (Val::C(a), Val::C(b)) => Val::C(exec::alu(op, a, b)),
                _ => Val::Top,
            };
            set(env, rd, v);
        }
        Inst::AluImm { op, rd, rs1, imm } => {
            let v = match env[rs1 as usize] {
                Val::C(a) => Val::C(exec::alu(op, a, imm as u32)),
                Val::Top => Val::Top,
            };
            set(env, rd, v);
        }
        Inst::Li { rd, imm } => set(env, rd, Val::C(imm as u32)),
        Inst::Load { rd, rs1, imm, post_inc, .. } => {
            set(env, rd, Val::Top);
            if post_inc {
                let v = match env[rs1 as usize] {
                    Val::C(a) => Val::C(a.wrapping_add(imm as u32)),
                    Val::Top => Val::Top,
                };
                set(env, rs1, v);
            }
        }
        Inst::Store { rs1, imm, post_inc, .. } => {
            if post_inc {
                let v = match env[rs1 as usize] {
                    Val::C(a) => Val::C(a.wrapping_add(imm as u32)),
                    Val::Top => Val::Top,
                };
                set(env, rs1, v);
            }
        }
        // Link values and data-dependent results: sound as unknown.
        Inst::Jal { rd, .. } | Inst::Jalr { rd, .. } => set(env, rd, Val::Top),
        Inst::Mac { rd, .. }
        | Inst::Msu { rd, .. }
        | Inst::Simd { rd, .. }
        | Inst::Fp { rd, .. } => set(env, rd, Val::Top),
        Inst::Branch { .. } | Inst::LpSetup { .. } | Inst::Barrier | Inst::Halt | Inst::Nop => {}
    }
}

fn region_name(addr: u32) -> Option<&'static str> {
    let tcdm_end = TCDM_BASE + TCDM_SIZE as u32;
    let l2_end = L2_BASE + L2_SIZE as u32;
    if (TCDM_BASE..tcdm_end).contains(&addr) {
        Some("TCDM")
    } else if (L2_BASE..l2_end).contains(&addr) {
        Some("L2")
    } else {
        None
    }
}

/// Does `[addr, addr + bytes)` sit entirely inside one mapped region?
fn in_bounds(addr: u32, bytes: u32) -> bool {
    // `addr` is inside the region, so the end sums cannot overflow.
    match region_name(addr) {
        Some("TCDM") => addr + bytes <= TCDM_BASE + TCDM_SIZE as u32,
        Some("L2") => addr + bytes <= L2_BASE + L2_SIZE as u32,
        _ => false,
    }
}

/// Are `[a, a+ab)` and `[b, b+bb)` disjoint? (u64 math: an out-of-bounds
/// constant near `u32::MAX` still lands in the dead-store map.)
fn disjoint(a: u32, ab: u32, b: u32, bb: u32) -> bool {
    u64::from(a) + u64::from(ab) <= u64::from(b) || u64::from(b) + u64::from(bb) <= u64::from(a)
}

/// Run constant propagation + memory checks. `entry` is the launch
/// register state (everything else starts `Top` — *not* zero, so every
/// resolved address is entry-state-implied and holds on all executions).
///
/// Returns the register-count hardware loops whose trip count resolved:
/// `setup_pc -> trip`.
pub fn run(
    prog: &Program,
    cfg: &Cfg,
    entry: &[(Reg, u32)],
    report: &mut AnalysisReport,
) -> HashMap<usize, u32> {
    let nb = cfg.blocks.len();
    let mut entry_env: Env = [Val::Top; 32];
    entry_env[0] = Val::C(0);
    for &(r, v) in entry {
        set(&mut entry_env, r, Val::C(v));
    }

    // -- fixpoint: block-entry environments ------------------------------
    let mut ins: Vec<Option<Env>> = vec![None; nb];
    ins[0] = Some(entry_env);
    let mut work = vec![0usize];
    while let Some(b) = work.pop() {
        let mut env = ins[b].expect("worklist block without IN env");
        for pc in cfg.blocks[b].start..cfg.blocks[b].end {
            transfer(&mut env, &prog.insts[pc]);
        }
        for &s in &cfg.blocks[b].succs {
            let changed = match ins[s] {
                None => {
                    ins[s] = Some(env);
                    true
                }
                Some(cur) => {
                    let mut joined = cur;
                    for (j, v) in joined.iter_mut().enumerate() {
                        *v = v.join(env[j]);
                    }
                    if joined != cur {
                        ins[s] = Some(joined);
                        true
                    } else {
                        false
                    }
                }
            };
            if changed {
                work.push(s);
            }
        }
    }

    // -- final pass: check each reachable access once --------------------
    let pre = prog.predecode();
    let mut trips: HashMap<usize, u32> = HashMap::new();
    let mut unresolved = 0usize;
    for b in 0..nb {
        let Some(mut env) = ins[b] else { continue };
        // (addr, bytes) -> pc of the last store nothing could have read.
        let mut last_store: HashMap<(u32, u32), usize> = HashMap::new();
        for pc in cfg.blocks[b].start..cfg.blocks[b].end {
            let inst = &prog.insts[pc];
            if let Inst::LpSetup { count: crate::isa::inst::LoopCount::Reg(r), .. } = *inst {
                if let Val::C(n) = env[r as usize] {
                    trips.insert(pc, n);
                }
            }
            // Single wildcard-free dispatch over the predecoded kind: a
            // new DecodedKind must state its memory behavior here.
            match pre.recs[pc].kind {
                DecodedKind::Mem { write, size, rs1, imm, post_inc, .. } => {
                    let addr = if post_inc {
                        env[rs1 as usize]
                    } else {
                        match env[rs1 as usize] {
                            Val::C(a) => Val::C(a.wrapping_add(imm as u32)),
                            Val::Top => Val::Top,
                        }
                    };
                    match addr {
                        Val::C(a) => {
                            let bytes = size.bytes();
                            if !in_bounds(a, bytes) {
                                report.push(
                                    Severity::Error,
                                    FindingKind::OutOfBounds,
                                    Some(pc),
                                    format!(
                                        "{} of {bytes} B at {a:#010x} is outside TCDM \
                                         [{TCDM_BASE:#010x}, {:#010x}) and L2 \
                                         [{L2_BASE:#010x}, {:#010x}) (MRAM is not \
                                         core-addressable)",
                                        if write { "store" } else { "load" },
                                        TCDM_BASE + TCDM_SIZE as u32,
                                        L2_BASE + L2_SIZE as u32,
                                    ),
                                );
                            }
                            if a % bytes != 0 {
                                report.push(
                                    Severity::Error,
                                    FindingKind::Misaligned,
                                    Some(pc),
                                    format!(
                                        "{} address {a:#010x} is not {bytes}-byte aligned",
                                        if write { "store" } else { "load" },
                                    ),
                                );
                            }
                            report.resolved_mem[pc] = Some(MemFact { addr: a, bytes, write });
                            if region_name(a) == Some("TCDM") {
                                let bank = ((a - TCDM_BASE) >> 2) as usize % TCDM_BANKS;
                                report.tcdm_bank_mask |= 1 << bank;
                            }
                            if write {
                                if let Some(&dead_pc) = last_store.get(&(a, bytes)) {
                                    report.push(
                                        Severity::Error,
                                        FindingKind::DeadStore,
                                        Some(dead_pc),
                                        format!(
                                            "store to {a:#010x} ({bytes} B) is overwritten \
                                             at pc {pc} with no possible read in between",
                                        ),
                                    );
                                }
                                // A differently-shaped overlap only partially
                                // survives — drop it without reporting.
                                last_store.retain(|&(sa, sb), _| disjoint(sa, sb, a, bytes));
                                last_store.insert((a, bytes), pc);
                            } else {
                                last_store.retain(|&(sa, sb), _| disjoint(sa, sb, a, bytes));
                            }
                        }
                        Val::Top => {
                            unresolved += 1;
                            // Unknown address may alias anything.
                            last_store.clear();
                        }
                    }
                }
                // Another core may observe TCDM around a barrier.
                DecodedKind::Barrier => last_store.clear(),
                DecodedKind::Fp { .. } | DecodedKind::Halt | DecodedKind::Local => {}
            }
            transfer(&mut env, inst);
        }
    }
    if unresolved > 0 {
        report.push(
            Severity::Info,
            FindingKind::UnresolvedAccess,
            None,
            format!(
                "{unresolved} access site(s) have run-time-computed addresses; \
                 the dynamic oracle checks them against the traced ISS"
            ),
        );
    }
    trips
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{Asm, A0, T0, T1};

    fn analyze(prog: &Program, entry: &[(Reg, u32)]) -> (AnalysisReport, HashMap<usize, u32>) {
        let mut r = AnalysisReport::new(&prog.name, prog.insts.len());
        let cfg = Cfg::build(prog, &mut r);
        let trips = run(prog, &cfg, entry, &mut r);
        (r, trips)
    }

    #[test]
    fn resolved_tcdm_access_is_clean_and_recorded() {
        let mut a = Asm::new("t");
        a.li(A0, TCDM_BASE as i32);
        a.lw(T0, A0, 8);
        a.halt();
        let p = a.finish().unwrap();
        let (r, _) = analyze(&p, &[]);
        assert_eq!(r.error_count(), 0);
        assert_eq!(
            r.resolved_mem[1],
            Some(MemFact { addr: TCDM_BASE + 8, bytes: 4, write: false })
        );
        assert_eq!(r.tcdm_bank_mask, 1 << 2); // word 2 -> bank 2
    }

    #[test]
    fn out_of_bounds_constant_address_is_error() {
        let mut a = Asm::new("t");
        a.li(A0, (TCDM_BASE + TCDM_SIZE as u32) as i32);
        a.lw(T0, A0, 0);
        a.halt();
        let p = a.finish().unwrap();
        let (r, _) = analyze(&p, &[]);
        assert!(r.has_error(FindingKind::OutOfBounds));
    }

    #[test]
    fn misaligned_word_load_is_error() {
        let mut a = Asm::new("t");
        a.li(A0, (TCDM_BASE + 2) as i32);
        a.lw(T0, A0, 0);
        a.halt();
        let p = a.finish().unwrap();
        let (r, _) = analyze(&p, &[]);
        assert!(r.has_error(FindingKind::Misaligned));
        // A halfword access at the same address is fine.
        let mut a = Asm::new("t");
        a.li(A0, (TCDM_BASE + 2) as i32);
        a.lh(T0, A0, 0);
        a.halt();
        let p = a.finish().unwrap();
        let (r, _) = analyze(&p, &[]);
        assert_eq!(r.error_count(), 0);
    }

    #[test]
    fn double_store_same_address_is_dead_store() {
        let mut a = Asm::new("t");
        a.li(A0, TCDM_BASE as i32);
        a.li(T0, 1);
        a.li(T1, 2);
        a.sw(T0, A0, 0);
        a.sw(T1, A0, 0);
        a.halt();
        let p = a.finish().unwrap();
        let (r, _) = analyze(&p, &[]);
        assert!(r.has_error(FindingKind::DeadStore));
        let f = r.findings.iter().find(|f| f.kind == FindingKind::DeadStore).unwrap();
        assert_eq!(f.pc, Some(3)); // the overwritten store
    }

    #[test]
    fn intervening_load_keeps_store_alive() {
        let mut a = Asm::new("t");
        a.li(A0, TCDM_BASE as i32);
        a.li(T0, 1);
        a.sw(T0, A0, 0);
        a.lw(T1, A0, 0);
        a.sw(T1, A0, 0);
        a.halt();
        let p = a.finish().unwrap();
        let (r, _) = analyze(&p, &[]);
        assert!(!r.has_error(FindingKind::DeadStore));
    }

    #[test]
    fn entry_state_resolves_addresses() {
        let mut a = Asm::new("t");
        a.lw(T0, A0, 4);
        a.halt();
        let p = a.finish().unwrap();
        let (r, _) = analyze(&p, &[(A0, TCDM_BASE)]);
        assert_eq!(r.error_count(), 0);
        assert_eq!(
            r.resolved_mem[0],
            Some(MemFact { addr: TCDM_BASE + 4, bytes: 4, write: false })
        );
        // Without the entry fact the address is unresolved, not an error.
        let (r, _) = analyze(&p, &[]);
        assert!(r.resolved_mem[0].is_none());
        assert!(r.findings.iter().any(|f| f.kind == FindingKind::UnresolvedAccess));
    }

    #[test]
    fn loop_varying_pointer_goes_top() {
        let mut a = Asm::new("t");
        let end = a.label();
        a.li(A0, TCDM_BASE as i32);
        a.lp_setup_imm(0, 4, end);
        a.lw_pi(T0, A0, 4); // A0 varies across iterations
        a.bind(end);
        a.halt();
        let p = a.finish().unwrap();
        let (r, _) = analyze(&p, &[]);
        // Joined env makes the pointer Top: unresolved, no false error.
        assert_eq!(r.error_count(), 0);
        assert!(r.resolved_mem[2].is_none());
    }

    #[test]
    fn register_trip_count_resolves() {
        let mut a = Asm::new("t");
        let end = a.label();
        a.li(T0, 12);
        a.lp_setup(0, T0, end);
        a.addi(A0, A0, 1);
        a.bind(end);
        a.halt();
        let p = a.finish().unwrap();
        let (_, trips) = analyze(&p, &[(A0, 0)]);
        assert_eq!(trips.get(&1), Some(&12));
    }

    #[test]
    fn exec_alu_folding_matches_executor() {
        use crate::isa::inst::AluOp;
        // Spot-check the totality contract memcheck relies on.
        assert_eq!(exec::alu(AluOp::Div, 5, 0), u32::MAX);
        assert_eq!(exec::alu(AluOp::Rem, 5, 0), 5);
        let mut a = Asm::new("t");
        a.li(A0, TCDM_BASE as i32);
        a.addi(A0, A0, 64);
        a.slli(T0, A0, 0);
        a.lw(T1, T0, 0);
        a.halt();
        let p = a.finish().unwrap();
        let (r, _) = analyze(&p, &[]);
        assert_eq!(
            r.resolved_mem[3],
            Some(MemFact { addr: TCDM_BASE + 64, bytes: 4, write: false })
        );
        assert_eq!(r.error_count(), 0);
    }
}
