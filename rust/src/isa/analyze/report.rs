//! Typed diagnostics and the severity-ranked [`AnalysisReport`].
//!
//! Every check in the verifier ([`cfg`](super::cfg),
//! [`dataflow`](super::dataflow), [`memcheck`](super::memcheck)) funnels
//! into one report per (program, entry state): a list of [`Finding`]s
//! ordered most-severe-first plus the analyzer-proven facts the
//! static-vs-dynamic oracle tests replay against the live ISS
//! ([`crate::iss::trace`]). `vega verify` exits non-zero iff any report
//! carries an [`Severity::Error`] finding.

use std::fmt;

/// How bad a finding is. Ordered: `Info < Warning < Error`.
///
/// * `Error` — the program is wrong on every execution consistent with
///   the entry state (read of a register no instruction ever writes, a
///   constant-address access outside the SoC memory map or misaligned
///   for its element size, a proven-dead memory store, statically
///   unreachable code). `vega verify` fails the program.
/// * `Warning` — suspicious but not provably wrong on all paths
///   (possibly-uninitialized read on *some* path, a register write no
///   path reads, indirect jumps the CFG cannot resolve).
/// * `Info` — analysis facts worth surfacing (superblock candidates
///   with trip bounds, counts of run-time-computed addresses left to
///   the dynamic oracle).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Severity {
    Info,
    Warning,
    Error,
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Severity::Info => "info",
            Severity::Warning => "warning",
            Severity::Error => "error",
        };
        f.write_str(s)
    }
}

/// The closed set of diagnostic classes the verifier emits.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FindingKind {
    /// Read of a register no reachable instruction ever writes and the
    /// entry state does not initialize (Error).
    UninitRead,
    /// Read of a register that is written somewhere, but not on every
    /// path from entry (Warning).
    MaybeUninitRead,
    /// Register write that no path ever reads back (Warning — the
    /// conditional-select idiom in the kmeans/svm argmin loops makes
    /// genuinely-dead final writes on purpose).
    DeadRegWrite,
    /// Computation into x0, which is hardwired zero (Warning; `jal
    /// x0`/`jalr x0` are the idiomatic discard and exempt).
    WriteToZero,
    /// Two stores to the same constant (address, size) in one basic
    /// block with nothing in between that could read it (Error).
    DeadStore,
    /// Constant-address access outside every core-addressable region
    /// of the SoC map, or crossing a region's end (Error).
    OutOfBounds,
    /// Constant address not aligned to the access element size (Error).
    Misaligned,
    /// Basic block no path from entry reaches (Error).
    UnreachableBlock,
    /// `jalr`: a CFG edge the analyzer cannot resolve (Warning).
    IndirectJump,
    /// Retreating CFG edge whose target does not dominate its source —
    /// a loop with multiple entries (Warning).
    IrreducibleLoop,
    /// Straight-line hardware-loop body: replayable as a superblock
    /// (Info, with a static trip bound when derivable — ROADMAP
    /// feedstock).
    SuperblockCandidate,
    /// Count of accesses whose addresses are run-time-computed; these
    /// are checked dynamically by the oracle tests (Info).
    UnresolvedAccess,
}

impl FindingKind {
    /// Stable lowercase name (rendered, and matched by golden tests).
    pub fn name(self) -> &'static str {
        match self {
            FindingKind::UninitRead => "uninit-read",
            FindingKind::MaybeUninitRead => "maybe-uninit-read",
            FindingKind::DeadRegWrite => "dead-reg-write",
            FindingKind::WriteToZero => "write-to-zero",
            FindingKind::DeadStore => "dead-store",
            FindingKind::OutOfBounds => "out-of-bounds",
            FindingKind::Misaligned => "misaligned",
            FindingKind::UnreachableBlock => "unreachable-block",
            FindingKind::IndirectJump => "indirect-jump",
            FindingKind::IrreducibleLoop => "irreducible-loop",
            FindingKind::SuperblockCandidate => "superblock-candidate",
            FindingKind::UnresolvedAccess => "unresolved-access",
        }
    }
}

/// One diagnostic, anchored to an instruction where that makes sense.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub severity: Severity,
    pub kind: FindingKind,
    /// Instruction index (the ISS pc), when the finding is local.
    pub pc: Option<usize>,
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.pc {
            Some(pc) => {
                write!(f, "{}[{}] pc {}: {}", self.severity, self.kind.name(), pc, self.message)
            }
            None => write!(f, "{}[{}]: {}", self.severity, self.kind.name(), self.message),
        }
    }
}

/// A memory access whose address the analyzer proved constant for the
/// given entry state: it holds on *every* dynamic execution of that pc,
/// which is exactly what the oracle tests assert against the traced ISS.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemFact {
    pub addr: u32,
    pub bytes: u32,
    pub write: bool,
}

/// The verifier's result for one program under one entry state.
#[derive(Debug, Clone)]
pub struct AnalysisReport {
    /// Program name (from the assembler).
    pub program: String,
    /// Diagnostics, most severe first ([`AnalysisReport::sort`]).
    pub findings: Vec<Finding>,
    /// Basic blocks in the CFG.
    pub n_blocks: usize,
    /// Loops (hardware loops + branch back-edges).
    pub n_loops: usize,
    /// Per-pc: does any path from entry reach this instruction's block?
    /// (Oracle: every dynamically executed pc must be reachable.)
    pub reachable_pcs: Vec<bool>,
    /// Registers any reachable instruction may write, as an x0..x31
    /// bitmask with bit 0 clear. (Oracle: the traced register-write set
    /// must be a subset.)
    pub may_def_mask: u32,
    /// Per-pc proven-constant memory accesses. (Oracle: the traced
    /// address set at such a pc must be exactly `{addr}`.)
    pub resolved_mem: Vec<Option<MemFact>>,
    /// TCDM banks (16, word-interleaved) touched by resolved accesses.
    pub tcdm_bank_mask: u16,
}

impl AnalysisReport {
    pub fn new(program: &str, prog_len: usize) -> Self {
        Self {
            program: program.to_string(),
            findings: Vec::new(),
            n_blocks: 0,
            n_loops: 0,
            reachable_pcs: vec![false; prog_len],
            may_def_mask: 0,
            resolved_mem: vec![None; prog_len],
            tcdm_bank_mask: 0,
        }
    }

    pub fn push(
        &mut self,
        severity: Severity,
        kind: FindingKind,
        pc: Option<usize>,
        message: impl Into<String>,
    ) {
        self.findings.push(Finding { severity, kind, pc, message: message.into() });
    }

    /// Order findings most-severe-first, then by pc, then by kind name
    /// (deterministic render for golden tests).
    pub fn sort(&mut self) {
        self.findings.sort_by(|a, b| {
            b.severity
                .cmp(&a.severity)
                .then(a.pc.cmp(&b.pc))
                .then(a.kind.name().cmp(b.kind.name()))
                .then(a.message.cmp(&b.message))
        });
    }

    pub fn count(&self, s: Severity) -> usize {
        self.findings.iter().filter(|f| f.severity == s).count()
    }

    pub fn error_count(&self) -> usize {
        self.count(Severity::Error)
    }

    /// Does the report contain a finding of `kind` at `Error` severity?
    pub fn has_error(&self, kind: FindingKind) -> bool {
        self.findings.iter().any(|f| f.severity == Severity::Error && f.kind == kind)
    }

    /// Human-readable render (one line per finding plus a summary).
    pub fn render(&self) -> String {
        let mut out = String::new();
        let resolved = self.resolved_mem.iter().filter(|m| m.is_some()).count();
        out.push_str(&format!(
            "{}: {} blocks, {} loops, {} resolved accesses, banks {:04x}\n",
            self.program, self.n_blocks, self.n_loops, resolved, self.tcdm_bank_mask
        ));
        for f in &self.findings {
            out.push_str(&format!("  {f}\n"));
        }
        out.push_str(&format!(
            "  {} error(s), {} warning(s), {} info\n",
            self.error_count(),
            self.count(Severity::Warning),
            self.count(Severity::Info)
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn severity_orders_and_sorts() {
        assert!(Severity::Error > Severity::Warning);
        assert!(Severity::Warning > Severity::Info);
        let mut r = AnalysisReport::new("t", 4);
        r.push(Severity::Info, FindingKind::SuperblockCandidate, Some(1), "a");
        r.push(Severity::Error, FindingKind::UninitRead, Some(3), "b");
        r.push(Severity::Warning, FindingKind::DeadRegWrite, Some(0), "c");
        r.sort();
        assert_eq!(r.findings[0].kind, FindingKind::UninitRead);
        assert_eq!(r.findings[2].kind, FindingKind::SuperblockCandidate);
        assert_eq!(r.error_count(), 1);
        assert!(r.has_error(FindingKind::UninitRead));
        assert!(!r.has_error(FindingKind::DeadRegWrite));
    }

    #[test]
    fn render_names_are_stable() {
        // Golden tests grep these names; renames are a breaking change.
        assert_eq!(FindingKind::UninitRead.name(), "uninit-read");
        assert_eq!(FindingKind::OutOfBounds.name(), "out-of-bounds");
        assert_eq!(FindingKind::Misaligned.name(), "misaligned");
        assert_eq!(FindingKind::UnreachableBlock.name(), "unreachable-block");
        assert_eq!(FindingKind::DeadStore.name(), "dead-store");
    }
}
