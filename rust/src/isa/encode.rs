//! Canonical byte encoding of the symbolic ISA (the persisted-key
//! contract).
//!
//! [`Program::content_hash`](crate::isa::Program::content_hash) keys every
//! on-disk cache entry, so the bytes it hashes must be *defined by this
//! crate*, not by `#[derive(Hash)]` — Rust documents that derived `Hash`
//! output may change between releases, and a silent change would orphan
//! every persisted simulation (the PR 3 store shipped with exactly that
//! caveat). This module is the fix: every [`Inst`] variant and operand
//! enum gets an explicit, versioned little-endian encoding, asserted
//! byte-for-byte by golden-vector tests (`tests/isa_encoding.rs`) so key
//! stability is a CI invariant rather than a convention.
//!
//! ## Layout (version [`ISA_ENCODING_VERSION`])
//!
//! One opcode byte selects the variant, then operands follow in
//! declaration order; registers are one byte, immediates are `i32` LE,
//! targets are `u32` LE. The opcode determines the record length, so the
//! concatenated stream is self-delimiting and the encoding is injective
//! on instruction streams (property-tested).
//!
//! ```text
//! 0x01 Alu      op:u8 rd rs1 rs2
//! 0x02 AluImm   op:u8 rd rs1 imm:i32
//! 0x03 Li       rd imm:i32
//! 0x04 Load     size:u8 rd rs1 imm:i32 post_inc:u8
//! 0x05 Store    size:u8 rs2 rs1 imm:i32 post_inc:u8
//! 0x06 Branch   cond:u8 rs1 rs2 target:u32
//! 0x07 Jal      rd target:u32
//! 0x08 Jalr     rd rs1
//! 0x09 Mac      rd rs1 rs2
//! 0x0A Msu      rd rs1 rs2
//! 0x0B Simd     op:u8 fmt:u8 rd rs1 rs2
//! 0x0C LpSetup  lp:u8 tag:u8 (0=imm,1=reg) value:u32 body_end:u32
//! 0x0D Fp       op:u8 fmt:u8 rd rs1 rs2
//! 0x0E Barrier
//! 0x0F Halt
//! 0x10 Nop
//! ```
//!
//! Changing any code or layout here is a **breaking key change**: bump
//! [`ISA_ENCODING_VERSION`] (the version is hashed into every content
//! hash, so old on-disk entries are orphaned, never misread) and update
//! the golden vectors deliberately in the same commit. *Appending* a new
//! operand code (e.g. `FpFmt::VB4 = 5`, the fp8 SIMD format) is additive:
//! no existing byte changes, so no version bump and no orphaned entries —
//! only new keys that older builds simply never produced.

use super::inst::{AluOp, Cond, FpFmt, FpOp, Inst, LoopCount, MemSize, SimdFmt, SimdOp};

/// Version of the byte layout below, hashed into every
/// [`Program::content_hash`](crate::isa::Program::content_hash). Bump on
/// any change to the opcode table, operand codes, or field layout.
pub const ISA_ENCODING_VERSION: u32 = 1;

impl Cond {
    /// Stable wire code (golden-asserted; append-only).
    pub fn code(self) -> u8 {
        match self {
            Cond::Eq => 0,
            Cond::Ne => 1,
            Cond::Lt => 2,
            Cond::Ge => 3,
            Cond::Ltu => 4,
            Cond::Geu => 5,
        }
    }
}

impl AluOp {
    /// Stable wire code (golden-asserted; append-only).
    pub fn code(self) -> u8 {
        match self {
            AluOp::Add => 0,
            AluOp::Sub => 1,
            AluOp::Sll => 2,
            AluOp::Srl => 3,
            AluOp::Sra => 4,
            AluOp::And => 5,
            AluOp::Or => 6,
            AluOp::Xor => 7,
            AluOp::Slt => 8,
            AluOp::Sltu => 9,
            AluOp::Mul => 10,
            AluOp::Mulh => 11,
            AluOp::Div => 12,
            AluOp::Divu => 13,
            AluOp::Rem => 14,
            AluOp::Remu => 15,
            AluOp::Min => 16,
            AluOp::Max => 17,
            AluOp::Abs => 18,
            AluOp::Clip => 19,
        }
    }
}

impl MemSize {
    /// Stable wire code (golden-asserted; append-only).
    pub fn code(self) -> u8 {
        match self {
            MemSize::B => 0,
            MemSize::Bu => 1,
            MemSize::H => 2,
            MemSize::Hu => 3,
            MemSize::W => 4,
        }
    }
}

impl SimdFmt {
    /// Stable wire code (golden-asserted; append-only).
    pub fn code(self) -> u8 {
        match self {
            SimdFmt::B4 => 0,
            SimdFmt::H2 => 1,
        }
    }
}

impl SimdOp {
    /// Stable wire code (golden-asserted; append-only).
    pub fn code(self) -> u8 {
        match self {
            SimdOp::Add => 0,
            SimdOp::Sub => 1,
            SimdOp::Min => 2,
            SimdOp::Max => 3,
            SimdOp::Avg => 4,
            SimdOp::SDotSp => 5,
            SimdOp::SDotUp => 6,
            SimdOp::Pack => 7,
        }
    }
}

impl FpFmt {
    /// Stable wire code (golden-asserted; append-only).
    pub fn code(self) -> u8 {
        match self {
            FpFmt::S => 0,
            FpFmt::H => 1,
            FpFmt::B => 2,
            FpFmt::VH => 3,
            FpFmt::VB => 4,
            FpFmt::VB4 => 5,
        }
    }
}

impl FpOp {
    /// Stable wire code (golden-asserted; append-only).
    pub fn code(self) -> u8 {
        match self {
            FpOp::Add => 0,
            FpOp::Sub => 1,
            FpOp::Mul => 2,
            FpOp::Madd => 3,
            FpOp::Msub => 4,
            FpOp::Min => 5,
            FpOp::Max => 6,
            FpOp::Div => 7,
            FpOp::Sqrt => 8,
            FpOp::Abs => 9,
            FpOp::Neg => 10,
            FpOp::CmpLt => 11,
            FpOp::CmpLe => 12,
            FpOp::CmpEq => 13,
            FpOp::CvtIF => 14,
            FpOp::CvtFI => 15,
            FpOp::CvtSH2 => 16,
            FpOp::CvtH2S0 => 17,
            FpOp::CvtH2S1 => 18,
            FpOp::DotpEx => 19,
        }
    }
}

fn target_u32(t: usize) -> u32 {
    debug_assert!(t <= u32::MAX as usize, "branch target {t} exceeds u32");
    t as u32
}

impl Inst {
    /// Append this instruction's canonical encoding to `out`.
    ///
    /// The layout is the module-level opcode table; the opcode byte
    /// determines the record length, so concatenated encodings parse
    /// unambiguously and distinct instruction streams encode to distinct
    /// byte streams.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        match *self {
            Inst::Alu { op, rd, rs1, rs2 } => {
                out.extend_from_slice(&[0x01, op.code(), rd, rs1, rs2]);
            }
            Inst::AluImm { op, rd, rs1, imm } => {
                out.extend_from_slice(&[0x02, op.code(), rd, rs1]);
                out.extend_from_slice(&imm.to_le_bytes());
            }
            Inst::Li { rd, imm } => {
                out.extend_from_slice(&[0x03, rd]);
                out.extend_from_slice(&imm.to_le_bytes());
            }
            Inst::Load { size, rd, rs1, imm, post_inc } => {
                out.extend_from_slice(&[0x04, size.code(), rd, rs1]);
                out.extend_from_slice(&imm.to_le_bytes());
                out.push(post_inc as u8);
            }
            Inst::Store { size, rs2, rs1, imm, post_inc } => {
                out.extend_from_slice(&[0x05, size.code(), rs2, rs1]);
                out.extend_from_slice(&imm.to_le_bytes());
                out.push(post_inc as u8);
            }
            Inst::Branch { cond, rs1, rs2, target } => {
                out.extend_from_slice(&[0x06, cond.code(), rs1, rs2]);
                out.extend_from_slice(&target_u32(target).to_le_bytes());
            }
            Inst::Jal { rd, target } => {
                out.extend_from_slice(&[0x07, rd]);
                out.extend_from_slice(&target_u32(target).to_le_bytes());
            }
            Inst::Jalr { rd, rs1 } => {
                out.extend_from_slice(&[0x08, rd, rs1]);
            }
            Inst::Mac { rd, rs1, rs2 } => {
                out.extend_from_slice(&[0x09, rd, rs1, rs2]);
            }
            Inst::Msu { rd, rs1, rs2 } => {
                out.extend_from_slice(&[0x0A, rd, rs1, rs2]);
            }
            Inst::Simd { op, fmt, rd, rs1, rs2 } => {
                out.extend_from_slice(&[0x0B, op.code(), fmt.code(), rd, rs1, rs2]);
            }
            Inst::LpSetup { lp, count, body_end } => {
                let (tag, value) = match count {
                    LoopCount::Imm(n) => (0u8, n),
                    LoopCount::Reg(r) => (1u8, r as u32),
                };
                out.extend_from_slice(&[0x0C, lp, tag]);
                out.extend_from_slice(&value.to_le_bytes());
                out.extend_from_slice(&target_u32(body_end).to_le_bytes());
            }
            Inst::Fp { op, fmt, rd, rs1, rs2 } => {
                out.extend_from_slice(&[0x0D, op.code(), fmt.code(), rd, rs1, rs2]);
            }
            Inst::Barrier => out.push(0x0E),
            Inst::Halt => out.push(0x0F),
            Inst::Nop => out.push(0x10),
        }
    }

    /// This instruction's canonical encoding as a fresh vector
    /// (convenience over [`Inst::encode_into`]).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(14);
        self.encode_into(&mut out);
        out
    }
}

/// Encode a resolved instruction stream: the [`ISA_ENCODING_VERSION`]
/// (u32 LE), the instruction count (u32 LE), then each instruction's
/// record. This is the exact byte stream
/// [`Program::content_hash`](crate::isa::Program::content_hash) runs the
/// pinned FNV-1a over.
pub fn encode_stream(insts: &[Inst]) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 + insts.len() * 14);
    out.extend_from_slice(&ISA_ENCODING_VERSION.to_le_bytes());
    out.extend_from_slice(&(insts.len() as u32).to_le_bytes());
    for i in insts {
        i.encode_into(&mut out);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_lengths_match_the_opcode_table() {
        let cases: [(Inst, usize); 17] = [
            (Inst::Alu { op: AluOp::Add, rd: 1, rs1: 2, rs2: 3 }, 5),
            (Inst::AluImm { op: AluOp::Add, rd: 1, rs1: 2, imm: -1 }, 8),
            (Inst::Li { rd: 1, imm: 7 }, 6),
            (Inst::Load { size: MemSize::W, rd: 1, rs1: 2, imm: 4, post_inc: true }, 9),
            (Inst::Store { size: MemSize::B, rs2: 1, rs1: 2, imm: 0, post_inc: false }, 9),
            (Inst::Branch { cond: Cond::Ne, rs1: 1, rs2: 2, target: 9 }, 8),
            (Inst::Jal { rd: 0, target: 3 }, 6),
            (Inst::Jalr { rd: 0, rs1: 1 }, 3),
            (Inst::Mac { rd: 1, rs1: 2, rs2: 3 }, 4),
            (Inst::Msu { rd: 1, rs1: 2, rs2: 3 }, 4),
            (Inst::Simd { op: SimdOp::SDotSp, fmt: SimdFmt::B4, rd: 1, rs1: 2, rs2: 3 }, 6),
            (Inst::LpSetup { lp: 0, count: LoopCount::Imm(10), body_end: 4 }, 11),
            (Inst::LpSetup { lp: 1, count: LoopCount::Reg(5), body_end: 4 }, 11),
            (Inst::Fp { op: FpOp::Madd, fmt: FpFmt::S, rd: 1, rs1: 2, rs2: 3 }, 6),
            (Inst::Barrier, 1),
            (Inst::Halt, 1),
            (Inst::Nop, 1),
        ];
        for (inst, want) in cases {
            assert_eq!(inst.encode().len(), want, "{inst:?}");
        }
    }

    #[test]
    fn stream_prefixes_version_and_count() {
        let bytes = encode_stream(&[Inst::Nop, Inst::Halt]);
        assert_eq!(&bytes[..4], &ISA_ENCODING_VERSION.to_le_bytes());
        assert_eq!(&bytes[4..8], &2u32.to_le_bytes());
        assert_eq!(&bytes[8..], &[0x10, 0x0F]);
    }

    #[test]
    fn loop_count_forms_disambiguate() {
        // Imm(5) and Reg(5) carry the same value word; only the tag
        // separates them — it must.
        let imm = Inst::LpSetup { lp: 0, count: LoopCount::Imm(5), body_end: 2 }.encode();
        let reg = Inst::LpSetup { lp: 0, count: LoopCount::Reg(5), body_end: 2 }.encode();
        assert_ne!(imm, reg);
        assert_eq!(imm[2], 0);
        assert_eq!(reg[2], 1);
    }
}
