//! Symbolic instruction definitions and static classification.
//!
//! Each variant carries exactly the operands the ISS needs; classification
//! ([`Inst::class`]) and operation counting ([`Inst::ops`]) feed the
//! performance counters behind Table V (FP intensity) and Figs. 6/8
//! (GOPS / GFLOPS: 1 MAC = 2 ops, per the paper's footnotes).

use super::Reg;

/// Branch conditions (RV32I B-type).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Cond {
    Eq,
    Ne,
    Lt,
    Ge,
    Ltu,
    Geu,
}

/// Integer ALU operations (RV32IM + Xpulp scalar extras).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AluOp {
    Add,
    Sub,
    Sll,
    Srl,
    Sra,
    And,
    Or,
    Xor,
    Slt,
    Sltu,
    Mul,
    Mulh,
    Div,
    Divu,
    Rem,
    Remu,
    /// Xpulp: p.min / p.max / p.abs (abs ignores rs2).
    Min,
    Max,
    Abs,
    /// Xpulp: p.clip rd = clamp(rs1, -2^imm, 2^imm - 1) (imm form only).
    Clip,
}

impl AluOp {
    /// RI5CY latency: MUL is single-cycle; DIV/REM use the 35-cycle serial
    /// divider.
    pub fn cycles(self) -> u64 {
        match self {
            AluOp::Div | AluOp::Divu | AluOp::Rem | AluOp::Remu => 35,
            _ => 1,
        }
    }
}

/// Memory access widths. Sub-word loads sign- or zero-extend.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MemSize {
    B,
    Bu,
    H,
    Hu,
    W,
}

impl MemSize {
    pub fn bytes(self) -> u32 {
        match self {
            MemSize::B | MemSize::Bu => 1,
            MemSize::H | MemSize::Hu => 2,
            MemSize::W => 4,
        }
    }
}

/// Packed-SIMD element format (Xpulp v2: one 32-bit register holds 4×i8 or
/// 2×i16).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SimdFmt {
    B4,
    H2,
}

/// Packed-SIMD integer operations.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SimdOp {
    Add,
    Sub,
    Min,
    Max,
    Avg,
    /// pv.sdotsp: signed dot product accumulated into rd (rd += Σ a_i·b_i).
    /// This is the PULP-NN workhorse: 4 MACs per instruction in B4.
    SDotSp,
    /// pv.sdotup: unsigned-by-signed variant (activations × weights).
    SDotUp,
    /// pv.shuffle2-style byte pack (used by the FP16 cast-and-pack path).
    Pack,
}

/// Floating-point formats of the shared FPnew-style FPU (Fig. 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FpFmt {
    /// Scalar IEEE binary32.
    S,
    /// Scalar IEEE binary16.
    H,
    /// Scalar bfloat16.
    B,
    /// Packed 2×binary16 SIMD.
    VH,
    /// Packed 2×bfloat16 SIMD.
    VB,
    /// Packed 4×binary8 (E5M2 smallFloat FP8) SIMD — the 8-bit mode the
    /// shared FPUs advertise behind the paper's 8-bit efficiency point.
    /// Four lanes per 32-bit register, like [`SimdFmt::B4`] on the
    /// integer side.
    VB4,
}

impl FpFmt {
    pub fn lanes(self) -> u32 {
        match self {
            FpFmt::S | FpFmt::H | FpFmt::B => 1,
            FpFmt::VH | FpFmt::VB => 2,
            FpFmt::VB4 => 4,
        }
    }
}

/// Floating-point operations (subset of FPnew used by the NSAA kernels).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FpOp {
    Add,
    Sub,
    Mul,
    /// Fused multiply-add: rd = rs1·rs2 + rs3 (single-cycle on Vega's
    /// shared FPU; the key NSAA operation per §II-C).
    Madd,
    /// rd = rs3 - rs1·rs2.
    Msub,
    Min,
    Max,
    /// Stand-alone shared DIV-SQRT unit (multi-cycle).
    Div,
    Sqrt,
    Abs,
    Neg,
    /// Comparisons write 0/1 to the integer view of rd.
    CmpLt,
    CmpLe,
    CmpEq,
    /// Conversions: int32 → fmt and fmt → int32 (truncating).
    CvtIF,
    CvtFI,
    /// Format conversion fmt→fmt2 uses `Cvt { to }`-style pairs; the
    /// cast-and-pack instruction converting 2×f32 into a packed 2×f16
    /// register (§II-C "cast-and-pack").
    CvtSH2,
    /// Widening from packed half to f32 lane 0 / lane 1.
    CvtH2S0,
    CvtH2S1,
    /// Multi-format dot product accumulating into a wider rd: f32 rd +=
    /// Σ rs1.lane_i·rs2.lane_i ("taking the product of two 16-bit
    /// operands and returning a 32-bit single-precision result", §II-C).
    /// One FMA per input lane: 2 FMAs = 4 FLOPs in `VH`/`VB`, 4 FMAs =
    /// 8 FLOPs in `VB4` — still a single pipelined FPU issue, which is
    /// what makes the fp8 path 4 MACs per FPU op in the timing model.
    DotpEx,
}

impl FpOp {
    /// Issue-to-result latency. All pipelined FPU ops are single-cycle on
    /// Vega (the static FPU mapping keeps them off the critical path,
    /// §II-C); DIV/SQRT occupy the shared iterative unit.
    pub fn cycles(self) -> u64 {
        match self {
            FpOp::Div => 11,
            FpOp::Sqrt => 15,
            _ => 1,
        }
    }

    /// Does this op use the shared DIV-SQRT unit instead of an FPU slice?
    pub fn is_divsqrt(self) -> bool {
        matches!(self, FpOp::Div | FpOp::Sqrt)
    }

    /// FLOPs retired by one instruction in format `fmt`.
    pub fn flops(self, fmt: FpFmt) -> u64 {
        let lanes = fmt.lanes() as u64;
        match self {
            FpOp::Madd | FpOp::Msub => 2 * lanes,
            FpOp::DotpEx => 2 * lanes,
            FpOp::Add | FpOp::Sub | FpOp::Mul | FpOp::Min | FpOp::Max => lanes,
            FpOp::Div | FpOp::Sqrt => lanes,
            _ => 0,
        }
    }
}

/// Hardware-loop trip count: immediate or register.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LoopCount {
    Imm(u32),
    Reg(Reg),
}

/// Branch/jump target: resolved instruction index (PC).
pub type Target = usize;

/// One symbolic instruction.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Inst {
    /// ALU register-register.
    Alu { op: AluOp, rd: Reg, rs1: Reg, rs2: Reg },
    /// ALU register-immediate (Sub not available; use Add with -imm).
    AluImm { op: AluOp, rd: Reg, rs1: Reg, imm: i32 },
    /// Load immediate (li pseudo; 1 cycle, as RI5CY fuses lui+addi rarely
    /// matters for kernels where li sits outside loops).
    Li { rd: Reg, imm: i32 },
    /// Load: rd = mem[rs1 + imm]; post_inc (Xpulp p.lw) adds imm to rs1
    /// *after* the access and ignores it in address formation is offset
    /// form rs1! semantics: addr = rs1, rs1 += imm.
    Load { size: MemSize, rd: Reg, rs1: Reg, imm: i32, post_inc: bool },
    /// Store: mem[rs1 + imm] = rs2 (post_inc as for Load).
    Store { size: MemSize, rs2: Reg, rs1: Reg, imm: i32, post_inc: bool },
    Branch { cond: Cond, rs1: Reg, rs2: Reg, target: Target },
    Jal { rd: Reg, target: Target },
    /// Indirect jump (used for returns; rare in kernels).
    Jalr { rd: Reg, rs1: Reg },
    /// Xpulp p.mac: rd += rs1·rs2 (32-bit).
    Mac { rd: Reg, rs1: Reg, rs2: Reg },
    /// Xpulp p.msu: rd -= rs1·rs2.
    Msu { rd: Reg, rs1: Reg, rs2: Reg },
    /// Packed-SIMD integer op.
    Simd { op: SimdOp, fmt: SimdFmt, rd: Reg, rs1: Reg, rs2: Reg },
    /// Hardware loop: body is `[pc+1, body_end)`, iterated `count` times
    /// with zero branch overhead (lp.setup).
    LpSetup { lp: u8, count: LoopCount, body_end: Target },
    /// Floating-point op (single register file; rs3 only for Madd/Msub).
    Fp { op: FpOp, fmt: FpFmt, rd: Reg, rs1: Reg, rs2: Reg },
    /// Event-unit barrier: block until all cores in the team arrive
    /// (2-cycle wake-up, §II-C).
    Barrier,
    /// Stop this core.
    Halt,
    Nop,
}

/// Coarse classification for the instruction-mix statistics (Table V
/// computes "FP intensity" = FP instructions / total instructions at ISA
/// level).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum InstClass {
    Alu,
    Mul,
    Div,
    Load,
    Store,
    Branch,
    Fp,
    Simd,
    Control,
}

impl Inst {
    pub fn class(&self) -> InstClass {
        match self {
            Inst::Alu { op, .. } | Inst::AluImm { op, .. } => match op {
                AluOp::Mul | AluOp::Mulh => InstClass::Mul,
                AluOp::Div | AluOp::Divu | AluOp::Rem | AluOp::Remu => InstClass::Div,
                _ => InstClass::Alu,
            },
            Inst::Li { .. } => InstClass::Alu,
            Inst::Load { .. } => InstClass::Load,
            Inst::Store { .. } => InstClass::Store,
            Inst::Branch { .. } => InstClass::Branch,
            Inst::Jal { .. } | Inst::Jalr { .. } => InstClass::Branch,
            Inst::Mac { .. } | Inst::Msu { .. } => InstClass::Mul,
            Inst::Simd { .. } => InstClass::Simd,
            Inst::Fp { .. } => InstClass::Fp,
            Inst::LpSetup { .. } | Inst::Barrier | Inst::Halt | Inst::Nop => InstClass::Control,
        }
    }

    /// Integer "operations" retired (the paper's OPS metric: 1 MAC = 2 ops,
    /// one SIMD lane op = 1 op).
    pub fn int_ops(&self) -> u64 {
        match self {
            Inst::Alu { .. } | Inst::AluImm { .. } | Inst::Li { .. } => 1,
            Inst::Mac { .. } | Inst::Msu { .. } => 2,
            Inst::Simd { op, fmt, .. } => {
                let lanes = match fmt {
                    SimdFmt::B4 => 4,
                    SimdFmt::H2 => 2,
                };
                match op {
                    SimdOp::SDotSp | SimdOp::SDotUp => 2 * lanes, // lanes MACs
                    _ => lanes,
                }
            }
            _ => 0,
        }
    }

    /// FLOPs retired.
    pub fn flops(&self) -> u64 {
        match self {
            Inst::Fp { op, fmt, .. } => op.flops(*fmt),
            _ => 0,
        }
    }

    pub fn is_fp(&self) -> bool {
        matches!(self, Inst::Fp { .. })
    }

    /// Registers read by this instruction (for hazard tracking).
    pub fn srcs(&self) -> [Option<Reg>; 3] {
        match *self {
            Inst::Alu { rs1, rs2, .. } => [Some(rs1), Some(rs2), None],
            Inst::AluImm { rs1, .. } => [Some(rs1), None, None],
            Inst::Li { .. } => [None, None, None],
            Inst::Load { rs1, .. } => [Some(rs1), None, None],
            Inst::Store { rs1, rs2, .. } => [Some(rs1), Some(rs2), None],
            Inst::Branch { rs1, rs2, .. } => [Some(rs1), Some(rs2), None],
            Inst::Jal { .. } => [None, None, None],
            Inst::Jalr { rs1, .. } => [Some(rs1), None, None],
            Inst::Mac { rd, rs1, rs2 } | Inst::Msu { rd, rs1, rs2 } => {
                [Some(rs1), Some(rs2), Some(rd)]
            }
            Inst::Simd { op, rd, rs1, rs2, .. } => match op {
                SimdOp::SDotSp | SimdOp::SDotUp => [Some(rs1), Some(rs2), Some(rd)],
                _ => [Some(rs1), Some(rs2), None],
            },
            Inst::LpSetup { count: LoopCount::Reg(r), .. } => [Some(r), None, None],
            Inst::LpSetup { .. } => [None, None, None],
            Inst::Fp { op, rd, rs1, rs2, .. } => match op {
                // Madd/Msub/DotpEx read the accumulator.
                FpOp::Madd | FpOp::Msub | FpOp::DotpEx => [Some(rs1), Some(rs2), Some(rd)],
                FpOp::Sqrt | FpOp::Abs | FpOp::Neg | FpOp::CvtIF | FpOp::CvtFI
                | FpOp::CvtH2S0 | FpOp::CvtH2S1 => [Some(rs1), None, None],
                _ => [Some(rs1), Some(rs2), None],
            },
            Inst::Barrier | Inst::Halt | Inst::Nop => [None, None, None],
        }
    }

    /// Destination register, if any.
    pub fn dst(&self) -> Option<Reg> {
        match *self {
            Inst::Alu { rd, .. }
            | Inst::AluImm { rd, .. }
            | Inst::Li { rd, .. }
            | Inst::Load { rd, .. }
            | Inst::Mac { rd, .. }
            | Inst::Msu { rd, .. }
            | Inst::Simd { rd, .. }
            | Inst::Fp { rd, .. } => Some(rd),
            Inst::Jal { rd, .. } | Inst::Jalr { rd, .. } => Some(rd),
            // No wildcard: a new variant must state its destination here
            // (and get handlers in isa/analyze — see analyze::dataflow).
            Inst::Store { .. }
            | Inst::Branch { .. }
            | Inst::LpSetup { .. }
            | Inst::Barrier
            | Inst::Halt
            | Inst::Nop => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sdotsp_b4_counts_8_ops() {
        let i = Inst::Simd { op: SimdOp::SDotSp, fmt: SimdFmt::B4, rd: 1, rs1: 2, rs2: 3 };
        assert_eq!(i.int_ops(), 8);
        assert_eq!(i.class(), InstClass::Simd);
    }

    #[test]
    fn fp_flop_counts() {
        let madd = Inst::Fp { op: FpOp::Madd, fmt: FpFmt::S, rd: 1, rs1: 2, rs2: 3 };
        assert_eq!(madd.flops(), 2);
        let vadd = Inst::Fp { op: FpOp::Add, fmt: FpFmt::VH, rd: 1, rs1: 2, rs2: 3 };
        assert_eq!(vadd.flops(), 2);
        let dotp = Inst::Fp { op: FpOp::DotpEx, fmt: FpFmt::VH, rd: 1, rs1: 2, rs2: 3 };
        assert_eq!(dotp.flops(), 4);
        // fp8 SIMD: 4 lanes per register, 4 MACs = 8 FLOPs per issue.
        let dotp8 = Inst::Fp { op: FpOp::DotpEx, fmt: FpFmt::VB4, rd: 1, rs1: 2, rs2: 3 };
        assert_eq!(FpFmt::VB4.lanes(), 4);
        assert_eq!(dotp8.flops(), 8);
        assert_eq!(FpOp::DotpEx.cycles(), 1, "fp8 dot product stays single-issue");
    }

    #[test]
    fn hazard_sources_include_accumulators() {
        let mac = Inst::Mac { rd: 5, rs1: 6, rs2: 7 };
        assert!(mac.srcs().contains(&Some(5)));
        assert_eq!(mac.dst(), Some(5));
    }

    #[test]
    fn div_latency() {
        assert_eq!(AluOp::Div.cycles(), 35);
        assert_eq!(AluOp::Mul.cycles(), 1);
        assert_eq!(FpOp::Sqrt.cycles(), 15);
        assert!(FpOp::Sqrt.is_divsqrt());
        assert!(!FpOp::Madd.is_divsqrt());
    }
}
