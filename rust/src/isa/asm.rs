//! The in-Rust macro-assembler.
//!
//! Kernels are authored as Rust functions building a [`Program`] through
//! one method per mnemonic, with forward-referencable [`Label`]s and the
//! two Xpulp hardware-loop channels. Replaces the GCC+Xpulp toolchain the
//! paper used (DESIGN.md §5): the instruction mix the paper measures at
//! ISA level is reproduced exactly because we emit it explicitly.

use crate::common::{Result, VegaError};

use super::inst::{AluOp, Cond, FpFmt, FpOp, Inst, LoopCount, MemSize, SimdFmt, SimdOp};
use super::Reg;

/// A forward-referencable code label.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Label(usize);

/// A finished, label-resolved instruction stream. PCs are indices.
#[derive(Debug, Clone, Default)]
pub struct Program {
    pub insts: Vec<Inst>,
    pub name: String,
}

impl Program {
    pub fn len(&self) -> usize {
        self.insts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.insts.is_empty()
    }

    /// Deterministic content hash of the resolved instruction stream.
    ///
    /// Part of the sweep-cache key ([`crate::sweep::SimKey`]): any change
    /// to a kernel's emitted instructions changes this hash, so memoized
    /// stats can never go stale against the program they were measured
    /// on. The hasher is the crate's pinned FNV-1a and the byte stream is
    /// the explicit versioned encoding of [`crate::isa::encode`] — never
    /// a derived `Hash` impl — so the hash is stable across builds *and
    /// toolchains* and safe to persist in on-disk cache keys
    /// (golden-asserted by `tests/isa_encoding.rs`).
    pub fn content_hash(&self) -> u64 {
        use std::hash::Hasher;
        let mut h = crate::common::Fnv1a::new();
        h.write(&super::encode::encode_stream(&self.insts));
        h.finish()
    }

    /// Static instruction-mix summary (Table V's "FP intensity" is
    /// computed on kernel assembly code, i.e. statically).
    pub fn static_fp_intensity(&self) -> f64 {
        let total = self
            .insts
            .iter()
            .filter(|i| !matches!(i, Inst::Halt | Inst::Nop | Inst::Barrier))
            .count();
        if total == 0 {
            return 0.0;
        }
        let fp = self.insts.iter().filter(|i| i.is_fp()).count();
        fp as f64 / total as f64
    }
}

/// The assembler/builder.
pub struct Asm {
    insts: Vec<Inst>,
    labels: Vec<Option<usize>>,
    name: String,
}

impl Asm {
    pub fn new(name: &str) -> Self {
        Self { insts: Vec::new(), labels: Vec::new(), name: name.to_string() }
    }

    /// Create an unbound label.
    pub fn label(&mut self) -> Label {
        self.labels.push(None);
        Label(self.labels.len() - 1)
    }

    /// Bind `l` to the current position.
    pub fn bind(&mut self, l: Label) {
        assert!(self.labels[l.0].is_none(), "label bound twice");
        self.labels[l.0] = Some(self.insts.len());
    }

    /// Create a label bound at the current position.
    pub fn here(&mut self) -> Label {
        let l = self.label();
        self.bind(l);
        l
    }

    pub fn pc(&self) -> usize {
        self.insts.len()
    }

    fn push(&mut self, i: Inst) {
        self.insts.push(i);
    }

    // ---- RV32I ---------------------------------------------------------

    pub fn li(&mut self, rd: Reg, imm: i32) {
        self.push(Inst::Li { rd, imm });
    }

    pub fn mv(&mut self, rd: Reg, rs: Reg) {
        self.push(Inst::AluImm { op: AluOp::Add, rd, rs1: rs, imm: 0 });
    }

    pub fn addi(&mut self, rd: Reg, rs1: Reg, imm: i32) {
        self.push(Inst::AluImm { op: AluOp::Add, rd, rs1, imm });
    }

    pub fn slli(&mut self, rd: Reg, rs1: Reg, imm: i32) {
        self.push(Inst::AluImm { op: AluOp::Sll, rd, rs1, imm });
    }

    pub fn srli(&mut self, rd: Reg, rs1: Reg, imm: i32) {
        self.push(Inst::AluImm { op: AluOp::Srl, rd, rs1, imm });
    }

    pub fn srai(&mut self, rd: Reg, rs1: Reg, imm: i32) {
        self.push(Inst::AluImm { op: AluOp::Sra, rd, rs1, imm });
    }

    pub fn andi(&mut self, rd: Reg, rs1: Reg, imm: i32) {
        self.push(Inst::AluImm { op: AluOp::And, rd, rs1, imm });
    }

    pub fn ori(&mut self, rd: Reg, rs1: Reg, imm: i32) {
        self.push(Inst::AluImm { op: AluOp::Or, rd, rs1, imm });
    }

    pub fn add(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.push(Inst::Alu { op: AluOp::Add, rd, rs1, rs2 });
    }

    pub fn sub(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.push(Inst::Alu { op: AluOp::Sub, rd, rs1, rs2 });
    }

    pub fn sll(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.push(Inst::Alu { op: AluOp::Sll, rd, rs1, rs2 });
    }

    pub fn and(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.push(Inst::Alu { op: AluOp::And, rd, rs1, rs2 });
    }

    pub fn or(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.push(Inst::Alu { op: AluOp::Or, rd, rs1, rs2 });
    }

    pub fn xor(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.push(Inst::Alu { op: AluOp::Xor, rd, rs1, rs2 });
    }

    pub fn slt(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.push(Inst::Alu { op: AluOp::Slt, rd, rs1, rs2 });
    }

    pub fn mul(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.push(Inst::Alu { op: AluOp::Mul, rd, rs1, rs2 });
    }

    pub fn div(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.push(Inst::Alu { op: AluOp::Div, rd, rs1, rs2 });
    }

    pub fn rem(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.push(Inst::Alu { op: AluOp::Rem, rd, rs1, rs2 });
    }

    // ---- loads/stores (plus Xpulp post-increment forms) -----------------

    pub fn lw(&mut self, rd: Reg, rs1: Reg, imm: i32) {
        self.push(Inst::Load { size: MemSize::W, rd, rs1, imm, post_inc: false });
    }

    pub fn lh(&mut self, rd: Reg, rs1: Reg, imm: i32) {
        self.push(Inst::Load { size: MemSize::H, rd, rs1, imm, post_inc: false });
    }

    pub fn lb(&mut self, rd: Reg, rs1: Reg, imm: i32) {
        self.push(Inst::Load { size: MemSize::B, rd, rs1, imm, post_inc: false });
    }

    pub fn lbu(&mut self, rd: Reg, rs1: Reg, imm: i32) {
        self.push(Inst::Load { size: MemSize::Bu, rd, rs1, imm, post_inc: false });
    }

    pub fn sw(&mut self, rs2: Reg, rs1: Reg, imm: i32) {
        self.push(Inst::Store { size: MemSize::W, rs2, rs1, imm, post_inc: false });
    }

    pub fn sh(&mut self, rs2: Reg, rs1: Reg, imm: i32) {
        self.push(Inst::Store { size: MemSize::H, rs2, rs1, imm, post_inc: false });
    }

    pub fn sb(&mut self, rs2: Reg, rs1: Reg, imm: i32) {
        self.push(Inst::Store { size: MemSize::B, rs2, rs1, imm, post_inc: false });
    }

    /// p.lw rd, imm(rs1!) — load word, then rs1 += imm.
    pub fn lw_pi(&mut self, rd: Reg, rs1: Reg, imm: i32) {
        self.push(Inst::Load { size: MemSize::W, rd, rs1, imm, post_inc: true });
    }

    pub fn lh_pi(&mut self, rd: Reg, rs1: Reg, imm: i32) {
        self.push(Inst::Load { size: MemSize::H, rd, rs1, imm, post_inc: true });
    }

    pub fn lb_pi(&mut self, rd: Reg, rs1: Reg, imm: i32) {
        self.push(Inst::Load { size: MemSize::B, rd, rs1, imm, post_inc: true });
    }

    pub fn sw_pi(&mut self, rs2: Reg, rs1: Reg, imm: i32) {
        self.push(Inst::Store { size: MemSize::W, rs2, rs1, imm, post_inc: true });
    }

    pub fn sh_pi(&mut self, rs2: Reg, rs1: Reg, imm: i32) {
        self.push(Inst::Store { size: MemSize::H, rs2, rs1, imm, post_inc: true });
    }

    pub fn sb_pi(&mut self, rs2: Reg, rs1: Reg, imm: i32) {
        self.push(Inst::Store { size: MemSize::B, rs2, rs1, imm, post_inc: true });
    }

    // ---- control flow ----------------------------------------------------

    pub fn beq(&mut self, rs1: Reg, rs2: Reg, l: Label) {
        self.push(Inst::Branch { cond: Cond::Eq, rs1, rs2, target: l.0 });
    }

    pub fn bne(&mut self, rs1: Reg, rs2: Reg, l: Label) {
        self.push(Inst::Branch { cond: Cond::Ne, rs1, rs2, target: l.0 });
    }

    pub fn blt(&mut self, rs1: Reg, rs2: Reg, l: Label) {
        self.push(Inst::Branch { cond: Cond::Lt, rs1, rs2, target: l.0 });
    }

    pub fn bge(&mut self, rs1: Reg, rs2: Reg, l: Label) {
        self.push(Inst::Branch { cond: Cond::Ge, rs1, rs2, target: l.0 });
    }

    pub fn bltu(&mut self, rs1: Reg, rs2: Reg, l: Label) {
        self.push(Inst::Branch { cond: Cond::Ltu, rs1, rs2, target: l.0 });
    }

    pub fn j(&mut self, l: Label) {
        self.push(Inst::Jal { rd: 0, target: l.0 });
    }

    // ---- Xpulp ----------------------------------------------------------

    pub fn mac(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.push(Inst::Mac { rd, rs1, rs2 });
    }

    pub fn msu(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.push(Inst::Msu { rd, rs1, rs2 });
    }

    pub fn p_min(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.push(Inst::Alu { op: AluOp::Min, rd, rs1, rs2 });
    }

    pub fn p_max(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.push(Inst::Alu { op: AluOp::Max, rd, rs1, rs2 });
    }

    pub fn p_clip(&mut self, rd: Reg, rs1: Reg, bits: i32) {
        self.push(Inst::AluImm { op: AluOp::Clip, rd, rs1, imm: bits });
    }

    /// lp.setup: iterate the body (instructions up to, excluding, `end`)
    /// `count` times with zero overhead. `lp` ∈ {0, 1}; loop 0 must be the
    /// inner loop when nested.
    pub fn lp_setup_imm(&mut self, lp: u8, count: u32, end: Label) {
        self.push(Inst::LpSetup { lp, count: LoopCount::Imm(count), body_end: end.0 });
    }

    pub fn lp_setup(&mut self, lp: u8, count_reg: Reg, end: Label) {
        self.push(Inst::LpSetup { lp, count: LoopCount::Reg(count_reg), body_end: end.0 });
    }

    /// pv.sdotsp.b rd, rs1, rs2 — 4×i8 dot product accumulated into rd.
    pub fn sdotsp_b(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.push(Inst::Simd { op: SimdOp::SDotSp, fmt: SimdFmt::B4, rd, rs1, rs2 });
    }

    /// pv.sdotsp.h rd, rs1, rs2 — 2×i16 dot product accumulated into rd.
    pub fn sdotsp_h(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.push(Inst::Simd { op: SimdOp::SDotSp, fmt: SimdFmt::H2, rd, rs1, rs2 });
    }

    pub fn pv_add_b(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.push(Inst::Simd { op: SimdOp::Add, fmt: SimdFmt::B4, rd, rs1, rs2 });
    }

    pub fn pv_add_h(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.push(Inst::Simd { op: SimdOp::Add, fmt: SimdFmt::H2, rd, rs1, rs2 });
    }

    pub fn pv_max_b(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.push(Inst::Simd { op: SimdOp::Max, fmt: SimdFmt::B4, rd, rs1, rs2 });
    }

    /// pv.pack.h rd = (rs1.lo, rs2.lo) — half-word lane recombination.
    pub fn pv_pack(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.push(Inst::Simd { op: SimdOp::Pack, fmt: SimdFmt::H2, rd, rs1, rs2 });
    }

    // ---- floating point ---------------------------------------------------

    fn fp(&mut self, op: FpOp, fmt: FpFmt, rd: Reg, rs1: Reg, rs2: Reg) {
        self.push(Inst::Fp { op, fmt, rd, rs1, rs2 });
    }

    pub fn fadd_s(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.fp(FpOp::Add, FpFmt::S, rd, rs1, rs2);
    }

    pub fn fsub_s(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.fp(FpOp::Sub, FpFmt::S, rd, rs1, rs2);
    }

    pub fn fmul_s(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.fp(FpOp::Mul, FpFmt::S, rd, rs1, rs2);
    }

    /// fmadd.s rd, rs1, rs2 with rd as accumulator: rd = rs1*rs2 + rd.
    pub fn fmac_s(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.fp(FpOp::Madd, FpFmt::S, rd, rs1, rs2);
    }

    pub fn fmsu_s(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.fp(FpOp::Msub, FpFmt::S, rd, rs1, rs2);
    }

    pub fn fdiv_s(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.fp(FpOp::Div, FpFmt::S, rd, rs1, rs2);
    }

    pub fn fsqrt_s(&mut self, rd: Reg, rs1: Reg) {
        self.fp(FpOp::Sqrt, FpFmt::S, rd, rs1, 0);
    }

    pub fn fmin_s(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.fp(FpOp::Min, FpFmt::S, rd, rs1, rs2);
    }

    pub fn fmax_s(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.fp(FpOp::Max, FpFmt::S, rd, rs1, rs2);
    }

    pub fn fabs_s(&mut self, rd: Reg, rs1: Reg) {
        self.fp(FpOp::Abs, FpFmt::S, rd, rs1, 0);
    }

    pub fn flt_s(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.fp(FpOp::CmpLt, FpFmt::S, rd, rs1, rs2);
    }

    pub fn fle_s(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.fp(FpOp::CmpLe, FpFmt::S, rd, rs1, rs2);
    }

    pub fn fcvt_s_w(&mut self, rd: Reg, rs1: Reg) {
        self.fp(FpOp::CvtIF, FpFmt::S, rd, rs1, 0);
    }

    pub fn fcvt_w_s(&mut self, rd: Reg, rs1: Reg) {
        self.fp(FpOp::CvtFI, FpFmt::S, rd, rs1, 0);
    }

    // smallFloat / packed-SIMD FP16

    pub fn vfadd_h(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.fp(FpOp::Add, FpFmt::VH, rd, rs1, rs2);
    }

    pub fn vfsub_h(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.fp(FpOp::Sub, FpFmt::VH, rd, rs1, rs2);
    }

    pub fn vfmul_h(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.fp(FpOp::Mul, FpFmt::VH, rd, rs1, rs2);
    }

    /// vfmac.h rd, rs1, rs2 — per-lane FMA into rd (2 lanes).
    pub fn vfmac_h(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.fp(FpOp::Madd, FpFmt::VH, rd, rs1, rs2);
    }

    pub fn vfmin_h(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.fp(FpOp::Min, FpFmt::VH, rd, rs1, rs2);
    }

    pub fn vfmax_h(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.fp(FpOp::Max, FpFmt::VH, rd, rs1, rs2);
    }

    /// vfdotpex.s.h rd, rs1, rs2 — multi-format: rd(f32) += dot of two
    /// packed f16 pairs (the accumulate-wider NSAA instruction of §II-C).
    pub fn vfdotpex_s_h(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.fp(FpOp::DotpEx, FpFmt::VH, rd, rs1, rs2);
    }

    /// vfdotpex.s.b rd, rs1, rs2 — multi-format fp8: rd(f32) += dot of
    /// two packed 4×binary8 (E5M2) registers. Four MACs per single-cycle
    /// FPU issue — the widest SIMD mode of the shared FPUs.
    pub fn vfdotpex_s_b(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.fp(FpOp::DotpEx, FpFmt::VB4, rd, rs1, rs2);
    }

    /// vfcpka.h.s rd, rs1, rs2 — cast-and-pack two f32 into packed f16.
    pub fn vfcpka_h_s(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.fp(FpOp::CvtSH2, FpFmt::VH, rd, rs1, rs2);
    }

    /// Widen packed-f16 lane 0/1 to f32.
    pub fn fcvt_s_h0(&mut self, rd: Reg, rs1: Reg) {
        self.fp(FpOp::CvtH2S0, FpFmt::VH, rd, rs1, 0);
    }

    pub fn fcvt_s_h1(&mut self, rd: Reg, rs1: Reg) {
        self.fp(FpOp::CvtH2S1, FpFmt::VH, rd, rs1, 0);
    }

    // ---- system ----------------------------------------------------------

    pub fn barrier(&mut self) {
        self.push(Inst::Barrier);
    }

    pub fn halt(&mut self) {
        self.push(Inst::Halt);
    }

    pub fn nop(&mut self) {
        self.push(Inst::Nop);
    }

    /// Resolve labels and produce the final program.
    pub fn finish(self) -> Result<Program> {
        let resolve = |idx: usize| -> Result<usize> {
            self.labels
                .get(idx)
                .copied()
                .flatten()
                .ok_or_else(|| VegaError::Asm(format!("unbound label {idx} in {}", self.name)))
        };
        let mut insts = Vec::with_capacity(self.insts.len());
        for inst in &self.insts {
            insts.push(match *inst {
                Inst::Branch { cond, rs1, rs2, target } => {
                    Inst::Branch { cond, rs1, rs2, target: resolve(target)? }
                }
                Inst::Jal { rd, target } => Inst::Jal { rd, target: resolve(target)? },
                Inst::LpSetup { lp, count, body_end } => {
                    let end = resolve(body_end)?;
                    if end <= insts.len() {
                        return Err(VegaError::Asm(format!(
                            "hw loop {lp} in {} has empty/backward body",
                            self.name
                        )));
                    }
                    Inst::LpSetup { lp, count, body_end: end }
                }
                other => other,
            });
        }
        Ok(Program { insts, name: self.name })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{A0, A1, A2};

    #[test]
    fn forward_labels_resolve() {
        let mut a = Asm::new("t");
        let end = a.label();
        a.li(A0, 1);
        a.beq(A0, A0, end);
        a.li(A0, 2);
        a.bind(end);
        a.halt();
        let p = a.finish().unwrap();
        assert_eq!(p.insts.len(), 4);
        match p.insts[1] {
            Inst::Branch { target, .. } => assert_eq!(target, 3),
            _ => panic!(),
        }
    }

    #[test]
    fn unbound_label_errors() {
        let mut a = Asm::new("t");
        let l = a.label();
        a.j(l);
        assert!(a.finish().is_err());
    }

    #[test]
    fn hw_loop_end_resolution() {
        let mut a = Asm::new("t");
        let end = a.label();
        a.lp_setup_imm(0, 10, end);
        a.addi(A1, A1, 1);
        a.bind(end);
        a.halt();
        let p = a.finish().unwrap();
        match p.insts[0] {
            Inst::LpSetup { body_end, .. } => assert_eq!(body_end, 2),
            _ => panic!(),
        }
    }

    #[test]
    fn empty_hw_loop_rejected() {
        let mut a = Asm::new("t");
        let end = a.here();
        a.lp_setup_imm(0, 10, end);
        assert!(a.finish().is_err());
    }

    #[test]
    fn fp_intensity_static() {
        let mut a = Asm::new("t");
        a.fmac_s(A0, A1, A2);
        a.fadd_s(A0, A1, A2);
        a.addi(A1, A1, 4);
        a.lw(A2, A1, 0);
        let p = a.finish().unwrap();
        assert!((p.static_fp_intensity() - 0.5).abs() < 1e-9);
    }
}
