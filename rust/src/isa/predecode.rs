//! Predecoded instruction stream (§Perf, hot-path layer 2) and the
//! superblock side-table that seeds hot-path layer 3.
//!
//! The ISS interprets the symbolic [`Inst`] enum, and the per-cycle path
//! used to re-match the full enum and re-build `inst.srcs()` on every
//! cycle of every core. [`Program::predecode`] flattens each instruction
//! once per run into a dense [`Decoded`] record — dispatch kind, operand
//! fields, source-register bitmask for the load-use interlock, FP latency
//! and the retire-time counters (class, int ops, FLOPs) — so
//! `Core::begin_cycle` / `retire_mem` / `retire_fp` reduce to field reads
//! and single-bit tests. Purely a representation change: every decoded
//! field is derived from the same `Inst` accessors the slow path used, so
//! cycle counts and results are identical by construction.
//!
//! On top of the flat records, `predecode` also scans for hardware loops
//! whose bodies pass [`is_straight_line_body`] — the same shape test the
//! static analyzer uses to emit `SuperblockCandidate` findings — and
//! packages each as a [`Superblock`]: a closed-form replay plan
//! ([`SbStep`] effect list plus [`SbMemOp`] affine address summaries)
//! that [`crate::iss::superblock`] can execute N iterations at a time.
//! Building the table is pure analysis; whether a given dynamic entry is
//! actually replayable (trip count, pending loads, address regions) is
//! decided at run time by the ISS.

use super::inst::{AluOp, FpFmt, FpOp, Inst, InstClass, MemSize, SimdFmt, SimdOp};
use super::{Program, Reg};

/// Per-cycle dispatch kind plus the operand fields each kind needs.
#[derive(Debug, Clone, Copy)]
pub enum DecodedKind {
    /// Memory access needing a TCDM/L2 grant. `reg` is the destination
    /// for loads and the store-data source for stores.
    Mem { write: bool, size: MemSize, reg: Reg, rs1: Reg, imm: i32, post_inc: bool },
    /// FP op needing an FPU issue slot (or the shared DIV-SQRT unit).
    Fp { op: FpOp, fmt: FpFmt, rd: Reg, rs1: Reg, rs2: Reg, latency: u64, divsqrt: bool },
    Barrier,
    Halt,
    /// Retires internally; `Core::exec_local` matches the original inst.
    Local,
}

/// One instruction, flattened for the per-cycle hot path.
#[derive(Debug, Clone, Copy)]
pub struct Decoded {
    pub kind: DecodedKind,
    /// Bitmask over x0..x31 of the registers this instruction reads
    /// (load-use interlock test is one AND instead of a 3-slot scan).
    pub src_mask: u32,
    pub class: InstClass,
    pub int_ops: u64,
    pub flops: u64,
}

impl Decoded {
    fn of(inst: &Inst) -> Self {
        let kind = match *inst {
            Inst::Load { size, rd, rs1, imm, post_inc } => {
                DecodedKind::Mem { write: false, size, reg: rd, rs1, imm, post_inc }
            }
            Inst::Store { size, rs2, rs1, imm, post_inc } => {
                DecodedKind::Mem { write: true, size, reg: rs2, rs1, imm, post_inc }
            }
            Inst::Fp { op, fmt, rd, rs1, rs2 } => DecodedKind::Fp {
                op,
                fmt,
                rd,
                rs1,
                rs2,
                latency: op.cycles(),
                divsqrt: op.is_divsqrt(),
            },
            Inst::Barrier => DecodedKind::Barrier,
            Inst::Halt => DecodedKind::Halt,
            // No wildcard: a new variant must choose its dispatch kind
            // explicitly (and get analyze/ handlers) or fail to compile.
            Inst::Alu { .. }
            | Inst::AluImm { .. }
            | Inst::Li { .. }
            | Inst::Branch { .. }
            | Inst::Jal { .. }
            | Inst::Jalr { .. }
            | Inst::Mac { .. }
            | Inst::Msu { .. }
            | Inst::Simd { .. }
            | Inst::LpSetup { .. }
            | Inst::Nop => DecodedKind::Local,
        };
        let mut src_mask = 0u32;
        for s in inst.srcs().into_iter().flatten() {
            src_mask |= 1u32 << s;
        }
        Self {
            kind,
            src_mask,
            class: inst.class(),
            int_ops: inst.int_ops(),
            flops: inst.flops(),
        }
    }
}

/// True when `[body_start, body_end)` contains no control flow, barrier
/// or halt — the straight-line hardware-loop shape. This is the single
/// definition shared by the static analyzer (which reports such loops as
/// `SuperblockCandidate` findings) and by the superblock side-table
/// below, so the static and dynamic sides can never disagree about what
/// counts as a candidate.
pub fn is_straight_line_body(prog: &Program, body_start: usize, body_end: usize) -> bool {
    (body_start..body_end).all(|p| {
        !matches!(
            prog.insts[p],
            Inst::Branch { .. }
                | Inst::Jal { .. }
                | Inst::Jalr { .. }
                | Inst::LpSetup { .. }
                | Inst::Barrier
                | Inst::Halt
        )
    })
}

/// One body instruction of a [`Superblock`], flattened into the exact
/// effect the replay loop applies. Multi-cycle latencies are pre-baked
/// as `extra` (cycles beyond the issue cycle) so the timing profile walk
/// is pure arithmetic.
#[derive(Debug, Clone, Copy)]
pub enum SbStep {
    Alu { op: AluOp, rd: Reg, rs1: Reg, rs2: Reg, extra: u64 },
    AluImm { op: AluOp, rd: Reg, rs1: Reg, imm: i32, extra: u64 },
    Li { rd: Reg, imm: i32 },
    Mac { rd: Reg, rs1: Reg, rs2: Reg },
    Msu { rd: Reg, rs1: Reg, rs2: Reg },
    Simd { op: SimdOp, fmt: SimdFmt, rd: Reg, rs1: Reg, rs2: Reg },
    Fp { op: FpOp, fmt: FpFmt, rd: Reg, rs1: Reg, rs2: Reg, extra: u64, divsqrt: bool },
    /// `reg` is the load destination / store-data source; `op_idx`
    /// indexes the plan's [`SbMemOp`] table for the address summary.
    Mem { write: bool, size: MemSize, reg: Reg, rs1: Reg, imm: i32, post_inc: bool, op_idx: u16 },
    Nop,
}

/// Affine address summary of one memory access in a superblock body:
/// iteration `i` touches `entry[rs1] + offset + i * stride` (exact in
/// `i64`; `offset` folds the post-increments that precede the access
/// inside the body, `stride` is the base register's net advance per
/// iteration). Valid only while `rs1` is not otherwise written in the
/// body — the builder refuses a plan when it is.
#[derive(Debug, Clone, Copy)]
pub struct SbMemOp {
    pub rs1: Reg,
    pub offset: i64,
    pub stride: i64,
    pub bytes: u32,
    pub write: bool,
}

/// The replayable effect of one loop body: the per-instruction effect
/// list, the affine summary of every access, and the pending-load state
/// a steady-state iteration hands to the next one (`Some` iff the body
/// ends in a load, whose use-interlock then straddles the back edge).
#[derive(Debug, Clone)]
pub struct SbPlan {
    pub steps: Vec<SbStep>,
    pub mem_ops: Vec<SbMemOp>,
    pub entry_pending: Option<Reg>,
}

/// A straight-line hardware-loop body promoted to a replay candidate.
/// `plan` is `None` when the body is straight-line but not closed-form
/// (an address base register is rewritten inside the body, e.g. a
/// pointer chase) — the ISS then counts a bail and interprets normally.
#[derive(Debug, Clone)]
pub struct Superblock {
    /// Hardware-loop channel (0 or 1) the setup targets.
    pub lp: u8,
    pub setup_pc: usize,
    pub body_start: usize,
    pub body_end: usize,
    pub plan: Option<SbPlan>,
}

fn build_plan(prog: &Program, body_start: usize, body_end: usize) -> Option<SbPlan> {
    let mut steps = Vec::with_capacity(body_end - body_start);
    let mut mem_ops: Vec<SbMemOp> = Vec::new();
    // Net post-increment applied to each register so far in the body
    // (exact i64: the u32 wrap of the machine matches the i64 sum as
    // long as the final address is range-checked, which replay does).
    let mut inc = [0i64; 32];
    let mut written = [false; 32];
    for p in body_start..body_end {
        let step = match prog.insts[p] {
            Inst::Alu { op, rd, rs1, rs2 } => {
                SbStep::Alu { op, rd, rs1, rs2, extra: op.cycles() - 1 }
            }
            Inst::AluImm { op, rd, rs1, imm } => {
                SbStep::AluImm { op, rd, rs1, imm, extra: op.cycles() - 1 }
            }
            Inst::Li { rd, imm } => SbStep::Li { rd, imm },
            Inst::Mac { rd, rs1, rs2 } => SbStep::Mac { rd, rs1, rs2 },
            Inst::Msu { rd, rs1, rs2 } => SbStep::Msu { rd, rs1, rs2 },
            Inst::Simd { op, fmt, rd, rs1, rs2 } => SbStep::Simd { op, fmt, rd, rs1, rs2 },
            Inst::Fp { op, fmt, rd, rs1, rs2 } => SbStep::Fp {
                op,
                fmt,
                rd,
                rs1,
                rs2,
                extra: op.cycles() - 1,
                divsqrt: op.is_divsqrt(),
            },
            Inst::Nop => SbStep::Nop,
            Inst::Load { size, rd, rs1, imm, post_inc } => {
                if mem_ops.len() >= u16::MAX as usize {
                    return None;
                }
                let op_idx = mem_ops.len() as u16;
                let offset = inc[rs1 as usize] + if post_inc { 0 } else { i64::from(imm) };
                mem_ops.push(SbMemOp {
                    rs1,
                    offset,
                    stride: 0,
                    bytes: size.bytes(),
                    write: false,
                });
                if post_inc && rs1 != 0 {
                    inc[rs1 as usize] += i64::from(imm);
                }
                SbStep::Mem { write: false, size, reg: rd, rs1, imm, post_inc, op_idx }
            }
            Inst::Store { size, rs2, rs1, imm, post_inc } => {
                if mem_ops.len() >= u16::MAX as usize {
                    return None;
                }
                let op_idx = mem_ops.len() as u16;
                let offset = inc[rs1 as usize] + if post_inc { 0 } else { i64::from(imm) };
                mem_ops.push(SbMemOp {
                    rs1,
                    offset,
                    stride: 0,
                    bytes: size.bytes(),
                    write: true,
                });
                if post_inc && rs1 != 0 {
                    inc[rs1 as usize] += i64::from(imm);
                }
                SbStep::Mem { write: true, size, reg: rs2, rs1, imm, post_inc, op_idx }
            }
            Inst::Branch { .. }
            | Inst::Jal { .. }
            | Inst::Jalr { .. }
            | Inst::LpSetup { .. }
            | Inst::Barrier
            | Inst::Halt => unreachable!("caller checked is_straight_line_body"),
        };
        if let Some(rd) = prog.insts[p].dst() {
            if rd != 0 {
                written[rd as usize] = true;
            }
        }
        steps.push(step);
    }
    for op in &mut mem_ops {
        op.stride = inc[op.rs1 as usize];
    }
    // An address base overwritten by anything other than its own
    // post-increments is not affine — no closed form, no plan.
    if mem_ops.iter().any(|op| written[op.rs1 as usize]) {
        return None;
    }
    let entry_pending = match steps.last() {
        Some(&SbStep::Mem { write: false, reg, .. }) => Some(reg),
        _ => None,
    };
    Some(SbPlan { steps, mem_ops, entry_pending })
}

/// The predecoded side-table of a program, built once per run.
pub struct PreDecoded {
    pub recs: Vec<Decoded>,
    /// Replay candidates: one per hardware loop with a straight-line
    /// body, in program order.
    pub superblocks: Vec<Superblock>,
    /// `body_start` pc → index into `superblocks` (body starts are
    /// unique: one `LpSetup` per pc). O(1) lookup keeps the per-issue
    /// poll in the cluster scheduler cheap when no superblock applies.
    pub sb_at: Vec<Option<u16>>,
}

impl PreDecoded {
    pub fn len(&self) -> usize {
        self.recs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.recs.is_empty()
    }
}

impl Program {
    /// Flatten every instruction into its dense hot-path record and
    /// collect the superblock replay candidates.
    pub fn predecode(&self) -> PreDecoded {
        let recs = self.insts.iter().map(Decoded::of).collect();
        let mut superblocks = Vec::new();
        let mut sb_at = vec![None; self.insts.len()];
        for (pc, inst) in self.insts.iter().enumerate() {
            let Inst::LpSetup { lp, body_end, .. } = *inst else { continue };
            if lp >= 2
                || body_end <= pc + 1
                || body_end > self.insts.len()
                || superblocks.len() >= u16::MAX as usize
                || !is_straight_line_body(self, pc + 1, body_end)
            {
                continue;
            }
            sb_at[pc + 1] = Some(superblocks.len() as u16);
            superblocks.push(Superblock {
                lp,
                setup_pc: pc,
                body_start: pc + 1,
                body_end,
                plan: build_plan(self, pc + 1, body_end),
            });
        }
        PreDecoded { recs, superblocks, sb_at }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{Asm, A0, A1, A2, T0};

    #[test]
    fn src_mask_matches_srcs() {
        let mut a = Asm::new("t");
        a.mac(A2, A0, A1); // reads rs1, rs2 and the accumulator rd
        a.lw(T0, A0, 4);
        a.sw(T0, A1, 0);
        a.halt();
        let p = a.finish().unwrap();
        let pre = p.predecode();
        assert_eq!(pre.len(), p.len());
        for (inst, dec) in p.insts.iter().zip(&pre.recs) {
            let mut want = 0u32;
            for s in inst.srcs().into_iter().flatten() {
                want |= 1 << s;
            }
            assert_eq!(dec.src_mask, want, "{inst:?}");
            assert_eq!(dec.class, inst.class());
            assert_eq!(dec.int_ops, inst.int_ops());
            assert_eq!(dec.flops, inst.flops());
        }
    }

    #[test]
    fn kinds_cover_arbitrated_insts() {
        let mut a = Asm::new("t");
        a.lw(T0, A0, 0);
        a.sw(T0, A0, 0);
        a.fdiv_s(A2, A0, A1);
        a.fmac_s(A2, A0, A1);
        a.barrier();
        a.addi(A0, A0, 1);
        a.halt();
        let pre = a.finish().unwrap().predecode();
        assert!(matches!(
            pre.recs[0].kind,
            DecodedKind::Mem { write: false, .. }
        ));
        assert!(matches!(pre.recs[1].kind, DecodedKind::Mem { write: true, .. }));
        assert!(matches!(
            pre.recs[2].kind,
            DecodedKind::Fp { divsqrt: true, latency: 11, .. }
        ));
        assert!(matches!(
            pre.recs[3].kind,
            DecodedKind::Fp { divsqrt: false, latency: 1, .. }
        ));
        assert!(matches!(pre.recs[4].kind, DecodedKind::Barrier));
        assert!(matches!(pre.recs[5].kind, DecodedKind::Local));
        assert!(matches!(pre.recs[6].kind, DecodedKind::Halt));
    }
}
