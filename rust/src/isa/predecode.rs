//! Predecoded instruction stream (§Perf, hot-path layer 2).
//!
//! The ISS interprets the symbolic [`Inst`] enum, and the per-cycle path
//! used to re-match the full enum and re-build `inst.srcs()` on every
//! cycle of every core. [`Program::predecode`] flattens each instruction
//! once per run into a dense [`Decoded`] record — dispatch kind, operand
//! fields, source-register bitmask for the load-use interlock, FP latency
//! and the retire-time counters (class, int ops, FLOPs) — so
//! `Core::begin_cycle` / `retire_mem` / `retire_fp` reduce to field reads
//! and single-bit tests. Purely a representation change: every decoded
//! field is derived from the same `Inst` accessors the slow path used, so
//! cycle counts and results are identical by construction.

use super::inst::{FpFmt, FpOp, Inst, InstClass, MemSize};
use super::{Program, Reg};

/// Per-cycle dispatch kind plus the operand fields each kind needs.
#[derive(Debug, Clone, Copy)]
pub enum DecodedKind {
    /// Memory access needing a TCDM/L2 grant. `reg` is the destination
    /// for loads and the store-data source for stores.
    Mem { write: bool, size: MemSize, reg: Reg, rs1: Reg, imm: i32, post_inc: bool },
    /// FP op needing an FPU issue slot (or the shared DIV-SQRT unit).
    Fp { op: FpOp, fmt: FpFmt, rd: Reg, rs1: Reg, rs2: Reg, latency: u64, divsqrt: bool },
    Barrier,
    Halt,
    /// Retires internally; `Core::exec_local` matches the original inst.
    Local,
}

/// One instruction, flattened for the per-cycle hot path.
#[derive(Debug, Clone, Copy)]
pub struct Decoded {
    pub kind: DecodedKind,
    /// Bitmask over x0..x31 of the registers this instruction reads
    /// (load-use interlock test is one AND instead of a 3-slot scan).
    pub src_mask: u32,
    pub class: InstClass,
    pub int_ops: u64,
    pub flops: u64,
}

impl Decoded {
    fn of(inst: &Inst) -> Self {
        let kind = match *inst {
            Inst::Load { size, rd, rs1, imm, post_inc } => {
                DecodedKind::Mem { write: false, size, reg: rd, rs1, imm, post_inc }
            }
            Inst::Store { size, rs2, rs1, imm, post_inc } => {
                DecodedKind::Mem { write: true, size, reg: rs2, rs1, imm, post_inc }
            }
            Inst::Fp { op, fmt, rd, rs1, rs2 } => DecodedKind::Fp {
                op,
                fmt,
                rd,
                rs1,
                rs2,
                latency: op.cycles(),
                divsqrt: op.is_divsqrt(),
            },
            Inst::Barrier => DecodedKind::Barrier,
            Inst::Halt => DecodedKind::Halt,
            // No wildcard: a new variant must choose its dispatch kind
            // explicitly (and get analyze/ handlers) or fail to compile.
            Inst::Alu { .. }
            | Inst::AluImm { .. }
            | Inst::Li { .. }
            | Inst::Branch { .. }
            | Inst::Jal { .. }
            | Inst::Jalr { .. }
            | Inst::Mac { .. }
            | Inst::Msu { .. }
            | Inst::Simd { .. }
            | Inst::LpSetup { .. }
            | Inst::Nop => DecodedKind::Local,
        };
        let mut src_mask = 0u32;
        for s in inst.srcs().into_iter().flatten() {
            src_mask |= 1u32 << s;
        }
        Self {
            kind,
            src_mask,
            class: inst.class(),
            int_ops: inst.int_ops(),
            flops: inst.flops(),
        }
    }
}

/// The predecoded side-table of a program, built once per run.
pub struct PreDecoded {
    pub recs: Vec<Decoded>,
}

impl PreDecoded {
    pub fn len(&self) -> usize {
        self.recs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.recs.is_empty()
    }
}

impl Program {
    /// Flatten every instruction into its dense hot-path record.
    pub fn predecode(&self) -> PreDecoded {
        PreDecoded { recs: self.insts.iter().map(Decoded::of).collect() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{Asm, A0, A1, A2, T0};

    #[test]
    fn src_mask_matches_srcs() {
        let mut a = Asm::new("t");
        a.mac(A2, A0, A1); // reads rs1, rs2 and the accumulator rd
        a.lw(T0, A0, 4);
        a.sw(T0, A1, 0);
        a.halt();
        let p = a.finish().unwrap();
        let pre = p.predecode();
        assert_eq!(pre.len(), p.len());
        for (inst, dec) in p.insts.iter().zip(&pre.recs) {
            let mut want = 0u32;
            for s in inst.srcs().into_iter().flatten() {
                want |= 1 << s;
            }
            assert_eq!(dec.src_mask, want, "{inst:?}");
            assert_eq!(dec.class, inst.class());
            assert_eq!(dec.int_ops, inst.int_ops());
            assert_eq!(dec.flops, inst.flops());
        }
    }

    #[test]
    fn kinds_cover_arbitrated_insts() {
        let mut a = Asm::new("t");
        a.lw(T0, A0, 0);
        a.sw(T0, A0, 0);
        a.fdiv_s(A2, A0, A1);
        a.fmac_s(A2, A0, A1);
        a.barrier();
        a.addi(A0, A0, 1);
        a.halt();
        let pre = a.finish().unwrap().predecode();
        assert!(matches!(
            pre.recs[0].kind,
            DecodedKind::Mem { write: false, .. }
        ));
        assert!(matches!(pre.recs[1].kind, DecodedKind::Mem { write: true, .. }));
        assert!(matches!(
            pre.recs[2].kind,
            DecodedKind::Fp { divsqrt: true, latency: 11, .. }
        ));
        assert!(matches!(
            pre.recs[3].kind,
            DecodedKind::Fp { divsqrt: false, latency: 1, .. }
        ));
        assert!(matches!(pre.recs[4].kind, DecodedKind::Barrier));
        assert!(matches!(pre.recs[5].kind, DecodedKind::Local));
        assert!(matches!(pre.recs[6].kind, DecodedKind::Halt));
    }
}
