//! The RV32IMF + Xpulp + smallFloat instruction set of Vega's RI5CY cores.
//!
//! Vega's ten cores implement `RVC32IMF-Xpulp + SF` (Table VIII): the RV32
//! base ISA with the M extension, single-precision F, the Xpulp DSP
//! extensions (hardware loops, post-incremented load/store, SIMD dot
//! products on packed 8/16-bit data, MAC), and the smallFloat extensions
//! (FP16/bfloat16 scalar and packed-SIMD, multi-format FMA accumulating
//! 16-bit products into 32-bit — see Fig. 3 and [FPnew]).
//!
//! There is no RISC-V cross-compiler in this environment, so kernels are
//! authored through the in-Rust macro-assembler in [`asm`] (DESIGN.md §5).
//! Instructions are kept symbolic (no binary encoding): the ISS interprets
//! the [`inst::Inst`] enum directly, which is also what makes the
//! instruction-mix statistics of Table V trivially exact.
//!
//! Floating-point state lives in the integer register file, matching the
//! paper: "the architecture design maps integer and FP registers on a
//! single register file" (§IV-A).

pub mod analyze;
pub mod asm;
pub mod encode;
pub mod inst;
pub mod predecode;

pub use analyze::{analyze, AnalysisReport};
pub use asm::{Asm, Label, Program};
pub use encode::ISA_ENCODING_VERSION;
pub use inst::{
    AluOp, Cond, FpFmt, FpOp, Inst, InstClass, LoopCount, MemSize, SimdFmt, SimdOp,
};
pub use predecode::{Decoded, DecodedKind, PreDecoded};

/// A register index (x0..x31). x0 is hardwired to zero.
pub type Reg = u8;

// ABI register names (subset used by the kernel builders).
pub const ZERO: Reg = 0;
pub const RA: Reg = 1;
pub const SP: Reg = 2;
// gp/tp are repurposed as kernel scratch: leaf SPMD kernels make no calls
// and keep no stack, so x1..x4 are free real estate (a PULP-NN idiom).
pub const GP: Reg = 3;
pub const TP: Reg = 4;
pub const T0: Reg = 5;
pub const T1: Reg = 6;
pub const T2: Reg = 7;
pub const S0: Reg = 8;
pub const S1: Reg = 9;
pub const A0: Reg = 10;
pub const A1: Reg = 11;
pub const A2: Reg = 12;
pub const A3: Reg = 13;
pub const A4: Reg = 14;
pub const A5: Reg = 15;
pub const A6: Reg = 16;
pub const A7: Reg = 17;
pub const S2: Reg = 18;
pub const S3: Reg = 19;
pub const S4: Reg = 20;
pub const S5: Reg = 21;
pub const S6: Reg = 22;
pub const S7: Reg = 23;
pub const S8: Reg = 24;
pub const S9: Reg = 25;
pub const S10: Reg = 26;
pub const S11: Reg = 27;
pub const T3: Reg = 28;
pub const T4: Reg = 29;
pub const T5: Reg = 30;
pub const T6: Reg = 31;
