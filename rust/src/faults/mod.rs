//! Deterministic fault injection & resilience measurement (ISSUE 6).
//!
//! Vega's headline claim — state retention through a 1.7 µW sleep mode —
//! rests on the MRAM keeping its bits across the sleep interval, and on
//! the 78-bit SECDED interface ([`crate::mem::ecc`]) catching the upsets
//! it doesn't keep. This module attacks that protection on purpose:
//!
//! * [`FaultPlan`] describes a campaign as per-tier upset **rates**
//!   (MRAM retention upsets scaled by a modeled sleep duration; SRAM/L2
//!   and TCDM soft errors per active run) and expands them, via the
//!   repo's own xorshift [`crate::common::Rng`], into an exact
//!   `(unit, bit, time)` flip list — replayable from its seed alone, at
//!   any `--jobs`.
//! * [`run_campaign`] stages a scenario's input image through the real
//!   tier objects ([`crate::mem::Mram`] with live SECDED encode/decode/
//!   scrub, [`crate::iss::FlatMem`] for L2, [`crate::cluster::Tcdm`] for
//!   L1), applies the flips, classifies every affected storage unit as
//!   corrected / detected-uncorrectable / **silent data corruption** /
//!   masked, then runs the unmodified kernel on the post-fault image and
//!   compares its output digest against the fault-free oracle.
//! * [`FaultStats`] rides inside [`crate::cluster::ClusterStats`] (all
//!   zeros outside campaigns — the normal simulation path is untouched)
//!   and out through the report/persistence pipeline.
//!
//! The sweep engine half of the issue — per-work-item `catch_unwind` and
//! structured [`crate::sweep::SimError`] cells — lives in
//! [`crate::sweep`]; the `vega faults` CLI grid lives in [`cli`].

pub mod campaign;
pub mod cli;
pub mod plan;

pub use campaign::{run_campaign, Campaign, CampaignOutcome, FAULT_MODEL_VERSION};
pub use cli::FaultsCmd;
pub use plan::{FaultPlan, Flip, FlipList, TierMask};

/// A storage tier fault campaigns can target.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tier {
    /// Non-volatile MRAM behind SECDED(72,64) — the retention store.
    Mram,
    /// L2 interleaved SRAM (unprotected in the model).
    L2,
    /// Cluster L1 TCDM banks (unprotected).
    Tcdm,
}

impl Tier {
    pub fn name(self) -> &'static str {
        match self {
            Tier::Mram => "mram",
            Tier::L2 => "l2",
            Tier::Tcdm => "tcdm",
        }
    }
}

/// Per-tier outcome counters for one campaign.
///
/// A classified *event* is one storage unit — a 64-bit SECDED codeword
/// for MRAM, a byte for the SRAM tiers — after net-XOR of every flip
/// that landed in it (two flips on the same bit cancel in silicon and
/// cancel here).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TierFaults {
    /// Raw flips the plan injected into this tier.
    pub flips: u64,
    /// Distinct storage units those flips landed in.
    pub words: u64,
    /// Units whose upset the tier's ECC corrected back to truth.
    pub corrected: u64,
    /// Units reported detected-uncorrectable (the controller interrupt).
    pub detected: u64,
    /// Units that read back wrong with no indication — silent data
    /// corruption: every upset unit of an unprotected tier, plus ≥3-flip
    /// miscorrection escapes through SECDED.
    pub silent: u64,
    /// Units whose flips net-cancelled or landed outside the data bits
    /// (check/parity positions "corrected" back to intact data).
    pub masked: u64,
}

impl TierFaults {
    /// Total classified units — equals `words` by construction.
    pub fn classified(&self) -> u64 {
        self.corrected + self.detected + self.silent + self.masked
    }
}

/// The per-tier fault ledger carried through [`crate::cluster::ClusterStats`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultStats {
    pub mram: TierFaults,
    pub l2: TierFaults,
    pub tcdm: TierFaults,
}

impl FaultStats {
    pub fn tier(&self, t: Tier) -> &TierFaults {
        match t {
            Tier::Mram => &self.mram,
            Tier::L2 => &self.l2,
            Tier::Tcdm => &self.tcdm,
        }
    }

    pub fn tier_mut(&mut self, t: Tier) -> &mut TierFaults {
        match t {
            Tier::Mram => &mut self.mram,
            Tier::L2 => &mut self.l2,
            Tier::Tcdm => &mut self.tcdm,
        }
    }

    /// Silent-data-corruption events across every tier.
    pub fn silent_total(&self) -> u64 {
        self.mram.silent + self.l2.silent + self.tcdm.silent
    }

    /// Raw injected flips across every tier.
    pub fn flips_total(&self) -> u64 {
        self.mram.flips + self.l2.flips + self.tcdm.flips
    }
}
