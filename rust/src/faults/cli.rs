//! The `vega faults` subcommand: run a campaign grid — seeds × an
//! upset-rate ladder × a tier mask — over one kernel and render the
//! ECC-coverage report as CSV, Markdown or JSON.
//!
//! Grid cells fan out across the engine's worker pool and memoize
//! through the persistent `.flt` store tier, and the report is emitted
//! in deterministic grid order (seed-major, then rate) — byte-identical
//! for any `--jobs`, like every other renderer in the crate. A
//! panicking cell renders as its own `status` column error while the
//! rest of the grid completes (the sweep-engine fault isolation this
//! issue added, applied to its own reporting path).

use crate::kernels::fp_matmul::FpWidth;
use crate::kernels::int_matmul::IntWidth;
use crate::sweep::explore::{
    parse_merge, parse_ms, parse_retries, sanitize_cell, GridFormat, RenderedGrid,
};
use crate::sweep::journal::{self, GridSession, ShardSpec};
use crate::sweep::{default_jobs, CellPolicy, Scenario, SweepEngine};

use super::{Campaign, CampaignOutcome, FaultPlan, TierMask};

/// A parsed `vega faults` invocation.
#[derive(Debug, Clone)]
pub struct FaultsCmd {
    /// The attacked kernel (canonical CLI token, for report labels).
    pub kernel: &'static str,
    /// The scenario every campaign of the grid attacks.
    pub scenario: Scenario,
    /// Active cores (matmul kernels only; NSAA kernels pin 8).
    pub cores: usize,
    /// Campaign seeds (`--seeds`, grid-major axis).
    pub seeds: Vec<u64>,
    /// Upset-rate ladder in upsets per Mbit (per hour of sleep for
    /// MRAM, per run for the SRAM tiers) — `--rates`, grid-minor axis.
    pub rates: Vec<f64>,
    /// Tiers under attack (`--tiers mram+l2+tcdm`, `l1` = `tcdm`).
    pub tiers: TierMask,
    /// Modeled sleep duration scaling MRAM retention upsets (`--sleep-s`).
    pub sleep_s: f64,
    /// Output renderer (`--format csv|md|json`).
    pub format: GridFormat,
    /// Worker count (`--jobs`, default `VEGA_JOBS`/all cores).
    pub jobs: usize,
    /// Print memo/store counters to stderr after rendering (`--stats`).
    pub stats: bool,
    /// Replay this grid's checkpoint journal and skip completed cells
    /// (`--resume`).
    pub resume: bool,
    /// Own only one deterministic slice of the grid (`--shard I/N`).
    pub shard: Option<ShardSpec>,
    /// Reassemble N shard journals into the full serial-order report
    /// (`--merge N`).
    pub merge: Option<u32>,
    /// Per-cell retry/timeout policy (`--retries`, `--backoff-ms`,
    /// `--timeout-ms`).
    pub policy: CellPolicy,
}

/// Resolve one `--kernel` token to its canonical label and scenario.
/// `pub(crate)`: the lifecycle CLI accepts the same kernel tokens.
pub(crate) fn parse_kernel(tok: &str, cores: usize) -> Result<(&'static str, Scenario), String> {
    let t = tok.trim();
    match t.to_ascii_lowercase().as_str() {
        "matmul-i8" => return Ok(("matmul-i8", Scenario::IntMatmul { w: IntWidth::I8, cores })),
        "matmul-i16" => {
            return Ok(("matmul-i16", Scenario::IntMatmul { w: IntWidth::I16, cores }))
        }
        "matmul-i32" => {
            return Ok(("matmul-i32", Scenario::IntMatmul { w: IntWidth::I32, cores }))
        }
        "matmul-f32" => return Ok(("matmul-f32", Scenario::FpMatmul { w: FpWidth::F32, cores })),
        "matmul-f16" => {
            return Ok(("matmul-f16", Scenario::FpMatmul { w: FpWidth::F16x2, cores }))
        }
        "matmul-f8" => return Ok(("matmul-f8", Scenario::FpMatmul { w: FpWidth::F8x4, cores })),
        _ => {}
    }
    // Table V NSAA kernels run on the fixed 8-core configuration.
    let name = match t.to_ascii_uppercase().as_str() {
        "CONV" => "CONV",
        "DWT" => "DWT",
        "FFT" => "FFT",
        "FIR" => "FIR",
        "IIR" => "IIR",
        "KMEANS" => "KMEANS",
        "SVM" => "SVM",
        "MATMUL" => "MATMUL",
        other => {
            return Err(format!(
                "unknown kernel '{other}' (supported: matmul-i8|matmul-i16|matmul-i32|\
                 matmul-f32|matmul-f16|matmul-f8|CONV|DWT|FFT|FIR|IIR|KMEANS|SVM|MATMUL)"
            ))
        }
    };
    Ok((name, Scenario::Nsaa { name, w: FpWidth::F32 }))
}

fn parse_seeds(s: &str) -> Result<Vec<u64>, String> {
    let mut out = Vec::new();
    for tok in s.split(',').map(str::trim).filter(|t| !t.is_empty()) {
        out.push(tok.parse::<u64>().map_err(|_| format!("bad seed '{tok}'"))?);
    }
    if out.is_empty() {
        return Err("--seeds selected no seeds".into());
    }
    Ok(out)
}

fn parse_rates(s: &str) -> Result<Vec<f64>, String> {
    let mut out = Vec::new();
    for tok in s.split(',').map(str::trim).filter(|t| !t.is_empty()) {
        let r = tok.parse::<f64>().ok().filter(|r| r.is_finite() && *r > 0.0).ok_or_else(
            || format!("bad rate '{tok}' (must be a finite positive upsets-per-Mbit value)"),
        )?;
        out.push(r);
    }
    if out.is_empty() {
        return Err("--rates selected no rates".into());
    }
    Ok(out)
}

impl FaultsCmd {
    /// Parse the arguments following `vega faults`. Unknown flags and
    /// malformed values are errors.
    pub fn parse(args: &[String]) -> Result<FaultsCmd, String> {
        let mut kernel_tok = "matmul-i8".to_string();
        let mut cores = 8usize;
        let mut seeds = vec![1u64];
        let mut rates = vec![1e-6, 1e-5, 1e-4];
        let mut tiers = TierMask::ALL;
        let mut sleep_s = 3600.0f64;
        let mut format = GridFormat::Csv;
        let mut jobs = default_jobs();
        let mut stats = false;
        let mut resume = false;
        let mut shard = None;
        let mut merge = None;
        let mut policy = CellPolicy::default();
        let mut it = args.iter();
        while let Some(a) = it.next() {
            let mut value = |flag: &str| {
                it.next().map(String::as_str).ok_or_else(|| format!("{flag} needs a value"))
            };
            match a.as_str() {
                "--kernel" => kernel_tok = value("--kernel")?.to_string(),
                "--cores" => {
                    let v = value("--cores")?;
                    cores = v
                        .parse::<usize>()
                        .ok()
                        .filter(|&n| (1..=crate::cluster::N_CORES).contains(&n))
                        .ok_or_else(|| {
                            format!(
                                "--cores must be 1..={}, got '{v}'",
                                crate::cluster::N_CORES
                            )
                        })?;
                }
                "--seeds" => seeds = parse_seeds(value("--seeds")?)?,
                "--rates" => rates = parse_rates(value("--rates")?)?,
                "--tiers" => tiers = TierMask::parse(value("--tiers")?)?,
                "--sleep-s" => {
                    let v = value("--sleep-s")?;
                    sleep_s = v
                        .parse::<f64>()
                        .ok()
                        .filter(|s| s.is_finite() && *s > 0.0)
                        .ok_or_else(|| format!("--sleep-s must be a positive duration, got '{v}'"))?;
                }
                "--format" => format = GridFormat::parse(value("--format")?)?,
                "--jobs" => {
                    let v = value("--jobs")?;
                    jobs = v
                        .parse::<usize>()
                        .ok()
                        .filter(|&n| n > 0)
                        .ok_or_else(|| format!("--jobs must be a positive integer, got '{v}'"))?;
                }
                "--stats" => stats = true,
                "--resume" => resume = true,
                "--shard" => shard = Some(ShardSpec::parse(value("--shard")?)?),
                "--merge" => merge = Some(parse_merge(value("--merge")?)?),
                "--retries" => policy.retries = parse_retries(value("--retries")?)?,
                "--backoff-ms" => {
                    policy.backoff_cap_ms = parse_ms("--backoff-ms", value("--backoff-ms")?)?
                }
                "--timeout-ms" => {
                    policy.timeout_ms = Some(parse_ms("--timeout-ms", value("--timeout-ms")?)?)
                }
                other => return Err(format!("unknown flag '{other}'")),
            }
        }
        if merge.is_some() && (shard.is_some() || resume) {
            return Err("--merge reassembles existing shard journals; it conflicts with --shard and --resume".into());
        }
        let (kernel, scenario) = parse_kernel(&kernel_tok, cores)?;
        Ok(FaultsCmd {
            kernel,
            scenario,
            cores,
            seeds,
            rates,
            tiers,
            sleep_s,
            format,
            jobs,
            stats,
            resume,
            shard,
            merge,
            policy,
        })
    }

    /// The grid's campaigns in render order (seed-major, then rate). The
    /// single `--rates` value drives both the MRAM retention rate (scaled
    /// by `--sleep-s`) and the per-run SRAM soft-error rate; the tier
    /// mask selects which of those streams actually fire.
    pub fn campaigns(&self) -> Vec<Campaign> {
        let mut v = Vec::with_capacity(self.seeds.len() * self.rates.len());
        for &seed in &self.seeds {
            for &rate in &self.rates {
                v.push(Campaign {
                    scenario: self.scenario,
                    plan: FaultPlan {
                        seed,
                        sleep_s: self.sleep_s,
                        mram_rate: rate,
                        sram_rate: rate,
                        tiers: self.tiers,
                    },
                });
            }
        }
        v
    }
}

const COLUMNS: [&str; 25] = [
    "kernel",
    "cores",
    "seed",
    "rate",
    "sleep_s",
    "tiers",
    "mram_flips",
    "mram_words",
    "mram_corrected",
    "mram_detected",
    "mram_silent",
    "mram_masked",
    "l2_flips",
    "l2_words",
    "l2_silent",
    "l2_masked",
    "tcdm_flips",
    "tcdm_words",
    "tcdm_silent",
    "tcdm_masked",
    "ecc_corrected",
    "ecc_detected",
    "poisoned_words",
    "diverged",
    "status",
];

/// One rendered grid row: the campaign's coordinates plus either its
/// outcome or the cell's structured error.
struct Row<'a> {
    cmd: &'a FaultsCmd,
    seed: u64,
    rate: f64,
    cell: Result<CampaignOutcome, String>,
}

impl Row<'_> {
    fn cells(&self) -> [String; 25] {
        let mut out: [String; 25] = Default::default();
        out[0] = self.cmd.kernel.to_string();
        out[1] = self.cmd.cores.to_string();
        out[2] = self.seed.to_string();
        out[3] = format!("{:e}", self.rate);
        out[4] = format!("{:.1}", self.cmd.sleep_s);
        out[5] = self.cmd.tiers.label();
        match &self.cell {
            Ok(o) => {
                let m = &o.stats.mram;
                let l = &o.stats.l2;
                let t = &o.stats.tcdm;
                for (i, v) in [
                    m.flips,
                    m.words,
                    m.corrected,
                    m.detected,
                    m.silent,
                    m.masked,
                    l.flips,
                    l.words,
                    l.silent,
                    l.masked,
                    t.flips,
                    t.words,
                    t.silent,
                    t.masked,
                    o.ecc.corrected,
                    o.ecc.detected,
                    o.poisoned_words,
                ]
                .into_iter()
                .enumerate()
                {
                    out[6 + i] = v.to_string();
                }
                out[23] = if o.diverged { "1" } else { "0" }.to_string();
                out[24] = "ok".to_string();
            }
            // Errored cell: coordinates + status only, numerics blank —
            // unmistakable for a zero-upset row.
            Err(msg) => out[24] = sanitize_cell(msg),
        }
        out
    }
}

/// The journal identity of a faults grid (ISSUE 7): kind, every
/// parameter shaping the rendered bytes, and each campaign's versioned
/// key in grid order. The campaign keys already embed
/// [`crate::faults::FAULT_MODEL_VERSION`], so a fault-model bump orphans
/// old journals along with old store entries.
pub fn grid_key(cmd: &FaultsCmd) -> u64 {
    let params = [
        format!("kernel={}", cmd.kernel),
        format!("cores={}", cmd.cores),
        format!("sleep_s={:.1}", cmd.sleep_s),
        format!("tiers={}", cmd.tiers.label()),
        format!("format={}", cmd.format.name()),
    ];
    let params: Vec<&str> = params.iter().map(String::as_str).collect();
    let ids: Vec<String> = cmd.campaigns().iter().map(Campaign::key).collect();
    journal::grid_key("faults", &params, &ids)
}

/// Render `cmd`'s grid through `eng`. The returned string ends in
/// exactly one newline and is byte-identical for any `--jobs`.
pub fn render(eng: &SweepEngine, cmd: &FaultsCmd) -> String {
    render_with(eng, cmd, &GridSession::off()).text
}

/// As [`render`], but through a [`GridSession`] (ISSUE 7): journaled
/// prior cells replay, shard-unowned cells emit no rows, and the
/// returned [`RenderedGrid`] carries the failed/skipped counts the
/// CLI's exit code needs.
pub fn render_with(eng: &SweepEngine, cmd: &FaultsCmd, session: &GridSession) -> RenderedGrid {
    let grid = cmd.campaigns();
    let cells = eng.run_campaigns_with(&grid, session);
    let mut failed = 0;
    let mut skipped = 0;
    let rows: Vec<Row> = grid
        .iter()
        .zip(cells)
        .filter_map(|(c, cell)| match cell {
            None => {
                skipped += 1;
                None
            }
            Some(cell) => {
                if cell.is_err() {
                    failed += 1;
                }
                Some(Row {
                    cmd,
                    seed: c.plan.seed,
                    rate: c.plan.mram_rate,
                    cell: cell.map_err(|e| e.message),
                })
            }
        })
        .collect();
    let text = match cmd.format {
        GridFormat::Csv => render_csv(&rows),
        GridFormat::Markdown => render_md(&rows),
        GridFormat::Json => render_json(cmd, &rows),
    };
    RenderedGrid { text, failed, skipped }
}

fn render_csv(rows: &[Row]) -> String {
    let mut out = COLUMNS.join(",");
    out.push('\n');
    for r in rows {
        out.push_str(&r.cells().join(","));
        out.push('\n');
    }
    out
}

fn render_md(rows: &[Row]) -> String {
    let mut out = format!("| {} |\n", COLUMNS.join(" | "));
    out.push_str(&format!("|{}\n", "---:|".repeat(COLUMNS.len())));
    for r in rows {
        out.push_str(&format!("| {} |\n", r.cells().join(" | ")));
    }
    out
}

fn render_json(cmd: &FaultsCmd, rows: &[Row]) -> String {
    let seeds: Vec<String> = cmd.seeds.iter().map(|s| s.to_string()).collect();
    let rates: Vec<String> = cmd.rates.iter().map(|r| format!("{r:e}")).collect();
    let mut out = format!(
        "{{\n  \"grid\": {{\"kernel\": \"{}\", \"cores\": {}, \"sleep_s\": {:.1}, \
         \"tiers\": \"{}\", \"seeds\": [{}], \"rates\": [{}]}},\n  \"rows\": [\n",
        cmd.kernel,
        cmd.cores,
        cmd.sleep_s,
        cmd.tiers.label(),
        seeds.join(", "),
        rates.join(", ")
    );
    for (i, r) in rows.iter().enumerate() {
        let cells = r.cells();
        out.push_str(&format!("    {{\"seed\": {}, \"rate\": {}, ", cells[2], cells[3]));
        match &r.cell {
            Ok(_) => {
                for (name, cell) in COLUMNS.iter().zip(cells.iter()).skip(6).take(17) {
                    out.push_str(&format!("\"{name}\": {cell}, "));
                }
                out.push_str(&format!(
                    "\"diverged\": {}, \"status\": \"ok\"}}",
                    if cells[23] == "1" { "true" } else { "false" }
                ));
            }
            Err(_) => out.push_str(&format!("\"status\": \"{}\"}}", cells[24])),
        }
        out.push_str(if i + 1 == rows.len() { "\n" } else { ",\n" });
    }
    out.push_str("  ]\n}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parse_round_trips_the_acceptance_invocation() {
        let cmd = FaultsCmd::parse(&argv(&[
            "--kernel",
            "matmul-f32",
            "--cores",
            "8",
            "--seeds",
            "7,8",
            "--rates",
            "1e-5,2e-4",
            "--tiers",
            "mram",
            "--sleep-s",
            "3600",
            "--format",
            "csv",
        ]))
        .unwrap();
        assert_eq!(cmd.kernel, "matmul-f32");
        assert_eq!(cmd.scenario, Scenario::FpMatmul { w: FpWidth::F32, cores: 8 });
        assert_eq!(cmd.seeds, vec![7, 8]);
        assert_eq!(cmd.rates, vec![1e-5, 2e-4]);
        assert_eq!(cmd.tiers, TierMask { mram: true, l2: false, tcdm: false });
        assert_eq!(cmd.campaigns().len(), 4, "2 seeds x 2 rates");
        // NSAA tokens resolve case-insensitively and pin 8 cores.
        let fir = FaultsCmd::parse(&argv(&["--kernel", "fir"])).unwrap();
        assert_eq!(fir.scenario, Scenario::Nsaa { name: "FIR", w: FpWidth::F32 });
        assert!(FaultsCmd::parse(&argv(&["--kernel", "bogus"])).is_err());
        assert!(FaultsCmd::parse(&argv(&["--rates", "0"])).is_err());
        assert!(FaultsCmd::parse(&argv(&["--rates", "nan"])).is_err());
        assert!(FaultsCmd::parse(&argv(&["--seeds", ""])).is_err());
        assert!(FaultsCmd::parse(&argv(&["--cores", "10"])).is_err());
        assert!(FaultsCmd::parse(&argv(&["--frobnicate"])).is_err());
    }

    #[test]
    fn csv_grid_renders_every_cell_with_ok_status() {
        let cmd = FaultsCmd::parse(&argv(&[
            "--kernel",
            "matmul-f32",
            "--cores",
            "2",
            "--seeds",
            "3",
            "--rates",
            "1e-4",
            "--sleep-s",
            "3600",
        ]))
        .unwrap();
        let eng = SweepEngine::serial();
        let out = render(&eng, &cmd);
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines.len(), 1 + 1);
        assert_eq!(lines[0], COLUMNS.join(","));
        assert!(lines[1].starts_with("matmul-f32,2,3,1e-4,3600.0,mram+l2+tcdm,"));
        assert!(lines[1].ends_with(",ok"));
        // Every data column is populated (no blank numerics on ok rows).
        assert_eq!(lines[1].split(',').count(), COLUMNS.len());
        assert!(lines[1].split(',').all(|c| !c.is_empty()));
    }

    /// ISSUE 7: the faults CLI grows the same resume/shard/merge/policy
    /// surface as `vega sweep`, with the same merge conflicts.
    #[test]
    fn parse_handles_resume_shard_merge_and_policy() {
        let cmd =
            FaultsCmd::parse(&argv(&["--resume", "--shard", "1/2", "--timeout-ms", "0"])).unwrap();
        assert!(cmd.resume);
        assert_eq!(cmd.shard, Some(ShardSpec { index: 1, total: 2 }));
        assert_eq!(cmd.policy.timeout_ms, Some(0));
        assert!(FaultsCmd::parse(&argv(&["--merge", "2", "--resume"])).is_err());
        assert!(FaultsCmd::parse(&argv(&["--shard", "0/2"])).is_err());
    }

    /// The journal key tracks every grid axis.
    #[test]
    fn faults_grid_key_tracks_every_axis() {
        let base = argv(&["--kernel", "matmul-i8", "--seeds", "1,2", "--rates", "1e-5"]);
        let k = grid_key(&FaultsCmd::parse(&base).unwrap());
        assert_eq!(k, grid_key(&FaultsCmd::parse(&base).unwrap()), "deterministic");
        for delta in [
            argv(&["--kernel", "matmul-i16", "--seeds", "1,2", "--rates", "1e-5"]),
            argv(&["--kernel", "matmul-i8", "--seeds", "1,3", "--rates", "1e-5"]),
            argv(&["--kernel", "matmul-i8", "--seeds", "1,2", "--rates", "1e-4"]),
            argv(&["--kernel", "matmul-i8", "--seeds", "1,2", "--rates", "1e-5", "--tiers", "mram"]),
            argv(&["--kernel", "matmul-i8", "--seeds", "1,2", "--rates", "1e-5", "--format", "md"]),
        ] {
            assert_ne!(k, grid_key(&FaultsCmd::parse(&delta).unwrap()), "{delta:?}");
        }
    }
}
