//! Campaign description and deterministic flip-list expansion.
//!
//! A [`FaultPlan`] is *rates*, not flips: MRAM retention upsets per Mbit
//! per second of sleep, SRAM soft errors per Mbit per run. [`expand`]
//! (`FaultPlan::expand`) turns the rates into an exact, ordered list of
//! `(unit, bit, time)` flips using one [`Rng`] stream per tier, salted
//! from the campaign seed — so the same plan expands to the same flips
//! on every machine, at any `--jobs`, forever. No global state, no
//! wall-clock entropy.

use crate::common::Rng;

use super::Tier;

/// Per-tier salts XORed into the campaign seed so each tier draws from
/// an independent deterministic stream — enabling or masking one tier
/// never perturbs another tier's flips.
const SALT_MRAM: u64 = 0x4D52_414D; // "MRAM"
const SALT_L2: u64 = 0x4C32_5352; // "L2SR"
const SALT_TCDM: u64 = 0x5443_444D; // "TCDM"

/// MRAM codeword width: 64 data + 7 check + 1 parity modeled bits.
const MRAM_UNIT_BITS: u64 = 72;

/// Which tiers a campaign may flip.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TierMask {
    pub mram: bool,
    pub l2: bool,
    pub tcdm: bool,
}

impl TierMask {
    pub const ALL: TierMask = TierMask { mram: true, l2: true, tcdm: true };

    /// Parse a comma-separated tier list (`mram,l2,tcdm`; `l1` is
    /// accepted as an alias for `tcdm`).
    pub fn parse(s: &str) -> Result<TierMask, String> {
        let mut m = TierMask { mram: false, l2: false, tcdm: false };
        for part in s.split(',') {
            match part.trim() {
                "mram" => m.mram = true,
                "l2" => m.l2 = true,
                "tcdm" | "l1" => m.tcdm = true,
                other => return Err(format!("unknown tier '{other}' (expected mram, l2, tcdm)")),
            }
        }
        if !(m.mram || m.l2 || m.tcdm) {
            return Err("empty tier mask".into());
        }
        Ok(m)
    }

    /// Canonical `mram+l2+tcdm` subset label (stable: used in cache keys
    /// and report rows).
    pub fn label(&self) -> String {
        let mut parts = Vec::new();
        if self.mram {
            parts.push("mram");
        }
        if self.l2 {
            parts.push("l2");
        }
        if self.tcdm {
            parts.push("tcdm");
        }
        parts.join("+")
    }

    pub fn enabled(&self, t: Tier) -> bool {
        match t {
            Tier::Mram => self.mram,
            Tier::L2 => self.l2,
            Tier::Tcdm => self.tcdm,
        }
    }
}

/// One exact bit upset: storage `unit` (64-bit codeword index for MRAM,
/// byte index for SRAM tiers), `bit` within the unit, and a normalized
/// occurrence `time` in [0, 1) that orders the flips within the modeled
/// interval.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Flip {
    pub unit: usize,
    pub bit: u32,
    pub time: f64,
}

/// All flips one campaign injects into one tier, time-ordered.
#[derive(Debug, Clone, PartialEq)]
pub struct FlipList {
    pub tier: Tier,
    pub flips: Vec<Flip>,
}

/// A seeded fault campaign over one scenario's input image.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultPlan {
    /// Campaign seed — the whole expansion derives from it.
    pub seed: u64,
    /// Modeled sleep interval in seconds (scales MRAM retention upsets;
    /// the SRAM tiers are powered off in retentive sleep, so it does not
    /// scale them).
    pub sleep_s: f64,
    /// MRAM retention-upset rate: upsets per Mbit per second of sleep.
    pub mram_rate: f64,
    /// SRAM soft-error rate: upsets per Mbit per (active) run.
    pub sram_rate: f64,
    /// Which tiers to attack.
    pub tiers: TierMask,
}

impl FaultPlan {
    /// Expand the rates into exact per-tier flip lists for an input
    /// image of `image_len` bytes. Canonical tier order MRAM → L2 →
    /// TCDM; each tier draws from its own salted stream, so the same
    /// seed yields the same MRAM flips whether or not L2 is masked.
    pub fn expand(&self, image_len: usize) -> Vec<FlipList> {
        let mut out = Vec::new();
        if self.tiers.mram {
            let words = image_len.div_ceil(8);
            let lambda =
                self.mram_rate * (words as f64 * MRAM_UNIT_BITS as f64 / 1e6) * self.sleep_s;
            out.push(self.expand_tier(Tier::Mram, SALT_MRAM, words, MRAM_UNIT_BITS, lambda));
        }
        if self.tiers.l2 {
            let lambda = self.sram_rate * (image_len as f64 * 8.0 / 1e6);
            out.push(self.expand_tier(Tier::L2, SALT_L2, image_len, 8, lambda));
        }
        if self.tiers.tcdm {
            let lambda = self.sram_rate * (image_len as f64 * 8.0 / 1e6);
            out.push(self.expand_tier(Tier::Tcdm, SALT_TCDM, image_len, 8, lambda));
        }
        out
    }

    fn expand_tier(
        &self,
        tier: Tier,
        salt: u64,
        units: usize,
        unit_bits: u64,
        lambda: f64,
    ) -> FlipList {
        let mut rng = Rng::new(self.seed ^ salt);
        // Expected count λ realized as floor(λ) certain flips plus one
        // Bernoulli(frac(λ)) flip — deterministic given the stream, with
        // E[count] = λ exactly.
        let count = if units == 0 {
            0
        } else {
            lambda as u64 + u64::from(rng.f64() < lambda.fract())
        };
        let mut flips: Vec<Flip> = (0..count)
            .map(|_| Flip {
                unit: rng.below(units as u64) as usize,
                bit: rng.below(unit_bits) as u32,
                time: rng.f64(),
            })
            .collect();
        // Stable time order: XOR injection is commutative, but a pinned
        // order keeps the expansion itself byte-reproducible.
        flips.sort_by(|a, b| a.time.partial_cmp(&b.time).expect("times are finite"));
        FlipList { tier, flips }
    }

    /// Stable key fragment for cache/report identity: every field that
    /// changes the expansion, bit-exact (f64 fields via `to_bits`).
    pub fn key_fragment(&self) -> String {
        format!(
            "seed={:016x}|sleep={:016x}|mr={:016x}|sr={:016x}|tiers={}",
            self.seed,
            self.sleep_s.to_bits(),
            self.mram_rate.to_bits(),
            self.sram_rate.to_bits(),
            self.tiers.label()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan() -> FaultPlan {
        FaultPlan {
            seed: 42,
            sleep_s: 3600.0,
            mram_rate: 1e-4,
            sram_rate: 1e-3,
            tiers: TierMask::ALL,
        }
    }

    #[test]
    fn expansion_is_deterministic() {
        assert_eq!(plan().expand(4096), plan().expand(4096));
    }

    #[test]
    fn tier_streams_are_independent_of_the_mask() {
        let full = plan().expand(4096);
        let solo = FaultPlan { tiers: TierMask { mram: false, l2: false, tcdm: true }, ..plan() }
            .expand(4096);
        let tcdm_full = full.iter().find(|l| l.tier == Tier::Tcdm).unwrap();
        assert_eq!(solo.len(), 1);
        assert_eq!(&solo[0], tcdm_full, "masking other tiers must not move TCDM's flips");
    }

    #[test]
    fn flips_stay_in_bounds_and_time_ordered() {
        for list in plan().expand(4096) {
            let (units, bits) = match list.tier {
                Tier::Mram => (4096usize.div_ceil(8), 72),
                Tier::L2 | Tier::Tcdm => (4096, 8),
            };
            let mut last = 0.0f64;
            for f in &list.flips {
                assert!(f.unit < units);
                assert!(f.bit < bits);
                assert!((0.0..1.0).contains(&f.time));
                assert!(f.time >= last, "flips must be time-sorted");
                last = f.time;
            }
        }
    }

    #[test]
    fn count_is_floor_or_ceil_of_lambda() {
        // λ for MRAM here: 1e-4 × (512 × 72 / 1e6) × 3600 ≈ 13.27.
        let lists = plan().expand(4096);
        let mram = lists.iter().find(|l| l.tier == Tier::Mram).unwrap();
        let lambda = 1e-4 * (512.0 * 72.0 / 1e6) * 3600.0;
        let n = mram.flips.len() as f64;
        assert!(n == lambda.floor() || n == lambda.floor() + 1.0, "count {n} vs λ {lambda}");
    }

    #[test]
    fn empty_image_expands_to_no_flips() {
        for list in plan().expand(0) {
            assert!(list.flips.is_empty());
        }
    }

    #[test]
    fn tier_mask_parse_and_label_round_trip() {
        assert_eq!(TierMask::parse("mram,l2,tcdm").unwrap(), TierMask::ALL);
        assert_eq!(TierMask::parse("l1").unwrap().label(), "tcdm");
        assert_eq!(TierMask::parse("mram").unwrap().label(), "mram");
        assert!(TierMask::parse("flash").is_err());
        assert_eq!(TierMask::ALL.label(), "mram+l2+tcdm");
    }
}
