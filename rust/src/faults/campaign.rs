//! Campaign execution: stage a scenario's inputs through the real
//! memory tiers under injected upsets, classify every outcome, then run
//! the unmodified kernel on whatever survived.
//!
//! The injection model is *pre-run image corruption*: the scenario's
//! serialized input image is written into a tier ([`crate::mem::Mram`]
//! for the retention store, [`crate::iss::FlatMem`] for L2,
//! [`crate::cluster::Tcdm`] for L1), the plan's flips are applied
//! through the tier's own injection hook, and the image is read back
//! through the tier's architectural path — for MRAM that is the live
//! SECDED decode with correction, scrubbing, counter bumps and the
//! typed [`MemFault`] on uncorrectables. The kernel then runs, bit-true,
//! on the post-fault bytes; divergence is judged against the fault-free
//! oracle's output digest. The normal `simulate()` path shares none of
//! this staging — campaigns cost nothing when not requested.

use crate::cluster::{TCDM_BASE, TCDM_SIZE};
use crate::iss::FlatMem;
use crate::kernels::KernelRun;
use crate::mem::ecc::{self, EccResult};
use crate::mem::mram::EccStats;
use crate::mem::{MemFault, Mram};
use crate::sweep::{Scenario, SimArena, SimResult};

use super::plan::{FaultPlan, FlipList};
use super::{FaultStats, Tier, TierFaults};

/// Version of the fault model (expansion algorithm, classification
/// rules, outcome payload). Part of every campaign's cache key: bump it
/// when the model changes so persisted outcomes can never go stale.
pub const FAULT_MODEL_VERSION: u32 = 1;

/// One cell of a campaign grid: a scenario attacked by a plan.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Campaign {
    pub scenario: Scenario,
    pub plan: FaultPlan,
}

impl Campaign {
    /// Stable identity string: fault-model version, the scenario's full
    /// persisted cache key (kernel, size, precision, cores, program
    /// hash), and the plan's bit-exact parameter fragment.
    pub fn key(&self) -> String {
        format!(
            "faults-v{}|{}|{}",
            FAULT_MODEL_VERSION,
            crate::sweep::persist::key_string(&self.scenario.key()),
            self.plan.key_fragment()
        )
    }

    /// The exact flip lists this campaign injects: the plan expanded
    /// against the scenario's staged input-image length. This is the
    /// same expansion [`run_campaign`] performs, exposed so tests and
    /// reports can derive classification expectations from the flips
    /// alone, without re-running the campaign.
    pub fn flip_lists(&self) -> Vec<FlipList> {
        let image_len = self.scenario.canonical().gen_inputs().to_bytes().len();
        self.plan.expand(image_len)
    }
}

/// Everything one campaign produced.
#[derive(Debug, Clone, PartialEq)]
pub struct CampaignOutcome {
    /// The faulted kernel run (its `stats.faults` ledger is populated —
    /// the one place in the codebase where it is nonzero).
    pub run: KernelRun,
    /// Per-tier classification counters (same data as
    /// `run.stats.faults`, duplicated at top level for reporting).
    pub stats: FaultStats,
    /// The MRAM controller's own ECC counters from the architectural
    /// read-back. `ecc.corrected` can exceed `stats.mram.corrected`:
    /// ≥3-flip escapes decode as "corrections" at the controller while
    /// the classifier, which knows the staged truth, counts them silent.
    pub ecc: EccStats,
    /// Words the MRAM read-back reported detected-uncorrectable
    /// (the typed [`MemFault`] path).
    pub poisoned_words: u64,
    /// Output digest of the fault-free oracle run.
    pub oracle_digest: u64,
    /// Output digest of the faulted run.
    pub faulted_digest: u64,
    /// Whether the faulted outputs differ from the oracle's.
    pub diverged: bool,
}

/// Run one campaign on `arena`, judging divergence against `oracle`
/// (the scenario's fault-free [`SimResult`]). Deterministic: the flip
/// lists come from the plan's seed alone, and injection is pure XOR
/// staging — two runs of the same campaign are bit-identical at any
/// worker count.
pub fn run_campaign(c: &Campaign, oracle: &SimResult, arena: &mut SimArena) -> CampaignOutcome {
    let scenario = c.scenario.canonical();
    let mut image = scenario.gen_inputs().to_bytes();
    let lists = c.plan.expand(image.len());

    let mut stats = FaultStats::default();
    let mut ecc = EccStats::default();
    let mut poisoned_words = 0u64;
    for list in &lists {
        match list.tier {
            Tier::Mram => inject_mram(
                &mut image,
                list,
                stats.tier_mut(Tier::Mram),
                &mut ecc,
                &mut poisoned_words,
            ),
            Tier::L2 => {
                arena.l2.reset();
                inject_flat(&mut arena.l2, &mut image, list, stats.tier_mut(Tier::L2));
            }
            Tier::Tcdm => {
                assert!(image.len() <= TCDM_SIZE, "campaign image must fit the 128 kB L1");
                let tcdm = &mut arena.cluster.tcdm;
                tcdm.reset();
                tcdm.mem.write_bytes(TCDM_BASE, &image);
                for f in &list.flips {
                    tcdm.flip_bit(TCDM_BASE + f.unit as u32, f.bit as u8);
                }
                let after = tcdm.mem.read_bytes(TCDM_BASE, image.len()).to_vec();
                classify_plain(&image, &after, list, stats.tier_mut(Tier::Tcdm));
                image = after;
            }
        }
    }

    // The kernel itself runs unmodified on the post-fault image
    // (run_on resets the arena, harmlessly wiping the staging bytes).
    let faulted = scenario.run_on(arena, &scenario.with_bytes(&image));
    let mut run = faulted.run;
    run.stats.faults = stats;
    CampaignOutcome {
        run,
        stats,
        ecc,
        poisoned_words,
        oracle_digest: oracle.outputs_digest,
        faulted_digest: faulted.outputs_digest,
        diverged: faulted.outputs_digest != oracle.outputs_digest,
    }
}

/// The 64-bit data word `w` of the staged image, zero-padded past the
/// end (matching [`Mram::new`]'s zero-initialized array).
fn word_truth(image: &[u8], w: usize) -> u64 {
    let mut b = [0u8; 8];
    let start = w * 8;
    let end = (start + 8).min(image.len());
    b[..end - start].copy_from_slice(&image[start..end]);
    u64::from_le_bytes(b)
}

/// MRAM hop: write the image, apply the plan's codeword flips, classify
/// every upset word against the staged truth via a raw SECDED decode,
/// then perform the architectural read-back (live correction, scrub,
/// [`MemFault`] on uncorrectables) whose bytes become the new image.
fn inject_mram(
    image: &mut Vec<u8>,
    list: &FlipList,
    tf: &mut TierFaults,
    ecc_out: &mut EccStats,
    poisoned: &mut u64,
) {
    if list.flips.is_empty() {
        return;
    }
    let mut mram = Mram::new();
    mram.write(0, image);
    for f in &list.flips {
        mram.inject_bit_flip(f.unit * 8, f.bit);
    }
    tf.flips += list.flips.len() as u64;

    let mut units: Vec<usize> = list.flips.iter().map(|f| f.unit).collect();
    units.sort_unstable();
    units.dedup();
    tf.words += units.len() as u64;
    for &w in &units {
        let truth = word_truth(image, w);
        match ecc::decode(mram.codeword(w * 8)) {
            // Clean with the right data = the flips net-cancelled;
            // clean with wrong data would be a ≥4-flip valid-codeword
            // escape — silent by definition.
            EccResult::Clean(v) if v == truth => tf.masked += 1,
            EccResult::Clean(_) => tf.silent += 1,
            // Corrected back to truth is SECDED doing its job; a
            // "correction" to the wrong value is a ≥3-flip
            // miscorrection escape — silent data corruption.
            EccResult::Corrected(v) if v == truth => tf.corrected += 1,
            EccResult::Corrected(_) => tf.silent += 1,
            EccResult::Detected(_) => tf.detected += 1,
        }
    }

    let len = image.len();
    let bytes = match mram.read(0, len) {
        Ok(b) => b,
        Err(fault) => {
            let MemFault::Uncorrectable { ref word_offsets, .. } = fault;
            *poisoned += word_offsets.len() as u64;
            fault.into_data()
        }
    };
    ecc_out.corrected += mram.ecc_stats.corrected;
    ecc_out.detected += mram.ecc_stats.detected;
    *image = bytes;
}

/// Unprotected-SRAM hop (L2): stage, flip through the tier hook, read
/// back, classify byte-wise.
fn inject_flat(mem: &mut FlatMem, image: &mut Vec<u8>, list: &FlipList, tf: &mut TierFaults) {
    let base = mem.base;
    mem.write_bytes(base, image);
    for f in &list.flips {
        mem.flip_bit(base + f.unit as u32, f.bit as u8);
    }
    let after = mem.read_bytes(base, image.len()).to_vec();
    classify_plain(image, &after, list, tf);
    *image = after;
}

/// Classify an unprotected tier's upsets: a byte that reads back equal
/// to the staged value had its flips net-cancel (masked); anything else
/// is silent data corruption — there is no ECC to correct or detect.
fn classify_plain(before: &[u8], after: &[u8], list: &FlipList, tf: &mut TierFaults) {
    tf.flips += list.flips.len() as u64;
    let mut units: Vec<usize> = list.flips.iter().map(|f| f.unit).collect();
    units.sort_unstable();
    units.dedup();
    tf.words += units.len() as u64;
    for &u in &units {
        if after[u] == before[u] {
            tf.masked += 1;
        } else {
            tf.silent += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faults::TierMask;
    use crate::kernels::fp_matmul::FpWidth;

    fn campaign(seed: u64) -> Campaign {
        Campaign {
            scenario: Scenario::FpMatmul { w: FpWidth::F32, cores: 2 },
            plan: FaultPlan {
                seed,
                sleep_s: 3600.0,
                mram_rate: 1e-4,
                sram_rate: 1e-3,
                tiers: TierMask::ALL,
            },
        }
    }

    #[test]
    fn campaign_is_deterministic_on_one_arena() {
        let mut arena = SimArena::new();
        let c = campaign(7);
        let oracle = c.scenario.simulate(&mut arena);
        let a = run_campaign(&c, &oracle, &mut arena);
        let b = run_campaign(&c, &oracle, &mut arena);
        assert_eq!(a, b);
    }

    #[test]
    fn classification_accounts_for_every_upset_unit() {
        let mut arena = SimArena::new();
        let c = campaign(11);
        let oracle = c.scenario.simulate(&mut arena);
        let out = run_campaign(&c, &oracle, &mut arena);
        for t in [Tier::Mram, Tier::L2, Tier::Tcdm] {
            let tf = out.stats.tier(t);
            assert_eq!(tf.classified(), tf.words, "{}: every unit classified once", t.name());
            assert!(tf.flips >= tf.words, "{}: units can't outnumber flips", t.name());
        }
        assert_eq!(out.diverged, out.faulted_digest != out.oracle_digest);
    }

    #[test]
    fn keys_separate_seeds_scenarios_and_model_version() {
        let a = campaign(1).key();
        let b = campaign(2).key();
        assert_ne!(a, b);
        assert!(a.starts_with("faults-v1|"));
        let other = Campaign {
            scenario: Scenario::FpMatmul { w: FpWidth::F32, cores: 4 },
            plan: campaign(1).plan,
        };
        assert_ne!(a, other.key());
    }
}
