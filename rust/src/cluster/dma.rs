//! Cluster DMA: autonomous L2 ↔ L1 mover programmed by the orchestrator
//! core (§IV-B stage 2/4 of the tiling pipeline).
//!
//! Calibration (Table VI): L2↔L1 sustains 1900 MB/s at 250 MHz ⇒ 7.6 B per
//! cluster cycle, i.e. a 64-bit AXI beat per cycle minus protocol
//! overhead. We model a 64-bit datapath with a fixed per-job setup cost;
//! the sustained-rate anchor is asserted by tests.

use crate::common::Cycles;

/// Bytes moved per cluster cycle once streaming (64-bit AXI beat).
pub const BYTES_PER_CYCLE: u64 = 8;

/// Fixed cycles to program + launch one 1-D transfer (register writes by
/// the orchestrator core plus command queue latency).
pub const JOB_SETUP_CYCLES: Cycles = 16;

/// Efficiency factor < 1.0 capturing AXI/interconnect overhead so the
/// sustained bandwidth matches the measured 1900 MB/s (= 7.6 B/cycle of
/// the 8 B/cycle raw datapath).
pub const EFFICIENCY: f64 = 0.95;

/// A DMA transfer descriptor (1-D or 2-D strided, as the real cluster DMA
/// supports for tile copies).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DmaJob {
    pub bytes: u64,
    /// Number of 1-D lines (2-D transfers pay per-line re-setup).
    pub lines: u64,
}

impl DmaJob {
    pub fn linear(bytes: u64) -> Self {
        Self { bytes, lines: 1 }
    }

    pub fn strided(bytes_per_line: u64, lines: u64) -> Self {
        Self { bytes: bytes_per_line * lines, lines }
    }
}

/// The DMA engine (timing model; data movement itself is performed by the
/// caller on host memory, which is exact since the DMA is a pure copy).
#[derive(Debug, Default)]
pub struct ClusterDma {
    pub jobs: u64,
    pub bytes: u64,
    pub busy_cycles: Cycles,
}

impl ClusterDma {
    pub fn new() -> Self {
        Self::default()
    }

    /// Cycles to complete `job` (the engine is single-channel; the tiling
    /// pipeline double-buffers around it).
    pub fn job_cycles(job: DmaJob) -> Cycles {
        let stream = (job.bytes as f64 / (BYTES_PER_CYCLE as f64 * EFFICIENCY)).ceil() as u64;
        // 2-D transfers pay a small per-line address-regeneration cost.
        JOB_SETUP_CYCLES + stream + job.lines.saturating_sub(1) * 2
    }

    /// Record a job's execution and return its latency.
    pub fn run(&mut self, job: DmaJob) -> Cycles {
        let c = Self::job_cycles(job);
        self.jobs += 1;
        self.bytes += job.bytes;
        self.busy_cycles += c;
        c
    }

    /// Sustained bandwidth in bytes/cycle for a given job size (tends to
    /// `BYTES_PER_CYCLE * EFFICIENCY` for large jobs).
    pub fn sustained_bpc(job: DmaJob) -> f64 {
        job.bytes as f64 / Self::job_cycles(job) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn large_transfers_hit_sustained_rate() {
        // 64 kB linear: must sustain ≈ 7.6 B/cycle (1900 MB/s @ 250 MHz).
        let bpc = ClusterDma::sustained_bpc(DmaJob::linear(64 * 1024));
        assert!((bpc - 7.6).abs() < 0.1, "bpc = {bpc}");
    }

    #[test]
    fn setup_dominates_tiny_transfers() {
        let c = ClusterDma::job_cycles(DmaJob::linear(8));
        assert!(c >= JOB_SETUP_CYCLES + 1);
    }

    #[test]
    fn strided_pays_per_line() {
        let lin = ClusterDma::job_cycles(DmaJob::linear(4096));
        let strided = ClusterDma::job_cycles(DmaJob::strided(64, 64));
        assert!(strided > lin);
        assert_eq!(strided - lin, 63 * 2);
    }

    #[test]
    fn run_accumulates_stats() {
        let mut d = ClusterDma::new();
        d.run(DmaJob::linear(1024));
        d.run(DmaJob::linear(1024));
        assert_eq!(d.jobs, 2);
        assert_eq!(d.bytes, 2048);
        assert!(d.busy_cycles > 0);
    }
}
