//! The 9-core parallel compute cluster (§II-C) and its cycle-level driver.
//!
//! Nine RI5CY cores share a 16-bank word-interleaved 128 kB L1 TCDM behind
//! a 1-cycle logarithmic interconnect, four statically-mapped FPUs, a
//! shared DIV-SQRT unit, a hierarchical instruction cache, an event unit
//! for barriers, and a cluster DMA to L2. The driver advances all cores in
//! lock-step one cycle at a time, arbitrating TCDM banks and FPU issue
//! slots each cycle — contention is *emergent*, not assumed.

pub mod dma;
pub mod event_unit;
pub mod fpu;
pub mod tcdm;

pub use dma::{ClusterDma, DmaJob};
pub use event_unit::EventUnit;
pub use fpu::{fpu_of_core, FpuFabric, N_FPUS};
pub use tcdm::{Tcdm, TCDM_BANKS, TCDM_BASE, TCDM_SIZE};

use crate::isa::predecode::DecodedKind;
use crate::isa::{Program, Reg};
use crate::iss::{Core, CoreState, CoreStats, FlatMem, Intent, Memory};

/// Cores in the cluster: 8 compute + 1 orchestrator (core 8, larger I$).
pub const N_CORES: usize = 9;

/// L2 as seen from the cluster (through the AXI master port).
pub const L2_BASE: u32 = 0x1C00_0000;
pub const L2_SIZE: usize = (1536 + 64) * 1024;

/// Extra cycles for a cluster-side access that misses TCDM and crosses
/// the dual-clock FIFO + SoC interconnect into L2 (`pub(crate)`: the
/// superblock replay profile charges the same constant).
pub(crate) const CLUSTER_TO_L2_LATENCY: u64 = 8;

/// Combined cluster-visible memory: TCDM + L2 window.
pub struct ClusterMemView<'a> {
    pub tcdm: &'a mut FlatMem,
    pub l2: &'a mut FlatMem,
}

impl Memory for ClusterMemView<'_> {
    fn load(&mut self, addr: u32, size: crate::isa::MemSize) -> u32 {
        if Tcdm::contains(addr) {
            self.tcdm.load(addr, size)
        } else {
            self.l2.load(addr, size)
        }
    }

    fn store(&mut self, addr: u32, size: crate::isa::MemSize, value: u32) {
        if Tcdm::contains(addr) {
            self.tcdm.store(addr, size, value)
        } else {
            self.l2.store(addr, size, value)
        }
    }
}

/// Aggregated result of one cluster run.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterStats {
    /// Wall-clock cluster cycles (barrier-to-halt of the slowest core).
    pub cycles: u64,
    pub per_core: Vec<CoreStats>,
    /// Sums of work counters across cores (cycles = max).
    pub total: CoreStats,
    pub tcdm_conflict_rate: f64,
    pub fpu_contention_rate: f64,
    pub barrier_gated_cycles: u64,
    /// Fault-injection ledger (ISSUE 6). All zeros outside fault
    /// campaigns — the normal simulation path never touches it.
    pub faults: crate::faults::FaultStats,
}

impl ClusterStats {
    /// MACs/cycle equivalent given ops-per-MAC = 2 (paper convention).
    pub fn mac_per_cycle(&self) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        (self.total.int_ops as f64 / 2.0) / self.cycles as f64
    }

    pub fn flops_per_cycle(&self) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        self.total.flops as f64 / self.cycles as f64
    }
}

/// Scheduler used by [`Cluster::run_program`].
///
/// Both produce bit-identical [`ClusterStats`] and memory/register state
/// (asserted by `tests/scheduler_equivalence.rs`); the reference loop is
/// retained as the oracle for the fast path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerMode {
    /// Cycle-skipping fast path (default): when every active core is
    /// draining a busy counter or parked at a barrier that cannot release,
    /// the cluster clock jumps to the next issue opportunity in one step.
    CycleSkip,
    /// The original one-cycle-per-loop-iteration driver.
    Reference,
}

/// The cluster fabric.
pub struct Cluster {
    pub cores: Vec<Core>,
    pub tcdm: Tcdm,
    pub fpus: FpuFabric,
    pub dma: ClusterDma,
    pub event_unit: EventUnit,
    /// Scheduler selection (equivalence tests and ablations flip this).
    pub scheduler: SchedulerMode,
    /// Superblock replay (§Perf, hot-path layer 3): batch-execute
    /// straight-line hardware-loop bodies when a single core owns the
    /// cluster. Defaults to [`crate::iss::superblock::env_default`]
    /// (`VEGA_SUPERBLOCKS=off` disables); equivalence tests and the
    /// hotpath bench flip it per run.
    pub superblocks: bool,
    cycle: u64,
    /// Shared-L1.5 warm bitmap, reused across runs (no per-run alloc).
    warm: Vec<bool>,
}

impl Cluster {
    pub fn new() -> Self {
        Self {
            cores: (0..N_CORES).map(Core::new).collect(),
            tcdm: Tcdm::new(),
            fpus: FpuFabric::new(),
            dma: ClusterDma::new(),
            event_unit: EventUnit::new(N_CORES),
            scheduler: SchedulerMode::CycleSkip,
            superblocks: crate::iss::superblock::env_default(),
            cycle: 0,
            warm: Vec::new(),
        }
    }

    /// Cheap between-runs reset: clears TCDM contents, arbitration
    /// pointers and counters without re-allocating the 128 kB backing
    /// store (§Perf: drivers that used to build a fresh `Cluster` per
    /// kernel invocation reuse one instead). Restores the default FPU
    /// fabric configuration — unlike the per-run [`FpuFabric::reset`],
    /// which deliberately preserves the ablation switch across a single
    /// driver's set-flag-then-run sequence. The `scheduler` selection and
    /// the `superblocks` switch are deliberately *not* restored: the
    /// hotpath bench and the equivalence tests flip them between timed
    /// runs that each call `reset()`. Callers needing a fully default
    /// cluster (the sweep arena, whose cache key has neither a scheduler
    /// nor a superblock component — both are bit-identical by the
    /// equivalence suite) pin them themselves.
    pub fn reset(&mut self) {
        self.tcdm.reset();
        self.fpus.reset();
        self.fpus.private_per_core = false;
        self.dma = ClusterDma::new();
        self.event_unit = EventUnit::new(N_CORES);
        self.cycle = 0;
        for c in &mut self.cores {
            c.reset(0);
        }
    }

    /// Per-run state reset shared by both scheduler loops.
    fn reset_for_run(
        &mut self,
        prog: &Program,
        n_active: usize,
        init: &impl Fn(usize) -> Vec<(Reg, u32)>,
    ) {
        assert!(n_active >= 1 && n_active <= N_CORES);
        self.tcdm.grants = 0;
        self.tcdm.conflicts = 0;
        self.fpus.reset();
        self.event_unit = EventUnit::new(n_active);
        self.cycle = 0;
        for (i, core) in self.cores.iter_mut().enumerate().take(n_active) {
            core.reset(prog.insts.len());
            for (r, v) in init(i) {
                core.set_reg(r, v);
            }
        }
        self.warm.clear();
        self.warm.resize(prog.insts.len(), false);
    }

    fn collect_stats(&self, n_active: usize) -> ClusterStats {
        let per_core: Vec<CoreStats> =
            self.cores[..n_active].iter().map(|c| c.stats.clone()).collect();
        let mut total = CoreStats::default();
        for s in &per_core {
            total.merge(s);
        }
        ClusterStats {
            cycles: self.cycle,
            per_core,
            total,
            tcdm_conflict_rate: self.tcdm.conflict_rate(),
            fpu_contention_rate: self.fpus.contention_rate(),
            barrier_gated_cycles: self.event_unit.gated_cycles,
            faults: crate::faults::FaultStats::default(),
        }
    }

    /// Run `prog` on cores `0..n_active` to completion (all halt).
    ///
    /// Every core runs the same instruction stream, parameterised by its
    /// initial registers from `init(core_id)` — the SPMD model of PULP
    /// kernels. `l2` is the cluster's view of the SoC L2.
    pub fn run_program(
        &mut self,
        prog: &Program,
        n_active: usize,
        l2: &mut FlatMem,
        init: impl Fn(usize) -> Vec<(Reg, u32)>,
        max_cycles: u64,
    ) -> ClusterStats {
        match self.scheduler {
            SchedulerMode::CycleSkip => self.run_fast(prog, n_active, l2, &init, max_cycles),
            SchedulerMode::Reference => {
                self.run_reference(prog, n_active, l2, &init, max_cycles)
            }
        }
    }

    /// As [`Cluster::run_program`] but always on the retained reference
    /// loop, regardless of [`Cluster::scheduler`].
    pub fn run_program_reference(
        &mut self,
        prog: &Program,
        n_active: usize,
        l2: &mut FlatMem,
        init: impl Fn(usize) -> Vec<(Reg, u32)>,
        max_cycles: u64,
    ) -> ClusterStats {
        self.run_reference(prog, n_active, l2, &init, max_cycles)
    }

    /// The cycle-skipping driver (§Perf).
    ///
    /// Invariants that make the skip exact:
    /// * a skipped cycle performs no arbitration — every active core is
    ///   `Ready` with `busy > 0` (pure stall) or `AtBarrier`;
    /// * the barrier cannot release inside the window (some running core
    ///   is not waiting), so `EventUnit::tick` would return false and only
    ///   accumulate `waiting` gated cycles per skipped cycle;
    /// * per skipped cycle a busy core does exactly `cycles += 1; busy -= 1`
    ///   and a barrier core `cycles += 1; stall_barrier += 1`
    ///   ([`Core::skip_stall_cycles`] applies `delta` of them at once);
    /// * `delta = min(busy)` stops at the first cycle where some core can
    ///   issue again, which the per-cycle path then handles normally.
    fn run_fast(
        &mut self,
        prog: &Program,
        n_active: usize,
        l2: &mut FlatMem,
        init: &impl Fn(usize) -> Vec<(Reg, u32)>,
        max_cycles: u64,
    ) -> ClusterStats {
        let pre = prog.predecode();
        self.reset_for_run(prog, n_active, init);

        let mut mem_reqs: Vec<(usize, crate::iss::MemReq)> = Vec::with_capacity(N_CORES);
        let mut fp_reqs: Vec<usize> = Vec::with_capacity(N_CORES);
        let mut ds_reqs: Vec<usize> = Vec::with_capacity(N_CORES);
        let mut tcdm_banked: Vec<(usize, usize)> = Vec::with_capacity(N_CORES);

        loop {
            // One poll pass replaces the halted/running/waiting scans.
            let mut n_halted = 0usize;
            let mut parked = 0usize;
            let mut min_busy = u64::MAX;
            let mut n_issuable = 0usize;
            let mut issuable = 0usize;
            for (i, c) in self.cores[..n_active].iter().enumerate() {
                match c.state {
                    CoreState::Halted => n_halted += 1,
                    CoreState::AtBarrier => parked += 1,
                    CoreState::Ready => {
                        let b = c.busy_cycles();
                        if b == 0 {
                            n_issuable += 1;
                            issuable = i;
                        } else if b < min_busy {
                            min_busy = b;
                        }
                    }
                }
            }
            let can_issue = n_issuable > 0;
            if n_halted == n_active {
                break;
            }
            assert!(
                self.cycle < max_cycles,
                "cluster run of {} exceeded {max_cycles} cycles",
                prog.name
            );

            // Superblock replay (hot-path layer 3): when exactly one core
            // can issue and every other active core is halted or parked
            // at a barrier that cannot release (the sole runner keeps it
            // from releasing), that core faces no arbitration — a
            // predecoded straight-line loop body can be replayed as one
            // batched effect. `try_replay` re-checks the dynamic entry
            // conditions and returns the cycles the window consumed;
            // parked cores and the event unit then age exactly as the
            // skip path below ages them. Bit-identity with the
            // interpreter is asserted in tests/scheduler_equivalence.rs.
            if self.superblocks && n_issuable == 1 && min_busy == u64::MAX {
                if let Some(w) = crate::iss::superblock::try_replay(
                    &pre,
                    &mut self.cores[issuable],
                    &mut self.tcdm,
                    l2,
                    &mut self.fpus,
                    self.cycle,
                    max_cycles,
                ) {
                    for (i, c) in self.cores[..n_active].iter_mut().enumerate() {
                        if i != issuable && c.state != CoreState::Halted {
                            c.skip_stall_cycles(w);
                        }
                    }
                    self.event_unit.skip(parked, w);
                    self.cycle += w;
                    continue;
                }
            }

            if !can_issue && parked < n_active - n_halted {
                // Nothing can happen until the shortest busy counter
                // drains (if no Ready core were busy, every running core
                // would be parked and the barrier would release instead).
                debug_assert!(min_busy != u64::MAX);
                let delta = min_busy.min(max_cycles - self.cycle);
                for c in &mut self.cores[..n_active] {
                    if c.state != CoreState::Halted {
                        c.skip_stall_cycles(delta);
                    }
                }
                self.event_unit.skip(parked, delta);
                self.cycle += delta;
                continue;
            }

            mem_reqs.clear();
            fp_reqs.clear();
            ds_reqs.clear();
            let mut running = 0usize;
            let mut waiting = 0usize;
            for i in 0..n_active {
                match self.cores[i].begin_cycle(prog, &pre, &mut self.warm) {
                    Intent::Mem(r) => {
                        running += 1;
                        mem_reqs.push((i, r));
                    }
                    Intent::Fp { divsqrt: false } => {
                        running += 1;
                        fp_reqs.push(i);
                    }
                    Intent::Fp { divsqrt: true } => {
                        running += 1;
                        ds_reqs.push(i);
                    }
                    Intent::Barrier => {
                        running += 1;
                        waiting += 1;
                    }
                    Intent::Retired | Intent::Stalled => running += 1,
                    Intent::Halted => {}
                }
            }

            // Event unit: release the barrier when every running core waits.
            if self.event_unit.tick(waiting, running) {
                for c in &mut self.cores[..n_active] {
                    if c.state == CoreState::AtBarrier {
                        c.release_barrier();
                    }
                }
            }

            // TCDM bank arbitration (word-interleaved; one grant per bank).
            tcdm_banked.clear();
            tcdm_banked.extend(
                mem_reqs
                    .iter()
                    .filter(|(_, r)| Tcdm::contains(r.addr))
                    .map(|&(i, r)| (i, Tcdm::bank_of(r.addr))),
            );
            let grants = self.tcdm.arbitrate_mask(&tcdm_banked);
            for &(i, req) in &mem_reqs {
                let mut view = ClusterMemView { tcdm: &mut self.tcdm.mem, l2: &mut *l2 };
                if Tcdm::contains(req.addr) {
                    if grants & (1u16 << i) != 0 {
                        self.cores[i].retire_mem(&pre, &mut view);
                    } else {
                        self.cores[i].deny_mem();
                    }
                } else {
                    // L2 access across the AXI bridge: always granted but
                    // multi-cycle.
                    self.cores[i].retire_mem(&pre, &mut view);
                    self.cores[i].add_busy(CLUSTER_TO_L2_LATENCY);
                }
            }

            // FPU issue arbitration (static mapping; 1 issue/FPU/cycle).
            let fp_grants = self.fpus.arbitrate_mask(&fp_reqs);
            for &i in &fp_reqs {
                if fp_grants & (1u16 << i) != 0 {
                    self.cores[i].retire_fp(&pre);
                } else {
                    self.cores[i].deny_fpu(false);
                }
            }
            // Shared DIV-SQRT unit: one op in flight cluster-wide.
            for &i in &ds_reqs {
                let lat = match pre.recs[self.cores[i].pc].kind {
                    DecodedKind::Fp { latency, .. } => latency,
                    _ => 1,
                };
                if self.fpus.try_divsqrt(self.cycle, lat) {
                    self.cores[i].retire_fp(&pre);
                } else {
                    self.cores[i].deny_fpu(true);
                }
            }

            self.cycle += 1;
        }

        self.collect_stats(n_active)
    }

    /// The retained 1-cycle-per-iteration reference driver (the seed
    /// implementation, modulo the shared predecode table): the oracle the
    /// equivalence suite holds [`Cluster::run_fast`] against.
    fn run_reference(
        &mut self,
        prog: &Program,
        n_active: usize,
        l2: &mut FlatMem,
        init: &impl Fn(usize) -> Vec<(Reg, u32)>,
        max_cycles: u64,
    ) -> ClusterStats {
        let pre = prog.predecode();
        self.reset_for_run(prog, n_active, init);

        let mut mem_reqs: Vec<(usize, crate::iss::MemReq)> = Vec::with_capacity(N_CORES);
        let mut fp_reqs: Vec<usize> = Vec::with_capacity(N_CORES);
        let mut ds_reqs: Vec<usize> = Vec::with_capacity(N_CORES);
        let mut tcdm_banked: Vec<(usize, usize)> = Vec::with_capacity(N_CORES);
        let mut granted: Vec<usize> = Vec::with_capacity(N_CORES);
        let mut fp_granted: Vec<usize> = Vec::with_capacity(N_CORES);

        loop {
            if self.cores[..n_active].iter().all(|c| c.halted()) {
                break;
            }
            assert!(
                self.cycle < max_cycles,
                "cluster run of {} exceeded {max_cycles} cycles",
                prog.name
            );
            mem_reqs.clear();
            fp_reqs.clear();
            ds_reqs.clear();

            for i in 0..n_active {
                match self.cores[i].begin_cycle(prog, &pre, &mut self.warm) {
                    Intent::Mem(r) => mem_reqs.push((i, r)),
                    Intent::Fp { divsqrt: false } => fp_reqs.push(i),
                    Intent::Fp { divsqrt: true } => ds_reqs.push(i),
                    _ => {}
                }
            }

            // Event unit: release the barrier when every running core waits.
            let running = self.cores[..n_active].iter().filter(|c| !c.halted()).count();
            let waiting = self.cores[..n_active]
                .iter()
                .filter(|c| c.state == CoreState::AtBarrier)
                .count();
            if self.event_unit.tick(waiting, running) {
                for c in &mut self.cores[..n_active] {
                    if c.state == CoreState::AtBarrier {
                        c.release_barrier();
                    }
                }
            }

            // TCDM bank arbitration (word-interleaved; one grant per bank).
            tcdm_banked.clear();
            tcdm_banked.extend(
                mem_reqs
                    .iter()
                    .filter(|(_, r)| Tcdm::contains(r.addr))
                    .map(|&(i, r)| (i, Tcdm::bank_of(r.addr))),
            );
            self.tcdm.arbitrate_into(&tcdm_banked, &mut granted);
            for &(i, req) in &mem_reqs {
                let mut view = ClusterMemView { tcdm: &mut self.tcdm.mem, l2: &mut *l2 };
                if Tcdm::contains(req.addr) {
                    if granted.contains(&i) {
                        self.cores[i].retire_mem(&pre, &mut view);
                    } else {
                        self.cores[i].deny_mem();
                    }
                } else {
                    // L2 access across the AXI bridge: always granted but
                    // multi-cycle.
                    self.cores[i].retire_mem(&pre, &mut view);
                    self.cores[i].add_busy(CLUSTER_TO_L2_LATENCY);
                }
            }

            // FPU issue arbitration (static mapping; 1 issue/FPU/cycle).
            self.fpus.arbitrate_into(&fp_reqs, &mut fp_granted);
            for &i in &fp_reqs {
                if fp_granted.contains(&i) {
                    self.cores[i].retire_fp(&pre);
                } else {
                    self.cores[i].deny_fpu(false);
                }
            }
            // Shared DIV-SQRT unit: one op in flight cluster-wide.
            for &i in &ds_reqs {
                let lat = match pre.recs[self.cores[i].pc].kind {
                    DecodedKind::Fp { latency, .. } => latency,
                    _ => 1,
                };
                if self.fpus.try_divsqrt(self.cycle, lat) {
                    self.cores[i].retire_fp(&pre);
                } else {
                    self.cores[i].deny_fpu(true);
                }
            }

            self.cycle += 1;
        }

        self.collect_stats(n_active)
    }
}

impl Default for Cluster {
    fn default() -> Self {
        Self::new()
    }
}

// The sweep engine moves one owned `Cluster`/`FlatMem` arena into each of
// its scoped worker threads; keep the fabric free of non-`Send` state.
const fn _assert_send<T: Send>() {}
const _: () = {
    _assert_send::<Cluster>();
    _assert_send::<FlatMem>();
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{Asm, A0, A1, A2, T0};

    fn l2() -> FlatMem {
        FlatMem::new(L2_BASE, L2_SIZE)
    }

    /// Each core increments its own TCDM word 100 times.
    #[test]
    fn spmd_private_counters() {
        let mut a = Asm::new("counters");
        let end = a.label();
        a.lp_setup_imm(0, 100, end);
        a.lw(T0, A0, 0);
        a.addi(T0, T0, 1);
        a.sw(T0, A0, 0);
        a.bind(end);
        a.halt();
        let prog = a.finish().unwrap();

        let mut cl = Cluster::new();
        let mut l2 = l2();
        // Word stride: core i owns word i -> 8 distinct banks.
        let stats = cl.run_program(
            &prog,
            8,
            &mut l2,
            |i| vec![(A0, TCDM_BASE + (i * 4) as u32)],
            1_000_000,
        );
        for i in 0..8 {
            assert_eq!(cl.tcdm.mem.read_i32s(TCDM_BASE + (i * 4) as u32, 1)[0], 100);
        }
        // Distinct banks: zero conflicts.
        assert_eq!(stats.tcdm_conflict_rate, 0.0);
    }

    /// All cores hammer the same bank: heavy contention, correctness kept.
    #[test]
    fn same_bank_contention_serialises() {
        let mut a = Asm::new("hot-bank");
        let end = a.label();
        a.lp_setup_imm(0, 50, end);
        a.lw(T0, A0, 0); // all cores read the same word
        a.bind(end);
        a.halt();
        let prog = a.finish().unwrap();

        let mut cl = Cluster::new();
        let mut l2 = l2();
        let stats = cl.run_program(&prog, 8, &mut l2, |_| vec![(A0, TCDM_BASE)], 1_000_000);
        assert!(
            stats.tcdm_conflict_rate > 0.5,
            "rate = {}",
            stats.tcdm_conflict_rate
        );
        // Every core still retired all its loads.
        for s in &stats.per_core {
            assert_eq!(s.by_class.load, 50);
        }
    }

    /// Barrier synchronises: core 0 writes, everyone reads after barrier.
    #[test]
    fn barrier_orders_producer_consumer() {
        let mut a = Asm::new("barrier");
        let skip = a.label();
        a.li(T0, 0xAB);
        a.bne(A1, 0, skip); // only core 0 stores
        a.sw(T0, A0, 0);
        a.bind(skip);
        a.barrier();
        a.lw(A2, A0, 0);
        a.halt();
        let prog = a.finish().unwrap();

        let mut cl = Cluster::new();
        let mut l2 = l2();
        let _ = cl.run_program(
            &prog,
            8,
            &mut l2,
            |i| vec![(A0, TCDM_BASE + 0x100), (A1, i as u32)],
            1_000_000,
        );
        for c in &cl.cores[..8] {
            assert_eq!(c.reg(A2), 0xAB, "core {} read after barrier", c.id);
        }
    }

    /// Unit-stride SPMD streaming: contention must be well under 10%
    /// (the paper's claim for data-intensive kernels).
    #[test]
    fn unit_stride_contention_below_10pct() {
        let mut a = Asm::new("stream");
        let end = a.label();
        a.lp_setup_imm(0, 256, end);
        a.lw_pi(T0, A0, 4);
        a.add(A2, A2, T0);
        a.bind(end);
        a.halt();
        let prog = a.finish().unwrap();

        let mut cl = Cluster::new();
        let mut l2 = l2();
        // Cores start 1 word apart: worst-ish case alignment.
        let stats = cl.run_program(
            &prog,
            8,
            &mut l2,
            |i| vec![(A0, TCDM_BASE + (4 * i) as u32)],
            1_000_000,
        );
        assert!(
            stats.tcdm_conflict_rate < 0.10,
            "conflict rate = {}",
            stats.tcdm_conflict_rate
        );
    }

    /// FPU sharing: cores 0 and 4 contend for FPU0; cores 0..4 don't.
    #[test]
    fn fpu_static_mapping_contention() {
        let mut a = Asm::new("fp");
        let end = a.label();
        a.li(A0, 1.0f32.to_bits() as i32);
        a.li(A1, 1.5f32.to_bits() as i32);
        a.lp_setup_imm(0, 200, end);
        a.fmac_s(A2, A0, A1);
        a.bind(end);
        a.halt();
        let prog = a.finish().unwrap();

        // 4 cores on 4 distinct FPUs: no contention.
        let mut cl = Cluster::new();
        let mut l2m = l2();
        let s4 = cl.run_program(&prog, 4, &mut l2m, |_| vec![], 1_000_000);
        assert_eq!(s4.fpu_contention_rate, 0.0);

        // 8 cores on 4 FPUs, back-to-back FP: ~50% issue conflicts.
        let mut cl = Cluster::new();
        let s8 = cl.run_program(&prog, 8, &mut l2m, |_| vec![], 1_000_000);
        assert!(s8.fpu_contention_rate > 0.3, "rate = {}", s8.fpu_contention_rate);
        // But everyone still finishes with the right value.
        let acc = f32::from_bits(cl.cores[0].reg(A2));
        assert!((acc - 300.0).abs() < 1e-3);
    }

    /// Cluster-side L2 access works and costs extra latency.
    #[test]
    fn l2_access_from_cluster() {
        let mut a = Asm::new("l2");
        a.lw(T0, A0, 0);
        a.addi(T0, T0, 1);
        a.sw(T0, A0, 0);
        a.halt();
        let prog = a.finish().unwrap();
        let mut cl = Cluster::new();
        let mut l2m = l2();
        l2m.write_i32s(L2_BASE + 0x40, &[41]);
        let stats = cl.run_program(&prog, 1, &mut l2m, |_| vec![(A0, L2_BASE + 0x40)], 10_000);
        assert_eq!(l2m.read_i32s(L2_BASE + 0x40, 1)[0], 42);
        assert!(stats.total.multicycle_busy >= 2 * CLUSTER_TO_L2_LATENCY);
    }

    /// 8-way near-linear speedup on an embarrassingly parallel loop.
    #[test]
    fn parallel_speedup_scales() {
        let mut a = Asm::new("scale");
        let end = a.label();
        a.lp_setup(0, A1, end);
        a.mac(A2, A0, A0);
        a.bind(end);
        a.halt();
        let prog = a.finish().unwrap();
        let mut l2m = l2();

        let mut cl = Cluster::new();
        let s1 = cl.run_program(&prog, 1, &mut l2m, |_| vec![(A1, 8000)], 1_000_000);
        let mut cl = Cluster::new();
        let s8 = cl.run_program(&prog, 8, &mut l2m, |_| vec![(A1, 1000)], 1_000_000);
        let speedup = s1.cycles as f64 / s8.cycles as f64;
        assert!(speedup > 7.0, "speedup = {speedup}");
    }
}
