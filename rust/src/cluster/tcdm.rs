//! L1 tightly-coupled data memory: 16 × 8 kB word-interleaved SRAM banks
//! behind the single-cycle logarithmic interconnect (§II-C, [27]).
//!
//! Word-level interleaving spreads consecutive words across banks so that
//! unit-stride parallel access patterns hit distinct banks; the
//! interconnect resolves residual conflicts by stalling all but one
//! requester per bank per cycle (round-robin). The paper measures < 10%
//! contention with 16 requesters on data-intensive kernels — an emergent
//! property checked by `cluster_integration` tests.

use crate::iss::FlatMem;

/// Base address of the cluster L1 TCDM in the Vega memory map.
pub const TCDM_BASE: u32 = 0x1000_0000;

/// Total TCDM capacity: 128 kB in 16 banks of 8 kB (16 × 8 kB SRAM cuts).
pub const TCDM_SIZE: usize = 128 * 1024;
pub const TCDM_BANKS: usize = 16;

/// The banked L1 with per-cycle arbitration state.
pub struct Tcdm {
    pub mem: FlatMem,
    /// Round-robin pointer per bank (fair arbitration).
    rr: [usize; TCDM_BANKS],
    /// Statistics.
    pub grants: u64,
    pub conflicts: u64,
}

impl Tcdm {
    pub fn new() -> Self {
        Self {
            mem: FlatMem::new(TCDM_BASE, TCDM_SIZE),
            rr: [0; TCDM_BANKS],
            grants: 0,
            conflicts: 0,
        }
    }

    /// Word-level interleave: bank = word-address mod #banks.
    pub fn bank_of(addr: u32) -> usize {
        ((addr >> 2) as usize) % TCDM_BANKS
    }

    pub fn contains(addr: u32) -> bool {
        (TCDM_BASE..TCDM_BASE + TCDM_SIZE as u32).contains(&addr)
    }

    /// Zero contents and arbitration state in place, keeping the backing
    /// allocation (between-runs reuse, §Perf).
    pub fn reset(&mut self) {
        self.mem.reset();
        self.rr = [0; TCDM_BANKS];
        self.grants = 0;
        self.conflicts = 0;
    }

    /// Arbitrate one cycle of requests: `reqs` maps requester-id → bank.
    /// Returns the granted requester per bank; losers are conflicts.
    ///
    /// Round-robin: the pointer advances past the granted requester so a
    /// hot bank is shared fairly.
    pub fn arbitrate(&mut self, reqs: &[(usize, usize)]) -> Vec<usize> {
        let mut granted = Vec::with_capacity(reqs.len().min(TCDM_BANKS));
        self.arbitrate_into(reqs, &mut granted);
        granted
    }

    /// As [`Tcdm::arbitrate`], writing grants into a caller-owned buffer.
    pub fn arbitrate_into(&mut self, reqs: &[(usize, usize)], granted: &mut Vec<usize>) {
        granted.clear();
        let mut m = self.arbitrate_mask(reqs);
        while m != 0 {
            granted.push(m.trailing_zeros() as usize);
            m &= m - 1;
        }
    }

    /// As [`Tcdm::arbitrate`], returning the grants as a requester-id
    /// bitmask — fully allocation-free, one bit test per requester on the
    /// consumer side instead of a linear `contains` scan (§Perf: this
    /// runs every simulated cycle).
    pub fn arbitrate_mask(&mut self, reqs: &[(usize, usize)]) -> u16 {
        // Per-bank aggregation in one pass: count, lowest id, lowest id
        // at/after the RR pointer. u8 is enough for <=16 requesters.
        let mut count = [0u8; TCDM_BANKS];
        let mut first = [u8::MAX; TCDM_BANKS];
        let mut at_or_after = [u8::MAX; TCDM_BANKS];
        for &(id, b) in reqs {
            debug_assert!(id < 16, "requester id exceeds grant mask");
            let id8 = id as u8;
            count[b] += 1;
            if id8 < first[b] {
                first[b] = id8;
            }
            if id >= self.rr[b] && id8 < at_or_after[b] {
                at_or_after[b] = id8;
            }
        }
        let mut mask = 0u16;
        for bank in 0..TCDM_BANKS {
            if count[bank] == 0 {
                continue;
            }
            let winner =
                if at_or_after[bank] != u8::MAX { at_or_after[bank] } else { first[bank] }
                    as usize;
            self.rr[bank] = winner + 1;
            self.grants += 1;
            self.conflicts += (count[bank] - 1) as u64;
            mask |= 1u16 << winner;
        }
        mask
    }

    /// Commit the arbitration bookkeeping of a superblock replay window:
    /// `grants` uncontended accesses by `winner` touching the banks in
    /// `banks` (a bank bitmask). With a single requester every access is
    /// granted and each grant leaves `rr[bank] = winner + 1` — the same
    /// value no matter how many times or in what order, so one batched
    /// update is bit-identical to the per-cycle path.
    pub(crate) fn replay_commit(&mut self, grants: u64, banks: u16, winner: usize) {
        self.grants += grants;
        let mut m = banks;
        while m != 0 {
            let b = m.trailing_zeros() as usize;
            m &= m - 1;
            self.rr[b] = winner + 1;
        }
    }

    /// Flip one bit of the byte at `addr` (absolute, TCDM-mapped): the
    /// L1 soft-error injection hook (ISSUE 6). TCDM banks carry no ECC,
    /// so an upset lands directly in the data the cores consume.
    pub fn flip_bit(&mut self, addr: u32, bit: u8) {
        self.mem.flip_bit(addr, bit);
    }

    /// Fraction of requests that lost arbitration.
    pub fn conflict_rate(&self) -> f64 {
        let total = self.grants + self.conflicts;
        if total == 0 {
            0.0
        } else {
            self.conflicts as f64 / total as f64
        }
    }
}

impl Default for Tcdm {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interleaving_spreads_unit_stride() {
        // 16 consecutive words -> 16 distinct banks
        let banks: Vec<usize> = (0..16).map(|i| Tcdm::bank_of(TCDM_BASE + 4 * i)).collect();
        let mut sorted = banks.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 16);
    }

    #[test]
    fn same_word_different_bytes_same_bank() {
        assert_eq!(Tcdm::bank_of(0x1000_0000), Tcdm::bank_of(0x1000_0003));
        assert_ne!(Tcdm::bank_of(0x1000_0000), Tcdm::bank_of(0x1000_0004));
    }

    #[test]
    fn arbitration_grants_one_per_bank() {
        let mut t = Tcdm::new();
        // 3 requesters on bank 0, 1 on bank 1
        let grants = t.arbitrate(&[(0, 0), (1, 0), (2, 0), (3, 1)]);
        assert_eq!(grants.len(), 2);
        assert_eq!(t.conflicts, 2);
        assert_eq!(t.grants, 2);
    }

    #[test]
    fn round_robin_is_fair() {
        let mut t = Tcdm::new();
        let mut wins = [0u32; 2];
        for _ in 0..10 {
            let g = t.arbitrate(&[(0, 0), (1, 0)]);
            wins[g[0]] += 1;
        }
        assert_eq!(wins[0], 5);
        assert_eq!(wins[1], 5);
    }

    #[test]
    fn flip_bit_is_a_self_inverse_xor() {
        let mut t = Tcdm::new();
        t.mem.write_bytes(TCDM_BASE + 100, &[0x0F]);
        t.flip_bit(TCDM_BASE + 100, 2);
        assert_eq!(t.mem.read_bytes(TCDM_BASE + 100, 1), &[0x0B]);
        t.flip_bit(TCDM_BASE + 100, 2);
        assert_eq!(t.mem.read_bytes(TCDM_BASE + 100, 1), &[0x0F]);
    }

    #[test]
    fn conflict_free_when_distinct_banks() {
        let mut t = Tcdm::new();
        let reqs: Vec<(usize, usize)> = (0..16).map(|i| (i, i)).collect();
        let g = t.arbitrate(&reqs);
        assert_eq!(g.len(), 16);
        assert_eq!(t.conflict_rate(), 0.0);
    }
}
