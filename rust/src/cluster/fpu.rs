//! The shared multi-precision FPU fabric (Fig. 3).
//!
//! Four FPnew-style FPUs are shared among the nine cores through a
//! *partial interconnect with static mapping*: units 0–3 serve cores
//! {0,4}, {1,5}, {2,6} and {3,7,8} respectively, so a core always reaches
//! the same physical FPU. This trades sharing flexibility for a shorter
//! critical path, keeping FP instructions single-cycle (§II-C). A
//! stand-alone iterative DIV-SQRT unit is shared cluster-wide.

/// Number of FPU slices in the cluster.
pub const N_FPUS: usize = 4;

/// The paper's static core→FPU mapping: 0&4→0, 1&5→1, 2&6→2, 3&7&8→3.
pub fn fpu_of_core(core: usize) -> usize {
    match core {
        0 | 4 => 0,
        1 | 5 => 1,
        2 | 6 => 2,
        3 | 7 | 8 => 3,
        _ => core % N_FPUS,
    }
}

/// Per-cycle FPU issue arbitration + the shared DIV-SQRT unit.
pub struct FpuFabric {
    /// Round-robin pointer per FPU.
    rr: [usize; N_FPUS],
    /// Cycle at which the DIV-SQRT unit becomes free.
    divsqrt_free_at: u64,
    /// Ablation switch: one private FPU per core (the design the paper
    /// rejected for area; used by `vega repro ablations`).
    pub private_per_core: bool,
    pub issues: u64,
    pub conflicts: u64,
    pub divsqrt_conflicts: u64,
}

impl FpuFabric {
    pub fn new() -> Self {
        Self {
            rr: [0; N_FPUS],
            divsqrt_free_at: 0,
            private_per_core: false,
            issues: 0,
            conflicts: 0,
            divsqrt_conflicts: 0,
        }
    }

    /// Clear per-run arbitration state and counters, keeping the ablation
    /// configuration (`private_per_core`).
    pub fn reset(&mut self) {
        self.rr = [0; N_FPUS];
        self.divsqrt_free_at = 0;
        self.issues = 0;
        self.conflicts = 0;
        self.divsqrt_conflicts = 0;
    }

    /// Arbitrate pipelined (single-cycle) FP issues: `reqs` is a list of
    /// core ids wanting to issue this cycle. Returns granted core ids
    /// (one per FPU).
    pub fn arbitrate(&mut self, reqs: &[usize]) -> Vec<usize> {
        let mut granted = Vec::with_capacity(N_FPUS);
        self.arbitrate_into(reqs, &mut granted);
        granted
    }

    /// As [`FpuFabric::arbitrate`] into a caller-owned buffer.
    pub fn arbitrate_into(&mut self, reqs: &[usize], granted: &mut Vec<usize>) {
        granted.clear();
        let mut m = self.arbitrate_mask(reqs);
        while m != 0 {
            granted.push(m.trailing_zeros() as usize);
            m &= m - 1;
        }
    }

    /// As [`FpuFabric::arbitrate`], returning grants as a core-id bitmask
    /// (§Perf: one bit test per requester in the cluster cycle loop).
    pub fn arbitrate_mask(&mut self, reqs: &[usize]) -> u16 {
        if self.private_per_core {
            self.issues += reqs.len() as u64;
            let mut mask = 0u16;
            for &c in reqs {
                debug_assert!(c < 16, "core id exceeds grant mask");
                mask |= 1u16 << c;
            }
            return mask;
        }
        let mut mask = 0u16;
        for unit in 0..N_FPUS {
            let start = self.rr[unit];
            let mut count = 0usize;
            let mut first: Option<usize> = None;
            let mut at_or_after: Option<usize> = None;
            for &c in reqs {
                if fpu_of_core(c) != unit {
                    continue;
                }
                count += 1;
                if first.map_or(true, |f| c < f) {
                    first = Some(c);
                }
                if c >= start && at_or_after.map_or(true, |f| c < f) {
                    at_or_after = Some(c);
                }
            }
            let Some(first) = first else { continue };
            let winner = at_or_after.unwrap_or(first);
            self.rr[unit] = winner + 1;
            self.issues += 1;
            self.conflicts += (count - 1) as u64;
            debug_assert!(winner < 16, "core id exceeds grant mask");
            mask |= 1u16 << winner;
        }
        mask
    }

    /// Try to claim the shared DIV-SQRT unit at cycle `now` for `latency`
    /// cycles. Returns false (caller stalls) while the unit is busy.
    pub fn try_divsqrt(&mut self, now: u64, latency: u64) -> bool {
        if now < self.divsqrt_free_at {
            self.divsqrt_conflicts += 1;
            return false;
        }
        self.divsqrt_free_at = now + latency;
        self.issues += 1;
        true
    }

    /// Cycle at which the shared DIV-SQRT unit becomes free (read by the
    /// superblock replay entry check).
    pub(crate) fn divsqrt_free_at(&self) -> u64 {
        self.divsqrt_free_at
    }

    /// Commit the issue bookkeeping of a superblock replay window for a
    /// single uncontended core: `issues` granted FP issues by `core`,
    /// `pipelined` true when any of them went through the per-FPU
    /// round-robin (which then ends at `core + 1` — the same value after
    /// every grant, so one batched update matches the per-cycle path),
    /// and `divsqrt_free_at` the unit's busy horizon after the window's
    /// last DIV-SQRT issue (`None` when the window issued none).
    pub(crate) fn replay_commit(
        &mut self,
        issues: u64,
        pipelined: bool,
        core: usize,
        divsqrt_free_at: Option<u64>,
    ) {
        self.issues += issues;
        if pipelined && !self.private_per_core {
            self.rr[fpu_of_core(core)] = core + 1;
        }
        if let Some(t) = divsqrt_free_at {
            self.divsqrt_free_at = t;
        }
    }

    /// Fraction of FP issues that were delayed by sharing.
    pub fn contention_rate(&self) -> f64 {
        let total = self.issues + self.conflicts;
        if total == 0 {
            0.0
        } else {
            self.conflicts as f64 / total as f64
        }
    }
}

impl Default for FpuFabric {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_mapping_matches_fig3() {
        assert_eq!(fpu_of_core(0), 0);
        assert_eq!(fpu_of_core(4), 0);
        assert_eq!(fpu_of_core(1), 1);
        assert_eq!(fpu_of_core(5), 1);
        assert_eq!(fpu_of_core(2), 2);
        assert_eq!(fpu_of_core(6), 2);
        assert_eq!(fpu_of_core(3), 3);
        assert_eq!(fpu_of_core(7), 3);
        assert_eq!(fpu_of_core(8), 3);
    }

    #[test]
    fn paired_cores_conflict() {
        let mut f = FpuFabric::new();
        let g = f.arbitrate(&[0, 4]); // same FPU
        assert_eq!(g.len(), 1);
        assert_eq!(f.conflicts, 1);
        // different FPUs: both granted
        let g = f.arbitrate(&[0, 1]);
        assert_eq!(g.len(), 2);
    }

    #[test]
    fn eight_cores_four_grants() {
        let mut f = FpuFabric::new();
        let g = f.arbitrate(&[0, 1, 2, 3, 4, 5, 6, 7]);
        assert_eq!(g.len(), 4);
        assert_eq!(f.conflicts, 4);
    }

    #[test]
    fn divsqrt_blocks_while_busy() {
        let mut f = FpuFabric::new();
        assert!(f.try_divsqrt(0, 11));
        assert!(!f.try_divsqrt(5, 11));
        assert!(f.try_divsqrt(11, 15));
        assert_eq!(f.divsqrt_conflicts, 1);
    }
}
