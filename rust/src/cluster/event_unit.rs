//! Hardware event unit: fine-grain parallel-thread dispatch + barriers.
//!
//! The event unit clock-gates idle cores waiting on synchronisation and
//! resumes them in 2 cycles (§II-C). Cores enter the barrier through the
//! `Barrier` instruction; the unit releases the team when the last member
//! arrives. Gated cycles are tracked so the power model can discount
//! clock-gated cores (they burn leakage + clock-tree power only).

/// Barrier bookkeeping for one team of cores.
#[derive(Debug, Clone)]
pub struct EventUnit {
    team: usize,
    /// Total core-cycles spent clock-gated at barriers.
    pub gated_cycles: u64,
    /// Number of barrier episodes completed.
    pub barriers: u64,
}

impl EventUnit {
    pub fn new(team: usize) -> Self {
        Self { team, gated_cycles: 0, barriers: 0 }
    }

    pub fn team(&self) -> usize {
        self.team
    }

    /// Called once per cycle with the number of cores currently waiting
    /// and the number still running (not halted). Returns true when the
    /// barrier releases this cycle.
    pub fn tick(&mut self, waiting: usize, running: usize) -> bool {
        self.gated_cycles += waiting as u64;
        if waiting > 0 && waiting == running {
            self.barriers += 1;
            true
        } else {
            false
        }
    }

    /// Account `delta` cycles with a constant `waiting` count and no
    /// release — what `delta` calls to [`EventUnit::tick`] do while the
    /// barrier cannot open (the cluster's cycle-skip fast path).
    pub fn skip(&mut self, waiting: usize, delta: u64) {
        self.gated_cycles += waiting as u64 * delta;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn releases_only_when_all_arrive() {
        let mut eu = EventUnit::new(4);
        assert!(!eu.tick(2, 4));
        assert!(!eu.tick(3, 4));
        assert!(eu.tick(4, 4));
        assert_eq!(eu.barriers, 1);
        assert_eq!(eu.gated_cycles, 2 + 3 + 4);
    }

    #[test]
    fn halted_cores_shrink_the_team() {
        let mut eu = EventUnit::new(4);
        // one core halted: release when the 3 remaining arrive
        assert!(eu.tick(3, 3));
    }

    #[test]
    fn no_release_when_nobody_waits() {
        let mut eu = EventUnit::new(4);
        assert!(!eu.tick(0, 4));
        assert_eq!(eu.gated_cycles, 0);
    }
}
