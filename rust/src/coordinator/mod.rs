//! Experiment coordinator: the shared drivers behind every table/figure
//! reproduction (invoked by `vega repro <id>`, the cargo benches, and the
//! integration tests).

pub mod report;

use crate::cwu::{ChannelConfig, Cwu};
use crate::hdc::{self, datasets, EncoderConfig};
use crate::kernels::fp_matmul::FpWidth;
use crate::kernels::int_matmul::IntWidth;
use crate::kernels::KernelRun;
use crate::power::tables::OperatingPoint;
use crate::sweep::{Scenario, SweepEngine};

pub use report::Table;

/// Run the int matmul benchmark at a width on `cores` cores (Fig. 6).
///
/// Per-id entry point, routed through the process-wide
/// [`SweepEngine::global`] engine: repeated calls (tests, examples,
/// `vega sim`) reuse cached cycle results instead of rebuilding
/// Cluster/L2 state per call, and warm-start from the on-disk store
/// across processes.
pub fn bench_int_matmul(w: IntWidth, cores: usize) -> KernelRun {
    SweepEngine::global().kernel_run(Scenario::IntMatmul { w, cores })
}

/// Run the FP matmul benchmark (Fig. 6 / Fig. 8).
pub fn bench_fp_matmul(w: FpWidth, cores: usize) -> KernelRun {
    SweepEngine::global().kernel_run(Scenario::FpMatmul { w, cores })
}

/// One Fig. 8 / Table V kernel run on 8 cores.
pub fn bench_nsaa_kernel(name: &str, w: FpWidth) -> KernelRun {
    let name = NSAA_KERNELS
        .iter()
        .copied()
        .find(|&k| k == name)
        .unwrap_or_else(|| panic!("unknown NSAA kernel {name}"));
    SweepEngine::global().kernel_run(Scenario::Nsaa { name, w })
}

/// The Table V / Fig. 8 kernel list.
pub const NSAA_KERNELS: [&str; 8] =
    ["MATMUL", "CONV", "DWT", "FFT", "FIR", "IIR", "KMEANS", "SVM"];

/// Result of the CWU reference workload (Table I's measurement setup:
/// 3×16-bit SPI channels, real-time HDC classification).
pub struct CwuRun {
    pub cwu: Cwu,
    pub accuracy: f64,
    pub frames: u64,
    pub duty_at_150sps: f64,
}

/// Train the EMG HDC model, program Hypnos, and stream test windows
/// through the full CWU pipeline (the Table I / Table II workload).
pub fn cwu_reference_run(f_clk: f64) -> CwuRun {
    let cfg = EncoderConfig {
        dim: 2048,
        input_width: 16,
        cim_max: 4095,
        channels: 3,
        window: 16,
        ngram: 1,
        discrete: false,
    };
    let mut gen = datasets::EmgGenerator::new(0xE39);
    let train_data = gen.dataset(5, cfg.window);
    let model = hdc::train(cfg, &train_data);

    // Watch for gesture class 1 ("fist") with a modest threshold.
    let hypnos = model.program_hypnos(1, (cfg.dim / 4) as u16);
    let mut cwu = Cwu::with_config(
        None,
        &[ChannelConfig { in_width: 16, ..Default::default() }; 3],
        hypnos,
        f_clk,
    );

    // Stream labelled windows; accuracy = wake on class-1, silence else.
    let mut correct = 0;
    let mut total = 0;
    for class in 0..gen.n_classes() {
        for _ in 0..10 {
            let w = gen.window(class, cfg.window);
            let mut woke = false;
            for frame in &w {
                if cwu.step_with_raw(frame).is_some() {
                    woke = true;
                }
            }
            if woke == (class == 1) {
                correct += 1;
            }
            total += 1;
        }
    }
    let duty = cwu.datapath_duty(150.0);
    CwuRun {
        accuracy: correct as f64 / total as f64,
        frames: cwu.hypnos.stats.frames,
        duty_at_150sps: duty,
        cwu,
    }
}

/// The scalar outcome of [`cwu_reference_run`] that the table renderers
/// consume — `Copy`, so it can live in the sweep engine's memo (the full
/// [`CwuRun`] carries the whole simulated CWU and is not cloneable).
#[derive(Debug, Clone, Copy)]
pub struct CwuSummary {
    /// Wake-decision accuracy over the labelled test windows.
    pub accuracy: f64,
    /// Frames classified by Hypnos.
    pub frames: u64,
    /// Total Hypnos datapath cycles over those frames.
    pub datapath_cycles: u64,
    /// Datapath duty factor at the 150 SPS reference rate.
    pub duty_at_150sps: f64,
}

/// Run the CWU reference workload and keep only the table-facing scalars.
///
/// A pure function of `f_clk` (the dataset generator and training are
/// fixed-seed), which is what lets
/// [`crate::sweep::SweepEngine::cwu_summary`] memoize it: the HDC
/// training inside dominates Table I's render time.
pub fn cwu_summary(f_clk: f64) -> CwuSummary {
    let run = cwu_reference_run(f_clk);
    CwuSummary {
        accuracy: run.accuracy,
        frames: run.frames,
        datapath_cycles: run.cwu.hypnos.stats.datapath_cycles,
        duty_at_150sps: run.duty_at_150sps,
    }
}

/// GOPS and GOPS/W of a kernel run at an operating point, including the
/// SoC-domain share (the paper's efficiency figures are chip-level).
pub fn efficiency(kr: &KernelRun, op: OperatingPoint, hwce: f64) -> (f64, f64) {
    let gops = kr.gops_at(op.f_cl);
    let util = 1.0 - kr.stats.barrier_gated_cycles as f64
        / (kr.stats.cycles as f64 * kr.stats.per_core.len().max(1) as f64);
    let p = crate::power::cluster_power_w(op, util.clamp(0.0, 1.0), hwce)
        + crate::power::soc_power_w(op, 0.1);
    (gops, gops / p)
}

#[cfg(test)]
mod tests {
    use super::*;

    // These regression asserts use a local in-memory engine, not the
    // persistent-global bench_* wrappers: a stale on-disk entry (e.g. a
    // timing-model change missing its MODEL_EPOCH bump) must never be
    // able to satisfy them.

    #[test]
    fn nsaa_kernels_all_run_both_widths() {
        let eng = SweepEngine::serial();
        for name in NSAA_KERNELS {
            for w in [FpWidth::F32, FpWidth::F16x2] {
                let kr = eng.kernel_run(Scenario::Nsaa { name, w });
                assert!(kr.stats.cycles > 0, "{name} {w:?}");
                assert!(kr.ops > 0, "{name} {w:?}");
            }
        }
    }

    #[test]
    fn cwu_reference_accuracy() {
        let run = cwu_reference_run(32_000.0);
        assert!(run.accuracy > 0.85, "accuracy = {}", run.accuracy);
        assert!(run.duty_at_150sps > 0.0 && run.duty_at_150sps < 1.0);
    }

    #[test]
    fn efficiency_is_positive_and_sane() {
        let kr = SweepEngine::serial()
            .kernel_run(Scenario::IntMatmul { w: IntWidth::I8, cores: 8 });
        let (gops, eff) = efficiency(&kr, crate::power::LV, 0.0);
        assert!(gops > 3.0 && gops < 10.0, "gops = {gops}");
        assert!(eff > 300.0 && eff < 900.0, "eff = {eff}");
    }
}
