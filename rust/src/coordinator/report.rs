//! Minimal fixed-width table renderer for the repro reports (serde/tabled
//! are unavailable offline; see DESIGN.md §5).

/// A simple text table.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, header: &[&str]) -> Self {
        Self {
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn rows(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!(" {:<w$} ", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        let mut out = format!("== {} ==\n", self.title);
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

/// Format helpers used across the report generators.
pub fn f1(v: f64) -> String {
    format!("{v:.1}")
}

pub fn f2(v: f64) -> String {
    format!("{v:.2}")
}

pub fn f3(v: f64) -> String {
    format!("{v:.3}")
}

pub fn si_power(w: f64) -> String {
    if w < 1e-3 {
        format!("{:.2} uW", w * 1e6)
    } else {
        format!("{:.2} mW", w * 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["a", "bbbb"]);
        t.row(&["xxx".into(), "1".into()]);
        let r = t.render();
        assert!(r.contains("== T =="));
        assert!(r.contains("xxx"));
        assert_eq!(t.rows(), 1);
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("T", &["a"]);
        t.row(&["x".into(), "y".into()]);
    }

    #[test]
    fn power_format() {
        assert_eq!(si_power(2.97e-6), "2.97 uW");
        assert_eq!(si_power(49.4e-3), "49.40 mW");
    }
}
