//! Functional HWCE datapath: bit-exact multi-precision 3×3/5×5 convolution.
//!
//! Tensors are NHWC-flattened slices: input `(h+2, w+2, cin)` pre-padded
//! (DORY pads tiles in L2, §IV-B), weights `(3, 3, cin, cout)`, output
//! `(h, w, cout)` i32 accumulators (or requantised i8 via the
//! normalisation + right-shift output stage).

/// Operand precision (§II-C: "multi-precision (4b/8b/16b) 3×3 convolution").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    Int4,
    Int8,
    Int16,
}

impl Precision {
    /// Storage bytes per operand element in L1 streams.
    pub fn bytes(self) -> usize {
        match self {
            Precision::Int4 => 1, // packed pairs in hardware; byte-aligned here
            Precision::Int8 => 1,
            Precision::Int16 => 2,
        }
    }

    /// Value range check (operands are upscaled to 16-bit internally, so
    /// ranges are enforced at the input boundary).
    pub fn in_range(self, v: i32) -> bool {
        match self {
            Precision::Int4 => (-8..=7).contains(&v),
            Precision::Int8 => (-128..=127).contains(&v),
            Precision::Int16 => (i16::MIN as i32..=i16::MAX as i32).contains(&v),
        }
    }
}

/// 3×3 valid convolution, int32 accumulation (the CSA-tree result before
/// the output stage). Panics on shape mismatch or out-of-range operands.
pub fn conv3x3(
    x: &[i32],
    w: &[i32],
    h: usize,
    wd: usize,
    cin: usize,
    cout: usize,
    prec: Precision,
) -> Vec<i32> {
    let (hp, wp) = (h + 2, wd + 2);
    assert_eq!(x.len(), hp * wp * cin, "input shape");
    assert_eq!(w.len(), 9 * cin * cout, "weight shape");
    debug_assert!(x.iter().all(|&v| prec.in_range(v)), "input range");
    debug_assert!(w.iter().all(|&v| prec.in_range(v)), "weight range");

    let mut out = vec![0i32; h * wd * cout];
    // The engine iterates sliding-window positions; three filters (cout
    // lanes) share each window. The NHWC layout makes each window row a
    // contiguous `3*cin` run of the input, and the matching weight block a
    // contiguous `3*cin*cout` run — so per window position we stream both
    // unit-stride and accumulate straight into the `cout` output lane.
    // Wrapping i32 addition is associative, so this retires bit-identical
    // sums to the per-(co,dy,dx,ci) probe order it replaces (§Perf).
    let run = 3 * cin;
    for r in 0..h {
        for c in 0..wd {
            let o = &mut out[(r * wd + c) * cout..][..cout];
            for dy in 0..3 {
                let xrow = &x[((r + dy) * wp + c) * cin..][..run];
                let wrow = &w[dy * run * cout..][..run * cout];
                for (i, &xv) in xrow.iter().enumerate() {
                    if xv == 0 {
                        continue;
                    }
                    // Operands upscale to 16-bit; products fit i32.
                    let ws = &wrow[i * cout..][..cout];
                    for (acc, &wv) in o.iter_mut().zip(ws) {
                        *acc = acc.wrapping_add(xv * wv);
                    }
                }
            }
        }
    }
    out
}

/// The output stage: normalisation (arithmetic right shift) + saturation
/// to the stream precision ("possibly, after undergoing normalization and
/// right-shift", §II-C).
pub fn requant(acc: &[i32], shift: u32, prec: Precision) -> Vec<i32> {
    let (lo, hi) = match prec {
        Precision::Int4 => (-8, 7),
        Precision::Int8 => (-128, 127),
        Precision::Int16 => (i16::MIN as i32, i16::MAX as i32),
    };
    acc.iter().map(|&a| (a >> shift).clamp(lo, hi)).collect()
}

/// Fused conv + output stage.
#[allow(clippy::too_many_arguments)]
pub fn conv3x3_requant(
    x: &[i32],
    w: &[i32],
    h: usize,
    wd: usize,
    cin: usize,
    cout: usize,
    prec: Precision,
    shift: u32,
) -> Vec<i32> {
    requant(&conv3x3(x, w, h, wd, cin, cout, prec), shift, prec)
}

/// 5×5 mode: the three sum-of-products units combine into one 5×5 unit
/// (§II-C). Functionally a direct 5×5 valid convolution; input is
/// `(h+4, w+4, cin)` pre-padded, weights `(5, 5, cin, cout)`.
pub fn conv5x5(
    x: &[i32],
    w: &[i32],
    h: usize,
    wd: usize,
    cin: usize,
    cout: usize,
    prec: Precision,
) -> Vec<i32> {
    let (hp, wp) = (h + 4, wd + 4);
    assert_eq!(x.len(), hp * wp * cin, "input shape");
    assert_eq!(w.len(), 25 * cin * cout, "weight shape");
    debug_assert!(x.iter().all(|&v| prec.in_range(v)));
    debug_assert!(w.iter().all(|&v| prec.in_range(v)));

    let xat = |r: usize, c: usize, ch: usize| x[(r * wp + c) * cin + ch];
    let wat =
        |dy: usize, dx: usize, ci: usize, co: usize| w[((dy * 5 + dx) * cin + ci) * cout + co];
    let mut out = vec![0i32; h * wd * cout];
    for r in 0..h {
        for c in 0..wd {
            for co in 0..cout {
                let mut acc = 0i32;
                for dy in 0..5 {
                    for dx in 0..5 {
                        for ci in 0..cin {
                            acc = acc.wrapping_add(xat(r + dy, c + dx, ci) * wat(dy, dx, ci, co));
                        }
                    }
                }
                out[(r * wd + c) * cout + co] = acc;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::{property, Rng};

    fn rand_tensor(rng: &mut Rng, n: usize, prec: Precision) -> Vec<i32> {
        let (lo, hi) = match prec {
            Precision::Int4 => (-8, 7),
            Precision::Int8 => (-128, 127),
            Precision::Int16 => (-2048, 2047),
        };
        (0..n).map(|_| rng.range_i64(lo, hi) as i32).collect()
    }

    #[test]
    fn identity_filter_passes_input_through() {
        let mut rng = Rng::new(1);
        let (h, w, c) = (4, 5, 3);
        let x = rand_tensor(&mut rng, (h + 2) * (w + 2) * c, Precision::Int8);
        // centre tap = 1 on the diagonal
        let mut k = vec![0i32; 9 * c * c];
        for ch in 0..c {
            k[((1 * 3 + 1) * c + ch) * c + ch] = 1;
        }
        let out = conv3x3(&x, &k, h, w, c, c, Precision::Int8);
        for r in 0..h {
            for cc in 0..w {
                for ch in 0..c {
                    assert_eq!(
                        out[(r * w + cc) * c + ch],
                        x[((r + 1) * (w + 2) + (cc + 1)) * c + ch]
                    );
                }
            }
        }
    }

    /// Cross-check against an independent formulation (dot product over
    /// flattened patches), property-swept over shapes and precisions.
    #[test]
    fn conv_matches_patch_dot_reference() {
        property("hwce-conv-ref", 30, |rng: &mut Rng| {
            let h = 1 + rng.below(5) as usize;
            let w = 1 + rng.below(5) as usize;
            let cin = 1 + rng.below(4) as usize;
            let cout = 1 + rng.below(4) as usize;
            let prec = match rng.below(3) {
                0 => Precision::Int4,
                1 => Precision::Int8,
                _ => Precision::Int16,
            };
            let x = rand_tensor(rng, (h + 2) * (w + 2) * cin, prec);
            let k = rand_tensor(rng, 9 * cin * cout, prec);
            let got = conv3x3(&x, &k, h, w, cin, cout, prec);
            for r in 0..h {
                for c in 0..w {
                    for co in 0..cout {
                        let mut want = 0i64;
                        for dy in 0..3 {
                            for dx in 0..3 {
                                for ci in 0..cin {
                                    let xv = x[((r + dy) * (w + 2) + c + dx) * cin + ci] as i64;
                                    let wv = k[((dy * 3 + dx) * cin + ci) * cout + co] as i64;
                                    want += xv * wv;
                                }
                            }
                        }
                        assert_eq!(got[(r * w + c) * cout + co] as i64, want);
                    }
                }
            }
        });
    }

    #[test]
    fn requant_saturates_per_precision() {
        let acc = vec![1 << 20, -(1 << 20), 256, -256];
        let q8 = requant(&acc, 4, Precision::Int8);
        assert_eq!(q8, vec![127, -128, 16, -16]);
        let q4 = requant(&acc, 4, Precision::Int4);
        assert_eq!(q4, vec![7, -8, 7, -8]);
    }

    #[test]
    fn conv5x5_identity() {
        let mut rng = Rng::new(2);
        let (h, w) = (3, 3);
        let x = rand_tensor(&mut rng, (h + 4) * (w + 4), Precision::Int8);
        let mut k = vec![0i32; 25];
        k[2 * 5 + 2] = 1; // centre tap
        let out = conv5x5(&x, &k, h, w, 1, 1, Precision::Int8);
        for r in 0..h {
            for c in 0..w {
                assert_eq!(out[r * w + c], x[(r + 2) * (w + 4) + c + 2]);
            }
        }
    }

    #[test]
    fn linearity_over_weights() {
        // The RepVGG re-parameterisation identity on the HWCE datapath.
        let mut rng = Rng::new(3);
        let (h, w, ci, co) = (3, 4, 2, 2);
        let x = rand_tensor(&mut rng, (h + 2) * (w + 2) * ci, Precision::Int8);
        let k1 = rand_tensor(&mut rng, 9 * ci * co, Precision::Int4);
        let k2 = rand_tensor(&mut rng, 9 * ci * co, Precision::Int4);
        let ksum: Vec<i32> = k1.iter().zip(&k2).map(|(a, b)| a + b).collect();
        let lhs = conv3x3(&x, &ksum, h, w, ci, co, Precision::Int8);
        let a = conv3x3(&x, &k1, h, w, ci, co, Precision::Int8);
        let b = conv3x3(&x, &k2, h, w, ci, co, Precision::Int8);
        let rhs: Vec<i32> = a.iter().zip(&b).map(|(x, y)| x + y).collect();
        assert_eq!(lhs, rhs);
    }
}
