//! The Hardware Convolution Engine (§II-C, Fig. 4).
//!
//! A cluster-coupled, multi-precision (4/8/16-bit) 3×3 convolution engine
//! with 27 MACs: three sum-of-products units (one per concurrently-computed
//! output filter), a line buffer building the sliding window from a
//! continuous input-pixel stream, a 3-filter weight buffer, and partial-sum
//! FIFOs accumulating across input channels. Operands are upscaled to
//! 16-bit before the carry-save reduction trees; accumulation is 32-bit
//! with an optional normalisation + right-shift output stage. The engine
//! reads/writes L1 through four 32-bit TCDM ports; stream bubbles from
//! bank contention add latency but never corrupt results (ready/valid).
//!
//! [`conv3x3`] is the *functional* datapath (bit-exact against the
//! JAX/Pallas golden artifact, see `runtime_integration`); [`ConvJob`] +
//! [`cycles`](ConvJob::cycles) is the *timing* model (anchored to the
//! paper's 27 MAC/cycle peak and ~19 MAC/cycle streaming numbers).

pub mod datapath;
pub mod timing;

pub use datapath::{conv3x3, conv3x3_requant, conv5x5, Precision};
pub use timing::{ConvJob, HwceStats};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_mac_per_cycle_is_27() {
        // Large layer with internal partial-sum reuse: approaches 27.
        let job = ConvJob {
            h: 64,
            w: 64,
            cin: 32,
            cout: 33,
            precision: Precision::Int8,
            partials_in_l1: false,
        };
        let mpc = job.mac_per_cycle();
        assert!(mpc > 23.0 && mpc <= 27.0, "mac/cycle = {mpc}");
    }

    #[test]
    fn streaming_partials_lands_near_19() {
        // Partial sums streamed through L1 (the common multi-Cin case):
        // "achieving up to 19 MAC/cycle on a 3x3 convolutional layer".
        let job = ConvJob {
            h: 56,
            w: 56,
            cin: 64,
            cout: 64,
            precision: Precision::Int8,
            partials_in_l1: true,
        };
        let mpc = job.mac_per_cycle();
        assert!(mpc > 17.0 && mpc < 21.0, "mac/cycle = {mpc}");
    }
}
