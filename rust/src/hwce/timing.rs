//! HWCE cycle model.
//!
//! Microarchitectural schedule (Fig. 4): a *job* convolves one group of up
//! to three output filters over one input-channel pass. Per pass:
//!
//! * weight-buffer load: 3 filters × 9 taps × ≤2 B over the 4×32-bit TCDM
//!   ports;
//! * line-buffer prologue: two padded rows + two pixels before the first
//!   window is complete;
//! * steady state: one sliding-window position per cycle → 3 filters × 9
//!   taps = 27 MACs/cycle;
//! * partial-sum traffic: when the pass's accumulators don't fit the three
//!   internal FIFOs, partials stream through L1 (read+write 4 B per
//!   output lane) and the four ports saturate, stretching the stream.
//!
//! The 16-bit precision halves the input-port packing (two pixels per
//! 32-bit beat instead of four), which shows up as a small stream stretch.

use crate::common::Cycles;

use super::datapath::Precision;

/// Per-job register programming via the peripheral interconnect; the
/// shadow register set lets the next job be offloaded during the current
/// one, so only the first job in a sequence pays it fully.
pub const JOB_OFFLOAD_CYCLES: Cycles = 32;

/// One 3×3 convolution layer (or tile) to run on the engine.
#[derive(Debug, Clone, Copy)]
pub struct ConvJob {
    pub h: usize,
    pub w: usize,
    pub cin: usize,
    pub cout: usize,
    pub precision: Precision,
    /// Partial sums stream through L1 (true for layers with more input
    /// channels than the internal FIFO depth covers — the common case).
    pub partials_in_l1: bool,
}

/// Aggregate engine statistics.
#[derive(Debug, Clone, Copy, Default)]
pub struct HwceStats {
    pub jobs: u64,
    pub cycles: Cycles,
    pub macs: u64,
}

impl HwceStats {
    pub fn mac_per_cycle(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.macs as f64 / self.cycles as f64
        }
    }

    pub fn add(&mut self, job: &ConvJob) {
        self.jobs += 1;
        self.cycles += job.cycles();
        self.macs += job.macs();
    }
}

impl ConvJob {
    /// Total multiply-accumulates in the layer.
    pub fn macs(&self) -> u64 {
        (self.h * self.w * 9 * self.cin * self.cout) as u64
    }

    /// Filter-group passes: 3 output filters per pass, per input channel.
    fn passes(&self) -> u64 {
        (self.cout.div_ceil(3) * self.cin) as u64
    }

    /// Cycles for one input-channel pass over the feature map.
    ///
    /// The pixel stream is continuous across passes ("a continuous stream
    /// of input pixels", §II-C): the line buffer refills once per job, so
    /// only the per-pass weight reload and the stream stretch recur.
    fn pass_cycles(&self) -> Cycles {
        let positions = (self.h * self.w) as u64;
        // Weight load: 27 taps x bytes over 16 B/cycle of port bandwidth.
        let wload = ((27 * self.precision.bytes() as u64) as f64 / 16.0).ceil() as u64 + 2;
        // Steady-state stream stretch from port contention:
        //  input stream: 1/2/4 pixels per 32-bit beat depending on width;
        //  partials (when in L1): 3 lanes x (4 B in + 4 B out) per position
        //  = 24 B/cycle demand on 16 B/cycle of ports -> 1.5x stretch, minus
        //  the input beat -> measured ~1.4x (=> ~19 MAC/cycle, §II-C).
        let stretch = if self.partials_in_l1 {
            match self.precision {
                Precision::Int16 => 1.55,
                _ => 1.40,
            }
        } else {
            match self.precision {
                Precision::Int16 => 1.10,
                _ => 1.02,
            }
        };
        wload + (positions as f64 * stretch).ceil() as u64
    }

    /// Line-buffer prologue, paid once per job: 2 padded rows + 2 pixels.
    fn prologue_cycles(&self) -> Cycles {
        (2 * (self.w + 2) + 2) as u64
    }

    /// Total engine cycles for the layer (all passes + first-job offload;
    /// subsequent jobs hide programming behind the shadow registers).
    pub fn cycles(&self) -> Cycles {
        JOB_OFFLOAD_CYCLES + self.prologue_cycles() + self.passes() * self.pass_cycles()
    }

    /// Effective MAC/cycle for this job.
    pub fn mac_per_cycle(&self) -> f64 {
        self.macs() as f64 / self.cycles() as f64
    }

    /// L1 traffic in bytes (input stream + weights + output, plus partial
    /// round-trips when they spill).
    pub fn l1_bytes(&self) -> u64 {
        let inb = ((self.h + 2) * (self.w + 2) * self.cin * self.precision.bytes()) as u64
            * self.cout.div_ceil(3) as u64;
        let wb = (9 * self.cin * self.cout * self.precision.bytes()) as u64;
        let outb = (self.h * self.w * self.cout * 4) as u64;
        let partials = if self.partials_in_l1 {
            // read+write per position per pass beyond the first channel
            (self.h * self.w * 4 * 2) as u64 * (self.passes() - self.cout.div_ceil(3) as u64)
        } else {
            0
        };
        inb + wb + outb + partials
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn macs_formula() {
        let j = ConvJob {
            h: 8,
            w: 8,
            cin: 4,
            cout: 6,
            precision: Precision::Int8,
            partials_in_l1: false,
        };
        assert_eq!(j.macs(), 8 * 8 * 9 * 4 * 6);
    }

    #[test]
    fn int16_is_slower_than_int8() {
        let mk = |p| ConvJob {
            h: 32,
            w: 32,
            cin: 16,
            cout: 16,
            precision: p,
            partials_in_l1: true,
        };
        assert!(mk(Precision::Int16).cycles() > mk(Precision::Int8).cycles());
        // Int4 uses the same byte-aligned streams as Int8 here.
        assert_eq!(mk(Precision::Int4).cycles(), mk(Precision::Int8).cycles());
    }

    #[test]
    fn small_tiles_are_overhead_dominated() {
        let j = ConvJob {
            h: 4,
            w: 4,
            cin: 1,
            cout: 3,
            precision: Precision::Int8,
            partials_in_l1: false,
        };
        assert!(j.mac_per_cycle() < 10.0);
    }

    #[test]
    fn stats_accumulate() {
        let mut s = HwceStats::default();
        let j = ConvJob {
            h: 16,
            w: 16,
            cin: 8,
            cout: 8,
            precision: Precision::Int8,
            partials_in_l1: true,
        };
        s.add(&j);
        s.add(&j);
        assert_eq!(s.jobs, 2);
        assert_eq!(s.macs, 2 * j.macs());
        assert!(s.mac_per_cycle() > 0.0);
    }
}
