//! The per-core model: state, two-phase cycle protocol, timing rules.
//!
//! Timing model (RI5CY 4-stage in-order):
//! * 1 instruction/cycle steady state;
//! * load-use interlock: +1 cycle when the instruction immediately after a
//!   load reads its destination;
//! * taken branch +2 cycles, jump +1 cycle;
//! * DIV/REM 35 cycles (serial divider), FDIV 11 / FSQRT 15 on the shared
//!   DIV-SQRT unit;
//! * zero-overhead hardware loops (two channels, lp0 innermost);
//! * instruction-cache model: +2 cycles the first time any core touches a
//!   PC (L1.5 miss, refill from L2), +1 the first time *this* core touches
//!   a PC already warm in the shared L1.5 (§II-C hierarchical I$);
//! * TCDM bank conflicts and FPU contention are decided by the fabric
//!   through the [`Intent`] protocol and charged via [`Core::deny_mem`] /
//!   [`Core::deny_fpu`].

use crate::isa::inst::{Inst, LoopCount, MemSize};
use crate::isa::predecode::{Decoded, DecodedKind, PreDecoded};
use crate::isa::{Program, Reg};

use super::exec;
use super::stats::CoreStats;
use super::Memory;

/// Lifecycle state of a core.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CoreState {
    Ready,
    AtBarrier,
    Halted,
}

/// A memory access the core wants to perform this cycle.
#[derive(Debug, Clone, Copy)]
pub struct MemReq {
    pub addr: u32,
    pub size: MemSize,
    pub write: bool,
}

/// What the core wants to do this cycle (returned by [`Core::begin_cycle`]).
#[derive(Debug, Clone, Copy)]
pub enum Intent {
    /// Needs a memory grant (TCDM/L2 arbitration).
    Mem(MemReq),
    /// Needs an FPU issue slot (`divsqrt` ops go to the shared unit).
    Fp { divsqrt: bool },
    /// Instruction retired internally this cycle; nothing to arbitrate.
    Retired,
    /// Waiting at the event-unit barrier.
    Barrier,
    /// Stalled (busy counter, hazard, icache refill).
    Stalled,
    Halted,
}

/// Hardware-loop channel state; `pub(super)` so the superblock replay
/// layer can check entry conditions and commit batched trip counts.
#[derive(Debug, Clone, Copy, Default)]
pub(super) struct HwLoop {
    pub(super) start: usize,
    pub(super) end: usize,
    pub(super) remaining: u32,
}

/// One RI5CY-class core.
pub struct Core {
    pub id: usize,
    pub regs: [u32; 32],
    pub pc: usize,
    pub state: CoreState,
    pub stats: CoreStats,
    pub(super) loops: [HwLoop; 2],
    /// Extra cycles the current instruction still occupies.
    busy: u64,
    /// Destination of a load retired in the previous cycle (interlock).
    pub(super) pending_load: Option<Reg>,
    /// Per-core I$ footprint (PCs executed at least once).
    pub(super) seen: Vec<bool>,
}

impl Core {
    pub fn new(id: usize) -> Self {
        Self {
            id,
            regs: [0; 32],
            pc: 0,
            state: CoreState::Ready,
            stats: CoreStats::default(),
            loops: [HwLoop::default(); 2],
            busy: 0,
            pending_load: None,
            seen: Vec::new(),
        }
    }

    /// Reset for a new program, keeping the id (and the `seen` bitmap's
    /// capacity — resetting must not re-allocate between runs, §Perf).
    pub fn reset(&mut self, prog_len: usize) {
        self.regs = [0; 32];
        self.pc = 0;
        self.state = CoreState::Ready;
        self.stats = CoreStats::default();
        self.loops = [HwLoop::default(); 2];
        self.busy = 0;
        self.pending_load = None;
        self.seen.clear();
        self.seen.resize(prog_len, false);
    }

    pub fn set_reg(&mut self, r: Reg, v: u32) {
        if r != 0 {
            self.regs[r as usize] = v;
        }
    }

    pub fn reg(&self, r: Reg) -> u32 {
        self.regs[r as usize]
    }

    fn write_reg(&mut self, r: Reg, v: u32) {
        if r != 0 {
            self.regs[r as usize] = v;
        }
    }

    pub fn halted(&self) -> bool {
        self.state == CoreState::Halted
    }

    /// Phase 1: advance one cycle and report what this core needs.
    ///
    /// `pre` is the program's predecoded side-table ([`Program::predecode`],
    /// built once per run); `shared_warm` is the shared-L1.5 footprint
    /// bitmap (sized to the program; shared across the cluster's cores).
    pub fn begin_cycle(
        &mut self,
        prog: &Program,
        pre: &PreDecoded,
        shared_warm: &mut [bool],
    ) -> Intent {
        if self.state == CoreState::Halted {
            return Intent::Halted;
        }
        self.stats.cycles += 1;
        if self.busy > 0 {
            self.busy -= 1;
            return Intent::Stalled;
        }
        if self.state == CoreState::AtBarrier {
            self.stats.stall_barrier += 1;
            return Intent::Barrier;
        }
        debug_assert!(self.pc < prog.insts.len(), "pc fell off program end");

        // Instruction-cache model (cold/compulsory misses only: kernel
        // loops fit the 512 B private caches, so steady state always hits).
        if !self.seen[self.pc] {
            self.seen[self.pc] = true;
            let warm = shared_warm[self.pc];
            shared_warm[self.pc] = true;
            let penalty = if warm { 1 } else { 2 };
            self.stats.stall_icache += penalty;
            self.busy = penalty; // spend the refill cycles, then re-issue
            return Intent::Stalled;
        }

        let dec = pre.recs[self.pc];

        // Load-use interlock.
        if let Some(ld) = self.pending_load.take() {
            if dec.src_mask & (1u32 << ld) != 0 {
                self.stats.stall_loaduse += 1;
                return Intent::Stalled;
            }
        }

        match dec.kind {
            DecodedKind::Mem { write, size, rs1, imm, post_inc, .. } => {
                let addr = if post_inc {
                    self.reg(rs1)
                } else {
                    self.reg(rs1).wrapping_add(imm as u32)
                };
                Intent::Mem(MemReq { addr, size, write })
            }
            DecodedKind::Fp { divsqrt, .. } => Intent::Fp { divsqrt },
            DecodedKind::Barrier => {
                self.state = CoreState::AtBarrier;
                self.stats.retired += 1;
                self.stats.by_class.bump(dec.class);
                Intent::Barrier
            }
            DecodedKind::Halt => {
                self.state = CoreState::Halted;
                self.stats.retired += 1;
                self.stats.by_class.bump(dec.class);
                Intent::Halted
            }
            DecodedKind::Local => {
                self.exec_local(prog, &dec);
                Intent::Retired
            }
        }
    }

    /// Phase 2a: the fabric granted the memory request.
    pub fn retire_mem(&mut self, pre: &PreDecoded, mem: &mut dyn Memory) {
        let dec = pre.recs[self.pc];
        let DecodedKind::Mem { write, size, reg, rs1, imm, post_inc } = dec.kind else {
            unreachable!("retire_mem on non-memory inst");
        };
        let addr = if post_inc {
            self.reg(rs1)
        } else {
            self.reg(rs1).wrapping_add(imm as u32)
        };
        if write {
            mem.store(addr, size, self.reg(reg));
            self.stats.bytes_stored += size.bytes() as u64;
        } else {
            let v = mem.load(addr, size);
            self.write_reg(reg, v);
            self.pending_load = Some(reg);
            self.stats.bytes_loaded += size.bytes() as u64;
        }
        if post_inc {
            let nv = self.reg(rs1).wrapping_add(imm as u32);
            self.write_reg(rs1, nv);
        }
        self.finish_retire(&dec, None);
    }

    /// Phase 2b: the memory request was not granted (bank conflict).
    pub fn deny_mem(&mut self) {
        self.stats.stall_tcdm += 1;
    }

    /// Phase 2c: the FPU issue slot was granted.
    pub fn retire_fp(&mut self, pre: &PreDecoded) {
        let dec = pre.recs[self.pc];
        let DecodedKind::Fp { op, fmt, rd, rs1, rs2, latency, .. } = dec.kind else {
            unreachable!("retire_fp on non-fp inst");
        };
        let acc = self.reg(rd);
        let v = exec::fp(op, fmt, self.reg(rs1), self.reg(rs2), acc);
        self.write_reg(rd, v);
        if latency > 1 {
            // Core blocks on the iterative DIV-SQRT unit.
            self.busy = latency - 1;
            self.stats.multicycle_busy += latency - 1;
        }
        self.finish_retire(&dec, None);
    }

    /// Phase 2d: FPU slot contended away (another core issued to the same
    /// shared FPU this cycle).
    pub fn deny_fpu(&mut self, divsqrt: bool) {
        if divsqrt {
            self.stats.stall_divsqrt += 1;
        } else {
            self.stats.stall_fpu += 1;
        }
    }

    /// Charge extra latency cycles from the fabric (e.g. a cluster-side
    /// access to L2 across the AXI bridge).
    pub fn add_busy(&mut self, cycles: u64) {
        self.busy += cycles;
        self.stats.multicycle_busy += cycles;
    }

    /// Release from the event-unit barrier (2-cycle wake-up, §II-C).
    pub fn release_barrier(&mut self) {
        debug_assert_eq!(self.state, CoreState::AtBarrier);
        self.state = CoreState::Ready;
        self.busy = 2;
        self.pc += 1;
    }

    /// Remaining multi-cycle busy count (read by the cluster scheduler's
    /// cycle-skip fast path).
    pub(crate) fn busy_cycles(&self) -> u64 {
        self.busy
    }

    /// Advance this core through `delta` pure-stall cycles in one step:
    /// exactly what `delta` consecutive [`Core::begin_cycle`] calls do
    /// when the core is draining a busy counter or parked at a barrier.
    /// The caller guarantees `delta <= busy` for busy cores and that the
    /// barrier cannot release during the skipped window.
    pub(crate) fn skip_stall_cycles(&mut self, delta: u64) {
        self.stats.cycles += delta;
        match self.state {
            CoreState::Ready => {
                debug_assert!(self.busy >= delta, "skip past next issue");
                self.busy -= delta;
            }
            CoreState::AtBarrier => self.stats.stall_barrier += delta,
            CoreState::Halted => debug_assert!(false, "skip on a halted core"),
        }
    }

    /// Execute an instruction that needs no external arbitration.
    fn exec_local(&mut self, prog: &Program, dec: &Decoded) {
        let inst = prog.insts[self.pc];
        let mut taken: Option<usize> = None;
        match inst {
            Inst::Alu { op, rd, rs1, rs2 } => {
                let v = exec::alu(op, self.reg(rs1), self.reg(rs2));
                self.write_reg(rd, v);
                let lat = op.cycles();
                if lat > 1 {
                    self.busy = lat - 1;
                    self.stats.multicycle_busy += lat - 1;
                }
            }
            Inst::AluImm { op, rd, rs1, imm } => {
                let v = exec::alu(op, self.reg(rs1), imm as u32);
                self.write_reg(rd, v);
                let lat = op.cycles();
                if lat > 1 {
                    self.busy = lat - 1;
                    self.stats.multicycle_busy += lat - 1;
                }
            }
            Inst::Li { rd, imm } => self.write_reg(rd, imm as u32),
            Inst::Branch { cond, rs1, rs2, target } => {
                if exec::branch_taken(cond, self.reg(rs1), self.reg(rs2)) {
                    taken = Some(target);
                    self.busy = 2;
                    self.stats.branch_penalty += 2;
                }
            }
            Inst::Jal { rd, target } => {
                self.write_reg(rd, (self.pc + 1) as u32);
                taken = Some(target);
                self.busy = 1;
                self.stats.branch_penalty += 1;
            }
            Inst::Jalr { rd, rs1 } => {
                let t = self.reg(rs1) as usize;
                self.write_reg(rd, (self.pc + 1) as u32);
                taken = Some(t);
                self.busy = 1;
                self.stats.branch_penalty += 1;
            }
            Inst::Mac { rd, rs1, rs2 } => {
                let v = (self.reg(rd) as i32)
                    .wrapping_add((self.reg(rs1) as i32).wrapping_mul(self.reg(rs2) as i32));
                self.write_reg(rd, v as u32);
            }
            Inst::Msu { rd, rs1, rs2 } => {
                let v = (self.reg(rd) as i32)
                    .wrapping_sub((self.reg(rs1) as i32).wrapping_mul(self.reg(rs2) as i32));
                self.write_reg(rd, v as u32);
            }
            Inst::Simd { op, fmt, rd, rs1, rs2 } => {
                let v = exec::simd(op, fmt, self.reg(rs1), self.reg(rs2), self.reg(rd));
                self.write_reg(rd, v);
            }
            Inst::LpSetup { lp, count, body_end } => {
                let n = match count {
                    LoopCount::Imm(n) => n,
                    LoopCount::Reg(r) => self.reg(r),
                };
                if n == 0 {
                    // Skip the body entirely.
                    self.loops[lp as usize].remaining = 0;
                    self.stats.retired += 1;
                    self.stats.by_class.bump(dec.class);
                    self.pc = body_end;
                    return;
                }
                self.loops[lp as usize] =
                    HwLoop { start: self.pc + 1, end: body_end, remaining: n };
            }
            Inst::Nop => {}
            Inst::Fp { .. }
            | Inst::Load { .. }
            | Inst::Store { .. }
            | Inst::Barrier
            | Inst::Halt => unreachable!("arbitrated insts handled elsewhere"),
        }
        self.finish_retire(dec, taken);
    }

    /// Book-keeping common to every retirement + next-PC computation with
    /// zero-overhead hardware loops.
    fn finish_retire(&mut self, dec: &Decoded, taken: Option<usize>) {
        self.stats.retired += 1;
        self.stats.by_class.bump(dec.class);
        self.stats.int_ops += dec.int_ops;
        self.stats.flops += dec.flops;

        if let Some(t) = taken {
            self.pc = t;
            return;
        }
        let cur = self.pc;
        // Hardware loops: innermost (lp0) first; falling out of an inner
        // loop must still honour an outer loop ending at the same PC.
        for lp in 0..2 {
            let l = &mut self.loops[lp];
            if l.remaining > 0 && cur + 1 == l.end {
                if l.remaining > 1 {
                    l.remaining -= 1;
                    self.pc = l.start;
                    return;
                }
                l.remaining = 0; // exhausted; check outer channel
            }
        }
        self.pc = cur + 1;
    }
}

/// Run a program on a single core with ideal memory (no contention): the
/// FC-core configuration, also the harness for ISS unit tests.
///
/// `init` sets registers before the run. Panics if `max_cycles` elapses
/// without `Halt` (runaway program).
pub fn run_single(
    prog: &Program,
    mem: &mut dyn Memory,
    init: &[(Reg, u32)],
    max_cycles: u64,
) -> CoreStats {
    run_single_regs(prog, mem, init, max_cycles).0
}

/// As [`run_single`] but returns the final register file too.
pub fn run_single_regs(
    prog: &Program,
    mem: &mut dyn Memory,
    init: &[(Reg, u32)],
    max_cycles: u64,
) -> (CoreStats, [u32; 32]) {
    let mut core = Core::new(0);
    core.reset(prog.insts.len());
    for &(r, v) in init {
        core.set_reg(r, v);
    }
    let pre = prog.predecode();
    let mut warm = vec![false; prog.insts.len()];
    while !core.halted() {
        assert!(
            core.stats.cycles < max_cycles,
            "program {} exceeded {max_cycles} cycles",
            prog.name
        );
        match core.begin_cycle(prog, &pre, &mut warm) {
            Intent::Mem(_) => core.retire_mem(&pre, mem),
            Intent::Fp { .. } => core.retire_fp(&pre),
            Intent::Barrier => core.release_barrier(),
            Intent::Stalled => {
                // A single core has nothing to arbitrate against: drain
                // the remaining busy cycles (DIV, icache refill, branch
                // penalty) in one step instead of one call per cycle.
                // Clamped so the runaway guard still fires where the
                // per-cycle loop would have panicked.
                let b = core
                    .busy_cycles()
                    .min(max_cycles.saturating_sub(core.stats.cycles));
                if b > 0 {
                    core.skip_stall_cycles(b);
                }
            }
            Intent::Retired | Intent::Halted => {}
        }
    }
    (core.stats.clone(), core.regs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{Asm, A0, A1, A2, A3, T0};
    use crate::iss::FlatMem;

    fn run(prog: &Program, init: &[(Reg, u32)]) -> (CoreStats, [u32; 32]) {
        let mut mem = FlatMem::new(0, 4096);
        run_single_regs(prog, &mut mem, init, 1_000_000)
    }

    #[test]
    fn arithmetic_and_li() {
        let mut a = Asm::new("t");
        a.li(A0, 21);
        a.slli(A1, A0, 1);
        a.addi(A1, A1, -2);
        a.halt();
        let (_, regs) = run(&a.finish().unwrap(), &[]);
        assert_eq!(regs[A1 as usize], 40);
    }

    #[test]
    fn hw_loop_executes_exact_count() {
        let mut a = Asm::new("t");
        let end = a.label();
        a.li(A0, 0);
        a.lp_setup_imm(0, 10, end);
        a.addi(A0, A0, 1);
        a.bind(end);
        a.halt();
        let (stats, regs) = run(&a.finish().unwrap(), &[]);
        assert_eq!(regs[A0 as usize], 10);
        // body retired 10 times + li + setup + halt
        assert_eq!(stats.retired, 13);
    }

    #[test]
    fn nested_hw_loops() {
        let mut a = Asm::new("t");
        let end1 = a.label();
        let end0 = a.label();
        a.li(A0, 0);
        a.lp_setup_imm(1, 5, end1);
        a.lp_setup_imm(0, 3, end0);
        a.addi(A0, A0, 1);
        a.bind(end0);
        a.addi(A1, A1, 1); // outer-only tail
        a.bind(end1);
        a.halt();
        let (_, regs) = run(&a.finish().unwrap(), &[]);
        assert_eq!(regs[A0 as usize], 15);
        assert_eq!(regs[A1 as usize], 5);
    }

    #[test]
    fn hw_loop_reg_count_zero_skips_body() {
        let mut a = Asm::new("t");
        let end = a.label();
        a.li(A0, 99);
        a.lp_setup(0, A1, end); // A1 = 0
        a.li(A0, 1);
        a.bind(end);
        a.halt();
        let (_, regs) = run(&a.finish().unwrap(), &[(A1, 0)]);
        assert_eq!(regs[A0 as usize], 99);
    }

    #[test]
    fn post_increment_load_store() {
        let mut a = Asm::new("t");
        // copy 4 words from A0 to A1
        let end = a.label();
        a.lp_setup_imm(0, 4, end);
        a.lw_pi(T0, A0, 4);
        a.sw_pi(T0, A1, 4);
        a.bind(end);
        a.halt();
        let prog = a.finish().unwrap();
        let mut mem = FlatMem::new(0, 256);
        mem.write_i32s(0, &[10, 20, 30, 40]);
        let stats = run_single(&prog, &mut mem, &[(A0, 0), (A1, 64)], 10_000);
        assert_eq!(mem.read_i32s(64, 4), vec![10, 20, 30, 40]);
        assert_eq!(stats.bytes_loaded, 16);
        assert_eq!(stats.bytes_stored, 16);
    }

    #[test]
    fn load_use_stall_charged() {
        // lw then immediately use -> 1 stall
        let mut a = Asm::new("t");
        a.lw(A0, A1, 0);
        a.addi(A2, A0, 1); // hazard
        a.halt();
        let p = a.finish().unwrap();
        let (s1, _) = run(&p, &[(A1, 0)]);
        assert_eq!(s1.stall_loaduse, 1);

        // with an independent instruction in between -> 0 stalls
        let mut b = Asm::new("t2");
        b.lw(A0, A1, 0);
        b.addi(A3, A1, 1); // independent
        b.addi(A2, A0, 1);
        b.halt();
        let (s2, _) = run(&b.finish().unwrap(), &[(A1, 0)]);
        assert_eq!(s2.stall_loaduse, 0);
    }

    #[test]
    fn branch_penalty_taken_only() {
        let mut a = Asm::new("t");
        let l = a.label();
        a.li(A0, 0);
        a.beq(A0, A0, l); // taken
        a.li(A0, 1); // skipped
        a.bind(l);
        a.bne(A0, A0, l); // not taken
        a.halt();
        let (s, regs) = run(&a.finish().unwrap(), &[]);
        assert_eq!(regs[A0 as usize], 0);
        assert_eq!(s.branch_penalty, 2);
    }

    #[test]
    fn mac_and_sdotsp() {
        let mut a = Asm::new("t");
        a.li(A0, 3);
        a.li(A1, 4);
        a.li(A2, 100);
        a.mac(A2, A0, A1); // 112
        a.li(T0, 0x0102_0304u32 as i32);
        a.li(A3, 0);
        a.sdotsp_b(A3, T0, T0); // 1+4+9+16 = 30
        a.halt();
        let (s, regs) = run(&a.finish().unwrap(), &[]);
        assert_eq!(regs[A2 as usize], 112);
        assert_eq!(regs[A3 as usize], 30);
        assert_eq!(s.int_ops, 2 + 8 + 5 /* 5 li/alu */);
    }

    #[test]
    fn fp_ops_retire_with_flops() {
        let mut a = Asm::new("t");
        a.li(A0, 2.0f32.to_bits() as i32);
        a.li(A1, 3.0f32.to_bits() as i32);
        a.li(A2, 1.0f32.to_bits() as i32);
        a.fmac_s(A2, A0, A1); // 7.0
        a.fdiv_s(A3, A2, A0); // 3.5, 11 cycles
        a.halt();
        let (s, regs) = run(&a.finish().unwrap(), &[]);
        assert_eq!(f32::from_bits(regs[A2 as usize]), 7.0);
        assert_eq!(f32::from_bits(regs[A3 as usize]), 3.5);
        assert_eq!(s.flops, 2 + 1);
        assert_eq!(s.multicycle_busy, 10);
    }

    #[test]
    fn div_takes_35_cycles() {
        let mut a = Asm::new("t");
        a.li(A0, 100);
        a.li(A1, 7);
        a.div(A2, A0, A1);
        a.halt();
        let (s, regs) = run(&a.finish().unwrap(), &[]);
        assert_eq!(regs[A2 as usize], 14);
        assert!(s.cycles >= 35);
    }

    #[test]
    fn icache_cold_misses_charged_once() {
        let mut a = Asm::new("t");
        let end = a.label();
        a.lp_setup_imm(0, 100, end);
        a.addi(A0, A0, 1);
        a.bind(end);
        a.halt();
        let (s, _) = run(&a.finish().unwrap(), &[]);
        // 3 unique PCs x 2 cycles cold = 6 icache stall cycles, not 100.
        assert_eq!(s.stall_icache, 6);
    }

    #[test]
    fn x0_is_hardwired_zero() {
        let mut a = Asm::new("t");
        a.li(0, 42);
        a.addi(A0, 0, 5);
        a.halt();
        let (_, regs) = run(&a.finish().unwrap(), &[]);
        assert_eq!(regs[0], 0);
        assert_eq!(regs[A0 as usize], 5);
    }

    #[test]
    fn steady_state_ipc_near_one() {
        // A long hw loop of independent ALU ops should retire ~1 IPC.
        let mut a = Asm::new("t");
        let end = a.label();
        a.lp_setup_imm(0, 1000, end);
        a.addi(A0, A0, 1);
        a.addi(A1, A1, 1);
        a.addi(A2, A2, 1);
        a.addi(A3, A3, 1);
        a.bind(end);
        a.halt();
        let (s, _) = run(&a.finish().unwrap(), &[]);
        assert!(s.ipc() > 0.99, "ipc = {}", s.ipc());
    }
}
