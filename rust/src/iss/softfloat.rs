//! Software FP16 (IEEE binary16), bfloat16 and FP8 (E5M2 binary8)
//! conversion/arithmetic.
//!
//! Vega's shared FPnew FPUs operate natively on FP32, FP16, bfloat16 and
//! an 8-bit smallFloat mode (§II-C). Rust has no stable `f16` (let alone
//! `f8`), so the packed-SIMD smallFloat lanes are evaluated by converting
//! to f32, operating, and rounding back — which is also exactly FPnew's
//! internal behaviour for the narrow formats (it computes in a wider
//! datapath and rounds to the target format, RNE). The FP8 format is
//! E5M2: 1 sign, 5 exponent (bias 15, the binary16 range) and 2 mantissa
//! bits — binary16 with the bottom 8 mantissa bits cut off.

/// binary16 -> binary32 (exact).
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h >> 15) & 1) as u32;
    let exp = ((h >> 10) & 0x1F) as u32;
    let frac = (h & 0x3FF) as u32;
    let bits = if exp == 0 {
        if frac == 0 {
            sign << 31 // signed zero
        } else {
            // subnormal: normalise
            let mut e = 127 - 15 + 1;
            let mut f = frac;
            while f & 0x400 == 0 {
                f <<= 1;
                e -= 1;
            }
            (sign << 31) | ((e as u32) << 23) | ((f & 0x3FF) << 13)
        }
    } else if exp == 0x1F {
        (sign << 31) | (0xFF << 23) | (frac << 13) // inf / NaN
    } else {
        (sign << 31) | ((exp + 127 - 15) << 23) | (frac << 13)
    };
    f32::from_bits(bits)
}

/// binary32 -> binary16, round to nearest even.
pub fn f32_to_f16(f: f32) -> u16 {
    let bits = f.to_bits();
    let sign = ((bits >> 31) & 1) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let frac = bits & 0x7F_FFFF;

    if exp == 0xFF {
        // inf / NaN
        let payload = if frac != 0 { 0x200 } else { 0 };
        return (sign << 15) | (0x1F << 10) | payload;
    }
    let unbiased = exp - 127;
    if unbiased > 15 {
        return (sign << 15) | (0x1F << 10); // overflow -> inf
    }
    if unbiased >= -14 {
        // normal range
        let mut e16 = (unbiased + 15) as u32;
        let mut f16 = frac >> 13;
        // RNE on the 13 dropped bits
        let rem = frac & 0x1FFF;
        if rem > 0x1000 || (rem == 0x1000 && (f16 & 1) == 1) {
            f16 += 1;
            if f16 == 0x400 {
                f16 = 0;
                e16 += 1;
                if e16 >= 0x1F {
                    return (sign << 15) | (0x1F << 10);
                }
            }
        }
        (sign << 15) | ((e16 as u16) << 10) | (f16 as u16)
    } else if unbiased >= -24 {
        // subnormal
        let shift = (-14 - unbiased) as u32; // 1..=10
        let mant = 0x80_0000 | frac; // implicit bit
        let total_shift = 13 + shift;
        let mut f16 = mant >> total_shift;
        let rem_mask = (1u32 << total_shift) - 1;
        let rem = mant & rem_mask;
        let half = 1u32 << (total_shift - 1);
        if rem > half || (rem == half && (f16 & 1) == 1) {
            f16 += 1;
        }
        (sign << 15) | (f16 as u16)
    } else {
        sign << 15 // underflow -> signed zero
    }
}

/// bfloat16 -> f32 (exact: bf16 is truncated f32).
pub fn bf16_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// f32 -> bfloat16, round to nearest even.
pub fn f32_to_bf16(f: f32) -> u16 {
    let bits = f.to_bits();
    if f.is_nan() {
        return ((bits >> 16) as u16) | 0x40; // quiet
    }
    let lsb = (bits >> 16) & 1;
    let rounded = bits.wrapping_add(0x7FFF + lsb);
    (rounded >> 16) as u16
}

/// binary8 E5M2 -> binary32 (exact: every E5M2 value is representable).
pub fn f8_to_f32(b: u8) -> f32 {
    let sign = ((b >> 7) & 1) as u32;
    let exp = ((b >> 2) & 0x1F) as u32;
    let frac = (b & 0x3) as u32;
    let bits = if exp == 0 {
        if frac == 0 {
            sign << 31 // signed zero
        } else {
            // subnormal (multiples of 2^-16): normalise
            let mut e = 127 - 15 + 1;
            let mut f = frac;
            while f & 0x4 == 0 {
                f <<= 1;
                e -= 1;
            }
            (sign << 31) | ((e as u32) << 23) | ((f & 0x3) << 21)
        }
    } else if exp == 0x1F {
        (sign << 31) | (0xFF << 23) | (frac << 21) // inf / NaN
    } else {
        (sign << 31) | ((exp + 127 - 15) << 23) | (frac << 21)
    };
    f32::from_bits(bits)
}

/// binary32 -> binary8 E5M2, round to nearest even (the quantize step of
/// the fp8 kernels' host-side data preparation and reference model).
pub fn f32_to_f8(f: f32) -> u8 {
    let bits = f.to_bits();
    let sign = ((bits >> 31) & 1) as u8;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let frac = bits & 0x7F_FFFF;

    if exp == 0xFF {
        // inf / NaN
        let payload = if frac != 0 { 0x2 } else { 0 };
        return (sign << 7) | (0x1F << 2) | payload;
    }
    let unbiased = exp - 127;
    if unbiased > 15 {
        return (sign << 7) | (0x1F << 2); // overflow -> inf
    }
    if unbiased >= -14 {
        // normal range
        let mut e8 = (unbiased + 15) as u32;
        let mut f8 = frac >> 21;
        // RNE on the 21 dropped bits
        let rem = frac & 0x1F_FFFF;
        if rem > 0x10_0000 || (rem == 0x10_0000 && (f8 & 1) == 1) {
            f8 += 1;
            if f8 == 0x4 {
                f8 = 0;
                e8 += 1;
                if e8 >= 0x1F {
                    return (sign << 7) | (0x1F << 2);
                }
            }
        }
        (sign << 7) | ((e8 as u8) << 2) | (f8 as u8)
    } else if unbiased >= -17 {
        // subnormal (shift 3 covers the round-up-from-below-minimum band)
        let shift = (-14 - unbiased) as u32; // 1..=3
        let mant = 0x80_0000 | frac; // implicit bit
        let total_shift = 21 + shift;
        let mut f8 = mant >> total_shift;
        let rem_mask = (1u32 << total_shift) - 1;
        let rem = mant & rem_mask;
        let half = 1u32 << (total_shift - 1);
        if rem > half || (rem == half && (f8 & 1) == 1) {
            f8 += 1;
        }
        (sign << 7) | (f8 as u8)
    } else {
        sign << 7 // underflow -> signed zero
    }
}

/// Multi-format fp8 dot: f32 acc += Σᵢ a.bᵢ·b.bᵢ over the four E5M2
/// lanes (vfdotpex.s.b). Lane products are exact in f32; they are summed
/// lane 0 → 3 and the accumulator added last — one fixed association, so
/// the result is bit-deterministic.
pub fn f8x4_dotpex_s(a: u32, b: u32, acc: u32) -> u32 {
    let mut s = 0f32;
    for i in 0..4 {
        s += f8_to_f32((a >> (8 * i)) as u8) * f8_to_f32((b >> (8 * i)) as u8);
    }
    (s + f32::from_bits(acc)).to_bits()
}

/// Apply `op` on two packed-f16 registers, lane-wise, rounding each lane.
pub fn f16_lanes_op(a: u32, b: u32, op: impl Fn(f32, f32) -> f32) -> u32 {
    let lo = f32_to_f16(op(f16_to_f32(a as u16), f16_to_f32(b as u16)));
    let hi = f32_to_f16(op(f16_to_f32((a >> 16) as u16), f16_to_f32((b >> 16) as u16)));
    (hi as u32) << 16 | lo as u32
}

/// Lane-wise FMA into packed accumulator: acc_i = a_i*b_i + acc_i.
pub fn f16_lanes_fma(a: u32, b: u32, acc: u32) -> u32 {
    let lo = f32_to_f16(
        f16_to_f32(a as u16) * f16_to_f32(b as u16) + f16_to_f32(acc as u16),
    );
    let hi = f32_to_f16(
        f16_to_f32((a >> 16) as u16) * f16_to_f32((b >> 16) as u16)
            + f16_to_f32((acc >> 16) as u16),
    );
    (hi as u32) << 16 | lo as u32
}

/// Multi-format dot: f32 acc += a.h0*b.h0 + a.h1*b.h1 (vfdotpex.s.h).
pub fn f16_dotpex_s(a: u32, b: u32, acc: u32) -> u32 {
    let s = f16_to_f32(a as u16) * f16_to_f32(b as u16)
        + f16_to_f32((a >> 16) as u16) * f16_to_f32((b >> 16) as u16)
        + f32::from_bits(acc);
    s.to_bits()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f16_roundtrip_exact_values() {
        for v in [0.0f32, -0.0, 1.0, -1.0, 0.5, 65504.0, -65504.0, 6.1035156e-5] {
            assert_eq!(f16_to_f32(f32_to_f16(v)), v, "value {v}");
        }
    }

    #[test]
    fn f16_subnormals() {
        let tiny = 5.9604645e-8; // smallest positive f16 subnormal
        assert_eq!(f16_to_f32(f32_to_f16(tiny)), tiny);
        assert_eq!(f32_to_f16(1e-12), 0); // underflow to zero
    }

    #[test]
    fn f16_overflow_to_inf() {
        assert_eq!(f32_to_f16(1e6), 0x7C00);
        assert_eq!(f32_to_f16(-1e6), 0xFC00);
        assert!(f16_to_f32(0x7C00).is_infinite());
    }

    #[test]
    fn f16_nan_propagates() {
        let h = f32_to_f16(f32::NAN);
        assert!(f16_to_f32(h).is_nan());
    }

    #[test]
    fn f16_rne_ties() {
        // 2049 lies exactly between representable 2048 and 2050 -> even (2048)
        assert_eq!(f16_to_f32(f32_to_f16(2049.0)), 2048.0);
        // 2051 between 2050 and 2052 -> even (2052)
        assert_eq!(f16_to_f32(f32_to_f16(2051.0)), 2052.0);
    }

    #[test]
    fn bf16_roundtrip() {
        for v in [0.0f32, 1.0, -2.5, 3.0e38, 1.0e-38] {
            let b = f32_to_bf16(v);
            let back = bf16_to_f32(b);
            let rel = if v == 0.0 { back.abs() } else { ((back - v) / v).abs() };
            assert!(rel < 0.01, "{v} -> {back}");
        }
        assert!(bf16_to_f32(f32_to_bf16(f32::NAN)).is_nan());
    }

    #[test]
    fn lane_ops() {
        let a = (f32_to_f16(2.0) as u32) << 16 | f32_to_f16(1.0) as u32;
        let b = (f32_to_f16(3.0) as u32) << 16 | f32_to_f16(4.0) as u32;
        let s = f16_lanes_op(a, b, |x, y| x + y);
        assert_eq!(f16_to_f32(s as u16), 5.0);
        assert_eq!(f16_to_f32((s >> 16) as u16), 5.0);
        // dotpex: 1*4 + 2*3 + 0.5 = 10.5
        let acc = 0.5f32.to_bits();
        assert_eq!(f32::from_bits(f16_dotpex_s(a, b, acc)), 10.5);
    }

    #[test]
    fn f8_roundtrip_exact_values() {
        for v in [
            0.0f32,
            -0.0,
            1.0,
            -1.0,
            0.5,
            1.25,
            1.75,
            57344.0,  // max normal: 1.75 * 2^15
            -57344.0,
            6.1035156e-5,     // min normal: 2^-14
            1.5258789e-5,     // min subnormal: 2^-16
            4.5776367e-5,     // 3 * 2^-16 (subnormal)
        ] {
            assert_eq!(f8_to_f32(f32_to_f8(v)), v, "value {v}");
        }
    }

    #[test]
    fn f8_overflow_underflow_and_nan() {
        assert_eq!(f32_to_f8(65536.0), 0x7C); // 2^16 -> +inf
        assert_eq!(f32_to_f8(-65536.0), 0xFC);
        assert!(f8_to_f32(0x7C).is_infinite());
        assert!(f8_to_f32(f32_to_f8(f32::NAN)).is_nan());
        assert_eq!(f32_to_f8(1e-12), 0); // deep underflow -> +0
        // Just above half the min subnormal rounds up to it.
        assert_eq!(f32_to_f8(1.2e-5), 0x01);
    }

    #[test]
    fn f8_rne_ties() {
        // 1.125 lies exactly between 1.0 and 1.25 -> even (1.0).
        assert_eq!(f8_to_f32(f32_to_f8(1.125)), 1.0);
        // 1.375 between 1.25 and 1.5 -> even (1.5).
        assert_eq!(f8_to_f32(f32_to_f8(1.375)), 1.5);
    }

    #[test]
    fn exhaustive_f8_f32_f8_identity() {
        // every finite E5M2 value must round-trip bit-exactly through f32
        for b in 0u16..=0xFF {
            let b = b as u8;
            let exp = (b >> 2) & 0x1F;
            if exp == 0x1F {
                continue; // inf/NaN
            }
            assert_eq!(f32_to_f8(f8_to_f32(b)), b, "b={b:#x}");
        }
    }

    #[test]
    fn f8_dotpex_accumulates_in_f32() {
        // lanes a = [1.0, 2.0, -0.5, 4.0], b = [3.0, 0.5, 2.0, 0.25]
        let a = (f32_to_f8(1.0) as u32)
            | ((f32_to_f8(2.0) as u32) << 8)
            | ((f32_to_f8(-0.5) as u32) << 16)
            | ((f32_to_f8(4.0) as u32) << 24);
        let b = (f32_to_f8(3.0) as u32)
            | ((f32_to_f8(0.5) as u32) << 8)
            | ((f32_to_f8(2.0) as u32) << 16)
            | ((f32_to_f8(0.25) as u32) << 24);
        let acc = 0.125f32.to_bits();
        // 3 + 1 - 1 + 1 + 0.125
        assert_eq!(f32::from_bits(f8x4_dotpex_s(a, b, acc)), 4.125);
    }

    #[test]
    fn exhaustive_f16_f32_f16_identity() {
        // every finite f16 must round-trip bit-exactly through f32
        for h in 0u16..=0xFFFF {
            let exp = (h >> 10) & 0x1F;
            if exp == 0x1F {
                continue; // inf/NaN
            }
            assert_eq!(f32_to_f16(f16_to_f32(h)), h, "h={h:#x}");
        }
    }
}
