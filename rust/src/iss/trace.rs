//! Instrumented single-core execution for the static-vs-dynamic oracle.
//!
//! [`run_single_traced`] mirrors [`run_single_regs`] cycle for cycle —
//! same two-phase protocol, same clamped stall skipping — while
//! recording the facts the static verifier ([`crate::isa::analyze`])
//! claims to prove:
//!
//! * which pcs ever issue (must be a subset of the analyzer's reachable
//!   set);
//! * per-pc memory touch summaries (count, bytes, address range, and
//!   whether every access hit one single address — which is exactly the
//!   shape of a [`crate::isa::analyze::MemFact`]);
//! * which registers change value (must be a subset of the analyzer's
//!   may-def mask).
//!
//! The mirroring is load-bearing: the oracle tests
//! (`tests/verify_static.rs`) only mean something if the traced run *is*
//! the production run plus observation. The one intended difference is
//! bookkeeping around the loop body; every [`Core`] call matches
//! [`run_single_regs`] call for call.

use crate::isa::{Program, Reg};

use super::core::{run_single_regs, Core, Intent};
use super::stats::CoreStats;
use super::Memory;

/// Summary of every memory access a single pc performed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PcTouch {
    /// Accesses performed (loop iterations included).
    pub count: u64,
    /// Total bytes moved (`count × element size`).
    pub bytes: u64,
    /// Smallest / largest start address seen.
    pub min_addr: u32,
    pub max_addr: u32,
    pub write: bool,
    /// `Some(addr)` iff every access hit exactly `addr` — the dynamic
    /// counterpart of a statically resolved constant address.
    pub uniform: Option<u32>,
}

/// Everything one traced run observed.
#[derive(Debug, Clone)]
pub struct ExecTrace {
    pub stats: CoreStats,
    pub regs: [u32; 32],
    /// Per-pc: did this instruction ever issue?
    pub executed: Vec<bool>,
    /// Per-pc memory touch summary (None: pc never accessed memory).
    pub mem: Vec<Option<PcTouch>>,
    /// Bitmask of registers whose value changed during the run (bit 0
    /// never set: x0 is hardwired).
    pub regs_written: u32,
}

impl ExecTrace {
    /// Total loaded/stored bytes across all pcs (cross-checked against
    /// the core's own `bytes_loaded`/`bytes_stored` counters).
    pub fn touched_bytes(&self) -> (u64, u64) {
        let mut loaded = 0;
        let mut stored = 0;
        for t in self.mem.iter().flatten() {
            if t.write {
                stored += t.bytes;
            } else {
                loaded += t.bytes;
            }
        }
        (loaded, stored)
    }
}

/// As [`run_single_regs`], returning the full [`ExecTrace`].
///
/// Panics if `max_cycles` elapses without `Halt`, like the production
/// runner.
pub fn run_single_traced(
    prog: &Program,
    mem: &mut dyn Memory,
    init: &[(Reg, u32)],
    max_cycles: u64,
) -> ExecTrace {
    let n = prog.insts.len();
    let mut core = Core::new(0);
    core.reset(n);
    for &(r, v) in init {
        core.set_reg(r, v);
    }
    let pre = prog.predecode();
    let mut warm = vec![false; n];

    let mut executed = vec![false; n];
    let mut touches: Vec<Option<PcTouch>> = vec![None; n];
    let mut regs_written = 0u32;

    while !core.halted() {
        assert!(
            core.stats.cycles < max_cycles,
            "program {} exceeded {max_cycles} cycles",
            prog.name
        );
        let pc = core.pc;
        let before = core.regs;
        let intent = core.begin_cycle(prog, &pre, &mut warm);
        match intent {
            Intent::Mem(req) => {
                let bytes = u64::from(req.size.bytes());
                let t = touches[pc].get_or_insert(PcTouch {
                    count: 0,
                    bytes: 0,
                    min_addr: req.addr,
                    max_addr: req.addr,
                    write: req.write,
                    uniform: Some(req.addr),
                });
                t.count += 1;
                t.bytes += bytes;
                t.min_addr = t.min_addr.min(req.addr);
                t.max_addr = t.max_addr.max(req.addr);
                if t.uniform != Some(req.addr) {
                    t.uniform = None;
                }
                executed[pc] = true;
                core.retire_mem(&pre, mem);
            }
            Intent::Fp { .. } => {
                executed[pc] = true;
                core.retire_fp(&pre);
            }
            Intent::Barrier => {
                executed[pc] = true;
                core.release_barrier();
            }
            Intent::Stalled => {
                // Identical clamped drain to run_single_regs.
                let b = core.busy_cycles().min(max_cycles.saturating_sub(core.stats.cycles));
                if b > 0 {
                    core.skip_stall_cycles(b);
                }
            }
            Intent::Retired | Intent::Halted => {
                executed[pc] = true;
            }
        }
        for r in 1..32 {
            if core.regs[r] != before[r] {
                regs_written |= 1 << r;
            }
        }
    }

    ExecTrace { stats: core.stats.clone(), regs: core.regs, executed, mem: touches, regs_written }
}

/// Debug-harness sanity check: the traced run must be bit-identical to
/// the production runner on stats and the final register file.
pub fn assert_trace_matches(
    prog: &Program,
    mem_a: &mut dyn Memory,
    mem_b: &mut dyn Memory,
    init: &[(Reg, u32)],
    max_cycles: u64,
) -> ExecTrace {
    let trace = run_single_traced(prog, mem_a, init, max_cycles);
    let (stats, regs) = run_single_regs(prog, mem_b, init, max_cycles);
    assert_eq!(trace.stats, stats, "traced stats diverge on {}", prog.name);
    assert_eq!(trace.regs, regs, "traced regfile diverges on {}", prog.name);
    trace
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::iss::FlatMem;
    use crate::isa::{Asm, A0, A1, T0};

    #[test]
    fn trace_is_production_run_plus_observation() {
        // Loops, loads, stores, fp, a branch: every intent arm exercised.
        let mut a = Asm::new("t");
        let end = a.label();
        let skip = a.label();
        a.li(A0, 0);
        a.lp_setup_imm(0, 4, end);
        a.lw_pi(T0, A1, 4);
        a.mac(A0, T0, T0);
        a.bind(end);
        a.fdiv_s(T0, A0, A0);
        a.beq(A0, 0, skip);
        a.sw(A0, A1, 0);
        a.bind(skip);
        a.halt();
        let p = a.finish().unwrap();
        let mut m1 = FlatMem::new(0, 256);
        let mut m2 = FlatMem::new(0, 256);
        m1.write_i32s(0, &[1, 2, 3, 4]);
        m2.write_i32s(0, &[1, 2, 3, 4]);
        let trace = assert_trace_matches(&p, &mut m1, &mut m2, &[(A1, 0)], 100_000);
        assert_eq!(m1.data, m2.data, "traced memory diverges");

        // The load at pc 2 ran 4 times over 4 distinct addresses.
        let t = trace.mem[2].expect("load touch");
        assert_eq!(t.count, 4);
        assert_eq!(t.bytes, 16);
        assert_eq!((t.min_addr, t.max_addr), (0, 12));
        assert_eq!(t.uniform, None);
        assert!(!t.write);
        // The store at pc 6 ran once at one address.
        let s = trace.mem[6].expect("store touch");
        assert_eq!((s.count, s.uniform, s.write), (1, Some(0), true));
        assert_eq!(trace.touched_bytes(), (16, 4));
        assert_eq!(trace.stats.bytes_loaded, 16);
        assert_eq!(trace.stats.bytes_stored, 4);

        assert!(trace.executed.iter().all(|&x| x), "every pc issues here");
        // A0 (mac), A1 (post-inc), T0 (load + fdiv) all changed.
        assert_eq!(trace.regs_written & (1 << A0 | 1 << A1 | 1 << T0), 1 << A0 | 1 << A1 | 1 << T0);
        assert_eq!(trace.regs_written & 1, 0, "x0 never changes");
    }

    #[test]
    fn skipped_branch_arm_is_not_executed() {
        let mut a = Asm::new("t");
        let skip = a.label();
        a.li(A0, 0);
        a.beq(A0, 0, skip); // always taken
        a.li(A1, 7); // never issues
        a.bind(skip);
        a.halt();
        let p = a.finish().unwrap();
        let mut m = FlatMem::new(0, 64);
        let trace = run_single_traced(&p, &mut m, &[], 10_000);
        assert!(!trace.executed[2]);
        assert_eq!(trace.regs_written & (1 << A1), 0);
    }
}
