//! Pure functional semantics of every instruction (no timing here).

use crate::isa::inst::{AluOp, Cond, FpFmt, FpOp, SimdFmt, SimdOp};

use super::softfloat as sf;

/// Evaluate a register-register ALU op.
pub fn alu(op: AluOp, a: u32, b: u32) -> u32 {
    let (ia, ib) = (a as i32, b as i32);
    match op {
        AluOp::Add => a.wrapping_add(b),
        AluOp::Sub => a.wrapping_sub(b),
        AluOp::Sll => a.wrapping_shl(b & 31),
        AluOp::Srl => a.wrapping_shr(b & 31),
        AluOp::Sra => (ia.wrapping_shr(b & 31)) as u32,
        AluOp::And => a & b,
        AluOp::Or => a | b,
        AluOp::Xor => a ^ b,
        AluOp::Slt => (ia < ib) as u32,
        AluOp::Sltu => (a < b) as u32,
        AluOp::Mul => a.wrapping_mul(b),
        AluOp::Mulh => (((ia as i64) * (ib as i64)) >> 32) as u32,
        AluOp::Div => {
            if ib == 0 {
                u32::MAX
            } else if ia == i32::MIN && ib == -1 {
                ia as u32
            } else {
                (ia / ib) as u32
            }
        }
        AluOp::Divu => {
            if b == 0 {
                u32::MAX
            } else {
                a / b
            }
        }
        AluOp::Rem => {
            if ib == 0 {
                a
            } else if ia == i32::MIN && ib == -1 {
                0
            } else {
                (ia % ib) as u32
            }
        }
        AluOp::Remu => {
            if b == 0 {
                a
            } else {
                a % b
            }
        }
        AluOp::Min => ia.min(ib) as u32,
        AluOp::Max => ia.max(ib) as u32,
        AluOp::Abs => ia.unsigned_abs(),
        // p.clip: b is the bit count; clamp to [-2^b, 2^b - 1].
        AluOp::Clip => {
            let bits = b.min(31);
            let lo = -(1i32 << bits);
            let hi = (1i32 << bits) - 1;
            ia.clamp(lo, hi) as u32
        }
    }
}

/// Evaluate a branch condition.
pub fn branch_taken(cond: Cond, a: u32, b: u32) -> bool {
    let (ia, ib) = (a as i32, b as i32);
    match cond {
        Cond::Eq => a == b,
        Cond::Ne => a != b,
        Cond::Lt => ia < ib,
        Cond::Ge => ia >= ib,
        Cond::Ltu => a < b,
        Cond::Geu => a >= b,
    }
}

fn lanes_b(x: u32) -> [i32; 4] {
    [
        x as u8 as i8 as i32,
        (x >> 8) as u8 as i8 as i32,
        (x >> 16) as u8 as i8 as i32,
        (x >> 24) as u8 as i8 as i32,
    ]
}

fn pack_b(l: [i32; 4]) -> u32 {
    (l[0] as u8 as u32)
        | ((l[1] as u8 as u32) << 8)
        | ((l[2] as u8 as u32) << 16)
        | ((l[3] as u8 as u32) << 24)
}

fn lanes_h(x: u32) -> [i32; 2] {
    [x as u16 as i16 as i32, (x >> 16) as u16 as i16 as i32]
}

fn pack_h(l: [i32; 2]) -> u32 {
    (l[0] as u16 as u32) | ((l[1] as u16 as u32) << 16)
}

/// Evaluate a packed-SIMD integer op. `acc` is the previous value of rd
/// (used by the accumulating dot products).
pub fn simd(op: SimdOp, fmt: SimdFmt, a: u32, b: u32, acc: u32) -> u32 {
    match (op, fmt) {
        (SimdOp::SDotSp, SimdFmt::B4) => {
            let (la, lb) = (lanes_b(a), lanes_b(b));
            let dot: i32 = la.iter().zip(&lb).map(|(x, y)| x * y).sum();
            (acc as i32).wrapping_add(dot) as u32
        }
        (SimdOp::SDotSp, SimdFmt::H2) => {
            let (la, lb) = (lanes_h(a), lanes_h(b));
            let dot: i32 = la.iter().zip(&lb).map(|(x, y)| x * y).sum();
            (acc as i32).wrapping_add(dot) as u32
        }
        (SimdOp::SDotUp, SimdFmt::B4) => {
            // unsigned a lanes × signed b lanes
            let la = [a & 0xFF, (a >> 8) & 0xFF, (a >> 16) & 0xFF, (a >> 24) & 0xFF];
            let lb = lanes_b(b);
            let dot: i32 = la.iter().zip(&lb).map(|(&x, &y)| x as i32 * y).sum();
            (acc as i32).wrapping_add(dot) as u32
        }
        (SimdOp::SDotUp, SimdFmt::H2) => {
            let la = [a & 0xFFFF, (a >> 16) & 0xFFFF];
            let lb = lanes_h(b);
            let dot: i32 = la.iter().zip(&lb).map(|(&x, &y)| x as i32 * y).sum();
            (acc as i32).wrapping_add(dot) as u32
        }
        (op, SimdFmt::B4) => {
            let (la, lb) = (lanes_b(a), lanes_b(b));
            let mut out = [0i32; 4];
            for i in 0..4 {
                out[i] = lane_scalar(op, la[i], lb[i]);
            }
            pack_b(out)
        }
        (op, SimdFmt::H2) => {
            let (la, lb) = (lanes_h(a), lanes_h(b));
            let mut out = [0i32; 2];
            for i in 0..2 {
                out[i] = lane_scalar(op, la[i], lb[i]);
            }
            pack_h(out)
        }
    }
}

fn lane_scalar(op: SimdOp, a: i32, b: i32) -> i32 {
    match op {
        SimdOp::Add => a.wrapping_add(b),
        SimdOp::Sub => a.wrapping_sub(b),
        SimdOp::Min => a.min(b),
        SimdOp::Max => a.max(b),
        SimdOp::Avg => (a + b) >> 1,
        SimdOp::Pack => (a & 0xFFFF) | (b << 16),
        SimdOp::SDotSp | SimdOp::SDotUp => unreachable!("handled above"),
    }
}

/// Evaluate an FP op. `acc` is the previous rd value (accumulator for
/// Madd/Msub/DotpEx; pack partner for CvtSH2).
pub fn fp(op: FpOp, fmt: FpFmt, a: u32, b: u32, acc: u32) -> u32 {
    match fmt {
        FpFmt::S => fp_scalar_f32(op, a, b, acc),
        FpFmt::H => fp_scalar_h(op, a, b, acc),
        FpFmt::B => fp_scalar_bf(op, a, b, acc),
        FpFmt::VH => fp_vec_h(op, a, b, acc),
        FpFmt::VB => fp_vec_bf(op, a, b, acc),
        FpFmt::VB4 => fp_vec_f8(op, a, b, acc),
    }
}

fn scalar_op(op: FpOp, x: f32, y: f32, acc: f32) -> f32 {
    match op {
        FpOp::Add => x + y,
        FpOp::Sub => x - y,
        FpOp::Mul => x * y,
        FpOp::Madd => x.mul_add(y, acc),
        FpOp::Msub => acc - x * y,
        FpOp::Min => x.min(y),
        FpOp::Max => x.max(y),
        FpOp::Div => x / y,
        FpOp::Sqrt => x.sqrt(),
        FpOp::Abs => x.abs(),
        FpOp::Neg => -x,
        _ => unreachable!("non-arithmetic op in scalar_op"),
    }
}

fn fp_scalar_f32(op: FpOp, a: u32, b: u32, acc: u32) -> u32 {
    let (x, y, z) = (f32::from_bits(a), f32::from_bits(b), f32::from_bits(acc));
    match op {
        FpOp::CmpLt => return (x < y) as u32,
        FpOp::CmpLe => return (x <= y) as u32,
        FpOp::CmpEq => return (x == y) as u32,
        FpOp::CvtIF => return ((a as i32) as f32).to_bits(),
        FpOp::CvtFI => {
            let v = f32::from_bits(a);
            return if v.is_nan() { 0 } else { (v as i64).clamp(i32::MIN as i64, i32::MAX as i64) as i32 as u32 };
        }
        _ => {}
    }
    scalar_op(op, x, y, z).to_bits()
}

fn fp_scalar_h(op: FpOp, a: u32, b: u32, acc: u32) -> u32 {
    let x = sf::f16_to_f32(a as u16);
    let y = sf::f16_to_f32(b as u16);
    let z = sf::f16_to_f32(acc as u16);
    match op {
        FpOp::CmpLt => return (x < y) as u32,
        FpOp::CmpLe => return (x <= y) as u32,
        FpOp::CmpEq => return (x == y) as u32,
        FpOp::CvtIF => return sf::f32_to_f16((a as i32) as f32) as u32,
        FpOp::CvtFI => return (x as i32) as u32,
        _ => {}
    }
    sf::f32_to_f16(scalar_op(op, x, y, z)) as u32
}

fn fp_scalar_bf(op: FpOp, a: u32, b: u32, acc: u32) -> u32 {
    let x = sf::bf16_to_f32(a as u16);
    let y = sf::bf16_to_f32(b as u16);
    let z = sf::bf16_to_f32(acc as u16);
    match op {
        FpOp::CmpLt => return (x < y) as u32,
        FpOp::CmpLe => return (x <= y) as u32,
        FpOp::CmpEq => return (x == y) as u32,
        _ => {}
    }
    sf::f32_to_bf16(scalar_op(op, x, y, z)) as u32
}

fn fp_vec_h(op: FpOp, a: u32, b: u32, acc: u32) -> u32 {
    match op {
        FpOp::Madd => sf::f16_lanes_fma(a, b, acc),
        FpOp::DotpEx => sf::f16_dotpex_s(a, b, acc),
        // cast-and-pack: rd = pack(f16(rs1_f32), f16(rs2_f32))
        FpOp::CvtSH2 => {
            let lo = sf::f32_to_f16(f32::from_bits(a)) as u32;
            let hi = sf::f32_to_f16(f32::from_bits(b)) as u32;
            (hi << 16) | lo
        }
        FpOp::CvtH2S0 => sf::f16_to_f32(a as u16).to_bits(),
        FpOp::CvtH2S1 => sf::f16_to_f32((a >> 16) as u16).to_bits(),
        FpOp::Add => sf::f16_lanes_op(a, b, |x, y| x + y),
        FpOp::Sub => sf::f16_lanes_op(a, b, |x, y| x - y),
        FpOp::Mul => sf::f16_lanes_op(a, b, |x, y| x * y),
        FpOp::Min => sf::f16_lanes_op(a, b, f32::min),
        FpOp::Max => sf::f16_lanes_op(a, b, f32::max),
        other => unreachable!("unsupported packed-f16 op {other:?}"),
    }
}

fn fp_vec_bf(op: FpOp, a: u32, b: u32, acc: u32) -> u32 {
    let lane = |h: u16| sf::bf16_to_f32(h);
    let lo_a = lane(a as u16);
    let hi_a = lane((a >> 16) as u16);
    let lo_b = lane(b as u16);
    let hi_b = lane((b >> 16) as u16);
    match op {
        FpOp::DotpEx => {
            (lo_a * lo_b + hi_a * hi_b + f32::from_bits(acc)).to_bits()
        }
        FpOp::Madd => {
            let lo = sf::f32_to_bf16(lo_a * lo_b + lane(acc as u16)) as u32;
            let hi = sf::f32_to_bf16(hi_a * hi_b + lane((acc >> 16) as u16)) as u32;
            (hi << 16) | lo
        }
        FpOp::Add | FpOp::Sub | FpOp::Mul | FpOp::Min | FpOp::Max => {
            let f = |x: f32, y: f32| match op {
                FpOp::Add => x + y,
                FpOp::Sub => x - y,
                FpOp::Mul => x * y,
                FpOp::Min => x.min(y),
                _ => x.max(y),
            };
            let lo = sf::f32_to_bf16(f(lo_a, lo_b)) as u32;
            let hi = sf::f32_to_bf16(f(hi_a, hi_b)) as u32;
            (hi << 16) | lo
        }
        other => unreachable!("unsupported packed-bf16 op {other:?}"),
    }
}

fn fp_vec_f8(op: FpOp, a: u32, b: u32, acc: u32) -> u32 {
    match op {
        // The one fp8 SIMD op the kernels use: 4-lane dot product
        // accumulating into an f32 rd (vfdotpex.s.b).
        FpOp::DotpEx => sf::f8x4_dotpex_s(a, b, acc),
        other => unreachable!("unsupported packed-fp8 op {other:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alu_signed_ops() {
        assert_eq!(alu(AluOp::Add, 1, u32::MAX), 0);
        assert_eq!(alu(AluOp::Sra, (-8i32) as u32, 1) as i32, -4);
        assert_eq!(alu(AluOp::Min, (-3i32) as u32, 2), (-3i32) as u32);
        assert_eq!(alu(AluOp::Abs, (-7i32) as u32, 0), 7);
        assert_eq!(alu(AluOp::Clip, 300u32, 7) as i32, 127);
        assert_eq!(alu(AluOp::Clip, (-300i32) as u32, 7) as i32, -128);
    }

    #[test]
    fn div_edge_cases() {
        assert_eq!(alu(AluOp::Div, 7, 0), u32::MAX);
        assert_eq!(alu(AluOp::Div, i32::MIN as u32, (-1i32) as u32), i32::MIN as u32);
        assert_eq!(alu(AluOp::Rem, 7, 0), 7);
        assert_eq!(alu(AluOp::Rem, i32::MIN as u32, (-1i32) as u32), 0);
    }

    #[test]
    fn sdotsp_b4() {
        // lanes a = [1, -2, 3, -4], b = [5, 6, 7, 8]
        let a = pack_b([1, -2, 3, -4]);
        let b = pack_b([5, 6, 7, 8]);
        let acc = 100u32;
        let want = 100 + (5 - 12 + 21 - 32);
        assert_eq!(simd(SimdOp::SDotSp, SimdFmt::B4, a, b, acc) as i32, want);
    }

    #[test]
    fn sdotsp_h2() {
        let a = pack_h([-1000, 2000]);
        let b = pack_h([3, -4]);
        assert_eq!(simd(SimdOp::SDotSp, SimdFmt::H2, a, b, 0) as i32, -3000 - 8000);
    }

    #[test]
    fn simd_lane_add_wraps_per_lane() {
        let a = pack_b([127, 0, 0, 0]);
        let b = pack_b([1, 0, 0, 0]);
        let r = simd(SimdOp::Add, SimdFmt::B4, a, b, 0);
        assert_eq!(lanes_b(r)[0], -128); // i8 wraparound contained in lane
        assert_eq!(lanes_b(r)[1], 0);
    }

    #[test]
    fn fp32_fma() {
        let r = fp(FpOp::Madd, FpFmt::S, 2.0f32.to_bits(), 3.0f32.to_bits(), 10.0f32.to_bits());
        assert_eq!(f32::from_bits(r), 16.0);
        let r = fp(FpOp::Msub, FpFmt::S, 2.0f32.to_bits(), 3.0f32.to_bits(), 10.0f32.to_bits());
        assert_eq!(f32::from_bits(r), 4.0);
    }

    #[test]
    fn fp_compare_and_convert() {
        assert_eq!(fp(FpOp::CmpLt, FpFmt::S, 1.0f32.to_bits(), 2.0f32.to_bits(), 0), 1);
        assert_eq!(fp(FpOp::CvtIF, FpFmt::S, (-5i32) as u32, 0, 0), (-5.0f32).to_bits());
        assert_eq!(fp(FpOp::CvtFI, FpFmt::S, (-5.7f32).to_bits(), 0, 0) as i32, -5);
    }

    #[test]
    fn packed_f16_dotpex_accumulates_in_f32() {
        use crate::iss::softfloat::f32_to_f16;
        let a = ((f32_to_f16(2.0) as u32) << 16) | f32_to_f16(1.0) as u32;
        let b = ((f32_to_f16(4.0) as u32) << 16) | f32_to_f16(3.0) as u32;
        let acc = 0.25f32.to_bits();
        let r = fp(FpOp::DotpEx, FpFmt::VH, a, b, acc);
        assert_eq!(f32::from_bits(r), 3.0 + 8.0 + 0.25);
    }

    #[test]
    fn packed_f8_dotpex_accumulates_in_f32() {
        use crate::iss::softfloat::f32_to_f8;
        // lanes a = [1, 2, 3, 4], b = [0.5, 0.5, 0.5, 0.5]
        let a = (f32_to_f8(1.0) as u32)
            | ((f32_to_f8(2.0) as u32) << 8)
            | ((f32_to_f8(3.0) as u32) << 16)
            | ((f32_to_f8(4.0) as u32) << 24);
        let b = u32::from_le_bytes([f32_to_f8(0.5); 4]);
        let acc = 1.0f32.to_bits();
        let r = fp(FpOp::DotpEx, FpFmt::VB4, a, b, acc);
        assert_eq!(f32::from_bits(r), 0.5 + 1.0 + 1.5 + 2.0 + 1.0);
    }

    #[test]
    fn cast_and_pack_roundtrip() {
        let r = fp(FpOp::CvtSH2, FpFmt::VH, 1.5f32.to_bits(), (-2.0f32).to_bits(), 0);
        assert_eq!(fp(FpOp::CvtH2S0, FpFmt::VH, r, 0, 0), 1.5f32.to_bits());
        assert_eq!(fp(FpOp::CvtH2S1, FpFmt::VH, r, 0, 0), (-2.0f32).to_bits());
    }
}
