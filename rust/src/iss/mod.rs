//! Instruction-set simulator for the RI5CY-class cores.
//!
//! [`Core`] models one 4-pipeline-stage in-order core: single-cycle ALU and
//! FP issue, load-use interlock, taken-branch penalty, 35-cycle serial
//! divider, zero-overhead hardware loops, and packed-SIMD / smallFloat
//! datapaths. Memory and FPU *timing* (bank conflicts, shared-FPU
//! contention) are arbitrated by the owning fabric ([`crate::cluster`]) via
//! a two-phase protocol: [`Core::begin_cycle`] reports the core's
//! [`Intent`] for the cycle, and the fabric answers with
//! [`Core::retire_mem`] / [`Core::retire_fp`] or a denial. The core itself
//! is cycle-accurate for everything private to it.
//!
//! # The three execution tiers (§Perf)
//!
//! The same instruction semantics run at three speeds, each held
//! bit-identical to the one below it by `tests/scheduler_equivalence.rs`:
//!
//! 1. **Reference scheduler** (`SchedulerMode::Reference`) — the retained
//!    one-cycle-per-loop-iteration cluster driver; the oracle.
//! 2. **Fast interpreter** (`SchedulerMode::CycleSkip`, the default) —
//!    the same per-cycle core model driven through the predecoded
//!    side-table ([`crate::isa::predecode`]), with pure-stall windows
//!    skipped in one step.
//! 3. **Superblock replay** ([`superblock`]) — straight-line hardware-loop
//!    bodies promoted to cached traces and replayed N iterations at a
//!    time when the dynamic entry conditions match; any mismatch falls
//!    back to tier 2 (`VEGA_SUPERBLOCKS=off` disables the tier).
//!
//! See `PERFORMANCE.md` at the repo root for how the tiers compose with
//! the caching layers above them.

pub mod core;
pub mod exec;
pub mod softfloat;
pub mod stats;
pub mod superblock;
pub mod trace;

pub use self::core::{Core, CoreState, Intent, MemReq};
pub use stats::CoreStats;
pub use trace::{run_single_traced, ExecTrace, PcTouch};

use crate::isa::MemSize;

/// Functional memory interface presented to a core (timing lives in the
/// fabric; this is data only).
pub trait Memory {
    fn load(&mut self, addr: u32, size: MemSize) -> u32;
    fn store(&mut self, addr: u32, size: MemSize, value: u32);
}

/// A flat little-endian memory region starting at `base`.
pub struct FlatMem {
    pub base: u32,
    pub data: Vec<u8>,
}

impl FlatMem {
    pub fn new(base: u32, size: usize) -> Self {
        Self { base, data: vec![0; size] }
    }

    /// Zero the contents in place, keeping the allocation (§Perf: drivers
    /// reuse one region across kernel invocations instead of re-allocating
    /// megabytes per run).
    pub fn reset(&mut self) {
        self.data.fill(0);
    }

    fn off(&self, addr: u32) -> usize {
        debug_assert!(
            addr >= self.base && ((addr - self.base) as usize) < self.data.len(),
            "address {addr:#x} outside [{:#x}, {:#x})",
            self.base,
            self.base as usize + self.data.len()
        );
        (addr - self.base) as usize
    }

    pub fn write_bytes(&mut self, addr: u32, bytes: &[u8]) {
        let o = self.off(addr);
        self.data[o..o + bytes.len()].copy_from_slice(bytes);
    }

    /// Flip one bit of the byte at `addr`: the SRAM soft-error injection
    /// hook (ISSUE 6). Plain unprotected SRAM — no ECC stands between an
    /// upset here and the consumer, which is exactly what the fault
    /// campaigns measure. Zero-cost when unused: nothing else in the
    /// load/store path changes.
    pub fn flip_bit(&mut self, addr: u32, bit: u8) {
        let o = self.off(addr);
        self.data[o] ^= 1 << (bit & 7);
    }

    pub fn read_bytes(&self, addr: u32, len: usize) -> &[u8] {
        let o = self.off(addr);
        &self.data[o..o + len]
    }

    pub fn write_i8s(&mut self, addr: u32, vals: &[i8]) {
        let bytes: Vec<u8> = vals.iter().map(|&v| v as u8).collect();
        self.write_bytes(addr, &bytes);
    }

    pub fn write_i32s(&mut self, addr: u32, vals: &[i32]) {
        for (i, &v) in vals.iter().enumerate() {
            self.write_bytes(addr + (i * 4) as u32, &v.to_le_bytes());
        }
    }

    pub fn write_f32s(&mut self, addr: u32, vals: &[f32]) {
        for (i, &v) in vals.iter().enumerate() {
            self.write_bytes(addr + (i * 4) as u32, &v.to_le_bytes());
        }
    }

    pub fn write_f16s(&mut self, addr: u32, vals: &[f32]) {
        for (i, &v) in vals.iter().enumerate() {
            let h = softfloat::f32_to_f16(v);
            self.write_bytes(addr + (i * 2) as u32, &h.to_le_bytes());
        }
    }

    pub fn write_f8s(&mut self, addr: u32, vals: &[f32]) {
        for (i, &v) in vals.iter().enumerate() {
            self.write_bytes(addr + i as u32, &[softfloat::f32_to_f8(v)]);
        }
    }

    pub fn read_i32s(&self, addr: u32, n: usize) -> Vec<i32> {
        (0..n)
            .map(|i| {
                let b = self.read_bytes(addr + (i * 4) as u32, 4);
                i32::from_le_bytes([b[0], b[1], b[2], b[3]])
            })
            .collect()
    }

    pub fn read_i8s(&self, addr: u32, n: usize) -> Vec<i8> {
        self.read_bytes(addr, n).iter().map(|&b| b as i8).collect()
    }

    pub fn read_f32s(&self, addr: u32, n: usize) -> Vec<f32> {
        (0..n)
            .map(|i| {
                let b = self.read_bytes(addr + (i * 4) as u32, 4);
                f32::from_le_bytes([b[0], b[1], b[2], b[3]])
            })
            .collect()
    }

    pub fn read_f16s(&self, addr: u32, n: usize) -> Vec<f32> {
        (0..n)
            .map(|i| {
                let b = self.read_bytes(addr + (i * 2) as u32, 2);
                softfloat::f16_to_f32(u16::from_le_bytes([b[0], b[1]]))
            })
            .collect()
    }

    pub fn read_f8s(&self, addr: u32, n: usize) -> Vec<f32> {
        self.read_bytes(addr, n).iter().map(|&b| softfloat::f8_to_f32(b)).collect()
    }
}

impl Memory for FlatMem {
    fn load(&mut self, addr: u32, size: MemSize) -> u32 {
        let o = self.off(addr);
        match size {
            MemSize::B => self.data[o] as i8 as i32 as u32,
            MemSize::Bu => self.data[o] as u32,
            MemSize::H => {
                i16::from_le_bytes([self.data[o], self.data[o + 1]]) as i32 as u32
            }
            MemSize::Hu => u16::from_le_bytes([self.data[o], self.data[o + 1]]) as u32,
            MemSize::W => u32::from_le_bytes([
                self.data[o],
                self.data[o + 1],
                self.data[o + 2],
                self.data[o + 3],
            ]),
        }
    }

    fn store(&mut self, addr: u32, size: MemSize, value: u32) {
        let o = self.off(addr);
        match size {
            MemSize::B | MemSize::Bu => self.data[o] = value as u8,
            MemSize::H | MemSize::Hu => {
                self.data[o..o + 2].copy_from_slice(&(value as u16).to_le_bytes())
            }
            MemSize::W => self.data[o..o + 4].copy_from_slice(&value.to_le_bytes()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flatmem_rw_roundtrip() {
        let mut m = FlatMem::new(0x1000_0000, 64);
        m.store(0x1000_0000, MemSize::W, 0xDEAD_BEEF);
        assert_eq!(m.load(0x1000_0000, MemSize::W), 0xDEAD_BEEF);
        assert_eq!(m.load(0x1000_0000, MemSize::Bu), 0xEF);
        assert_eq!(m.load(0x1000_0003, MemSize::B), 0xDEu8 as i8 as i32 as u32);
        m.store(0x1000_0004, MemSize::H, 0xFFFF_8001);
        assert_eq!(m.load(0x1000_0004, MemSize::H), 0xFFFF_8001);
        assert_eq!(m.load(0x1000_0004, MemSize::Hu), 0x8001);
    }

    #[test]
    fn flatmem_typed_helpers() {
        let mut m = FlatMem::new(0, 64);
        m.write_i32s(0, &[-1, 2, 3]);
        assert_eq!(m.read_i32s(0, 3), vec![-1, 2, 3]);
        m.write_i8s(16, &[-128, 127]);
        assert_eq!(m.read_i8s(16, 2), vec![-128, 127]);
        m.write_f32s(24, &[1.5, -2.5]);
        assert_eq!(m.read_f32s(24, 2), vec![1.5, -2.5]);
        m.write_f16s(32, &[0.5, -0.25]);
        assert_eq!(m.read_f16s(32, 2), vec![0.5, -0.25]);
        m.write_f8s(40, &[1.5, -0.25, 4.0, -1.0]);
        assert_eq!(m.read_f8s(40, 4), vec![1.5, -0.25, 4.0, -1.0]);
    }
}
