//! Superblock trace replay (§Perf, hot-path layer 3).
//!
//! The predecode pass promotes every straight-line hardware-loop body —
//! the same shape the static analyzer reports as a `SuperblockCandidate`
//! finding — into a [`Superblock`]: an effect list plus an affine summary
//! of every memory access. When the cluster's fast scheduler sees exactly
//! one core able to issue (everyone else halted or parked at a barrier
//! that cannot release), [`try_replay`] checks the *dynamic* entry
//! conditions and, if they hold, executes `k` whole iterations as one
//! batched effect: data writes replayed concretely, timing and statistics
//! committed in closed form from a per-iteration profile walked over the
//! predecoded records.
//!
//! # Entry conditions (any failure counts a bail and falls back)
//!
//! * the loop channel matches the superblock and has ≥ 2 trips left
//!   (the final iteration is always interpreted, so loop-exit bookkeeping
//!   stays on the oracle-verified path);
//! * the body has a closed-form plan (no address base rewritten inside
//!   the body), the other loop channel cannot steal a back edge inside
//!   the window, every body pc is warm in the I$, and the pending-load
//!   interlock state matches the steady-state profile;
//! * every access's affine address range stays inside one memory region
//!   (TCDM or L2) for the whole window, exact in wide arithmetic;
//! * the shared DIV-SQRT unit is free by the window's first issue.
//!
//! # Why the batch is exact
//!
//! With a single requester there is no arbitration: every TCDM access is
//! granted (round-robin pointer parked at `winner + 1`, the same value
//! after every grant), every FPU issue succeeds, and consecutive DIV-SQRT
//! issues are provably spaced by at least their latency (the profile
//! advances past each issue by its full latency, so `cpi` bounds the
//! spacing). The replay window ends exactly where the interpreter would
//! issue the first instruction of the iteration after the window, with
//! `busy = 0` and the profiled pending-load state — so the interpreter
//! resumes mid-loop with no seam. `tests/scheduler_equivalence.rs` holds
//! replay-on runs bit-identical (stats, memory, register files) to
//! replay-off and to the reference scheduler across the whole
//! `verify_targets()` suite.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;

use crate::cluster::{
    FpuFabric, Tcdm, CLUSTER_TO_L2_LATENCY, L2_BASE, L2_SIZE, TCDM_BASE, TCDM_SIZE,
};
use crate::isa::predecode::{PreDecoded, SbMemOp, SbPlan, SbStep, Superblock};

use super::core::Core;
use super::exec;
use super::stats::ClassCounts;
use super::{FlatMem, Memory};

/// Process-wide replay telemetry (`vega repro <id> --stats` prints it):
/// windows replayed, entry-condition bails, iterations batched. Relaxed
/// atomics — diagnostics only, never part of simulation results.
static HITS: AtomicU64 = AtomicU64::new(0);
static BAILS: AtomicU64 = AtomicU64::new(0);
static ITERS: AtomicU64 = AtomicU64::new(0);

/// (windows replayed, bails, iterations batched) since process start.
pub fn counters() -> (u64, u64, u64) {
    (
        HITS.load(Ordering::Relaxed),
        BAILS.load(Ordering::Relaxed),
        ITERS.load(Ordering::Relaxed),
    )
}

/// Process-default for [`crate::cluster::Cluster::superblocks`]:
/// `VEGA_SUPERBLOCKS=off|0|false|no` disables replay (the escape hatch —
/// results are bit-identical either way, only wall-clock changes).
pub fn env_default() -> bool {
    static DEFAULT: OnceLock<bool> = OnceLock::new();
    *DEFAULT.get_or_init(|| match std::env::var("VEGA_SUPERBLOCKS") {
        Ok(v) => !matches!(v.to_ascii_lowercase().as_str(), "off" | "0" | "false" | "no"),
        Err(_) => true,
    })
}

/// Per-iteration timing/statistics profile of one steady-state trip,
/// walked from the predecoded records exactly as `Core::begin_cycle`
/// would spend the cycles.
struct IterProfile {
    /// Cycles from one iteration's first dispatch to the next one's.
    cpi: u64,
    /// Load-use interlock stalls per iteration.
    interlocks: u64,
    /// `multicycle_busy` charged per iteration (ALU/FP latency + L2).
    multicycle: u64,
    int_ops: u64,
    flops: u64,
    bytes_loaded: u64,
    bytes_stored: u64,
    class: ClassCounts,
    /// Pipelined FPU issues per iteration (excludes DIV-SQRT).
    n_fp: u64,
    /// DIV-SQRT issues per iteration, with first/last issue offsets and
    /// the last issue's latency (for the unit's busy horizon).
    n_ds: u64,
    ds_first: u64,
    ds_last: u64,
    ds_last_lat: u64,
    /// TCDM accesses per iteration (each granted: single requester).
    n_tcdm: u64,
}

fn profile(pre: &PreDecoded, sb: &Superblock, plan: &SbPlan, tcdm_op: &[bool]) -> IterProfile {
    let mut p = IterProfile {
        cpi: 0,
        interlocks: 0,
        multicycle: 0,
        int_ops: 0,
        flops: 0,
        bytes_loaded: 0,
        bytes_stored: 0,
        class: ClassCounts::default(),
        n_fp: 0,
        n_ds: 0,
        ds_first: 0,
        ds_last: 0,
        ds_last_lat: 0,
        n_tcdm: 0,
    };
    let mut t = 0u64;
    let mut pending = plan.entry_pending;
    for (j, step) in plan.steps.iter().enumerate() {
        let dec = &pre.recs[sb.body_start + j];
        // Load-use interlock: one stall cycle iff the previous step's
        // load destination is in this instruction's source mask (the
        // interlock test only runs on dispatch cycles, so the pending
        // register survives any busy drain in between — same as the
        // interpreter's take-on-issue semantics).
        if let Some(ld) = pending.take() {
            if dec.src_mask & (1u32 << ld) != 0 {
                t += 1;
                p.interlocks += 1;
            }
        }
        let issue_at = t;
        t += 1;
        p.class.bump(dec.class);
        p.int_ops += dec.int_ops;
        p.flops += dec.flops;
        match *step {
            SbStep::Mem { write, reg, op_idx, .. } => {
                let bytes = u64::from(plan.mem_ops[op_idx as usize].bytes);
                if tcdm_op[op_idx as usize] {
                    p.n_tcdm += 1;
                } else {
                    t += CLUSTER_TO_L2_LATENCY;
                    p.multicycle += CLUSTER_TO_L2_LATENCY;
                }
                if write {
                    p.bytes_stored += bytes;
                } else {
                    p.bytes_loaded += bytes;
                    pending = Some(reg);
                }
            }
            SbStep::Alu { extra, .. } | SbStep::AluImm { extra, .. } => {
                t += extra;
                p.multicycle += extra;
            }
            SbStep::Fp { extra, divsqrt, .. } => {
                if divsqrt {
                    if p.n_ds == 0 {
                        p.ds_first = issue_at;
                    }
                    p.n_ds += 1;
                    p.ds_last = issue_at;
                    p.ds_last_lat = extra + 1;
                }
                if !divsqrt {
                    p.n_fp += 1;
                }
                t += extra;
                p.multicycle += extra;
            }
            SbStep::Li { .. }
            | SbStep::Mac { .. }
            | SbStep::Msu { .. }
            | SbStep::Simd { .. }
            | SbStep::Nop => {}
        }
    }
    p.cpi = t;
    p
}

/// Classify every access's address range over `k` iterations: `true` for
/// TCDM-resident, `false` for L2-resident, `None` (bail) when a range
/// leaves both regions or would wrap. Affine addresses are monotone in
/// the iteration index, so checking both endpoints (in `i128`, exact)
/// bounds every access in between.
fn classify_regions(plan: &SbPlan, regs: &[u32; 32], k: u64, out: &mut Vec<bool>) -> bool {
    const TCDM_LO: i128 = TCDM_BASE as i128;
    const TCDM_HI: i128 = TCDM_BASE as i128 + TCDM_SIZE as i128;
    const L2_LO: i128 = L2_BASE as i128;
    const L2_HI: i128 = L2_BASE as i128 + L2_SIZE as i128;
    out.clear();
    for op in &plan.mem_ops {
        let a0 = i128::from(regs[op.rs1 as usize]) + i128::from(op.offset);
        let alast = a0 + (i128::from(k) - 1) * i128::from(op.stride);
        let (lo, hi) = if a0 <= alast { (a0, alast) } else { (alast, a0) };
        let hi = hi + i128::from(op.bytes) - 1;
        if lo < 0 || hi > u32::MAX as i128 {
            return false;
        }
        if lo >= TCDM_LO && hi < TCDM_HI {
            out.push(true);
        } else if lo >= L2_LO && hi < L2_HI {
            out.push(false);
        } else {
            return false;
        }
    }
    true
}

/// Banks touched by one TCDM-resident access over `k` iterations.
/// `bank_of` depends only on `addr mod 64`, which is periodic in the
/// iteration index with period ≤ 64 — enumerating `min(k, 64)`
/// iterations covers the full orbit.
fn touched_banks(op: &SbMemOp, regs: &[u32; 32], k: u64) -> u16 {
    let a0 = i128::from(regs[op.rs1 as usize]) + i128::from(op.offset);
    let mut m = 0u16;
    for i in 0..k.min(64) {
        let a = (a0 + i128::from(i) * i128::from(op.stride)) as u32;
        m |= 1u16 << Tcdm::bank_of(a);
    }
    m
}

fn bail() -> Option<u64> {
    BAILS.fetch_add(1, Ordering::Relaxed);
    None
}

/// Attempt to replay a superblock window on `core`, which the caller
/// guarantees is the only core able to issue this cycle (no arbitration).
/// Returns the window length in cycles (stats, registers, memory, loop
/// state and fabric bookkeeping already committed), or `None` to let the
/// interpreter proceed. A `None` on a genuine candidate counts a bail;
/// "not at a replayable loop entry at all" stays silent.
pub(crate) fn try_replay(
    pre: &PreDecoded,
    core: &mut Core,
    tcdm: &mut Tcdm,
    l2: &mut FlatMem,
    fpus: &mut FpuFabric,
    cycle: u64,
    max_cycles: u64,
) -> Option<u64> {
    let sb_idx = (*pre.sb_at.get(core.pc)?)?;
    let sb = &pre.superblocks[sb_idx as usize];
    let ch = sb.lp as usize;
    let lp = core.loops[ch];
    if lp.remaining < 2 || lp.start != sb.body_start || lp.end != sb.body_end {
        // Final trip, dead channel, or a different loop configured on the
        // same channel: the interpreter path is already the right one.
        return None;
    }
    let Some(plan) = &sb.plan else {
        return bail();
    };
    // The other loop channel must not be able to steal a back edge
    // inside the body. An lp0 back edge at the shared body end outranks
    // a replayed lp1 (the core checks lp0 first); the converse is safe
    // because a replayed lp0 with trips left returns before lp1 is
    // consulted.
    let other = core.loops[1 - ch];
    if other.remaining > 0 {
        let mid_body = other.end > sb.body_start && other.end < sb.body_end;
        let outranked = ch == 1 && other.end == sb.body_end;
        if mid_body || outranked {
            return bail();
        }
    }
    if core.pending_load != plan.entry_pending {
        // First arrival after LpSetup when the body ends in a load: the
        // steady-state interlock profile doesn't hold yet. One
        // interpreted iteration establishes it.
        return bail();
    }
    if !core.seen[sb.body_start..sb.body_end].iter().all(|&s| s) {
        // Cold I$ lines in the body: let the interpreter pay the
        // compulsory misses, then replay from the next entry.
        return bail();
    }
    let k_max = u64::from(lp.remaining - 1);
    let mut regions = Vec::with_capacity(plan.mem_ops.len());
    if !classify_regions(plan, &core.regs, k_max, &mut regions) {
        return bail();
    }
    let prof = profile(pre, sb, plan, &regions);
    debug_assert!(prof.cpi >= plan.steps.len() as u64);
    if prof.n_ds > 0 && fpus.divsqrt_free_at() > cycle + prof.ds_first {
        return bail();
    }
    let k = k_max.min((max_cycles - cycle) / prof.cpi);
    if k == 0 {
        return bail();
    }

    // Bank footprint from the *entry* register values (the replay below
    // mutates the address bases).
    let mut banks = 0u16;
    if prof.n_tcdm > 0 {
        for (op, &is_tcdm) in plan.mem_ops.iter().zip(&regions) {
            if is_tcdm {
                banks |= touched_banks(op, &core.regs, k);
            }
        }
    }

    // ---- Execute k iterations of concrete data effects. ----
    let regs = &mut core.regs;
    for _ in 0..k {
        for step in &plan.steps {
            match *step {
                SbStep::Alu { op, rd, rs1, rs2, .. } => {
                    let v = exec::alu(op, regs[rs1 as usize], regs[rs2 as usize]);
                    if rd != 0 {
                        regs[rd as usize] = v;
                    }
                }
                SbStep::AluImm { op, rd, rs1, imm, .. } => {
                    let v = exec::alu(op, regs[rs1 as usize], imm as u32);
                    if rd != 0 {
                        regs[rd as usize] = v;
                    }
                }
                SbStep::Li { rd, imm } => {
                    if rd != 0 {
                        regs[rd as usize] = imm as u32;
                    }
                }
                SbStep::Mac { rd, rs1, rs2 } => {
                    let v = (regs[rd as usize] as i32).wrapping_add(
                        (regs[rs1 as usize] as i32).wrapping_mul(regs[rs2 as usize] as i32),
                    );
                    if rd != 0 {
                        regs[rd as usize] = v as u32;
                    }
                }
                SbStep::Msu { rd, rs1, rs2 } => {
                    let v = (regs[rd as usize] as i32).wrapping_sub(
                        (regs[rs1 as usize] as i32).wrapping_mul(regs[rs2 as usize] as i32),
                    );
                    if rd != 0 {
                        regs[rd as usize] = v as u32;
                    }
                }
                SbStep::Simd { op, fmt, rd, rs1, rs2 } => {
                    let v = exec::simd(
                        op,
                        fmt,
                        regs[rs1 as usize],
                        regs[rs2 as usize],
                        regs[rd as usize],
                    );
                    if rd != 0 {
                        regs[rd as usize] = v;
                    }
                }
                SbStep::Fp { op, fmt, rd, rs1, rs2, .. } => {
                    let v = exec::fp(
                        op,
                        fmt,
                        regs[rs1 as usize],
                        regs[rs2 as usize],
                        regs[rd as usize],
                    );
                    if rd != 0 {
                        regs[rd as usize] = v;
                    }
                }
                SbStep::Mem { write, size, reg, rs1, imm, post_inc, op_idx } => {
                    let addr = if post_inc {
                        regs[rs1 as usize]
                    } else {
                        regs[rs1 as usize].wrapping_add(imm as u32)
                    };
                    let mem: &mut FlatMem =
                        if regions[op_idx as usize] { &mut tcdm.mem } else { &mut *l2 };
                    if write {
                        mem.store(addr, size, regs[reg as usize]);
                    } else {
                        let v = mem.load(addr, size);
                        if reg != 0 {
                            regs[reg as usize] = v;
                        }
                    }
                    if post_inc && rs1 != 0 {
                        regs[rs1 as usize] = regs[rs1 as usize].wrapping_add(imm as u32);
                    }
                }
                SbStep::Nop => {}
            }
        }
    }

    // ---- Commit timing, statistics and fabric bookkeeping. ----
    let w = k * prof.cpi;
    let s = &mut core.stats;
    s.cycles += w;
    s.retired += plan.steps.len() as u64 * k;
    s.int_ops += prof.int_ops * k;
    s.flops += prof.flops * k;
    s.bytes_loaded += prof.bytes_loaded * k;
    s.bytes_stored += prof.bytes_stored * k;
    s.stall_loaduse += prof.interlocks * k;
    s.multicycle_busy += prof.multicycle * k;
    s.by_class.add_scaled(&prof.class, k);
    core.loops[ch].remaining -= k as u32;
    core.pending_load = plan.entry_pending;
    if prof.n_tcdm > 0 {
        tcdm.replay_commit(prof.n_tcdm * k, banks, core.id);
    }
    if prof.n_fp + prof.n_ds > 0 {
        let ds_free = (prof.n_ds > 0)
            .then(|| cycle + (k - 1) * prof.cpi + prof.ds_last + prof.ds_last_lat);
        fpus.replay_commit((prof.n_fp + prof.n_ds) * k, prof.n_fp > 0, core.id, ds_free);
    }
    HITS.fetch_add(1, Ordering::Relaxed);
    ITERS.fetch_add(k, Ordering::Relaxed);
    Some(w)
}
