//! Per-core performance counters.
//!
//! These back every performance number in the reproduction: GOPS/GFLOPS
//! come from `int_ops`/`flops` over `cycles`; Table V's FP intensity from
//! the per-class retire counts; the stall breakdown validates the
//! microarchitectural claims (TCDM contention < 10%, FPU sharing not
//! detrimental).

use crate::isa::InstClass;

#[derive(Debug, Clone, Default, PartialEq)]
pub struct CoreStats {
    /// Cycles this core was powered in the measured region.
    pub cycles: u64,
    /// Instructions retired.
    pub retired: u64,
    /// Retired, by class.
    pub by_class: ClassCounts,
    /// Integer operations (paper metric: 1 MAC = 2 ops).
    pub int_ops: u64,
    /// Floating-point operations (1 FMA = 2 FLOPs).
    pub flops: u64,
    /// Bytes moved to/from memory by this core.
    pub bytes_loaded: u64,
    pub bytes_stored: u64,
    /// Stall cycles by cause.
    pub stall_loaduse: u64,
    pub stall_tcdm: u64,
    pub stall_fpu: u64,
    pub stall_divsqrt: u64,
    pub stall_icache: u64,
    pub stall_barrier: u64,
    /// Taken-branch/jump penalty cycles.
    pub branch_penalty: u64,
    /// Multi-cycle op busy cycles (div, sqrt).
    pub multicycle_busy: u64,
}

#[derive(Debug, Clone, Default, PartialEq)]
pub struct ClassCounts {
    pub alu: u64,
    pub mul: u64,
    pub div: u64,
    pub load: u64,
    pub store: u64,
    pub branch: u64,
    pub fp: u64,
    pub simd: u64,
    pub control: u64,
}

impl ClassCounts {
    pub fn bump(&mut self, c: InstClass) {
        match c {
            InstClass::Alu => self.alu += 1,
            InstClass::Mul => self.mul += 1,
            InstClass::Div => self.div += 1,
            InstClass::Load => self.load += 1,
            InstClass::Store => self.store += 1,
            InstClass::Branch => self.branch += 1,
            InstClass::Fp => self.fp += 1,
            InstClass::Simd => self.simd += 1,
            InstClass::Control => self.control += 1,
        }
    }

    /// Add `k` copies of another count set in one step (the superblock
    /// replay path commits `k` identical loop iterations at once).
    pub fn add_scaled(&mut self, o: &ClassCounts, k: u64) {
        self.alu += o.alu * k;
        self.mul += o.mul * k;
        self.div += o.div * k;
        self.load += o.load * k;
        self.store += o.store * k;
        self.branch += o.branch * k;
        self.fp += o.fp * k;
        self.simd += o.simd * k;
        self.control += o.control * k;
    }

    pub fn total(&self) -> u64 {
        self.alu
            + self.mul
            + self.div
            + self.load
            + self.store
            + self.branch
            + self.fp
            + self.simd
            + self.control
    }
}

impl CoreStats {
    /// Dynamic FP intensity: FP instructions / retired instructions
    /// (Table V definition, measured on the executed stream).
    pub fn fp_intensity(&self) -> f64 {
        if self.retired == 0 {
            return 0.0;
        }
        self.by_class.fp as f64 / self.retired as f64
    }

    /// Total stall cycles.
    pub fn stalls(&self) -> u64 {
        self.stall_loaduse
            + self.stall_tcdm
            + self.stall_fpu
            + self.stall_divsqrt
            + self.stall_icache
            + self.stall_barrier
    }

    /// Instructions per cycle.
    pub fn ipc(&self) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        self.retired as f64 / self.cycles as f64
    }

    /// Merge another core's counters (for cluster aggregation).
    pub fn merge(&mut self, o: &CoreStats) {
        self.cycles = self.cycles.max(o.cycles);
        self.retired += o.retired;
        self.int_ops += o.int_ops;
        self.flops += o.flops;
        self.bytes_loaded += o.bytes_loaded;
        self.bytes_stored += o.bytes_stored;
        self.stall_loaduse += o.stall_loaduse;
        self.stall_tcdm += o.stall_tcdm;
        self.stall_fpu += o.stall_fpu;
        self.stall_divsqrt += o.stall_divsqrt;
        self.stall_icache += o.stall_icache;
        self.stall_barrier += o.stall_barrier;
        self.branch_penalty += o.branch_penalty;
        self.multicycle_busy += o.multicycle_busy;
        self.by_class.alu += o.by_class.alu;
        self.by_class.mul += o.by_class.mul;
        self.by_class.div += o.by_class.div;
        self.by_class.load += o.by_class.load;
        self.by_class.store += o.by_class.store;
        self.by_class.branch += o.by_class.branch;
        self.by_class.fp += o.by_class.fp;
        self.by_class.simd += o.by_class.simd;
        self.by_class.control += o.by_class.control;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intensity_and_ipc() {
        let mut s = CoreStats::default();
        s.retired = 10;
        s.cycles = 20;
        s.by_class.fp = 4;
        assert!((s.fp_intensity() - 0.4).abs() < 1e-12);
        assert!((s.ipc() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn merge_takes_max_cycles_sums_work() {
        let mut a = CoreStats { cycles: 100, retired: 50, ..Default::default() };
        let b = CoreStats { cycles: 120, retired: 60, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.cycles, 120);
        assert_eq!(a.retired, 110);
    }
}
