//! The embedded power management unit: Fig. 7's power modes and the
//! wake-up machinery (§III).
//!
//! Modes, lowest to highest power: deep sleep → cognitive sleep (CWU on)
//! → retentive sleep (+ L2 retention, optionally + CWU) → SoC active →
//! cluster active. Wake-up sources: external pad, RTC, CWU interrupt.
//! After wake-up, boot is *warm* from retentive L2 (fast) or from MRAM
//! (zero retention power, but the image must be restored into L2 first —
//! the duty-cycle trade-off of §II-A).

use crate::common::Cycles;
use crate::mem::BulkChannel;

use super::tables::OperatingPoint;

/// Wake-up sources of the PMU (Table VIII row "Wake-up Sources": GPIO,
/// RTC, Cognitive).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WakeSource {
    ExternalPad,
    Rtc,
    Cognitive,
}

/// Fig. 7 power modes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PowerMode {
    /// Everything off except PMU/RTC/POR.
    DeepSleep,
    /// CWU classifying autonomously; everything else off.
    CognitiveSleep { retentive_l2_bytes: usize },
    /// L2 retention without the CWU (pad/RTC wake only).
    RetentiveSleep { retentive_l2_bytes: usize },
    /// FC + SoC domain on.
    SocActive { op: OperatingPoint, fc_util: f64 },
    /// SoC + cluster domains on.
    ClusterActive {
        op: OperatingPoint,
        fc_util: f64,
        core_util: f64,
        hwce_active: f64,
    },
}

impl PowerMode {
    /// Short stable label (error messages, lifecycle reports).
    pub fn name(&self) -> &'static str {
        match self {
            PowerMode::DeepSleep => "deep-sleep",
            PowerMode::CognitiveSleep { .. } => "cognitive-sleep",
            PowerMode::RetentiveSleep { .. } => "retentive-sleep",
            PowerMode::SocActive { .. } => "soc-active",
            PowerMode::ClusterActive { .. } => "cluster-active",
        }
    }

    /// Total chip power in this mode.
    pub fn power_w(&self) -> f64 {
        use super::tables::DEEP_SLEEP_W;
        match *self {
            PowerMode::DeepSleep => DEEP_SLEEP_W,
            PowerMode::CognitiveSleep { retentive_l2_bytes } => {
                // 1.7 µW base (§III) + retention.
                super::cwu_power_w(crate::cwu::SLEEP_CLK_HZ, super::tables::CWU_REF_DUTY, false)
                    + super::retention_power_w(retentive_l2_bytes)
            }
            PowerMode::RetentiveSleep { retentive_l2_bytes } => {
                DEEP_SLEEP_W + super::retention_power_w(retentive_l2_bytes)
            }
            PowerMode::SocActive { op, fc_util } => super::soc_power_w(op, fc_util),
            PowerMode::ClusterActive { op, fc_util, core_util, hwce_active } => {
                super::soc_power_w(op, fc_util)
                    + super::cluster_power_w(op, core_util, hwce_active)
            }
        }
    }
}

/// A malformed sleep↔wake trajectory, as a typed error instead of a
/// panic: a grid cell driving the PMU through a bad trace renders as one
/// structured `status=error` row under the sweep engine's per-cell
/// `catch_unwind` contract, and library callers get a `Result` they can
/// match on rather than an `assert!` they must pre-validate against.
#[derive(Debug, Clone, PartialEq)]
pub enum LifecycleError {
    /// [`Pmu::wake`] called while the SoC or cluster domain is already
    /// up — wake events are only meaningful from a sleep mode.
    WakeFromActive { mode: &'static str },
    /// [`Pmu::duty_cycled_power_w`] asked for more active time than the
    /// period contains.
    ActiveExceedsPeriod { active_s: f64, period_s: f64 },
    /// A non-finite or negative duration reached the PMU.
    MalformedTrace { what: String },
}

impl std::fmt::Display for LifecycleError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LifecycleError::WakeFromActive { mode } => {
                write!(f, "lifecycle error: wake from an active mode ({mode})")
            }
            LifecycleError::ActiveExceedsPeriod { active_s, period_s } => write!(
                f,
                "lifecycle error: active time {active_s} s exceeds period {period_s} s"
            ),
            LifecycleError::MalformedTrace { what } => {
                write!(f, "lifecycle error: malformed trace ({what})")
            }
        }
    }
}

impl std::error::Error for LifecycleError {}

/// Boot strategy after wake-up.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BootPath {
    /// Program/data retained in L2: resume immediately.
    WarmFromL2,
    /// Restore `image_bytes` from MRAM into L2 first.
    WarmFromMram { image_bytes: u64 },
}

/// The PMU state machine.
pub struct Pmu {
    pub mode: PowerMode,
    /// Wake events observed (source, at simulated time seconds).
    pub wake_log: Vec<(WakeSource, f64)>,
    /// Domain power-switch latency in SoC cycles (DC-DC settle + reset).
    pub domain_switch_cycles: Cycles,
}

impl Pmu {
    pub fn new() -> Self {
        Self {
            mode: PowerMode::DeepSleep,
            wake_log: Vec::new(),
            domain_switch_cycles: 2_000,
        }
    }

    pub fn enter(&mut self, mode: PowerMode) {
        self.mode = mode;
    }

    /// Handle a wake event: transition to SoC-active and return the
    /// wake-up latency in seconds at `op`. Waking an already-active
    /// domain is a [`LifecycleError`], not a panic: a malformed trace in
    /// a lifecycle grid must fail its own cell, nothing more.
    pub fn wake(
        &mut self,
        source: WakeSource,
        at_seconds: f64,
        op: OperatingPoint,
        boot: BootPath,
        mram: &dyn BulkChannel,
    ) -> Result<f64, LifecycleError> {
        if matches!(self.mode, PowerMode::SocActive { .. } | PowerMode::ClusterActive { .. }) {
            return Err(LifecycleError::WakeFromActive { mode: self.mode.name() });
        }
        self.wake_log.push((source, at_seconds));
        let switch = self.domain_switch_cycles as f64 / op.f_soc;
        let boot_t = match boot {
            BootPath::WarmFromL2 => 0.0,
            BootPath::WarmFromMram { image_bytes } => {
                mram.transfer_cycles(image_bytes, op.f_soc, false) as f64 / op.f_soc
            }
        };
        self.mode = PowerMode::SocActive { op, fc_util: 0.5 };
        Ok(switch + boot_t)
    }

    /// Average power of a duty-cycled deployment: `active_s` seconds in
    /// `active` mode per `period_s` seconds spent otherwise in `sleep`
    /// mode (the TinyML lifetime equation that motivates the CWU, §II-B).
    pub fn duty_cycled_power_w(
        active: PowerMode,
        sleep: PowerMode,
        active_s: f64,
        period_s: f64,
    ) -> Result<f64, LifecycleError> {
        if !(active_s.is_finite() && period_s.is_finite()) || active_s < 0.0 || period_s <= 0.0 {
            return Err(LifecycleError::MalformedTrace {
                what: format!("duty cycle active_s={active_s} period_s={period_s}"),
            });
        }
        if active_s > period_s {
            return Err(LifecycleError::ActiveExceedsPeriod { active_s, period_s });
        }
        Ok((active.power_w() * active_s + sleep.power_w() * (period_s - active_s)) / period_s)
    }
}

impl Default for Pmu {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::Mram;
    use crate::power::tables::{HV, NOM};

    #[test]
    fn mode_power_ordering() {
        let deep = PowerMode::DeepSleep.power_w();
        let cog = PowerMode::CognitiveSleep { retentive_l2_bytes: 0 }.power_w();
        let ret = PowerMode::CognitiveSleep { retentive_l2_bytes: 128 * 1024 }.power_w();
        let soc = PowerMode::SocActive { op: NOM, fc_util: 0.5 }.power_w();
        let cl = PowerMode::ClusterActive {
            op: HV,
            fc_util: 0.3,
            core_util: 1.0,
            hwce_active: 1.0,
        }
        .power_w();
        assert!(deep < cog && cog < ret && ret < soc && soc < cl);
        // Sanity: µW sleep, mW active.
        assert!(ret < 50e-6);
        assert!(soc > 1e-3);
    }

    #[test]
    fn wake_from_mram_pays_restore_time() {
        let mram = Mram::new();
        let mut pmu = Pmu::new();
        pmu.enter(PowerMode::CognitiveSleep { retentive_l2_bytes: 0 });
        let t_mram = pmu
            .wake(
                WakeSource::Cognitive,
                1.0,
                NOM,
                BootPath::WarmFromMram { image_bytes: 256 * 1024 },
                &mram,
            )
            .unwrap();
        let mut pmu2 = Pmu::new();
        pmu2.enter(PowerMode::RetentiveSleep { retentive_l2_bytes: 256 * 1024 });
        let t_l2 = pmu2.wake(WakeSource::Rtc, 1.0, NOM, BootPath::WarmFromL2, &mram).unwrap();
        assert!(t_mram > t_l2);
        // 256 kB at 300 MB/s ≈ 0.9 ms.
        assert!(t_mram > 0.6e-3 && t_mram < 2e-3, "t = {t_mram}");
        assert_eq!(pmu.wake_log.len(), 1);
        assert_eq!(pmu.wake_log[0].0, WakeSource::Cognitive);
    }

    #[test]
    fn mram_boot_wins_at_low_duty_cycle() {
        // The §II-A trade-off: zero retention power vs restore cost.
        // At a very low duty cycle, MRAM boot (deep sleep) beats paying
        // 1.6 MB retention continuously.
        let active = PowerMode::SocActive { op: NOM, fc_util: 1.0 };
        let sleep_ret = PowerMode::RetentiveSleep { retentive_l2_bytes: 1600 * 1024 };
        let sleep_mram = PowerMode::DeepSleep;
        // One 10 ms activation per 10 min.
        let p_ret = Pmu::duty_cycled_power_w(active, sleep_ret, 10e-3, 600.0).unwrap();
        // MRAM path: add the restore time as extra active time.
        let p_mram = Pmu::duty_cycled_power_w(active, sleep_mram, 10e-3 + 8e-3, 600.0).unwrap();
        assert!(p_mram < p_ret, "mram {p_mram} vs ret {p_ret}");

        // At a high duty cycle (4 activations/s) the per-wake MRAM
        // restore energy exceeds the standing retention power: retention
        // wins. (Crossover ≈ 2.7 wakes/s for a 256 kB image at NOM.)
        let p_ret_hi = Pmu::duty_cycled_power_w(active, sleep_ret, 10e-3, 0.25).unwrap();
        let p_mram_hi = Pmu::duty_cycled_power_w(active, sleep_mram, 18e-3, 0.25).unwrap();
        assert!(p_ret_hi < p_mram_hi, "ret {p_ret_hi} vs mram {p_mram_hi}");
    }

    #[test]
    fn cannot_wake_from_active() {
        let mram = Mram::new();
        let mut pmu = Pmu::new();
        pmu.enter(PowerMode::SocActive { op: NOM, fc_util: 0.5 });
        let err = pmu.wake(WakeSource::Rtc, 0.0, NOM, BootPath::WarmFromL2, &mram).unwrap_err();
        assert_eq!(err, LifecycleError::WakeFromActive { mode: "soc-active" });
        assert!(err.to_string().contains("wake from an active mode"));
        assert!(pmu.wake_log.is_empty(), "a refused wake is not logged");
    }

    #[test]
    fn duty_cycle_rejects_malformed_intervals() {
        let active = PowerMode::SocActive { op: NOM, fc_util: 0.5 };
        let sleep = PowerMode::DeepSleep;
        assert_eq!(
            Pmu::duty_cycled_power_w(active, sleep, 2.0, 1.0),
            Err(LifecycleError::ActiveExceedsPeriod { active_s: 2.0, period_s: 1.0 })
        );
        assert!(Pmu::duty_cycled_power_w(active, sleep, -1.0, 10.0).is_err());
        assert!(Pmu::duty_cycled_power_w(active, sleep, 0.0, 0.0).is_err());
        assert!(Pmu::duty_cycled_power_w(active, sleep, f64::NAN, 10.0).is_err());
        assert!(Pmu::duty_cycled_power_w(active, sleep, 1.0, f64::INFINITY).is_err());
    }
}
